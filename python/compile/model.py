"""L2: the JAX transformer block whose lowered HLO the Rust verifier
consumes and the Rust runtime executes.

Two variants of the same block are authored:

* ``block_baseline`` — the trusted oracle form;
* ``block_optimized`` — the framework-optimized form (reciprocal-multiply
  scaling, fused output reshape) that a production pipeline would emit.

Both call the L1 Pallas attention kernel, so the kernel's computation
lowers into the same artifacts. ``block_optimized_buggy`` reproduces the
paper's Figure-1 BSH layout fault for the bug-hunting example.

This module is build-time only: it is lowered once by ``aot.py`` and never
imported on the Rust request path.
"""

from dataclasses import dataclass

import jax.numpy as jnp

from .kernels.attention import attention
from .kernels.ref import rmsnorm_ref, silu_ref


@dataclass(frozen=True)
class BlockConfig:
    """Shape configuration of the demo block."""

    seq: int = 8
    batch: int = 2
    heads: int = 4
    head_dim: int = 8
    ffn: int = 32

    @property
    def hidden(self) -> int:
        return self.heads * self.head_dim

    @property
    def tokens(self) -> int:
        return self.seq * self.batch

    def param_shapes(self):
        h, f = self.hidden, self.ffn
        return dict(
            x=(self.tokens, h),
            g_attn=(h,),
            wq=(h, h),
            wk=(h, h),
            wv=(h, h),
            wo=(h, h),
            g_mlp=(h,),
            wg=(h, f),
            wu=(h, f),
            wd=(f, h),
        )


def _attention_part(cfg, x, g_attn, wq, wk, wv):
    xn = rmsnorm_ref(x, g_attn)
    q = (xn @ wq).reshape(cfg.tokens, cfg.heads, cfg.head_dim).transpose(1, 0, 2)
    k = (xn @ wk).reshape(cfg.tokens, cfg.heads, cfg.head_dim).transpose(1, 0, 2)
    v = (xn @ wv).reshape(cfg.tokens, cfg.heads, cfg.head_dim).transpose(1, 0, 2)
    return attention(q, k, v)  # (heads, T, head_dim) — the L1 kernel


def block_baseline(cfg, x, g_attn, wq, wk, wv, wo, g_mlp, wg, wu, wd):
    """Oracle form of the decoder block."""
    ctx = _attention_part(cfg, x, g_attn, wq, wk, wv)
    # BSH output path, oracle order: transpose then merge
    ctx = ctx.transpose(1, 0, 2).reshape(cfg.tokens, cfg.hidden)
    x = x + ctx @ wo
    xn = rmsnorm_ref(x, g_mlp)
    h = silu_ref(xn @ wg) * (xn @ wu)
    return (x + h @ wd,)


def block_optimized(cfg, x, g_attn, wq, wk, wv, wo, g_mlp, wg, wu, wd):
    """Framework-optimized form: same semantics, different HLO graph.

    Differences vs the baseline (each survives jax tracing and is closed
    by Scalify's rewrite rules): the BSH transpose is expressed as a
    two-transpose chain `(2,1,0)∘(1,2,0) ≡ (1,0,2)`, and the residual adds
    flip operand order (commutativity).
    """
    import jax.lax as lax

    ctx = _attention_part(cfg, x, g_attn, wq, wk, wv)
    # transpose chain equivalent to transpose(1, 0, 2)
    ctx = lax.transpose(lax.transpose(ctx, (2, 1, 0)), (1, 2, 0))
    ctx = ctx.reshape(cfg.tokens, cfg.hidden)
    x = (ctx @ wo) + x  # flipped residual
    xn = rmsnorm_ref(x, g_mlp)
    h = silu_ref(xn @ wg) * (xn @ wu)
    return ((h @ wd) + x,)


def block_optimized_buggy(cfg, x, g_attn, wq, wk, wv, wo, g_mlp, wg, wu, wd):
    """The Figure-1 BSH fault: reshape without the transpose."""
    ctx = _attention_part(cfg, x, g_attn, wq, wk, wv)
    # BUG: merges (heads, T) instead of (T, heads)
    ctx = ctx.reshape(cfg.tokens, cfg.hidden)
    x = x + ctx @ wo
    xn = rmsnorm_ref(x, g_mlp)
    h = silu_ref(xn @ wg) * (xn @ wu)
    return (x + h @ wd,)
