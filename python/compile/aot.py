"""AOT lowering: JAX model variants → HLO *text* artifacts.

HLO text, NOT ``lowered.compiler_ir("hlo")`` protos or ``.serialize()``:
jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids that the
runtime's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Run once at build time (``make artifacts``); Python never executes on the
verification / request path.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import (
    BlockConfig,
    block_baseline,
    block_optimized,
    block_optimized_buggy,
)


def to_hlo_text(fn, cfg: BlockConfig) -> str:
    """Lower a block function to HLO text."""
    shapes = cfg.param_shapes()
    specs = [
        jax.ShapeDtypeStruct(shapes[name], jax.numpy.float32)
        for name in (
            "x",
            "g_attn",
            "wq",
            "wk",
            "wv",
            "wo",
            "g_mlp",
            "wg",
            "wu",
            "wd",
        )
    ]
    lowered = jax.jit(lambda *args: fn(cfg, *args)).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


VARIANTS = {
    "model_single": block_baseline,
    "model_opt": block_optimized,
    "model_opt_buggy": block_optimized_buggy,
}


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = BlockConfig()
    for name, fn in VARIANTS.items():
        text = to_hlo_text(fn, cfg)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
