"""L1: flash-style attention as a Pallas kernel (interpret=True).

The kernel streams the key/value sequence in blocks with an online
(running max / running sum) softmax — the flash-attention recurrence,
adapted for TPU-style tiling:

* block sizes are chosen for VMEM residency (see DESIGN.md §L1 perf
  model): a (heads, T, d) query tile plus one (heads, block_k, d) kv tile
  fit comfortably in a TPU core's 16 MiB VMEM with double-buffering
  headroom;
* the two matmuls per block are batched over heads and contract over
  head_dim — MXU-shaped work.

Two structural choices keep the lowered HLO a pure dataflow DAG (no HLO
`while`/`call`), which the AOT interchange requires so the Rust verifier
can traverse it and the PJRT/interpreter cross-check can run it:

* the kv-block loop is **statically unrolled** (shapes are static);
* the kernel runs **gridless** (one program instance, batched over
  heads) — pallas interpret mode lowers multi-program grids via an HLO
  `while` loop.

``interpret=True`` is mandatory: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_K = 64


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int):
    """All heads at once: online-softmax attention over kv blocks."""
    q = q_ref[...]  # (nh, T, d)
    nh, t, d = q.shape
    seq = k_ref.shape[1]
    scale = 1.0 / jnp.sqrt(d).astype(q.dtype)

    n_blocks = pl.cdiv(seq, block_k)
    k_all = k_ref[...]
    v_all = v_ref[...]

    acc = jnp.zeros((nh, t, d), dtype=q.dtype)
    m = jnp.full((nh, t), -jnp.inf, dtype=q.dtype)
    l = jnp.zeros((nh, t), dtype=q.dtype)

    for i in range(n_blocks):  # static unroll — pure dataflow HLO
        start = min(i * block_k, max(seq - block_k, 0))
        k_blk = jax.lax.slice_in_dim(k_all, start, start + block_k, axis=1)
        v_blk = jax.lax.slice_in_dim(v_all, start, start + block_k, axis=1)
        s = jnp.einsum("htd,hkd->htk", q, k_blk) * scale
        # the last partial block re-reads earlier keys (the start index is
        # clamped); mask to exactly the not-yet-seen positions
        idx = start + jnp.arange(block_k)
        fresh = (idx >= i * block_k) & (idx < seq)
        s = jnp.where(fresh[None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("htk,hkd->htd", p, v_blk)
        m = m_new

    o_ref[...] = acc / l[..., None]


def attention(q, k, v, block_k: int = DEFAULT_BLOCK_K):
    """Flash-style attention over (heads, seq, head_dim) tensors."""
    nh, t, d = q.shape
    block_k = min(block_k, k.shape[1])
    kernel = functools.partial(_attention_kernel, block_k=block_k)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((nh, t, d), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, k, v)
