"""Pure-jnp correctness oracle for the Pallas attention kernel.

The reference is deliberately naive (materializes the full score matrix)
so the flash-style kernel has an independent ground truth.
"""

import jax.numpy as jnp


def attention_ref(q, k, v):
    """softmax(q·kᵀ/√d)·v over (heads, seq, head_dim) tensors."""
    d = q.shape[-1]
    scores = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(d).astype(q.dtype)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", p, v)


def rmsnorm_ref(x, g, eps=1e-5):
    """RMSNorm reference."""
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jnp.reciprocal(jnp.sqrt(var + eps)) * g


def silu_ref(x):
    """SiLU reference."""
    return x * jnp.reciprocal(1.0 + jnp.exp(-x))
