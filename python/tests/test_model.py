"""L2 model tests: variant equivalence, shapes, and AOT lowering."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import to_hlo_text, VARIANTS
from compile.model import (
    BlockConfig,
    block_baseline,
    block_optimized,
    block_optimized_buggy,
)


def _params(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    shapes = cfg.param_shapes()
    order = ["x", "g_attn", "wq", "wk", "wv", "wo", "g_mlp", "wg", "wu", "wd"]
    out = []
    for name in order:
        key, sub = jax.random.split(key)
        scale = 0.2 if name.startswith("w") else 1.0
        out.append(scale * jax.random.normal(sub, shapes[name], dtype=jnp.float32))
    return out


def test_optimized_variant_is_equivalent():
    cfg = BlockConfig()
    params = _params(cfg)
    base = block_baseline(cfg, *params)[0]
    opt = block_optimized(cfg, *params)[0]
    np.testing.assert_allclose(base, opt, rtol=1e-5, atol=1e-5)


def test_buggy_variant_diverges():
    cfg = BlockConfig()
    params = _params(cfg)
    base = block_baseline(cfg, *params)[0]
    buggy = block_optimized_buggy(cfg, *params)[0]
    assert np.abs(np.asarray(base) - np.asarray(buggy)).max() > 1e-2


def test_output_shape():
    cfg = BlockConfig()
    params = _params(cfg)
    out = block_baseline(cfg, *params)[0]
    assert out.shape == (cfg.tokens, cfg.hidden)


def test_all_variants_lower_to_hlo_text():
    cfg = BlockConfig()
    for name, fn in VARIANTS.items():
        text = to_hlo_text(fn, cfg)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # pallas interpret mode must lower to plain HLO (no custom-call
        # that the CPU PJRT client can't run)
        assert "custom-call" not in text or "Sharding" in text, name


def test_artifacts_are_deterministic():
    cfg = BlockConfig()
    a = to_hlo_text(block_baseline, cfg)
    b = to_hlo_text(block_baseline, cfg)
    assert a == b
