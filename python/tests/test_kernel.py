"""L1 kernel correctness: Pallas attention vs the pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.py is the
core correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention
from compile.kernels.ref import attention_ref


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


@settings(max_examples=25, deadline=None)
@given(
    nh=st.sampled_from([1, 2, 4]),
    t=st.sampled_from([1, 3, 8, 17, 64]),
    d=st.sampled_from([4, 8, 16]),
    block_k=st.sampled_from([4, 8, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref_shapes(nh, t, d, block_k, seed):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (nh, t, d), jnp.float32)
    k = _rand(kk, (nh, t, d), jnp.float32)
    v = _rand(kv, (nh, t, d), jnp.float32)
    out = attention(q, k, v, block_k=block_k)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_kernel_bf16(seed):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (2, 16, 8), jnp.bfloat16)
    k = _rand(kk, (2, 16, 8), jnp.bfloat16)
    v = _rand(kv, (2, 16, 8), jnp.bfloat16)
    out = attention(q, k, v, block_k=8)
    ref = attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    )
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref, rtol=5e-2, atol=5e-2
    )


def test_kernel_rows_sum_to_one_property():
    # softmax(QKᵀ)V with V = identity-ish rows exposes the row-stochastic
    # property: output rows are convex combinations of V rows
    key = jax.random.PRNGKey(0)
    q = _rand(key, (1, 8, 4), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (1, 8, 4), jnp.float32)
    v = jnp.ones((1, 8, 4), dtype=jnp.float32)
    out = attention(q, k, v, block_k=4)
    np.testing.assert_allclose(out, jnp.ones_like(out), rtol=1e-5, atol=1e-5)


def test_kernel_block_size_invariance():
    key = jax.random.PRNGKey(7)
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (2, 32, 8), jnp.float32)
    k = _rand(kk, (2, 32, 8), jnp.float32)
    v = _rand(kv, (2, 32, 8), jnp.float32)
    outs = [attention(q, k, v, block_k=b) for b in (4, 8, 16, 32)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-6)
