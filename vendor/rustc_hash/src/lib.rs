//! In-tree stand-in for the `rustc_hash` crate: the Fx multiply-rotate
//! hash specialized for small integer-ish keys, plus the `FxHashMap` /
//! `FxHashSet` aliases the main crate uses everywhere.
//!
//! The build environment is fully offline, so instead of pulling the real
//! crate we carry these ~80 lines ourselves. The hash is *not*
//! DoS-resistant — keys here are node ids, layer tags and fingerprints we
//! generate ourselves, never attacker-controlled input.

use std::hash::{BuildHasherDefault, Hasher};

/// `HashMap` keyed with [`FxHasher`].
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;

/// `HashSet` keyed with [`FxHasher`].
pub type FxHashSet<V> = std::collections::HashSet<V, FxBuildHasher>;

/// `BuildHasher` producing [`FxHasher`]s.
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// The Fx hasher: `hash = (hash rotl 5 ^ word) * seed` per word.
#[derive(Clone, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_word(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, mut bytes: &[u8]) {
        while bytes.len() >= 8 {
            let mut buf = [0u8; 8];
            buf.copy_from_slice(&bytes[..8]);
            self.add_word(u64::from_ne_bytes(buf));
            bytes = &bytes[8..];
        }
        if bytes.len() >= 4 {
            let mut buf = [0u8; 4];
            buf.copy_from_slice(&bytes[..4]);
            self.add_word(u64::from(u32::from_ne_bytes(buf)));
            bytes = &bytes[4..];
        }
        for &b in bytes {
            self.add_word(u64::from(b));
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add_word(u64::from(n));
    }

    #[inline]
    fn write_u16(&mut self, n: u16) {
        self.add_word(u64::from(n));
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add_word(u64::from(n));
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add_word(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add_word(n as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_spreading() {
        let h = |x: u64| {
            let mut hasher = FxHasher::default();
            hasher.write_u64(x);
            hasher.finish()
        };
        assert_eq!(h(42), h(42));
        assert_ne!(h(1), h(2));
        assert_ne!(h(0), 0);
    }

    #[test]
    fn map_and_set_work() {
        let mut m: FxHashMap<u32, &str> = FxHashMap::default();
        m.insert(7, "seven");
        assert_eq!(m.get(&7), Some(&"seven"));
        let s: FxHashSet<u32> = [1, 2, 2, 3].into_iter().collect();
        assert_eq!(s.len(), 3);
    }
}
