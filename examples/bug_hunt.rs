//! Bug hunt: inject production bugs from the corpus, verify, and show the
//! localized source sites (paper §5.3 / Tables 4-5 at example scale).
//!
//! Run: `cargo run --release --example bug_hunt`

use scalify::baseline::numerical_verify;
use scalify::bugs::{evaluate, new_bugs, reproduced_bugs, ExpectedLoc};

fn main() {
    println!("=== reproduced production bugs (Table 4) ===");
    let mut detected = 0;
    let mut total_detectable = 0;
    for case in reproduced_bugs() {
        let outcome = evaluate(&case);
        if case.expected != ExpectedLoc::NotApplicable {
            total_detectable += 1;
            if outcome.detected {
                detected += 1;
            }
        }
        println!(
            "{:>6}  {:<52} {}",
            case.id,
            case.description,
            if outcome.detected { "DETECTED" } else { "verified (bug outside graph)" }
        );
        for site in outcome.sites.iter().take(2) {
            println!("        ↳ {site}");
        }
    }
    println!("\ndetected {detected}/{total_detectable} detectable bugs (+2 n/a outside graph scope, as in the paper)\n");

    println!("=== new bugs (Table 5) ===");
    for case in new_bugs() {
        let outcome = evaluate(&case);
        println!(
            "{:>6}  {:<52} {}",
            case.id,
            case.description,
            if outcome.detected { "DETECTED" } else { "MISSED" }
        );
        for site in outcome.sites.iter().take(2) {
            println!("        ↳ {site}");
        }
    }

    // contrast with the ad-hoc numerical practice: a loose tolerance
    // masks the precision bug Scalify catches semantically
    let case = reproduced_bugs().into_iter().find(|c| c.id == "T4#17").unwrap();
    let pair = (case.build)();
    let loose = numerical_verify(&pair, 2, 0.5, 7);
    println!(
        "\nnumerical diffing with loose tolerance on {}: equivalent={} (max dev {:.2e}) — the fragility the paper describes",
        case.id, loose.equivalent, loose.max_dev
    );
}
