//! Verify Llama-3.1-shaped models under the paper's parallelism configs
//! (the Table-2 workload at example scale).
//!
//! Run: `cargo run --release --example verify_llama_tp`

use scalify::modelgen::{llama_pair, mixtral_pair, LlamaConfig, MixtralConfig, Parallelism};
use scalify::util::fmt_duration;
use scalify::verifier::{Session, VerifyConfig};

fn main() {
    // one session across all model/parallelism variants: the compiled
    // rewrite templates and the layer memo are shared, so later pairs
    // start warm wherever their layer structure overlaps earlier ones
    let verifier = Session::new(VerifyConfig::default());

    // Llama-3.1-8B-shaped graph at TP=32, the paper's headline workload
    let cfg = LlamaConfig::llama3_8b();
    println!(
        "Llama-8B graph: {} layers, hidden {}, heads {}, tp 32",
        cfg.layers, cfg.hidden, cfg.heads
    );
    let pair = llama_pair(&cfg, Parallelism::Tensor { tp: 32 });
    println!(
        "  baseline {} nodes, distributed {} nodes",
        pair.base.len(),
        pair.dist.len()
    );
    let report = verifier.verify(&pair).unwrap();
    println!("  {}", report.summary());
    assert!(report.verified());

    // sequence parallelism and flash decoding on the same model family
    for (label, par) in [
        ("sequence parallel (tp=32)", Parallelism::Sequence { tp: 32 }),
        ("flash decoding (kv-shard=32)", Parallelism::FlashDecoding { tp: 32 }),
    ] {
        let pair = llama_pair(&cfg, par);
        let report = verifier.verify(&pair).unwrap();
        println!("{label}: {}", report.summary());
        assert!(report.verified());
    }

    // Mixtral expert parallelism with the unrolled expert-sum baseline
    let mcfg = MixtralConfig::mixtral_8x7b();
    let pair = mixtral_pair(&mcfg, Parallelism::Expert { ep: 8 });
    let (report, dur) = {
        let t0 = std::time::Instant::now();
        let r = verifier.verify(&pair).unwrap();
        (r, t0.elapsed())
    };
    println!("Mixtral-8x7B expert parallel: {} ({})", report.summary(), fmt_duration(dur));
    assert!(report.verified());
}
