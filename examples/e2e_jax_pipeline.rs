//! End-to-end driver: all three layers composed on real JAX artifacts.
//!
//! 1. `make artifacts` lowered a transformer block (whose attention
//!    hot-spot is the L1 **Pallas kernel**) through the L2 **JAX model**
//!    into HLO-text artifacts: the trusted baseline, a framework-optimized
//!    variant, and a variant with the Figure-1 BSH layout bug injected.
//! 2. This driver (L3, Rust) parses the artifacts with Scalify's HLO
//!    parser, **verifies** baseline ≡ optimized (and catches the bug in
//!    the buggy variant), then
//! 3. loads the artifacts into the **execution runtime**, executes them with
//!    identical inputs, and numerically cross-checks the verdicts.
//!
//! Run: `make artifacts && cargo run --release --example e2e_jax_pipeline`

use scalify::hlo::parse_hlo_file;
use scalify::interp::Tensor;
use scalify::ir::Annotation;
use scalify::runtime::Executable;
use scalify::util::Prng;
use scalify::verifier::{GraphPair, Session, VerifyConfig};
use std::path::Path;

fn pair_of(base: &Path, dist: &Path) -> GraphPair {
    let bg = parse_hlo_file(base, 1).expect("parse baseline artifact");
    let dg = parse_hlo_file(dist, 1).expect("parse variant artifact");
    let ann: Vec<Annotation> = bg
        .parameters()
        .into_iter()
        .zip(dg.parameters())
        .map(|(b, d)| Annotation::replicated(b, d))
        .collect();
    GraphPair::new(bg, dg, ann)
}

fn main() {
    let dir = Path::new("artifacts");
    let single = dir.join("model_single.hlo.txt");
    let opt = dir.join("model_opt.hlo.txt");
    let buggy = dir.join("model_opt_buggy.hlo.txt");
    if !single.exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }

    let verifier = Session::new(VerifyConfig::default());

    // ---- stage 1: semantic verification of the JAX-lowered graphs ----
    let good = verifier.verify(&pair_of(&single, &opt)).unwrap();
    println!("verify baseline ≡ optimized:   {}", good.summary());
    assert!(good.verified(), "optimized artifact must verify");

    let bad = verifier.verify(&pair_of(&single, &buggy)).unwrap();
    println!("verify baseline ≡ buggy:       {}", bad.summary());
    assert!(!bad.verified(), "BSH-buggy artifact must NOT verify");

    // ---- stage 2: execute via the runtime and cross-check the verdicts ----
    let exe_single = Executable::load(&single).expect("compile baseline");
    let exe_opt = Executable::load(&opt).expect("compile optimized");
    let exe_buggy = Executable::load(&buggy).expect("compile buggy");

    let g = parse_hlo_file(&single, 1).unwrap();
    let mut prng = Prng::new(2026);
    let inputs: Vec<Tensor> = g
        .parameters()
        .iter()
        .map(|&pid| Tensor::random(g.node(pid).shape.clone(), &mut prng))
        .collect();

    let t0 = std::time::Instant::now();
    let out_single = exe_single.run(&inputs).unwrap();
    let exec_time = t0.elapsed();
    let out_opt = exe_opt.run(&inputs).unwrap();
    let out_buggy = exe_buggy.run(&inputs).unwrap();

    let dev_opt = out_single[0].max_abs_diff(&out_opt[0]);
    let dev_buggy = out_single[0].max_abs_diff(&out_buggy[0]);
    println!("runtime execution ({} params, {exec_time:?}/run):", inputs.len());
    println!("  |baseline - optimized|∞ = {dev_opt:.3e}   (verified ⇒ tiny)");
    println!("  |baseline - buggy|∞     = {dev_buggy:.3e}   (unverified ⇒ large)");
    assert!(dev_opt < 1e-4, "verified pair must agree numerically");
    assert!(dev_buggy > 1e-3, "unverified pair must diverge numerically");

    println!("\nend-to-end OK: Pallas kernel → JAX artifact → parse → verify → execute");
}
