//! Quickstart: the session-oriented API on the paper's Figure-3 example
//! (tensor-parallel matmul) and the Figure-1 BSH layout bug.
//!
//! One `Session` serves every call: rewrite templates compile once, layer
//! results memoize across runs, and malformed input is a typed error —
//! the shape you want when verification runs continuously beside a
//! training pipeline.
//!
//! Run: `cargo run --release --example quickstart`

use scalify::modelgen::demo;
use scalify::prelude::*;

fn main() -> Result<()> {
    // validated configuration: nonsense (threads = 0, zero budgets…)
    // is a ScalifyError::Config, not a panic deep in the engine
    let cfg = VerifyConfig::builder().partition(true).memoize(true).build()?;
    let session = Session::new(cfg);

    // Figure 3: Y = X·W vs contracted-dim-sharded TP + all-reduce
    let report = session.verify(&demo::matmul_allreduce_pair(4))?;
    println!("tensor-parallel matmul:   {}", report.summary());
    assert!(report.verified());

    // same structure again — served from the session's cross-run memo
    let warm = session.verify(&demo::matmul_allreduce_pair(4))?;
    assert!(warm.layers.iter().all(|l| l.memoized));
    println!("second run (warm memo):   {}", warm.summary());

    // Figure 1: the BSH layout transformation, correct and buggy
    let ok = session.verify(&demo::bsh_pair(false))?;
    println!("BSH output (correct):     {}", ok.summary());
    assert!(ok.verified());

    let buggy = session.verify(&demo::bsh_pair(true))?;
    println!("BSH output (buggy):       {}", buggy.summary());
    assert!(!buggy.verified());
    for d in buggy.discrepancies() {
        println!("  localized: {}", d.render());
    }

    // machine-readable report: serialize, parse back, same verdict
    let round = VerifyReport::from_json_str(&buggy.to_json_string())?;
    assert_eq!(round.verdict.status(), buggy.verdict.status());

    let stats = session.stats();
    println!(
        "session: {} runs, {} memo entries, {} memo hits",
        stats.runs, stats.memo_entries, stats.memo_hits
    );
    Ok(())
}
