//! Quickstart: verify the paper's Figure-3 example (tensor-parallel
//! matmul) and the Figure-1 BSH layout bug.
//!
//! Run: `cargo run --release --example quickstart`

use scalify::modelgen::demo;
use scalify::verifier::{Verifier, VerifyConfig};

fn main() {
    let verifier = Verifier::new(VerifyConfig::default());

    // Figure 3: Y = X·W vs contracted-dim-sharded TP + all-reduce
    let pair = demo::matmul_allreduce_pair(4);
    let report = verifier.verify_pair(&pair);
    println!("tensor-parallel matmul:   {}", report.summary());
    assert!(report.verified());

    // Figure 1: the BSH layout transformation, correct and buggy
    let ok = verifier.verify_pair(&demo::bsh_pair(false));
    println!("BSH output (correct):     {}", ok.summary());
    assert!(ok.verified());

    let buggy = verifier.verify_pair(&demo::bsh_pair(true));
    println!("BSH output (buggy):       {}", buggy.summary());
    assert!(!buggy.verified());
    for d in buggy.discrepancies() {
        println!("  localized: {}", d.render());
    }
}
