//! Integration tests for the verification service: concurrent clients
//! sharing one warm session, and persistent cross-process memo caching.
//!
//! The concurrency tests drive an in-process [`Server`] over real TCP;
//! the restart test spawns the actual `scalify` binary
//! (`CARGO_BIN_EXE_scalify`) twice against one `--cache-dir`, so the
//! "second process starts warm" claim is tested process-for-process.

use scalify::service::{
    CacheLoad, Client, MemoCache, ServeConfig, Server, VerifySource, CACHE_FILE,
};
use scalify::verifier::VerifyConfig;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};

fn tiny_server() -> Server {
    Server::start(ServeConfig {
        queue_capacity: 8,
        workers: 4,
        verify: VerifyConfig { threads: 2, ..VerifyConfig::default() },
        ..ServeConfig::default()
    })
    .expect("server starts on an ephemeral port")
}

/// The request mix: three clean zoo pairs across model families plus a
/// bug-injected pair that must come back unverified.
fn request_mix() -> Vec<(&'static str, VerifySource, bool)> {
    vec![
        (
            "llama-tp2",
            VerifySource::Model {
                model: "llama-tiny".into(),
                par: "tp2".into(),
                layers: None,
                edit_layer: None,
            },
            true,
        ),
        (
            "mixtral-ep4",
            VerifySource::Model {
                model: "mixtral-tiny".into(),
                par: "ep4".into(),
                layers: None,
                edit_layer: None,
            },
            true,
        ),
        (
            "dpstep-dp2z1",
            VerifySource::Model {
                model: "dpstep-tiny".into(),
                par: "dp2z1".into(),
                layers: None,
                edit_layer: None,
            },
            true,
        ),
        ("bug-T4#1", VerifySource::Bug { id: "T4#1".into() }, false),
    ]
}

#[test]
fn eight_concurrent_clients_get_deterministic_verdicts_and_a_warming_memo() {
    let server = tiny_server();
    let addr = server.local_addr().to_string();

    let run_wave = || -> Vec<BTreeMap<String, bool>> {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut client = Client::connect(&addr).expect("connect");
                    let mut verdicts = BTreeMap::new();
                    for (label, source, _) in request_mix() {
                        let (report, _, _) =
                            client.verify(source).unwrap_or_else(|e| panic!("{label}: {e}"));
                        verdicts.insert(label.to_string(), report.verified());
                    }
                    verdicts
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    };

    let wave1 = run_wave();
    let expected: BTreeMap<String, bool> = request_mix()
        .into_iter()
        .map(|(label, _, verified)| (label.to_string(), verified))
        .collect();
    for verdicts in &wave1 {
        assert_eq!(verdicts, &expected, "every client must see the same verdicts");
    }

    let mut probe = Client::connect(&addr).expect("connect");
    let after_wave1 = probe.stats().expect("stats");
    assert_eq!(after_wave1.jobs, 32, "8 clients x 4 requests");

    // a second identical wave replays the now-warm memo
    let wave2 = run_wave();
    for verdicts in &wave2 {
        assert_eq!(verdicts, &expected, "verdicts must be stable across waves");
    }
    let after_wave2 = probe.stats().expect("stats");
    assert_eq!(after_wave2.jobs, 64);
    assert!(
        after_wave2.memo_hits > after_wave1.memo_hits,
        "second wave must strictly increase memo hits ({} -> {})",
        after_wave1.memo_hits,
        after_wave2.memo_hits
    );
    // the shared memo holds one entry set, not one per client
    assert_eq!(after_wave2.memo_entries, after_wave1.memo_entries);

    probe.shutdown().expect("shutdown");
    server.wait();
}

/// Child daemon that is killed even when an assertion fails mid-test.
struct DaemonGuard {
    child: Child,
    addr: String,
}

impl DaemonGuard {
    fn spawn(cache_dir: &std::path::Path) -> DaemonGuard {
        let mut child = Command::new(env!("CARGO_BIN_EXE_scalify"))
            .args([
                "serve",
                "--addr",
                "127.0.0.1:0",
                "--cache-dir",
                cache_dir.to_str().expect("utf-8 tmpdir"),
                "--threads",
                "2",
            ])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning the scalify binary");
        // the daemon prints `scalify: serving on 127.0.0.1:PORT` first
        let stdout = child.stdout.take().expect("piped stdout");
        let mut line = String::new();
        BufReader::new(stdout).read_line(&mut line).expect("daemon banner");
        let addr = line
            .trim()
            .rsplit(' ')
            .next()
            .expect("banner carries the address")
            .to_string();
        assert!(addr.contains(':'), "unexpected banner: {line:?}");
        DaemonGuard { child, addr }
    }
}

impl Drop for DaemonGuard {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn service_tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("scalify-service-test-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn a_restarted_daemon_answers_its_first_request_from_the_disk_cache() {
    let cache_dir = service_tmpdir("restart");
    let source = VerifySource::Model {
        model: "llama-tiny".into(),
        par: "tp2".into(),
        layers: None,
        edit_layer: None,
    };

    // first process: cold start, verify, shut down cleanly
    {
        let mut daemon = DaemonGuard::spawn(&cache_dir);
        let addr = daemon.addr.clone();
        let mut client = Client::connect(&addr).expect("connect");
        let (report, _, stats) = client.verify(source.clone()).expect("first verify");
        assert!(report.verified());
        assert!(stats.memo_misses > 0, "a cold daemon must compute layers");
        assert_eq!(stats.cache_entries_loaded, 0);
        client.shutdown().expect("shutdown");
        // wait for a clean exit so every cache flush has landed
        let _ = daemon.child.wait();
    }
    assert!(
        cache_dir.join(CACHE_FILE).exists(),
        "the daemon must have flushed its memo to {}",
        cache_dir.display()
    );

    // second process, same cache dir: the very first request replays the
    // previous process's layer proofs
    {
        let daemon = DaemonGuard::spawn(&cache_dir);
        let mut client = Client::connect(&daemon.addr).expect("connect");
        let (report, _, stats) = client.verify(source).expect("warm verify");
        assert!(report.verified());
        assert!(
            stats.cache_entries_loaded > 0,
            "the restarted daemon must preload the persisted entries"
        );
        assert!(
            stats.memo_hits > 0,
            "first request after restart must hit the preloaded memo"
        );
        assert_eq!(
            stats.memo_misses, 0,
            "no layer should be recomputed after a clean warm start"
        );
        assert!(report.layers.iter().all(|l| l.memoized));
        client.shutdown().expect("shutdown");
    }
    let _ = std::fs::remove_dir_all(&cache_dir);
}

#[test]
fn a_corrupted_cache_file_degrades_to_a_cold_start_not_an_error() {
    let cache_dir = service_tmpdir("corrupt");
    std::fs::create_dir_all(&cache_dir).expect("mkdir");
    std::fs::write(cache_dir.join(CACHE_FILE), "{ definitely not valid json")
        .expect("plant corruption");

    // opening the store directly reports the degradation...
    let (_, load): (MemoCache, CacheLoad) =
        MemoCache::open(&cache_dir).expect("corruption is not an open error");
    assert_eq!(load.loaded, 0);
    assert!(load.warning.expect("must warn").contains("starting cold"));

    // ...and a server over the same directory starts, serves, and heals
    // the file on its next write
    let server = Server::start(ServeConfig {
        cache_dir: Some(cache_dir.clone()),
        queue_capacity: 4,
        workers: 2,
        verify: VerifyConfig { threads: 2, ..VerifyConfig::default() },
        ..ServeConfig::default()
    })
    .expect("server must start despite the corrupt cache");
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).expect("connect");
    let (report, _, stats) = client
        .verify(VerifySource::Model {
            model: "llama-tiny".into(),
            par: "tp2".into(),
            layers: None,
            edit_layer: None,
        })
        .expect("verify");
    assert!(report.verified());
    assert_eq!(stats.cache_entries_loaded, 0, "cold start after corruption");
    client.shutdown().expect("shutdown");
    server.wait();

    let (_, load) = MemoCache::open(&cache_dir).expect("reopen");
    assert!(load.warning.is_none(), "the flush must have replaced the corrupt file");
    assert!(load.loaded > 0);
    let _ = std::fs::remove_dir_all(&cache_dir);
}

#[test]
fn inline_hlo_pairs_verify_over_the_wire() {
    // round-trip a pair through the HLO printer and the wire protocol;
    // the inline path annotates parameters positionally as replicated, so
    // it needs a pair whose inputs really are replicated
    use scalify::hlo::print_hlo_module;
    use scalify::modelgen::demo;

    let pair = demo::microbatch_pair(false);
    let base_text = print_hlo_module(&pair.base);
    let dist_text = print_hlo_module(&pair.dist);

    let server = tiny_server();
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).expect("connect");
    let (report, _, _) = client
        .verify(VerifySource::Hlo { base: base_text, dist: dist_text, cores: 2 })
        .expect("inline verify");
    assert!(report.verified(), "{:?}", report.verdict);
    client.shutdown().expect("shutdown");
    server.wait();
}

#[test]
fn raw_protocol_lines_work_without_the_typed_client() {
    // a plain netcat-style exchange: write a line, read a line
    let server = tiny_server();
    let addr = server.local_addr();
    let stream = std::net::TcpStream::connect(addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);

    writer.write_all(b"{\"cmd\":\"stats\"}\n").expect("send");
    writer.flush().expect("flush");
    let mut line = String::new();
    reader.read_line(&mut line).expect("recv");
    assert!(line.contains("\"ok\":true"), "{line}");
    assert!(line.contains("\"memo_entries\""), "{line}");

    writer.write_all(b"{\"cmd\":\"shutdown\"}\n").expect("send");
    writer.flush().expect("flush");
    line.clear();
    reader.read_line(&mut line).expect("recv");
    assert!(line.contains("\"shutdown\""), "{line}");
    server.wait();
}
