//! Integration tests for incremental verify-on-diff (`verify --against`):
//! the 100%-reuse contract on unchanged graphs, one-op-edit localization,
//! cold-vs-incremental differential over the bug corpus, and the on-disk
//! state round trip.

use scalify::bugs::{new_bugs, reproduced_bugs};
use scalify::diff::{one_op_edit, one_sided_edit};
use scalify::modelgen::llama_pair;
use scalify::prelude::*;

fn tiny_pair() -> GraphPair {
    llama_pair(&LlamaConfig::tiny(), Parallelism::Tensor { tp: 2 })
}

/// Sorted localization keys of a report — the (site, func, layer)
/// triples two runs must agree on.
fn sites(report: &VerifyReport) -> Vec<(String, String, Option<u32>)> {
    let mut keys: Vec<_> = report
        .discrepancies()
        .iter()
        .map(|d| (d.site.clone(), d.func.clone(), d.layer))
        .collect();
    keys.sort();
    keys
}

#[test]
fn unchanged_reverify_reuses_every_layer() {
    let pair = tiny_pair();
    let (cold, state) =
        Session::new(VerifyConfig::default()).verify_capture(&pair).unwrap();
    assert!(cold.verified(), "{}", cold.summary());

    // a fresh session, as a separate CLI invocation would be
    let (warm, _) =
        Session::new(VerifyConfig::default()).verify_against(&pair, &state).unwrap();
    assert!(warm.verified(), "{}", warm.summary());
    assert_eq!(warm.layers.len(), cold.layers.len());
    assert!(
        warm.layers.iter().all(|l| l.reused),
        "every layer must replay on an unchanged graph: {}",
        warm.summary()
    );
    assert!(warm.layers.iter().all(|l| !l.reverified && l.delta_nodes == 0));
}

#[test]
fn one_op_edit_reverifies_exactly_the_edited_layer() {
    let pair = tiny_pair();
    let (_, state) =
        Session::new(VerifyConfig::default()).verify_capture(&pair).unwrap();

    let edited = one_op_edit(&pair, 1).unwrap();
    // the diff front end localizes the edit before any verification
    let diff = GraphDiff::compute(&pair.dist, &edited.dist);
    assert_eq!(diff.dirty_layers, vec![1]);

    let (report, _) =
        Session::new(VerifyConfig::default()).verify_against(&edited, &state).unwrap();
    assert!(report.verified(), "equivalence-preserving edit: {}", report.summary());
    let reverified: Vec<_> = report.layers.iter().filter(|l| l.reverified).collect();
    assert_eq!(reverified.len(), 1, "{}", report.summary());
    assert!(reverified[0].delta_nodes > 0, "the edited layer's node delta is visible");
    let reused = report.layers.iter().filter(|l| l.reused).count();
    assert_eq!(reused, report.layers.len() - 1);
}

#[test]
fn one_sided_edit_localizes_identically_cold_and_incremental() {
    let pair = tiny_pair();
    let (_, state) =
        Session::new(VerifyConfig::default()).verify_capture(&pair).unwrap();

    // dist-only bump: v2 is genuinely wrong in layer 1
    let broken = one_sided_edit(&pair, 1).unwrap();
    let cold = Session::new(VerifyConfig::default()).verify(&broken).unwrap();
    let (inc, _) =
        Session::new(VerifyConfig::default()).verify_against(&broken, &state).unwrap();

    assert!(!cold.verified(), "{}", cold.summary());
    assert!(!inc.verified(), "{}", inc.summary());
    assert_eq!(
        sites(&cold),
        sites(&inc),
        "incremental re-verification must localize exactly like cold"
    );
    assert!(inc.layers.iter().any(|l| l.reused), "unaffected layers still replay");
}

#[test]
fn state_survives_the_disk_round_trip() {
    let pair = tiny_pair();
    let (_, state) =
        Session::new(VerifyConfig::default()).verify_capture(&pair).unwrap();
    let path = std::env::temp_dir()
        .join(format!("scalify-incr-test-{}.json", std::process::id()));
    state.save(&path).unwrap();
    let loaded = VerifyState::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(loaded, state);
    assert!(loaded.matches_graph(&pair.dist));

    let (report, _) =
        Session::new(VerifyConfig::default()).verify_against(&pair, &loaded).unwrap();
    assert!(report.verified() && report.layers.iter().all(|l| l.reused));
}

/// Differential over the whole bug corpus: verifying a buggy pair
/// against its *own* captured state must reproduce the cold verdict and
/// the cold localization exactly. Failed layers never replay (their
/// state entry is marked unverified), so each bug is re-found, not
/// remembered.
#[test]
fn bug_corpus_verdicts_match_cold_and_incremental() {
    for case in reproduced_bugs().into_iter().chain(new_bugs()) {
        let pair = (case.build)();
        let (cold, state) = match Session::new(VerifyConfig::default()).verify_capture(&pair)
        {
            Ok(out) => out,
            // a corpus case the verifier cannot process at all is outside
            // this differential (evaluate() covers those)
            Err(_) => continue,
        };
        let (inc, _) = Session::new(VerifyConfig::default())
            .verify_against(&pair, &state)
            .unwrap_or_else(|e| panic!("{}: incremental run errored: {e}", case.id));
        assert_eq!(
            cold.verified(),
            inc.verified(),
            "{}: cold {} vs incremental {}",
            case.id,
            cold.summary(),
            inc.summary()
        );
        assert_eq!(sites(&cold), sites(&inc), "{}: localization differs", case.id);
        for (c, i) in cold.layers.iter().zip(&inc.layers) {
            if !c.verified {
                assert!(
                    !i.reused,
                    "{}: a failed layer must re-verify, never replay",
                    case.id
                );
            }
        }
    }
}
