//! Transform-engine acceptance grid (`cargo test --test transform_engine`).
//!
//! The PR's acceptance criteria, as an executable suite: the engine must
//! derive pipeline graphs for pp ∈ {2, 4} and data-parallel/ZeRO graphs
//! for dp ∈ {2, 4} × stages {0, 1, 2} that `Session::verify` proves
//! equivalent to their baselines, and the engine-derived tensor/sequence
//! variants must verify against the same baselines the hand-built golden
//! builders verify against, with the two distributed graphs numerically
//! interchangeable.

use scalify::interp::{run_single, run_spmd, Tensor};
use scalify::modelgen::llama::shard_inputs;
use scalify::modelgen::{
    dpstep_pair, golden_llama_pair, llama_pair, LlamaConfig, Parallelism, TrainStepConfig,
};
use scalify::util::Prng;
use scalify::verifier::{Session, VerifyConfig, VerifyReport};

fn session() -> Session {
    Session::new(VerifyConfig { parallel: false, ..VerifyConfig::default() })
}

fn render(report: &VerifyReport) -> String {
    let mut s = report.summary();
    for d in report.discrepancies() {
        s.push('\n');
        s.push_str(&d.render());
    }
    s
}

#[test]
fn pipeline_grid_verifies() {
    // pp ∈ {2, 4}; four layers so pp4 has one layer per stage
    let cfg = LlamaConfig { layers: 4, ..LlamaConfig::tiny() };
    let session = session();
    for pp in [2u32, 4] {
        let pair = llama_pair(&cfg, Parallelism::Pipeline { pp });
        assert_eq!(pair.dist.num_cores, pp);
        let sends = pair.dist.nodes.iter().filter(|n| n.op.name() == "send").count();
        assert_eq!(sends as u32, pp - 1, "one boundary per adjacent stage pair");
        let report = session.verify(&pair).unwrap();
        assert!(report.verified(), "pp{pp}: {}", render(&report));
        // every stage shows up in the per-layer reports
        for s in 0..pp {
            assert!(
                report.layers.iter().any(|l| l.stage == Some(s)),
                "pp{pp}: stage {s} missing from the report"
            );
        }
    }
}

#[test]
fn data_parallel_zero_grid_verifies() {
    let cfg = TrainStepConfig::tiny();
    let session = session();
    for dp in [2u32, 4] {
        for zero_stage in [0u8, 1, 2] {
            let pair = dpstep_pair(&cfg, Parallelism::Data { dp, zero_stage });
            assert_eq!(pair.dist.num_cores, dp);
            let report = session.verify(&pair).unwrap();
            assert!(report.verified(), "dp{dp}z{zero_stage}: {}", render(&report));
        }
    }
}

/// The 3D-mesh acceptance grid: `pp2dp2tp2` llama-tiny (the PR's headline
/// scenario) plus the dp×tp training-step meshes — one SPMD graph each,
/// subgroup collectives, verified equivalent and numerically faithful.
#[test]
fn mesh_grid_verifies() {
    use scalify::ir::Mesh;
    let session = session();

    // llama-tiny under pp2dp2tp2: 4-core [dp,tp] SPMD graph + stages
    let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::Mesh3D { pp: 2, dp: 2, tp: 2 });
    assert_eq!(pair.dist.num_cores, 4);
    assert_eq!(pair.dist.mesh, vec![2, 2]);
    let tp_groups = Mesh::new(vec![2, 2]).groups_for(1 << 1);
    assert!(
        pair.dist.nodes.iter().any(|n| matches!(
            &n.op,
            scalify::ir::Op::AllReduce { groups, .. } if *groups == tp_groups
        )),
        "pp2dp2tp2 must emit tp-subgroup all-reduces"
    );
    let report = session.verify(&pair).unwrap();
    assert!(report.verified(), "pp2dp2tp2: {}", render(&report));

    // training-step meshes: dp-subgroup gradient reduction in the same graph
    for (pp, dp, tp) in [(1u32, 2u32, 2u32), (2, 2, 2)] {
        let pair = dpstep_pair(&TrainStepConfig::tiny(), Parallelism::Mesh3D { pp, dp, tp });
        assert_eq!(pair.dist.num_cores, dp * tp);
        let report = session.verify(&pair).unwrap();
        assert!(report.verified(), "pp{pp}dp{dp}tp{tp}: {}", render(&report));

        let mut p = Prng::new(211 + (pp + dp + tp) as u64);
        let base_inputs: Vec<Tensor> = pair
            .base
            .parameters()
            .iter()
            .map(|&pid| Tensor::random(pair.base.node(pid).shape.clone(), &mut p))
            .collect();
        let base_out = run_single(&pair.base, &base_inputs).unwrap();
        let d_out =
            run_spmd(&pair.dist, &shard_inputs(&pair, &base_inputs).unwrap()).unwrap();
        for core in 0..pair.dist.num_cores as usize {
            for (k, (b, d)) in base_out.iter().zip(&d_out[core]).enumerate() {
                let diff = b.max_abs_diff(d);
                assert!(
                    diff < 1e-3,
                    "pp{pp}dp{dp}tp{tp} core {core} output {k} diverged by {diff}"
                );
            }
        }
    }
}

/// Engine-derived tensor/sequence graphs against the hand-built golden
/// builders: both verify, and on identical inputs the two distributed
/// graphs produce the same outputs on every core.
#[test]
fn engine_vs_golden_differential() {
    let cfg = LlamaConfig::tiny();
    let session = session();
    for (par, seed) in [
        (Parallelism::Tensor { tp: 2 }, 101u64),
        (Parallelism::Sequence { tp: 2 }, 103),
    ] {
        let engine = llama_pair(&cfg, par);
        let golden = golden_llama_pair(&cfg, par);

        let er = session.verify(&engine).unwrap();
        assert!(er.verified(), "engine {}: {}", par.label(), render(&er));
        let gr = session.verify(&golden).unwrap();
        assert!(gr.verified(), "golden {}: {}", par.label(), render(&gr));

        let mut p = Prng::new(seed);
        let base_inputs: Vec<Tensor> = engine
            .base
            .parameters()
            .iter()
            .map(|&pid| Tensor::random(engine.base.node(pid).shape.clone(), &mut p))
            .collect();
        let base_out = run_single(&engine.base, &base_inputs).unwrap();
        let e_out =
            run_spmd(&engine.dist, &shard_inputs(&engine, &base_inputs).unwrap()).unwrap();
        let g_out =
            run_spmd(&golden.dist, &shard_inputs(&golden, &base_inputs).unwrap()).unwrap();
        for core in 0..engine.dist.num_cores as usize {
            let de = base_out[0].max_abs_diff(&e_out[core][0]);
            let dg = base_out[0].max_abs_diff(&g_out[core][0]);
            let cross = e_out[core][0].max_abs_diff(&g_out[core][0]);
            assert!(de < 1e-4, "{} engine core {core}: {de}", par.label());
            assert!(dg < 1e-4, "{} golden core {core}: {dg}", par.label());
            assert!(cross < 1e-4, "{} engine≠golden on core {core}: {cross}", par.label());
        }
    }
}

/// The memo makes scenario sweeps cheap: verifying tp2 after sp2 in one
/// session reuses compiled templates, and repeated pipeline layers hit
/// the fingerprint memo.
#[test]
fn scenario_sweep_shares_one_session() {
    let cfg = LlamaConfig { layers: 4, ..LlamaConfig::tiny() };
    let session = session();
    for par in [
        Parallelism::Tensor { tp: 2 },
        Parallelism::Sequence { tp: 2 },
        Parallelism::Pipeline { pp: 2 },
    ] {
        let pair = llama_pair(&cfg, par);
        let report = session.verify(&pair).unwrap();
        assert!(report.verified(), "{}: {}", par.label(), render(&report));
    }
    let stats = session.stats();
    assert_eq!(stats.runs, 3);
    assert!(stats.memo_hits > 0, "identical decoder layers must replay");
}
