//! Bug-corpus integration suite (`cargo test --test bug_corpus`).
//!
//! Promoted from inline unit checks to a first-class suite: every
//! catalog case — Table 4 (19 reproduced production bugs), Table 5 (5 new
//! bugs) and the pipeline/data-parallel cases the transform engine opened
//! — is asserted for **both** detection and localization precision
//! against its paper-reported (or design-time) outcome. CI runs this
//! suite as its own gate so a regression in any single case fails the
//! build with the case id in the assertion message.

use scalify::bugs::{
    evaluate, new_bugs, parallel_transform_bugs, replica_group_bugs, reproduced_bugs,
    BugCase, ExpectedLoc, LocResult,
};

/// Assert one case keeps its catalogued detection + localization outcome.
fn assert_case(case: &BugCase) {
    let outcome = evaluate(case);
    match case.expected {
        ExpectedLoc::NotApplicable => {
            // manifests outside graph compilation: Scalify must (correctly)
            // report the compiled pair as equivalent
            assert!(
                !outcome.detected,
                "{}: should be missed (outside the compiled graph), got {:?}",
                case.id, outcome.sites
            );
        }
        ExpectedLoc::Instruction => {
            assert!(outcome.detected, "{}: not detected", case.id);
            assert_eq!(
                outcome.loc,
                LocResult::Instruction,
                "{}: expected instruction-precise localization at {}, got {:?} ({:?})",
                case.id,
                case.truth_site,
                outcome.loc,
                outcome.sites
            );
        }
        ExpectedLoc::Function => {
            assert!(outcome.detected, "{}: not detected", case.id);
            assert!(
                matches!(outcome.loc, LocResult::Instruction | LocResult::Function),
                "{}: expected >= function-precise localization in {}(), got {:?} ({:?})",
                case.id,
                case.truth_func,
                outcome.loc,
                outcome.sites
            );
        }
    }
}

#[test]
fn corpus_sizes_match_paper() {
    assert_eq!(reproduced_bugs().len(), 19, "Table 4 rows");
    assert_eq!(new_bugs().len(), 5, "Table 5 rows");
    assert!(
        parallel_transform_bugs().len() >= 4,
        "pipeline/data-parallel catalog cases"
    );
}

#[test]
fn reproduced_bugs_keep_their_outcomes() {
    for case in reproduced_bugs() {
        assert_case(&case);
    }
}

#[test]
fn new_bugs_keep_their_outcomes() {
    for case in new_bugs() {
        assert_case(&case);
    }
}

#[test]
fn parallel_transform_bugs_keep_their_outcomes() {
    for case in parallel_transform_bugs() {
        assert_case(&case);
    }
}

#[test]
fn replica_group_bugs_keep_their_outcomes() {
    assert_eq!(replica_group_bugs().len(), 3, "RG#1..3");
    for case in replica_group_bugs() {
        assert_case(&case);
    }
}

#[test]
fn every_case_has_usable_ground_truth() {
    for case in reproduced_bugs()
        .iter()
        .chain(new_bugs().iter())
        .chain(parallel_transform_bugs().iter())
        .chain(replica_group_bugs().iter())
    {
        match case.expected {
            ExpectedLoc::NotApplicable => {}
            _ => {
                assert!(
                    !case.truth_site.is_empty() && !case.truth_func.is_empty(),
                    "{}: detectable case without a ground-truth site",
                    case.id
                );
                assert!(
                    case.truth_site.contains(':'),
                    "{}: truth site must be file:line",
                    case.id
                );
            }
        }
    }
}
