//! Bug-corpus integration suite (`cargo test --test bug_corpus`).
//!
//! Promoted from inline unit checks to a first-class suite: every
//! catalog case — Table 4 (19 reproduced production bugs), Table 5 (5 new
//! bugs) and the pipeline/data-parallel cases the transform engine opened
//! — is asserted for **both** detection and localization precision
//! against its paper-reported (or design-time) outcome. CI runs this
//! suite as its own gate so a regression in any single case fails the
//! build with the case id in the assertion message.

use scalify::bugs::{
    evaluate, new_bugs, parallel_transform_bugs, replica_group_bugs, reproduced_bugs,
    BugCase, ExpectedLoc, LocResult,
};

/// Assert one case keeps its catalogued detection + localization outcome.
fn assert_case(case: &BugCase) {
    let outcome = evaluate(case);
    match case.expected {
        ExpectedLoc::NotApplicable => {
            // manifests outside graph compilation: Scalify must (correctly)
            // report the compiled pair as equivalent
            assert!(
                !outcome.detected,
                "{}: should be missed (outside the compiled graph), got {:?}",
                case.id, outcome.sites
            );
        }
        ExpectedLoc::Instruction => {
            assert!(outcome.detected, "{}: not detected", case.id);
            assert_eq!(
                outcome.loc,
                LocResult::Instruction,
                "{}: expected instruction-precise localization at {}, got {:?} ({:?})",
                case.id,
                case.truth_site,
                outcome.loc,
                outcome.sites
            );
        }
        ExpectedLoc::Function => {
            assert!(outcome.detected, "{}: not detected", case.id);
            assert!(
                matches!(outcome.loc, LocResult::Instruction | LocResult::Function),
                "{}: expected >= function-precise localization in {}(), got {:?} ({:?})",
                case.id,
                case.truth_func,
                outcome.loc,
                outcome.sites
            );
        }
    }
}

#[test]
fn corpus_sizes_match_paper() {
    assert_eq!(reproduced_bugs().len(), 19, "Table 4 rows");
    assert_eq!(new_bugs().len(), 5, "Table 5 rows");
    assert!(
        parallel_transform_bugs().len() >= 4,
        "pipeline/data-parallel catalog cases"
    );
}

#[test]
fn reproduced_bugs_keep_their_outcomes() {
    for case in reproduced_bugs() {
        assert_case(&case);
    }
}

#[test]
fn new_bugs_keep_their_outcomes() {
    for case in new_bugs() {
        assert_case(&case);
    }
}

#[test]
fn parallel_transform_bugs_keep_their_outcomes() {
    for case in parallel_transform_bugs() {
        assert_case(&case);
    }
}

#[test]
fn replica_group_bugs_keep_their_outcomes() {
    assert_eq!(replica_group_bugs().len(), 3, "RG#1..3");
    for case in replica_group_bugs() {
        assert_case(&case);
    }
}

#[test]
fn every_case_has_usable_ground_truth() {
    for case in reproduced_bugs()
        .iter()
        .chain(new_bugs().iter())
        .chain(parallel_transform_bugs().iter())
        .chain(replica_group_bugs().iter())
    {
        match case.expected {
            ExpectedLoc::NotApplicable => {}
            _ => {
                assert!(
                    !case.truth_site.is_empty() && !case.truth_func.is_empty(),
                    "{}: detectable case without a ground-truth site",
                    case.id
                );
                assert!(
                    case.truth_site.contains(':'),
                    "{}: truth site must be file:line",
                    case.id
                );
            }
        }
    }
}

/// The indexed incremental e-matcher must agree with the naive
/// full-rescan matcher on every corpus case: same verdict, same
/// localization sites, same per-layer e-graph sizes — and never more
/// e-match work. (The transform-grid half of this differential lives in
/// `proptest::prop_indexed_matcher_is_equivalent_to_naive`.)
#[test]
fn indexed_matcher_agrees_with_naive_on_the_whole_corpus() {
    use scalify::egraph::{MatchMode, RunLimits};
    use scalify::verifier::{Session, VerifyConfig, VerifyReport};

    fn mode_cfg(mode: MatchMode) -> VerifyConfig {
        VerifyConfig {
            parallel: false,
            memoize: false,
            limits: RunLimits { match_mode: mode, ..RunLimits::default() },
            ..VerifyConfig::default()
        }
    }
    fn tried(r: &VerifyReport) -> usize {
        r.layers.iter().map(|l| l.matches_tried).sum()
    }
    fn sites(r: &VerifyReport) -> Vec<String> {
        let mut v: Vec<String> =
            r.discrepancies().iter().map(|d| d.site.clone()).collect();
        v.sort();
        v
    }

    let mut all: Vec<BugCase> = reproduced_bugs();
    all.extend(new_bugs());
    all.extend(parallel_transform_bugs());
    all.extend(replica_group_bugs());
    for case in &all {
        let pair = (case.build)();
        let indexed = Session::new(mode_cfg(MatchMode::Indexed)).verify(&pair);
        let naive = Session::new(mode_cfg(MatchMode::Naive)).verify(&pair);
        match (indexed, naive) {
            (Ok(a), Ok(b)) => {
                assert_eq!(
                    a.verdict.status(),
                    b.verdict.status(),
                    "{}: verdict diverged between matchers",
                    case.id
                );
                assert_eq!(a.layers.len(), b.layers.len(), "{}: layer count", case.id);
                for (la, lb) in a.layers.iter().zip(&b.layers) {
                    assert_eq!(
                        la.verified, lb.verified,
                        "{}: layer {} verdict diverged",
                        case.id, la.layer
                    );
                    assert_eq!(
                        la.egraph_nodes, lb.egraph_nodes,
                        "{}: layer {} e-node count diverged",
                        case.id, la.layer
                    );
                    assert_eq!(
                        la.egraph_classes, lb.egraph_classes,
                        "{}: layer {} e-class count diverged",
                        case.id, la.layer
                    );
                }
                assert!(
                    tried(&a) <= tried(&b),
                    "{}: indexed matcher did MORE e-match work ({} vs {})",
                    case.id,
                    tried(&a),
                    tried(&b)
                );
                assert_eq!(sites(&a), sites(&b), "{}: localization diverged", case.id);
            }
            // typed structural rejections (e.g. malformed replica groups)
            // must reject identically — they never reach the matcher
            (Err(a), Err(b)) => {
                assert_eq!(a.to_string(), b.to_string(), "{}: errors diverged", case.id)
            }
            (a, b) => panic!(
                "{}: one matcher errored (indexed ok={}, naive ok={})",
                case.id,
                a.is_ok(),
                b.is_ok()
            ),
        }
    }
}
