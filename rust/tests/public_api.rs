//! Integration tests over the public crate API — what a downstream user
//! of the library actually touches.

use scalify::prelude::*;
use scalify::bugs;
use scalify::modelgen::{llama_pair, mixtral_pair, demo};

fn verifier() -> Session {
    Session::new(VerifyConfig::default())
}

#[test]
fn model_matrix_verifies() {
    // every (model, parallelism, degree) combination the CLI exposes, at
    // test scale
    let llama =
        LlamaConfig { layers: 2, hidden: 16, heads: 4, kv_heads: 4, ffn: 32, seqlen: 8, batch: 2 };
    for par in [
        Parallelism::Tensor { tp: 2 },
        Parallelism::Tensor { tp: 4 },
        Parallelism::Sequence { tp: 2 },
        Parallelism::Sequence { tp: 4 },
        Parallelism::FlashDecoding { tp: 2 },
        Parallelism::FlashDecoding { tp: 4 },
    ] {
        let pair = llama_pair(&llama, par);
        let report = verifier().verify(&pair).unwrap();
        assert!(report.verified(), "{}: {:?}", par.label(), report.verdict);
    }
    for ep in [2u32, 4, 8] {
        let mixtral =
            MixtralConfig { layers: 2, hidden: 8, experts: ep as i64, ffn: 8, seqlen: 2, batch: 1 };
        let pair = mixtral_pair(&mixtral, Parallelism::Expert { ep });
        let report = verifier().verify(&pair).unwrap();
        assert!(report.verified(), "ep{ep}: {:?}", report.verdict);
    }
}

#[test]
fn verdicts_are_stable_across_runs() {
    // determinism: repeated verification gives identical verdicts and
    // discrepancy sites
    let case = bugs::reproduced_bugs().into_iter().find(|c| c.id == "T4#13").unwrap();
    let sites = |pair: &GraphPair| -> Vec<String> {
        let r = verifier().verify(pair).unwrap();
        r.discrepancies().iter().map(|d| d.site.clone()).collect()
    };
    let a = sites(&(case.build)());
    let b = sites(&(case.build)());
    assert!(!a.is_empty());
    assert_eq!(a, b);
}

#[test]
fn layer_reports_expose_memoization() {
    let cfg =
        LlamaConfig { layers: 6, hidden: 8, heads: 2, kv_heads: 2, ffn: 16, seqlen: 4, batch: 1 };
    let pair = llama_pair(&cfg, Parallelism::Tensor { tp: 2 });
    let report = verifier().verify(&pair).unwrap();
    assert!(report.verified());
    assert!(report.layers.len() >= 6);
    assert!(report.layers.iter().filter(|l| l.memoized).count() >= 5);
    // phase timings recorded
    assert!(report.stopwatch.phases().count() >= 2);
}

#[test]
fn graph_pair_survives_hlo_roundtrip_and_verifies() {
    // print both graphs of a pair to HLO text, re-parse, re-verify
    use scalify::hlo::{parse_hlo_module, print_hlo_module};
    let pair = demo::matmul_allreduce_pair(2);
    let base2 = parse_hlo_module(&print_hlo_module(&pair.base), 1).unwrap();
    let dist2 = parse_hlo_module(&print_hlo_module(&pair.dist), 2).unwrap();
    // re-pair by parameter order (names/positions preserved by the printer)
    let ann: Vec<Annotation> = base2
        .parameters()
        .into_iter()
        .zip(dist2.parameters())
        .zip(pair.annotations.iter())
        .map(|((b, d), orig)| Annotation { baseline: Some(b), distributed: d, relation: orig.relation.clone() })
        .collect();
    let pair2 = GraphPair::new(base2, dist2, ann);
    let report = verifier().verify(&pair2).unwrap();
    assert!(report.verified(), "{:?}", report.verdict);
}

#[test]
fn discrepancy_rendering_is_actionable() {
    let report = verifier().verify(&demo::bsh_pair(true)).unwrap();
    let ds = report.discrepancies();
    assert!(!ds.is_empty());
    for d in ds {
        let line = d.render();
        assert!(line.contains(".py:"), "must carry a source site: {line}");
        assert!(!d.reason.is_empty());
    }
}

#[test]
fn bug_corpus_is_fully_described() {
    for case in bugs::reproduced_bugs().into_iter().chain(bugs::new_bugs()) {
        assert!(!case.description.is_empty());
        assert!(!case.issue.is_empty());
        // buildable and structurally valid
        let pair = (case.build)();
        pair.base.validate().unwrap();
        pair.dist.validate().unwrap();
    }
}

#[test]
fn resource_budget_is_honored() {
    let cfg = VerifyConfig {
        parallel: false,
        limits: scalify::egraph::RunLimits {
            max_iters: 50,
            max_nodes: 4,
            ..scalify::egraph::RunLimits::default()
        },
        ..Default::default()
    };
    let pair = demo::matmul_allreduce_pair(2);
    let report = Session::new(cfg).verify(&pair).unwrap();
    assert!(matches!(report.verdict, Verdict::ResourceExhausted { .. }));
}
