//! Trace-integrity suite (`cargo test --test trace_integrity`).
//!
//! The span tracer stitches one verify run across the main thread and
//! the parallel pass's worker pool, so a trace is only trustworthy if
//! the RAII guards actually produce well-formed timelines: on any one
//! thread spans must nest or be disjoint (never partially overlap), the
//! per-layer spans must agree one-to-one with the report's layer
//! entries, and the exported Chrome trace-event document must be JSON a
//! Perfetto-style consumer can load.
//!
//! The tracer is process-global state, so every test here serializes on
//! one mutex and runs in this dedicated integration-test process —
//! unit tests in the library cannot race it.

use scalify::cli::model_pair;
use scalify::obs::{self, SpanRecord};
use scalify::prelude::*;
use scalify::report::json::Json;
use std::sync::Mutex;

static TRACER: Mutex<()> = Mutex::new(());

/// A 4-thread parallel cold verify with the tracer live. Memoization is
/// off so every layer runs a real verification job and the worker
/// threads all contribute spans.
fn traced_cold_verify() -> (VerifyReport, Vec<SpanRecord>) {
    obs::start_tracing();
    let pair = model_pair("llama-tiny", Parallelism::Tensor { tp: 2 }, None)
        .expect("llama-tiny tp2 builds");
    let cfg = VerifyConfig {
        parallel: true,
        threads: 4,
        memoize: false,
        ..VerifyConfig::default()
    };
    let report = Session::new(cfg).verify(&pair).expect("llama-tiny tp2 verifies");
    (report, obs::stop_tracing())
}

/// True when `a` and `b` partially overlap: each starts strictly inside
/// the other's interior. Nested and disjoint pairs (including ones that
/// merely touch at a boundary timestamp) are fine; a partial overlap
/// means two RAII guards on one thread closed out of order.
fn partially_overlap(a: &SpanRecord, b: &SpanRecord) -> bool {
    let (a0, a1) = (a.start_us, a.start_us + a.dur_us);
    let (b0, b1) = (b.start_us, b.start_us + b.dur_us);
    a0 < b0 && b0 < a1 && a1 < b1
}

#[test]
fn spans_nest_per_thread_and_match_the_report() {
    let _serial = TRACER.lock().unwrap_or_else(|p| p.into_inner());
    let (report, records) = traced_cold_verify();
    assert!(report.verified(), "{}", report.summary());
    assert!(!records.is_empty(), "a traced verify must record spans");

    // one span per reported layer, carrying its layer tag as an attr
    let layer_spans: Vec<&SpanRecord> =
        records.iter().filter(|r| r.cat == "layer").collect();
    assert_eq!(
        layer_spans.len(),
        report.layers.len(),
        "per-layer spans must agree with the report's layer entries"
    );
    let mut span_tags: Vec<u64> = layer_spans
        .iter()
        .map(|s| {
            s.args
                .iter()
                .find(|(k, _)| *k == "layer")
                .map(|(_, v)| *v)
                .expect("layer spans carry a 'layer' attr")
        })
        .collect();
    let mut report_tags: Vec<u64> =
        report.layers.iter().map(|l| l.layer as u64).collect();
    span_tags.sort_unstable();
    report_tags.sort_unstable();
    assert_eq!(span_tags, report_tags);

    // the parallel pass ran its per-layer jobs off the main thread
    let run_tid = records
        .iter()
        .find(|r| r.cat == "verify")
        .expect("the run emits a top-level verify span")
        .tid;
    let job_spans: Vec<&SpanRecord> =
        records.iter().filter(|r| r.cat == "job").collect();
    assert!(!job_spans.is_empty(), "parallel cold verify must emit job spans");
    assert!(
        job_spans.iter().any(|s| s.tid != run_tid),
        "job spans must come from worker threads, not the run thread"
    );

    // per-thread timelines are well-formed: every pair of spans on one
    // thread either nests or is disjoint
    let mut tids: Vec<u64> = records.iter().map(|r| r.tid).collect();
    tids.sort_unstable();
    tids.dedup();
    for tid in tids {
        let own: Vec<&SpanRecord> =
            records.iter().filter(|r| r.tid == tid).collect();
        for (i, a) in own.iter().enumerate() {
            for b in &own[i + 1..] {
                assert!(
                    !partially_overlap(a, b) && !partially_overlap(b, a),
                    "spans '{}' and '{}' partially overlap on thread {tid}",
                    a.name,
                    b.name
                );
            }
        }
    }
}

#[test]
fn exported_trace_file_is_valid_chrome_trace_json() {
    let _serial = TRACER.lock().unwrap_or_else(|p| p.into_inner());
    let path = std::env::temp_dir()
        .join(format!("scalify-trace-integrity-{}.json", std::process::id()));
    obs::start_tracing();
    let pair = model_pair("llama-tiny", Parallelism::Tensor { tp: 2 }, None)
        .expect("llama-tiny tp2 builds");
    let report = Session::new(VerifyConfig {
        parallel: true,
        threads: 4,
        memoize: false,
        ..VerifyConfig::default()
    })
    .verify(&pair)
    .expect("llama-tiny tp2 verifies");
    assert!(report.verified(), "{}", report.summary());
    let spans = obs::export_chrome_trace(&path).expect("trace export writes");
    assert!(spans > 0);

    let text = std::fs::read_to_string(&path).expect("trace file readable");
    let _ = std::fs::remove_file(&path);
    let doc = Json::parse(&text).expect("trace file is valid JSON");
    assert_eq!(doc.str_at("displayTimeUnit"), Some("ms"));
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("trace document has a traceEvents array");

    let mut complete = 0usize;
    let mut cats: Vec<String> = Vec::new();
    for e in events {
        let ph = e.str_at("ph").expect("every event has a phase");
        assert!(e.str_at("name").is_some(), "every event has a name");
        assert!(e.f64_at("pid").is_some(), "every event has a pid");
        assert!(e.f64_at("tid").is_some(), "every event has a tid");
        match ph {
            "X" => {
                complete += 1;
                assert!(e.f64_at("ts").is_some(), "X events carry ts");
                assert!(e.f64_at("dur").is_some(), "X events carry dur");
                cats.push(e.str_at("cat").expect("X events carry cat").to_owned());
            }
            "M" => {}
            other => panic!("unexpected event phase '{other}'"),
        }
    }
    assert_eq!(complete, spans, "exported span count must match the document");
    for expected in ["verify", "phase", "layer", "rule"] {
        assert!(
            cats.iter().any(|c| c == expected),
            "trace must contain a '{expected}' span, got {cats:?}"
        );
    }
}
