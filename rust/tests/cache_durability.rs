//! Kill -9 durability fuzz (`cargo test --test cache_durability`): a
//! daemon writing its layer-memo segment cache is SIGKILLed mid-verify
//! at randomized offsets, over several rounds against the same cache
//! directory. Every restart must load a consistent index — a torn tail
//! record may be dropped, but nothing previously durable disappears and
//! the daemon always comes back serving.

use scalify::service::Client;
use scalify::service::VerifySource;
use scalify::util::Prng;
use std::io::{BufRead, BufReader};
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::time::Duration;

const ROUNDS: usize = 4;

fn spawn_daemon(cache_dir: &Path) -> (Child, String) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_scalify"))
        .args([
            "serve",
            "--addr",
            "127.0.0.1:0",
            "--threads",
            "2",
            "--cache-dir",
            cache_dir.to_str().expect("utf-8 temp path"),
        ])
        // the fuzz is about torn writes, not injected faults — keep the
        // child deterministic even if the outer environment arms chaos
        .env_remove("SCALIFY_FAULTS")
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning the scalify binary");
    let stdout = child.stdout.take().expect("piped stdout");
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).expect("daemon banner");
    let addr = line
        .trim()
        .rsplit(' ')
        .next()
        .expect("banner carries the address")
        .to_string();
    assert!(addr.contains(':'), "unexpected banner: {line:?}");
    (child, addr)
}

fn tiny_model() -> VerifySource {
    VerifySource::Model {
        model: "llama-tiny".into(),
        par: "tp2".into(),
        layers: None,
        edit_layer: None,
    }
}

#[test]
fn sigkill_mid_verify_never_corrupts_the_segment_cache() {
    let cache_dir =
        std::env::temp_dir().join(format!("scalify-durability-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache_dir);
    std::fs::create_dir_all(&cache_dir).expect("creating the cache dir");

    // deterministic offsets: the rounds kill the daemon at staggered
    // points of the verify/cache-append window
    let mut prng = Prng::new(0xD00D);
    let mut durable_floor: u64 = 0;

    for round in 0..ROUNDS {
        let (mut child, addr) = spawn_daemon(&cache_dir);

        // restart invariant: whatever the previous round made durable
        // is still in the index — a crash may lose its own in-flight
        // tail, never an earlier round's records
        let mut stats_client = Client::connect_with_timeout(&addr, Duration::from_secs(10))
            .expect("connect for stats");
        let loaded = stats_client.stats().expect("stats after restart").cache_entries_loaded;
        assert!(
            loaded >= durable_floor,
            "round {round}: restart lost durable cache entries ({loaded} < {durable_floor})"
        );
        durable_floor = loaded;

        // fire a verify (it appends memo records as layers finish) and
        // SIGKILL the daemon a randomized slice into it
        let verify_addr = addr.clone();
        let verifier = std::thread::spawn(move || {
            let Ok(mut client) =
                Client::connect_with_timeout(&verify_addr, Duration::from_secs(10))
            else {
                return;
            };
            // the kill usually lands mid-request: connection reset /
            // EOF / timeout are all expected here
            let _ = client.verify(tiny_model());
        });
        std::thread::sleep(Duration::from_millis(prng.below(300)));
        child.kill().expect("SIGKILL the daemon");
        let _ = child.wait();
        verifier.join().expect("verify thread exits once the daemon dies");
    }

    // final restart: index loads, the daemon serves a full verify from
    // whatever survived, and shuts down cleanly
    let (mut child, addr) = spawn_daemon(&cache_dir);
    let mut client =
        Client::connect_with_timeout(&addr, Duration::from_secs(30)).expect("final connect");
    let stats = client.stats().expect("final stats");
    assert!(stats.cache_entries_loaded >= durable_floor, "{}", stats.cache_entries_loaded);
    let (report, _latency, _stats) = client.verify(tiny_model()).expect("final verify");
    assert!(report.verified(), "{}", report.summary());
    client.shutdown().expect("clean shutdown");
    let _ = child.wait();
    let _ = std::fs::remove_dir_all(&cache_dir);
}
