//! Parallel-vs-sequential determinism suite (`cargo test --test
//! parallel_determinism`).
//!
//! The parallel cold pass schedules per-layer verification jobs on the
//! worker pool as a dependency DAG and promotes speculative results only
//! when their input relations match the exact ones — so it must be
//! *observationally identical* to the sequential pass: same verdict, same
//! discrepancy sites, and (because `verify_layer` is a pure function of
//! its inputs) the same per-layer e-graph statistics. This suite pins
//! that equivalence across the zoo and across parallelism shapes.
//!
//! What is deliberately NOT compared: `memoized` flags (a parallel
//! pre-pass hit is reported as a memo hit even when the sequential run
//! computes the layer inline) and wall-clock durations.

use scalify::bugs::reproduced_bugs;
use scalify::cli::model_pair;
use scalify::prelude::*;

/// Sequential configuration: one thread, no parallel pre-pass.
fn seq_cfg() -> VerifyConfig {
    VerifyConfig { parallel: false, threads: 1, memoize: false, ..VerifyConfig::default() }
}

/// Parallel configuration: DAG pre-pass on `threads` workers. Memoization
/// is off in both configs so every layer's statistics come from a real
/// saturation run (memo-served layers legitimately report zero facts).
fn par_cfg(threads: usize) -> VerifyConfig {
    VerifyConfig { parallel: true, threads, memoize: false, ..VerifyConfig::default() }
}

/// Stable projection of a verdict (ignores durations).
fn verdict_key(r: &VerifyReport) -> String {
    match &r.verdict {
        Verdict::Verified => "verified".to_string(),
        Verdict::Unverified { discrepancies } => {
            format!("unverified ({} discrepancies)", discrepancies.len())
        }
        Verdict::ResourceExhausted { at } => format!("resource-exhausted at {at}"),
    }
}

/// Localization sites, in report order (the assembly pass emits them in
/// layer order in both modes, so exact order must match too).
fn sites(r: &VerifyReport) -> Vec<(Option<u32>, String, String, String)> {
    r.discrepancies()
        .iter()
        .map(|d| (d.layer, d.site.clone(), d.func.clone(), d.reason.clone()))
        .collect()
}

/// Per-layer statistics that must be bit-identical when memoization is
/// off: a speculative result is only reused when its input relations
/// equal the exact ones, and `verify_layer` is pure, so e-graph sizes,
/// fact counts and matcher effort all replay exactly.
fn layer_keys(r: &VerifyReport) -> Vec<(u32, Option<u32>, bool, usize, usize, usize, usize)> {
    let mut keys: Vec<_> = r
        .layers
        .iter()
        .map(|l| {
            (l.layer, l.stage, l.verified, l.egraph_nodes, l.egraph_classes, l.facts,
             l.matches_tried)
        })
        .collect();
    keys.sort();
    keys
}

fn assert_equivalent(label: &str, pair: &GraphPair, threads: usize) {
    let seq = Session::new(seq_cfg()).verify(pair).unwrap_or_else(|e| {
        panic!("{label}: sequential verify failed: {e}");
    });
    let par = Session::new(par_cfg(threads)).verify(pair).unwrap_or_else(|e| {
        panic!("{label}: parallel verify failed: {e}");
    });
    assert_eq!(verdict_key(&seq), verdict_key(&par), "{label}: verdict diverged");
    assert_eq!(sites(&seq), sites(&par), "{label}: localization diverged");
    assert_eq!(layer_keys(&seq), layer_keys(&par), "{label}: per-layer e-graph stats diverged");
}

#[test]
fn zoo_verdicts_match_sequential_across_parallelism_shapes() {
    // every (model, parallelism) cell verifies identically with 1 thread
    // (sequential) and 4 workers (DAG pre-pass + assembly)
    let grid: Vec<(&str, Parallelism)> = vec![
        ("llama-tiny", Parallelism::Tensor { tp: 2 }),
        ("llama-tiny", Parallelism::Combined { pp: 2, tp: 2 }),
        ("llama-tiny", Parallelism::Mesh3D { pp: 1, dp: 2, tp: 2 }),
        ("llama-tiny-gqa", Parallelism::Tensor { tp: 2 }),
        ("llama-tiny-gqa", Parallelism::Combined { pp: 2, tp: 2 }),
        ("mixtral-tiny", Parallelism::Expert { ep: 4 }),
        ("dpstep-tiny", Parallelism::Data { dp: 2, zero_stage: 1 }),
    ];
    for (model, par) in grid {
        let label = format!("{model}/{}", par.label());
        let pair = model_pair(model, par, None)
            .unwrap_or_else(|e| panic!("{label}: pair build failed: {e}"));
        assert_equivalent(&label, &pair, 4);
    }
}

#[test]
fn buggy_pairs_localize_identically_in_parallel() {
    // failed layer outcomes carry their discrepancies through the
    // speculative path, so localization precision must not depend on the
    // thread count — take the first few corpus bugs the verifier detects
    // through graph comparison (skipping structurally-rejected cases)
    let mut checked = 0;
    for case in reproduced_bugs() {
        if checked == 3 {
            break;
        }
        let pair = (case.build)();
        match Session::new(seq_cfg()).verify(&pair) {
            Ok(report) if !report.verified() => {
                assert_equivalent(case.id, &pair, 4);
                checked += 1;
            }
            // verified (bug outside the compiled graph) or typed
            // structural rejection: nothing for the parallel pass to do
            _ => continue,
        }
    }
    assert_eq!(checked, 3, "corpus no longer has three graph-detectable bugs");
}

#[test]
fn memoized_parallel_runs_agree_on_verdicts() {
    // with memoization on, per-layer stats legitimately differ (memo
    // hits report the producing run's numbers and zero facts) but the
    // verdict and localization must still match
    let pair = model_pair("llama-tiny", Parallelism::Combined { pp: 2, tp: 2 }, None).unwrap();
    let seq = Session::new(VerifyConfig {
        parallel: false,
        threads: 1,
        ..VerifyConfig::default()
    })
    .verify(&pair)
    .unwrap();
    let par = Session::new(VerifyConfig::default()).verify(&pair).unwrap();
    assert_eq!(verdict_key(&seq), verdict_key(&par));
    assert_eq!(sites(&seq), sites(&par));
}

#[test]
fn sequential_escape_hatch_is_behavior_preserving() {
    // SCALIFY_SEQUENTIAL=1 forces the cold pass off the pool even with
    // `parallel: true` — the differential-testing escape hatch mirrors
    // SCALIFY_NAIVE_MATCH and must not change any observable output
    let pair = model_pair("llama-tiny", Parallelism::Tensor { tp: 2 }, None).unwrap();
    std::env::set_var("SCALIFY_SEQUENTIAL", "1");
    let hatched = Session::new(par_cfg(4)).verify(&pair).unwrap();
    std::env::remove_var("SCALIFY_SEQUENTIAL");
    let parallel = Session::new(par_cfg(4)).verify(&pair).unwrap();
    assert_eq!(verdict_key(&hatched), verdict_key(&parallel));
    assert_eq!(sites(&hatched), sites(&parallel));
    assert_eq!(layer_keys(&hatched), layer_keys(&parallel));
}
