//! Wire-compatibility tests (`cargo test --test protocol_compat`): a v1
//! client pointed at the fleet daemon must see byte-for-byte the same
//! protocol surface it saw before sharding, streaming and cancellation
//! existed. The golden-bytes test pins the exact v1 stats encoding;
//! the socket tests pin that v2 never leaks into a connection that did
//! not negotiate it; the spawned-binary test pins the fresh-daemon
//! zero-percentile fix end to end through `scalify client stats`.

use scalify::report::json::Json;
use scalify::service::{
    Client, ServeConfig, Server, StatsSnapshot, VerifySource, PROTOCOL_V2,
};
use scalify::verifier::VerifyConfig;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};

fn tiny_server() -> Server {
    Server::start(ServeConfig {
        queue_capacity: 4,
        workers: 2,
        verify: VerifyConfig { threads: 2, ..VerifyConfig::default() },
        ..ServeConfig::default()
    })
    .expect("server starts on an ephemeral port")
}

/// Netcat-style connection: one line out, lines back.
struct RawConn {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl RawConn {
    fn connect(addr: &str) -> RawConn {
        let stream = TcpStream::connect(addr).expect("connect");
        let writer = stream.try_clone().expect("clone");
        RawConn { writer, reader: BufReader::new(stream) }
    }

    fn round_trip(&mut self, line: &str) -> String {
        self.writer.write_all(line.as_bytes()).expect("send");
        self.writer.write_all(b"\n").expect("send");
        self.writer.flush().expect("flush");
        let mut out = String::new();
        self.reader.read_line(&mut out).expect("recv");
        out.trim_end().to_string()
    }
}

#[test]
fn v1_stats_snapshot_encoding_is_pinned_byte_for_byte() {
    // the exact bytes a pre-fleet daemon put on the wire; if this test
    // breaks, a v1 client broke — adding fields to the v1 encoding is a
    // protocol bump, not a patch (docs/PROTOCOL.md)
    let snap = StatsSnapshot {
        jobs: 3,
        runs: 2,
        memo_hits: 1,
        templates: 40,
        threads: 4,
        queue_capacity: 8,
        scheduler_workers: 4,
        uptime_secs: 1.5,
        ..StatsSnapshot::default()
    };
    assert_eq!(
        snap.to_json().render(),
        "{\"protocol\":1,\"jobs\":3,\"runs\":2,\"memo_entries\":0,\"memo_hits\":1,\
         \"memo_misses\":0,\"memo_evictions\":0,\"templates\":40,\"threads\":4,\
         \"queue_capacity\":8,\"scheduler_workers\":4,\"egraph_nodes_total\":0,\
         \"ematch_tried_total\":0,\"rule_applications_total\":0,\
         \"cache_entries_loaded\":0,\"uptime_secs\":1.5,\"latency_p50_secs\":0,\
         \"latency_p95_secs\":0,\"latency_max_secs\":0}"
    );

    // the optional cache_dir stays the final v1 field
    let with_dir = StatsSnapshot {
        cache_dir: Some("/tmp/scalify".into()),
        ..StatsSnapshot::default()
    };
    assert!(
        with_dir.to_json().render().ends_with("\"cache_dir\":\"/tmp/scalify\"}"),
        "{}",
        with_dir.to_json().render()
    );

    // and the same struct at protocol 2 appends exactly one new field
    let v2 = StatsSnapshot { protocol: PROTOCOL_V2, ..StatsSnapshot::default() };
    assert!(v2.to_json().render().ends_with("\"shards\":[]}"), "{}", v2.to_json().render());
}

#[test]
fn a_v1_connection_never_sees_v2_fields_even_after_others_negotiate() {
    let server = tiny_server();
    let addr = server.local_addr().to_string();

    let mut v1 = RawConn::connect(&addr);
    let mut v2 = RawConn::connect(&addr);

    // the fresh-daemon stats a v1 client decodes: protocol 1, no shard
    // array, and *exactly* zero latency percentiles (the merged-quantile
    // guard — an empty histogram must not interpolate)
    let line = v1.round_trip("{\"cmd\":\"stats\"}");
    assert!(line.starts_with("{\"ok\":true,\"kind\":\"stats\""), "{line}");
    assert!(line.contains("\"protocol\":1"), "{line}");
    assert!(!line.contains("\"shards\""), "{line}");
    assert!(
        line.contains(
            "\"latency_p50_secs\":0,\"latency_p95_secs\":0,\"latency_max_secs\":0"
        ),
        "fresh-daemon percentiles must be exactly 0: {line}"
    );

    // another connection upgrading to v2 must not bleed into this one
    let hello = v2.round_trip(&format!("{{\"cmd\":\"hello\",\"protocol\":{PROTOCOL_V2}}}"));
    assert!(hello.contains("\"protocol\":2"), "{hello}");
    let v2_stats = v2.round_trip("{\"cmd\":\"stats\"}");
    assert!(v2_stats.contains("\"shards\":["), "{v2_stats}");

    let line = v1.round_trip("{\"cmd\":\"stats\"}");
    assert!(!line.contains("\"shards\""), "v2 leaked into a v1 connection: {line}");
    assert!(line.contains("\"protocol\":1"), "{line}");

    // a v1 verify response carries no id, no events, no cancelled flag —
    // even when the request (like old clients sometimes did) carries
    // extra fields the v1 daemon ignored
    let line = v1.round_trip(
        "{\"cmd\":\"verify\",\"model\":\"llama-tiny\",\"par\":\"tp2\",\"stream\":true,\
         \"id\":\"ignored-on-v1\"}",
    );
    assert!(line.starts_with("{\"ok\":true,\"kind\":\"verify\""), "{line}");
    let doc = Json::parse(&line).expect("valid response json");
    assert!(doc.get("id").is_none(), "v1 verify must not echo an id: {line}");
    assert!(doc.get("cancelled").is_none(), "{line}");
    let stats = doc.get("stats").expect("stats object");
    assert!(stats.get("shards").is_none(), "{line}");

    v1.round_trip("{\"cmd\":\"shutdown\"}");
    server.wait();
}

#[test]
fn typed_v1_client_decodes_fleet_daemon_responses_unchanged() {
    // the 0.2.0 Client type (no hello call) against the fleet daemon:
    // verify/stats/metrics/shutdown behave exactly as before
    let server = tiny_server();
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).expect("connect");

    let (report, latency, stats) = client
        .verify(VerifySource::Model {
            model: "llama-tiny".into(),
            par: "tp2".into(),
            layers: None,
            edit_layer: None,
        })
        .expect("verify");
    assert!(report.verified(), "{:?}", report.verdict);
    assert!(latency >= 0.0);
    assert_eq!(stats.protocol, 1);
    assert!(stats.shards.is_empty());
    assert!(stats.latency_max_secs >= stats.latency_p50_secs);

    client.shutdown().expect("shutdown");
    server.wait();
}

/// Child daemon killed even when an assertion fails mid-test.
struct DaemonGuard {
    child: Child,
    addr: String,
}

impl DaemonGuard {
    fn spawn() -> DaemonGuard {
        let mut child = Command::new(env!("CARGO_BIN_EXE_scalify"))
            .args(["serve", "--addr", "127.0.0.1:0", "--threads", "2"])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning the scalify binary");
        let stdout = child.stdout.take().expect("piped stdout");
        let mut line = String::new();
        BufReader::new(stdout).read_line(&mut line).expect("daemon banner");
        let addr = line
            .trim()
            .rsplit(' ')
            .next()
            .expect("banner carries the address")
            .to_string();
        assert!(addr.contains(':'), "unexpected banner: {line:?}");
        DaemonGuard { child, addr }
    }
}

impl Drop for DaemonGuard {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

#[test]
fn fresh_daemon_stats_through_the_cli_report_zero_percentiles() {
    // regression: a fresh daemon used to report interpolated nonsense
    // percentiles before any job ran; `scalify client stats` must print
    // exact zeros
    let daemon = DaemonGuard::spawn();
    let out = Command::new(env!("CARGO_BIN_EXE_scalify"))
        .args(["client", "stats", "--addr", &daemon.addr])
        .output()
        .expect("spawn scalify client");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    // the trailing comma / newline pins the value as exactly `0` (a
    // bare `": 0"` would also match an interpolated `0.5`)
    assert!(stdout.contains("\"latency_p50_secs\": 0,"), "{stdout}");
    assert!(stdout.contains("\"latency_p95_secs\": 0,"), "{stdout}");
    assert!(stdout.contains("\"latency_max_secs\": 0\n"), "{stdout}");
    assert!(stdout.contains("\"jobs\": 0,"), "{stdout}");
    let _ = Command::new(env!("CARGO_BIN_EXE_scalify"))
        .args(["client", "shutdown", "--addr", &daemon.addr])
        .output();
}
