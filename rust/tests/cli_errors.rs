//! CLI error-path integration tests (`cargo test --test cli_errors`):
//! spawn the real binary and pin the exit-code contract — 0 verified,
//! 1 unverified, 2 bad input (parse/config/model-spec), 3 runtime
//! failure — and that failures are typed `scalify:` diagnostics on
//! stderr, never panics.

use std::path::PathBuf;
use std::process::{Command, Output};

fn scalify(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_scalify"))
        .args(args)
        .output()
        .expect("spawn scalify binary")
}

fn stderr_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

/// A path whose parent directory does not exist (and is re-removed in
/// case a previous failed run created it).
fn unwritable_state_path() -> PathBuf {
    let dir = std::env::temp_dir().join("scalify-cli-errors-no-such-dir");
    let _ = std::fs::remove_dir_all(&dir);
    dir.join("deeper").join("state.json")
}

#[test]
fn unwritable_emit_state_path_is_a_runtime_error_not_a_panic() {
    let path = unwritable_state_path();
    let out = scalify(&[
        "model",
        "--model",
        "llama-tiny",
        "--par",
        "tp2",
        "--layers",
        "1",
        "--emit-state",
        path.to_str().expect("utf-8 temp path"),
    ]);
    let stderr = stderr_of(&out);
    assert_eq!(out.status.code(), Some(3), "runtime failures exit 3; stderr:\n{stderr}");
    assert!(
        stderr.contains("scalify: runtime error") && stderr.contains("writing --emit-state"),
        "expected a typed --emit-state diagnostic, got:\n{stderr}"
    );
    assert!(!stderr.contains("panicked"), "CLI must not panic:\n{stderr}");
}

#[test]
fn writable_emit_state_path_round_trips() {
    // the same invocation with a writable path succeeds and leaves the
    // state file behind for a later --against run
    let dir = std::env::temp_dir().join("scalify-cli-errors-emit-state");
    std::fs::create_dir_all(&dir).expect("create temp dir");
    let path = dir.join("state.json");
    let out = scalify(&[
        "model",
        "--model",
        "llama-tiny",
        "--par",
        "tp2",
        "--layers",
        "1",
        "--emit-state",
        path.to_str().expect("utf-8 temp path"),
    ]);
    let stderr = stderr_of(&out);
    assert_eq!(out.status.code(), Some(0), "verified pair exits 0; stderr:\n{stderr}");
    assert!(stderr.contains("wrote verification state"), "missing confirmation:\n{stderr}");
    assert!(path.is_file(), "state file was not written");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bad_parallelism_spec_is_a_config_error() {
    let out = scalify(&["model", "--model", "llama-tiny", "--par", "bogus"]);
    let stderr = stderr_of(&out);
    assert_eq!(out.status.code(), Some(2), "bad input exits 2; stderr:\n{stderr}");
    assert!(stderr.contains("scalify: config error"), "expected typed config error:\n{stderr}");
}

#[test]
fn unknown_model_is_a_model_spec_error() {
    let out = scalify(&["model", "--model", "gpt-5", "--par", "tp2"]);
    let stderr = stderr_of(&out);
    assert_eq!(out.status.code(), Some(2), "bad input exits 2; stderr:\n{stderr}");
    assert!(
        stderr.contains("scalify: model-spec error") && stderr.contains("unknown model"),
        "expected typed model-spec error:\n{stderr}"
    );
}
