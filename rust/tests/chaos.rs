//! Chaos suite (`cargo test --test chaos`): deterministic fault
//! injection against an in-process fleet daemon. The acceptance
//! scenario drives eight concurrent clients through injected shard
//! panics and connection drops and requires every request to terminate
//! with a typed outcome — success via retry, or a `retryable: ` error
//! after exhaustion — with the daemon still serving and
//! `shard_restarts_total` > 0 at the end.
//!
//! The fault registry is process-global, so every test here serializes
//! on one lock and clears the registry on entry and exit; the nightly
//! CI `chaos` job re-runs the same scenarios against the spawned binary
//! via `SCALIFY_FAULTS` (see TESTING.md § "The chaos suite").

use scalify::service::{
    verify_with_retry, Client, Request, Response, RetryPolicy, ServeConfig, Server,
    VerifyOpts, VerifySource, PROTOCOL_V2,
};
use scalify::verifier::VerifyConfig;
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

/// Serializes the tests in this binary: they all mutate the
/// process-global fault registry and an in-process server shares it.
fn chaos_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    // a previous test panicking while holding the lock must not wedge
    // the rest of the suite
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|p| p.into_inner())
}

fn fleet(shards: usize) -> Server {
    Server::start(ServeConfig {
        queue_capacity: 16,
        workers: 4,
        shards,
        verify: VerifyConfig { threads: 2, ..VerifyConfig::default() },
        ..ServeConfig::default()
    })
    .expect("fleet starts on an ephemeral port")
}

fn tiny_model() -> VerifySource {
    VerifySource::Model {
        model: "llama-tiny".into(),
        par: "tp2".into(),
        layers: None,
        edit_layer: None,
    }
}

#[test]
fn fleet_self_heals_under_shard_panics_and_conn_drops() {
    let _guard = chaos_lock();
    scalify::faults::clear();

    let server = fleet(4);
    let addr = server.local_addr().to_string();

    // arm the chaos mix: 20% of verify jobs panic on a worker thread,
    // 10% of response writes drop the connection instead
    let mut ctl = Client::connect(&addr).expect("control connection");
    ctl.faults(Some("shard-verify:panic:0.2:42,conn-write:drop:0.1:43"), false)
        .expect("arming the chaos faults");

    const CLIENTS: usize = 8;
    const REQUESTS: usize = 6;
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || -> Vec<String> {
            let policy = RetryPolicy {
                attempts: 8,
                base_backoff: Duration::from_millis(5),
                max_backoff: Duration::from_millis(40),
                // bounds every read: a hung client would fail the test
                // with a typed timeout instead of wedging the harness
                timeout: Duration::from_secs(30),
                jitter_seed: c as u64 + 1,
            };
            let mut outcomes = Vec::new();
            for r in 0..REQUESTS {
                let request = Request::Verify(tiny_model());
                let opts = VerifyOpts {
                    id: Some(format!("chaos-{c}-{r}")),
                    ..VerifyOpts::default()
                };
                let outcome = match verify_with_retry(&addr, &request, &opts, &policy, |_| {})
                {
                    Ok(Response::VerifyDone { report, .. }) => {
                        format!("done:{}", report.verified())
                    }
                    Ok(Response::Cancelled { .. }) => "cancelled".into(),
                    Ok(Response::Error { message }) => format!("error:{message}"),
                    Ok(other) => format!("unexpected:{other:?}"),
                    Err(e) => format!("err:{}", e.message()),
                };
                outcomes.push(outcome);
            }
            outcomes
        }));
    }

    let mut successes = 0usize;
    let mut retry_exhausted = 0usize;
    for handle in handles {
        // a hung client never joins; the per-attempt socket timeout
        // guarantees this join terminates
        let outcomes = handle.join().expect("chaos client thread completed");
        for outcome in outcomes {
            if outcome == "done:true" {
                successes += 1;
            } else if let Some(msg) =
                outcome.strip_prefix("error:").or_else(|| outcome.strip_prefix("err:"))
            {
                // attempts exhausted is acceptable — but only with a
                // typed retryable error, never a hang or a hard failure
                assert!(
                    scalify::service::is_retryable(msg),
                    "non-retryable terminal outcome under chaos: {outcome}"
                );
                retry_exhausted += 1;
            } else {
                panic!("untyped chaos outcome: {outcome}");
            }
        }
    }
    assert_eq!(successes + retry_exhausted, CLIENTS * REQUESTS);
    assert!(
        successes > 0,
        "retry must carry most requests through 20% panics ({retry_exhausted} exhausted)"
    );

    // disarm, then prove the fleet is still healthy and supervised:
    // a fresh verify succeeds and the restart counter saw the panics
    let mut ctl = Client::connect(&addr).expect("reconnect after chaos");
    ctl.faults(None, true).expect("clearing the chaos faults");
    ctl.hello(PROTOCOL_V2).expect("hello");
    let (report, _latency, stats) = ctl.verify(tiny_model()).expect("fleet serves after chaos");
    assert!(report.verified(), "{}", report.summary());
    assert!(
        stats.shard_restarts_total > 0,
        "20% panics across {} requests must restart at least one shard",
        CLIENTS * REQUESTS
    );
    ctl.shutdown().expect("daemon survived the whole run");
    server.wait();
    scalify::faults::clear();
}

#[test]
fn deadline_with_slow_layers_degrades_to_a_partial_verdict() {
    let _guard = chaos_lock();
    scalify::faults::clear();

    let server = fleet(2);
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).expect("connect");
    // every layer boundary stalls 100ms; a 50ms deadline therefore
    // expires after the first slice and the run must degrade, not hang
    // and not cancel
    client.faults(Some("verify-layer:delay100:1.0:7"), false).expect("arm slow layers");
    client.hello(PROTOCOL_V2).expect("hello");

    let request = Request::Verify(VerifySource::Model {
        model: "llama-tiny".into(),
        par: "tp2".into(),
        layers: Some(4),
        edit_layer: None,
    });
    let opts = VerifyOpts {
        id: Some("chaos-degraded".into()),
        deadline_secs: Some(0.05),
        ..VerifyOpts::default()
    };
    match client.verify_opts(&request, &opts, |_| {}).expect("typed response") {
        Response::VerifyDone { report, stats, .. } => {
            assert!(report.degraded, "{}", report.summary());
            let at = report.first_unverified.as_deref().expect("degraded names the boundary");
            assert!(at.starts_with("layer "), "{at}");
            assert!(report.summary().contains("DEGRADED"), "{}", report.summary());
            assert!(stats.degraded_total >= 1, "{}", stats.degraded_total);
        }
        other => panic!("expected a degraded VerifyDone, got {other:?}"),
    }

    // with the fault cleared and no deadline the same request verifies
    // fully — degradation was the deadline's doing, not corruption
    client.faults(None, true).expect("clear");
    let (report, _, _) = client.verify(tiny_model()).expect("clean verify");
    assert!(report.verified() && !report.degraded, "{}", report.summary());
    client.shutdown().expect("shutdown");
    server.wait();
    scalify::faults::clear();
}

#[test]
fn faults_protocol_arms_inspects_and_clears_the_registry() {
    let _guard = chaos_lock();
    scalify::faults::clear();

    let server = fleet(1);
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).expect("connect");

    assert!(client.faults(None, false).expect("inspect").is_empty());

    // arm two points in one spec; the snapshot comes back sorted with
    // zeroed counters (rate 0 / unreachable points never fire)
    let snap = client
        .faults(Some("cache-write:bitrot:0.5:3,sched-admit:error:0.0:4"), false)
        .expect("arm");
    assert_eq!(snap.len(), 2);
    assert_eq!((snap[0].point.as_str(), snap[0].kind.as_str()), ("cache-write", "bitrot"));
    assert_eq!((snap[1].point.as_str(), snap[1].kind.as_str()), ("sched-admit", "error"));
    assert_eq!(snap[0].seed, 3);
    assert_eq!(snap[0].fired, 0);

    // a typo'd spec is a typed error and leaves the registry untouched
    let err = client.faults(Some("bogus:panic:1.0:1"), false).unwrap_err();
    assert!(err.message().contains("unknown fault point"), "{err}");
    assert_eq!(client.faults(None, false).expect("inspect").len(), 2);

    // clear disarms everything and restores the fast path
    assert!(client.faults(None, true).expect("clear").is_empty());
    assert!(!scalify::faults::enabled());

    client.shutdown().expect("shutdown");
    server.wait();
}
