//! Integration tests for the session-oriented API: config builder
//! validation, typed errors, machine-readable reports and cross-run
//! memo/template reuse.

use scalify::modelgen::{demo, llama_pair, try_llama_pair, try_mixtral_pair, MixtralConfig};
use scalify::prelude::*;
use scalify::report::json::Json;

fn tiny_llama() -> LlamaConfig {
    LlamaConfig { layers: 4, hidden: 16, heads: 4, kv_heads: 4, ffn: 32, seqlen: 8, batch: 2 }
}

#[test]
fn builder_accepts_sane_configs() {
    let cfg = VerifyConfig::builder()
        .partition(true)
        .parallel(true)
        .memoize(true)
        .threads(8)
        .max_rounds(4)
        .max_iters(16)
        .max_nodes(100_000)
        .build()
        .unwrap();
    assert_eq!(cfg.threads, 8);
    assert_eq!(cfg.max_rounds, 4);
    assert_eq!(cfg.limits.max_iters, 16);
    assert_eq!(cfg.limits.max_nodes, 100_000);
}

#[test]
fn builder_rejects_nonsense_with_config_errors() {
    let cases: Vec<(&str, scalify::error::Result<VerifyConfig>)> = vec![
        ("threads=0", VerifyConfig::builder().threads(0).build()),
        ("threads huge", VerifyConfig::builder().threads(1_000_000).build()),
        ("max_iters=0", VerifyConfig::builder().max_iters(0).build()),
        ("max_nodes=0", VerifyConfig::builder().max_nodes(0).build()),
        ("max_rounds=0", VerifyConfig::builder().max_rounds(0).build()),
        (
            "parallel without partition",
            VerifyConfig::builder().partition(false).parallel(true).build(),
        ),
    ];
    for (label, result) in cases {
        let err = result.expect_err(label);
        assert!(matches!(err, ScalifyError::Config(_)), "{label}: {err}");
        assert!(!err.message().is_empty(), "{label}");
    }
}

#[test]
fn error_kinds_display_and_convert() {
    let e = ScalifyError::parse("bad hlo");
    assert_eq!(e.to_string(), "parse error: bad hlo");
    let e = ScalifyError::model_spec("heads must divide tp").context("llama-8b");
    assert_eq!(e.to_string(), "model-spec error: llama-8b: heads must divide tp");
    let io: ScalifyError =
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such manifest").into();
    assert_eq!(io.kind(), "io");
    // std::error::Error object safety (boxing works for ? in user code)
    let boxed: Box<dyn std::error::Error> = Box::new(ScalifyError::runtime("pool died"));
    assert!(boxed.to_string().contains("pool died"));
}

#[test]
fn modelgen_validation_is_typed_not_panicking() {
    let err = try_llama_pair(&tiny_llama(), Parallelism::Tensor { tp: 3 }).unwrap_err();
    assert!(matches!(err, ScalifyError::ModelSpec(_)), "{err}");
    let err = try_llama_pair(&tiny_llama(), Parallelism::Expert { ep: 2 }).unwrap_err();
    assert!(matches!(err, ScalifyError::ModelSpec(_)), "{err}");
    let err = try_mixtral_pair(&MixtralConfig::tiny(), Parallelism::Tensor { tp: 2 })
        .unwrap_err();
    assert!(matches!(err, ScalifyError::ModelSpec(_)), "{err}");
    // the valid combination still builds
    let pair = try_llama_pair(&tiny_llama(), Parallelism::Tensor { tp: 2 }).unwrap();
    assert!(pair.total_nodes() > 0);
}

#[test]
fn session_verify_reports_typed_errors_on_bad_annotations() {
    let mut pair = demo::matmul_allreduce_pair(2);
    pair.annotations.push(Annotation::replicated(NodeId(0), NodeId(10_000)));
    let err = Session::new(VerifyConfig::default()).verify(&pair).unwrap_err();
    assert!(matches!(err, ScalifyError::ModelSpec(_)), "{err}");
}

#[test]
fn json_report_round_trips_with_same_verdict() {
    let session = Session::new(VerifyConfig::default());

    // verified report
    let ok = session.verify(&demo::matmul_allreduce_pair(2)).unwrap();
    let back = VerifyReport::from_json_str(&ok.to_json_string()).unwrap();
    assert!(back.verified());
    assert_eq!(back.verdict.status(), "verified");
    assert_eq!(back.layers.len(), ok.layers.len());

    // unverified report keeps its discrepancies and localization payload
    let buggy = session.verify(&demo::bsh_pair(true)).unwrap();
    assert!(!buggy.verified());
    let text = buggy.to_json_string();
    let doc = Json::parse(&text).unwrap();
    assert_eq!(doc.get("status").and_then(Json::as_str), Some("unverified"));
    let back = VerifyReport::from_json(&doc).unwrap();
    assert_eq!(back.verdict.status(), buggy.verdict.status());
    assert_eq!(back.discrepancies().len(), buggy.discrepancies().len());
    assert_eq!(back.discrepancies()[0].site, buggy.discrepancies()[0].site);
    assert_eq!(back.discrepancies()[0].reason, buggy.discrepancies()[0].reason);
}

#[test]
fn session_memo_survives_across_runs() {
    let session = Session::new(
        VerifyConfig::builder().parallel(false).threads(1).build().unwrap(),
    );
    let pair = llama_pair(&tiny_llama(), Parallelism::Tensor { tp: 2 });

    let first = session.verify(&pair).unwrap();
    assert!(first.verified(), "{:?}", first.verdict);
    // sequential first run: identical decoder layers dedup via the memo,
    // but at least the first layer is computed fresh
    assert!(first.layers.iter().any(|l| !l.memoized));
    let stats_after_first = session.stats();
    assert_eq!(stats_after_first.runs, 1);
    assert!(stats_after_first.memo_entries > 0);

    // a rebuilt, structurally-identical pair is fully served by the memo
    let again = llama_pair(&tiny_llama(), Parallelism::Tensor { tp: 2 });
    let second = session.verify(&again).unwrap();
    assert!(second.verified());
    assert!(
        second.layers.iter().all(|l| l.memoized),
        "second run must be fully memoized: {:?}",
        second.layers
    );
    let stats = session.stats();
    assert_eq!(stats.runs, 2);
    assert!(stats.memo_hits > stats_after_first.memo_hits);

    // a structurally-overlapping config (fewer layers) stays warm too
    let small = LlamaConfig { layers: 2, ..tiny_llama() };
    let overlap = session.verify(&llama_pair(&small, Parallelism::Tensor { tp: 2 })).unwrap();
    assert!(overlap.verified());
    let decoder_layers_memoized = overlap
        .layers
        .iter()
        .filter(|l| l.layer != u32::MAX && l.memoized)
        .count();
    assert!(decoder_layers_memoized >= 2, "{:?}", overlap.layers);

    // clearing the memo makes the next run cold again
    session.clear_memo();
    assert_eq!(session.stats().memo_entries, 0);
    let cold = session.verify(&pair).unwrap();
    assert!(cold.verified());
    assert!(cold.layers.iter().any(|l| !l.memoized));
}

#[test]
fn parallel_session_reuses_pool_and_memo() {
    let session = Session::new(
        VerifyConfig::builder().parallel(true).threads(2).build().unwrap(),
    );
    assert_eq!(session.stats().threads, 2);
    let pair = llama_pair(&tiny_llama(), Parallelism::Tensor { tp: 2 });
    for round in 0..3 {
        let report = session.verify(&pair).unwrap();
        assert!(report.verified(), "round {round}: {:?}", report.verdict);
    }
    let stats = session.stats();
    assert_eq!(stats.runs, 3);
    assert!(stats.memo_hits > 0);
    assert!(stats.templates > 0);
}

#[test]
fn sessions_are_isolated() {
    let pair = llama_pair(&tiny_llama(), Parallelism::Tensor { tp: 2 });
    let a = Session::new(VerifyConfig::default());
    a.verify(&pair).unwrap();
    // a fresh session has no memo state from `a`
    let b = Session::new(VerifyConfig::default());
    assert_eq!(b.stats().memo_entries, 0);
    assert_eq!(b.stats().runs, 0);
}

#[test]
#[allow(deprecated)]
fn deprecated_verifier_shim_still_works() {
    let report = Verifier::new(VerifyConfig::default())
        .verify_pair(&demo::matmul_allreduce_pair(2));
    assert!(report.verified());
}

#[test]
fn indexed_matcher_cuts_ematch_work_at_least_3x() {
    use scalify::egraph::{MatchMode, RunLimits};

    let cfg_for = |mode: MatchMode| VerifyConfig {
        parallel: false,
        memoize: false,
        limits: RunLimits { match_mode: mode, ..RunLimits::default() },
        ..VerifyConfig::default()
    };
    let tried = |r: &VerifyReport| -> usize { r.layers.iter().map(|l| l.matches_tried).sum() };

    for par in [
        Parallelism::Tensor { tp: 2 },
        Parallelism::Combined { pp: 2, tp: 2 },
        Parallelism::Mesh3D { pp: 1, dp: 2, tp: 2 },
    ] {
        let pair = llama_pair(&tiny_llama(), par);
        let indexed = Session::new(cfg_for(MatchMode::Indexed)).verify(&pair).unwrap();
        let naive = Session::new(cfg_for(MatchMode::Naive)).verify(&pair).unwrap();
        assert_eq!(
            indexed.verified(),
            naive.verified(),
            "{}: matchers must agree on the verdict",
            par.label()
        );
        assert!(indexed.verified(), "{}: {}", par.label(), indexed.summary());
        let (ti, tn) = (tried(&indexed), tried(&naive));
        assert!(ti > 0, "{}: indexed run must report its e-match work", par.label());
        assert!(
            ti * 3 <= tn,
            "{}: indexed matcher should do >=3x less e-match work ({ti} vs {tn})",
            par.label()
        );
        // the per-rule counters decompose the total
        let per_rule: usize = indexed
            .layers
            .iter()
            .flat_map(|l| l.rules.iter())
            .map(|r| r.matches_tried)
            .sum();
        assert_eq!(per_rule, ti, "{}: per-rule counters must sum to the total", par.label());
    }
}
