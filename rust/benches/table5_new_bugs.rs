//! Table 5: the 5 previously-unknown Amazon-SDK bugs (all detected).

use scalify::bugs::{evaluate, new_bugs, ExpectedLoc, LocResult};
use scalify::report::Table;
use scalify::util::fmt_duration;

fn main() {
    let mut table = Table::new(
        "Table 5 — new bugs",
        &["Bug", "Description", "Framework", "Paper", "Result", "Time"],
    );
    let mut detected = 0;
    for case in new_bugs() {
        let outcome = evaluate(&case);
        if outcome.detected {
            detected += 1;
        }
        let paper = match case.expected {
            ExpectedLoc::Instruction => "instr",
            ExpectedLoc::Function => "func",
            ExpectedLoc::NotApplicable => "n/a",
        };
        let result = match (outcome.detected, outcome.loc) {
            (true, LocResult::Instruction) => "detected @instr",
            (true, LocResult::Function) => "detected @func",
            (true, _) => "detected",
            (false, _) => "MISSED",
        };
        table.row(&[
            case.id.into(),
            case.description.into(),
            case.issue.into(),
            paper.into(),
            result.into(),
            fmt_duration(outcome.duration),
        ]);
    }
    print!("{}", table.render());
    println!("summary: {detected}/5 detected — paper: 5/5");
    assert_eq!(detected, 5);
    table.save_csv("table5_new_bugs");
}
