//! Table 2: verification time for the five real-world model shapes.
//!
//! Paper: L1 Llama-8B 48s, L2 70B 1m40s, L3 405B 2m37s, M1 Mixtral-8x7B
//! 1m52s, M2 8x22B 3m1s — minutes-scale on a 6-core laptop, Mixtral slower
//! than Llama due to the unroll analysis. We reproduce the *shape*
//! (minutes → here milliseconds: Rust engine + smaller per-layer graphs),
//! the layer-count scaling, and the Mixtral-vs-Llama ordering per node.

use scalify::bench::time_once;
use scalify::modelgen::{llama_pair, mixtral_pair, LlamaConfig, MixtralConfig, Parallelism};
use scalify::report::Table;
use scalify::util::fmt_duration;
use scalify::verifier::{Session, VerifyConfig};

fn main() {
    let verifier = Session::new(VerifyConfig::default());
    let mut table = Table::new(
        "Table 2 — verifying real-world model shapes (tp/ep as paper)",
        &["Exp", "Model", "Layers", "Nodes", "Verified", "Time", "Paper"],
    );

    let llama = |name: &str, cfg: LlamaConfig, paper: &str, exp: &str, table: &mut Table| {
        let pair = llama_pair(&cfg, Parallelism::Tensor { tp: 32 });
        let nodes = pair.total_nodes();
        let (report, stats) = time_once(name, || verifier.verify(&pair).unwrap());
        table.row(&[
            exp.into(),
            name.into(),
            cfg.layers.to_string(),
            nodes.to_string(),
            report.verified().to_string(),
            fmt_duration(stats.median()),
            paper.into(),
        ]);
        assert!(report.verified(), "{name} must verify");
    };
    llama("Llama-3.1-8B", LlamaConfig::llama3_8b(), "48s", "L1", &mut table);
    llama("Llama-3.1-70B", LlamaConfig::llama3_70b(), "1m 40s", "L2", &mut table);
    llama("Llama-3.1-405B", LlamaConfig::llama3_405b(), "2m 37s", "L3", &mut table);

    let mixtral = |name: &str, cfg: MixtralConfig, paper: &str, exp: &str, table: &mut Table| {
        let pair = mixtral_pair(&cfg, Parallelism::Expert { ep: 8 });
        let nodes = pair.total_nodes();
        let (report, stats) = time_once(name, || verifier.verify(&pair).unwrap());
        table.row(&[
            exp.into(),
            name.into(),
            cfg.layers.to_string(),
            nodes.to_string(),
            report.verified().to_string(),
            fmt_duration(stats.median()),
            paper.into(),
        ]);
        assert!(report.verified(), "{name} must verify");
    };
    mixtral("Mixtral-8x7B", MixtralConfig::mixtral_8x7b(), "1m 52s", "M1", &mut table);
    mixtral("Mixtral-8x22B", MixtralConfig::mixtral_8x22b(), "3m 1s", "M2", &mut table);

    print!("{}", table.render());
    table.save_csv("table2_models");
}
