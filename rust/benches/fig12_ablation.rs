//! Figure 12: the scaling-technique ablation on Llama-8B (tp=32, 32
//! layers). Paper shape: whole-graph rewriting exhausts resources;
//! sequential partitioning works; parallel rewriting is faster;
//! memoization is fastest.

use scalify::bench::bench;
use scalify::egraph::RunLimits;
use scalify::modelgen::{llama_pair, LlamaConfig, Parallelism};
use scalify::report::Table;
use scalify::util::fmt_duration;
use scalify::verifier::{Session, Verdict, VerifyConfig};

fn main() {
    let cfg = LlamaConfig::llama3_8b();
    let pair = llama_pair(&cfg, Parallelism::Tensor { tp: 32 });
    let mut table = Table::new(
        "Figure 12 — verification time by scaling technique (Llama-8B tp32)",
        &["Technique", "Outcome", "Median time"],
    );

    // (0) no partitioning: whole-graph e-graph under a production memory
    // budget — the paper reports resource exhaustion; we bound the node
    // budget to a laptop-scale equivalent and report the same outcome
    {
        let verifier = Session::new(VerifyConfig {
            partition: false,
            parallel: false,
            memoize: false,
            limits: RunLimits { max_iters: 24, max_nodes: 4_000, ..RunLimits::default() },
            ..VerifyConfig::default()
        });
        let t0 = std::time::Instant::now();
        let report = verifier.verify(&pair).unwrap();
        let outcome = match report.verdict {
            Verdict::ResourceExhausted { .. } => "resource-exhausted (as paper)",
            Verdict::Verified => "verified",
            Verdict::Unverified { .. } => "unverified",
        };
        table.row(&["no partitioning".into(), outcome.into(), fmt_duration(t0.elapsed())]);
    }

    let mut run = |label: &str, cfgv: VerifyConfig| {
        let verifier = Session::new(cfgv);
        let stats = bench(label, 1, 3, || {
            let r = verifier.verify(&pair).unwrap();
            assert!(r.verified(), "{label}: {:?}", r.verdict);
            r
        });
        table.row(&[label.into(), "verified".into(), fmt_duration(stats.median())]);
    };

    run(
        "graph partitioning (sequential)",
        VerifyConfig { parallel: false, memoize: false, ..VerifyConfig::default() },
    );
    run(
        "partitioning + parallel rewriting",
        VerifyConfig { parallel: true, memoize: false, ..VerifyConfig::default() },
    );
    run(
        "partitioning + parallel + layer memoization",
        VerifyConfig { parallel: true, memoize: true, ..VerifyConfig::default() },
    );

    print!("{}", table.render());
    table.save_csv("fig12_ablation");
}
