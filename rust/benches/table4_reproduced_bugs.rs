//! Table 4: the 19 reproduced production bugs — detection + localization
//! precision + per-bug verification time (paper: all detected ones under
//! one minute; 17/19 detected, Bug#18-19 n/a).

use scalify::bugs::{evaluate, reproduced_bugs, ExpectedLoc, LocResult};
use scalify::report::Table;
use scalify::util::fmt_duration;

fn main() {
    let mut table = Table::new(
        "Table 4 — reproduced bugs",
        &["Bug", "Description", "Issue", "Paper", "Result", "Time"],
    );
    let mut detected = 0;
    let mut na = 0;
    for case in reproduced_bugs() {
        let outcome = evaluate(&case);
        let paper = match case.expected {
            ExpectedLoc::Instruction => "instr",
            ExpectedLoc::Function => "func",
            ExpectedLoc::NotApplicable => "n/a",
        };
        let result = match (outcome.detected, outcome.loc) {
            (false, _) if case.expected == ExpectedLoc::NotApplicable => {
                na += 1;
                "n/a (outside graph)".to_string()
            }
            (false, _) => "MISSED".to_string(),
            (true, LocResult::Instruction) => {
                detected += 1;
                "detected @instr".to_string()
            }
            (true, LocResult::Function) => {
                detected += 1;
                "detected @func".to_string()
            }
            (true, _) => {
                detected += 1;
                "detected".to_string()
            }
        };
        table.row(&[
            case.id.into(),
            case.description.into(),
            case.issue.into(),
            paper.into(),
            result,
            fmt_duration(outcome.duration),
        ]);
    }
    print!("{}", table.render());
    println!("summary: {detected}/19 detected, {na} n/a — paper: 17/19 detected, 2 n/a");
    assert_eq!(detected, 17);
    assert_eq!(na, 2);
    table.save_csv("table4_reproduced_bugs");
}
