//! Engine microbenchmarks: the hot paths of the verifier (L3 perf pass
//! targets, EXPERIMENTS.md §Perf).

use scalify::bench::bench;
use scalify::egraph::{default_rules, EGraph, ENode, RunLimits, Runner};
use scalify::hlo::{parse_hlo_module, print_hlo_module};
use scalify::layout::{infer_bijection, AtomStore, AxisExpr};
use scalify::modelgen::{llama_pair, LlamaConfig, Parallelism};
use scalify::report::Table;
use scalify::util::fmt_duration;
use scalify::verifier::{Session, VerifyConfig};

fn main() {
    let mut table = Table::new("Engine microbenchmarks", &["Path", "Median", "Mean"]);
    let mut add = |label: &str, stats: scalify::bench::Stats| {
        table.row(&[label.into(), fmt_duration(stats.median()), fmt_duration(stats.mean())]);
    };

    // e-graph: build + saturate one decoder layer pair worth of nodes
    add("egraph: saturate transpose/reshape tower", bench("egraph", 3, 20, || {
        let mut eg = EGraph::new();
        let x = eg.add(ENode::new(
            scalify::ir::Op::Parameter { index: 0, name: "x".into() },
            vec![],
        ));
        let mut cur = x;
        for i in 0..40u32 {
            let perm = if i % 2 == 0 { vec![1, 0, 2] } else { vec![2, 0, 1] };
            cur = eg.add(ENode::new(scalify::ir::Op::Transpose { perm }, vec![cur]));
        }
        let rules = default_rules();
        Runner::new(&rules, RunLimits::default()).run(&mut eg)
    }));

    // bijection inference on Figure-9-scale expressions
    add("bijection inference (Fig. 9 shape)", bench("bij", 10, 200, || {
        let mut st = AtomStore::new();
        let x = AxisExpr::from_shape(&mut st, &[4, 64, 4096]);
        let b = x.reshape(&mut st, &[256, 4096]).unwrap();
        let d = x.transpose(&[1, 0, 2]).unwrap();
        infer_bijection(&st, &b, &d).unwrap()
    }));

    // HLO parse + print round-trip throughput on a real decoder layer
    let pair = llama_pair(
        &LlamaConfig { layers: 1, ..LlamaConfig::llama3_8b() },
        Parallelism::Tensor { tp: 32 },
    );
    let text = print_hlo_module(&pair.dist);
    add(
        &format!("hlo parse ({} nodes)", pair.dist.len()),
        bench("parse", 3, 30, || parse_hlo_module(&text, 32).unwrap()),
    );

    // one full layer-pair verification (the per-layer unit of Algorithm 1)
    let verifier = Session::new(VerifyConfig { parallel: false, memoize: false, ..Default::default() });
    add("verify one decoder layer pair", bench("layer", 2, 10, || {
        verifier.verify(&pair).unwrap()
    }));

    print!("{}", table.render());
    table.save_csv("engine_microbench");
}
