//! Session reuse: the amortization the `Session` API exists for.
//!
//! One persistent session verifies (1) a Llama-8B tp32 pair cold, (2) the
//! same pair again — every layer served from the cross-run memo, (3) a
//! structurally-overlapping second config (same shapes, fewer layers) —
//! warm from the first run's layers, and (4) the same pair on a *fresh*
//! session as the contrast: the speedup lives in the session state, not
//! in the OS cache.
//!
//! Run: `cargo bench --bench session_reuse` (or `cargo run --release ...`)

use scalify::bench::time_once;
use scalify::modelgen::{llama_pair, LlamaConfig, Parallelism};
use scalify::report::Table;
use scalify::util::fmt_duration;
use scalify::verifier::{Session, VerifyConfig};

fn main() {
    let cfg = LlamaConfig::llama3_8b();
    let par = Parallelism::Tensor { tp: 32 };
    let session = Session::new(VerifyConfig::default());
    let mut table = Table::new(
        "Session reuse — one engine, many verify calls (Llama-8B tp32)",
        &["Run", "Layers", "Memoized", "Time"],
    );
    let mut row = |label: &str, report: &scalify::verifier::VerifyReport, t| {
        assert!(report.verified(), "{label}: {:?}", report.verdict);
        table.row(&[
            label.into(),
            report.layers.len().to_string(),
            report.layers.iter().filter(|l| l.memoized).count().to_string(),
            fmt_duration(t),
        ]);
    };

    // (1) cold: templates are already compiled (Session::new), but every
    // distinct layer structure is verified for the first time
    let pair = llama_pair(&cfg, par);
    let (cold, s1) = time_once("cold", || session.verify(&pair).unwrap());
    row("cold (first verify)", &cold, s1.median());

    // (2) the same pair, rebuilt: every layer hits the cross-run memo
    let pair_again = llama_pair(&cfg, par);
    let (warm, s2) = time_once("warm", || session.verify(&pair_again).unwrap());
    row("warm (same pair rebuilt)", &warm, s2.median());

    // (3) structurally-overlapping second config: fewer layers, same
    // shapes — its decoder layers replay the first run's results
    let small = LlamaConfig { layers: 8, ..cfg };
    let overlap_pair = llama_pair(&small, par);
    let (overlap, s3) = time_once("overlap", || session.verify(&overlap_pair).unwrap());
    row("overlapping config (8 layers)", &overlap, s3.median());

    // (4) contrast: a fresh session pays the cold cost again
    let fresh = Session::new(VerifyConfig::default());
    let (fresh_report, s4) = time_once("fresh", || fresh.verify(&pair).unwrap());
    row("fresh session (cold again)", &fresh_report, s4.median());

    print!("{}", table.render());
    table.save_csv("session_reuse");

    // (5) pipeline case: per-stage/per-layer partitions keep each verify
    // call's e-graph small — contrast the max layer e-graph against the
    // single whole-graph e-graph of an unpartitioned run
    let pipe_cfg = LlamaConfig { layers: 8, ..LlamaConfig::tiny() };
    let pipe = llama_pair(&pipe_cfg, Parallelism::Pipeline { pp: 4 });
    let (pipe_report, s5) = time_once("pipeline", || session.verify(&pipe).unwrap());
    row("pipeline pp4 (8 tiny layers)", &pipe_report, s5.median());
    let whole_session = Session::new(
        scalify::verifier::VerifyConfig::builder()
            .partition(false)
            .parallel(false)
            .build()
            .expect("valid config"),
    );
    let (whole_report, s6) = time_once("pipeline-whole", || whole_session.verify(&pipe).unwrap());
    row("pipeline pp4, no partition", &whole_report, s6.median());
    let max_layer_egraph =
        pipe_report.layers.iter().map(|l| l.egraph_nodes).max().unwrap_or(0);
    let whole_egraph =
        whole_report.layers.iter().map(|l| l.egraph_nodes).max().unwrap_or(0);
    println!(
        "pipeline e-graph size: {max_layer_egraph} max per layer (partitioned) vs \
         {whole_egraph} whole-graph"
    );
    assert!(
        max_layer_egraph < whole_egraph,
        "per-stage partitions must shrink the per-call e-graph"
    );

    let stats = session.stats();
    println!(
        "session stats: {} runs, {} memo entries, {} hits, {} misses, {} templates",
        stats.runs, stats.memo_entries, stats.memo_hits, stats.memo_misses, stats.templates
    );

    // the acceptance claim: a warm second verify is measurably faster
    assert!(
        warm.layers.iter().all(|l| l.memoized),
        "warm run must serve every layer from the session memo"
    );
    assert!(
        s2.median() < s1.median(),
        "warm verify ({}) must beat the cold verify ({})",
        fmt_duration(s2.median()),
        fmt_duration(s1.median())
    );
    let speedup = s1.median().as_secs_f64() / s2.median().as_secs_f64().max(1e-9);
    println!("cross-run speedup (cold/warm): {speedup:.1}x");
}
