//! §7.1 contrast: Scalify vs the numerical-diffing practice vs the
//! TrainVerify-style per-element cost model. Paper: TrainVerify takes days
//! on Llama-405B where Scalify takes minutes — per-element reasoning
//! scales with tensor elements, Scalify with graph structure. We measure
//! per-element cost on a small pair and extrapolate the rate to the
//! Table-2 model shapes.

use scalify::baseline::{numerical_verify, per_element_verify};
use scalify::bench::time_once;
use scalify::modelgen::{llama_pair, LlamaConfig, Parallelism};
use scalify::report::Table;
use scalify::util::fmt_duration;
use scalify::verifier::{Session, VerifyConfig};

fn main() {
    let cfg =
        LlamaConfig { layers: 2, hidden: 16, heads: 4, kv_heads: 4, ffn: 32, seqlen: 4, batch: 1 };
    let pair = llama_pair(&cfg, Parallelism::Tensor { tp: 2 });
    let mut table = Table::new(
        "Baseline contrast — same pair, three verifiers",
        &["Method", "Verdict", "Time", "Scales with"],
    );

    let verifier = Session::new(VerifyConfig::default());
    let (report, s) = time_once("scalify", || verifier.verify(&pair).unwrap());
    table.row(&[
        "Scalify (this work)".into(),
        if report.verified() { "verified".into() } else { "unverified".into() },
        fmt_duration(s.median()),
        "graph structure".into(),
    ]);

    let (num, s2) = time_once("numerical", || numerical_verify(&pair, 3, 1e-3, 7));
    table.row(&[
        "numerical diffing (3 trials)".into(),
        if num.equivalent { "within tol".into() } else { "diverged".into() },
        fmt_duration(s2.median()),
        "tensor sizes × trials".into(),
    ]);

    let elements = 16usize;
    let (pe, s3) = time_once("per-element", || per_element_verify(&pair, 1e-3, 7, elements));
    let per_elem = s3.median() / elements as u32;
    table.row(&[
        format!("per-element (TrainVerify-style, {elements} of all elems)"),
        if pe.equivalent { "within tol".into() } else { "diverged".into() },
        fmt_duration(s3.median()),
        "elements × graph".into(),
    ]);

    // extrapolate the per-element rate to the Table-2 output sizes
    let big = LlamaConfig::llama3_405b();
    let big_elems = (big.tokens() * big.hidden) as u32;
    let projected = per_elem * big_elems;
    table.row(&[
        "per-element projected to Llama-405B outputs".into(),
        "—".into(),
        fmt_duration(projected),
        format!("{big_elems} elements"),
    ]);

    print!("{}", table.render());
    println!(
        "shape check: per-element ≫ Scalify by ~{}× already at toy scale; the paper's days-vs-minutes gap",
        (s3.median().as_nanos() / s.median().as_nanos().max(1)).max(1)
    );
    table.save_csv("baseline_contrast");
}
