//! Figure 11: verification-time scaling over the five controlled sweeps
//! (Table 3 configurations). Expected shapes: (a) seqlen, (b) batch,
//! (d) tp and (e) heads are ~constant; (c) layers is linear (flattened by
//! memoization only in the memo-on config; the paper sweeps with the full
//! pipeline, which we mirror).

use scalify::bench::bench;
use scalify::modelgen::{llama_pair, LlamaConfig, Parallelism};
use scalify::report::Table;
use scalify::util::fmt_duration;
use scalify::verifier::{Session, VerifyConfig};

fn base_cfg() -> LlamaConfig {
    // Table 3 base: seqlen 64, bs 4, layers 32, tp 32, heads 32 — with
    // bench-scale layer count kept at the paper's 32
    LlamaConfig {
        layers: 32,
        hidden: 4096,
        heads: 32,
        kv_heads: 32,
        ffn: 14336,
        seqlen: 64,
        batch: 4,
    }
}

fn run(table: &mut Table, group: &str, label: String, cfg: LlamaConfig, tp: u32) {
    let verifier = Session::new(VerifyConfig::default());
    let pair = llama_pair(&cfg, Parallelism::Tensor { tp });
    let stats = bench(&label, 1, 3, || {
        let r = verifier.verify(&pair).unwrap();
        assert!(r.verified());
        r
    });
    table.row(&[
        group.into(),
        label,
        pair.total_nodes().to_string(),
        fmt_duration(stats.median()),
    ]);
}

fn main() {
    let mut table = Table::new(
        "Figure 11 — scalability sweeps (Table 3 configs)",
        &["Group", "Config", "Nodes", "Median time"],
    );

    // (a) sequence length — constant (graph size is shape-independent)
    for seqlen in [64, 256, 1024, 4096, 8192] {
        run(&mut table, "a:seqlen", format!("seqlen={seqlen}"),
            LlamaConfig { seqlen, ..base_cfg() }, 32);
    }
    // (b) batch size — constant
    for batch in [1, 4, 16, 64] {
        run(&mut table, "b:batch", format!("batch={batch}"),
            LlamaConfig { batch, ..base_cfg() }, 32);
    }
    // (c) layers — linear
    for layers in [8, 16, 32, 64, 126] {
        run(&mut table, "c:layers", format!("layers={layers}"),
            LlamaConfig { layers, ..base_cfg() }, 32);
    }
    // (d) tensor-parallel degree — constant
    for tp in [2, 4, 8, 16, 32] {
        run(&mut table, "d:tp", format!("tp={tp}"), base_cfg(), tp);
    }
    // (e) heads — constant
    for heads in [8, 16, 32, 64] {
        let hidden = heads * 128;
        run(&mut table, "e:heads", format!("heads={heads}"),
            LlamaConfig { heads, kv_heads: heads, hidden, ..base_cfg() }, 8);
    }

    print!("{}", table.render());
    table.save_csv("fig11_scalability");
}
