//! Relation propagation engine: the Table-1 rule templates.
//!
//! Rules are dispatched by the distributed node's operator ("polymorphic
//! over operator types", paper §6) over the facts of its operands. All
//! lookups go through the e-graph, so structurally-normalized terms match
//! even when the two graphs spell them differently.

use super::facts::{Fact, FactKey, PerCoreFact};
use crate::egraph::{EGraph, ENode, Id};
use crate::ir::{AxesMask, Graph, Mesh, Node, NodeId, Op, ReduceKind, ReplicaGroups};
use crate::layout::{AtomStore, AxisExpr};
use rustc_hash::{FxHashMap, FxHashSet};


/// Lookup an e-node, requiring the found class to contain a *baseline*
/// term. Without this check, a distributed node that the e-graph merged
/// with prior facts could be found as its own "baseline partner", which
/// would let a divergent chain silently verify against itself.
fn lookup_base(eg: &EGraph, enode: &ENode) -> Option<Id> {
    eg.lookup(enode).filter(|&id| eg.class(id).data.origin.baseline)
}

/// Shard stride profile of a flattened index: total extent plus the
/// (stride, size, mesh axis) of every core-distributed digit. Two operands
/// whose profiles match embed their local indices into the global index
/// the same way **and follow the same mesh digits**, so their per-core
/// values pair correctly — a dp-sharded and a tp-sharded contraction digit
/// of equal geometry must not pair (they select different slices on a
/// given core).
fn shard_profile(
    st: &AtomStore,
    leaves: &[crate::layout::AtomId],
    missing: &[crate::layout::AtomId],
) -> (i64, Vec<(i64, i64, u8)>) {
    let total: i64 = leaves.iter().map(|&a| st.size(a)).product();
    let mut out = Vec::new();
    let mut stride = total;
    for &a in leaves {
        stride /= st.size(a);
        if missing.contains(&a) {
            out.push((stride, st.size(a), st.mesh_axis(a)));
        }
    }
    out.sort_unstable();
    (total, out)
}

/// Union of the mesh-axis bits of a set of shard atoms.
fn axes_of(st: &AtomStore, atoms: &[crate::layout::AtomId]) -> AxesMask {
    atoms.iter().fold(0, |m, &a| m | (1 << st.mesh_axis(a)))
}

/// Graph-pair context handed to the engine by the verifier.
pub struct GraphCtx<'a> {
    /// Baseline graph.
    pub base: &'a Graph,
    /// Distributed graph.
    pub dist: &'a Graph,
    /// Baseline node → e-class.
    pub b2c: &'a [Id],
    /// Distributed node → e-class.
    pub d2c: &'a [Id],
    /// Baseline use-lists.
    pub base_uses: &'a [Vec<NodeId>],
    /// Lazy class → baseline-node index (valid for one propagation round —
    /// unions between rounds invalidate it, so the verifier rebuilds the
    /// context each round).
    pub class_index: std::cell::RefCell<Option<FxHashMap<Id, Vec<NodeId>>>>,
}

impl<'a> GraphCtx<'a> {
    /// Baseline nodes whose class canonicalizes to `class` — served from a
    /// lazily-built index (the previous full-graph scan per dot-fact was
    /// the top L3 hotspot, see EXPERIMENTS.md §Perf).
    fn base_nodes_of(&self, eg: &EGraph, class: Id) -> Vec<NodeId> {
        let canon = eg.find(class);
        let mut cache = self.class_index.borrow_mut();
        let idx = cache.get_or_insert_with(|| {
            let mut idx: FxHashMap<Id, Vec<NodeId>> = FxHashMap::default();
            for n in &self.base.nodes {
                idx.entry(eg.find(self.b2c[n.id.idx()])).or_default().push(n.id);
            }
            idx
        });
        idx.get(&canon).cloned().unwrap_or_default()
    }
}

/// Outcome of processing one distributed node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepOutcome {
    /// At least one new fact derived.
    Derived,
    /// Facts existed already; nothing new.
    Known,
    /// Inputs carry facts but no rule fired — a discrepancy frontier
    /// candidate (§5.3).
    NoRule,
    /// Inputs don't have facts yet.
    NotReady,
}

/// The relation store + rule engine.
pub struct RelEngine {
    /// Shared symbolic-axis store.
    pub store: AtomStore,
    facts: FxHashMap<Id, Vec<Fact>>,
    keys: FxHashSet<FactKey>,
    percore: FxHashMap<Id, Vec<PerCoreFact>>,
    /// SPMD width (total cores — the mesh's axis-size product).
    pub cores: u32,
    /// Logical mesh over the cores: subgroup collectives are interpreted
    /// against its axes ([`Mesh::groups_for`]).
    pub mesh: Mesh,
    /// Facts added since construction (monotone counter for fixpoints).
    pub fact_count: usize,
}

impl RelEngine {
    /// New engine for a flat `cores`-wide mesh.
    pub fn new(cores: u32) -> RelEngine {
        RelEngine::with_mesh(Mesh::flat(cores))
    }

    /// New engine over an explicit mesh geometry.
    pub fn with_mesh(mesh: Mesh) -> RelEngine {
        RelEngine {
            store: AtomStore::new(),
            facts: FxHashMap::default(),
            keys: FxHashSet::default(),
            percore: FxHashMap::default(),
            cores: mesh.total(),
            mesh,
            fact_count: 0,
        }
    }

    /// The mesh-axis subset a collective's replica groups span, if they
    /// match one (memo-free: meshes are tiny). Normalized: size-1 axes
    /// never appear in the returned mask.
    fn groups_axes(&self, groups: &ReplicaGroups) -> Option<AxesMask> {
        self.mesh.axes_of_groups(groups).map(|m| self.mesh.normalize_mask(m))
    }

    /// Mask comparison modulo degenerate axes.
    fn same_axes(&self, a: AxesMask, b: AxesMask) -> bool {
        self.mesh.normalize_mask(a) == self.mesh.normalize_mask(b)
    }

    /// Add a fact (deduped). Returns true when new.
    pub fn add_fact(&mut self, eg: &EGraph, mut fact: Fact) -> bool {
        fact.base = eg.find(fact.base);
        fact.dist = eg.find(fact.dist);
        let key = fact.key(&self.store);
        if !self.keys.insert(key) {
            return false;
        }
        self.facts.entry(fact.dist).or_default().push(fact);
        self.fact_count += 1;
        true
    }

    /// Add a per-core fact (deduped).
    pub fn add_percore(&mut self, eg: &EGraph, mut fact: PerCoreFact) -> bool {
        fact.dist = eg.find(fact.dist);
        for b in fact.bases.iter_mut() {
            *b = eg.find(*b);
        }
        let list = self.percore.entry(fact.dist).or_default();
        if list.contains(&fact) {
            return false;
        }
        list.push(fact);
        self.fact_count += 1;
        true
    }

    /// Facts of a distributed class.
    pub fn facts_for(&self, eg: &EGraph, dist: Id) -> Vec<Fact> {
        self.facts.get(&eg.find(dist)).cloned().unwrap_or_default()
    }

    /// Per-core facts of a distributed class.
    pub fn percore_for(&self, eg: &EGraph, dist: Id) -> Vec<PerCoreFact> {
        self.percore.get(&eg.find(dist)).cloned().unwrap_or_default()
    }

    /// True when class `dist` has any relation at all.
    pub fn has_any(&self, eg: &EGraph, dist: Id) -> bool {
        let c = eg.find(dist);
        self.facts.get(&c).map(|v| !v.is_empty()).unwrap_or(false)
            || self.percore.get(&c).map(|v| !v.is_empty()).unwrap_or(false)
    }

    /// Re-key the stores after e-graph unions moved canonical ids.
    pub fn rekey(&mut self, eg: &EGraph) {
        let facts = std::mem::take(&mut self.facts);
        for (_, list) in facts {
            for mut f in list {
                f.base = eg.find(f.base);
                f.dist = eg.find(f.dist);
                let key = f.key(&self.store);
                if self.keys.insert(key) {
                    self.fact_count += 1;
                }
                self.facts.entry(f.dist).or_default().push(f);
            }
        }
        let percore = std::mem::take(&mut self.percore);
        for (_, list) in percore {
            for mut f in list {
                f.dist = eg.find(f.dist);
                for b in f.bases.iter_mut() {
                    *b = eg.find(*b);
                }
                let entry = self.percore.entry(f.dist).or_default();
                if !entry.contains(&f) {
                    entry.push(f);
                }
            }
        }
    }

    // ---------------------------------------------------------------
    // Input registration (§5.2.1)
    // ---------------------------------------------------------------

    /// Register `dist` param as `base` param sharded along `dim` over mesh
    /// axis `axis` (`parts` must equal that axis's size).
    pub fn register_shard(
        &mut self,
        eg: &EGraph,
        base: Id,
        dist: Id,
        base_dims: &[i64],
        dim: usize,
        parts: u32,
        axis: usize,
    ) {
        let base_expr = AxisExpr::from_shape(&mut self.store, base_dims);
        let axis_atom = base_expr.axes[dim][0];
        let kids = self
            .store
            .split_leaf(axis_atom, &[parts as i64, base_dims[dim] / parts as i64])
            .expect("shard split");
        let _ = self.store.set_mesh_axis(kids[0], axis as u8); // fresh atom: always tags
        let mut dist_axes = base_expr.axes.clone();
        dist_axes[dim] = vec![kids[1]];
        let fact = Fact {
            base,
            dist,
            base_expr,
            dist_expr: AxisExpr::from_axes(dist_axes),
            shard_atoms: vec![kids[0]],
            partial: None,
            partial_axes: 0,
        };
        self.add_fact(eg, fact);
    }

    /// Register `dist` param as `base` sharded along several dims at once
    /// — `(dim, parts, axis)` entries over distinct dims and axes (the
    /// dp×tp boundary form).
    pub fn register_mesh_shard(
        &mut self,
        eg: &EGraph,
        base: Id,
        dist: Id,
        base_dims: &[i64],
        entries: &[(usize, u32, usize)],
    ) {
        let base_expr = AxisExpr::from_shape(&mut self.store, base_dims);
        let mut dist_axes = base_expr.axes.clone();
        let mut shard_atoms = Vec::with_capacity(entries.len());
        for &(dim, parts, axis) in entries {
            let axis_atom = base_expr.axes[dim][0];
            let kids = self
                .store
                .split_leaf(axis_atom, &[parts as i64, base_dims[dim] / parts as i64])
                .expect("mesh shard split");
            let _ = self.store.set_mesh_axis(kids[0], axis as u8); // fresh atom
            dist_axes[dim] = vec![kids[1]];
            shard_atoms.push(kids[0]);
        }
        let fact = Fact {
            base,
            dist,
            base_expr,
            dist_expr: AxisExpr::from_axes(dist_axes),
            shard_atoms,
            partial: None,
            partial_axes: 0,
        };
        self.add_fact(eg, fact);
    }

    /// Register `dist` param as a full replica of `base`.
    pub fn register_replicated(&mut self, eg: &EGraph, base: Id, dist: Id, dims: &[i64]) {
        let expr = AxisExpr::from_shape(&mut self.store, dims);
        self.add_fact(eg, Fact::duplicate(base, dist, expr));
    }

    /// Register `dist` param as a per-core partial of `base` over the
    /// masked mesh axes (layer boundaries can carry undischarged partials
    /// forward).
    pub fn register_partial(
        &mut self,
        eg: &EGraph,
        base: Id,
        dist: Id,
        dims: &[i64],
        kind: ReduceKind,
        axes: AxesMask,
    ) {
        let expr = AxisExpr::from_shape(&mut self.store, dims);
        let fact = Fact {
            base,
            dist,
            base_expr: expr.clone(),
            dist_expr: expr,
            shard_atoms: vec![],
            partial: Some(kind),
            partial_axes: if axes == 0 { 1 } else { axes },
        };
        self.add_fact(eg, fact);
    }

    // ---------------------------------------------------------------
    // Rule dispatch
    // ---------------------------------------------------------------

    /// Process one distributed node; derive facts for its class.
    pub fn process_dist_node(&mut self, eg: &mut EGraph, ctx: &GraphCtx, node: &Node) -> StepOutcome {
        let dclass = eg.find(ctx.d2c[node.id.idx()]);
        let mut derived = false;

        // Template 0 (structural sharing): the e-graph merged this term
        // with a baseline term — it is its own duplicate.
        let origin = eg.class(dclass).data.origin;
        if origin.baseline && origin.distributed {
            let expr = AxisExpr::from_shape(&mut self.store, &node.shape.dims);
            derived |= self.add_fact(eg, Fact::duplicate(dclass, dclass, expr));
        }

        let in_classes: Vec<Id> =
            node.inputs.iter().map(|&i| eg.find(ctx.d2c[i.idx()])).collect();
        let inputs_have_facts =
            !in_classes.is_empty() && in_classes.iter().all(|&c| self.has_any(eg, c));

        derived |= match &node.op {
            Op::Parameter { .. } | Op::Constant(_) | Op::Iota { .. } => false,
            op if op.is_elementwise() && node.inputs.len() == 1 => {
                self.rule_unary(eg, node, dclass, in_classes[0])
            }
            Op::Convert { .. } => self.rule_unary(eg, node, dclass, in_classes[0]),
            op if op.is_elementwise() && node.inputs.len() >= 2 => {
                self.rule_nary_elementwise(eg, node, dclass, &in_classes)
            }
            Op::Reshape { .. } | Op::Transpose { .. } => {
                self.rule_dist_layout(eg, node, dclass, in_classes[0])
            }
            Op::Dot { .. } => self.rule_dot(eg, ctx, node, dclass, &in_classes),
            Op::Slice { .. } => self.rule_slice(eg, node, dclass, in_classes[0]),
            Op::Concat { .. } => self.rule_concat(eg, node, dclass, &in_classes),
            Op::Broadcast { .. } => self.rule_broadcast(eg, node, dclass, in_classes[0]),
            Op::Reduce { .. } => self.rule_reduce(eg, node, dclass, in_classes[0]),
            Op::AllReduce { kind, groups } => {
                self.rule_all_reduce(eg, node, dclass, in_classes[0], *kind, groups)
            }
            Op::AllGather { dim, groups } => {
                self.rule_all_gather(eg, node, dclass, in_classes[0], *dim, groups)
            }
            Op::ReduceScatter { kind, dim, groups } => {
                self.rule_reduce_scatter(eg, node, dclass, in_classes[0], *kind, *dim, groups)
            }
            Op::AllToAll { split_dim, concat_dim, groups } => {
                self.rule_all_to_all(eg, node, dclass, in_classes[0], *split_dim, *concat_dim, groups)
            }
            Op::Send { .. } | Op::Recv { .. } => {
                self.rule_boundary_hop(eg, dclass, in_classes[0])
            }
            Op::Custom { .. } | Op::Tuple | Op::GetTupleElement { .. } => {
                self.rule_uninterpreted(eg, node, dclass, &in_classes)
            }
            _ => false,
        };

        // Fine-grained slicing: a freshly-sharded input may also relate
        // per-core to explicit baseline slice nodes (Figure 8).
        derived |= self.try_derive_percore(eg, dclass);

        if derived {
            StepOutcome::Derived
        } else if self.has_any(eg, dclass) {
            StepOutcome::Known
        } else if inputs_have_facts {
            StepOutcome::NoRule
        } else {
            StepOutcome::NotReady
        }
    }

    /// Baseline-side layout composition: `layout(x,x',ℓ) ∧ z = transpose(x)
    /// ⟹ layout(z, x', ℓ∘transposeᵀ)` — walk baseline layout consumers of
    /// every fact base and extend the relation (Table 1 Layout rules).
    pub fn propagate_base_layouts(&mut self, eg: &mut EGraph, ctx: &GraphCtx) -> usize {
        let mut new = 0;
        let all: Vec<Fact> = self.facts.values().flatten().cloned().collect();
        for fact in all {
            for bnode_id in ctx.base_nodes_of(eg, fact.base) {
                for &use_id in &ctx.base_uses[bnode_id.idx()] {
                    let unode = ctx.base.node(use_id);
                    let new_base_expr = match &unode.op {
                        Op::Transpose { perm } => match fact.base_expr.transpose(perm) {
                            Ok(e) => e,
                            Err(_) => continue,
                        },
                        Op::Reshape { .. } => {
                            match fact.base_expr.reshape(&mut self.store, &unode.shape.dims) {
                                Ok(e) => e,
                                Err(_) => continue,
                            }
                        }
                        _ => continue,
                    };
                    let f = Fact {
                        base: ctx.b2c[use_id.idx()],
                        dist: fact.dist,
                        base_expr: new_base_expr,
                        dist_expr: fact.dist_expr.clone(),
                        shard_atoms: fact.shard_atoms.clone(),
                        partial: fact.partial,
                        partial_axes: fact.partial_axes,
                    };
                    if self.add_fact(eg, f) {
                        new += 1;
                    }
                }
            }
        }
        new
    }

    // ---------------------------------------------------------------
    // Individual rule templates
    // ---------------------------------------------------------------

    fn rule_unary(&mut self, eg: &mut EGraph, node: &Node, dclass: Id, xc: Id) -> bool {
        let mut derived = false;
        for f in self.facts_for(eg, xc) {
            // partial propagation: only linearity-preserving ops
            if f.partial.is_some()
                && !matches!(node.op, Op::Neg | Op::Convert { .. })
            {
                continue;
            }
            let Some(partner) = lookup_base(eg, &ENode::new(node.op.clone(), vec![f.base])) else {
                continue;
            };
            let nf = Fact { base: partner, dist: dclass, ..f.clone() };
            derived |= self.add_fact(eg, nf);
        }
        // per-core propagation
        for pc in self.percore_for(eg, xc) {
            let partners: Option<Vec<Id>> = pc
                .bases
                .iter()
                .map(|&b| lookup_base(eg, &ENode::new(node.op.clone(), vec![b])))
                .collect();
            if let Some(bases) = partners {
                derived |= self.add_percore(eg, PerCoreFact { dist: dclass, bases });
            }
        }
        derived
    }

    fn rule_nary_elementwise(
        &mut self,
        eg: &mut EGraph,
        node: &Node,
        dclass: Id,
        ins: &[Id],
    ) -> bool {
        let mut derived = false;
        let fact_lists: Vec<Vec<Fact>> =
            ins.iter().map(|&c| self.facts_for(eg, c)).collect();
        // cartesian product is tiny in practice (1-2 facts per class)
        let mut idx = vec![0usize; ins.len()];
        'combos: loop {
            let combo: Vec<&Fact> = idx
                .iter()
                .enumerate()
                .filter_map(|(i, &j)| fact_lists[i].get(j))
                .collect();
            if combo.len() == ins.len() {
                if let Some(f) = self.try_elementwise_combo(eg, node, dclass, &combo) {
                    derived |= self.add_fact(eg, f);
                }
            }
            // advance multi-index
            for i in 0..ins.len() {
                idx[i] += 1;
                if idx[i] < fact_lists[i].len().max(1) {
                    continue 'combos;
                }
                idx[i] = 0;
            }
            break;
        }
        // per-core: exactly one PerCore operand, the rest identity dups
        derived |= self.percore_elementwise(eg, node, dclass, ins);
        derived
    }

    fn try_elementwise_combo(
        &mut self,
        eg: &EGraph,
        node: &Node,
        dclass: Id,
        combo: &[&Fact],
    ) -> Option<Fact> {
        // signatures must agree across non-scalar operands
        let sigs: Vec<_> = combo.iter().map(|f| f.signature(&self.store)).collect();
        let non_scalar: Vec<usize> =
            (0..combo.len()).filter(|&i| !sigs[i].axes.is_empty()).collect();
        let lead = *non_scalar.first()?;
        for &i in &non_scalar {
            if sigs[i].axes != sigs[lead].axes || sigs[i].shard_pos != sigs[lead].shard_pos {
                return None;
            }
        }
        // partial combination table; a pending reduction only combines
        // with another pending reduction over the SAME mesh axes — summing
        // a dp-partial into a tp-partial has no linear-algebra identity
        let partials: Vec<Option<ReduceKind>> = combo.iter().map(|f| f.partial).collect();
        let masks: Vec<AxesMask> = combo.iter().map(|f| f.partial_axes).collect();
        let same_mask = |want: AxesMask| masks.iter().all(|&m| m == want);
        let (partial, partial_axes) = match &node.op {
            Op::Add | Op::Sub => {
                if partials.iter().all(|p| *p == Some(ReduceKind::Add)) {
                    if !same_mask(masks[0]) {
                        return None;
                    }
                    (Some(ReduceKind::Add), masks[0])
                } else if partials.iter().all(|p| p.is_none()) {
                    (None, 0)
                } else {
                    return None; // partial + non-partial: the missing-allreduce bug
                }
            }
            Op::Mul | Op::Div => {
                let n_partial = partials.iter().filter(|p| p.is_some()).count();
                match n_partial {
                    0 => (None, 0),
                    1 if partials[0] == Some(ReduceKind::Add) && matches!(node.op, Op::Mul | Op::Div) => {
                        // (Σ xᵣ) ⊙ y = Σ (xᵣ ⊙ y) when y is duplicate
                        (Some(ReduceKind::Add), masks[0])
                    }
                    1 if partials.last() == Some(&Some(ReduceKind::Add))
                        && matches!(node.op, Op::Mul) =>
                    {
                        (Some(ReduceKind::Add), *masks.last().unwrap_or(&0))
                    }
                    _ => return None,
                }
            }
            Op::Max | Op::Min => {
                let want = if matches!(node.op, Op::Max) { ReduceKind::Max } else { ReduceKind::Min };
                if partials.iter().all(|p| p.is_none()) {
                    (None, 0)
                } else if partials.iter().all(|p| *p == Some(want)) {
                    if !same_mask(masks[0]) {
                        return None;
                    }
                    (Some(want), masks[0])
                } else {
                    return None;
                }
            }
            _ => {
                if partials.iter().any(|p| p.is_some()) {
                    return None;
                }
                (None, 0)
            }
        };
        // baseline partner
        let bases: Vec<Id> = combo.iter().map(|f| f.base).collect();
        let partner = lookup_base(eg, &ENode::new(node.op.clone(), bases))?;
        Some(Fact {
            base: partner,
            dist: dclass,
            base_expr: combo[lead].base_expr.clone(),
            dist_expr: combo[lead].dist_expr.clone(),
            shard_atoms: combo[lead].shard_atoms.clone(),
            partial,
            partial_axes,
        })
    }

    fn percore_elementwise(&mut self, eg: &mut EGraph, node: &Node, dclass: Id, ins: &[Id]) -> bool {
        // each operand is either per-core (vector of baseline partners) or
        // a duplicate (same partner on every core); at least one per-core
        enum Arg {
            Per(Vec<Id>),
            Dup(Id),
        }
        let mut args = Vec::with_capacity(ins.len());
        let mut any_percore = false;
        for &c in ins {
            if let Some(pc) = self.percore_for(eg, c).into_iter().next() {
                any_percore = true;
                args.push(Arg::Per(pc.bases));
            } else if let Some(f) =
                self.facts_for(eg, c).into_iter().find(|f| f.is_duplicate(&self.store))
            {
                args.push(Arg::Dup(f.base));
            } else {
                return false;
            }
        }
        if !any_percore {
            return false;
        }
        let cores = self.cores as usize;
        let partners: Option<Vec<Id>> = (0..cores)
            .map(|r| {
                let children: Vec<Id> = args
                    .iter()
                    .map(|a| match a {
                        Arg::Per(v) => v[r],
                        Arg::Dup(b) => *b,
                    })
                    .collect();
                lookup_base(eg, &ENode::new(node.op.clone(), children))
            })
            .collect();
        match partners {
            Some(bases) => self.add_percore(eg, PerCoreFact { dist: dclass, bases }),
            None => false,
        }
    }

    /// Uninterpreted ops (`while`/`call` with fingerprinted bodies, tuples):
    /// congruence only — equal op applied to equal (duplicate) arguments
    /// yields equal results.
    fn rule_uninterpreted(&mut self, eg: &mut EGraph, node: &Node, dclass: Id, ins: &[Id]) -> bool {
        let bases: Option<Vec<Id>> = ins
            .iter()
            .map(|&c| {
                self.facts_for(eg, c)
                    .into_iter()
                    .find(|f| f.is_duplicate(&self.store))
                    .map(|f| f.base)
            })
            .collect();
        let Some(bases) = bases else { return false };
        let Some(partner) = lookup_base(eg, &ENode::new(node.op.clone(), bases)) else {
            return false;
        };
        let expr = AxisExpr::from_shape(&mut self.store, &node.shape.dims);
        self.add_fact(eg, Fact::duplicate(partner, dclass, expr))
    }

    fn rule_dist_layout(&mut self, eg: &mut EGraph, node: &Node, dclass: Id, xc: Id) -> bool {
        let mut derived = false;
        for f in self.facts_for(eg, xc) {
            let new_dist = match &node.op {
                Op::Transpose { perm } => match f.dist_expr.transpose(perm) {
                    Ok(e) => e,
                    Err(_) => continue,
                },
                Op::Reshape { .. } => match f.dist_expr.reshape(&mut self.store, &node.shape.dims) {
                    Ok(e) => e,
                    Err(_) => continue,
                },
                _ => unreachable!(),
            };
            let nf = Fact { dist: dclass, dist_expr: new_dist, ..f.clone() };
            derived |= self.add_fact(eg, nf);
        }
        // per-core layout: identical op must exist over each baseline partner
        for pc in self.percore_for(eg, xc) {
            let partners: Option<Vec<Id>> = pc
                .bases
                .iter()
                .map(|&b| lookup_base(eg, &ENode::new(node.op.clone(), vec![b])))
                .collect();
            if let Some(bases) = partners {
                derived |= self.add_percore(eg, PerCoreFact { dist: dclass, bases });
            }
        }
        derived
    }

    fn rule_dot(&mut self, eg: &mut EGraph, ctx: &GraphCtx, node: &Node, dclass: Id, ins: &[Id]) -> bool {
        let Op::Dot { lhs_contract, rhs_contract, lhs_batch, rhs_batch } = &node.op else {
            unreachable!()
        };
        let mut derived = false;
        let fx_list = self.facts_for(eg, ins[0]);
        let fy_list = self.facts_for(eg, ins[1]);
        for fx in &fx_list {
            for fy in &fy_list {
                // partial handling: at most one Add-partial operand; its
                // axes mask rides along so the eventual discharge targets
                // the right subgroup
                let partial_in = match (fx.partial, fy.partial) {
                    (None, None) => None,
                    (Some(ReduceKind::Add), None) => Some((ReduceKind::Add, fx.partial_axes)),
                    (None, Some(ReduceKind::Add)) => Some((ReduceKind::Add, fy.partial_axes)),
                    _ => continue,
                };
                // find baseline dot candidates over (fx.base, fy.base)
                for bx_node in ctx.base_nodes_of(eg, fx.base) {
                    for &use_id in &ctx.base_uses[bx_node.idx()] {
                        let u = ctx.base.node(use_id);
                        let Op::Dot {
                            lhs_contract: blc,
                            rhs_contract: brc,
                            lhs_batch: blb,
                            rhs_batch: brb,
                        } = &u.op
                        else {
                            continue;
                        };
                        if eg.find(ctx.b2c[u.inputs[0].idx()]) != eg.find(fx.base)
                            || eg.find(ctx.b2c[u.inputs[1].idx()]) != eg.find(fy.base)
                        {
                            continue;
                        }
                        if let Some(f) = self.try_dot_match(
                            eg,
                            dclass,
                            ctx.b2c[use_id.idx()],
                            fx,
                            fy,
                            (lhs_contract, rhs_contract, lhs_batch, rhs_batch),
                            (blc, brc, blb, brb),
                            partial_in,
                        ) {
                            derived |= self.add_fact(eg, f);
                        }
                    }
                }
            }
        }
        // per-core dot: any mix of PerCore and duplicate operands
        derived |= self.percore_elementwise(eg, node, dclass, ins);
        derived
    }

    #[allow(clippy::too_many_arguments)]
    fn try_dot_match(
        &mut self,
        _eg: &EGraph,
        dclass: Id,
        partner: Id,
        fx: &Fact,
        fy: &Fact,
        d_dims: (&[usize], &[usize], &[usize], &[usize]),
        b_dims: (&[usize], &[usize], &[usize], &[usize]),
        partial_in: Option<(ReduceKind, AxesMask)>,
    ) -> Option<Fact> {
        let (dlc, drc, dlb, drb) = d_dims;
        let (blc, brc, blb, brb) = b_dims;
        let st = &self.store;
        let leaves = |e: &AxisExpr, dims: &[usize]| -> Vec<crate::layout::AtomId> {
            dims.iter()
                .flat_map(|&d| e.expanded(st).axes[d].clone())
                .filter(|&a| st.size(a) != 1)
                .collect()
        };
        // contracted atoms: dist side vs baseline side
        let d_con_l = leaves(&fx.dist_expr, dlc);
        let d_con_r = leaves(&fy.dist_expr, drc);
        let b_con_l = leaves(&fx.base_expr, blc);
        let b_con_r = leaves(&fy.base_expr, brc);
        // distributed contraction must contract corresponding atoms:
        // baseline contracted atoms = dist contracted atoms + shard atoms
        // missing on the dist side (those become a partial result).
        let missing_l: Vec<_> =
            b_con_l.iter().filter(|a| !d_con_l.contains(a)).copied().collect();
        let missing_r: Vec<_> =
            b_con_r.iter().filter(|a| !d_con_r.contains(a)).copied().collect();
        // dist contracted atoms must be the baseline's, in order, minus the
        // missing shard atoms
        let filt_l: Vec<_> =
            b_con_l.iter().filter(|a| !missing_l.contains(a)).copied().collect();
        let filt_r: Vec<_> =
            b_con_r.iter().filter(|a| !missing_r.contains(a)).copied().collect();
        if filt_l != d_con_l || filt_r != d_con_r {
            return None;
        }
        // missing atoms must be exactly the operands' shard atoms
        if !missing_l.iter().all(|a| fx.shard_atoms.contains(a))
            || !missing_r.iter().all(|a| fy.shard_atoms.contains(a))
        {
            return None;
        }
        // shard-alignment: both operands' shard *stride profiles* over the
        // flattened contraction index must match — each side's shard atoms
        // are *different* atoms (different tensors) but must cover the same
        // contiguous chunk of the contraction index, otherwise the per-core
        // products pair the wrong slices. The profile is {total, multiset
        // of (stride, size) of the distributed digits}: the embedding of a
        // local index into the global index depends only on those.
        if shard_profile(st, &b_con_l, &missing_l) != shard_profile(st, &b_con_r, &missing_r)
        {
            return None;
        }
        // batch dims pair elementwise across the operands: same rules as
        // contraction — missing atoms must be shard atoms with matching
        // stride profiles on both sides (head-sharded attention batches).
        let d_bat_l = leaves(&fx.dist_expr, dlb);
        let b_bat_l = leaves(&fx.base_expr, blb);
        let d_bat_r = leaves(&fy.dist_expr, drb);
        let b_bat_r = leaves(&fy.base_expr, brb);
        let missing_bat_l: Vec<_> =
            b_bat_l.iter().filter(|a| !d_bat_l.contains(a)).copied().collect();
        let missing_bat_r: Vec<_> =
            b_bat_r.iter().filter(|a| !d_bat_r.contains(a)).copied().collect();
        let filt_bat_l: Vec<_> =
            b_bat_l.iter().filter(|a| !missing_bat_l.contains(a)).copied().collect();
        let filt_bat_r: Vec<_> =
            b_bat_r.iter().filter(|a| !missing_bat_r.contains(a)).copied().collect();
        if filt_bat_l != d_bat_l || filt_bat_r != d_bat_r {
            return None;
        }
        if !missing_bat_l.iter().all(|a| fx.shard_atoms.contains(a))
            || !missing_bat_r.iter().all(|a| fy.shard_atoms.contains(a))
        {
            return None;
        }
        if shard_profile(st, &b_bat_l, &missing_bat_l)
            != shard_profile(st, &b_bat_r, &missing_bat_r)
        {
            return None;
        }

        // output exprs: batch ++ lhs-free ++ rhs-free on each side
        let free_axes = |e: &AxisExpr, con: &[usize], bat: &[usize]| -> Vec<Vec<crate::layout::AtomId>> {
            e.axes
                .iter()
                .enumerate()
                .filter(|(i, _)| !con.contains(i) && !bat.contains(i))
                .map(|(_, a)| a.clone())
                .collect()
        };
        let mut base_axes: Vec<Vec<crate::layout::AtomId>> =
            blb.iter().map(|&d| fx.base_expr.axes[d].clone()).collect();
        base_axes.extend(free_axes(&fx.base_expr, blc, blb));
        base_axes.extend(free_axes(&fy.base_expr, brc, brb));
        let mut dist_axes: Vec<Vec<crate::layout::AtomId>> =
            dlb.iter().map(|&d| fx.dist_expr.axes[d].clone()).collect();
        dist_axes.extend(free_axes(&fx.dist_expr, dlc, dlb));
        dist_axes.extend(free_axes(&fy.dist_expr, drc, drb));

        // remaining shard atoms: free/batch shards carry over
        let mut shard_atoms: Vec<_> = fx
            .shard_atoms
            .iter()
            .chain(&fy.shard_atoms)
            .copied()
            .filter(|a| !missing_l.contains(a) && !missing_r.contains(a))
            .collect();
        shard_atoms.sort_unstable();
        shard_atoms.dedup();
        // contracted shard atoms induce a pending add-reduction over their
        // mesh axes, folded into any incoming partial's axes; a contracted
        // axis that is ALSO carried in as a pending sum has no sound
        // combination (it would double-count that axis) — bail
        let (partial, partial_axes) = if !missing_l.is_empty() {
            let contracted =
                axes_of(&self.store, &missing_l) | axes_of(&self.store, &missing_r);
            match partial_in {
                None => (Some(ReduceKind::Add), contracted),
                Some((ReduceKind::Add, in_axes)) if contracted & in_axes == 0 => {
                    (Some(ReduceKind::Add), contracted | in_axes)
                }
                Some(_) => return None,
            }
        } else {
            match partial_in {
                None => (None, 0),
                Some((k, m)) => (Some(k), m),
            }
        };
        // Canonicalize with FRESH atoms per output axis. Without this, the
        // two operands' atoms mix in one expression, and q·kᵀ-style dots
        // (both operands tracing to the same tensor) repeat an atom —
        // which breaks positional signatures and bijection inference. Each
        // output axis keeps only its shard *pattern*: fresh parent split
        // into alternating present/distributed segments.
        let (base_expr, dist_expr, shard_atoms) =
            self.canonicalize_axes(&base_axes, &dist_axes, &shard_atoms)?;

        Some(Fact {
            base: partner,
            dist: dclass,
            base_expr,
            dist_expr,
            shard_atoms,
            partial,
            partial_axes,
        })
    }

    /// Rebuild `(base, dist)` axis lists over fresh atoms, preserving the
    /// per-axis shard segmentation. Requires the dist axis to be the base
    /// axis minus shard atoms, in order (identity-modulo-shard per axis).
    fn canonicalize_axes(
        &mut self,
        base_axes: &[Vec<crate::layout::AtomId>],
        dist_axes: &[Vec<crate::layout::AtomId>],
        shard_atoms: &[crate::layout::AtomId],
    ) -> Option<(AxisExpr, AxisExpr, Vec<crate::layout::AtomId>)> {
        if base_axes.len() != dist_axes.len() {
            return None;
        }
        let mut new_base = Vec::with_capacity(base_axes.len());
        let mut new_dist = Vec::with_capacity(dist_axes.len());
        let mut new_shards = Vec::new();
        for (baxis, daxis) in base_axes.iter().zip(dist_axes) {
            let bleaves: Vec<_> = baxis
                .iter()
                .flat_map(|&a| self.store.expand(a))
                .filter(|&a| self.store.size(a) != 1)
                .collect();
            let dleaves: Vec<_> = daxis
                .iter()
                .flat_map(|&a| self.store.expand(a))
                .filter(|&a| self.store.size(a) != 1)
                .collect();
            let present: Vec<_> =
                bleaves.iter().copied().filter(|a| !shard_atoms.contains(a)).collect();
            if present != dleaves {
                return None; // per-axis reordering: keep original exprs? bail
            }
            // segment sizes, alternating (shard mesh-axis or None, size);
            // adjacent shard leaves merge only when they span the SAME
            // mesh axis — a dp·tp-mixed segment has no single digit to
            // re-derive, so multi-axis segments stay separate
            let mut segments: Vec<(Option<u8>, i64)> = Vec::new();
            for &a in &bleaves {
                let tag = if shard_atoms.contains(&a) {
                    Some(self.store.mesh_axis(a))
                } else {
                    None
                };
                let size = self.store.size(a);
                match segments.last_mut() {
                    Some((s, sz)) if *s == tag => *sz *= size,
                    _ => segments.push((tag, size)),
                }
            }
            let total: i64 = segments.iter().map(|(_, s)| *s).product::<i64>().max(1);
            let fresh = self.store.fresh(total);
            if segments.len() <= 1 {
                // wholly present or wholly distributed
                if let Some(Some(ax)) = segments.first().map(|(s, _)| *s) {
                    let _ = self.store.set_mesh_axis(fresh, ax); // fresh atom: always tags
                    new_base.push(vec![fresh]);
                    new_dist.push(vec![]);
                    new_shards.push(fresh);
                } else {
                    new_base.push(vec![fresh]);
                    new_dist.push(vec![fresh]);
                }
                continue;
            }
            let sizes: Vec<i64> = segments.iter().map(|(_, s)| *s).collect();
            let kids = self.store.split_leaf(fresh, &sizes)?;
            let mut daxis_new = Vec::new();
            for ((tag, _), kid) in segments.iter().zip(kids) {
                if let Some(ax) = tag {
                    let _ = self.store.set_mesh_axis(kid, *ax); // fresh parent: kids are fresh
                    new_shards.push(kid);
                } else {
                    daxis_new.push(kid);
                }
            }
            new_base.push(vec![fresh]);
            new_dist.push(daxis_new);
        }
        Some((AxisExpr::from_axes(new_base), AxisExpr::from_axes(new_dist), new_shards))
    }

    fn rule_slice(&mut self, eg: &mut EGraph, node: &Node, dclass: Id, xc: Id) -> bool {
        let Op::Slice { starts, limits, strides } = &node.op else { unreachable!() };
        if strides.iter().any(|&s| s != 1) {
            return false;
        }
        let mut derived = false;
        for f in self.facts_for(eg, xc) {
            if f.partial.is_some() {
                continue;
            }
            let sig = f.signature(&self.store);
            // only identity-modulo-shards layouts (axes in base order)
            let identity_mod_shard = {
                let mut ok = true;
                let mut prev = -1i64;
                for axis in &sig.axes {
                    for &(p, _) in axis {
                        if (p as i64) <= prev {
                            ok = false;
                        }
                        prev = p as i64;
                    }
                }
                ok
            };
            if !identity_mod_shard {
                continue;
            }
            // build the baseline slice attrs: same starts/limits except on
            // shard axes, where a full local range maps to full global range
            let base_dims = f.base_expr.dims(&self.store);
            let dist_dims = f.dist_expr.dims(&self.store);
            if f.base_expr.rank() != f.dist_expr.rank() {
                continue;
            }
            let mut bstarts = Vec::with_capacity(starts.len());
            let mut blimits = Vec::with_capacity(limits.len());
            let mut ok = true;
            let mut touched_shard = false;
            for i in 0..starts.len() {
                let local_full = starts[i] == 0 && limits[i] == dist_dims[i];
                if base_dims[i] != dist_dims[i] {
                    // shard axis: only full-range pass-through supported
                    if !local_full {
                        ok = false;
                        break;
                    }
                    touched_shard = true;
                    bstarts.push(0);
                    blimits.push(base_dims[i]);
                } else {
                    bstarts.push(starts[i]);
                    blimits.push(limits[i]);
                }
            }
            let _ = touched_shard;
            if !ok {
                continue;
            }
            let bop = Op::Slice {
                starts: bstarts,
                limits: blimits.clone(),
                strides: vec![1; blimits.len()],
            };
            let Some(partner) = lookup_base(eg, &ENode::new(bop, vec![f.base])) else { continue };
            // output exprs: untouched axes keep atoms; sliced axes get a
            // fresh shared atom
            let mut base_axes = Vec::new();
            let mut dist_axes = Vec::new();
            for i in 0..starts.len() {
                let full_local = starts[i] == 0 && limits[i] == dist_dims[i];
                if full_local {
                    base_axes.push(f.base_expr.axes[i].clone());
                    dist_axes.push(f.dist_expr.axes[i].clone());
                } else {
                    let fresh = self.store.fresh(limits[i] - starts[i]);
                    base_axes.push(vec![fresh]);
                    dist_axes.push(vec![fresh]);
                }
            }
            let nf = Fact {
                base: partner,
                dist: dclass,
                base_expr: AxisExpr::from_axes(base_axes),
                dist_expr: AxisExpr::from_axes(dist_axes),
                shard_atoms: f.shard_atoms.clone(),
                partial: None,
                partial_axes: 0,
            };
            derived |= self.add_fact(eg, nf);
        }
        // per-core slices
        for pc in self.percore_for(eg, xc) {
            let partners: Option<Vec<Id>> = pc
                .bases
                .iter()
                .map(|&b| lookup_base(eg, &ENode::new(node.op.clone(), vec![b])))
                .collect();
            if let Some(bases) = partners {
                derived |= self.add_percore(eg, PerCoreFact { dist: dclass, bases });
            }
        }
        derived
    }

    fn rule_concat(&mut self, eg: &mut EGraph, node: &Node, dclass: Id, ins: &[Id]) -> bool {
        let Op::Concat { dim } = node.op else { unreachable!() };
        let mut derived = false;
        // Case 1: all operands identity duplicates → duplicate concat.
        let dups: Option<Vec<Fact>> = ins
            .iter()
            .map(|&c| {
                self.facts_for(eg, c).into_iter().find(|f| f.is_duplicate(&self.store))
            })
            .collect();
        if let Some(facts) = dups {
            let children: Vec<Id> = facts.iter().map(|f| f.base).collect();
            if let Some(partner) = lookup_base(eg, &ENode::new(Op::Concat { dim }, children)) {
                let expr = AxisExpr::from_shape(&mut self.store, &node.shape.dims);
                derived |= self.add_fact(eg, Fact::duplicate(partner, dclass, expr));
            }
        }
        // Case 2: operands share all non-concat axes *atoms* (e.g. two
        // slices of the same head-sharded tensor, the rotate-half pattern)
        // — shard/partial structure carries through, concat axis gets a
        // fresh shared atom.
        'outer: {
            let facts: Option<Vec<Fact>> = ins
                .iter()
                .map(|&c| self.facts_for(eg, c).into_iter().next())
                .collect();
            let Some(facts) = facts else { break 'outer };
            let lead = &facts[0];
            if facts.iter().any(|f| {
                f.partial != lead.partial
                    || f.partial_axes != lead.partial_axes
                    || f.shard_atoms != lead.shard_atoms
                    || f.base_expr.rank() != lead.base_expr.rank()
                    || f.dist_expr.rank() != lead.dist_expr.rank()
            }) {
                break 'outer;
            }
            for f in &facts {
                for ax in 0..f.base_expr.rank() {
                    if ax == dim {
                        continue;
                    }
                    if f.base_expr.axes[ax] != lead.base_expr.axes[ax]
                        || f.dist_expr.axes[ax] != lead.dist_expr.axes[ax]
                    {
                        break 'outer;
                    }
                }
            }
            let children: Vec<Id> = facts.iter().map(|f| f.base).collect();
            let Some(partner) = lookup_base(eg, &ENode::new(Op::Concat { dim }, children))
            else {
                break 'outer;
            };
            let fresh = self.store.fresh(node.shape.dims[dim]);
            let mut base_axes = lead.base_expr.axes.clone();
            let mut dist_axes = lead.dist_expr.axes.clone();
            base_axes[dim] = vec![fresh];
            dist_axes[dim] = vec![fresh];
            let nf = Fact {
                base: partner,
                dist: dclass,
                base_expr: AxisExpr::from_axes(base_axes),
                dist_expr: AxisExpr::from_axes(dist_axes),
                shard_atoms: lead.shard_atoms.clone(),
                partial: lead.partial,
                partial_axes: lead.partial_axes,
            };
            derived |= self.add_fact(eg, nf);
        }
        derived
    }

    fn rule_broadcast(&mut self, eg: &mut EGraph, node: &Node, dclass: Id, xc: Id) -> bool {
        let Op::Broadcast { mapped, .. } = &node.op else { unreachable!() };
        let mut derived = false;
        for f in self.facts_for(eg, xc) {
            // allow duplicate / sharded inputs with aligned layout
            if f.partial.is_some() && f.partial != Some(ReduceKind::Add) {
                continue;
            }
            if f.base_expr.rank() != f.dist_expr.rank() {
                continue;
            }
            // The baseline broadcast targets the *baseline* extents: mapped
            // axes take the input fact's base dims (larger than the local
            // dims when the input is sharded there); unmapped axes are the
            // local extent or — for a shard-born axis — ×cores.
            let in_base_dims = f.base_expr.dims(&self.store);
            let mut proto = node.shape.dims.clone();
            for (i, &m) in mapped.iter().enumerate() {
                if i < in_base_dims.len() {
                    proto[m] = in_base_dims[i];
                }
            }
            let mut candidates = vec![proto.clone()];
            let mut axis_sizes: Vec<i64> =
                self.mesh.axes.iter().map(|&a| a as i64).filter(|&a| a > 1).collect();
            axis_sizes.sort_unstable();
            axis_sizes.dedup();
            for i in 0..node.shape.rank() {
                if !mapped.contains(&i) {
                    // a new axis may be born sharded over any single mesh
                    // axis (the whole mesh on flat graphs)
                    for &s in &axis_sizes {
                        let mut d = proto.clone();
                        d[i] *= s;
                        candidates.push(d);
                    }
                }
            }
            let partner = candidates.into_iter().find_map(|cand_dims| {
                lookup_base(
                    eg,
                    &ENode::new(
                        Op::Broadcast { mapped: mapped.clone(), dims: cand_dims },
                        vec![f.base],
                    ),
                )
            });
            let Some(partner) = partner else {
                continue;
            };
            // construct output exprs: mapped axes carry input factor lists,
            // new axes get fresh shared atoms (same size both sides only
            // when the axis is not sharded — broadcast result dims match
            // per-core, so fresh shared atoms are correct for new axes)
            let rank = node.shape.rank();
            let bnode_shape = eg.class(partner).data.shape.clone();
            let mut base_axes: Vec<Vec<crate::layout::AtomId>> = vec![Vec::new(); rank];
            let mut dist_axes: Vec<Vec<crate::layout::AtomId>> = vec![Vec::new(); rank];
            let mut filled = vec![false; rank];
            for (i, &m) in mapped.iter().enumerate() {
                base_axes[m] = f.base_expr.axes[i].clone();
                dist_axes[m] = f.dist_expr.axes[i].clone();
                filled[m] = true;
            }
            // Born-sharded dims may span ANY mesh axis of the right size —
            // a broadcast-born axis is constant along itself, so every
            // choice is sound. Emit one fact per axis assignment: when two
            // mesh axes share a size (dp2·tp2) the consumer's signature
            // match picks the fact whose tag lines up.
            let mut choices: Vec<(usize, Vec<u8>)> = Vec::new(); // (dim, axis options; empty = fresh shared)
            let mut ok = true;
            for i in 0..rank {
                if filled[i] {
                    continue;
                }
                let dist_size = node.shape.dims[i];
                let base_size =
                    bnode_shape.as_ref().map(|s| s.dims[i]).unwrap_or(dist_size);
                if base_size == dist_size {
                    choices.push((i, Vec::new()));
                } else if dist_size > 0 && base_size % dist_size == 0 {
                    let ratio = base_size / dist_size;
                    let options: Vec<u8> = self
                        .mesh
                        .axes
                        .iter()
                        .enumerate()
                        .filter(|&(_, &a)| a as i64 == ratio)
                        .map(|(k, _)| k as u8)
                        .collect();
                    if options.is_empty() {
                        ok = false;
                        break;
                    }
                    choices.push((i, options));
                } else {
                    ok = false;
                    break;
                }
            }
            if !ok {
                continue;
            }
            // cartesian product over the (tiny) per-dim axis options
            let mut assignments: Vec<Vec<(usize, Option<u8>)>> = vec![Vec::new()];
            for (i, options) in &choices {
                let mut next = Vec::new();
                for asg in &assignments {
                    if options.is_empty() {
                        let mut a = asg.clone();
                        a.push((*i, None));
                        next.push(a);
                    } else {
                        for &k in options {
                            let mut a = asg.clone();
                            a.push((*i, Some(k)));
                            next.push(a);
                        }
                    }
                }
                assignments = next;
                if assignments.len() > 16 {
                    assignments.truncate(16); // combinatorial backstop
                }
            }
            for asg in assignments {
                let mut base_axes = base_axes.clone();
                let mut dist_axes = dist_axes.clone();
                let mut shard_atoms = f.shard_atoms.clone();
                for &(i, axis) in &asg {
                    let dist_size = node.shape.dims[i];
                    match axis {
                        None => {
                            let fresh = self.store.fresh(dist_size);
                            base_axes[i] = vec![fresh];
                            dist_axes[i] = vec![fresh];
                        }
                        Some(k) => {
                            let ratio = self.mesh.axes[k as usize] as i64;
                            let fresh = self.store.fresh(ratio * dist_size);
                            let kids = self
                                .store
                                .split_leaf(fresh, &[ratio, dist_size])
                                .expect("fresh atom split");
                            let _ = self.store.set_mesh_axis(kids[0], k); // fresh atom
                            base_axes[i] = vec![fresh];
                            dist_axes[i] = vec![kids[1]];
                            shard_atoms.push(kids[0]);
                        }
                    }
                }
                let nf = Fact {
                    base: partner,
                    dist: dclass,
                    base_expr: AxisExpr::from_axes(base_axes),
                    dist_expr: AxisExpr::from_axes(dist_axes),
                    shard_atoms,
                    partial: f.partial,
                    partial_axes: f.partial_axes,
                };
                derived |= self.add_fact(eg, nf);
            }
        }
        derived
    }

    fn rule_reduce(&mut self, eg: &mut EGraph, node: &Node, dclass: Id, xc: Id) -> bool {
        let Op::Reduce { kind, dims } = &node.op else { unreachable!() };
        let mut derived = false;
        for f in self.facts_for(eg, xc) {
            // partial-through-reduce: Σ then Σ fine; max then max fine
            let partial_ok = match f.partial {
                None => true,
                Some(k) => k == *kind && matches!(k, ReduceKind::Add | ReduceKind::Max | ReduceKind::Min),
            };
            if !partial_ok || f.base_expr.rank() != f.dist_expr.rank() {
                continue;
            }
            // require axis-aligned layout (identity modulo shards): every
            // distributed leaf must live in the corresponding base axis
            let base_exp = f.base_expr.expanded(&self.store);
            let dist_exp = f.dist_expr.expanded(&self.store);
            let aligned = base_exp
                .axes
                .iter()
                .zip(&dist_exp.axes)
                .all(|(b, d)| d.iter().all(|a| b.contains(a)));
            if !aligned {
                continue;
            }
            let Some(partner) = lookup_base(eg, &ENode::new(
                Op::Reduce { kind: *kind, dims: dims.clone() },
                vec![f.base],
            )) else {
                continue;
            };
            // reduced shard atoms become a pending cross-core reduction
            // over their mesh axes (joined with any incoming pending axes)
            let reduced_shards: Vec<_> = dims
                .iter()
                .flat_map(|&d| base_exp.axes[d].clone())
                .filter(|a| f.shard_atoms.contains(a))
                .collect();
            let (partial, partial_axes) = if reduced_shards.is_empty() {
                (f.partial, f.partial_axes)
            } else {
                let reduced_axes = axes_of(&self.store, &reduced_shards);
                match f.partial {
                    None => (Some(*kind), reduced_axes),
                    Some(k) if k == *kind => (Some(k), f.partial_axes | reduced_axes),
                    _ => continue,
                }
            };
            let keep =
                |e: &AxisExpr| -> Vec<Vec<crate::layout::AtomId>> {
                    e.axes
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| !dims.contains(i))
                        .map(|(_, a)| a.clone())
                        .collect()
                };
            let shard_atoms: Vec<_> = f
                .shard_atoms
                .iter()
                .copied()
                .filter(|a| !reduced_shards.contains(a))
                .collect();
            let nf = Fact {
                base: partner,
                dist: dclass,
                base_expr: AxisExpr::from_axes(keep(&f.base_expr)),
                dist_expr: AxisExpr::from_axes(keep(&f.dist_expr)),
                shard_atoms,
                partial,
                partial_axes,
            };
            derived |= self.add_fact(eg, nf);
        }
        derived
    }

    fn rule_all_reduce(
        &mut self,
        eg: &mut EGraph,
        node: &Node,
        dclass: Id,
        xc: Id,
        kind: ReduceKind,
        groups: &ReplicaGroups,
    ) -> bool {
        let full_mesh = groups.0.len() == 1 && groups.0[0].len() == self.cores as usize;
        // which mesh-axis subset do these groups reduce over? (None for
        // groups matching no subset — the wrong-replica-group bug family)
        let group_axes = self.groups_axes(groups);
        let mut derived = false;
        for f in self.facts_for(eg, xc) {
            match f.partial {
                Some(k)
                    if k == kind
                        && group_axes
                            .is_some_and(|ga| self.same_axes(ga, f.partial_axes)) =>
                {
                    // collective discharge (Table 1): a pending reduction
                    // over axes S resolves iff the groups are exactly the
                    // cores varying on S — a subgroup all-reduce over the
                    // tp axis discharges a tp-partial and nothing else.
                    // Within each group the reduce spans every pending
                    // digit once, and cores in different groups hold the
                    // same discharged value afterwards.
                    let nf =
                        Fact { dist: dclass, partial: None, partial_axes: 0, ..f.clone() };
                    derived |= self.add_fact(eg, nf);
                }
                None if matches!(kind, ReduceKind::Max | ReduceKind::Min)
                    && f.shard_atoms.is_empty()
                    && group_axes.is_some() =>
                {
                    // max/min over identical replicas is a no-op (any
                    // axis-shaped groups: replicas agree everywhere)
                    let nf = Fact { dist: dclass, ..f.clone() };
                    derived |= self.add_fact(eg, nf);
                }
                _ => {
                    // add-all-reduce over duplicates (redundant all-reduce
                    // bug) or wrong groups: no rule fires
                }
            }
        }
        // unroll discharge (loop_red rules): per-core facts sum to the
        // baseline's unrolled reduction tree
        if kind == ReduceKind::Add && full_mesh {
            for pc in self.percore_for(eg, xc) {
                if let Some(total) = self.fold_baseline_sum(eg, &pc.bases) {
                    let expr = AxisExpr::from_shape(&mut self.store, &node.shape.dims);
                    derived |= self.add_fact(eg, Fact::duplicate(total, dclass, expr));
                }
            }
        }
        derived
    }

    /// Find the baseline class equal to `bases[0] + bases[1] + …` by
    /// folding lookups through the e-graph (commutativity is already in
    /// the e-graph, so either operand order matches).
    fn fold_baseline_sum(&self, eg: &EGraph, bases: &[Id]) -> Option<Id> {
        let mut acc = *bases.first()?;
        for &b in &bases[1..] {
            acc = lookup_base(eg, &ENode::new(Op::Add, vec![acc, b]))?;
        }
        Some(acc)
    }

    fn rule_all_gather(
        &mut self,
        eg: &mut EGraph,
        _node: &Node,
        dclass: Id,
        xc: Id,
        dim: usize,
        groups: &ReplicaGroups,
    ) -> bool {
        // all-gather concatenates in group-member order, so the raw
        // listing must be the canonical ascending form of some axis subset
        // (ascending member order = ascending digit order along the axis)
        let Some(group_axes) = self.groups_axes(groups) else { return false };
        if *groups != self.mesh.groups_for(group_axes) {
            return false;
        }
        let mut derived = false;
        for f in self.facts_for(eg, xc) {
            // a pending reduction over the gathered axes would interleave
            // un-summed contributions into the concat — no sound fact
            if f.partial.is_some()
                && self.mesh.normalize_mask(f.partial_axes) & group_axes != 0
            {
                continue;
            }
            // exactly one shard atom on the gathered axes; shards on other
            // mesh axes ride through untouched (a dp-sharded activation
            // keeps its dp shard while its tp shard is gathered)
            let (on_axis, off_axis): (Vec<_>, Vec<_>) = f
                .shard_atoms
                .iter()
                .copied()
                .partition(|&a| {
                    self.mesh
                        .normalize_mask(1 << self.store.mesh_axis(a))
                        == group_axes
                });
            if on_axis.len() != 1 {
                continue;
            }
            let s = on_axis[0];
            // gathered axis becomes [s ∥ old factors]
            let mut dist_axes = f.dist_expr.axes.clone();
            let mut new_axis = vec![s];
            new_axis.extend(dist_axes[dim].iter().copied());
            dist_axes[dim] = new_axis;
            let nf = Fact {
                base: f.base,
                dist: dclass,
                base_expr: f.base_expr.clone(),
                dist_expr: AxisExpr::from_axes(dist_axes),
                shard_atoms: off_axis,
                partial: f.partial,
                partial_axes: f.partial_axes,
            };
            derived |= self.add_fact(eg, nf);
        }
        derived
    }

    #[allow(clippy::too_many_arguments)]
    fn rule_reduce_scatter(
        &mut self,
        eg: &mut EGraph,
        _node: &Node,
        dclass: Id,
        xc: Id,
        kind: ReduceKind,
        dim: usize,
        groups: &ReplicaGroups,
    ) -> bool {
        // scatter order is group-member order: require the canonical
        // listing of a single mesh axis (the common subgroup shape; a
        // multi-axis scatter has no single digit to index the shards by)
        let Some(group_axes) = self.groups_axes(groups) else { return false };
        if *groups != self.mesh.groups_for(group_axes) {
            return false;
        }
        let scatter_axis = match (0..self.mesh.rank())
            .filter(|&k| group_axes & (1 << k) != 0)
            .collect::<Vec<_>>()
            .as_slice()
        {
            [k] => *k,
            _ => return false,
        };
        let c = self.mesh.size(scatter_axis) as i64;
        let mut derived = false;
        for f in self.facts_for(eg, xc) {
            // discharges a pending `kind`-reduction spanning exactly the
            // group axis (reduce within the group, then each member keeps
            // its digit's slice)
            if f.partial != Some(kind)
                || !self.same_axes(f.partial_axes, group_axes)
            {
                continue;
            }
            // scatter dim: split its leading factor into [axis size, rest]
            let axis = f.dist_expr.axes[dim].clone();
            let Some(&lead) = axis.first() else { continue };
            let lead_size = self.store.size(lead);
            if lead_size % c != 0 {
                continue;
            }
            // expand lead to leaves and split the first leaf
            let leaves = self.store.expand(lead);
            let first = leaves[0];
            if self.store.size(first) % c != 0 {
                continue;
            }
            let kids = match self.store.split_leaf(first, &[c, self.store.size(first) / c]) {
                Some(k) => k,
                None => {
                    // already split compatibly: re-derive via take_product
                    let mut q: std::collections::VecDeque<_> =
                        leaves.iter().copied().collect();
                    match self.store.take_product(&mut q, c) {
                        Some(taken) if taken.len() == 1 => {
                            let shard = taken[0];
                            if !self.store.set_mesh_axis(shard, scatter_axis as u8) {
                                // hash-consed atom already spans another
                                // axis: no sound derivation here
                                continue;
                            }
                            let mut rest: Vec<_> = q.into_iter().collect();
                            rest.extend(axis.iter().skip(leaves.len()).copied());
                            let mut dist_axes = f.dist_expr.axes.clone();
                            dist_axes[dim] = rest;
                            let mut shard_atoms = f.shard_atoms.clone();
                            shard_atoms.push(shard);
                            let nf = Fact {
                                base: f.base,
                                dist: dclass,
                                base_expr: f.base_expr.clone(),
                                dist_expr: AxisExpr::from_axes(dist_axes),
                                shard_atoms,
                                partial: None,
                                partial_axes: 0,
                            };
                            derived |= self.add_fact(eg, nf);
                        }
                        _ => {}
                    }
                    continue;
                }
            };
            if !self.store.set_mesh_axis(kids[0], scatter_axis as u8) {
                continue; // shared split child already spans another axis
            }
            let mut new_axis = vec![kids[1]];
            new_axis.extend(leaves[1..].iter().copied());
            new_axis.extend(axis.iter().skip(1).copied());
            let mut dist_axes = f.dist_expr.axes.clone();
            dist_axes[dim] = new_axis;
            let mut shard_atoms = f.shard_atoms.clone();
            shard_atoms.push(kids[0]);
            let nf = Fact {
                base: f.base,
                dist: dclass,
                base_expr: f.base_expr.clone(),
                dist_expr: AxisExpr::from_axes(dist_axes),
                shard_atoms,
                partial: None,
                partial_axes: 0,
            };
            derived |= self.add_fact(eg, nf);
        }
        derived
    }

    #[allow(clippy::too_many_arguments)]
    fn rule_all_to_all(
        &mut self,
        eg: &mut EGraph,
        _node: &Node,
        dclass: Id,
        xc: Id,
        split_dim: usize,
        concat_dim: usize,
        groups: &ReplicaGroups,
    ) -> bool {
        // order-sensitive (peer rank = chunk index): canonical listing of
        // a single mesh axis required
        let Some(group_axes) = self.groups_axes(groups) else { return false };
        if *groups != self.mesh.groups_for(group_axes) {
            return false;
        }
        let a2a_axis = match (0..self.mesh.rank())
            .filter(|&k| group_axes & (1 << k) != 0)
            .collect::<Vec<_>>()
            .as_slice()
        {
            [k] => *k,
            _ => return false,
        };
        let c = self.mesh.size(a2a_axis) as i64;
        let mut derived = false;
        for f in self.facts_for(eg, xc) {
            if f.shard_atoms.len() != 1 || f.partial.is_some() {
                continue;
            }
            let s = f.shard_atoms[0];
            // the exchanged shard must live on the group axis
            if !self
                .mesh
                .normalize_mask(1 << self.store.mesh_axis(s))
                .eq(&group_axes)
            {
                continue;
            }
            // split the leading factor of split_dim
            let axis = f.dist_expr.axes[split_dim].clone();
            let leaves: Vec<_> = axis.iter().flat_map(|&a| self.store.expand(a)).collect();
            let Some(&first) = leaves.first() else { continue };
            if self.store.size(first) % c != 0 {
                continue;
            }
            let kids = match self.store.split_leaf(first, &[c, self.store.size(first) / c]) {
                Some(k) => k,
                None => continue,
            };
            if !self.store.set_mesh_axis(kids[0], a2a_axis as u8) {
                continue; // shared split child already spans another axis
            }
            let mut split_axis = vec![kids[1]];
            split_axis.extend(leaves[1..].iter().copied());
            let mut dist_axes = f.dist_expr.axes.clone();
            dist_axes[split_dim] = split_axis;
            // shard atom s returns as leading factor of concat_dim
            let mut cat_axis = vec![s];
            cat_axis.extend(dist_axes[concat_dim].iter().copied());
            dist_axes[concat_dim] = cat_axis;
            let nf = Fact {
                base: f.base,
                dist: dclass,
                base_expr: f.base_expr.clone(),
                dist_expr: AxisExpr::from_axes(dist_axes),
                shard_atoms: vec![kids[0]],
                partial: None,
                partial_axes: 0,
            };
            derived |= self.add_fact(eg, nf);
        }
        derived
    }

    /// Pipeline boundary hop (`send` / `recv`): the value is relocated to
    /// another stage, not transformed, so every relation of the operand
    /// carries through unchanged (identity semantics — the soundness
    /// argument is that a send/recv pair denotes the identity function on
    /// its tensor).
    fn rule_boundary_hop(&mut self, eg: &mut EGraph, dclass: Id, xc: Id) -> bool {
        let mut derived = false;
        for f in self.facts_for(eg, xc) {
            derived |= self.add_fact(eg, Fact { dist: dclass, ..f });
        }
        for pc in self.percore_for(eg, xc) {
            derived |= self.add_percore(eg, PerCoreFact { dist: dclass, bases: pc.bases });
        }
        derived
    }

    /// Derive per-core slice relations from a sharded fact when the
    /// baseline graph contains the explicit per-core slice nodes
    /// (fine-grained slicing, Figure 8).
    fn try_derive_percore(&mut self, eg: &mut EGraph, dclass: Id) -> bool {
        let mut derived = false;
        for f in self.facts_for(eg, dclass) {
            if f.shard_atoms.len() != 1 || f.partial.is_some() {
                continue;
            }
            let s = f.shard_atoms[0];
            // identity layout apart from the shard
            if f.base_expr.rank() != f.dist_expr.rank() {
                continue;
            }
            // shard axis: the base axis whose expansion starts with s
            let mut shard_axis = None;
            for (i, axis) in f.base_expr.expanded(&self.store).axes.iter().enumerate() {
                if axis.first() == Some(&s) {
                    shard_axis = Some(i);
                }
            }
            let Some(dim) = shard_axis else { continue };
            let base_dims = f.base_expr.dims(&self.store);
            // slice index on core r = r's digit along the shard atom's
            // mesh axis (the raw core id on flat meshes)
            let mesh_axis = self.store.mesh_axis(s) as usize;
            if mesh_axis >= self.mesh.rank()
                || self.mesh.size(mesh_axis) as i64 != self.store.size(s)
            {
                continue;
            }
            let local = base_dims[dim] / self.store.size(s);
            let rank = base_dims.len();
            let mut bases = Vec::with_capacity(self.cores as usize);
            let mut ok = true;
            for r in 0..self.cores {
                let d = self.mesh.digit(r, mesh_axis) as i64;
                let mut starts = vec![0i64; rank];
                let mut limits = base_dims.clone();
                starts[dim] = d * local;
                limits[dim] = (d + 1) * local;
                let op = Op::Slice { starts, limits, strides: vec![1; rank] };
                match lookup_base(eg, &ENode::new(op, vec![f.base])) {
                    Some(id) => bases.push(id),
                    None => {
                        ok = false;
                        break;
                    }
                }
            }
            if ok {
                derived |= self.add_percore(eg, PerCoreFact { dist: dclass, bases });
            }
        }
        derived
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DType, GraphBuilder, Shape};

    /// Two tiny structurally-identical graphs registered into one e-graph,
    /// the way `verify_layer` does it, plus the node→class maps.
    fn tiny_ctx_parts() -> (Graph, Graph, EGraph, Vec<Id>, Vec<Id>) {
        let build = |side: &str| {
            let mut b = GraphBuilder::new(format!("{side}-g"), 1);
            let x = b.parameter(&format!("{side}::x"), Shape::new(DType::F32, vec![4]));
            let y = b.parameter(&format!("{side}::y"), Shape::new(DType::F32, vec![4]));
            let z = b.add(x, y);
            b.output(z);
            b.finish()
        };
        let base = build("B");
        let dist = build("D");
        let mut eg = EGraph::new();
        let mut reg = |g: &Graph| -> Vec<Id> {
            let mut map: Vec<Id> = Vec::with_capacity(g.len());
            for n in &g.nodes {
                let children: Vec<Id> = n.inputs.iter().map(|i| map[i.idx()]).collect();
                map.push(eg.add(ENode::new(n.op.clone(), children)));
            }
            map
        };
        let b2c = reg(&base);
        let d2c = reg(&dist);
        (base, dist, eg, b2c, d2c)
    }

    /// Regression: the lazily-built class→baseline-node index must be
    /// correct on the very first (cold) query, whichever class that query
    /// asks for — including a class with no baseline members at all. The
    /// original implementation initialized the cache and then re-read it
    /// through `as_ref().unwrap()`; a refactor that returned before the
    /// write (or a poisoned first query) would panic or answer from an
    /// empty index.
    #[test]
    fn class_index_is_correct_on_a_cold_first_query() {
        let (base, dist, eg, b2c, d2c) = tiny_ctx_parts();
        let base_uses = base.uses();
        let ctx = GraphCtx {
            base: &base,
            dist: &dist,
            b2c: &b2c,
            d2c: &d2c,
            base_uses: &base_uses,
            class_index: std::cell::RefCell::new(None),
        };
        // cold first query: a distributed-only class — no baseline nodes
        // canonicalize there, so the answer is empty (and must not panic)
        assert!(ctx.base_nodes_of(&eg, d2c[0]).is_empty());
        // the same cache now serves the populated classes
        for n in &base.nodes {
            let hits = ctx.base_nodes_of(&eg, b2c[n.id.idx()]);
            assert!(hits.contains(&n.id), "node {:?} missing from its own class", n.id);
        }
    }

    /// The cold query order must not change answers: querying a populated
    /// class first and an empty one second gives the same results as the
    /// reverse order on a fresh context.
    #[test]
    fn class_index_answers_are_query_order_independent() {
        let (base, dist, eg, b2c, d2c) = tiny_ctx_parts();
        let base_uses = base.uses();
        let fresh = || GraphCtx {
            base: &base,
            dist: &dist,
            b2c: &b2c,
            d2c: &d2c,
            base_uses: &base_uses,
            class_index: std::cell::RefCell::new(None),
        };
        let a = fresh();
        let first_then_empty =
            (a.base_nodes_of(&eg, b2c[2]), a.base_nodes_of(&eg, d2c[2]));
        let b = fresh();
        let empty_then_first =
            (b.base_nodes_of(&eg, d2c[2]), b.base_nodes_of(&eg, b2c[2]));
        assert_eq!(first_then_empty.0, empty_then_first.1);
        assert_eq!(first_then_empty.1, empty_then_first.0);
        assert!(first_then_empty.0.contains(&base.nodes[2].id));
    }
}
