//! Relation facts between baseline and distributed e-classes.

use crate::egraph::Id;
use crate::ir::{AxesMask, ReduceKind};
use crate::layout::{AtomId, AtomStore, AxisExpr};

/// A relation between baseline class `base` and distributed class `dist`.
///
/// Semantics (per core `r` of the mesh):
///
/// ```text
/// restore(d_r) := inverse-layout of d_r placed into the baseline frame,
///                 with each shard atom filled at r's digit along the
///                 atom's mesh axis
/// partial == None  =>  for all r:  restore(d_r) == slice_r(base)
/// partial == Some(op) => op-reducing restore(d_r) over each group of
///                        cores that agree on every axis OUTSIDE
///                        partial_axes yields base — i.e. the pending
///                        reduction spans exactly the masked axes
/// ```
///
/// * `shard_atoms.is_empty() && partial.is_none() && identity layout`
///   ⇒ the paper's `duplicate(x, x', c)`.
/// * `shard_atoms == [s]` ⇒ `sharded(x, x', dim-of-s, c)`.
/// * `partial == Some(Add)` ⇒ `partial(x, x', c, add)`.
/// * non-identity layout ⇒ `layout(x, x', ℓ, c)` (combined with the above).
///
/// On a flat 1-axis mesh `partial_axes` is always `1` and every shard
/// atom's axis is `0` — the pre-mesh semantics exactly.
#[derive(Clone, Debug)]
pub struct Fact {
    /// Baseline e-class.
    pub base: Id,
    /// Distributed e-class.
    pub dist: Id,
    /// Baseline tensor's symbolic axes.
    pub base_expr: AxisExpr,
    /// Distributed (per-core local) tensor's symbolic axes, over the same
    /// atoms — minus the shard atoms.
    pub dist_expr: AxisExpr,
    /// Atoms of `base_expr` that are distributed across the core mesh
    /// (absent from `dist_expr`). Each atom's mesh axis lives in the
    /// [`AtomStore`] (`mesh_axis`).
    pub shard_atoms: Vec<AtomId>,
    /// Pending cross-core reduction.
    pub partial: Option<ReduceKind>,
    /// Mesh axes the pending reduction spans (meaningful only when
    /// `partial.is_some()`; `0` otherwise).
    pub partial_axes: AxesMask,
}

impl Fact {
    /// `duplicate` fact with identity layout.
    pub fn duplicate(base: Id, dist: Id, expr: AxisExpr) -> Fact {
        Fact {
            base,
            dist,
            base_expr: expr.clone(),
            dist_expr: expr,
            shard_atoms: vec![],
            partial: None,
            partial_axes: 0,
        }
    }

    /// True when this fact proves element-for-element equality: no shard
    /// atoms, no pending reduction, and the layout is the identity.
    pub fn is_duplicate(&self, store: &AtomStore) -> bool {
        self.shard_atoms.is_empty()
            && self.partial.is_none()
            && self.base_expr.structurally_equal(&self.dist_expr, store)
    }

    /// True when it proves equality *up to a bijective layout*.
    pub fn is_layout_duplicate(&self, store: &AtomStore) -> bool {
        self.shard_atoms.is_empty()
            && self.partial.is_none()
            && crate::layout::infer_bijection(store, &self.base_expr, &self.dist_expr).is_some()
    }

    /// Positional signature of the distributed layout relative to the
    /// baseline layout. Two facts over *different* atom sets are
    /// layout-compatible for an elementwise op iff their signatures match.
    pub fn signature(&self, store: &AtomStore) -> Signature {
        let base_flat = self.base_expr.flat_leaves(store);
        let pos = |a: AtomId| -> Option<(u32, i64)> {
            base_flat
                .iter()
                .position(|&b| b == a)
                .map(|p| (p as u32, store.size(a)))
        };
        let dist_expanded = self.dist_expr.expanded(store);
        let axes: Vec<Vec<(u32, i64)>> = dist_expanded
            .axes
            .iter()
            .map(|axis| {
                axis.iter()
                    .filter(|&&a| store.size(a) != 1)
                    .map(|&a| pos(a).unwrap_or((u32::MAX, store.size(a))))
                    .collect()
            })
            .collect();
        // the mesh axis is part of the positional encoding: a dp-shard and
        // a tp-shard of equal size at the same position are NOT compatible
        // (their per-core slice indices follow different mesh digits)
        let shard_pos: Vec<(u32, i64, u8)> = self
            .shard_atoms
            .iter()
            .map(|&a| {
                let (p, s) = pos(a).unwrap_or((u32::MAX, store.size(a)));
                (p, s, store.mesh_axis(a))
            })
            .collect();
        Signature { axes, shard_pos, partial: self.partial, partial_axes: self.partial_axes }
    }

    /// Dedup key (canonical class ids + signature).
    pub fn key(&self, store: &AtomStore) -> FactKey {
        FactKey { base: self.base, dist: self.dist, sig: self.signature(store) }
    }
}

/// Layout signature: positional encoding of the distributed axes relative
/// to the baseline's flat leaf order.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Signature {
    /// Per distributed axis: (position in base flat, size) of each factor.
    pub axes: Vec<Vec<(u32, i64)>>,
    /// Positions of the shard atoms: (position in base flat, size, mesh
    /// axis).
    pub shard_pos: Vec<(u32, i64, u8)>,
    /// Pending reduction.
    pub partial: Option<ReduceKind>,
    /// Mesh axes the pending reduction spans.
    pub partial_axes: AxesMask,
}

impl Signature {
    /// Identity signature check: axes enumerate base positions in order
    /// with no shards or partials.
    pub fn is_identity(&self) -> bool {
        if !self.shard_pos.is_empty() || self.partial.is_some() {
            return false;
        }
        let mut expect = 0u32;
        for axis in &self.axes {
            for &(p, _) in axis {
                if p != expect {
                    return false;
                }
                expect += 1;
            }
        }
        true
    }
}

/// Dedup key for facts.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct FactKey {
    /// Baseline class.
    pub base: Id,
    /// Distributed class.
    pub dist: Id,
    /// Layout signature.
    pub sig: Signature,
}

/// Fine-grained per-core relation (paper's slicing/unroll analyses):
/// the distributed class's value **on core r** equals baseline class
/// `bases[r]` (identity layout). One distributed tensor, `c` different
/// baseline partners.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PerCoreFact {
    /// Distributed e-class.
    pub dist: Id,
    /// Baseline e-class per core.
    pub bases: Vec<Id>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::AtomStore;

    #[test]
    fn duplicate_fact_properties() {
        let mut store = AtomStore::new();
        let e = AxisExpr::from_shape(&mut store, &[4, 8]);
        let f = Fact::duplicate(Id(0), Id(1), e);
        assert!(f.is_duplicate(&store));
        assert!(f.signature(&store).is_identity());
    }

    #[test]
    fn sharded_fact_signature() {
        let mut store = AtomStore::new();
        let base = AxisExpr::from_shape(&mut store, &[8, 16]);
        // shard dim 1 across 4 cores: split atom -> [shard, local]
        let atom1 = base.axes[1][0];
        let kids = store.split_leaf(atom1, &[4, 4]).unwrap();
        let dist = AxisExpr::from_axes(vec![base.axes[0].clone(), vec![kids[1]]]);
        let f = Fact {
            base: Id(0),
            dist: Id(1),
            base_expr: base,
            dist_expr: dist,
            shard_atoms: vec![kids[0]],
            partial: None,
            partial_axes: 0,
        };
        assert!(!f.is_duplicate(&store));
        let sig = f.signature(&store);
        assert!(!sig.is_identity());
        assert_eq!(sig.shard_pos, vec![(1, 4, 0)]);
    }

    #[test]
    fn transposed_fact_is_layout_duplicate_not_duplicate() {
        let mut store = AtomStore::new();
        let base = AxisExpr::from_shape(&mut store, &[4, 8]);
        let dist = base.transpose(&[1, 0]).unwrap();
        let f = Fact {
            base: Id(0),
            dist: Id(1),
            base_expr: base,
            dist_expr: dist,
            shard_atoms: vec![],
            partial: None,
            partial_axes: 0,
        };
        assert!(!f.is_duplicate(&store));
        assert!(f.is_layout_duplicate(&store));
    }

    #[test]
    fn signatures_compare_across_atom_sets() {
        // two different tensors, both transposed the same way → equal sigs
        let mut store = AtomStore::new();
        let bx = AxisExpr::from_shape(&mut store, &[4, 8]);
        let by = AxisExpr::from_shape(&mut store, &[4, 8]);
        let fx = Fact {
            base: Id(0),
            dist: Id(1),
            base_expr: bx.clone(),
            dist_expr: bx.transpose(&[1, 0]).unwrap(),
            shard_atoms: vec![],
            partial: None,
            partial_axes: 0,
        };
        let fy = Fact {
            base: Id(2),
            dist: Id(3),
            base_expr: by.clone(),
            dist_expr: by.transpose(&[1, 0]).unwrap(),
            shard_atoms: vec![],
            partial: None,
            partial_axes: 0,
        };
        assert_eq!(fx.signature(&store), fy.signature(&store));
        // and a differently-transposed one differs
        let fz = Fact {
            base: Id(4),
            dist: Id(5),
            base_expr: by.clone(),
            dist_expr: by,
            shard_atoms: vec![],
            partial: None,
            partial_axes: 0,
        };
        assert_ne!(fx.signature(&store), fz.signature(&store));
    }
}
