//! Datalog-style relational analysis (paper §5.2, Table 1).
//!
//! The e-graph alone proves equality of *structurally rewritable* terms.
//! Distribution needs more: a distributed tensor is not equal to its
//! baseline counterpart — it is a **shard** of it, a **partial** result
//! whose cross-core reduction equals it, or a **relayouted bijection** of
//! it. This module maintains those relations as facts over e-class pairs
//! and propagates them through operators with the paper's rule families:
//!
//! * **Partition** — `sharded` / `duplicate` propagation through
//!   elementwise ops, dot, broadcast, reduce and the collectives;
//! * **Layout** — symbolic [`crate::layout::AxisExpr`] pairs tracked
//!   through reshape/transpose on either graph, aligned via bijection
//!   inference when the two paths diverge structurally;
//! * **Slicing** — fine-grained per-core slice relations
//!   ([`PerCoreFact`]) relating one distributed tensor to *different*
//!   baseline nodes on different cores;
//! * **Unroll** — discharge of per-core relations against the baseline's
//!   unrolled reduction tree (`loop_red_B`/`loop_red_D` of the paper).
//!
//! Facts are only ever derived by sound rules, so a final
//! `duplicate`-with-identity-layout fact on the output pair is a proof of
//! semantic equivalence (§5.1 soundness).

mod facts;
mod engine;

pub use engine::{GraphCtx, RelEngine, StepOutcome};
pub use facts::{Fact, FactKey, PerCoreFact, Signature};
