//! Thread-safe span tracer with Chrome trace-event export.
//!
//! Spans are RAII: [`span`] returns a guard that records a
//! [`SpanRecord`] when dropped. Guards nest naturally per thread —
//! inner guards drop first — so the emitted intervals are properly
//! nested and never partially overlap within one thread, which is
//! exactly what Perfetto's track view assumes.
//!
//! Disabled path: one relaxed atomic load, no allocation, no lock. The
//! verify pipeline leaves its instrumentation in place permanently;
//! only `--trace` (or a test) flips the flag.

use crate::report::json::Json;
use std::io;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

static ENABLED: AtomicBool = AtomicBool::new(false);
static BUFFER: Mutex<Vec<SpanRecord>> = Mutex::new(Vec::new());
static THREADS: Mutex<Vec<(u64, String)>> = Mutex::new(Vec::new());

/// Tracer-local thread ids: small dense integers assigned on first use,
/// stable for the thread's lifetime (std's `ThreadId` has no stable
/// numeric accessor). Worker threads keep their id across verify runs.
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    static NAMED: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

fn tid() -> u64 {
    TID.with(|t| *t)
}

/// Register the current thread's name once, for the trace's
/// `thread_name` metadata events.
fn register_thread() {
    NAMED.with(|named| {
        if named.get() {
            return;
        }
        named.set(true);
        let name = std::thread::current()
            .name()
            .map(|n| n.to_string())
            .unwrap_or_else(|| format!("thread-{}", tid()));
        THREADS.lock().expect("trace thread lock").push((tid(), name));
    });
}

/// Is span recording on? One relaxed load — callers may use this to skip
/// building expensive attributes.
#[inline]
pub fn trace_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Clear the buffer and start recording spans.
pub fn start_tracing() {
    BUFFER.lock().expect("trace buffer lock").clear();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Stop recording and drain the captured spans.
pub fn stop_tracing() -> Vec<SpanRecord> {
    ENABLED.store(false, Ordering::SeqCst);
    std::mem::take(&mut *BUFFER.lock().expect("trace buffer lock"))
}

/// One finished span: a named interval on one thread, with counted
/// attributes (`layer`, `rule`, `matches_tried`, `reused`, …).
#[derive(Clone, Debug)]
pub struct SpanRecord {
    /// Display name (e.g. `layer 3`, a rule name, `queue-wait`).
    pub name: String,
    /// Category: `phase`, `layer`, `job`, `round`, `rule`, `scheduler`.
    pub cat: &'static str,
    /// Tracer-local thread id (dense, stable per thread).
    pub tid: u64,
    /// Start, microseconds since the shared [`super::epoch`].
    pub start_us: u64,
    /// Duration in microseconds.
    pub dur_us: u64,
    /// Counted attributes, insertion order.
    pub args: Vec<(&'static str, u64)>,
}

struct OpenSpan {
    name: String,
    cat: &'static str,
    start: Duration,
    args: Vec<(&'static str, u64)>,
}

/// RAII span guard; records on drop. Inert (and free) when tracing is
/// off.
pub struct SpanGuard {
    open: Option<OpenSpan>,
}

impl SpanGuard {
    /// Attach a counted attribute; no-op on an inert guard.
    pub fn attr(&mut self, key: &'static str, value: u64) {
        if let Some(open) = &mut self.open {
            open.args.push((key, value));
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(open) = self.open.take() else { return };
        let end = super::now();
        register_thread();
        let record = SpanRecord {
            name: open.name,
            cat: open.cat,
            tid: tid(),
            start_us: open.start.as_micros() as u64,
            dur_us: end.saturating_sub(open.start).as_micros() as u64,
            args: open.args,
        };
        BUFFER.lock().expect("trace buffer lock").push(record);
    }
}

/// Open a span. The name is only copied when tracing is on.
pub fn span(cat: &'static str, name: &str) -> SpanGuard {
    if !trace_enabled() {
        return SpanGuard { open: None };
    }
    SpanGuard {
        open: Some(OpenSpan {
            name: name.to_owned(),
            cat,
            start: super::now(),
            args: Vec::new(),
        }),
    }
}

/// Open a span with a lazily formatted name: `span_fmt("layer",
/// format_args!("layer {tag}"))` formats nothing when tracing is off.
pub fn span_fmt(cat: &'static str, name: std::fmt::Arguments<'_>) -> SpanGuard {
    if !trace_enabled() {
        return SpanGuard { open: None };
    }
    SpanGuard {
        open: Some(OpenSpan {
            name: name.to_string(),
            cat,
            start: super::now(),
            args: Vec::new(),
        }),
    }
}

/// Render spans as a Chrome trace-event document (Perfetto-loadable):
/// one `"X"` complete event per span plus `thread_name` metadata.
pub fn render_chrome_trace(records: &[SpanRecord]) -> Json {
    let mut events = Vec::with_capacity(records.len() + 8);
    {
        let threads = THREADS.lock().expect("trace thread lock");
        for (tid, name) in threads.iter() {
            events.push(Json::Obj(vec![
                ("name".into(), Json::Str("thread_name".into())),
                ("ph".into(), Json::Str("M".into())),
                ("pid".into(), Json::Num(1.0)),
                ("tid".into(), Json::Num(*tid as f64)),
                (
                    "args".into(),
                    Json::Obj(vec![("name".into(), Json::Str(name.clone()))]),
                ),
            ]));
        }
    }
    for r in records {
        let mut event = vec![
            ("name".into(), Json::Str(r.name.clone())),
            ("cat".into(), Json::Str(r.cat.into())),
            ("ph".into(), Json::Str("X".into())),
            ("pid".into(), Json::Num(1.0)),
            ("tid".into(), Json::Num(r.tid as f64)),
            ("ts".into(), Json::Num(r.start_us as f64)),
            ("dur".into(), Json::Num(r.dur_us as f64)),
        ];
        if !r.args.is_empty() {
            let args = r
                .args
                .iter()
                .map(|(k, v)| ((*k).to_string(), Json::Num(*v as f64)))
                .collect();
            event.push(("args".into(), Json::Obj(args)));
        }
        events.push(Json::Obj(event));
    }
    Json::Obj(vec![
        ("traceEvents".into(), Json::Arr(events)),
        ("displayTimeUnit".into(), Json::Str("ms".into())),
    ])
}

/// Stop tracing and write the captured spans to `path` as Chrome
/// trace-event JSON. Returns the number of spans written.
pub fn export_chrome_trace(path: &Path) -> io::Result<usize> {
    let records = stop_tracing();
    let doc = render_chrome_trace(&records);
    std::fs::write(path, doc.render())?;
    Ok(records.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    // tracing state is process-global; tests that flip it serialize here
    static TRACE_TEST_LOCK: Mutex<()> = Mutex::new(());

    // other lib tests may run verify pipelines concurrently and record
    // spans while the flag is up; assertions filter to this thread's tid
    // and this test's span names to stay deterministic
    fn mine(records: Vec<SpanRecord>, prefix: &str) -> Vec<SpanRecord> {
        let me = tid();
        records
            .into_iter()
            .filter(|r| r.tid == me && r.name.starts_with(prefix))
            .collect()
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _guard = TRACE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        assert!(!trace_enabled());
        let before = BUFFER.lock().unwrap().len();
        {
            let mut sp = span("phase", "obs-test-noop");
            sp.attr("layer", 1);
        }
        assert_eq!(BUFFER.lock().unwrap().len(), before);
    }

    #[test]
    fn spans_nest_and_carry_attrs() {
        let _guard = TRACE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        start_tracing();
        {
            let _outer = span("phase", "obs-test-outer");
            let mut inner = span_fmt("layer", format_args!("obs-test-layer {}", 7));
            inner.attr("layer", 7);
            inner.attr("reused", 1);
        }
        let records = mine(stop_tracing(), "obs-test-");
        assert_eq!(records.len(), 2);
        // inner drops first
        assert_eq!(records[0].name, "obs-test-layer 7");
        assert_eq!(records[0].args, vec![("layer", 7), ("reused", 1)]);
        assert_eq!(records[1].name, "obs-test-outer");
        assert_eq!(records[0].tid, records[1].tid);
        // containment: inner inside outer
        assert!(records[0].start_us >= records[1].start_us);
        assert!(
            records[0].start_us + records[0].dur_us
                <= records[1].start_us + records[1].dur_us
        );
    }

    #[test]
    fn chrome_export_round_trips_as_json() {
        let _guard = TRACE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        start_tracing();
        {
            let mut sp = span("rule", "obs-test-mul-comm");
            sp.attr("matches_tried", 42);
        }
        let records = mine(stop_tracing(), "obs-test-");
        let doc = render_chrome_trace(&records);
        let parsed = Json::parse(&doc.render()).expect("trace must be valid JSON");
        let events = parsed.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        let rule = events
            .iter()
            .find(|e| e.str_at("cat") == Some("rule"))
            .expect("rule span present");
        assert_eq!(rule.str_at("name"), Some("obs-test-mul-comm"));
        assert_eq!(rule.str_at("ph"), Some("X"));
        assert_eq!(rule.get("args").and_then(|a| a.u64_at("matches_tried")), Some(42));
    }
}
