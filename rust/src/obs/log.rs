//! Leveled stderr logging, controlled by `SCALIFY_LOG=warn|info|debug`.
//!
//! The default level is `warn`, and warn-level lines print as
//! `scalify: warning: …` — byte-identical to the `eprintln!` warnings
//! this logger replaced, so default output is unchanged. `debug` is
//! where the degrade-to-cold paths explain *why* a warm start went cold
//! (cache parse failures, state version skew, fingerprint mismatches).

use std::fmt;
use std::sync::OnceLock;

/// Log severity; larger is chattier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Degrades and recoverable problems; always printed.
    Warn = 0,
    /// Lifecycle notes (cache preloads, state writes).
    Info = 1,
    /// Why-did-that-happen detail for warm-start forensics.
    Debug = 2,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Warn => "warning",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// Parse a `SCALIFY_LOG` value; unknown strings fall back to `warn`.
pub fn parse_level(value: &str) -> Level {
    match value.trim().to_ascii_lowercase().as_str() {
        "debug" => Level::Debug,
        "info" => Level::Info,
        _ => Level::Warn,
    }
}

static LEVEL: OnceLock<Level> = OnceLock::new();

/// The active level (reads `SCALIFY_LOG` once).
pub fn level() -> Level {
    *LEVEL.get_or_init(|| {
        std::env::var("SCALIFY_LOG").map(|v| parse_level(&v)).unwrap_or(Level::Warn)
    })
}

/// Would a line at `l` print?
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Print one line at level `l` (callers use the `log_warn!` /
/// `log_info!` / `log_debug!` macros).
pub fn log(l: Level, args: fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("scalify: {}: {args}", l.tag());
    }
}

/// Log at warn level: `log_warn!("cache flush failed: {e}")` prints
/// `scalify: warning: cache flush failed: …` (always).
#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Warn, format_args!($($t)*))
    };
}

/// Log at info level (printed under `SCALIFY_LOG=info|debug`).
#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Info, format_args!($($t)*))
    };
}

/// Log at debug level (printed under `SCALIFY_LOG=debug`).
#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Debug, format_args!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_is_lenient_and_defaults_to_warn() {
        assert_eq!(parse_level("debug"), Level::Debug);
        assert_eq!(parse_level(" INFO "), Level::Info);
        assert_eq!(parse_level("warn"), Level::Warn);
        assert_eq!(parse_level("nonsense"), Level::Warn);
    }

    #[test]
    fn warn_is_never_filtered() {
        assert!(Level::Warn <= level());
    }
}
