//! Observability: span tracing, a process-wide metrics registry, and a
//! leveled stderr logger — the measurement substrate the perf work
//! ratchets against.
//!
//! Three surfaces, all std-only and all near-zero-cost when off:
//!
//! * [`trace`] — a thread-safe span tracer. [`span`]/[`span_fmt`] return an
//!   RAII guard; when tracing is disabled the guard is inert and the call
//!   costs one relaxed atomic load. Finished traces export as Chrome
//!   trace-event JSON, loadable in Perfetto (`scalify … --trace out.json`).
//! * [`metrics`] — monotonic [`Counter`]s, [`Gauge`]s and fixed-bucket
//!   [`Histogram`]s with a Prometheus text renderer (`scalify client
//!   metrics`). Histograms replace the old unbounded latency `Vec`s.
//! * [`log`] — `SCALIFY_LOG=warn|info|debug` leveled logging. `warn` is
//!   the default, so routed warnings print exactly what the old scattered
//!   `eprintln!` calls printed.
//!
//! The module also owns the **shared clock**: one process-wide monotonic
//! epoch ([`epoch`]) that trace timestamps, bench timings and batch
//! `wall_secs` all read from, so traces and bench JSON agree on the same
//! numbers.

pub mod log;
pub mod metrics;
pub mod trace;

pub use log::Level;
pub use metrics::{registry, Counter, Gauge, Histogram, Registry, LATENCY_BUCKETS};
pub use trace::{
    export_chrome_trace, span, span_fmt, start_tracing, stop_tracing, trace_enabled,
    SpanGuard, SpanRecord,
};

use std::sync::OnceLock;
use std::time::{Duration, Instant};

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// The process-wide monotonic epoch. First caller pins it; every trace
/// timestamp and [`Stamp`] is relative to this instant.
pub fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Time since the shared epoch.
pub fn now() -> Duration {
    epoch().elapsed()
}

/// A point on the shared clock; the unit benches and `batch --json` use
/// for wall timings so they agree with trace timestamps.
#[derive(Clone, Copy, Debug)]
pub struct Stamp(Duration);

/// Read the shared clock.
pub fn stamp() -> Stamp {
    Stamp(now())
}

impl Stamp {
    /// Wall time elapsed since this stamp was taken.
    pub fn elapsed(&self) -> Duration {
        now().saturating_sub(self.0)
    }

    /// `elapsed` in seconds, the shape bench JSON wants.
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Microseconds since the epoch (trace-event `ts` unit).
    pub fn micros(&self) -> u64 {
        self.0.as_micros() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stamps_are_monotonic_on_the_shared_epoch() {
        let a = stamp();
        let b = stamp();
        assert!(b.micros() >= a.micros());
        assert!(a.elapsed_secs() >= 0.0);
    }
}
