//! Process-wide metrics registry: monotonic counters, gauges and
//! fixed-bucket histograms, rendered in Prometheus text exposition
//! format.
//!
//! The registry is global and append-only: a name, once used, keeps its
//! instrument for the process lifetime. Instruments are plain atomics —
//! recording never blocks on more than the name-lookup mutex, and
//! callers on hot paths hold an `Arc` to skip even that.
//!
//! Histograms are the bounded replacement for the service's old
//! unbounded per-request latency `Vec`: a fixed set of buckets plus an
//! exact max, so p50/p95/max survive (as bucket-interpolated estimates
//! and an exact max) under "org hammers the verifier" load with O(1)
//! memory.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Latency bucket upper bounds in seconds (a final `+Inf` bucket is
/// implicit). Spans four decades: sub-millisecond memo replays to
/// minutes-scale 405B cold verifies.
pub const LATENCY_BUCKETS: &[f64] = &[
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0,
    60.0,
];

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add `n` to the counter.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Gauge: a settable instantaneous value (stored as `f64` bits).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// Set the gauge.
    pub fn set(&self, value: f64) {
        self.0.store(value.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket histogram with an exact running max.
///
/// `buckets[i]` counts observations `<= bounds[i]`; the final slot is
/// the `+Inf` bucket. Quantiles interpolate linearly inside the
/// containing bucket and clamp to the exact max, so `p50 <= p95 <= max`
/// always holds.
#[derive(Debug)]
pub struct Histogram {
    bounds: &'static [f64],
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Sum in microseconds (kept integral for lock-free accumulation).
    sum_us: AtomicU64,
    /// Exact max as `f64` bits (valid `fetch_max`: non-negative IEEE-754
    /// floats order like their bit patterns).
    max_bits: AtomicU64,
}

impl Histogram {
    /// New histogram over `bounds` (ascending upper bounds; `+Inf`
    /// implicit).
    pub fn new(bounds: &'static [f64]) -> Histogram {
        Histogram {
            bounds,
            buckets: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_bits: AtomicU64::new(0),
        }
    }

    /// Record one observation (seconds; negative values clamp to 0).
    pub fn observe(&self, value: f64) {
        let value = value.max(0.0);
        let idx = self
            .bounds
            .iter()
            .position(|b| value <= *b)
            .unwrap_or(self.bounds.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add((value * 1e6) as u64, Ordering::Relaxed);
        self.max_bits.fetch_max(value.to_bits(), Ordering::Relaxed);
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of observations, seconds.
    pub fn sum_secs(&self) -> f64 {
        self.sum_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Exact maximum observed (0 when empty).
    pub fn max(&self) -> f64 {
        f64::from_bits(self.max_bits.load(Ordering::Relaxed))
    }

    /// Quantile estimate: linear interpolation inside the containing
    /// bucket, clamped to the exact max. 0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = (q * total as f64).ceil().clamp(1.0, total as f64) as u64;
        let mut seen = 0u64;
        for (i, bucket) in self.buckets.iter().enumerate() {
            let here = bucket.load(Ordering::Relaxed);
            if here == 0 {
                continue;
            }
            if seen + here >= rank {
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = if i < self.bounds.len() { self.bounds[i] } else { self.max() };
                let frac = (rank - seen) as f64 / here as f64;
                return (lo + (hi - lo) * frac).min(self.max());
            }
            seen += here;
        }
        self.max()
    }

    /// Per-bucket cumulative counts paired with their upper bounds
    /// (`f64::INFINITY` last), the shape Prometheus `_bucket` lines want.
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::with_capacity(self.buckets.len());
        let mut acc = 0u64;
        for (i, bucket) in self.buckets.iter().enumerate() {
            acc += bucket.load(Ordering::Relaxed);
            let bound =
                if i < self.bounds.len() { self.bounds[i] } else { f64::INFINITY };
            out.push((bound, acc));
        }
        out
    }
}

/// Append one histogram in Prometheus text exposition format.
pub fn render_histogram(out: &mut String, name: &str, hist: &Histogram) {
    let _ = writeln!(out, "# TYPE {name} histogram");
    for (bound, cum) in hist.cumulative_buckets() {
        if bound.is_infinite() {
            let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
        } else {
            let _ = writeln!(out, "{name}_bucket{{le=\"{bound}\"}} {cum}");
        }
    }
    let _ = writeln!(out, "{name}_sum {}", hist.sum_secs());
    let _ = writeln!(out, "{name}_count {}", hist.count());
}

/// Like [`render_histogram`], with extra labels on every series (the
/// sharded service's per-shard latency, e.g. `labels = "shard=\"0\""`).
/// The `# TYPE` line is the caller's job — labeled series of one metric
/// share a single type declaration.
pub fn render_histogram_labeled(
    out: &mut String,
    name: &str,
    labels: &str,
    hist: &Histogram,
) {
    for (bound, cum) in hist.cumulative_buckets() {
        if bound.is_infinite() {
            let _ = writeln!(out, "{name}_bucket{{{labels},le=\"+Inf\"}} {cum}");
        } else {
            let _ = writeln!(out, "{name}_bucket{{{labels},le=\"{bound}\"}} {cum}");
        }
    }
    let _ = writeln!(out, "{name}_sum{{{labels}}} {}", hist.sum_secs());
    let _ = writeln!(out, "{name}_count{{{labels}}} {}", hist.count());
}

/// Total observations across a set of same-bounds histograms.
pub fn merged_count(hists: &[&Histogram]) -> u64 {
    hists.iter().map(|h| h.count()).sum()
}

/// Exact maximum across a set of histograms (0 when all are empty).
pub fn merged_max(hists: &[&Histogram]) -> f64 {
    hists.iter().map(|h| h.max()).fold(0.0, f64::max)
}

/// Quantile estimate over the **merged** bucket counts of several
/// same-bounds histograms — how the sharded service rolls per-shard
/// latency up into one `StatsSnapshot`.
///
/// The empty case is guarded explicitly: with zero total observations
/// the answer is 0.0, never an interpolation over empty buckets (a
/// fresh daemon must report all-zero percentiles). Mirrors
/// [`Histogram::quantile`]: linear interpolation inside the containing
/// bucket, clamped to the exact merged max.
pub fn merged_quantile(hists: &[&Histogram], q: f64) -> f64 {
    let total = merged_count(hists);
    if total == 0 || hists.is_empty() {
        return 0.0;
    }
    debug_assert!(
        hists.windows(2).all(|w| std::ptr::eq(w[0].bounds, w[1].bounds)),
        "merged histograms must share bucket bounds"
    );
    let max = merged_max(hists);
    let bounds = hists[0].bounds;
    // merge per-bucket counts (not cumulative: the interpolation needs
    // the count inside each bucket)
    let mut merged = vec![0u64; bounds.len() + 1];
    for h in hists {
        let mut prev = 0u64;
        for (i, (_, cum)) in h.cumulative_buckets().into_iter().enumerate() {
            merged[i] += cum - prev;
            prev = cum;
        }
    }
    let rank = (q * total as f64).ceil().clamp(1.0, total as f64) as u64;
    let mut seen = 0u64;
    for (i, here) in merged.into_iter().enumerate() {
        if here == 0 {
            continue;
        }
        if seen + here >= rank {
            let lo = if i == 0 { 0.0 } else { bounds[i - 1] };
            let hi = if i < bounds.len() { bounds[i] } else { max };
            let frac = (rank - seen) as f64 / here as f64;
            return (lo + (hi - lo) * frac).min(max);
        }
        seen += here;
    }
    max
}

/// The process-wide instrument registry.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    /// Get or create the counter `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = self.counters.lock().expect("metrics counter lock");
        Arc::clone(map.entry(name.to_owned()).or_default())
    }

    /// Get or create the gauge `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut map = self.gauges.lock().expect("metrics gauge lock");
        Arc::clone(map.entry(name.to_owned()).or_default())
    }

    /// Get or create the histogram `name` over `bounds` (bounds are
    /// fixed by the first caller).
    pub fn histogram(&self, name: &str, bounds: &'static [f64]) -> Arc<Histogram> {
        let mut map = self.histograms.lock().expect("metrics histogram lock");
        Arc::clone(
            map.entry(name.to_owned()).or_insert_with(|| Arc::new(Histogram::new(bounds))),
        )
    }

    /// Render every instrument in Prometheus text exposition format,
    /// sorted by name.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().expect("metrics counter lock").iter() {
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {}", c.get());
        }
        for (name, g) in self.gauges.lock().expect("metrics gauge lock").iter() {
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {}", g.get());
        }
        for (name, h) in self.histograms.lock().expect("metrics histogram lock").iter() {
            render_histogram(&mut out, name, h);
        }
        out
    }
}

static REGISTRY: OnceLock<Registry> = OnceLock::new();

/// The process-wide registry.
pub fn registry() -> &'static Registry {
    REGISTRY.get_or_init(Registry::default)
}

/// Bump a registry counter by `n` — the coarse-grained convenience the
/// pipeline instrumentation uses (one name lookup per call; hot paths
/// hold the `Arc` instead).
pub fn count(name: &str, n: u64) {
    registry().counter(name).add(n);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_are_ordered_and_capped_by_exact_max() {
        let h = Histogram::new(LATENCY_BUCKETS);
        for i in 1..=100 {
            h.observe(i as f64 / 1000.0); // 1ms … 100ms
        }
        assert_eq!(h.count(), 100);
        let (p50, p95, max) = (h.quantile(0.5), h.quantile(0.95), h.max());
        assert!(p50 <= p95 && p95 <= max, "{p50} <= {p95} <= {max}");
        assert!((max - 0.1).abs() < 1e-9, "exact max: {max}");
        // p50 lands in the right decade (true value 0.050)
        assert!((0.025..=0.1).contains(&p50), "{p50}");
        assert!(h.sum_secs() > 5.0 * 0.99 && h.sum_secs() < 5.1);
    }

    #[test]
    fn histogram_memory_is_bounded() {
        let h = Histogram::new(LATENCY_BUCKETS);
        for _ in 0..100_000 {
            h.observe(0.002);
        }
        assert_eq!(h.count(), 100_000);
        assert_eq!(h.buckets.len(), LATENCY_BUCKETS.len() + 1);
        let (p50, max) = (h.quantile(0.5), h.max());
        assert!(p50 <= 0.0025 + 1e-9 && max == 0.002, "{p50} {max}");
    }

    #[test]
    fn prometheus_render_has_bucket_sum_count_series() {
        let h = Histogram::new(LATENCY_BUCKETS);
        h.observe(0.004);
        h.observe(40.0);
        let mut text = String::new();
        render_histogram(&mut text, "test_latency_seconds", &h);
        assert!(text.contains("# TYPE test_latency_seconds histogram"));
        assert!(text.contains("test_latency_seconds_bucket{le=\"0.005\"} 1"));
        assert!(text.contains("test_latency_seconds_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("test_latency_seconds_count 2"));
        assert!(text.contains("test_latency_seconds_sum "));
    }

    #[test]
    fn merged_quantiles_over_empty_histograms_are_exactly_zero() {
        // the fresh-daemon regression: zero observations must roll up to
        // 0s, never an interpolation over empty buckets
        let a = Histogram::new(LATENCY_BUCKETS);
        let b = Histogram::new(LATENCY_BUCKETS);
        for q in [0.5, 0.95, 0.999] {
            assert_eq!(merged_quantile(&[&a, &b], q), 0.0);
        }
        assert_eq!(merged_quantile(&[], 0.5), 0.0);
        assert_eq!(merged_max(&[&a, &b]), 0.0);
        assert_eq!(merged_count(&[&a, &b]), 0);
    }

    #[test]
    fn merged_quantiles_agree_with_a_single_combined_histogram() {
        let a = Histogram::new(LATENCY_BUCKETS);
        let b = Histogram::new(LATENCY_BUCKETS);
        let combined = Histogram::new(LATENCY_BUCKETS);
        for i in 1..=100 {
            let v = i as f64 / 1000.0;
            if i % 2 == 0 { a.observe(v) } else { b.observe(v) }
            combined.observe(v);
        }
        for q in [0.5, 0.95] {
            let merged = merged_quantile(&[&a, &b], q);
            let single = combined.quantile(q);
            assert!((merged - single).abs() < 1e-9, "q={q}: {merged} vs {single}");
        }
        assert_eq!(merged_max(&[&a, &b]), combined.max());
        assert_eq!(merged_count(&[&a, &b]), 100);
        // one empty shard must not perturb the rollup
        let empty = Histogram::new(LATENCY_BUCKETS);
        assert_eq!(
            merged_quantile(&[&a, &b, &empty], 0.95),
            merged_quantile(&[&a, &b], 0.95)
        );
    }

    #[test]
    fn labeled_histogram_render_carries_the_labels_on_every_series() {
        let h = Histogram::new(LATENCY_BUCKETS);
        h.observe(0.004);
        let mut text = String::new();
        render_histogram_labeled(&mut text, "shard_latency_seconds", "shard=\"2\"", &h);
        assert!(
            text.contains("shard_latency_seconds_bucket{shard=\"2\",le=\"0.005\"} 1"),
            "{text}"
        );
        assert!(
            text.contains("shard_latency_seconds_bucket{shard=\"2\",le=\"+Inf\"} 1"),
            "{text}"
        );
        assert!(text.contains("shard_latency_seconds_count{shard=\"2\"} 1"), "{text}");
        assert!(!text.contains("# TYPE"), "type line is the caller's job");
    }

    #[test]
    fn registry_instruments_are_shared_by_name() {
        let r = Registry::default();
        r.counter("x_total").add(2);
        r.counter("x_total").inc();
        assert_eq!(r.counter("x_total").get(), 3);
        r.gauge("g").set(1.5);
        assert_eq!(r.gauge("g").get(), 1.5);
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE x_total counter\nx_total 3"));
        assert!(text.contains("# TYPE g gauge\ng 1.5"));
    }
}
