//! Bounded job scheduling for the verification service.
//!
//! A [`Scheduler`] layers a **bounded in-flight window with blocking
//! backpressure** on the reusable [`WorkerPool`]: `execute` admits a job
//! only when a slot is free (callers — service connections, batch
//! submitters — block at the admission gate otherwise), runs it on a pool
//! worker, and hands the result back to the submitting thread. Many
//! concurrent clients therefore share one pool and one
//! [`crate::verifier::Session`] without unbounded queue growth: when the
//! daemon is saturated, new requests wait at the gate instead of piling
//! up memory.
//!
//! A panicking job surfaces as a typed [`ScalifyError::Runtime`] on the
//! submitter (its admission slot is released as usual) — the daemon
//! answers the offending request with an error response and keeps
//! serving; see the panic-isolation tests in `service::server`.
//!
//! The admission gate is **priority-aware** ([`Scheduler::execute_prio`]):
//! when the window is contended, queued submitters are admitted
//! highest-priority first (FIFO among equals — arrival order breaks
//! ties), and a submitter with a deadline gives up with a typed error
//! instead of waiting past it. `execute` is the priority-0, no-deadline
//! case and behaves exactly as before.
//!
//! The session's own parallel-pass pool is a *different* pool —
//! scheduler workers block on it while verifying, which is fine; the two
//! pools must stay separate or a saturated scheduler could deadlock
//! waiting for sub-jobs that need its own workers.

use crate::error::{Result, ScalifyError};
use crate::util::{panic_message, WorkerPool};
use std::cmp::Reverse;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Admission-gate state: the in-flight count plus the queue of waiting
/// submitters. The queue is a plain vector, not a heap, because a
/// deadline-expired waiter must remove itself from the middle; it is
/// tiny (bounded by concurrent connections), so the `max_by_key` head
/// scan is cheaper than heap bookkeeping.
struct Gate {
    inflight: usize,
    /// Waiting submitters as `(priority, arrival seq)`; the head is the
    /// max by `(priority, Reverse(seq))` — highest priority, earliest
    /// arrival among equals.
    waiting: Vec<(i64, u64)>,
}

/// Bounded scheduler over a private worker pool; see the module docs.
pub struct Scheduler {
    pool: WorkerPool,
    /// (gate state, wakeup for slot release / queue change).
    slots: Arc<(Mutex<Gate>, Condvar)>,
    capacity: usize,
    seq: AtomicU64,
    submitted: AtomicUsize,
    completed: Arc<AtomicUsize>,
}

impl Scheduler {
    /// Scheduler with `workers` pool threads and an admission window of
    /// `capacity` in-flight jobs (both clamped to at least 1).
    pub fn new(workers: usize, capacity: usize) -> Scheduler {
        Scheduler {
            pool: WorkerPool::new(workers),
            slots: Arc::new((
                Mutex::new(Gate { inflight: 0, waiting: Vec::new() }),
                Condvar::new(),
            )),
            capacity: capacity.max(1),
            seq: AtomicU64::new(0),
            submitted: AtomicUsize::new(0),
            completed: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// Admission window size.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Pool worker threads.
    pub fn workers(&self) -> usize {
        self.pool.threads()
    }

    /// Jobs admitted so far.
    pub fn submitted(&self) -> usize {
        self.submitted.load(Ordering::Relaxed)
    }

    /// Jobs finished so far.
    pub fn completed(&self) -> usize {
        self.completed.load(Ordering::Relaxed)
    }

    /// Jobs currently admitted but not finished.
    pub fn inflight(&self) -> usize {
        self.slots.0.lock().unwrap_or_else(|p| p.into_inner()).inflight
    }

    /// Block until an admission slot is free, then take it (priority 0,
    /// no deadline — infallible).
    fn acquire(&self) {
        self.acquire_prio(0, None).expect("acquire without a deadline cannot fail");
    }

    /// Block until this submitter is at the head of the priority queue
    /// *and* a slot is free, then take the slot. With a deadline, gives
    /// up at `deadline` with a typed error instead of waiting on.
    fn acquire_prio(&self, priority: i64, deadline: Option<Instant>) -> Result<()> {
        // the admission gate is the service's queueing point: the span
        // length is exactly how long this job waited for a slot
        let mut qsp = crate::obs::span("scheduler", "queue-wait");
        let (lock, cv) = &*self.slots;
        let mut gate = lock.lock().unwrap_or_else(|p| p.into_inner());
        if gate.inflight >= self.capacity || !gate.waiting.is_empty() {
            crate::obs::metrics::count("scalify_scheduler_queue_waits_total", 1);
            let me = (priority, self.seq.fetch_add(1, Ordering::Relaxed));
            gate.waiting.push(me);
            loop {
                let head = gate
                    .waiting
                    .iter()
                    .copied()
                    .max_by_key(|&(p, s)| (p, Reverse(s)))
                    .expect("queue holds at least this waiter");
                if head == me && gate.inflight < self.capacity {
                    break;
                }
                match deadline {
                    Some(dl) => {
                        let now = Instant::now();
                        if now >= dl {
                            gate.waiting.retain(|&w| w != me);
                            // the head may have been blocked behind us
                            cv.notify_all();
                            return Err(ScalifyError::runtime(
                                "deadline exceeded while queued",
                            ));
                        }
                        gate = cv
                            .wait_timeout(gate, dl - now)
                            .unwrap_or_else(|p| p.into_inner())
                            .0;
                    }
                    None => {
                        gate = cv.wait(gate).unwrap_or_else(|p| p.into_inner());
                    }
                }
            }
            gate.waiting.retain(|&w| w != me);
            // with capacity > 1 another slot may still be free — wake the
            // new head so it can claim it without waiting for a release
            cv.notify_all();
        }
        gate.inflight += 1;
        qsp.attr("inflight", gate.inflight as u64);
        crate::obs::metrics::count("scalify_scheduler_admissions_total", 1);
        Ok(())
    }

    fn release(slots: &(Mutex<Gate>, Condvar)) {
        let (lock, cv) = slots;
        let mut gate = lock.lock().unwrap_or_else(|p| p.into_inner());
        gate.inflight = gate.inflight.saturating_sub(1);
        cv.notify_all();
    }

    /// Run one job through the bounded queue and block for its result.
    /// This is the backpressure point: with `capacity` jobs in flight the
    /// caller waits here. A panicking job comes back as a typed
    /// [`ScalifyError::Runtime`], never as a re-raised panic.
    pub fn execute<T, F>(&self, job: F) -> Result<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        self.execute_prio(0, None, job)
    }

    /// [`Scheduler::execute`] with an admission priority and an optional
    /// queueing deadline. Higher priorities are admitted first when the
    /// window is contended; a deadline that expires while still queued
    /// returns a typed error (`deadline exceeded while queued`) without
    /// running the job. A deadline does **not** interrupt a job that was
    /// already admitted — in-verify deadlines are the session control's
    /// job (checked at layer boundaries).
    pub fn execute_prio<T, F>(
        &self,
        priority: i64,
        deadline: Option<Instant>,
        job: F,
    ) -> Result<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = channel::<std::thread::Result<T>>();
        crate::faults::check("sched-admit")?;
        self.acquire_prio(priority, deadline)?;
        self.submitted.fetch_add(1, Ordering::Relaxed);
        let slots = Arc::clone(&self.slots);
        let completed = Arc::clone(&self.completed);
        if let Err(e) = self.pool.submit(move || {
            let out = catch_unwind(AssertUnwindSafe(job));
            completed.fetch_add(1, Ordering::Relaxed);
            Scheduler::release(&slots);
            // receiver only disappears if the caller itself died
            let _ = tx.send(out);
        }) {
            // the closure never ran, so its slot must be released here
            Scheduler::release(&self.slots);
            return Err(e);
        }
        match rx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(panic)) => Err(ScalifyError::runtime(format!(
                "verify job panicked: {}",
                panic_message(panic.as_ref())
            ))),
            Err(_) => Err(ScalifyError::runtime("scheduler worker dropped a job result")),
        }
    }

    /// Run every job through the bounded queue; results come back in
    /// submission order, each a typed `Result` (a panicking or dropped
    /// job errors its own slot only). Unlike [`WorkerPool::run_all`],
    /// admission obeys the capacity bound: at most `capacity` jobs
    /// *execute* concurrently (the submitted closures themselves are
    /// materialized by the caller; the bound is on in-flight work, not on
    /// the job list).
    pub fn run_all<T, F>(&self, jobs: Vec<F>) -> Vec<Result<T>>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = jobs.len();
        let (tx, rx) = channel::<(usize, std::thread::Result<T>)>();
        let mut slots_out: Vec<Option<Result<T>>> = (0..n).map(|_| None).collect();
        let mut pending = 0usize;
        for (i, job) in jobs.into_iter().enumerate() {
            self.acquire();
            self.submitted.fetch_add(1, Ordering::Relaxed);
            let slots = Arc::clone(&self.slots);
            let completed = Arc::clone(&self.completed);
            let tx = tx.clone();
            match self.pool.submit(move || {
                let out = catch_unwind(AssertUnwindSafe(job));
                completed.fetch_add(1, Ordering::Relaxed);
                Scheduler::release(&slots);
                let _ = tx.send((i, out));
            }) {
                Ok(()) => pending += 1,
                Err(e) => {
                    Scheduler::release(&self.slots);
                    slots_out[i] = Some(Err(e));
                }
            }
        }
        drop(tx);
        for _ in 0..pending {
            let Ok((i, out)) = rx.recv() else { break };
            slots_out[i] = Some(out.map_err(|panic| {
                ScalifyError::runtime(format!(
                    "verify job panicked: {}",
                    panic_message(panic.as_ref())
                ))
            }));
        }
        slots_out
            .into_iter()
            .map(|s| {
                s.unwrap_or_else(|| {
                    Err(ScalifyError::runtime("scheduler worker dropped a job result"))
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    #[test]
    fn execute_returns_results() {
        let s = Scheduler::new(2, 4);
        assert_eq!(s.execute(|| 40 + 2).unwrap(), 42);
        assert_eq!(s.submitted(), 1);
        assert_eq!(s.completed(), 1);
        assert_eq!(s.inflight(), 0);
    }

    #[test]
    fn run_all_preserves_order_under_bounded_admission() {
        let s = Scheduler::new(4, 2);
        let jobs: Vec<_> = (0..32).map(|i| move || i * 3).collect();
        let out: Vec<i32> = s.run_all(jobs).into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(out, (0..32).map(|i| i * 3).collect::<Vec<_>>());
        assert_eq!(s.completed(), 32);
    }

    #[test]
    fn inflight_never_exceeds_capacity() {
        let s = Arc::new(Scheduler::new(4, 2));
        let peak = Arc::new(AtomicUsize::new(0));
        let live = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let s2 = Arc::clone(&s);
            let peak2 = Arc::clone(&peak);
            let live2 = Arc::clone(&live);
            handles.push(std::thread::spawn(move || {
                s2.execute(move || {
                    let now = live2.fetch_add(1, Ordering::SeqCst) + 1;
                    peak2.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(10));
                    live2.fetch_sub(1, Ordering::SeqCst);
                })
                .unwrap()
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(
            peak.load(Ordering::SeqCst) <= 2,
            "backpressure must cap concurrent jobs at capacity: peak {}",
            peak.load(Ordering::SeqCst)
        );
        assert_eq!(s.completed(), 8);
        assert_eq!(s.inflight(), 0);
    }

    #[test]
    fn job_panic_is_a_typed_error_on_the_submitter() {
        let s = Scheduler::new(1, 1);
        let err = s.execute::<(), _>(|| panic!("job went boom")).unwrap_err();
        assert!(matches!(err, ScalifyError::Runtime(_)), "{err:?}");
        assert!(err.message().contains("job went boom"), "{err}");
    }

    #[test]
    fn slot_frees_even_after_a_panic() {
        let s = Scheduler::new(1, 1);
        assert!(s.execute::<(), _>(|| panic!("first")).is_err());
        // the slot released; the scheduler still works
        assert_eq!(s.execute(|| 7).unwrap(), 7);
        assert_eq!(s.inflight(), 0);
    }

    #[test]
    fn higher_priority_submitters_are_admitted_first() {
        let s = Arc::new(Scheduler::new(1, 1));
        let (hold_tx, hold_rx) = std::sync::mpsc::channel::<()>();
        let order = Arc::new(Mutex::new(Vec::<&'static str>::new()));

        // occupy the single slot so every later submitter queues
        let blocker = {
            let s2 = Arc::clone(&s);
            std::thread::spawn(move || {
                s2.execute(move || {
                    let _ = hold_rx.recv();
                })
                .unwrap()
            })
        };
        while s.inflight() == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }

        let mut handles = Vec::new();
        for name in ["low-a", "low-b"] {
            let s2 = Arc::clone(&s);
            let order2 = Arc::clone(&order);
            handles.push(std::thread::spawn(move || {
                s2.execute_prio(0, None, move || {
                    order2.lock().unwrap().push(name);
                })
                .unwrap()
            }));
            // let this submitter reach the queue before the next
            std::thread::sleep(Duration::from_millis(30));
        }
        {
            let s2 = Arc::clone(&s);
            let order2 = Arc::clone(&order);
            handles.push(std::thread::spawn(move || {
                s2.execute_prio(10, None, move || {
                    order2.lock().unwrap().push("high");
                })
                .unwrap()
            }));
        }
        std::thread::sleep(Duration::from_millis(30));

        hold_tx.send(()).unwrap();
        blocker.join().unwrap();
        for h in handles {
            h.join().unwrap();
        }
        let order = order.lock().unwrap();
        assert_eq!(
            order.first(),
            Some(&"high"),
            "priority 10 must jump the queued priority-0 jobs: {order:?}"
        );
        assert_eq!(order.len(), 3);
    }

    #[test]
    fn deadline_expiring_in_the_queue_is_a_typed_error() {
        let s = Arc::new(Scheduler::new(1, 1));
        let (hold_tx, hold_rx) = std::sync::mpsc::channel::<()>();
        let blocker = {
            let s2 = Arc::clone(&s);
            std::thread::spawn(move || {
                s2.execute(move || {
                    let _ = hold_rx.recv();
                })
                .unwrap()
            })
        };
        while s.inflight() == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }

        let deadline = Instant::now() + Duration::from_millis(40);
        let err = s
            .execute_prio(0, Some(deadline), || {
                unreachable!("must never be admitted");
            })
            .unwrap_err();
        assert!(err.message().contains("deadline exceeded while queued"), "{err}");

        // the abandoned waiter left no debris: the queue drains normally
        hold_tx.send(()).unwrap();
        blocker.join().unwrap();
        assert_eq!(s.execute(|| 5).unwrap(), 5);
        assert_eq!(s.inflight(), 0);
    }

    #[test]
    fn run_all_isolates_a_panicking_job_to_its_slot() {
        let s = Scheduler::new(2, 2);
        let jobs: Vec<Box<dyn FnOnce() -> i32 + Send>> =
            vec![Box::new(|| 10), Box::new(|| panic!("mid-batch")), Box::new(|| 30)];
        let out = s.run_all(jobs);
        assert_eq!(*out[0].as_ref().unwrap(), 10);
        assert!(out[1].as_ref().unwrap_err().message().contains("mid-batch"));
        assert_eq!(*out[2].as_ref().unwrap(), 30);
        assert_eq!(s.inflight(), 0);
    }
}
