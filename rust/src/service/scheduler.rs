//! Bounded job scheduling for the verification service.
//!
//! A [`Scheduler`] layers a **bounded in-flight window with blocking
//! backpressure** on the reusable [`WorkerPool`]: `execute` admits a job
//! only when a slot is free (callers — service connections, batch
//! submitters — block at the admission gate otherwise), runs it on a pool
//! worker, and hands the result back to the submitting thread. Many
//! concurrent clients therefore share one pool and one
//! [`crate::verifier::Session`] without unbounded queue growth: when the
//! daemon is saturated, new requests wait at the gate instead of piling
//! up memory.
//!
//! The session's own speculative-pass pool is a *different* pool —
//! scheduler workers block on it while verifying, which is fine; the two
//! pools must stay separate or a saturated scheduler could deadlock
//! waiting for sub-jobs that need its own workers.

use crate::util::WorkerPool;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Condvar, Mutex};

/// Bounded scheduler over a private worker pool; see the module docs.
pub struct Scheduler {
    pool: WorkerPool,
    /// (in-flight count, wakeup for slot release).
    slots: Arc<(Mutex<usize>, Condvar)>,
    capacity: usize,
    submitted: AtomicUsize,
    completed: Arc<AtomicUsize>,
}

impl Scheduler {
    /// Scheduler with `workers` pool threads and an admission window of
    /// `capacity` in-flight jobs (both clamped to at least 1).
    pub fn new(workers: usize, capacity: usize) -> Scheduler {
        Scheduler {
            pool: WorkerPool::new(workers),
            slots: Arc::new((Mutex::new(0), Condvar::new())),
            capacity: capacity.max(1),
            submitted: AtomicUsize::new(0),
            completed: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// Admission window size.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Pool worker threads.
    pub fn workers(&self) -> usize {
        self.pool.threads()
    }

    /// Jobs admitted so far.
    pub fn submitted(&self) -> usize {
        self.submitted.load(Ordering::Relaxed)
    }

    /// Jobs finished so far.
    pub fn completed(&self) -> usize {
        self.completed.load(Ordering::Relaxed)
    }

    /// Jobs currently admitted but not finished.
    pub fn inflight(&self) -> usize {
        *self.slots.0.lock().expect("scheduler slot lock")
    }

    /// Block until an admission slot is free, then take it.
    fn acquire(&self) {
        let (lock, cv) = &*self.slots;
        let mut inflight = lock.lock().expect("scheduler slot lock");
        while *inflight >= self.capacity {
            inflight = cv.wait(inflight).expect("scheduler slot lock");
        }
        *inflight += 1;
    }

    fn release(slots: &(Mutex<usize>, Condvar)) {
        let (lock, cv) = slots;
        let mut inflight = lock.lock().expect("scheduler slot lock");
        *inflight = inflight.saturating_sub(1);
        cv.notify_all();
    }

    /// Run one job through the bounded queue and block for its result.
    /// This is the backpressure point: with `capacity` jobs in flight the
    /// caller waits here. A panicking job is re-raised on the caller.
    pub fn execute<T, F>(&self, job: F) -> T
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = channel::<std::thread::Result<T>>();
        self.acquire();
        self.submitted.fetch_add(1, Ordering::Relaxed);
        let slots = Arc::clone(&self.slots);
        let completed = Arc::clone(&self.completed);
        self.pool.submit(move || {
            let out = catch_unwind(AssertUnwindSafe(job));
            completed.fetch_add(1, Ordering::Relaxed);
            Scheduler::release(&slots);
            // receiver only disappears if the caller itself died
            let _ = tx.send(out);
        });
        match rx.recv() {
            Ok(Ok(v)) => v,
            Ok(Err(panic)) => resume_unwind(panic),
            Err(_) => panic!("scheduler worker dropped a job result"),
        }
    }

    /// Run every job through the bounded queue; results come back in
    /// submission order. Unlike [`WorkerPool::run_all`], admission obeys
    /// the capacity bound: at most `capacity` jobs *execute* concurrently
    /// (the submitted closures themselves are materialized by the caller;
    /// the bound is on in-flight work, not on the job list).
    pub fn run_all<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = jobs.len();
        let (tx, rx) = channel::<(usize, std::thread::Result<T>)>();
        for (i, job) in jobs.into_iter().enumerate() {
            self.acquire();
            self.submitted.fetch_add(1, Ordering::Relaxed);
            let slots = Arc::clone(&self.slots);
            let completed = Arc::clone(&self.completed);
            let tx = tx.clone();
            self.pool.submit(move || {
                let out = catch_unwind(AssertUnwindSafe(job));
                completed.fetch_add(1, Ordering::Relaxed);
                Scheduler::release(&slots);
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, out) = rx.recv().expect("scheduler workers hung up");
            match out {
                Ok(v) => results[i] = Some(v),
                Err(panic) => resume_unwind(panic),
            }
        }
        results.into_iter().map(|r| r.expect("missing job result")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    #[test]
    fn execute_returns_results() {
        let s = Scheduler::new(2, 4);
        assert_eq!(s.execute(|| 40 + 2), 42);
        assert_eq!(s.submitted(), 1);
        assert_eq!(s.completed(), 1);
        assert_eq!(s.inflight(), 0);
    }

    #[test]
    fn run_all_preserves_order_under_bounded_admission() {
        let s = Scheduler::new(4, 2);
        let jobs: Vec<_> = (0..32).map(|i| move || i * 3).collect();
        assert_eq!(s.run_all(jobs), (0..32).map(|i| i * 3).collect::<Vec<_>>());
        assert_eq!(s.completed(), 32);
    }

    #[test]
    fn inflight_never_exceeds_capacity() {
        let s = Arc::new(Scheduler::new(4, 2));
        let peak = Arc::new(AtomicUsize::new(0));
        let live = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let s2 = Arc::clone(&s);
            let peak2 = Arc::clone(&peak);
            let live2 = Arc::clone(&live);
            handles.push(std::thread::spawn(move || {
                s2.execute(move || {
                    let now = live2.fetch_add(1, Ordering::SeqCst) + 1;
                    peak2.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(10));
                    live2.fetch_sub(1, Ordering::SeqCst);
                })
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(
            peak.load(Ordering::SeqCst) <= 2,
            "backpressure must cap concurrent jobs at capacity: peak {}",
            peak.load(Ordering::SeqCst)
        );
        assert_eq!(s.completed(), 8);
        assert_eq!(s.inflight(), 0);
    }

    #[test]
    #[should_panic(expected = "job went boom")]
    fn job_panic_reraises_on_the_submitter() {
        let s = Scheduler::new(1, 1);
        s.execute(|| panic!("job went boom"));
    }

    #[test]
    fn slot_frees_even_after_a_panic() {
        let s = Scheduler::new(1, 1);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            s.execute(|| panic!("first"));
        }));
        assert!(caught.is_err());
        // the slot released; the scheduler still works
        assert_eq!(s.execute(|| 7), 7);
    }
}
