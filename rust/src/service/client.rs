//! Blocking client for the `scalify serve` wire protocol.
//!
//! One TCP connection, one request line out, one response line back —
//! the `scalify client` subcommand and the integration tests both drive
//! the daemon through this type. After a [`Client::hello`] negotiation
//! to protocol v2, [`Client::verify_opts`] can attach ids, priorities
//! and deadlines and consume streamed per-layer events; the normative
//! wire reference lives in `docs/PROTOCOL.md`.

use super::protocol::{
    LayerEvent, Request, Response, StatsSnapshot, VerifyOpts, VerifySource, PROTOCOL_V2,
};
use crate::error::{Result, ResultExt, ScalifyError};
use crate::report::json::Json;
use crate::util::Prng;
use crate::verifier::VerifyReport;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Default per-attempt socket timeout (connect, read and write): a hung
/// daemon surfaces as a typed error instead of pinning the caller
/// forever. `scalify client --timeout-secs` overrides it.
pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(30);

/// Monotone counter behind [`next_request_id`].
static NEXT_REQUEST_ID: AtomicU64 = AtomicU64::new(0);

/// A process-unique v2 request id. Retry loops reuse ONE id across every
/// attempt of the same logical request: re-submitting under an in-flight
/// id supersedes (cancels) the stale attempt on the daemon, so a retry
/// after a lost response never runs the same verify twice concurrently.
pub fn next_request_id() -> String {
    format!("req-{}-{}", std::process::id(), NEXT_REQUEST_ID.fetch_add(1, Ordering::Relaxed))
}

/// True for errors worth re-submitting: transport faults (the response
/// was lost; the daemon may or may not have served the request) and
/// daemon errors carrying the `retryable: ` convention (shard restarted
/// mid-job, injected fault). Verdicts, parse errors and unknown-model
/// errors are terminal.
pub fn is_retryable(message: &str) -> bool {
    // OS error strings vary in case ("Connection refused (os error 111)")
    let m = message.to_ascii_lowercase();
    m.contains("retryable: ")
        || m.contains("timed out")
        || m.contains("connection refused")
        || m.contains("connection reset")
        || m.contains("broken pipe")
        || m.contains("closed the connection")
        || m.contains("connecting to")
}

/// Client-side resilience policy: per-attempt socket timeouts plus
/// truncated exponential backoff with deterministic jitter between
/// attempts.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Total attempts, first try included (1 = no retry).
    pub attempts: u32,
    /// Backoff before retry `n` is `base_backoff * 2^(n-1)`, capped at
    /// [`RetryPolicy::max_backoff`], plus up to 50% jitter.
    pub base_backoff: Duration,
    /// Backoff ceiling.
    pub max_backoff: Duration,
    /// Per-attempt connect/read/write timeout.
    pub timeout: Duration,
    /// Jitter PRNG seed (deterministic for tests; vary per process for
    /// fleet de-synchronization).
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            attempts: 3,
            base_backoff: Duration::from_millis(50),
            max_backoff: Duration::from_secs(2),
            timeout: DEFAULT_TIMEOUT,
            jitter_seed: std::process::id() as u64,
        }
    }
}

impl RetryPolicy {
    /// The sleep before retry attempt `n` (1-based): truncated binary
    /// exponential backoff with up to +50% deterministic jitter.
    pub fn backoff(&self, n: u32, prng: &mut Prng) -> Duration {
        let exp = self.base_backoff.saturating_mul(1u32 << n.saturating_sub(1).min(16));
        let capped = exp.min(self.max_backoff);
        let jitter_ms = capped.as_millis() as u64 / 2;
        let jitter = if jitter_ms == 0 { 0 } else { prng.below(jitter_ms + 1) };
        capped + Duration::from_millis(jitter)
    }
}

/// Submit one verify request under a [`RetryPolicy`]: reconnect per
/// attempt (the previous connection may be dead), negotiate v2, reuse a
/// single request id across attempts (supersession makes the retry
/// idempotent), and back off between attempts. Streamed events from any
/// attempt reach `on_event`. Returns the first terminal outcome:
/// [`Response::VerifyDone`], [`Response::Cancelled`], a non-retryable
/// daemon error, or — attempts exhausted — the last retryable error.
pub fn verify_with_retry(
    addr: &str,
    request: &Request,
    opts: &VerifyOpts,
    policy: &RetryPolicy,
    mut on_event: impl FnMut(LayerEvent),
) -> Result<Response> {
    let mut opts = opts.clone();
    if opts.id.is_none() {
        opts.id = Some(next_request_id());
    }
    let mut prng = Prng::new(policy.jitter_seed);
    let attempts = policy.attempts.max(1);
    let mut last: Option<ScalifyError> = None;
    for attempt in 1..=attempts {
        if attempt > 1 {
            std::thread::sleep(policy.backoff(attempt - 1, &mut prng));
        }
        let outcome = Client::connect_with_timeout(addr, policy.timeout)
            .and_then(|mut client| {
                client.hello(PROTOCOL_V2)?;
                client.verify_opts(request, &opts, &mut on_event)
            });
        match outcome {
            Ok(Response::Error { message }) if is_retryable(&message) => {
                crate::log_debug!("attempt {attempt}/{attempts} failed: {message}");
                last = Some(ScalifyError::runtime(message));
            }
            Ok(terminal) => return Ok(terminal),
            Err(e) if is_retryable(e.message()) => {
                crate::log_debug!("attempt {attempt}/{attempts} failed: {e}");
                last = Some(e);
            }
            Err(e) => return Err(e),
        }
    }
    Err(last.unwrap_or_else(|| ScalifyError::runtime("no attempts were made")))
}

/// A connected protocol client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    timeout: Duration,
}

impl Client {
    /// Connect to a daemon at `host:port` with the
    /// [`DEFAULT_TIMEOUT`] on connect and per-request I/O.
    pub fn connect(addr: &str) -> Result<Client> {
        Client::connect_with_timeout(addr, DEFAULT_TIMEOUT)
    }

    /// Connect with an explicit timeout applied to the connect itself
    /// and to every later read/write. A zero timeout disables the
    /// bound (blocking I/O).
    pub fn connect_with_timeout(addr: &str, timeout: Duration) -> Result<Client> {
        let stream = if timeout.is_zero() {
            TcpStream::connect(addr).with_ctx(|| format!("connecting to {addr}"))?
        } else {
            let resolved = addr
                .to_socket_addrs()
                .with_ctx(|| format!("connecting to {addr}"))?
                .next()
                .ok_or_else(|| {
                    ScalifyError::runtime(format!("connecting to {addr}: no address"))
                })?;
            TcpStream::connect_timeout(&resolved, timeout).map_err(|e| {
                if e.kind() == std::io::ErrorKind::TimedOut
                    || e.kind() == std::io::ErrorKind::WouldBlock
                {
                    ScalifyError::runtime(format!(
                        "connecting to {addr}: timed out after {:.1}s",
                        timeout.as_secs_f64()
                    ))
                } else {
                    ScalifyError::from(e).context(format!("connecting to {addr}"))
                }
            })?
        };
        if !timeout.is_zero() {
            stream.set_read_timeout(Some(timeout)).ctx("configuring socket")?;
            stream.set_write_timeout(Some(timeout)).ctx("configuring socket")?;
        }
        let writer = stream.try_clone().ctx("cloning connection")?;
        Ok(Client { reader: BufReader::new(stream), writer, timeout })
    }

    /// The configured per-request I/O timeout (zero = unbounded).
    pub fn timeout(&self) -> Duration {
        self.timeout
    }

    /// Typed mapping for an I/O failure: socket-timeout kinds become a
    /// `timed out` runtime error (retryable), everything else keeps the
    /// plain I/O context.
    fn io_error(&self, e: std::io::Error, doing: &str) -> ScalifyError {
        if e.kind() == std::io::ErrorKind::TimedOut
            || e.kind() == std::io::ErrorKind::WouldBlock
        {
            ScalifyError::runtime(format!(
                "{doing}: timed out after {:.1}s",
                self.timeout.as_secs_f64()
            ))
        } else {
            ScalifyError::from(e).context(doing)
        }
    }

    /// Send one request, read one response.
    pub fn request(&mut self, request: &Request) -> Result<Response> {
        self.request_line(&request.to_line())
    }

    /// Send one raw wire line (exposed for protocol tests), read one
    /// response.
    pub fn request_line(&mut self, line: &str) -> Result<Response> {
        let mut out = line.to_string();
        out.push('\n');
        self.writer
            .write_all(out.as_bytes())
            .map_err(|e| self.io_error(e, "sending request"))?;
        self.writer.flush().map_err(|e| self.io_error(e, "sending request"))?;
        let mut buf = String::new();
        let n = self
            .reader
            .read_line(&mut buf)
            .map_err(|e| self.io_error(e, "reading response"))?;
        if n == 0 {
            return Err(ScalifyError::runtime(
                "server closed the connection before responding",
            ));
        }
        Response::from_line(buf.trim())
    }

    /// Verify a pair; unwraps the response into (report, daemon-side
    /// latency, post-request stats). A daemon-side failure (unknown
    /// model, parse error) comes back as `Err`.
    pub fn verify(
        &mut self,
        source: VerifySource,
    ) -> Result<(VerifyReport, f64, StatsSnapshot)> {
        match self.request(&Request::Verify(source))? {
            Response::VerifyDone { report, latency_secs, stats, .. } => {
                Ok((report, latency_secs, stats))
            }
            Response::Error { message } => Err(ScalifyError::runtime(message)),
            other => Err(ScalifyError::runtime(format!(
                "unexpected response to verify: {other:?}"
            ))),
        }
    }

    /// Verify a pair incrementally against a previously captured
    /// [`crate::diff::VerifyState`] document. The fourth tuple slot
    /// carries the daemon's degradation warning when the state was
    /// unusable and the run fell back to a cold verify.
    pub fn verify_diff(
        &mut self,
        source: VerifySource,
        state: Json,
    ) -> Result<(VerifyReport, f64, StatsSnapshot, Option<String>)> {
        match self.request(&Request::VerifyDiff { source, state })? {
            Response::VerifyDone { report, latency_secs, stats, warning, .. } => {
                Ok((report, latency_secs, stats, warning))
            }
            Response::Error { message } => Err(ScalifyError::runtime(message)),
            other => Err(ScalifyError::runtime(format!(
                "unexpected response to verify_diff: {other:?}"
            ))),
        }
    }

    /// Fetch the daemon counters.
    pub fn stats(&mut self) -> Result<StatsSnapshot> {
        match self.request(&Request::Stats)? {
            Response::Stats(stats) => Ok(stats),
            Response::Error { message } => Err(ScalifyError::runtime(message)),
            other => Err(ScalifyError::runtime(format!(
                "unexpected response to stats: {other:?}"
            ))),
        }
    }

    /// Fetch the daemon's metrics registry as Prometheus text
    /// exposition format.
    pub fn metrics(&mut self) -> Result<String> {
        match self.request(&Request::Metrics)? {
            Response::Metrics { prometheus } => Ok(prometheus),
            Response::Error { message } => Err(ScalifyError::runtime(message)),
            other => Err(ScalifyError::runtime(format!(
                "unexpected response to metrics: {other:?}"
            ))),
        }
    }

    /// Negotiate the connection's protocol version; returns the version
    /// the daemon settled on (`min(ours, daemon's)`, at least 1). Until
    /// this is called the connection speaks v1 and the daemon ignores
    /// every v2 request option.
    pub fn hello(&mut self, protocol: u32) -> Result<u32> {
        match self.request(&Request::Hello { protocol })? {
            Response::Hello { protocol, .. } => Ok(protocol),
            Response::Error { message } => Err(ScalifyError::runtime(message)),
            other => Err(ScalifyError::runtime(format!(
                "unexpected response to hello: {other:?}"
            ))),
        }
    }

    /// Cancel the in-flight verify carrying `id` (daemon-global — the
    /// request may have been submitted on another connection). Returns
    /// whether anything was in flight under that id.
    pub fn cancel(&mut self, id: &str) -> Result<bool> {
        match self.request(&Request::Cancel { id: id.into() })? {
            Response::CancelAck { cancelled, .. } => Ok(cancelled),
            Response::Error { message } => Err(ScalifyError::runtime(message)),
            other => Err(ScalifyError::runtime(format!(
                "unexpected response to cancel: {other:?}"
            ))),
        }
    }

    /// Send a verify/verify_diff request with v2 per-request options
    /// attached, invoke `on_event` for every streamed per-layer event
    /// line, and return the terminal response ([`Response::VerifyDone`],
    /// [`Response::Cancelled`] or [`Response::Error`]). Call
    /// [`Client::hello`] first — on a v1 connection the daemon ignores
    /// the options and streams nothing.
    pub fn verify_opts(
        &mut self,
        request: &Request,
        opts: &VerifyOpts,
        mut on_event: impl FnMut(LayerEvent),
    ) -> Result<Response> {
        let mut doc = request.to_json();
        if let Json::Obj(fields) = &mut doc {
            opts.extend_fields(fields);
        }
        let mut out = doc.render();
        out.push('\n');
        self.writer
            .write_all(out.as_bytes())
            .map_err(|e| self.io_error(e, "sending request"))?;
        self.writer.flush().map_err(|e| self.io_error(e, "sending request"))?;
        loop {
            let mut buf = String::new();
            let n = self
                .reader
                .read_line(&mut buf)
                .map_err(|e| self.io_error(e, "reading response"))?;
            if n == 0 {
                return Err(ScalifyError::runtime(
                    "server closed the connection before responding",
                ));
            }
            match Response::from_line(buf.trim())? {
                Response::Event(event) => on_event(event),
                terminal => return Ok(terminal),
            }
        }
    }

    /// Inspect or change the daemon's fault-injection registry (v2):
    /// optionally disarm everything (`clear`), optionally install a
    /// `SCALIFY_FAULTS`-syntax `spec`, and return the armed points with
    /// their evaluated/fired counters.
    pub fn faults(
        &mut self,
        spec: Option<&str>,
        clear: bool,
    ) -> Result<Vec<crate::faults::FaultStatus>> {
        let request = Request::Faults { set: spec.map(str::to_owned), clear };
        match self.request(&request)? {
            Response::Faults { faults } => Ok(faults),
            Response::Error { message } => Err(ScalifyError::runtime(message)),
            other => Err(ScalifyError::runtime(format!(
                "unexpected response to faults: {other:?}"
            ))),
        }
    }

    /// Ask the daemon to exit.
    pub fn shutdown(&mut self) -> Result<()> {
        match self.request(&Request::Shutdown)? {
            Response::ShuttingDown => Ok(()),
            Response::Error { message } => Err(ScalifyError::runtime(message)),
            other => Err(ScalifyError::runtime(format!(
                "unexpected response to shutdown: {other:?}"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retryable_classification_covers_transport_and_convention() {
        for msg in [
            "retryable: shard 0 restarted after a crashed verify job (x); retry the request",
            "reading response: timed out after 30.0s",
            "connecting to 127.0.0.1:1: connection refused",
            "server closed the connection before responding",
        ] {
            assert!(is_retryable(msg), "{msg}");
        }
        for msg in [
            "unknown model 'gpt-5'",
            "parse error: missing a limit",
            "deadline exceeded while queued",
        ] {
            assert!(!is_retryable(msg), "{msg}");
        }
    }

    #[test]
    fn backoff_grows_exponentially_and_caps() {
        let policy = RetryPolicy {
            attempts: 8,
            base_backoff: Duration::from_millis(100),
            max_backoff: Duration::from_millis(400),
            timeout: DEFAULT_TIMEOUT,
            jitter_seed: 7,
        };
        let mut prng = Prng::new(policy.jitter_seed);
        let b1 = policy.backoff(1, &mut prng);
        let b2 = policy.backoff(2, &mut prng);
        let b4 = policy.backoff(4, &mut prng);
        assert!(b1 >= Duration::from_millis(100) && b1 <= Duration::from_millis(150), "{b1:?}");
        assert!(b2 >= Duration::from_millis(200) && b2 <= Duration::from_millis(300), "{b2:?}");
        // capped: never beyond max + 50% jitter
        assert!(b4 <= Duration::from_millis(600), "{b4:?}");
        // deterministic for a fixed seed
        let mut again = Prng::new(policy.jitter_seed);
        assert_eq!(policy.backoff(1, &mut again), b1);
    }

    #[test]
    fn request_ids_are_process_unique_and_monotone() {
        let a = next_request_id();
        let b = next_request_id();
        assert_ne!(a, b);
        assert!(a.starts_with("req-"), "{a}");
    }

    #[test]
    fn connecting_to_a_dead_port_is_a_typed_retryable_error() {
        // bind-then-drop: the port was just free, so connect must fail fast
        let port = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let err = Client::connect_with_timeout(
            &format!("127.0.0.1:{port}"),
            Duration::from_millis(500),
        )
        .unwrap_err();
        assert!(is_retryable(err.message()), "{err}");
    }
}
