//! Blocking client for the `scalify serve` wire protocol.
//!
//! One TCP connection, one request line out, one response line back —
//! the `scalify client` subcommand and the integration tests both drive
//! the daemon through this type.

use super::protocol::{Request, Response, StatsSnapshot, VerifySource};
use crate::error::{Result, ResultExt, ScalifyError};
use crate::report::json::Json;
use crate::verifier::VerifyReport;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// A connected protocol client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Connect to a daemon at `host:port`.
    pub fn connect(addr: &str) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).with_ctx(|| format!("connecting to {addr}"))?;
        let writer = stream.try_clone().ctx("cloning connection")?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one request, read one response.
    pub fn request(&mut self, request: &Request) -> Result<Response> {
        self.request_line(&request.to_line())
    }

    /// Send one raw wire line (exposed for protocol tests), read one
    /// response.
    pub fn request_line(&mut self, line: &str) -> Result<Response> {
        let mut out = line.to_string();
        out.push('\n');
        self.writer.write_all(out.as_bytes()).ctx("sending request")?;
        self.writer.flush().ctx("sending request")?;
        let mut buf = String::new();
        let n = self.reader.read_line(&mut buf).ctx("reading response")?;
        if n == 0 {
            return Err(ScalifyError::runtime(
                "server closed the connection before responding",
            ));
        }
        Response::from_line(buf.trim())
    }

    /// Verify a pair; unwraps the response into (report, daemon-side
    /// latency, post-request stats). A daemon-side failure (unknown
    /// model, parse error) comes back as `Err`.
    pub fn verify(
        &mut self,
        source: VerifySource,
    ) -> Result<(VerifyReport, f64, StatsSnapshot)> {
        match self.request(&Request::Verify(source))? {
            Response::VerifyDone { report, latency_secs, stats, .. } => {
                Ok((report, latency_secs, stats))
            }
            Response::Error { message } => Err(ScalifyError::runtime(message)),
            other => Err(ScalifyError::runtime(format!(
                "unexpected response to verify: {other:?}"
            ))),
        }
    }

    /// Verify a pair incrementally against a previously captured
    /// [`crate::diff::VerifyState`] document. The fourth tuple slot
    /// carries the daemon's degradation warning when the state was
    /// unusable and the run fell back to a cold verify.
    pub fn verify_diff(
        &mut self,
        source: VerifySource,
        state: Json,
    ) -> Result<(VerifyReport, f64, StatsSnapshot, Option<String>)> {
        match self.request(&Request::VerifyDiff { source, state })? {
            Response::VerifyDone { report, latency_secs, stats, warning } => {
                Ok((report, latency_secs, stats, warning))
            }
            Response::Error { message } => Err(ScalifyError::runtime(message)),
            other => Err(ScalifyError::runtime(format!(
                "unexpected response to verify_diff: {other:?}"
            ))),
        }
    }

    /// Fetch the daemon counters.
    pub fn stats(&mut self) -> Result<StatsSnapshot> {
        match self.request(&Request::Stats)? {
            Response::Stats(stats) => Ok(stats),
            Response::Error { message } => Err(ScalifyError::runtime(message)),
            other => Err(ScalifyError::runtime(format!(
                "unexpected response to stats: {other:?}"
            ))),
        }
    }

    /// Fetch the daemon's metrics registry as Prometheus text
    /// exposition format.
    pub fn metrics(&mut self) -> Result<String> {
        match self.request(&Request::Metrics)? {
            Response::Metrics { prometheus } => Ok(prometheus),
            Response::Error { message } => Err(ScalifyError::runtime(message)),
            other => Err(ScalifyError::runtime(format!(
                "unexpected response to metrics: {other:?}"
            ))),
        }
    }

    /// Ask the daemon to exit.
    pub fn shutdown(&mut self) -> Result<()> {
        match self.request(&Request::Shutdown)? {
            Response::ShuttingDown => Ok(()),
            Response::Error { message } => Err(ScalifyError::runtime(message)),
            other => Err(ScalifyError::runtime(format!(
                "unexpected response to shutdown: {other:?}"
            ))),
        }
    }
}
