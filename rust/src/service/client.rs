//! Blocking client for the `scalify serve` wire protocol.
//!
//! One TCP connection, one request line out, one response line back —
//! the `scalify client` subcommand and the integration tests both drive
//! the daemon through this type. After a [`Client::hello`] negotiation
//! to protocol v2, [`Client::verify_opts`] can attach ids, priorities
//! and deadlines and consume streamed per-layer events; the normative
//! wire reference lives in `docs/PROTOCOL.md`.

use super::protocol::{
    LayerEvent, Request, Response, StatsSnapshot, VerifyOpts, VerifySource,
};
use crate::error::{Result, ResultExt, ScalifyError};
use crate::report::json::Json;
use crate::verifier::VerifyReport;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

/// A connected protocol client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Connect to a daemon at `host:port`.
    pub fn connect(addr: &str) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).with_ctx(|| format!("connecting to {addr}"))?;
        let writer = stream.try_clone().ctx("cloning connection")?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one request, read one response.
    pub fn request(&mut self, request: &Request) -> Result<Response> {
        self.request_line(&request.to_line())
    }

    /// Send one raw wire line (exposed for protocol tests), read one
    /// response.
    pub fn request_line(&mut self, line: &str) -> Result<Response> {
        let mut out = line.to_string();
        out.push('\n');
        self.writer.write_all(out.as_bytes()).ctx("sending request")?;
        self.writer.flush().ctx("sending request")?;
        let mut buf = String::new();
        let n = self.reader.read_line(&mut buf).ctx("reading response")?;
        if n == 0 {
            return Err(ScalifyError::runtime(
                "server closed the connection before responding",
            ));
        }
        Response::from_line(buf.trim())
    }

    /// Verify a pair; unwraps the response into (report, daemon-side
    /// latency, post-request stats). A daemon-side failure (unknown
    /// model, parse error) comes back as `Err`.
    pub fn verify(
        &mut self,
        source: VerifySource,
    ) -> Result<(VerifyReport, f64, StatsSnapshot)> {
        match self.request(&Request::Verify(source))? {
            Response::VerifyDone { report, latency_secs, stats, .. } => {
                Ok((report, latency_secs, stats))
            }
            Response::Error { message } => Err(ScalifyError::runtime(message)),
            other => Err(ScalifyError::runtime(format!(
                "unexpected response to verify: {other:?}"
            ))),
        }
    }

    /// Verify a pair incrementally against a previously captured
    /// [`crate::diff::VerifyState`] document. The fourth tuple slot
    /// carries the daemon's degradation warning when the state was
    /// unusable and the run fell back to a cold verify.
    pub fn verify_diff(
        &mut self,
        source: VerifySource,
        state: Json,
    ) -> Result<(VerifyReport, f64, StatsSnapshot, Option<String>)> {
        match self.request(&Request::VerifyDiff { source, state })? {
            Response::VerifyDone { report, latency_secs, stats, warning, .. } => {
                Ok((report, latency_secs, stats, warning))
            }
            Response::Error { message } => Err(ScalifyError::runtime(message)),
            other => Err(ScalifyError::runtime(format!(
                "unexpected response to verify_diff: {other:?}"
            ))),
        }
    }

    /// Fetch the daemon counters.
    pub fn stats(&mut self) -> Result<StatsSnapshot> {
        match self.request(&Request::Stats)? {
            Response::Stats(stats) => Ok(stats),
            Response::Error { message } => Err(ScalifyError::runtime(message)),
            other => Err(ScalifyError::runtime(format!(
                "unexpected response to stats: {other:?}"
            ))),
        }
    }

    /// Fetch the daemon's metrics registry as Prometheus text
    /// exposition format.
    pub fn metrics(&mut self) -> Result<String> {
        match self.request(&Request::Metrics)? {
            Response::Metrics { prometheus } => Ok(prometheus),
            Response::Error { message } => Err(ScalifyError::runtime(message)),
            other => Err(ScalifyError::runtime(format!(
                "unexpected response to metrics: {other:?}"
            ))),
        }
    }

    /// Negotiate the connection's protocol version; returns the version
    /// the daemon settled on (`min(ours, daemon's)`, at least 1). Until
    /// this is called the connection speaks v1 and the daemon ignores
    /// every v2 request option.
    pub fn hello(&mut self, protocol: u32) -> Result<u32> {
        match self.request(&Request::Hello { protocol })? {
            Response::Hello { protocol, .. } => Ok(protocol),
            Response::Error { message } => Err(ScalifyError::runtime(message)),
            other => Err(ScalifyError::runtime(format!(
                "unexpected response to hello: {other:?}"
            ))),
        }
    }

    /// Cancel the in-flight verify carrying `id` (daemon-global — the
    /// request may have been submitted on another connection). Returns
    /// whether anything was in flight under that id.
    pub fn cancel(&mut self, id: &str) -> Result<bool> {
        match self.request(&Request::Cancel { id: id.into() })? {
            Response::CancelAck { cancelled, .. } => Ok(cancelled),
            Response::Error { message } => Err(ScalifyError::runtime(message)),
            other => Err(ScalifyError::runtime(format!(
                "unexpected response to cancel: {other:?}"
            ))),
        }
    }

    /// Send a verify/verify_diff request with v2 per-request options
    /// attached, invoke `on_event` for every streamed per-layer event
    /// line, and return the terminal response ([`Response::VerifyDone`],
    /// [`Response::Cancelled`] or [`Response::Error`]). Call
    /// [`Client::hello`] first — on a v1 connection the daemon ignores
    /// the options and streams nothing.
    pub fn verify_opts(
        &mut self,
        request: &Request,
        opts: &VerifyOpts,
        mut on_event: impl FnMut(LayerEvent),
    ) -> Result<Response> {
        let mut doc = request.to_json();
        if let Json::Obj(fields) = &mut doc {
            opts.extend_fields(fields);
        }
        let mut out = doc.render();
        out.push('\n');
        self.writer.write_all(out.as_bytes()).ctx("sending request")?;
        self.writer.flush().ctx("sending request")?;
        loop {
            let mut buf = String::new();
            let n = self.reader.read_line(&mut buf).ctx("reading response")?;
            if n == 0 {
                return Err(ScalifyError::runtime(
                    "server closed the connection before responding",
                ));
            }
            match Response::from_line(buf.trim())? {
                Response::Event(event) => on_event(event),
                terminal => return Ok(terminal),
            }
        }
    }

    /// Ask the daemon to exit.
    pub fn shutdown(&mut self) -> Result<()> {
        match self.request(&Request::Shutdown)? {
            Response::ShuttingDown => Ok(()),
            Response::Error { message } => Err(ScalifyError::runtime(message)),
            other => Err(ScalifyError::runtime(format!(
                "unexpected response to shutdown: {other:?}"
            ))),
        }
    }
}
