//! Session shard pool: N independent verification engines behind one
//! daemon, with per-shard supervision.
//!
//! A single [`Session`] serializes unrelated requests on one memo lock
//! and mixes every model family's layer fingerprints into one LRU. The
//! [`ShardPool`] runs `N` sessions side by side and routes each request
//! by a **model-family key** (model name, bug-corpus id, or a hash of
//! the HLO text — see the server's routing), so requests for the same
//! family always land on the same shard and keep hitting its warm memo,
//! while unrelated families stop contending entirely.
//!
//! All shards share one compiled rewrite-template set
//! ([`Session::with_rules`]); each owns its own memo, worker pool,
//! request counter and latency histogram. Per-shard latency histograms
//! roll up into the global percentiles via
//! [`crate::obs::metrics::merged_quantile`], and render as labeled
//! Prometheus series next to the unlabeled aggregate.
//!
//! **Supervision:** a verify job that panics may leave its shard's
//! session poisoned (a worker died holding the memo lock, a half-built
//! e-graph, …). The server calls [`ShardPool::restart_shard`], which
//! marks the shard unhealthy, builds a fresh [`Session`] against the
//! shared rule set, warms it from the persistent segment cache, and
//! swaps it in. While a shard is restarting, [`ShardPool::index_for`]
//! probes forward to the next healthy sibling so new traffic keeps
//! flowing; in-flight jobs on the old session keep their own
//! [`Arc<Session>`] and finish (or fail) independently.
//!
//! With `N = 1` (the default) the pool is behaviorally identical to the
//! pre-fleet single-session daemon.

use super::protocol::ShardStat;
use crate::egraph::RuleSet;
use crate::obs::{self, Histogram};
use crate::partition::MemoEntry;
use crate::verifier::{MemoWriteHook, Session, SessionStats, VerifyConfig};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// One shard: a session plus its routing-level counters.
pub struct Shard {
    /// Swapped wholesale on supervisor restart; jobs clone the `Arc` at
    /// admission and are unaffected by a mid-flight swap.
    session: RwLock<Arc<Session>>,
    /// Requests routed to this shard.
    pub jobs: AtomicU64,
    /// Per-shard request latencies (merged for the global percentiles).
    pub latency: Histogram,
    /// Supervisor restarts of this shard.
    pub restarts: AtomicU64,
    healthy: AtomicBool,
}

impl Shard {
    /// The shard's verification engine (a clone of the current `Arc`;
    /// stable for the caller even across a concurrent restart).
    pub fn session(&self) -> Arc<Session> {
        Arc::clone(&self.session.read().unwrap_or_else(|p| p.into_inner()))
    }

    /// False only during a supervisor restart.
    pub fn healthy(&self) -> bool {
        self.healthy.load(Ordering::SeqCst)
    }
}

/// Fixed pool of [`Session`] shards; see the module docs.
pub struct ShardPool {
    shards: Vec<Shard>,
    // what restart_shard needs to rebuild a session in place
    cfg: VerifyConfig,
    rules: Arc<RuleSet>,
    hook: Option<MemoWriteHook>,
}

impl ShardPool {
    /// Build `n` shards (clamped to at least 1) sharing one compiled
    /// rule set. When a memo-write hook is given, every shard gets a
    /// clone — the persistent cache is daemon-global, so a fingerprint
    /// verified by any shard survives restarts for all of them.
    pub fn new(cfg: &VerifyConfig, n: usize, hook: Option<MemoWriteHook>) -> ShardPool {
        let n = n.max(1);
        let rules = Arc::new(RuleSet::compile());
        let shards = (0..n)
            .map(|_| Shard {
                session: RwLock::new(Arc::new(build_session(cfg, &rules, &hook))),
                jobs: AtomicU64::new(0),
                latency: Histogram::new(obs::LATENCY_BUCKETS),
                restarts: AtomicU64::new(0),
                healthy: AtomicBool::new(true),
            })
            .collect();
        ShardPool { shards, cfg: cfg.clone(), rules, hook }
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    /// Always false — the pool holds at least one shard.
    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// Stable routing: the shard index for a model-family key. The same
    /// key always routes to the same shard, so repeat requests for a
    /// family keep hitting that shard's warm memo — except while that
    /// shard is mid-restart, when the key probes forward to the next
    /// healthy sibling (losing memo locality beats losing the request).
    pub fn index_for(&self, key: &str) -> usize {
        let n = self.shards.len();
        let home = (fnv1a(key.as_bytes()) % n as u64) as usize;
        for probe in 0..n {
            let i = (home + probe) % n;
            if self.shards[i].healthy() {
                return i;
            }
        }
        home
    }

    /// The shard a model-family key routes to.
    pub fn shard_for(&self, key: &str) -> &Shard {
        &self.shards[self.index_for(key)]
    }

    /// Shard by index (for iteration/rendering).
    pub fn shard(&self, idx: usize) -> &Shard {
        &self.shards[idx]
    }

    /// Iterate over all shards in index order.
    pub fn iter(&self) -> impl Iterator<Item = &Shard> {
        self.shards.iter()
    }

    /// Supervisor restart: replace shard `idx`'s session with a fresh
    /// one (shared rule set, same memo-write hook) warm-started from
    /// `warm` — normally the persistent cache's current entries. The
    /// shard is unhealthy (siblings absorb its traffic) only for the
    /// duration of the rebuild. Returns the number of entries preloaded.
    pub fn restart_shard(&self, idx: usize, warm: &[(u64, MemoEntry)]) -> usize {
        let shard = &self.shards[idx];
        shard.healthy.store(false, Ordering::SeqCst);
        let session = build_session(&self.cfg, &self.rules, &self.hook);
        let loaded = session.preload_memo(warm.iter().cloned());
        *shard.session.write().unwrap_or_else(|p| p.into_inner()) = Arc::new(session);
        shard.restarts.fetch_add(1, Ordering::SeqCst);
        shard.healthy.store(true, Ordering::SeqCst);
        obs::metrics::count("scalify_shard_restarts_total", 1);
        loaded
    }

    /// Total supervisor restarts across all shards.
    pub fn restarts_total(&self) -> u64 {
        self.shards.iter().map(|s| s.restarts.load(Ordering::SeqCst)).sum()
    }

    /// Warm-start **every** shard from persisted cache entries: routing
    /// is by request key, not fingerprint, so any shard may be asked
    /// about any persisted layer. Returns the number of distinct entries
    /// loaded (not multiplied by the shard count).
    pub fn preload_memo(&self, entries: &[(u64, MemoEntry)]) -> usize {
        for shard in &self.shards {
            shard.session().preload_memo(entries.iter().cloned());
        }
        entries.len()
    }

    /// Session statistics rolled up across shards: counters sum,
    /// `templates` is the shared rule-set size, `threads` sums the
    /// per-shard worker pools.
    pub fn stats(&self) -> SessionStats {
        let mut total = SessionStats::default();
        for (i, shard) in self.shards.iter().enumerate() {
            let s = shard.session().stats();
            if i == 0 {
                total.templates = s.templates;
            }
            total.runs += s.runs;
            total.memo_entries += s.memo_entries;
            total.memo_hits += s.memo_hits;
            total.memo_misses += s.memo_misses;
            total.memo_evictions += s.memo_evictions;
            total.threads += s.threads;
        }
        total
    }

    /// Per-shard wire snapshot (the v2 `stats` extension).
    pub fn shard_stats(&self) -> Vec<ShardStat> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                let s = shard.session().stats();
                ShardStat {
                    shard: i as u64,
                    jobs: shard.jobs.load(Ordering::Relaxed),
                    runs: s.runs as u64,
                    memo_entries: s.memo_entries as u64,
                    memo_hits: s.memo_hits as u64,
                    memo_misses: s.memo_misses as u64,
                    latency_p50_secs: shard.latency.quantile(0.50),
                    latency_p95_secs: shard.latency.quantile(0.95),
                }
            })
            .collect()
    }

    /// Global latency quantile merged across all shard histograms
    /// (exactly 0.0 on a fresh daemon — see
    /// [`crate::obs::metrics::merged_quantile`]).
    pub fn latency_quantile(&self, q: f64) -> f64 {
        let hists: Vec<&Histogram> = self.shards.iter().map(|s| &s.latency).collect();
        obs::metrics::merged_quantile(&hists, q)
    }

    /// Largest latency observed by any shard (0.0 when idle).
    pub fn latency_max(&self) -> f64 {
        let hists: Vec<&Histogram> = self.shards.iter().map(|s| &s.latency).collect();
        obs::metrics::merged_max(&hists)
    }
}

fn build_session(
    cfg: &VerifyConfig,
    rules: &Arc<RuleSet>,
    hook: &Option<MemoWriteHook>,
) -> Session {
    let mut session = Session::with_rules(cfg.clone(), Arc::clone(rules));
    if let Some(h) = hook {
        session.set_memo_write_hook(Arc::clone(h));
    }
    session
}

/// FNV-1a over the routing key — stable across runs and platforms, so
/// shard placement (and therefore memo locality) is deterministic.
fn fnv1a(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> VerifyConfig {
        VerifyConfig::builder().threads(1).build().expect("valid config")
    }

    #[test]
    fn routing_is_deterministic_and_in_range() {
        let pool = ShardPool::new(&tiny_cfg(), 4, None);
        for key in ["llama-tiny", "mixtral-tiny", "T4#1", "hlo:deadbeef"] {
            let i = pool.index_for(key);
            assert!(i < pool.len());
            assert_eq!(i, pool.index_for(key), "same key must route to the same shard");
        }
        assert_eq!(pool.len(), 4);
    }

    #[test]
    fn shards_share_one_compiled_rule_set() {
        let pool = ShardPool::new(&tiny_cfg(), 3, None);
        let s0 = pool.shard(0).session();
        let first = s0.rules();
        for i in 1..pool.len() {
            let si = pool.shard(i).session();
            assert!(
                Arc::ptr_eq(first, si.rules()),
                "shard {i} compiled its own rule set"
            );
        }
    }

    #[test]
    fn zero_shards_clamps_to_one() {
        let pool = ShardPool::new(&tiny_cfg(), 0, None);
        assert_eq!(pool.len(), 1);
        assert_eq!(pool.index_for("anything"), 0);
    }

    #[test]
    fn rollup_sums_counters_and_keeps_shared_template_count() {
        let pool = ShardPool::new(&tiny_cfg(), 2, None);
        let per_shard = pool.shard(0).session().stats();
        let total = pool.stats();
        assert_eq!(total.templates, per_shard.templates);
        assert_eq!(total.runs, 0);
        assert_eq!(total.memo_entries, 0);
        let stats = pool.shard_stats();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].shard, 0);
        assert_eq!(stats[1].shard, 1);
        assert_eq!(stats[0].latency_p50_secs, 0.0, "fresh shard percentiles must be 0");
    }

    #[test]
    fn fresh_pool_merged_latency_is_exactly_zero() {
        let pool = ShardPool::new(&tiny_cfg(), 3, None);
        assert_eq!(pool.latency_quantile(0.50), 0.0);
        assert_eq!(pool.latency_quantile(0.95), 0.0);
        assert_eq!(pool.latency_max(), 0.0);
    }

    #[test]
    fn restart_swaps_the_session_and_keeps_the_shared_rules() {
        let pool = ShardPool::new(&tiny_cfg(), 2, None);
        let before = pool.shard(1).session();
        assert_eq!(pool.restart_shard(1, &[]), 0);
        let after = pool.shard(1).session();
        assert!(!Arc::ptr_eq(&before, &after), "restart must swap the session");
        assert!(Arc::ptr_eq(before.rules(), after.rules()), "rules stay shared");
        assert_eq!(pool.shard(1).restarts.load(Ordering::SeqCst), 1);
        assert_eq!(pool.restarts_total(), 1);
        assert!(pool.shard(1).healthy(), "restart must end healthy");
    }

    #[test]
    fn unhealthy_shards_route_to_the_next_healthy_sibling() {
        let pool = ShardPool::new(&tiny_cfg(), 3, None);
        let key = "llama-tiny";
        let home = pool.index_for(key);
        pool.shards[home].healthy.store(false, Ordering::SeqCst);
        let rerouted = pool.index_for(key);
        assert_ne!(rerouted, home, "unhealthy home shard must be skipped");
        assert!(pool.shards[rerouted].healthy());
        pool.shards[home].healthy.store(true, Ordering::SeqCst);
        assert_eq!(pool.index_for(key), home, "healthy home shard routes again");
    }

    #[test]
    fn restart_preloads_the_warm_entries() {
        let pool = ShardPool::new(&tiny_cfg(), 1, None);
        let warm = vec![(
            0xfeed_beef_u64,
            MemoEntry {
                verified: true,
                out_rels: vec![],
                egraph_nodes: 3,
                egraph_classes: 2,
            },
        )];
        assert_eq!(pool.restart_shard(0, &warm), 1);
        assert_eq!(pool.shard(0).session().stats().memo_entries, 1);
    }
}
