//! The newline-delimited JSON wire protocol of `scalify serve`.
//!
//! One request per line, one response per line (plus, on v2 streaming
//! connections, zero or more event lines before the terminal response),
//! all single JSON documents rendered compactly (no embedded newlines).
//! The baseline (v1) request kinds:
//!
//! ```text
//! {"cmd":"verify","model":"llama-tiny","par":"tp4","layers":2}
//! {"cmd":"verify","bug":"T4#3"}
//! {"cmd":"verify","base_hlo":"HloModule ...","dist_hlo":"HloModule ...","cores":4}
//! {"cmd":"verify_diff","model":"llama-tiny","par":"tp2","state":{...}}
//! {"cmd":"stats"}
//! {"cmd":"shutdown"}
//! ```
//!
//! Responses carry `"ok"` plus a `"kind"` discriminator; verify responses
//! embed the full [`VerifyReport`] JSON (the same document `--json`
//! prints) and a [`StatsSnapshot`] taken after the request, so a client
//! can watch memo hits grow without a second round trip. Every error —
//! malformed request, unknown model, failed parse — is `{"ok":false,
//! "error":...}`; the connection stays usable afterwards.
//!
//! **Protocol v2** is negotiated per connection with a `hello` exchange
//! (`{"cmd":"hello","protocol":2}` → `{"ok":true,"kind":"hello",
//! "protocol":2,...}`); a connection that never says hello speaks v1 and
//! gets byte-identical v1 responses. v2 adds per-request options on
//! `verify`/`verify_diff` ([`VerifyOpts`]: `id`, `priority`,
//! `deadline_secs`, `stream`), per-layer progress events
//! ([`LayerEvent`], streamed before the terminal response when
//! `"stream":true`), cancellation (`{"cmd":"cancel","id":...}` and
//! superseded-request abort — reusing an `id` cancels the in-flight
//! request carrying it), and per-shard detail in [`StatsSnapshot`].
//!
//! The normative wire reference — every field of every request and
//! response, negotiation, and the error/exit-code contract — lives in
//! `docs/PROTOCOL.md` at the repository root.

use crate::error::{Result, ScalifyError};
use crate::report::json::Json;
use crate::verifier::VerifyReport;

/// Baseline wire protocol version, included in stats responses so
/// mixed-version fleets can detect skew. Connections speak v1 until
/// they negotiate higher with a `hello` request.
pub const PROTOCOL_VERSION: u32 = 1;

/// The streaming protocol revision (progress events, priorities,
/// deadlines, cancellation, per-shard stats). The highest version this
/// build can negotiate.
pub const PROTOCOL_V2: u32 = 2;

/// What a `verify` request asks the daemon to check.
#[derive(Clone, Debug, PartialEq)]
pub enum VerifySource {
    /// A model-zoo pair by name + parallelism spec (`llama-tiny` / `tp4`).
    Model {
        /// Zoo model name (see `scalify model`).
        model: String,
        /// Parallelism spec (`tp4`, `pp2tp4`, `dp4z1`, ...).
        par: String,
        /// Optional layer-count override.
        layers: Option<u32>,
        /// Optional scripted one-op edit: bump every constant in this
        /// layer on both sides before verifying (the CI vehicle for
        /// exercising `verify_diff` — HLO text loses layer tags, so the
        /// zoo-model path carries the edit).
        edit_layer: Option<u32>,
    },
    /// A bug-corpus case by id (`T4#3`, `PT#1`, ...) — always expected to
    /// come back unverified; used for smoke checks and tests.
    Bug {
        /// Catalog id.
        id: String,
    },
    /// An inline HLO-text pair (positional replicated annotations, like
    /// `scalify verify` on files).
    Hlo {
        /// Baseline module text.
        base: String,
        /// Distributed module text.
        dist: String,
        /// SPMD width of the distributed module.
        cores: u32,
    },
}

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Verify a pair.
    Verify(VerifySource),
    /// Verify a pair incrementally against a previous run's persisted
    /// [`crate::diff::VerifyState`] (embedded as a JSON object). A state
    /// that fails to decode or names a different graph degrades to a
    /// cold verify with a warning in the response — never an error.
    VerifyDiff {
        /// What to verify.
        source: VerifySource,
        /// The `VerifyState` document from a previous `--emit-state` run.
        state: Json,
    },
    /// Report service counters.
    Stats,
    /// Report the full metrics registry in Prometheus text exposition
    /// format (counters, gauges, and the request-latency histogram).
    Metrics,
    /// Stop accepting connections and exit.
    Shutdown,
    /// Negotiate the connection's protocol version (v2+). The daemon
    /// answers with its own version; the connection then speaks
    /// `min(client, server)`.
    Hello {
        /// Highest protocol version the client speaks.
        protocol: u32,
    },
    /// Cancel the in-flight verify carrying this request id (v2). The
    /// id is daemon-global, so a cancel may arrive on a different
    /// connection than the request it targets.
    Cancel {
        /// The `id` the verify request was submitted with.
        id: String,
    },
    /// Inspect or change the daemon's fault-injection registry (v2,
    /// chaos testing). With neither `set` nor `clear` this just lists
    /// the armed points and their evaluated/fired counters.
    Faults {
        /// `SCALIFY_FAULTS`-syntax spec to install (`point:kind:rate:seed`,
        /// comma separated), merged over the armed points.
        set: Option<String>,
        /// Disarm every point first.
        clear: bool,
    },
}

impl Request {
    /// JSON encoding.
    pub fn to_json(&self) -> Json {
        match self {
            Request::Verify(source) => {
                let mut fields = vec![("cmd".into(), Json::Str("verify".into()))];
                fields.extend(source_fields(source));
                Json::Obj(fields)
            }
            Request::VerifyDiff { source, state } => {
                let mut fields = vec![("cmd".into(), Json::Str("verify_diff".into()))];
                fields.extend(source_fields(source));
                fields.push(("state".into(), state.clone()));
                Json::Obj(fields)
            }
            Request::Stats => Json::Obj(vec![("cmd".into(), Json::Str("stats".into()))]),
            Request::Metrics => {
                Json::Obj(vec![("cmd".into(), Json::Str("metrics".into()))])
            }
            Request::Shutdown => {
                Json::Obj(vec![("cmd".into(), Json::Str("shutdown".into()))])
            }
            Request::Hello { protocol } => Json::Obj(vec![
                ("cmd".into(), Json::Str("hello".into())),
                ("protocol".into(), Json::Num(*protocol as f64)),
            ]),
            Request::Cancel { id } => Json::Obj(vec![
                ("cmd".into(), Json::Str("cancel".into())),
                ("id".into(), Json::Str(id.clone())),
            ]),
            Request::Faults { set, clear } => {
                let mut fields = vec![("cmd".into(), Json::Str("faults".into()))];
                if let Some(spec) = set {
                    fields.push(("set".into(), Json::Str(spec.clone())));
                }
                if *clear {
                    fields.push(("clear".into(), Json::Bool(true)));
                }
                Json::Obj(fields)
            }
        }
    }

    /// One compact wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().render()
    }

    /// Decode a request document.
    pub fn from_json(doc: &Json) -> Result<Request> {
        let cmd = doc
            .str_at("cmd")
            .ok_or_else(|| ScalifyError::parse("request is missing string field 'cmd'"))?;
        match cmd {
            "stats" => Ok(Request::Stats),
            "metrics" => Ok(Request::Metrics),
            "shutdown" => Ok(Request::Shutdown),
            "verify" => Ok(Request::Verify(decode_source(doc)?)),
            "verify_diff" => {
                let state = doc
                    .get("state")
                    .ok_or_else(|| {
                        ScalifyError::parse(
                            "verify_diff request is missing the 'state' object",
                        )
                    })?
                    .clone();
                Ok(Request::VerifyDiff { source: decode_source(doc)?, state })
            }
            "hello" => {
                let protocol = doc.u64_at("protocol").ok_or_else(|| {
                    ScalifyError::parse("hello request is missing integer 'protocol'")
                })?;
                if protocol == 0 || protocol > u32::MAX as u64 {
                    return Err(ScalifyError::parse("'protocol' must be in 1..=u32::MAX"));
                }
                Ok(Request::Hello { protocol: protocol as u32 })
            }
            "cancel" => {
                let id = doc.str_at("id").ok_or_else(|| {
                    ScalifyError::parse("cancel request is missing string 'id'")
                })?;
                Ok(Request::Cancel { id: id.to_string() })
            }
            "faults" => Ok(Request::Faults {
                set: doc.str_at("set").map(str::to_owned),
                clear: doc.bool_at("clear").unwrap_or(false),
            }),
            other => Err(ScalifyError::parse(format!(
                "unknown request cmd '{other}' (expected verify, verify_diff, stats, \
                 metrics, shutdown, hello, cancel or faults)"
            ))),
        }
    }

    /// Decode one wire line.
    pub fn from_line(line: &str) -> Result<Request> {
        Request::from_json(&Json::parse(line)?)
    }
}

/// The source-describing fields of a verify/verify_diff request (shared
/// by both encodings; `cmd` and `state` are the caller's).
fn source_fields(source: &VerifySource) -> Vec<(String, Json)> {
    match source {
        VerifySource::Model { model, par, layers, edit_layer } => {
            let mut fields = vec![
                ("model".into(), Json::Str(model.clone())),
                ("par".into(), Json::Str(par.clone())),
            ];
            if let Some(l) = layers {
                fields.push(("layers".into(), Json::Num(*l as f64)));
            }
            if let Some(l) = edit_layer {
                fields.push(("edit_layer".into(), Json::Num(*l as f64)));
            }
            fields
        }
        VerifySource::Bug { id } => vec![("bug".into(), Json::Str(id.clone()))],
        VerifySource::Hlo { base, dist, cores } => vec![
            ("base_hlo".into(), Json::Str(base.clone())),
            ("dist_hlo".into(), Json::Str(dist.clone())),
            ("cores".into(), Json::Num(*cores as f64)),
        ],
    }
}

fn decode_source(doc: &Json) -> Result<VerifySource> {
    if let Some(id) = doc.str_at("bug") {
        return Ok(VerifySource::Bug { id: id.to_string() });
    }
    if let Some(model) = doc.str_at("model") {
        let par = doc
            .str_at("par")
            .ok_or_else(|| ScalifyError::parse("verify-by-model needs a 'par' spec"))?;
        let opt_u32 = |key: &str| -> Result<Option<u32>> {
            match doc.get(key) {
                None | Some(Json::Null) => Ok(None),
                Some(v) => {
                    let n = v.as_u64().ok_or_else(|| {
                        ScalifyError::parse(format!(
                            "'{key}' must be a non-negative integer"
                        ))
                    })?;
                    if n > u32::MAX as u64 {
                        return Err(ScalifyError::parse(format!(
                            "'{key}' must fit in u32"
                        )));
                    }
                    Ok(Some(n as u32))
                }
            }
        };
        return Ok(VerifySource::Model {
            model: model.to_string(),
            par: par.to_string(),
            layers: opt_u32("layers")?,
            edit_layer: opt_u32("edit_layer")?,
        });
    }
    if let Some(base) = doc.str_at("base_hlo") {
        let dist = doc.str_at("dist_hlo").ok_or_else(|| {
            ScalifyError::parse("inline verify needs both 'base_hlo' and 'dist_hlo'")
        })?;
        let cores = doc.u64_at("cores").unwrap_or(1);
        if cores == 0 || cores > u32::MAX as u64 {
            return Err(ScalifyError::parse("'cores' must be in 1..=u32::MAX"));
        }
        return Ok(VerifySource::Hlo {
            base: base.to_string(),
            dist: dist.to_string(),
            cores: cores as u32,
        });
    }
    Err(ScalifyError::parse(
        "verify request names no source (expected 'model'+'par', 'bug', or \
         'base_hlo'+'dist_hlo')",
    ))
}

/// Per-request options a v2 client may attach to `verify`/`verify_diff`.
///
/// They ride as extra top-level fields on the request document —
/// [`Request::from_json`] ignores unknown fields, which is exactly why a
/// v1 daemon silently ignores them instead of erroring. The v2 daemon
/// parses them separately with [`VerifyOpts::from_json`]; on a v1
/// connection they are not parsed at all.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct VerifyOpts {
    /// Client-chosen request id: names the request for `cancel` and for
    /// event correlation. Submitting a new request with an id already
    /// in flight cancels the older request (superseded-request abort).
    pub id: Option<String>,
    /// Scheduler priority; higher runs first when the queue is
    /// contended. Default 0 (FIFO among equals).
    pub priority: i64,
    /// Optional deadline: the request is abandoned (typed error) if it
    /// is still queued or verifying this many seconds after arrival.
    pub deadline_secs: Option<f64>,
    /// Stream per-layer [`LayerEvent`] lines before the terminal
    /// response.
    pub stream: bool,
}

impl VerifyOpts {
    /// Parse the v2 options off a verify/verify_diff document.
    pub fn from_json(doc: &Json) -> Result<VerifyOpts> {
        let priority = match doc.get("priority") {
            None | Some(Json::Null) => 0,
            Some(v) => v
                .as_f64()
                .filter(|p| p.fract() == 0.0 && p.abs() <= i64::MAX as f64)
                .ok_or_else(|| ScalifyError::parse("'priority' must be an integer"))?
                as i64,
        };
        let deadline_secs = match doc.get("deadline_secs") {
            None | Some(Json::Null) => None,
            Some(v) => {
                let secs = v.as_f64().filter(|s| *s > 0.0).ok_or_else(|| {
                    ScalifyError::parse("'deadline_secs' must be a positive number")
                })?;
                Some(secs)
            }
        };
        Ok(VerifyOpts {
            id: doc.str_at("id").map(str::to_owned),
            priority,
            deadline_secs,
            stream: doc.bool_at("stream").unwrap_or(false),
        })
    }

    /// Append the non-default options onto a request's field list (the
    /// encoding side of [`VerifyOpts::from_json`]).
    pub fn extend_fields(&self, fields: &mut Vec<(String, Json)>) {
        if let Some(id) = &self.id {
            fields.push(("id".into(), Json::Str(id.clone())));
        }
        if self.priority != 0 {
            fields.push(("priority".into(), Json::Num(self.priority as f64)));
        }
        if let Some(d) = self.deadline_secs {
            fields.push(("deadline_secs".into(), Json::Num(d)));
        }
        if self.stream {
            fields.push(("stream".into(), Json::Bool(true)));
        }
    }
}

/// One per-layer progress event, streamed on v2 connections that asked
/// for `"stream":true` — one line per completed layer, before the
/// terminal verify response.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerEvent {
    /// The request id, when the request carried one.
    pub id: Option<String>,
    /// Layer tag.
    pub layer: u32,
    /// Zero-based position in assembly order.
    pub index: u64,
    /// Total layers in the verify.
    pub total: u64,
    /// Whether this layer verified.
    pub verified: bool,
}

/// Per-shard counters (the v2 extension of [`StatsSnapshot`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardStat {
    /// Shard index (0-based).
    pub shard: u64,
    /// Requests routed to this shard.
    pub jobs: u64,
    /// `Session::verify` calls on this shard.
    pub runs: u64,
    /// Distinct memo fingerprints held by this shard.
    pub memo_entries: u64,
    /// Layer verifications served from this shard's memo.
    pub memo_hits: u64,
    /// Layer verifications computed by this shard.
    pub memo_misses: u64,
    /// Median request latency on this shard (0 when idle).
    pub latency_p50_secs: f64,
    /// 95th-percentile request latency on this shard.
    pub latency_p95_secs: f64,
}

impl ShardStat {
    /// JSON encoding.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("shard".into(), Json::Num(self.shard as f64)),
            ("jobs".into(), Json::Num(self.jobs as f64)),
            ("runs".into(), Json::Num(self.runs as f64)),
            ("memo_entries".into(), Json::Num(self.memo_entries as f64)),
            ("memo_hits".into(), Json::Num(self.memo_hits as f64)),
            ("memo_misses".into(), Json::Num(self.memo_misses as f64)),
            ("latency_p50_secs".into(), Json::Num(self.latency_p50_secs)),
            ("latency_p95_secs".into(), Json::Num(self.latency_p95_secs)),
        ])
    }

    /// Decode from [`ShardStat::to_json`] output.
    pub fn from_json(doc: &Json) -> Result<ShardStat> {
        let need = |key: &str| {
            doc.u64_at(key).ok_or_else(|| {
                ScalifyError::parse(format!("shard stat is missing counter '{key}'"))
            })
        };
        Ok(ShardStat {
            shard: need("shard")?,
            jobs: need("jobs")?,
            runs: need("runs")?,
            memo_entries: need("memo_entries")?,
            memo_hits: need("memo_hits")?,
            memo_misses: need("memo_misses")?,
            latency_p50_secs: doc.f64_at("latency_p50_secs").unwrap_or(0.0),
            latency_p95_secs: doc.f64_at("latency_p95_secs").unwrap_or(0.0),
        })
    }
}

/// Point-in-time service counters (the `stats` response payload, also
/// embedded in every verify response).
#[derive(Clone, Debug, PartialEq)]
pub struct StatsSnapshot {
    /// Protocol version this snapshot is encoded for. 1 (the default)
    /// produces exactly the v1 document; 2+ appends the `shards` array.
    /// Set per connection from the negotiated version.
    pub protocol: u32,
    /// Verify jobs completed by the daemon (successful reports).
    pub jobs: u64,
    /// `Session::verify` calls (includes jobs that errored mid-verify).
    pub runs: u64,
    /// Distinct memo fingerprints currently held.
    pub memo_entries: u64,
    /// Layer verifications served from the memo.
    pub memo_hits: u64,
    /// Layer verifications computed and inserted.
    pub memo_misses: u64,
    /// Memo entries evicted under the capacity bound.
    pub memo_evictions: u64,
    /// Compiled rewrite templates in the shared rule set.
    pub templates: u64,
    /// Session worker threads (speculative pass).
    pub threads: u64,
    /// Scheduler queue capacity (backpressure threshold).
    pub queue_capacity: u64,
    /// Scheduler worker threads.
    pub scheduler_workers: u64,
    /// Total e-graph nodes across all completed verify jobs.
    pub egraph_nodes_total: u64,
    /// Total e-nodes examined by the e-matcher across all completed
    /// verify jobs (memo-served layers contribute 0 — that is the point).
    pub ematch_tried_total: u64,
    /// Total rewrite-rule applications (unions) across all completed
    /// verify jobs.
    pub rule_applications_total: u64,
    /// Entries preloaded from the persistent cache at startup.
    pub cache_entries_loaded: u64,
    /// Cache directory, when persistence is on.
    pub cache_dir: Option<String>,
    /// Seconds since the daemon started.
    pub uptime_secs: f64,
    /// Median per-request verify latency (seconds; 0 when no jobs yet).
    pub latency_p50_secs: f64,
    /// 95th-percentile verify latency.
    pub latency_p95_secs: f64,
    /// Worst verify latency.
    pub latency_max_secs: f64,
    /// Verify jobs that returned a degraded (deadline-truncated) report
    /// (v2 only; 0 and unencoded on v1).
    pub degraded_total: u64,
    /// Supervisor restarts of panicked/poisoned shards (v2 only; 0 and
    /// unencoded on v1).
    pub shard_restarts_total: u64,
    /// Per-shard detail (v2 only; empty and unencoded on v1).
    pub shards: Vec<ShardStat>,
}

impl Default for StatsSnapshot {
    fn default() -> StatsSnapshot {
        StatsSnapshot {
            protocol: PROTOCOL_VERSION,
            jobs: 0,
            runs: 0,
            memo_entries: 0,
            memo_hits: 0,
            memo_misses: 0,
            memo_evictions: 0,
            templates: 0,
            threads: 0,
            queue_capacity: 0,
            scheduler_workers: 0,
            egraph_nodes_total: 0,
            ematch_tried_total: 0,
            rule_applications_total: 0,
            cache_entries_loaded: 0,
            cache_dir: None,
            uptime_secs: 0.0,
            latency_p50_secs: 0.0,
            latency_p95_secs: 0.0,
            latency_max_secs: 0.0,
            degraded_total: 0,
            shard_restarts_total: 0,
            shards: Vec::new(),
        }
    }
}

impl StatsSnapshot {
    /// JSON encoding.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("protocol".into(), Json::Num(self.protocol as f64)),
            ("jobs".into(), Json::Num(self.jobs as f64)),
            ("runs".into(), Json::Num(self.runs as f64)),
            ("memo_entries".into(), Json::Num(self.memo_entries as f64)),
            ("memo_hits".into(), Json::Num(self.memo_hits as f64)),
            ("memo_misses".into(), Json::Num(self.memo_misses as f64)),
            ("memo_evictions".into(), Json::Num(self.memo_evictions as f64)),
            ("templates".into(), Json::Num(self.templates as f64)),
            ("threads".into(), Json::Num(self.threads as f64)),
            ("queue_capacity".into(), Json::Num(self.queue_capacity as f64)),
            ("scheduler_workers".into(), Json::Num(self.scheduler_workers as f64)),
            ("egraph_nodes_total".into(), Json::Num(self.egraph_nodes_total as f64)),
            ("ematch_tried_total".into(), Json::Num(self.ematch_tried_total as f64)),
            (
                "rule_applications_total".into(),
                Json::Num(self.rule_applications_total as f64),
            ),
            (
                "cache_entries_loaded".into(),
                Json::Num(self.cache_entries_loaded as f64),
            ),
            ("uptime_secs".into(), Json::Num(self.uptime_secs)),
            ("latency_p50_secs".into(), Json::Num(self.latency_p50_secs)),
            ("latency_p95_secs".into(), Json::Num(self.latency_p95_secs)),
            ("latency_max_secs".into(), Json::Num(self.latency_max_secs)),
        ];
        if let Some(dir) = &self.cache_dir {
            fields.push(("cache_dir".into(), Json::Str(dir.clone())));
        }
        // v1 bytes stop here; the fleet-health counters and the shard
        // array are a v2-only appendix (shards stays last: v2 consumers
        // pin the render's tail)
        if self.protocol >= PROTOCOL_V2 {
            fields.push(("degraded_total".into(), Json::Num(self.degraded_total as f64)));
            fields.push((
                "shard_restarts_total".into(),
                Json::Num(self.shard_restarts_total as f64),
            ));
            fields.push((
                "shards".into(),
                Json::Arr(self.shards.iter().map(ShardStat::to_json).collect()),
            ));
        }
        Json::Obj(fields)
    }

    /// Decode from [`StatsSnapshot::to_json`] output. Counter fields are
    /// required; latency/uptime default to 0 when absent.
    pub fn from_json(doc: &Json) -> Result<StatsSnapshot> {
        let need = |key: &str| {
            doc.u64_at(key).ok_or_else(|| {
                ScalifyError::parse(format!("stats is missing counter '{key}'"))
            })
        };
        let shards = match doc.get("shards") {
            Some(Json::Arr(items)) => {
                items.iter().map(ShardStat::from_json).collect::<Result<Vec<_>>>()?
            }
            _ => Vec::new(),
        };
        Ok(StatsSnapshot {
            protocol: doc
                .u64_at("protocol")
                .filter(|p| *p <= u32::MAX as u64)
                .unwrap_or(PROTOCOL_VERSION as u64) as u32,
            jobs: need("jobs")?,
            runs: need("runs")?,
            memo_entries: need("memo_entries")?,
            memo_hits: need("memo_hits")?,
            memo_misses: need("memo_misses")?,
            memo_evictions: need("memo_evictions")?,
            templates: need("templates")?,
            threads: need("threads")?,
            queue_capacity: need("queue_capacity")?,
            scheduler_workers: need("scheduler_workers")?,
            egraph_nodes_total: need("egraph_nodes_total")?,
            // optional: absent in snapshots from pre-indexed-matcher daemons
            ematch_tried_total: doc.u64_at("ematch_tried_total").unwrap_or(0),
            rule_applications_total: doc.u64_at("rule_applications_total").unwrap_or(0),
            cache_entries_loaded: need("cache_entries_loaded")?,
            cache_dir: doc.str_at("cache_dir").map(str::to_owned),
            uptime_secs: doc.f64_at("uptime_secs").unwrap_or(0.0),
            latency_p50_secs: doc.f64_at("latency_p50_secs").unwrap_or(0.0),
            latency_p95_secs: doc.f64_at("latency_p95_secs").unwrap_or(0.0),
            latency_max_secs: doc.f64_at("latency_max_secs").unwrap_or(0.0),
            degraded_total: doc.u64_at("degraded_total").unwrap_or(0),
            shard_restarts_total: doc.u64_at("shard_restarts_total").unwrap_or(0),
            shards,
        })
    }
}

/// A daemon response.
#[derive(Clone, Debug)]
pub enum Response {
    /// A verify job finished (the report itself may be UNVERIFIED — that
    /// is a successful response, not an error).
    VerifyDone {
        /// The full verification report.
        report: VerifyReport,
        /// Wall time of this request inside the daemon (queue + verify).
        latency_secs: f64,
        /// Counters sampled right after the job.
        stats: StatsSnapshot,
        /// Non-fatal degradation notice (a `verify_diff` whose state was
        /// unusable ran cold; absent on clean runs).
        warning: Option<String>,
        /// Echo of the request's v2 `id` (absent on v1 or id-less
        /// requests, keeping v1 responses byte-identical).
        id: Option<String>,
    },
    /// Stats request served.
    Stats(StatsSnapshot),
    /// Metrics request served: the registry rendered as Prometheus text
    /// exposition format (transported as one JSON string).
    Metrics {
        /// The exposition document (`# TYPE …` lines and samples).
        prometheus: String,
    },
    /// Shutdown acknowledged; the daemon exits after this line.
    ShuttingDown,
    /// Version negotiation answered (v2): the version the connection
    /// will speak from now on.
    Hello {
        /// `min(client, server)` — the negotiated version.
        protocol: u32,
        /// Server identification (`scalify <crate version>`).
        server: String,
    },
    /// Cancel request acknowledged (v2).
    CancelAck {
        /// The id the cancel named.
        id: String,
        /// Whether an in-flight request with that id was found and
        /// signalled (false: it had already finished, or never existed).
        cancelled: bool,
    },
    /// One per-layer progress event (v2 streaming verify only; zero or
    /// more precede the terminal verify response on the same line
    /// stream).
    Event(LayerEvent),
    /// A verify aborted by cancellation, supersession or deadline (v2).
    /// Encoded `ok:false` with `"cancelled":true`, so a v1 decoder sees
    /// a plain error.
    Cancelled {
        /// The request's id, when it carried one.
        id: Option<String>,
        /// Why the request stopped (`cancelled`, `superseded`,
        /// `deadline exceeded`).
        message: String,
    },
    /// Faults request served (v2): the armed injection points after any
    /// requested install/clear.
    Faults {
        /// Snapshot of every armed point.
        faults: Vec<crate::faults::FaultStatus>,
    },
    /// The request failed (malformed input, unknown model, parse error).
    Error {
        /// Human-readable cause.
        message: String,
    },
}

impl Response {
    /// JSON encoding.
    pub fn to_json(&self) -> Json {
        match self {
            Response::VerifyDone { report, latency_secs, stats, warning, id } => {
                let mut fields = vec![
                    ("ok".into(), Json::Bool(true)),
                    ("kind".into(), Json::Str("verify".into())),
                    ("report".into(), report.to_json()),
                    ("latency_secs".into(), Json::Num(*latency_secs)),
                    ("stats".into(), stats.to_json()),
                ];
                if let Some(w) = warning {
                    fields.push(("warning".into(), Json::Str(w.clone())));
                }
                if let Some(id) = id {
                    fields.push(("id".into(), Json::Str(id.clone())));
                }
                Json::Obj(fields)
            }
            Response::Stats(stats) => Json::Obj(vec![
                ("ok".into(), Json::Bool(true)),
                ("kind".into(), Json::Str("stats".into())),
                ("stats".into(), stats.to_json()),
            ]),
            Response::Metrics { prometheus } => Json::Obj(vec![
                ("ok".into(), Json::Bool(true)),
                ("kind".into(), Json::Str("metrics".into())),
                ("prometheus".into(), Json::Str(prometheus.clone())),
            ]),
            Response::ShuttingDown => Json::Obj(vec![
                ("ok".into(), Json::Bool(true)),
                ("kind".into(), Json::Str("shutdown".into())),
            ]),
            Response::Hello { protocol, server } => Json::Obj(vec![
                ("ok".into(), Json::Bool(true)),
                ("kind".into(), Json::Str("hello".into())),
                ("protocol".into(), Json::Num(*protocol as f64)),
                ("server".into(), Json::Str(server.clone())),
            ]),
            Response::CancelAck { id, cancelled } => Json::Obj(vec![
                ("ok".into(), Json::Bool(true)),
                ("kind".into(), Json::Str("cancel".into())),
                ("id".into(), Json::Str(id.clone())),
                ("cancelled".into(), Json::Bool(*cancelled)),
            ]),
            Response::Event(ev) => {
                let mut fields = vec![
                    ("ok".into(), Json::Bool(true)),
                    ("kind".into(), Json::Str("event".into())),
                    ("event".into(), Json::Str("layer".into())),
                    ("layer".into(), Json::Num(ev.layer as f64)),
                    ("index".into(), Json::Num(ev.index as f64)),
                    ("total".into(), Json::Num(ev.total as f64)),
                    ("verified".into(), Json::Bool(ev.verified)),
                ];
                if let Some(id) = &ev.id {
                    fields.push(("id".into(), Json::Str(id.clone())));
                }
                Json::Obj(fields)
            }
            Response::Cancelled { id, message } => {
                let mut fields = vec![
                    ("ok".into(), Json::Bool(false)),
                    ("error".into(), Json::Str(message.clone())),
                    ("cancelled".into(), Json::Bool(true)),
                ];
                if let Some(id) = id {
                    fields.push(("id".into(), Json::Str(id.clone())));
                }
                Json::Obj(fields)
            }
            Response::Faults { faults } => Json::Obj(vec![
                ("ok".into(), Json::Bool(true)),
                ("kind".into(), Json::Str("faults".into())),
                (
                    "faults".into(),
                    Json::Arr(
                        faults
                            .iter()
                            .map(|f| {
                                Json::Obj(vec![
                                    ("point".into(), Json::Str(f.point.clone())),
                                    ("kind".into(), Json::Str(f.kind.clone())),
                                    ("rate".into(), Json::Num(f.rate)),
                                    ("seed".into(), Json::Num(f.seed as f64)),
                                    ("evaluated".into(), Json::Num(f.evaluated as f64)),
                                    ("fired".into(), Json::Num(f.fired as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Response::Error { message } => Json::Obj(vec![
                ("ok".into(), Json::Bool(false)),
                ("error".into(), Json::Str(message.clone())),
            ]),
        }
    }

    /// One compact wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().render()
    }

    /// Decode a response document.
    pub fn from_json(doc: &Json) -> Result<Response> {
        let ok = doc
            .bool_at("ok")
            .ok_or_else(|| ScalifyError::parse("response is missing bool field 'ok'"))?;
        if !ok {
            let message = doc
                .str_at("error")
                .ok_or_else(|| ScalifyError::parse("error response carries no 'error'"))?
                .to_string();
            if doc.bool_at("cancelled") == Some(true) {
                return Ok(Response::Cancelled {
                    id: doc.str_at("id").map(str::to_owned),
                    message,
                });
            }
            return Ok(Response::Error { message });
        }
        match doc.str_at("kind") {
            Some("verify") => {
                let report = doc.get("report").ok_or_else(|| {
                    ScalifyError::parse("verify response is missing 'report'")
                })?;
                let stats = doc.get("stats").ok_or_else(|| {
                    ScalifyError::parse("verify response is missing 'stats'")
                })?;
                Ok(Response::VerifyDone {
                    report: VerifyReport::from_json(report)?,
                    latency_secs: doc.f64_at("latency_secs").unwrap_or(0.0),
                    stats: StatsSnapshot::from_json(stats)?,
                    warning: doc.str_at("warning").map(str::to_owned),
                    id: doc.str_at("id").map(str::to_owned),
                })
            }
            Some("stats") => {
                let stats = doc.get("stats").ok_or_else(|| {
                    ScalifyError::parse("stats response is missing 'stats'")
                })?;
                Ok(Response::Stats(StatsSnapshot::from_json(stats)?))
            }
            Some("metrics") => {
                let prometheus = doc
                    .str_at("prometheus")
                    .ok_or_else(|| {
                        ScalifyError::parse("metrics response is missing 'prometheus'")
                    })?
                    .to_string();
                Ok(Response::Metrics { prometheus })
            }
            Some("shutdown") => Ok(Response::ShuttingDown),
            Some("hello") => {
                let protocol = doc.u64_at("protocol").ok_or_else(|| {
                    ScalifyError::parse("hello response is missing 'protocol'")
                })?;
                if protocol == 0 || protocol > u32::MAX as u64 {
                    return Err(ScalifyError::parse("'protocol' must be in 1..=u32::MAX"));
                }
                Ok(Response::Hello {
                    protocol: protocol as u32,
                    server: doc.str_at("server").unwrap_or("").to_string(),
                })
            }
            Some("cancel") => {
                let id = doc.str_at("id").ok_or_else(|| {
                    ScalifyError::parse("cancel response is missing 'id'")
                })?;
                Ok(Response::CancelAck {
                    id: id.to_string(),
                    cancelled: doc.bool_at("cancelled").unwrap_or(false),
                })
            }
            Some("event") => {
                let need = |key: &str| {
                    doc.u64_at(key).ok_or_else(|| {
                        ScalifyError::parse(format!("event is missing integer '{key}'"))
                    })
                };
                let layer = need("layer")?;
                if layer > u32::MAX as u64 {
                    return Err(ScalifyError::parse("'layer' must fit in u32"));
                }
                Ok(Response::Event(LayerEvent {
                    id: doc.str_at("id").map(str::to_owned),
                    layer: layer as u32,
                    index: need("index")?,
                    total: need("total")?,
                    verified: doc.bool_at("verified").unwrap_or(false),
                }))
            }
            Some("faults") => {
                let items = doc
                    .get("faults")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| {
                        ScalifyError::parse("faults response is missing the 'faults' array")
                    })?;
                let faults = items
                    .iter()
                    .map(|f| {
                        Ok(crate::faults::FaultStatus {
                            point: f
                                .str_at("point")
                                .ok_or_else(|| {
                                    ScalifyError::parse("fault entry is missing 'point'")
                                })?
                                .to_string(),
                            kind: f.str_at("kind").unwrap_or("").to_string(),
                            rate: f.f64_at("rate").unwrap_or(0.0),
                            seed: f.u64_at("seed").unwrap_or(0),
                            evaluated: f.u64_at("evaluated").unwrap_or(0),
                            fired: f.u64_at("fired").unwrap_or(0),
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(Response::Faults { faults })
            }
            other => Err(ScalifyError::parse(format!(
                "unknown response kind {other:?}"
            ))),
        }
    }

    /// Decode one wire line.
    pub fn from_line(line: &str) -> Result<Response> {
        Response::from_json(&Json::parse(line)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip_request(req: Request) {
        let line = req.to_line();
        assert!(!line.contains('\n'), "wire lines must be single-line: {line}");
        let back = Request::from_line(&line).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn requests_round_trip() {
        round_trip_request(Request::Stats);
        round_trip_request(Request::Metrics);
        round_trip_request(Request::Shutdown);
        round_trip_request(Request::Verify(VerifySource::Model {
            model: "llama-tiny".into(),
            par: "tp4".into(),
            layers: Some(2),
            edit_layer: None,
        }));
        round_trip_request(Request::Verify(VerifySource::Model {
            model: "mixtral-tiny".into(),
            par: "ep4".into(),
            layers: None,
            edit_layer: None,
        }));
        round_trip_request(Request::Verify(VerifySource::Bug { id: "T4#3".into() }));
        round_trip_request(Request::Verify(VerifySource::Hlo {
            base: "HloModule a\nENTRY e { ... }".into(),
            dist: "HloModule b".into(),
            cores: 8,
        }));
    }

    #[test]
    fn verify_diff_requests_round_trip() {
        round_trip_request(Request::VerifyDiff {
            source: VerifySource::Model {
                model: "llama-tiny".into(),
                par: "tp2".into(),
                layers: Some(4),
                edit_layer: Some(1),
            },
            state: Json::Obj(vec![
                ("format".into(), Json::Num(1.0)),
                ("layers".into(), Json::Arr(vec![])),
            ]),
        });
        round_trip_request(Request::VerifyDiff {
            source: VerifySource::Bug { id: "PT#2".into() },
            state: Json::Obj(vec![]),
        });
        // a verify_diff without a state is malformed
        assert!(Request::from_line(
            "{\"cmd\":\"verify_diff\",\"model\":\"llama-tiny\",\"par\":\"tp2\"}"
        )
        .is_err());
        // pre-diff clients that never send edit_layer still decode to None
        match Request::from_line("{\"cmd\":\"verify\",\"model\":\"m\",\"par\":\"tp2\"}")
            .unwrap()
        {
            Request::Verify(VerifySource::Model { edit_layer, layers, .. }) => {
                assert_eq!(edit_layer, None);
                assert_eq!(layers, None);
            }
            other => panic!("expected model verify, got {other:?}"),
        }
    }

    #[test]
    fn malformed_requests_are_typed_errors() {
        for bad in [
            "",
            "not json",
            "{}",
            "{\"cmd\":\"nope\"}",
            "{\"cmd\":\"verify\"}",
            "{\"cmd\":\"verify\",\"model\":\"llama-tiny\"}",
            "{\"cmd\":\"verify\",\"base_hlo\":\"x\"}",
            "{\"cmd\":\"verify\",\"base_hlo\":\"x\",\"dist_hlo\":\"y\",\"cores\":0}",
            "{\"cmd\":\"verify\",\"model\":\"m\",\"par\":\"tp2\",\"layers\":-1}",
            "{\"cmd\":\"verify\",\"model\":\"m\",\"par\":\"tp2\",\"layers\":4294967297}",
            "{\"cmd\":\"verify\",\"model\":\"m\",\"par\":\"tp2\",\"edit_layer\":-2}",
            "{\"cmd\":\"verify_diff\",\"model\":\"m\",\"par\":\"tp2\"}",
        ] {
            assert!(Request::from_line(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn stats_snapshot_round_trips() {
        let snap = StatsSnapshot {
            protocol: PROTOCOL_VERSION,
            jobs: 12,
            runs: 13,
            memo_entries: 40,
            memo_hits: 100,
            memo_misses: 41,
            memo_evictions: 1,
            templates: 25,
            threads: 4,
            queue_capacity: 64,
            scheduler_workers: 4,
            egraph_nodes_total: 123_456,
            ematch_tried_total: 9_876,
            rule_applications_total: 321,
            cache_entries_loaded: 40,
            cache_dir: Some("/tmp/scalify-cache".into()),
            uptime_secs: 12.5,
            latency_p50_secs: 0.01,
            latency_p95_secs: 0.05,
            latency_max_secs: 0.2,
            degraded_total: 0,
            shard_restarts_total: 0,
            shards: vec![],
        };
        let back = StatsSnapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(back, snap);
        // cache_dir is optional
        let bare = StatsSnapshot::default();
        let back = StatsSnapshot::from_json(&bare.to_json()).unwrap();
        assert_eq!(back, bare);
    }

    #[test]
    fn responses_round_trip() {
        let line = Response::ShuttingDown.to_line();
        assert!(matches!(Response::from_line(&line).unwrap(), Response::ShuttingDown));

        let line = Response::Error { message: "unknown model 'gpt-5'".into() }.to_line();
        match Response::from_line(&line).unwrap() {
            Response::Error { message } => assert!(message.contains("gpt-5")),
            other => panic!("expected error, got {other:?}"),
        }

        let line = Response::Stats(StatsSnapshot::default()).to_line();
        assert!(matches!(Response::from_line(&line).unwrap(), Response::Stats(_)));

        // Prometheus text crosses the wire as one JSON string, newlines
        // escaped — the wire line itself must stay single-line
        let text = "# TYPE scalify_jobs_total counter\nscalify_jobs_total 3\n";
        let line = Response::Metrics { prometheus: text.into() }.to_line();
        assert!(!line.contains('\n'), "{line}");
        match Response::from_line(&line).unwrap() {
            Response::Metrics { prometheus } => assert_eq!(prometheus, text),
            other => panic!("expected metrics response, got {other:?}"),
        }
    }

    #[test]
    fn verify_response_embeds_report_and_stats() {
        let report = VerifyReport {
            verdict: crate::verifier::Verdict::Verified,
            layers: vec![],
            stopwatch: crate::util::Stopwatch::new(),
            total: std::time::Duration::from_millis(3),
            degraded: false,
            first_unverified: None,
        };
        let resp = Response::VerifyDone {
            report,
            latency_secs: 0.004,
            stats: StatsSnapshot { jobs: 1, ..Default::default() },
            warning: None,
            id: None,
        };
        let line = resp.to_line();
        assert!(!line.contains('\n'));
        assert!(!line.contains("\"id\""), "id-less verify must not encode an id");
        match Response::from_line(&line).unwrap() {
            Response::VerifyDone { report, latency_secs, stats, warning, id } => {
                assert!(report.verified());
                assert!((latency_secs - 0.004).abs() < 1e-12);
                assert_eq!(stats.jobs, 1);
                assert_eq!(warning, None);
                assert_eq!(id, None);
            }
            other => panic!("expected verify response, got {other:?}"),
        }
    }

    #[test]
    fn degraded_verify_responses_carry_their_warning() {
        let resp = Response::VerifyDone {
            report: VerifyReport {
                verdict: crate::verifier::Verdict::Verified,
                layers: vec![],
                stopwatch: crate::util::Stopwatch::new(),
                total: std::time::Duration::from_millis(1),
                degraded: false,
                first_unverified: None,
            },
            latency_secs: 0.001,
            stats: StatsSnapshot::default(),
            warning: Some("state names model 'other'; ran cold".into()),
            id: None,
        };
        match Response::from_line(&resp.to_line()).unwrap() {
            Response::VerifyDone { warning, .. } => {
                assert!(warning.unwrap().contains("ran cold"));
            }
            other => panic!("expected verify response, got {other:?}"),
        }
    }

    #[test]
    fn hello_and_cancel_requests_round_trip() {
        round_trip_request(Request::Hello { protocol: PROTOCOL_V2 });
        round_trip_request(Request::Cancel { id: "req-7".into() });
        assert!(Request::from_line("{\"cmd\":\"hello\"}").is_err());
        assert!(Request::from_line("{\"cmd\":\"hello\",\"protocol\":0}").is_err());
        assert!(Request::from_line("{\"cmd\":\"cancel\"}").is_err());
    }

    #[test]
    fn verify_opts_parse_off_the_request_document_and_back() {
        // a bare v1 request parses to all defaults
        let doc = Json::parse("{\"cmd\":\"verify\",\"model\":\"m\",\"par\":\"tp2\"}").unwrap();
        assert_eq!(VerifyOpts::from_json(&doc).unwrap(), VerifyOpts::default());

        let opts = VerifyOpts {
            id: Some("r1".into()),
            priority: 5,
            deadline_secs: Some(1.5),
            stream: true,
        };
        let mut fields = vec![
            ("cmd".into(), Json::Str("verify".into())),
            ("bug".into(), Json::Str("T4#1".into())),
        ];
        opts.extend_fields(&mut fields);
        let doc = Json::Obj(fields);
        // v1 Request decoding ignores the extra fields entirely
        assert_eq!(
            Request::from_json(&doc).unwrap(),
            Request::Verify(VerifySource::Bug { id: "T4#1".into() })
        );
        assert_eq!(VerifyOpts::from_json(&doc).unwrap(), opts);

        let bad = Json::parse("{\"cmd\":\"verify\",\"bug\":\"x\",\"priority\":1.5}").unwrap();
        assert!(VerifyOpts::from_json(&bad).is_err());
        let bad = Json::parse("{\"cmd\":\"verify\",\"bug\":\"x\",\"deadline_secs\":0}").unwrap();
        assert!(VerifyOpts::from_json(&bad).is_err());
    }

    #[test]
    fn hello_cancel_and_event_responses_round_trip() {
        let line = Response::Hello { protocol: 2, server: "scalify 0.2.0".into() }.to_line();
        match Response::from_line(&line).unwrap() {
            Response::Hello { protocol, server } => {
                assert_eq!(protocol, 2);
                assert_eq!(server, "scalify 0.2.0");
            }
            other => panic!("expected hello, got {other:?}"),
        }

        let line = Response::CancelAck { id: "r1".into(), cancelled: true }.to_line();
        match Response::from_line(&line).unwrap() {
            Response::CancelAck { id, cancelled } => {
                assert_eq!(id, "r1");
                assert!(cancelled);
            }
            other => panic!("expected cancel ack, got {other:?}"),
        }

        let ev = LayerEvent {
            id: Some("r1".into()),
            layer: 3,
            index: 2,
            total: 6,
            verified: true,
        };
        match Response::from_line(&Response::Event(ev.clone()).to_line()).unwrap() {
            Response::Event(back) => assert_eq!(back, ev),
            other => panic!("expected event, got {other:?}"),
        }
    }

    #[test]
    fn cancelled_responses_decode_as_plain_errors_for_v1_decoders() {
        let resp = Response::Cancelled {
            id: Some("r9".into()),
            message: "verify cancelled at a layer boundary".into(),
        };
        let line = resp.to_line();
        // the v2 decoder sees the cancellation
        match Response::from_line(&line).unwrap() {
            Response::Cancelled { id, message } => {
                assert_eq!(id.as_deref(), Some("r9"));
                assert!(message.contains("cancelled"));
            }
            other => panic!("expected cancelled, got {other:?}"),
        }
        // the document is still shaped like a v1 error (`ok:false` +
        // `error`), so a decoder that predates `cancelled` reads it as
        // a failed request rather than choking
        let doc = Json::parse(&line).unwrap();
        assert_eq!(doc.bool_at("ok"), Some(false));
        assert!(doc.str_at("error").unwrap().contains("cancelled"));
    }

    #[test]
    fn v1_stats_never_encode_the_shard_array() {
        let mut snap = StatsSnapshot { jobs: 3, ..Default::default() };
        snap.shards = vec![ShardStat { shard: 0, jobs: 3, ..Default::default() }];
        snap.degraded_total = 2;
        snap.shard_restarts_total = 1;
        assert_eq!(snap.protocol, PROTOCOL_VERSION);
        let line = snap.to_json().render();
        assert!(!line.contains("shards"), "v1 stats must stay byte-identical: {line}");
        assert!(!line.contains("degraded_total"), "{line}");
        assert!(!line.contains("shard_restarts_total"), "{line}");

        snap.protocol = PROTOCOL_V2;
        let line = snap.to_json().render();
        assert!(line.contains("\"shards\":[{\"shard\":0"), "{line}");
        assert!(line.contains("\"degraded_total\":2"), "{line}");
        assert!(line.contains("\"shard_restarts_total\":1"), "{line}");
        let back = StatsSnapshot::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, snap);
        assert_eq!(back.shards.len(), 1);
    }

    #[test]
    fn faults_requests_and_responses_round_trip() {
        round_trip_request(Request::Faults {
            set: Some("shard-verify:panic:0.1:7".into()),
            clear: false,
        });
        round_trip_request(Request::Faults { set: None, clear: true });

        let resp = Response::Faults {
            faults: vec![crate::faults::FaultStatus {
                point: "conn-write".into(),
                kind: "drop".into(),
                rate: 0.25,
                seed: 9,
                evaluated: 12,
                fired: 3,
            }],
        };
        let line = resp.to_line();
        match Response::from_line(&line).unwrap() {
            Response::Faults { faults } => {
                assert_eq!(faults.len(), 1);
                assert_eq!(faults[0].point, "conn-write");
                assert_eq!(faults[0].kind, "drop");
                assert!((faults[0].rate - 0.25).abs() < 1e-9);
                assert_eq!(faults[0].evaluated, 12);
                assert_eq!(faults[0].fired, 3);
            }
            other => panic!("expected faults response, got {other:?}"),
        }
    }
}
