//! The `scalify serve` daemon: a warm verification fleet serving many
//! clients.
//!
//! Architecture:
//!
//! ```text
//! accept loop ──► connection thread (1 per client)
//!                    │  parse request line, negotiate protocol (hello)
//!                    ▼
//!                [`Scheduler`] — bounded admission, backpressure,
//!                                priorities and queue deadlines
//!                    │  route by model-family key
//!                    ▼
//!                [`ShardPool`] — N [`crate::verifier::Session`] shards,
//!                ONE shared compiled rule set, per-shard memo +
//!                worker pool + latency histogram
//!                    │  fresh memo inserts
//!                    ▼
//!                [`MemoCache`] — daemon-global append-only segment
//!                store (optional, `--cache-dir`)
//! ```
//!
//! Every connection thread blocks at the scheduler's admission gate when
//! the daemon is saturated, so a burst of CI jobs queues at the socket
//! instead of exhausting memory. With `--cache-dir`, every shard's memo
//! preloads from disk at startup and every fresh entry is appended on
//! write, so a restarted daemon answers its first request warm.
//!
//! Connections speak protocol v1 until they negotiate v2 with a `hello`
//! request; v2 connections may attach ids, priorities, deadlines and
//! streaming to verify requests, and may cancel in-flight requests by id
//! (their own or another connection's — the id registry is
//! daemon-global). Cancellation, supersession and deadlines take effect
//! at layer boundaries inside the verify; see
//! [`crate::verifier::VerifyControl`].

use super::cache::MemoCache;
use super::protocol::{
    LayerEvent, Request, Response, StatsSnapshot, VerifyOpts, VerifySource, PROTOCOL_V2,
    PROTOCOL_VERSION,
};
use super::scheduler::Scheduler;
use super::shard::ShardPool;
use crate::cli;
use crate::diff::VerifyState;
use crate::error::{Result, ResultExt, ScalifyError};
use crate::hlo::parse_hlo_module;
use crate::obs::{self, Histogram};
use crate::report::json::Json;
use crate::verifier::{GraphPair, LayerProgress, VerifyConfig, VerifyControl};
use rustc_hash::FxHashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Daemon configuration (`scalify serve` flags).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address; port 0 picks an ephemeral port (printed at startup,
    /// used by the tests).
    pub addr: String,
    /// Directory for the persistent layer-memo store; `None` keeps the
    /// memo in-process only.
    pub cache_dir: Option<PathBuf>,
    /// Scheduler admission window (in-flight verify jobs before
    /// backpressure).
    pub queue_capacity: usize,
    /// Scheduler worker threads (concurrent verify jobs).
    pub workers: usize,
    /// Session shards. Requests route by model-family key, so `1` (the
    /// default) behaves exactly like the pre-fleet single-session
    /// daemon.
    pub shards: usize,
    /// Verifier configuration for every session shard.
    pub verify: VerifyConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            cache_dir: None,
            queue_capacity: 64,
            workers: 4,
            shards: 1,
            verify: VerifyConfig::default(),
        }
    }
}

/// Shared state behind every connection thread.
struct ServiceState {
    shards: ShardPool,
    scheduler: Scheduler,
    cache: Option<Arc<MemoCache>>,
    cache_loaded: usize,
    /// Daemon-global registry of in-flight v2 request ids → their cancel
    /// tokens. A `cancel` request (any connection) or a superseding
    /// request with the same id sets the token.
    inflight_ids: Mutex<FxHashMap<String, Arc<AtomicBool>>>,
    /// Verify jobs that produced a report.
    jobs: AtomicU64,
    /// Verify jobs that produced a deadline-degraded (partial) report.
    degraded: AtomicU64,
    /// Total e-graph nodes across completed jobs.
    egraph_nodes_total: AtomicU64,
    /// Total e-nodes examined by the e-matcher across completed jobs.
    ematch_tried_total: AtomicU64,
    /// Total rewrite-rule applications across completed jobs.
    rule_applications_total: AtomicU64,
    /// Per-request wall latencies: a fixed-bucket histogram, so memory
    /// stays O(buckets) no matter how hard an org hammers the verifier
    /// (this replaced a bounded-but-large `VecDeque` window; the
    /// p50/p95 fields below became bucket-interpolated estimates, the
    /// max stays exact).
    latency_hist: Histogram,
    started: Instant,
    shutdown: AtomicBool,
    local_addr: SocketAddr,
}

impl ServiceState {
    fn record_latency(&self, secs: f64) {
        self.latency_hist.observe(secs);
    }

    /// Register a v2 request id; a previous in-flight request with the
    /// same id is superseded (its cancel token is set).
    fn register_inflight(&self, id: &str, token: Arc<AtomicBool>) {
        let mut map = self.inflight_ids.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(old) = map.insert(id.to_string(), token) {
            old.store(true, Ordering::SeqCst);
        }
    }

    /// Drop the id → token mapping, but only if it is still ours (a
    /// superseding request may have replaced it already).
    fn unregister_inflight(&self, id: &str, token: &Arc<AtomicBool>) {
        let mut map = self.inflight_ids.lock().unwrap_or_else(|p| p.into_inner());
        if map.get(id).map_or(false, |t| Arc::ptr_eq(t, token)) {
            map.remove(id);
        }
    }

    /// Signal the in-flight request carrying `id`; false when none is.
    fn cancel_inflight(&self, id: &str) -> bool {
        let map = self.inflight_ids.lock().unwrap_or_else(|p| p.into_inner());
        match map.get(id) {
            Some(token) => {
                token.store(true, Ordering::SeqCst);
                true
            }
            None => false,
        }
    }

    fn snapshot(&self) -> StatsSnapshot {
        self.snapshot_for(PROTOCOL_VERSION)
    }

    /// Counters snapshot encoded for a connection's negotiated protocol
    /// (v2 adds the per-shard array). The global percentiles merge the
    /// per-shard histograms — exactly 0 on a fresh daemon.
    fn snapshot_for(&self, protocol: u32) -> StatsSnapshot {
        let (p50, p95, max) = (
            self.shards.latency_quantile(0.50),
            self.shards.latency_quantile(0.95),
            self.shards.latency_max(),
        );
        let session = self.shards.stats();
        StatsSnapshot {
            protocol,
            jobs: self.jobs.load(Ordering::Relaxed),
            runs: session.runs as u64,
            memo_entries: session.memo_entries as u64,
            memo_hits: session.memo_hits as u64,
            memo_misses: session.memo_misses as u64,
            memo_evictions: session.memo_evictions as u64,
            templates: session.templates as u64,
            threads: session.threads as u64,
            queue_capacity: self.scheduler.capacity() as u64,
            scheduler_workers: self.scheduler.workers() as u64,
            egraph_nodes_total: self.egraph_nodes_total.load(Ordering::Relaxed),
            ematch_tried_total: self.ematch_tried_total.load(Ordering::Relaxed),
            rule_applications_total: self.rule_applications_total.load(Ordering::Relaxed),
            cache_entries_loaded: self.cache_loaded as u64,
            cache_dir: self
                .cache
                .as_ref()
                .and_then(|c| c.path().parent().map(|p| p.display().to_string())),
            uptime_secs: self.started.elapsed().as_secs_f64(),
            latency_p50_secs: p50,
            latency_p95_secs: p95,
            latency_max_secs: max,
            degraded_total: if protocol >= PROTOCOL_V2 {
                self.degraded.load(Ordering::Relaxed)
            } else {
                0
            },
            shard_restarts_total: if protocol >= PROTOCOL_V2 {
                self.shards.restarts_total()
            } else {
                0
            },
            shards: if protocol >= PROTOCOL_V2 {
                self.shards.shard_stats()
            } else {
                Vec::new()
            },
        }
    }

    /// Accept loops block in `accept`; poke them awake after setting the
    /// shutdown flag.
    fn wake_accept(&self) {
        let _ = TcpStream::connect(self.local_addr);
    }
}

/// A running daemon. Dropping the handle does **not** stop the daemon;
/// call [`Server::shutdown`] or send a `shutdown` request, then
/// [`Server::wait`].
pub struct Server {
    local_addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    state: Arc<ServiceState>,
}

impl Server {
    /// Bind, preload the cache (if configured) and start accepting.
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_ctx(|| format!("binding {}", cfg.addr))?;
        let local_addr = listener.local_addr()?;

        // open the persistent store first: every shard shares its write
        // hook, and every shard preloads its entries
        let (cache, hook, loaded_entries) = match &cfg.cache_dir {
            None => (None, None, Vec::new()),
            Some(dir) => {
                // the persistent mirror obeys the same bound as the memo
                let (cache, load) =
                    MemoCache::open_with_capacity(dir, cfg.verify.memo_capacity)
                        .with_ctx(|| format!("opening cache dir {}", dir.display()))?;
                if let Some(warning) = &load.warning {
                    crate::log_warn!("{warning}");
                    crate::log_debug!(
                        "cache dir {}: the memo starts cold for the skipped \
                         entries; they re-verify and re-flush on first use",
                        dir.display()
                    );
                }
                let cache = Arc::new(cache);
                let hook_cache = Arc::clone(&cache);
                let hook: crate::verifier::MemoWriteHook =
                    Arc::new(move |fp, entry| {
                        hook_cache.record(fp, entry);
                    });
                let entries = cache.entries();
                debug_assert_eq!(entries.len(), load.loaded);
                (Some(cache), Some(hook), entries)
            }
        };
        let shards = ShardPool::new(&cfg.verify, cfg.shards, hook);
        let cache_loaded = shards.preload_memo(&loaded_entries);

        let state = Arc::new(ServiceState {
            shards,
            scheduler: Scheduler::new(cfg.workers, cfg.queue_capacity),
            cache,
            cache_loaded,
            inflight_ids: Mutex::new(FxHashMap::default()),
            jobs: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            egraph_nodes_total: AtomicU64::new(0),
            ematch_tried_total: AtomicU64::new(0),
            rule_applications_total: AtomicU64::new(0),
            latency_hist: Histogram::new(obs::LATENCY_BUCKETS),
            started: Instant::now(),
            shutdown: AtomicBool::new(false),
            local_addr,
        });

        let accept_state = Arc::clone(&state);
        let accept = std::thread::Builder::new()
            .name("scalify-accept".into())
            .spawn(move || accept_loop(listener, accept_state))
            .map_err(|e| ScalifyError::runtime(format!("spawning accept thread: {e}")))?;

        Ok(Server { local_addr, accept: Some(accept), state })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Current counters (the same snapshot a `stats` request returns).
    pub fn stats(&self) -> StatsSnapshot {
        self.state.snapshot()
    }

    /// Ask the daemon to stop, as if a `shutdown` request arrived.
    pub fn shutdown(&self) {
        self.state.shutdown.store(true, Ordering::SeqCst);
        self.state.wake_accept();
    }

    /// Block until the daemon has stopped (accept loop exited and every
    /// connection thread drained).
    pub fn wait(mut self) {
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
    }
}

fn accept_loop(listener: TcpListener, state: Arc<ServiceState>) {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    for stream in listener.incoming() {
        if state.shutdown.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => {
                // persistent accept errors (e.g. EMFILE under fd
                // exhaustion) return immediately — back off instead of
                // spinning a full core
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
        };
        let conn_state = Arc::clone(&state);
        match std::thread::Builder::new()
            .name("scalify-conn".into())
            .spawn(move || handle_conn(stream, conn_state))
        {
            Ok(handle) => conns.push(handle),
            Err(_) => continue,
        }
        // reap finished connection threads so a long-lived daemon does
        // not accumulate handles
        conns.retain(|h| !h.is_finished());
    }
    for handle in conns {
        let _ = handle.join();
    }
}

/// Hard cap on one request line — generous for inline HLO text, small
/// enough that a client streaming garbage without a newline cannot OOM
/// the shared daemon (everything else in the service is bounded too).
const MAX_REQUEST_BYTES: usize = 64 << 20;

/// Per-connection protocol state: everything a `hello` negotiation
/// changes about how later lines on the same connection are served.
struct ConnCtx {
    /// Negotiated protocol version; starts (and, for v1 clients that
    /// never say hello, stays) at [`PROTOCOL_VERSION`].
    protocol: u32,
}

/// Write one response line through the shared connection writer (the
/// mutex keeps streamed event lines and terminal responses from
/// interleaving mid-line).
fn write_line(writer: &Arc<Mutex<TcpStream>>, response: &Response) -> bool {
    if let Some(action) = crate::faults::fire("conn-write") {
        match action.kind {
            crate::faults::FaultKind::Delay(d) => std::thread::sleep(d),
            // any other kind swallows the response, as a torn socket
            // would; the caller closes the connection
            _ => return false,
        }
    }
    let mut out = response.to_line();
    out.push('\n');
    let mut w = writer.lock().unwrap_or_else(|p| p.into_inner());
    if w.write_all(out.as_bytes()).is_err() {
        return false;
    }
    let _ = w.flush();
    true
}

/// Serve one complete request line; returns `false` when the connection
/// should close (write failure or shutdown).
fn serve_line(
    line: &[u8],
    state: &Arc<ServiceState>,
    ctx: &mut ConnCtx,
    writer: &Arc<Mutex<TcpStream>>,
) -> bool {
    let text = String::from_utf8_lossy(line);
    let trimmed = text.trim();
    if trimmed.is_empty() {
        return true;
    }
    let response = handle_request(trimmed, state, ctx, writer);
    let closing = matches!(response, Response::ShuttingDown);
    if !write_line(writer, &response) {
        return false;
    }
    if closing {
        state.wake_accept();
        return false;
    }
    true
}

fn handle_conn(stream: TcpStream, state: Arc<ServiceState>) {
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    let mut ctx = ConnCtx { protocol: PROTOCOL_VERSION };
    // short read timeout: idle connections poll the shutdown flag instead
    // of pinning the daemon open forever
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let mut reader = BufReader::new(stream);
    // bytes, not String: `read_line` would discard consumed bytes when a
    // timeout lands mid-UTF-8-sequence (its guard truncates on invalid
    // UTF-8), whereas `read_until` keeps every byte across retries
    let mut line: Vec<u8> = Vec::new();
    loop {
        if state.shutdown.load(Ordering::SeqCst) {
            break;
        }
        if let Some(action) = crate::faults::fire("conn-read") {
            match action.kind {
                crate::faults::FaultKind::Delay(d) => std::thread::sleep(d),
                // any other kind drops the connection mid-read, as a
                // flaky network would; clients are expected to retry
                _ => break,
            }
        }
        if line.len() >= MAX_REQUEST_BYTES {
            let _ = write_line(
                &writer,
                &Response::Error {
                    message: format!("request line exceeds {MAX_REQUEST_BYTES} bytes"),
                },
            );
            break;
        }
        // the per-read cap makes a newline-less flood surface at the
        // length check above instead of growing `line` unboundedly
        let budget = (MAX_REQUEST_BYTES - line.len()) as u64;
        let mut limited = std::io::Read::take(&mut reader, budget);
        match limited.read_until(b'\n', &mut line) {
            Ok(0) => {
                // peer closed; serve a final unterminated line, if any
                if !line.is_empty() {
                    let _ = serve_line(&line, &state, &mut ctx, &writer);
                }
                break;
            }
            Ok(_) => {
                if line.last() != Some(&b'\n') {
                    // cut short by the cap (caught next turn) or by EOF
                    // (next read returns Ok(0)); keep accumulating
                    continue;
                }
                if !serve_line(&line, &state, &mut ctx, &writer) {
                    break;
                }
                line.clear();
            }
            // timeout with a partial line: the consumed bytes stay in
            // `line`, so looping without clearing resumes mid-line
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => break,
        }
    }
}

fn handle_request(
    line: &str,
    state: &Arc<ServiceState>,
    ctx: &mut ConnCtx,
    writer: &Arc<Mutex<TcpStream>>,
) -> Response {
    // parse the document once: the request proper and (on v2
    // connections) the per-request verify options both read from it
    let doc = match Json::parse(line) {
        Ok(d) => d,
        Err(e) => return Response::Error { message: e.to_string() },
    };
    let request = match Request::from_json(&doc) {
        Ok(r) => r,
        Err(e) => return Response::Error { message: e.to_string() },
    };
    match request {
        Request::Hello { protocol } => {
            // meet in the middle: never above what we speak, never below
            // the v1 baseline
            ctx.protocol = protocol.min(PROTOCOL_V2).max(PROTOCOL_VERSION);
            Response::Hello {
                protocol: ctx.protocol,
                server: format!("scalify {}", env!("CARGO_PKG_VERSION")),
            }
        }
        Request::Cancel { id } => {
            let cancelled = state.cancel_inflight(&id);
            Response::CancelAck { id, cancelled }
        }
        Request::Stats => Response::Stats(state.snapshot_for(ctx.protocol)),
        Request::Faults { set, clear } => {
            if clear {
                crate::faults::clear();
            }
            if let Some(spec) = set {
                if let Err(e) = crate::faults::install(&spec) {
                    return Response::Error { message: e.to_string() };
                }
            }
            Response::Faults { faults: crate::faults::snapshot() }
        }
        Request::Metrics => Response::Metrics { prometheus: render_metrics(state) },
        Request::Shutdown => {
            state.shutdown.store(true, Ordering::SeqCst);
            Response::ShuttingDown
        }
        Request::Verify(source) => {
            let opts = match verify_opts_for(ctx, &doc) {
                Ok(o) => o,
                Err(e) => return Response::Error { message: e.to_string() },
            };
            run_verify_job(state, source, None, opts, ctx.protocol, writer)
        }
        Request::VerifyDiff { source, state: prev } => {
            let opts = match verify_opts_for(ctx, &doc) {
                Ok(o) => o,
                Err(e) => return Response::Error { message: e.to_string() },
            };
            run_verify_job(state, source, Some(prev), opts, ctx.protocol, writer)
        }
    }
}

/// Per-request verify options: parsed from the request document on v2
/// connections, defaulted on v1 (where the fields, if present, are
/// ignored exactly as the v1 daemon ignored them).
fn verify_opts_for(ctx: &ConnCtx, doc: &Json) -> Result<VerifyOpts> {
    if ctx.protocol >= PROTOCOL_V2 {
        VerifyOpts::from_json(doc)
    } else {
        Ok(VerifyOpts::default())
    }
}

/// The model-family routing key for a verify source: requests for the
/// same family land on the same shard and keep hitting its warm memo.
fn family_key(source: &VerifySource) -> &str {
    match source {
        VerifySource::Model { model, .. } => model,
        VerifySource::Bug { id } => id,
        VerifySource::Hlo { base, .. } => base,
    }
}

/// Render the daemon's full metrics surface in Prometheus text
/// exposition format: the stats-snapshot counters and gauges, the
/// bounded request-latency histogram, and every process-wide pipeline
/// instrument in the [`obs`] registry (layer outcomes, speculation,
/// scheduler queueing, relation facts).
fn render_metrics(state: &Arc<ServiceState>) -> String {
    use std::fmt::Write as _;
    let snap = state.snapshot();
    let mut out = String::new();
    let counters: &[(&str, u64)] = &[
        ("scalify_jobs_total", snap.jobs),
        ("scalify_session_runs_total", snap.runs),
        ("scalify_memo_hits_total", snap.memo_hits),
        ("scalify_memo_misses_total", snap.memo_misses),
        ("scalify_memo_evictions_total", snap.memo_evictions),
        ("scalify_egraph_nodes_total", snap.egraph_nodes_total),
        ("scalify_ematch_tried_total", snap.ematch_tried_total),
        ("scalify_rule_applications_total", snap.rule_applications_total),
        ("scalify_cache_entries_loaded_total", snap.cache_entries_loaded),
    ];
    for (name, v) in counters {
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {v}");
    }
    let gauges: &[(&str, f64)] = &[
        ("scalify_memo_entries", snap.memo_entries as f64),
        ("scalify_rule_templates", snap.templates as f64),
        ("scalify_session_threads", snap.threads as f64),
        ("scalify_queue_capacity", snap.queue_capacity as f64),
        ("scalify_scheduler_workers", snap.scheduler_workers as f64),
        ("scalify_scheduler_inflight", state.scheduler.inflight() as f64),
        ("scalify_uptime_seconds", snap.uptime_secs),
    ];
    for (name, v) in gauges {
        let _ = writeln!(out, "# TYPE {name} gauge");
        let _ = writeln!(out, "{name} {v}");
    }
    obs::metrics::render_histogram(
        &mut out,
        "scalify_request_latency_seconds",
        &state.latency_hist,
    );
    // per-shard fleet series alongside the unlabeled aggregate (labels
    // carry no spaces: exposition sample lines stay `name value`)
    let _ = writeln!(out, "# TYPE scalify_shard_jobs_total counter");
    for (i, shard) in state.shards.iter().enumerate() {
        let _ = writeln!(
            out,
            "scalify_shard_jobs_total{{shard=\"{i}\"}} {}",
            shard.jobs.load(Ordering::Relaxed)
        );
    }
    let _ = writeln!(out, "# TYPE scalify_shard_request_latency_seconds histogram");
    for (i, shard) in state.shards.iter().enumerate() {
        obs::metrics::render_histogram_labeled(
            &mut out,
            "scalify_shard_request_latency_seconds",
            &format!("shard=\"{i}\""),
            &shard.latency,
        );
    }
    out.push_str(&obs::registry().render_prometheus());
    out
}

/// Run one verify job under the scheduler's admission bound, cold or —
/// when `prev` carries a usable [`VerifyState`] — incrementally. An
/// unusable state (parse failure, version skew, different graph) costs a
/// cold run plus a warning in the response, never an error: the same
/// degrade-only contract as the on-disk memo cache.
///
/// The job routes to a shard by model-family key, honors the request's
/// v2 options (priority and deadline at the admission gate, cancellation
/// and deadline at layer boundaries, streamed per-layer events), and
/// answers a cancelled/expired job with [`Response::Cancelled`].
fn run_verify_job(
    state: &Arc<ServiceState>,
    source: VerifySource,
    prev: Option<Json>,
    opts: VerifyOpts,
    protocol: u32,
    writer: &Arc<Mutex<TcpStream>>,
) -> Response {
    let t0 = obs::stamp();
    crate::faults::disturb("shard-route");
    let shard_idx = state.shards.index_for(family_key(&source));
    state.shards.shard(shard_idx).jobs.fetch_add(1, Ordering::Relaxed);

    let deadline = opts.deadline_secs.map(|s| Instant::now() + Duration::from_secs_f64(s));
    let mut control = VerifyControl::new();
    control.deadline = deadline;
    if protocol >= PROTOCOL_V2 && opts.stream {
        let ev_writer = Arc::clone(writer);
        let ev_id = opts.id.clone();
        control.progress = Some(Arc::new(move |p: LayerProgress| {
            let event = Response::Event(LayerEvent {
                id: ev_id.clone(),
                layer: p.layer,
                index: p.index as u64,
                total: p.total as u64,
                verified: p.verified,
            });
            // a dead client is discovered at the terminal write; the
            // verify itself never aborts on a lost event
            let _ = write_line(&ev_writer, &event);
        }) as Arc<dyn Fn(LayerProgress) + Send + Sync>);
    }
    let token = control.token();
    if let Some(id) = &opts.id {
        state.register_inflight(id, Arc::clone(&token));
    }

    let job_state = Arc::clone(state);
    let job_control = control.clone();
    // the whole job — pair construction included — runs under the
    // scheduler's admission bound; this call blocks (backpressure)
    // when the daemon is saturated, and a priority/deadline pair decides
    // queue order and queue expiry
    let outcome = state
        .scheduler
        .execute_prio(opts.priority, deadline, move || {
            crate::faults::check("shard-verify")?;
            let pair = build_pair(&source)?;
            let session = job_state.shards.shard(shard_idx).session();
            match prev {
                None => {
                    session.verify_controlled(&pair, &job_control).map(|r| (r, None))
                }
                Some(doc) => match VerifyState::from_json(&doc) {
                    Ok(prev_state) if prev_state.matches_graph(&pair.dist) => session
                        .verify_against_controlled(&pair, &prev_state, &job_control)
                        .map(|(r, _)| (r, None)),
                    Ok(prev_state) => {
                        let warning = format!(
                            "verify state is for '{}' on {} cores, request built '{}' on \
                             {} cores; ran cold",
                            prev_state.model,
                            prev_state.num_cores,
                            pair.dist.name,
                            pair.dist.num_cores
                        );
                        crate::log_debug!("verify_diff degraded to cold: {warning}");
                        session
                            .verify_controlled(&pair, &job_control)
                            .map(|r| (r, Some(warning)))
                    }
                    Err(why) => {
                        let warning = format!("ignoring verify state ({why}); ran cold");
                        crate::log_debug!("verify_diff degraded to cold: {why}");
                        session
                            .verify_controlled(&pair, &job_control)
                            .map(|r| (r, Some(warning)))
                    }
                },
            }
        })
        // a panicked job is a typed scheduler error: collapse it into the
        // same error channel as a failed verify, so the response below is
        // `Error { .. }` and the daemon keeps serving
        .and_then(|r| r);
    if let Some(id) = &opts.id {
        state.unregister_inflight(id, &token);
    }
    let latency_secs = t0.elapsed_secs();
    match outcome {
        Ok((report, warning)) => {
            state.jobs.fetch_add(1, Ordering::Relaxed);
            let nodes: u64 = report.layers.iter().map(|l| l.egraph_nodes as u64).sum();
            state.egraph_nodes_total.fetch_add(nodes, Ordering::Relaxed);
            let tried: u64 = report.layers.iter().map(|l| l.matches_tried as u64).sum();
            state.ematch_tried_total.fetch_add(tried, Ordering::Relaxed);
            let applied: u64 = report
                .layers
                .iter()
                .flat_map(|l| l.rules.iter())
                .map(|r| r.applications as u64)
                .sum();
            state.rule_applications_total.fetch_add(applied, Ordering::Relaxed);
            state.record_latency(latency_secs);
            state.shards.shard(shard_idx).latency.observe(latency_secs);
            if report.degraded {
                state.degraded.fetch_add(1, Ordering::Relaxed);
                obs::metrics::count("scalify_degraded_total", 1);
            }
            Response::VerifyDone {
                report,
                latency_secs,
                stats: state.snapshot_for(protocol),
                warning,
                id: opts.id,
            }
        }
        Err(e) => {
            let message = e.to_string();
            // a set token (cancel / supersession) or an expired deadline
            // is a cancellation, not a failure; v1 decoders read it as a
            // plain error either way
            if token.load(Ordering::SeqCst) || message.contains("deadline exceeded") {
                Response::Cancelled { id: opts.id, message }
            } else if message.contains("panicked") {
                // supervision: a panicking job may have poisoned the
                // shard's session internals mid-layer. Swap in a fresh
                // session warm from the persistent cache (sibling shards
                // keep serving throughout) and answer with a typed
                // retryable error so the client re-submits.
                let warm = state.cache.as_ref().map(|c| c.entries()).unwrap_or_default();
                let preloaded = state.shards.restart_shard(shard_idx, &warm);
                crate::log_warn!(
                    "shard {shard_idx} restarted after a crashed verify job \
                     ({preloaded} memo entries preloaded warm)"
                );
                Response::Error {
                    message: format!(
                        "retryable: shard {shard_idx} restarted after a crashed \
                         verify job ({message}); retry the request"
                    ),
                }
            } else {
                Response::Error { message }
            }
        }
    }
}

/// Materialize the graph pair a verify request names.
fn build_pair(source: &VerifySource) -> Result<GraphPair> {
    // test-only trapdoor: a deliberately panicking job, to prove the
    // scheduler isolates panics to one response (compiled out of release)
    #[cfg(test)]
    if matches!(source, VerifySource::Model { model, .. } if model == "__panic__") {
        panic!("deliberate test panic in a verify job");
    }
    match source {
        VerifySource::Model { model, par, layers, edit_layer } => {
            let pair = cli::model_pair(model, cli::parallelism(par)?, *layers)?;
            match edit_layer {
                None => Ok(pair),
                Some(layer) => crate::diff::one_op_edit(&pair, *layer),
            }
        }
        VerifySource::Bug { id } => {
            let case = crate::bugs::reproduced_bugs()
                .into_iter()
                .chain(crate::bugs::new_bugs())
                .chain(crate::bugs::parallel_transform_bugs())
                .chain(crate::bugs::replica_group_bugs())
                .find(|c| c.id == id.as_str())
                .ok_or_else(|| {
                    ScalifyError::model_spec(format!("unknown bug-corpus id '{id}'"))
                })?;
            Ok((case.build)())
        }
        VerifySource::Hlo { base, dist, cores } => {
            let bg = parse_hlo_module(base, 1).ctx("inline base_hlo")?;
            let dg = parse_hlo_module(dist, *cores).ctx("inline dist_hlo")?;
            GraphPair::replicated(bg, dg)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::client::Client;
    use crate::verifier::Session;

    fn tiny_serve_config() -> ServeConfig {
        ServeConfig {
            queue_capacity: 4,
            workers: 2,
            verify: VerifyConfig { threads: 2, ..VerifyConfig::default() },
            ..ServeConfig::default()
        }
    }

    #[test]
    fn serve_verify_stats_shutdown_round_trip() {
        let server = Server::start(tiny_serve_config()).unwrap();
        let addr = server.local_addr().to_string();

        let mut client = Client::connect(&addr).unwrap();
        let (report, _latency, stats) = client
            .verify(VerifySource::Model {
                model: "llama-tiny".into(),
                par: "tp2".into(),
                layers: None,
                edit_layer: None,
            })
            .unwrap();
        assert!(report.verified(), "{:?}", report.verdict);
        assert_eq!(stats.jobs, 1);

        let stats = client.stats().unwrap();
        assert_eq!(stats.jobs, 1);
        assert!(stats.memo_entries > 0);
        assert_eq!(stats.cache_entries_loaded, 0);

        client.shutdown().unwrap();
        server.wait();
    }

    #[test]
    fn second_request_hits_the_shared_memo() {
        let server = Server::start(tiny_serve_config()).unwrap();
        let addr = server.local_addr().to_string();
        let source = VerifySource::Model {
            model: "llama-tiny".into(),
            par: "tp2".into(),
            layers: None,
            edit_layer: None,
        };

        let mut client = Client::connect(&addr).unwrap();
        let (_, _, first) = client.verify(source.clone()).unwrap();
        let (report, _, second) = client.verify(source).unwrap();
        assert!(report.verified());
        assert!(
            second.memo_hits > first.memo_hits,
            "second identical request must replay the memo: {first:?} -> {second:?}"
        );
        assert!(report.layers.iter().all(|l| l.memoized));

        client.shutdown().unwrap();
        server.wait();
    }

    #[test]
    fn metrics_request_returns_prometheus_text() {
        let server = Server::start(tiny_serve_config()).unwrap();
        let addr = server.local_addr().to_string();
        let mut client = Client::connect(&addr).unwrap();
        client
            .verify(VerifySource::Model {
                model: "llama-tiny".into(),
                par: "tp2".into(),
                layers: None,
                edit_layer: None,
            })
            .unwrap();

        let text = client.metrics().unwrap();
        // memo, e-match and latency-histogram series must all be present
        assert!(text.contains("# TYPE scalify_jobs_total counter"), "{text}");
        assert!(text.contains("scalify_jobs_total 1"), "{text}");
        assert!(text.contains("scalify_memo_hits_total"), "{text}");
        assert!(text.contains("scalify_memo_misses_total"), "{text}");
        assert!(text.contains("scalify_ematch_tried_total"), "{text}");
        assert!(
            text.contains("# TYPE scalify_request_latency_seconds histogram"),
            "{text}"
        );
        assert!(
            text.contains("scalify_request_latency_seconds_bucket{le=\"+Inf\"} 1"),
            "{text}"
        );
        assert!(text.contains("scalify_request_latency_seconds_count 1"), "{text}");
        // exposition-format shape: every sample line is `name value` with
        // a parseable float value
        for line in text.lines() {
            if line.starts_with('#') || line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let _name = parts.next().expect("sample name");
            let value = parts.next().unwrap_or_else(|| panic!("no value in {line:?}"));
            assert!(parts.next().is_none(), "extra token in {line:?}");
            assert!(value.parse::<f64>().is_ok(), "unparseable value in {line:?}");
        }

        client.shutdown().unwrap();
        server.wait();
    }

    #[test]
    fn malformed_and_unknown_requests_keep_the_connection_alive() {
        let server = Server::start(tiny_serve_config()).unwrap();
        let addr = server.local_addr().to_string();
        let mut client = Client::connect(&addr).unwrap();

        let resp = client.request_line("this is not json").unwrap();
        assert!(matches!(resp, Response::Error { .. }));
        let resp = client
            .request(&Request::Verify(VerifySource::Model {
                model: "gpt-5".into(),
                par: "tp2".into(),
                layers: None,
                edit_layer: None,
            }))
            .unwrap();
        match resp {
            Response::Error { message } => assert!(message.contains("gpt-5"), "{message}"),
            other => panic!("expected error, got {other:?}"),
        }

        // the connection still serves real work afterwards
        let stats = client.stats().unwrap();
        assert_eq!(stats.jobs, 0);
        client.shutdown().unwrap();
        server.wait();
    }

    #[test]
    fn verify_diff_replays_unchanged_layers_and_degrades_on_bad_state() {
        let server = Server::start(tiny_serve_config()).unwrap();
        let addr = server.local_addr().to_string();
        let source = VerifySource::Model {
            model: "llama-tiny".into(),
            par: "tp2".into(),
            layers: Some(4),
            edit_layer: None,
        };

        // capture the state the client would persist: verify locally with
        // the same pair the daemon builds, then hand the document over
        let pair = build_pair(&source).unwrap();
        let session = Session::new(VerifyConfig {
            threads: 2,
            parallel: false,
            ..VerifyConfig::default()
        });
        let (_, captured) = session.verify_capture(&pair).unwrap();

        let mut client = Client::connect(&addr).unwrap();
        let (report, _, _, warning) =
            client.verify_diff(source.clone(), captured.to_json()).unwrap();
        assert!(warning.is_none(), "clean state must not warn: {warning:?}");
        assert!(report.verified());
        assert!(
            report.layers.iter().all(|l| l.reused),
            "unchanged graph must replay every layer: {report:?}"
        );

        // a one-op edit re-verifies exactly the touched layer
        let edited = VerifySource::Model {
            model: "llama-tiny".into(),
            par: "tp2".into(),
            layers: Some(4),
            edit_layer: Some(1),
        };
        let (report, _, _, warning) =
            client.verify_diff(edited, captured.to_json()).unwrap();
        assert!(warning.is_none());
        assert!(report.verified());
        assert_eq!(report.layers.iter().filter(|l| l.reverified).count(), 1);
        assert!(report.layers.iter().any(|l| l.reverified && l.delta_nodes > 0));

        // garbage state degrades to a cold verify with a warning
        let (report, _, _, warning) = client
            .verify_diff(
                source,
                crate::report::json::Json::Obj(vec![(
                    "format".into(),
                    crate::report::json::Json::Num(9999.0),
                )]),
            )
            .unwrap();
        assert!(report.verified());
        let warning = warning.expect("bad state must warn");
        assert!(warning.contains("ran cold"), "{warning}");
        assert!(report.layers.iter().all(|l| !l.reused));

        client.shutdown().unwrap();
        server.wait();
    }

    #[test]
    fn panicking_verify_job_yields_an_error_and_the_daemon_keeps_serving() {
        let server = Server::start(tiny_serve_config()).unwrap();
        let addr = server.local_addr().to_string();
        let mut client = Client::connect(&addr).unwrap();

        // the deliberately-panicking job must answer with a typed error…
        let resp = client
            .request(&Request::Verify(VerifySource::Model {
                model: "__panic__".into(),
                par: "tp2".into(),
                layers: None,
                edit_layer: None,
            }))
            .unwrap();
        match resp {
            Response::Error { message } => {
                assert!(message.contains("panicked"), "{message}");
                assert!(message.contains("deliberate test panic"), "{message}");
                // the supervisor marks the error retryable and names the
                // restarted shard
                assert!(message.starts_with("retryable: "), "{message}");
                assert!(message.contains("restarted"), "{message}");
            }
            other => panic!("expected error, got {other:?}"),
        }

        // …and the very next request on the same daemon still verifies
        // (the admission slot released; the supervisor swapped the shard's
        // session for a fresh one)
        let (report, _, stats) = client
            .verify(VerifySource::Model {
                model: "llama-tiny".into(),
                par: "tp2".into(),
                layers: None,
                edit_layer: None,
            })
            .unwrap();
        assert!(report.verified(), "{:?}", report.verdict);
        assert_eq!(stats.jobs, 1);

        // the restart is visible in the v2 counters
        client.hello(PROTOCOL_V2).unwrap();
        let stats = client.stats().unwrap();
        assert_eq!(stats.shard_restarts_total, 1, "{stats:?}");

        client.shutdown().unwrap();
        server.wait();
    }

    #[test]
    fn bug_corpus_requests_come_back_unverified() {
        let server = Server::start(tiny_serve_config()).unwrap();
        let addr = server.local_addr().to_string();
        let mut client = Client::connect(&addr).unwrap();
        let (report, _, _) =
            client.verify(VerifySource::Bug { id: "T4#1".into() }).unwrap();
        assert!(!report.verified(), "bug-corpus pairs must not verify");
        client.shutdown().unwrap();
        server.wait();
    }

    #[test]
    fn malformed_inline_hlo_is_a_typed_error_naming_the_spec() {
        let server = Server::start(tiny_serve_config()).unwrap();
        let addr = server.local_addr().to_string();
        let mut client = Client::connect(&addr).unwrap();

        let base = "HloModule b\n\nENTRY main {\n  p = f32[4,4]{1,0} parameter(0)\n  \
                    ROOT s = f32[2,4]{1,0} slice(p), slice={[0:2], [0:4]}\n}\n";
        let dist_with = |root: &str| {
            format!(
                "HloModule d\n\nENTRY main {{\n  p = f32[4,4]{{1,0}} parameter(0)\n  {root}\n}}\n"
            )
        };
        // (malformed ROOT line, fragment its error must carry)
        let cases = [
            ("ROOT s = f32[2,4]{1,0} slice(p), slice={[0:2], [0:}", "missing a limit"),
            ("ROOT s = f32[2,4]{1,0} slice(p), slice={}", "names no dimensions"),
            ("ROOT t = f32[4,4]{1,0} transpose(p)", "transpose without dims"),
            (
                "ROOT c = f32[8,4]{1,0} concatenate(p, p), dimensions={}",
                "name no dimension",
            ),
        ];
        for (root, needle) in cases {
            let resp = client
                .request(&Request::Verify(VerifySource::Hlo {
                    base: base.into(),
                    dist: dist_with(root),
                    cores: 2,
                }))
                .unwrap();
            match resp {
                Response::Error { message } => {
                    assert!(message.contains("parse error"), "{root}: {message}");
                    assert!(message.contains(needle), "{root}: {message}");
                    // localization: the failing instruction is named
                    assert!(message.contains("parsing instruction"), "{root}: {message}");
                }
                other => panic!("expected a parse error for {root}, got {other:?}"),
            }
        }

        // the daemon keeps serving well-formed work on the same connection
        let (report, _, _) = client
            .verify(VerifySource::Hlo {
                base: base.into(),
                dist: base.replace("HloModule b", "HloModule d"),
                cores: 2,
            })
            .unwrap();
        assert!(report.verified(), "{:?}", report.verdict);

        client.shutdown().unwrap();
        server.wait();
    }

    fn zoo_source() -> VerifySource {
        VerifySource::Model {
            model: "llama-tiny".into(),
            par: "tp2".into(),
            layers: None,
            edit_layer: None,
        }
    }

    #[test]
    fn hello_negotiates_down_and_unlocks_shard_stats() {
        let server = Server::start(tiny_serve_config()).unwrap();
        let addr = server.local_addr().to_string();
        let mut client = Client::connect(&addr).unwrap();

        // a from-the-future client is met at the daemon's ceiling
        assert_eq!(client.hello(9).unwrap(), PROTOCOL_V2);
        let stats = client.stats().unwrap();
        assert_eq!(stats.protocol, PROTOCOL_V2);
        assert_eq!(stats.shards.len(), 1, "v2 stats must carry the shard rows");
        assert_eq!(stats.shards[0].jobs, 0);

        // a v1 hello downgrades the connection back
        assert_eq!(client.hello(1).unwrap(), 1);
        let stats = client.stats().unwrap();
        assert_eq!(stats.protocol, 1);
        assert!(stats.shards.is_empty(), "v1 stats must not carry shard rows");

        client.shutdown().unwrap();
        server.wait();
    }

    #[test]
    fn cancel_with_no_such_inflight_id_acks_false() {
        let server = Server::start(tiny_serve_config()).unwrap();
        let addr = server.local_addr().to_string();
        let mut client = Client::connect(&addr).unwrap();
        client.hello(PROTOCOL_V2).unwrap();
        assert!(!client.cancel("no-such-job").unwrap());
        client.shutdown().unwrap();
        server.wait();
    }

    #[test]
    fn sharded_daemon_keeps_memo_hits_and_counts_per_shard_jobs() {
        let server = Server::start(ServeConfig {
            shards: 2,
            ..tiny_serve_config()
        })
        .unwrap();
        let addr = server.local_addr().to_string();
        let mut client = Client::connect(&addr).unwrap();

        // same family key routes to the same shard, so the second
        // identical request replays that shard's warm memo
        let (_, _, first) = client.verify(zoo_source()).unwrap();
        let (report, _, second) = client.verify(zoo_source()).unwrap();
        assert!(report.verified());
        assert!(
            second.memo_hits > first.memo_hits,
            "sharded daemon must keep memo locality: {first:?} -> {second:?}"
        );

        client.hello(PROTOCOL_V2).unwrap();
        let stats = client.stats().unwrap();
        assert_eq!(stats.shards.len(), 2);
        let routed: u64 = stats.shards.iter().map(|s| s.jobs).sum();
        assert_eq!(routed, 2, "both jobs must be counted on their shard");
        assert!(
            stats.shards.iter().any(|s| s.jobs == 2),
            "one family must pin to one shard: {:?}",
            stats.shards
        );

        client.shutdown().unwrap();
        server.wait();
    }

    #[test]
    fn expired_deadline_degrades_to_a_partial_verdict() {
        let server = Server::start(tiny_serve_config()).unwrap();
        let addr = server.local_addr().to_string();
        let mut client = Client::connect(&addr).unwrap();
        client.hello(PROTOCOL_V2).unwrap();

        let opts = VerifyOpts {
            id: Some("doomed".into()),
            deadline_secs: Some(0.000000001),
            ..VerifyOpts::default()
        };
        let resp = client
            .verify_opts(&Request::Verify(zoo_source()), &opts, |_| {})
            .unwrap();
        match resp {
            Response::VerifyDone { report, id, stats, .. } => {
                assert_eq!(id.as_deref(), Some("doomed"));
                assert!(report.degraded, "an expired deadline must degrade: {report:?}");
                let at = report.first_unverified.as_deref().expect("first unverified");
                assert!(at.starts_with("layer "), "{at}");
                assert!(report.summary().contains("DEGRADED"), "{}", report.summary());
                assert_eq!(stats.degraded_total, 1, "{stats:?}");
            }
            other => panic!("expected a degraded VerifyDone, got {other:?}"),
        }

        // the daemon still serves fresh work, and the id registry is clean
        let (report, _, _) = client.verify(zoo_source()).unwrap();
        assert!(report.verified());
        assert!(!client.cancel("doomed").unwrap(), "finished job must unregister");

        client.shutdown().unwrap();
        server.wait();
    }

    #[test]
    fn streamed_verify_emits_one_event_per_layer_then_the_report() {
        let server = Server::start(tiny_serve_config()).unwrap();
        let addr = server.local_addr().to_string();
        let mut client = Client::connect(&addr).unwrap();
        client.hello(PROTOCOL_V2).unwrap();

        let opts = VerifyOpts {
            id: Some("streamed".into()),
            stream: true,
            ..VerifyOpts::default()
        };
        let mut events = Vec::new();
        let resp = client
            .verify_opts(&Request::Verify(zoo_source()), &opts, |e| events.push(e))
            .unwrap();
        match resp {
            Response::VerifyDone { report, id, .. } => {
                assert!(report.verified(), "{:?}", report.verdict);
                assert_eq!(id.as_deref(), Some("streamed"));
                assert_eq!(
                    events.len(),
                    report.layers.len(),
                    "one event per verified layer: {events:?}"
                );
            }
            other => panic!("expected VerifyDone, got {other:?}"),
        }
        for event in &events {
            assert_eq!(event.id.as_deref(), Some("streamed"));
            assert_eq!(event.total as usize, events.len());
            assert!(event.verified, "{event:?}");
        }
        // events arrive in assembly order
        let indices: Vec<u64> = events.iter().map(|e| e.index).collect();
        let sorted = {
            let mut s = indices.clone();
            s.sort_unstable();
            s
        };
        assert_eq!(indices, sorted, "per-layer events must arrive in order");

        // a v1-style request on the same negotiated connection streams
        // nothing (stream defaults off)
        let (report, _, _) = client.verify(zoo_source()).unwrap();
        assert!(report.verified());

        client.shutdown().unwrap();
        server.wait();
    }
}
