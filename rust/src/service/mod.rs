//! The verification service: `scalify serve` / `scalify client`.
//!
//! Everything before this module is library- or process-shaped: a
//! [`crate::verifier::Session`] amortizes compiled templates and the
//! layer memo across calls, but dies with its process, so a fleet of CI
//! jobs or training controllers each pay the cold start. This module
//! turns the session into a shared long-running daemon — a sharded
//! verification fleet behind one socket:
//!
//! * [`protocol`] — the newline-delimited JSON wire format (`verify`,
//!   `stats`, `shutdown`; v2 adds `hello` negotiation, request ids,
//!   priorities, deadlines, streamed per-layer events and `cancel`),
//!   reusing the crate's hand-rolled [`crate::report::json`] machinery
//!   — the normative reference is `docs/PROTOCOL.md`,
//! * [`scheduler`] — a bounded admission queue with blocking
//!   backpressure, priority ordering and queue deadlines, layered on
//!   the reusable [`crate::util::WorkerPool`],
//! * [`cache`] — the persistent on-disk layer-memo store
//!   (`--cache-dir`): a single append-only segment file plus an
//!   in-memory fingerprint index, loaded at startup and appended on
//!   write, so warm state survives restarts and is shared across
//!   processes,
//! * [`shard`] — the [`shard::ShardPool`]: N sessions behind one
//!   daemon, routed by model-family key, sharing one compiled rule
//!   set,
//! * [`server`] — the accept loop, protocol negotiation and connection
//!   handling around the shard pool, and
//! * [`client`] — the blocking client the `scalify client` subcommand
//!   and the tests drive the daemon with: per-attempt socket timeouts,
//!   plus [`client::RetryPolicy`] reconnect-and-retry with exponential
//!   backoff for transient faults (`retryable: `-prefixed daemon errors
//!   and transport failures).
//!
//! Failure domains and the chaos-testing story (the [`crate::faults`]
//! registry, shard supervision, deadline degradation) are documented in
//! `ARCHITECTURE.md` § "Failure domains & recovery".

pub mod cache;
pub mod client;
pub mod protocol;
pub mod scheduler;
pub mod server;
pub mod shard;

pub use cache::{CacheLoad, MemoCache, CACHE_FILE, CACHE_FORMAT_VERSION};
pub use client::{
    is_retryable, next_request_id, verify_with_retry, Client, RetryPolicy,
    DEFAULT_TIMEOUT,
};
pub use protocol::{
    LayerEvent, Request, Response, ShardStat, StatsSnapshot, VerifyOpts, VerifySource,
    PROTOCOL_V2, PROTOCOL_VERSION,
};
pub use scheduler::Scheduler;
pub use server::{ServeConfig, Server};
pub use shard::{Shard, ShardPool};
