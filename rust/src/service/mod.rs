//! The verification service: `scalify serve` / `scalify client`.
//!
//! Everything before this module is library- or process-shaped: a
//! [`crate::verifier::Session`] amortizes compiled templates and the
//! layer memo across calls, but dies with its process, so a fleet of CI
//! jobs or training controllers each pay the cold start. This module
//! turns the session into a shared long-running daemon:
//!
//! * [`protocol`] — the newline-delimited JSON wire format (`verify`,
//!   `stats`, `shutdown`), reusing the crate's hand-rolled
//!   [`crate::report::json`] machinery,
//! * [`scheduler`] — a bounded admission queue with blocking
//!   backpressure layered on the reusable [`crate::util::WorkerPool`],
//! * [`cache`] — the persistent on-disk layer-memo store
//!   (`--cache-dir`): stable-fingerprint-keyed entries loaded at startup
//!   and flushed on write, so warm state survives restarts and is shared
//!   across processes,
//! * [`server`] — the accept loop and connection handling around ONE
//!   shared session, and
//! * [`client`] — the blocking client the `scalify client` subcommand
//!   and the tests drive the daemon with.

pub mod cache;
pub mod client;
pub mod protocol;
pub mod scheduler;
pub mod server;

pub use cache::{CacheLoad, MemoCache, CACHE_FILE, CACHE_FORMAT_VERSION};
pub use client::Client;
pub use protocol::{Request, Response, StatsSnapshot, VerifySource, PROTOCOL_VERSION};
pub use scheduler::Scheduler;
pub use server::{ServeConfig, Server};
