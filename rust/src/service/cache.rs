//! Persistent on-disk layer-memo store (`scalify serve --cache-dir`).
//!
//! Verified [`MemoEntry`]s are JSON-serialized keyed by their **stable**
//! structural fingerprint (see [`crate::partition::fingerprint`]), loaded
//! at daemon startup and flushed on every write, so a restarted daemon —
//! or a different CI job pointed at the same directory — starts warm:
//! its first request replays every layer an earlier process already
//! proved.
//!
//! The file records both a cache format version and the fingerprint
//! scheme version; any mismatch, parse failure or torn write **degrades
//! to a cold start with a warning** — a corrupted cache can cost time,
//! never correctness. Writes go through a temp file + rename so a crash
//! mid-flush leaves the previous generation intact. Fingerprints are
//! written as fixed-width hex strings (JSON numbers are doubles and
//! cannot carry 64 bits).

use crate::error::Result;
use crate::partition::{check_fingerprint_version, MemoEntry, FINGERPRINT_VERSION};
use crate::report::json::Json;
use crate::report::{json_checksum, rel_summary_from_json, rel_summary_to_json};
use rustc_hash::FxHashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// On-disk format version (independent of the fingerprint scheme).
pub const CACHE_FORMAT_VERSION: u32 = 1;

/// File name inside `--cache-dir`.
pub const CACHE_FILE: &str = "layer-memo.json";

/// Outcome of opening a cache directory.
#[derive(Clone, Debug, Default)]
pub struct CacheLoad {
    /// Entries successfully loaded.
    pub loaded: usize,
    /// Present when the store degraded to a cold start (corrupt file,
    /// version skew, unreadable directory).
    pub warning: Option<String>,
}

/// Handle on a cache directory: an in-memory mirror plus flush-on-write
/// persistence. Shared behind `Arc` between the session's memo-write hook
/// and the service's stats plumbing.
///
/// The mirror is **bounded** (same spirit as `VerifyConfig::memo_capacity`
/// — a long-lived daemon must not grow without limit): once `capacity`
/// entries are held, further fingerprints are dropped from persistence,
/// first-come-first-kept (the session's own memo still serves them for
/// its lifetime; an LRU mirror would force a full-file rewrite per
/// eviction for a workload that has already outgrown warm-start anyway).
/// The bound also caps the flush cost, since every write rewrites the
/// whole file.
pub struct MemoCache {
    path: PathBuf,
    capacity: usize,
    mirror: Mutex<FxHashMap<u64, MemoEntry>>,
    /// Serializes flushes against each other without holding `mirror`
    /// during disk I/O, so stats/preload readers and other memo-write
    /// hooks are never blocked behind a file write. Holds the number of
    /// entries already persisted: recorders that queued behind a flush
    /// which already covered their entry skip their own write, so a
    /// burst of fresh layers costs ~one file rewrite, not one each.
    flush_lock: Mutex<usize>,
}

impl MemoCache {
    /// Open with the default capacity
    /// ([`crate::partition::DEFAULT_MEMO_CAPACITY`]).
    pub fn open(dir: &Path) -> Result<(MemoCache, CacheLoad)> {
        MemoCache::open_with_capacity(dir, crate::partition::DEFAULT_MEMO_CAPACITY)
    }

    /// Open (creating the directory if needed) and load whatever previous
    /// processes persisted. Never fails on a bad cache *file* — that is a
    /// cold start plus [`CacheLoad::warning`]; only an unusable directory
    /// is an error.
    pub fn open_with_capacity(
        dir: &Path,
        capacity: usize,
    ) -> Result<(MemoCache, CacheLoad)> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(CACHE_FILE);
        let (map, load) = match std::fs::read_to_string(&path) {
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                (FxHashMap::default(), CacheLoad::default())
            }
            Err(e) => (
                FxHashMap::default(),
                CacheLoad {
                    loaded: 0,
                    warning: Some(format!(
                        "cache file {} is unreadable ({e}); starting cold",
                        path.display()
                    )),
                },
            ),
            Ok(text) => match parse_cache(&text) {
                Ok(map) => {
                    let loaded = map.len();
                    (map, CacheLoad { loaded, warning: None })
                }
                Err(why) => (
                    FxHashMap::default(),
                    CacheLoad {
                        loaded: 0,
                        warning: Some(format!(
                            "ignoring cache file {} ({why}); starting cold",
                            path.display()
                        )),
                    },
                ),
            },
        };
        let persisted = map.len();
        Ok((
            MemoCache {
                path,
                capacity: capacity.max(1),
                mirror: Mutex::new(map),
                flush_lock: Mutex::new(persisted),
            },
            load,
        ))
    }

    /// Maximum entries persisted.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// The backing file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Entries currently mirrored (== persisted, modulo write failures).
    pub fn len(&self) -> usize {
        self.mirror.lock().expect("cache lock").len()
    }

    /// True when the mirror is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of every entry, for preloading a fresh session's memo.
    pub fn entries(&self) -> Vec<(u64, MemoEntry)> {
        self.mirror
            .lock()
            .expect("cache lock")
            .iter()
            .map(|(fp, e)| (*fp, e.clone()))
            .collect()
    }

    /// Record one entry and flush the store (the session's memo-write
    /// hook). Entries are immutable once verified, so a known fingerprint
    /// is a no-op — repeat hits never touch the disk — and a full mirror
    /// drops new fingerprints instead of growing. Write failures are
    /// reported on stderr, not propagated: persistence is an optimization
    /// and must never fail a verify request.
    pub fn record(&self, fp: u64, entry: &MemoEntry) {
        {
            let mut mirror = self.mirror.lock().expect("cache lock");
            if mirror.contains_key(&fp) || mirror.len() >= self.capacity {
                return;
            }
            mirror.insert(fp, entry.clone());
        }
        // flushes serialize on their own lock; snapshotting *inside* it
        // makes later flushes see supersets, so the last write on disk
        // always carries every recorded entry. A recorder whose entry a
        // queued-ahead flush already covered skips its own write.
        let mut persisted = self.flush_lock.lock().expect("flush lock");
        let snapshot = self.entries();
        if snapshot.len() <= *persisted {
            return;
        }
        let count = snapshot.len();
        match self.flush(snapshot) {
            Ok(()) => *persisted = count,
            Err(e) => crate::log_warn!(
                "cache flush to {} failed: {e}",
                self.path.display()
            ),
        }
    }

    fn flush(&self, mut entries: Vec<(u64, MemoEntry)>) -> std::io::Result<()> {
        // stable file ordering: deterministic bytes for identical content
        entries.sort_by_key(|(fp, _)| *fp);
        let arr =
            Json::Arr(entries.iter().map(|(fp, e)| entry_to_json(*fp, e)).collect());
        let checksum = json_checksum(&arr);
        let doc = Json::Obj(vec![
            ("format".into(), Json::Num(CACHE_FORMAT_VERSION as f64)),
            (
                "fingerprint_version".into(),
                Json::Num(FINGERPRINT_VERSION as f64),
            ),
            ("checksum".into(), Json::Str(checksum)),
            ("entries".into(), arr),
        ]);
        // per-process temp name: concurrent daemons sharing one cache dir
        // must not interleave writes into the same temp file (the atomic
        // rename then keeps whichever finished last, both valid)
        let tmp = self.path.with_extension(format!("tmp.{}", std::process::id()));
        std::fs::write(&tmp, doc.render_pretty())?;
        std::fs::rename(&tmp, &self.path)
    }
}

fn parse_cache(text: &str) -> std::result::Result<FxHashMap<u64, MemoEntry>, String> {
    let doc = Json::parse(text).map_err(|e| format!("corrupted JSON: {e}"))?;
    let format = doc.u64_at("format").ok_or("missing 'format' version")?;
    if format != CACHE_FORMAT_VERSION as u64 {
        return Err(format!(
            "cache format v{format} (this build reads v{CACHE_FORMAT_VERSION})"
        ));
    }
    // one shared gate with the diff VerifyState: skew degrades to a cold
    // start with identical wording everywhere fingerprints are persisted
    check_fingerprint_version(&doc)?;
    let items = doc
        .get("entries")
        .and_then(Json::as_arr)
        .ok_or("missing 'entries' array")?;
    let expected = doc.str_at("checksum").ok_or("missing 'checksum'")?;
    let actual = json_checksum(&Json::Arr(items.to_vec()));
    if actual != expected {
        return Err(format!(
            "checksum mismatch (file says {expected}, contents hash to {actual})"
        ));
    }
    let mut map = FxHashMap::default();
    for item in items {
        let (fp, entry) = entry_from_json(item)?;
        map.insert(fp, entry);
    }
    Ok(map)
}

fn entry_to_json(fp: u64, e: &MemoEntry) -> Json {
    Json::Obj(vec![
        ("fp".into(), Json::Str(format!("{fp:016x}"))),
        ("verified".into(), Json::Bool(e.verified)),
        ("egraph_nodes".into(), Json::Num(e.egraph_nodes as f64)),
        ("egraph_classes".into(), Json::Num(e.egraph_classes as f64)),
        (
            "out_rels".into(),
            Json::Arr(e.out_rels.iter().map(rel_summary_to_json).collect()),
        ),
    ])
}

fn entry_from_json(doc: &Json) -> std::result::Result<(u64, MemoEntry), String> {
    let fp_hex = doc.str_at("fp").ok_or("entry is missing 'fp'")?;
    let fp = u64::from_str_radix(fp_hex, 16)
        .map_err(|_| format!("bad fingerprint '{fp_hex}'"))?;
    let verified = doc.bool_at("verified").ok_or("entry is missing 'verified'")?;
    let egraph_nodes =
        doc.u64_at("egraph_nodes").ok_or("entry is missing 'egraph_nodes'")? as usize;
    // absent in caches written before the field existed: stats-only, so
    // default to 0 instead of invalidating the warm start
    let egraph_classes = doc.u64_at("egraph_classes").unwrap_or(0) as usize;
    let rels = doc
        .get("out_rels")
        .and_then(Json::as_arr)
        .ok_or("entry is missing 'out_rels'")?;
    let out_rels = rels
        .iter()
        .map(rel_summary_from_json)
        .collect::<std::result::Result<Vec<_>, String>>()?;
    Ok((fp, MemoEntry { verified, out_rels, egraph_nodes, egraph_classes }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ReduceKind;
    use crate::verifier::boundary::RelSummary;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "scalify-cache-test-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample_entry() -> MemoEntry {
        MemoEntry {
            verified: true,
            out_rels: vec![
                RelSummary::Duplicate,
                RelSummary::Sharded { dim: 1, parts: 4, axis: 1 },
                RelSummary::MeshSharded { entries: vec![(0, 2, 0), (1, 2, 1)] },
                RelSummary::Partial { kind: ReduceKind::Add, axes: 0b10 },
            ],
            egraph_nodes: 321,
            egraph_classes: 123,
        }
    }

    #[test]
    fn record_then_reopen_round_trips() {
        let dir = tmpdir("roundtrip");
        {
            let (cache, load) = MemoCache::open(&dir).unwrap();
            assert_eq!(load.loaded, 0);
            assert!(load.warning.is_none());
            cache.record(0xdead_beef_0000_0042, &sample_entry());
            cache.record(7, &sample_entry());
            // duplicate fingerprints are no-ops
            cache.record(7, &sample_entry());
            assert_eq!(cache.len(), 2);
        }
        let (cache, load) = MemoCache::open(&dir).unwrap();
        assert_eq!(load.loaded, 2, "{:?}", load.warning);
        assert!(load.warning.is_none());
        let entries = cache.entries();
        let (_, e) = entries
            .iter()
            .find(|(fp, _)| *fp == 0xdead_beef_0000_0042)
            .expect("high-bit fingerprint survives the hex encoding");
        assert_eq!(e, &sample_entry());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_file_degrades_to_cold_start_with_warning() {
        let dir = tmpdir("corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(CACHE_FILE), "{ this is not json").unwrap();
        let (cache, load) = MemoCache::open(&dir).unwrap();
        assert_eq!(load.loaded, 0);
        let warning = load.warning.expect("corruption must warn");
        assert!(warning.contains("starting cold"), "{warning}");
        // the cache still works: a write replaces the corrupt file
        cache.record(1, &sample_entry());
        let (_, load) = MemoCache::open(&dir).unwrap();
        assert_eq!(load.loaded, 1);
        assert!(load.warning.is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn version_skew_degrades_to_cold_start() {
        let dir = tmpdir("skew");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join(CACHE_FILE),
            format!(
                "{{\"format\":{CACHE_FORMAT_VERSION},\"fingerprint_version\":9999,\
                 \"entries\":[]}}"
            ),
        )
        .unwrap();
        let (_, load) = MemoCache::open(&dir).unwrap();
        assert_eq!(load.loaded, 0);
        assert!(load.warning.unwrap().contains("scheme v9999"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bitrot_in_a_parseable_file_fails_the_checksum_and_starts_cold() {
        let dir = tmpdir("bitrot");
        {
            let (cache, _) = MemoCache::open(&dir).unwrap();
            cache.record(0x1111_2222_3333_4444, &sample_entry());
        }
        // flip one hex digit of the stored fingerprint: still valid JSON,
        // still valid hex — but now it names a different layer structure
        let path = dir.join(CACHE_FILE);
        let text = std::fs::read_to_string(&path).unwrap();
        let tampered = text.replace("1111222233334444", "1111222233334445");
        assert_ne!(text, tampered, "fixture must actually change");
        std::fs::write(&path, tampered).unwrap();

        let (_, load) = MemoCache::open(&dir).unwrap();
        assert_eq!(load.loaded, 0, "tampered entries must not be replayed");
        assert!(load.warning.unwrap().contains("checksum mismatch"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn duplicate_records_never_rewrite_the_file() {
        let dir = tmpdir("coalesce");
        let (cache, _) = MemoCache::open(&dir).unwrap();
        cache.record(1, &sample_entry());
        let first = std::fs::metadata(dir.join(CACHE_FILE)).unwrap().modified().ok();
        // same fingerprint again: no mirror change, no rewrite
        cache.record(1, &sample_entry());
        let second = std::fs::metadata(dir.join(CACHE_FILE)).unwrap().modified().ok();
        assert_eq!(first, second);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mirror_is_bounded_by_capacity() {
        let dir = tmpdir("bounded");
        let (cache, _) = MemoCache::open_with_capacity(&dir, 2).unwrap();
        cache.record(1, &sample_entry());
        cache.record(2, &sample_entry());
        cache.record(3, &sample_entry()); // dropped: mirror is full
        assert_eq!(cache.len(), 2);
        let (reopened, load) = MemoCache::open_with_capacity(&dir, 2).unwrap();
        assert_eq!(load.loaded, 2);
        assert!(reopened.entries().iter().all(|(fp, _)| *fp != 3));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_directory_is_created() {
        let dir = tmpdir("mkdir").join("nested/deeper");
        let (cache, load) = MemoCache::open(&dir).unwrap();
        assert_eq!(load.loaded, 0);
        cache.record(3, &sample_entry());
        assert!(dir.join(CACHE_FILE).exists());
        let _ = std::fs::remove_dir_all(dir.parent().unwrap().parent().unwrap());
    }
}
