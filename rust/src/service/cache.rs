//! Persistent on-disk layer-memo store (`scalify serve --cache-dir`):
//! a single append-only **segment file** plus an in-memory fingerprint
//! index.
//!
//! Verified [`MemoEntry`]s are keyed by their **stable** structural
//! fingerprint (see [`crate::partition::fingerprint`]), loaded at daemon
//! startup and appended on write, so a restarted daemon — or a different
//! CI job pointed at the same directory — starts warm: its first request
//! replays every layer an earlier process already proved.
//!
//! ## On-disk layout
//!
//! ```text
//! header   "SCLFYSEG" · format u32 LE · fingerprint-scheme u32 LE
//! record*  payload-len u32 LE · fp u64 LE · checksum u64 LE · payload
//! ```
//!
//! Each record is independently checksummed (FNV-1a over the fingerprint
//! and payload bytes), so recording an entry is **one `O(record)`
//! append**, not the full-file rewrite the old JSON store paid per write
//! — under fleet load the write cost no longer grows with the number of
//! entries already proved. The payload itself is the entry's compact
//! JSON body, reusing the crate's hand-rolled codec.
//!
//! ## In-memory index
//!
//! Records live in flat arrays (`DenseStorage` idiom): one contiguous
//! payload buffer, a prefix-sum array of record boundaries, a parallel
//! fingerprint array and a fingerprint→record hash index. The layout is
//! mmap-friendly — the byte buffer mirrors the file's record region —
//! and costs two `Vec`s plus a hash map instead of one allocation per
//! entry.
//!
//! ## Failure behavior
//!
//! Startup scans the segment and **compacts** it when recovery dropped
//! anything: a crash mid-append leaves a truncated final record, which
//! is detected, logged, cut off and rewritten — every fully-checksummed
//! record before it survives. Bitrot *inside* a complete record (a
//! checksum mismatch mid-file), an unknown header, or fingerprint-scheme
//! skew all **degrade to a cold start with a warning** — a corrupted
//! cache can cost time, never correctness. Caches written by the old
//! JSON format (`layer-memo.json`) are migrated into the segment on
//! first open.

use crate::error::Result;
use crate::partition::{check_fingerprint_version, MemoEntry, FINGERPRINT_VERSION};
use crate::report::json::Json;
use crate::report::{json_checksum, rel_summary_from_json, rel_summary_to_json};
use rustc_hash::FxHashMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// On-disk format version (independent of the fingerprint scheme).
/// v1 was the whole-file JSON document; v2 is the append-only segment.
pub const CACHE_FORMAT_VERSION: u32 = 2;

/// File name inside `--cache-dir`.
pub const CACHE_FILE: &str = "layer-memo.seg";

/// File name of the v1 whole-file JSON store, read once for migration.
pub const LEGACY_CACHE_FILE: &str = "layer-memo.json";

/// Segment magic: identifies the file before any parsing happens.
const MAGIC: &[u8; 8] = b"SCLFYSEG";
const HEADER_LEN: usize = 16;
/// Bytes before each payload: length (u32) + fingerprint (u64) +
/// checksum (u64).
const RECORD_HEADER_LEN: usize = 4 + 8 + 8;
/// Sanity bound on one payload — anything larger is corruption, not a
/// layer summary.
const MAX_RECORD_LEN: usize = 1 << 20;

/// Outcome of opening a cache directory.
#[derive(Clone, Debug, Default)]
pub struct CacheLoad {
    /// Entries successfully loaded.
    pub loaded: usize,
    /// Present when the store degraded (corrupt file, version skew,
    /// unreadable directory) or recovered from a torn append.
    pub warning: Option<String>,
}

/// The flat-array record index: one contiguous payload buffer with
/// prefix-sum boundaries, a parallel fingerprint array and a
/// fingerprint→record map for duplicate suppression.
struct SegmentIndex {
    /// Record fingerprints, in append order.
    fps: Vec<u64>,
    /// All payload bytes, concatenated.
    data: Vec<u8>,
    /// Prefix sums into `data`: record `i` spans
    /// `bounds[i]..bounds[i + 1]`. (u32 offsets: the capacity bound keeps
    /// the buffer far below 4 GiB.)
    bounds: Vec<u32>,
    /// Fingerprint → record position.
    by_fp: FxHashMap<u64, u32>,
}

impl SegmentIndex {
    fn new() -> SegmentIndex {
        SegmentIndex {
            fps: Vec::new(),
            data: Vec::new(),
            bounds: vec![0],
            by_fp: FxHashMap::default(),
        }
    }

    fn len(&self) -> usize {
        self.fps.len()
    }

    /// Append one record; duplicate fingerprints are rejected (entries
    /// are immutable once verified, so first-writer-wins is exact).
    fn push(&mut self, fp: u64, payload: &[u8]) -> bool {
        if self.by_fp.contains_key(&fp) {
            return false;
        }
        self.by_fp.insert(fp, self.fps.len() as u32);
        self.fps.push(fp);
        self.data.extend_from_slice(payload);
        self.bounds.push(self.data.len() as u32);
        true
    }

    fn payload(&self, i: usize) -> &[u8] {
        &self.data[self.bounds[i] as usize..self.bounds[i + 1] as usize]
    }

    /// Encoded record bytes for records `from..len` (the append tail).
    fn encode_range(&self, from: usize) -> Vec<u8> {
        let mut out = Vec::new();
        for i in from..self.len() {
            out.extend_from_slice(&record_bytes(self.fps[i], self.payload(i)));
        }
        out
    }

    /// The whole file image: header plus every record.
    fn encode_all(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEADER_LEN + self.data.len());
        out.extend_from_slice(&header_bytes());
        out.extend_from_slice(&self.encode_range(0));
        out
    }

    fn decode_entries(&self) -> Vec<(u64, MemoEntry)> {
        let mut out = Vec::with_capacity(self.len());
        for i in 0..self.len() {
            match decode_payload(self.payload(i)) {
                Ok(entry) => out.push((self.fps[i], entry)),
                // unreachable post-scan (payloads are validated at open,
                // and appended payloads were just encoded) — but a skip
                // beats a panic in a long-lived daemon
                Err(why) => crate::log_warn!(
                    "cache record {i} (fp {:016x}) became undecodable: {why}",
                    self.fps[i]
                ),
            }
        }
        out
    }
}

/// What the disk currently holds, tracked so appends stay `O(record)`.
struct FileState {
    /// True when the file is a valid segment holding exactly `records`
    /// records. False after opening over garbage or a failed write —
    /// healed by a full rewrite on the next append.
    valid: bool,
    /// Records currently persisted.
    records: usize,
}

/// Handle on a cache directory: the in-memory segment index plus
/// append-on-write persistence. Shared behind `Arc` between the session
/// shards' memo-write hooks and the service's stats plumbing.
///
/// The index is **bounded** (same spirit as `VerifyConfig::memo_capacity`
/// — a long-lived daemon must not grow without limit): once `capacity`
/// entries are held, further fingerprints are dropped from persistence,
/// first-come-first-kept (the sessions' own memos still serve them for
/// their lifetime; a workload past the bound has outgrown warm-start
/// anyway).
pub struct MemoCache {
    path: PathBuf,
    capacity: usize,
    index: Mutex<SegmentIndex>,
    /// Serializes disk writes without holding `index` during I/O, so
    /// stats/preload readers and other memo-write hooks are never
    /// blocked behind a file write. Lock order: `file` may acquire
    /// `index`, never the reverse.
    file: Mutex<FileState>,
}

impl MemoCache {
    /// Open with the default capacity
    /// ([`crate::partition::DEFAULT_MEMO_CAPACITY`]).
    pub fn open(dir: &Path) -> Result<(MemoCache, CacheLoad)> {
        MemoCache::open_with_capacity(dir, crate::partition::DEFAULT_MEMO_CAPACITY)
    }

    /// Open (creating the directory if needed) and load whatever previous
    /// processes persisted, compacting the segment if recovery dropped a
    /// torn tail. Never fails on a bad cache *file* — that is a cold
    /// start plus [`CacheLoad::warning`]; only an unusable directory is
    /// an error.
    pub fn open_with_capacity(
        dir: &Path,
        capacity: usize,
    ) -> Result<(MemoCache, CacheLoad)> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(CACHE_FILE);
        let capacity = capacity.max(1);
        let (index, load, on_disk) = match std::fs::read(&path) {
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                open_legacy(dir, &path, capacity)
            }
            Err(e) => (
                SegmentIndex::new(),
                CacheLoad {
                    loaded: 0,
                    warning: Some(format!(
                        "cache file {} is unreadable ({e}); starting cold",
                        path.display()
                    )),
                },
                Disk::Invalid,
            ),
            Ok(bytes) => match scan_segment(&bytes, capacity) {
                Ok((index, torn)) => {
                    let loaded = index.len();
                    if torn == 0 {
                        (index, CacheLoad { loaded, warning: None }, Disk::Holds(loaded))
                    } else {
                        let warning = format!(
                            "cache file {} has a torn tail ({torn} trailing bytes \
                             after {loaded} whole records, a crash mid-append); \
                             compacting",
                            path.display()
                        );
                        (index, CacheLoad { loaded, warning: Some(warning) }, Disk::Rewrite)
                    }
                }
                Err(why) => (
                    SegmentIndex::new(),
                    CacheLoad {
                        loaded: 0,
                        warning: Some(format!(
                            "ignoring cache file {} ({why}); starting cold",
                            path.display()
                        )),
                    },
                    Disk::Invalid,
                ),
            },
        };
        let cache = MemoCache {
            path,
            capacity,
            file: Mutex::new(FileState { valid: false, records: 0 }),
            index: Mutex::new(index),
        };
        match on_disk {
            Disk::Holds(records) => {
                let mut file = cache.file.lock().expect("cache file lock");
                file.valid = true;
                file.records = records;
            }
            // startup compaction: rewrite the recovered prefix (or the
            // migrated legacy entries) as a clean segment right away
            Disk::Rewrite => cache.compact(),
            // garbage stays untouched until the first append replaces it
            Disk::Invalid => {}
        }
        Ok((cache, load))
    }

    /// Maximum entries persisted.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// The backing segment file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Entries currently indexed (== persisted, modulo write failures).
    pub fn len(&self) -> usize {
        self.index.lock().expect("cache lock").len()
    }

    /// True when the index is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of every entry, for preloading fresh session memos.
    pub fn entries(&self) -> Vec<(u64, MemoEntry)> {
        self.index.lock().expect("cache lock").decode_entries()
    }

    /// Record one entry (the session's memo-write hook): one index push
    /// plus **one appended record** — never a rewrite of what is already
    /// on disk. Entries are immutable once verified, so a known
    /// fingerprint is a no-op — repeat hits never touch the disk — and a
    /// full index drops new fingerprints instead of growing. Write
    /// failures are logged, not propagated: persistence is an
    /// optimization and must never fail a verify request.
    pub fn record(&self, fp: u64, entry: &MemoEntry) {
        let payload = encode_payload(entry);
        {
            let mut index = self.index.lock().expect("cache lock");
            if index.len() >= self.capacity || !index.push(fp, &payload) {
                return;
            }
        }
        let mut file = self.file.lock().expect("cache file lock");
        let (mut buf, total, fresh) = {
            let index = self.index.lock().expect("cache lock");
            if file.valid {
                // usually just our record; a racing recorder that queued
                // ahead may have persisted more, which `records` tracks
                (index.encode_range(file.records), index.len(), false)
            } else {
                (index.encode_all(), index.len(), true)
            }
        };
        if !fresh && buf.is_empty() {
            return;
        }
        if let Some(action) = crate::faults::fire("cache-write") {
            match action.kind {
                crate::faults::FaultKind::Bitrot if !buf.is_empty() => {
                    // corrupt one byte of the outgoing record; the startup
                    // checksum scan must catch it and degrade to cold
                    let at = (action.noise % buf.len() as u64) as usize;
                    buf[at] ^= 0x01;
                }
                crate::faults::FaultKind::Error => {
                    file.valid = false;
                    crate::log_warn!("cache append skipped: injected fault at cache-write");
                    return;
                }
                crate::faults::FaultKind::Delay(d) => std::thread::sleep(d),
                _ => {}
            }
        }
        let wrote = if fresh { self.replace_file(&buf) } else { self.append_file(&buf) };
        match wrote {
            Ok(()) => {
                file.valid = true;
                file.records = total;
            }
            Err(e) => {
                // the disk may now hold a partial append; force the next
                // write to lay down a clean segment from scratch
                file.valid = false;
                crate::log_warn!("cache append to {} failed: {e}", self.path.display());
            }
        }
    }

    /// Rewrite the whole segment from the index (startup compaction).
    fn compact(&self) {
        let mut file = self.file.lock().expect("cache file lock");
        let (buf, total) = {
            let index = self.index.lock().expect("cache lock");
            (index.encode_all(), index.len())
        };
        match self.replace_file(&buf) {
            Ok(()) => {
                file.valid = true;
                file.records = total;
            }
            Err(e) => {
                file.valid = false;
                crate::log_warn!(
                    "cache compaction to {} failed: {e}",
                    self.path.display()
                );
            }
        }
    }

    /// Atomically replace the segment via a per-process temp file —
    /// concurrent daemons sharing one cache dir must not interleave
    /// writes into the same temp file (the rename then keeps whichever
    /// finished last, both valid).
    fn replace_file(&self, buf: &[u8]) -> std::io::Result<()> {
        let tmp = self.path.with_extension(format!("tmp.{}", std::process::id()));
        std::fs::write(&tmp, buf)?;
        std::fs::rename(&tmp, &self.path)
    }

    /// Append record bytes. One `write_all` call, so a crash tears at
    /// most the final record — exactly what the startup scan recovers.
    fn append_file(&self, buf: &[u8]) -> std::io::Result<()> {
        let mut f = std::fs::OpenOptions::new().append(true).open(&self.path)?;
        f.write_all(buf)
    }
}

/// Where `open` left the disk relative to the in-memory index.
enum Disk {
    /// A valid segment holding this many records.
    Holds(usize),
    /// Index is right, file needs a compaction rewrite.
    Rewrite,
    /// File (if any) is garbage; first append replaces it.
    Invalid,
}

/// No segment file: migrate a v1 JSON cache if one is present.
fn open_legacy(dir: &Path, path: &Path, capacity: usize) -> (SegmentIndex, CacheLoad, Disk) {
    let legacy = dir.join(LEGACY_CACHE_FILE);
    let text = match std::fs::read_to_string(&legacy) {
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return (SegmentIndex::new(), CacheLoad::default(), Disk::Invalid);
        }
        Err(e) => {
            return (
                SegmentIndex::new(),
                CacheLoad {
                    loaded: 0,
                    warning: Some(format!(
                        "cache file {} is unreadable ({e}); starting cold",
                        legacy.display()
                    )),
                },
                Disk::Invalid,
            );
        }
        Ok(text) => text,
    };
    match parse_legacy(&text) {
        Ok(entries) => {
            let mut index = SegmentIndex::new();
            for (fp, entry) in entries {
                if index.len() >= capacity {
                    break;
                }
                index.push(fp, &encode_payload(&entry));
            }
            let loaded = index.len();
            crate::log_debug!(
                "migrating {loaded} entries from v1 cache {} into segment {}",
                legacy.display(),
                path.display()
            );
            (index, CacheLoad { loaded, warning: None }, Disk::Rewrite)
        }
        Err(why) => (
            SegmentIndex::new(),
            CacheLoad {
                loaded: 0,
                warning: Some(format!(
                    "ignoring cache file {} ({why}); starting cold",
                    legacy.display()
                )),
            },
            Disk::Invalid,
        ),
    }
}

fn header_bytes() -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[..8].copy_from_slice(MAGIC);
    h[8..12].copy_from_slice(&CACHE_FORMAT_VERSION.to_le_bytes());
    h[12..16].copy_from_slice(&FINGERPRINT_VERSION.to_le_bytes());
    h
}

fn record_bytes(fp: u64, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(RECORD_HEADER_LEN + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&fp.to_le_bytes());
    out.extend_from_slice(&record_checksum(fp, payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// FNV-1a over the fingerprint and payload bytes — same constants as the
/// structural fingerprints themselves.
fn record_checksum(fp: u64, payload: &[u8]) -> u64 {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = FNV_OFFSET;
    for b in fp.to_le_bytes().iter().chain(payload) {
        h ^= *b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// One scanned record.
enum Rec<'a> {
    /// Complete, checksummed record: fingerprint, payload, next offset.
    Full(u64, &'a [u8], usize),
    /// The bytes from here to EOF are not a whole record (torn append).
    Torn,
    /// Unambiguous mid-file damage.
    Corrupt(String),
}

fn read_record(bytes: &[u8], at: usize) -> Rec<'_> {
    if bytes.len() - at < RECORD_HEADER_LEN {
        return Rec::Torn;
    }
    let len =
        u32::from_le_bytes(bytes[at..at + 4].try_into().expect("4 bytes")) as usize;
    if len > MAX_RECORD_LEN {
        return Rec::Corrupt(format!("implausible record length {len} at byte {at}"));
    }
    let fp = u64::from_le_bytes(bytes[at + 4..at + 12].try_into().expect("8 bytes"));
    let sum = u64::from_le_bytes(bytes[at + 12..at + 20].try_into().expect("8 bytes"));
    let start = at + RECORD_HEADER_LEN;
    if start + len > bytes.len() {
        return Rec::Torn;
    }
    let payload = &bytes[start..start + len];
    if record_checksum(fp, payload) != sum {
        // a *complete* record whose checksum fails is bitrot, not a torn
        // append — torn writes can only truncate the file
        return Rec::Corrupt(format!("checksum mismatch at record starting byte {at}"));
    }
    Rec::Full(fp, payload, start + len)
}

/// Parse and index a segment image. `Err` ⇒ nothing salvageable (cold
/// start); `Ok((index, torn))` with `torn > 0` ⇒ the trailing `torn`
/// bytes were an incomplete append and the checksummed prefix was kept.
fn scan_segment(
    bytes: &[u8],
    capacity: usize,
) -> std::result::Result<(SegmentIndex, usize), String> {
    if bytes.len() < HEADER_LEN || &bytes[..8] != MAGIC {
        return Err("not a scalify cache segment".into());
    }
    let format = u32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes"));
    if format != CACHE_FORMAT_VERSION {
        return Err(format!(
            "cache format v{format} (this build reads v{CACHE_FORMAT_VERSION})"
        ));
    }
    let fpv = u32::from_le_bytes(bytes[12..16].try_into().expect("4 bytes"));
    // route the scheme check through the shared gate so skew degrades
    // with identical wording everywhere fingerprints are persisted
    let gate = Json::Obj(vec![("fingerprint_version".into(), Json::Num(fpv as f64))]);
    check_fingerprint_version(&gate)?;
    let mut index = SegmentIndex::new();
    let mut at = HEADER_LEN;
    while at < bytes.len() {
        match read_record(bytes, at) {
            Rec::Torn => return Ok((index, bytes.len() - at)),
            Rec::Corrupt(why) => return Err(why),
            Rec::Full(fp, payload, next) => {
                // validate decodability up front: a checksummed-but-
                // unparseable record means the writer and reader disagree,
                // which is a cold start, not a runtime surprise later
                decode_payload(payload)
                    .map_err(|why| format!("record at byte {at}: {why}"))?;
                if index.len() < capacity {
                    index.push(fp, payload);
                }
                at = next;
            }
        }
    }
    Ok((index, 0))
}

/// Entry payload codec: the legacy JSON field contract minus `fp` (the
/// record header carries it out-of-band).
fn encode_payload(e: &MemoEntry) -> Vec<u8> {
    Json::Obj(vec![
        ("verified".into(), Json::Bool(e.verified)),
        ("egraph_nodes".into(), Json::Num(e.egraph_nodes as f64)),
        ("egraph_classes".into(), Json::Num(e.egraph_classes as f64)),
        (
            "out_rels".into(),
            Json::Arr(e.out_rels.iter().map(rel_summary_to_json).collect()),
        ),
    ])
    .render()
    .into_bytes()
}

fn decode_payload(bytes: &[u8]) -> std::result::Result<MemoEntry, String> {
    let text = std::str::from_utf8(bytes).map_err(|_| "payload is not UTF-8".to_string())?;
    let doc = Json::parse(text).map_err(|e| format!("payload: {e}"))?;
    let verified = doc.bool_at("verified").ok_or("payload is missing 'verified'")?;
    let egraph_nodes =
        doc.u64_at("egraph_nodes").ok_or("payload is missing 'egraph_nodes'")? as usize;
    let egraph_classes = doc.u64_at("egraph_classes").unwrap_or(0) as usize;
    let rels = doc
        .get("out_rels")
        .and_then(Json::as_arr)
        .ok_or("payload is missing 'out_rels'")?;
    let out_rels = rels
        .iter()
        .map(rel_summary_from_json)
        .collect::<std::result::Result<Vec<_>, String>>()?;
    Ok(MemoEntry { verified, out_rels, egraph_nodes, egraph_classes })
}

/// Parse the v1 whole-file JSON document (read-only migration path).
fn parse_legacy(text: &str) -> std::result::Result<Vec<(u64, MemoEntry)>, String> {
    let doc = Json::parse(text).map_err(|e| format!("corrupted JSON: {e}"))?;
    let format = doc.u64_at("format").ok_or("missing 'format' version")?;
    if format != 1 {
        return Err(format!("cache format v{format} (the legacy reader takes v1)"));
    }
    check_fingerprint_version(&doc)?;
    let items = doc
        .get("entries")
        .and_then(Json::as_arr)
        .ok_or("missing 'entries' array")?;
    let expected = doc.str_at("checksum").ok_or("missing 'checksum'")?;
    let actual = json_checksum(&Json::Arr(items.to_vec()));
    if actual != expected {
        return Err(format!(
            "checksum mismatch (file says {expected}, contents hash to {actual})"
        ));
    }
    let mut entries = Vec::with_capacity(items.len());
    for item in items {
        entries.push(legacy_entry_from_json(item)?);
    }
    Ok(entries)
}

fn legacy_entry_from_json(doc: &Json) -> std::result::Result<(u64, MemoEntry), String> {
    let fp_hex = doc.str_at("fp").ok_or("entry is missing 'fp'")?;
    let fp = u64::from_str_radix(fp_hex, 16)
        .map_err(|_| format!("bad fingerprint '{fp_hex}'"))?;
    let verified = doc.bool_at("verified").ok_or("entry is missing 'verified'")?;
    let egraph_nodes =
        doc.u64_at("egraph_nodes").ok_or("entry is missing 'egraph_nodes'")? as usize;
    let egraph_classes = doc.u64_at("egraph_classes").unwrap_or(0) as usize;
    let rels = doc
        .get("out_rels")
        .and_then(Json::as_arr)
        .ok_or("entry is missing 'out_rels'")?;
    let out_rels = rels
        .iter()
        .map(rel_summary_from_json)
        .collect::<std::result::Result<Vec<_>, String>>()?;
    Ok((fp, MemoEntry { verified, out_rels, egraph_nodes, egraph_classes }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ReduceKind;
    use crate::verifier::boundary::RelSummary;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "scalify-cache-test-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample_entry() -> MemoEntry {
        MemoEntry {
            verified: true,
            out_rels: vec![
                RelSummary::Duplicate,
                RelSummary::Sharded { dim: 1, parts: 4, axis: 1 },
                RelSummary::MeshSharded { entries: vec![(0, 2, 0), (1, 2, 1)] },
                RelSummary::Partial { kind: ReduceKind::Add, axes: 0b10 },
            ],
            egraph_nodes: 321,
            egraph_classes: 123,
        }
    }

    /// A distinguishable second entry, so recovery tests can tell records
    /// apart.
    fn other_entry(nodes: usize) -> MemoEntry {
        MemoEntry {
            verified: true,
            out_rels: vec![RelSummary::Duplicate],
            egraph_nodes: nodes,
            egraph_classes: 1,
        }
    }

    #[test]
    fn record_then_reopen_round_trips() {
        let dir = tmpdir("roundtrip");
        {
            let (cache, load) = MemoCache::open(&dir).unwrap();
            assert_eq!(load.loaded, 0);
            assert!(load.warning.is_none());
            cache.record(0xdead_beef_0000_0042, &sample_entry());
            cache.record(7, &other_entry(11));
            // duplicate fingerprints are no-ops
            cache.record(7, &other_entry(99));
            assert_eq!(cache.len(), 2);
        }
        let (cache, load) = MemoCache::open(&dir).unwrap();
        assert_eq!(load.loaded, 2, "{:?}", load.warning);
        assert!(load.warning.is_none());
        let entries = cache.entries();
        let (_, e) = entries
            .iter()
            .find(|(fp, _)| *fp == 0xdead_beef_0000_0042)
            .expect("high-bit fingerprint survives the record encoding");
        assert_eq!(e, &sample_entry());
        let (_, e) = entries.iter().find(|(fp, _)| *fp == 7).unwrap();
        assert_eq!(e.egraph_nodes, 11, "first writer wins on duplicates");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn appends_are_constant_size_not_full_rewrites() {
        let dir = tmpdir("append");
        let (cache, _) = MemoCache::open(&dir).unwrap();
        let size = |entry: &MemoEntry| RECORD_HEADER_LEN + encode_payload(entry).len();
        cache.record(1, &sample_entry());
        let after_one = std::fs::metadata(dir.join(CACHE_FILE)).unwrap().len();
        assert_eq!(after_one as usize, HEADER_LEN + size(&sample_entry()));
        cache.record(2, &other_entry(5));
        let after_two = std::fs::metadata(dir.join(CACHE_FILE)).unwrap().len();
        // the second write appended exactly one record — the store never
        // rewrites what is already on disk
        assert_eq!((after_two - after_one) as usize, size(&other_entry(5)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_file_degrades_to_cold_start_with_warning() {
        let dir = tmpdir("corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(CACHE_FILE), "{ this is not a segment").unwrap();
        let (cache, load) = MemoCache::open(&dir).unwrap();
        assert_eq!(load.loaded, 0);
        let warning = load.warning.expect("corruption must warn");
        assert!(warning.contains("starting cold"), "{warning}");
        // the cache still works: the first write replaces the corrupt file
        cache.record(1, &sample_entry());
        let (_, load) = MemoCache::open(&dir).unwrap();
        assert_eq!(load.loaded, 1, "{:?}", load.warning);
        assert!(load.warning.is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn version_skew_degrades_to_cold_start() {
        let dir = tmpdir("skew");
        std::fs::create_dir_all(&dir).unwrap();
        // a segment whose header says the fingerprints were computed
        // under a different scheme
        let mut header = header_bytes();
        header[12..16].copy_from_slice(&9999u32.to_le_bytes());
        std::fs::write(dir.join(CACHE_FILE), header).unwrap();
        let (_, load) = MemoCache::open(&dir).unwrap();
        assert_eq!(load.loaded, 0);
        assert!(load.warning.unwrap().contains("scheme v9999"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn format_skew_degrades_to_cold_start() {
        let dir = tmpdir("format-skew");
        std::fs::create_dir_all(&dir).unwrap();
        let mut header = header_bytes();
        header[8..12].copy_from_slice(&77u32.to_le_bytes());
        std::fs::write(dir.join(CACHE_FILE), header).unwrap();
        let (_, load) = MemoCache::open(&dir).unwrap();
        assert_eq!(load.loaded, 0);
        assert!(load.warning.unwrap().contains("cache format v77"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bitrot_inside_a_record_fails_the_checksum_and_starts_cold() {
        let dir = tmpdir("bitrot");
        {
            let (cache, _) = MemoCache::open(&dir).unwrap();
            cache.record(0x1111_2222_3333_4444, &sample_entry());
            cache.record(5, &other_entry(9));
        }
        // flip one payload byte of the FIRST record: lengths and framing
        // stay intact, only the checksum can catch it
        let path = dir.join(CACHE_FILE);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[HEADER_LEN + RECORD_HEADER_LEN + 3] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();

        let (_, load) = MemoCache::open(&dir).unwrap();
        assert_eq!(load.loaded, 0, "tampered segments must not be replayed");
        assert!(load.warning.unwrap().contains("checksum mismatch"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_append_recovers_every_whole_record_and_compacts() {
        let dir = tmpdir("torture");
        {
            let (cache, _) = MemoCache::open(&dir).unwrap();
            cache.record(1, &sample_entry());
            cache.record(2, &other_entry(7));
            cache.record(3, &other_entry(8));
        }
        let path = dir.join(CACHE_FILE);
        let full = std::fs::read(&path).unwrap();
        let two_records = HEADER_LEN
            + 2 * RECORD_HEADER_LEN
            + encode_payload(&sample_entry()).len()
            + encode_payload(&other_entry(7)).len();
        // kill-mid-append torture: cut the file at EVERY byte inside the
        // third record; the two whole records must survive each time
        for cut in two_records + 1..full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            let (cache, load) = MemoCache::open(&dir).unwrap();
            assert_eq!(load.loaded, 2, "cut at byte {cut}");
            let warning = load.warning.expect("a torn tail must warn");
            assert!(warning.contains("torn tail"), "cut {cut}: {warning}");
            let fps: Vec<u64> = cache.entries().iter().map(|(fp, _)| *fp).collect();
            assert_eq!(fps, vec![1, 2], "cut at byte {cut}");
            // startup compaction rewrote a clean two-record segment…
            assert_eq!(
                std::fs::metadata(&path).unwrap().len() as usize,
                two_records,
                "cut at byte {cut}"
            );
        }
        // …so the next open is warning-free
        let (_, load) = MemoCache::open(&dir).unwrap();
        assert_eq!(load.loaded, 2);
        assert!(load.warning.is_none());
        // and a truncation into the *header* is a plain cold start
        std::fs::write(&path, &full[..HEADER_LEN - 3]).unwrap();
        let (_, load) = MemoCache::open(&dir).unwrap();
        assert_eq!(load.loaded, 0);
        assert!(load.warning.unwrap().contains("starting cold"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recording_after_a_torn_recovery_appends_cleanly() {
        let dir = tmpdir("torn-then-append");
        {
            let (cache, _) = MemoCache::open(&dir).unwrap();
            cache.record(1, &sample_entry());
            cache.record(2, &other_entry(7));
        }
        let path = dir.join(CACHE_FILE);
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        let (cache, load) = MemoCache::open(&dir).unwrap();
        assert_eq!(load.loaded, 1);
        cache.record(9, &other_entry(4));
        let (cache, load) = MemoCache::open(&dir).unwrap();
        assert_eq!(load.loaded, 2, "{:?}", load.warning);
        assert!(load.warning.is_none());
        let fps: Vec<u64> = cache.entries().iter().map(|(fp, _)| *fp).collect();
        assert_eq!(fps, vec![1, 9]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn duplicate_records_never_touch_the_file() {
        let dir = tmpdir("coalesce");
        let (cache, _) = MemoCache::open(&dir).unwrap();
        cache.record(1, &sample_entry());
        let first = std::fs::metadata(dir.join(CACHE_FILE)).unwrap().len();
        // same fingerprint again: no index change, no write
        cache.record(1, &sample_entry());
        let second = std::fs::metadata(dir.join(CACHE_FILE)).unwrap().len();
        assert_eq!(first, second);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn index_is_bounded_by_capacity() {
        let dir = tmpdir("bounded");
        let (cache, _) = MemoCache::open_with_capacity(&dir, 2).unwrap();
        cache.record(1, &sample_entry());
        cache.record(2, &sample_entry());
        cache.record(3, &sample_entry()); // dropped: index is full
        assert_eq!(cache.len(), 2);
        let (reopened, load) = MemoCache::open_with_capacity(&dir, 2).unwrap();
        assert_eq!(load.loaded, 2);
        assert!(reopened.entries().iter().all(|(fp, _)| *fp != 3));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_directory_is_created() {
        let dir = tmpdir("mkdir").join("nested/deeper");
        let (cache, load) = MemoCache::open(&dir).unwrap();
        assert_eq!(load.loaded, 0);
        cache.record(3, &sample_entry());
        assert!(dir.join(CACHE_FILE).exists());
        let _ = std::fs::remove_dir_all(dir.parent().unwrap().parent().unwrap());
    }

    /// Build a v1 whole-file JSON cache the way the old store wrote it.
    fn legacy_v1_doc(entries: &[(u64, MemoEntry)]) -> String {
        let arr = Json::Arr(
            entries
                .iter()
                .map(|(fp, e)| {
                    Json::Obj(vec![
                        ("fp".into(), Json::Str(format!("{fp:016x}"))),
                        ("verified".into(), Json::Bool(e.verified)),
                        ("egraph_nodes".into(), Json::Num(e.egraph_nodes as f64)),
                        ("egraph_classes".into(), Json::Num(e.egraph_classes as f64)),
                        (
                            "out_rels".into(),
                            Json::Arr(e.out_rels.iter().map(rel_summary_to_json).collect()),
                        ),
                    ])
                })
                .collect(),
        );
        let checksum = json_checksum(&arr);
        Json::Obj(vec![
            ("format".into(), Json::Num(1.0)),
            ("fingerprint_version".into(), Json::Num(FINGERPRINT_VERSION as f64)),
            ("checksum".into(), Json::Str(checksum)),
            ("entries".into(), arr),
        ])
        .render_pretty()
    }

    #[test]
    fn legacy_v1_json_cache_migrates_into_the_segment() {
        let dir = tmpdir("migrate");
        std::fs::create_dir_all(&dir).unwrap();
        let doc =
            legacy_v1_doc(&[(0xdead_beef_0000_0042, sample_entry()), (7, other_entry(3))]);
        std::fs::write(dir.join(LEGACY_CACHE_FILE), doc).unwrap();
        let (cache, load) = MemoCache::open(&dir).unwrap();
        assert_eq!(load.loaded, 2, "{:?}", load.warning);
        assert!(load.warning.is_none());
        assert!(dir.join(CACHE_FILE).exists(), "migration compacts at open");
        let entries = cache.entries();
        let (_, e) =
            entries.iter().find(|(fp, _)| *fp == 0xdead_beef_0000_0042).unwrap();
        assert_eq!(e, &sample_entry());
        // the segment, not the legacy file, serves the next open
        let (_, load) = MemoCache::open(&dir).unwrap();
        assert_eq!(load.loaded, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_version_skew_degrades_to_cold_start() {
        let dir = tmpdir("legacy-skew");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join(LEGACY_CACHE_FILE),
            "{\"format\":1,\"fingerprint_version\":9999,\"entries\":[]}",
        )
        .unwrap();
        let (_, load) = MemoCache::open(&dir).unwrap();
        assert_eq!(load.loaded, 0);
        assert!(load.warning.unwrap().contains("scheme v9999"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
