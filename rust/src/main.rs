//! `scalify` CLI — the leader entrypoint.
//!
//! ```text
//! scalify verify --base <hlo> --dist <hlo> [--cores N] [--json]   verify two HLO files
//! scalify model --model llama-8b --par tp32 [--layers N] [--json] verify a zoo model
//! scalify batch --manifest pairs.txt [--json]                     verify a manifest through one session
//! scalify bugs [--reproduced|--new]                               run the bug corpus
//! scalify exec --artifact <hlo>                                   run via the runtime
//! scalify info                                                    version/build info
//! ```
//!
//! Exit codes: 0 verified/ok · 1 unverified (a divergence was found) ·
//! 2 usage or input error · 3 runtime execution error. With `--json`,
//! stdout carries exactly one machine-readable document.

use scalify::bugs::{
    evaluate, new_bugs, parallel_transform_bugs, reproduced_bugs, ExpectedLoc, LocResult,
};
use scalify::cli;
use scalify::error::{Result, ResultExt, ScalifyError};
use scalify::hlo::parse_hlo_file;
use scalify::ir::Annotation;
use scalify::report::json::Json;
use scalify::report::Table;
use scalify::verifier::{GraphPair, Session, VerifyReport};
use std::collections::HashMap;
use std::path::Path;
use std::process::ExitCode;

type Flags = HashMap<String, String>;

fn require<'f>(flags: &'f Flags, key: &str, usage: &str) -> Result<&'f String> {
    flags
        .get(key)
        .ok_or_else(|| ScalifyError::config(format!("missing --{key} ({usage})")))
}

/// Load a `(base, dist)` HLO file pair with positional replicated
/// annotations (HLO files carry no sharding info).
fn load_pair(base: &Path, dist: &Path, cores: u32) -> Result<GraphPair> {
    let bg = parse_hlo_file(base, 1).with_ctx(|| format!("--base {}", base.display()))?;
    let dg = parse_hlo_file(dist, cores).with_ctx(|| format!("--dist {}", dist.display()))?;
    let ann: Vec<Annotation> = bg
        .parameters()
        .into_iter()
        .zip(dg.parameters())
        .map(|(b, d)| Annotation::replicated(b, d))
        .collect();
    GraphPair::try_new(bg, dg, ann)
}

fn emit_report(report: &VerifyReport, json: bool, max_discrepancies: usize) {
    if json {
        print!("{}", report.to_json_string());
        return;
    }
    println!("{}", report.summary());
    for d in report.discrepancies().iter().take(max_discrepancies) {
        println!("  {}", d.render());
    }
}

fn cmd_verify(flags: &Flags) -> Result<ExitCode> {
    let base = require(flags, "base", "baseline HLO file")?;
    let dist = require(flags, "dist", "distributed HLO file")?;
    let cores: u32 = match flags.get("cores") {
        Some(c) => c
            .parse()
            .map_err(|_| ScalifyError::config(format!("--cores wants an integer, got '{c}'")))?,
        None => 1,
    };
    let pair = load_pair(Path::new(base), Path::new(dist), cores)?;
    let session = Session::new(cli::config_from_flags(flags)?);
    let report = session.verify(&pair)?;
    emit_report(&report, flags.contains_key("json"), usize::MAX);
    Ok(if report.verified() { ExitCode::SUCCESS } else { ExitCode::from(1) })
}

fn cmd_model(flags: &Flags) -> Result<ExitCode> {
    let model = flags.get("model").map(|s| s.as_str()).unwrap_or("llama-8b");
    // --parallelism is the spelled-out alias of --par
    let par_spec = flags
        .get("par")
        .or_else(|| flags.get("parallelism"))
        .map(|s| s.as_str())
        .unwrap_or("tp32");
    let par = cli::parallelism(par_spec)?;
    let layers = match flags.get("layers") {
        Some(l) => Some(l.parse().map_err(|_| {
            ScalifyError::config(format!("--layers wants an integer, got '{l}'"))
        })?),
        None => None,
    };
    let json = flags.contains_key("json");
    if !json {
        eprintln!("generating {model} ({}) graphs…", par.label());
    }
    let pair = cli::model_pair(model, par, layers)?;
    if !json {
        eprintln!(
            "verifying {} baseline + {} distributed nodes…",
            pair.base.len(),
            pair.dist.len()
        );
    }
    let session = Session::new(cli::config_from_flags(flags)?);
    let report = session.verify(&pair)?;
    emit_report(&report, json, 10);
    Ok(if report.verified() { ExitCode::SUCCESS } else { ExitCode::from(1) })
}

fn cmd_batch(flags: &Flags) -> Result<ExitCode> {
    let manifest = require(flags, "manifest", "text file of `base.hlo dist.hlo [cores]` lines")?;
    let text = std::fs::read_to_string(manifest)
        .with_ctx(|| format!("reading manifest {manifest}"))?;
    let entries = cli::parse_manifest(&text).with_ctx(|| format!("manifest {manifest}"))?;
    let json = flags.contains_key("json");

    // one session for the whole batch: templates compile once, and layers
    // shared between pairs (same model, different variants) hit the memo
    let session = Session::new(cli::config_from_flags(flags)?);
    let mut all_verified = true;
    let mut had_errors = false;
    let mut docs: Vec<Json> = Vec::new();
    for entry in &entries {
        // one broken pair must not discard the rest of the batch
        let outcome = load_pair(&entry.base, &entry.dist, entry.cores)
            .and_then(|pair| session.verify(&pair));
        let mut fields = vec![
            ("base".into(), Json::Str(entry.base.display().to_string())),
            ("dist".into(), Json::Str(entry.dist.display().to_string())),
            ("cores".into(), Json::Num(entry.cores as f64)),
        ];
        match outcome {
            Ok(report) => {
                all_verified &= report.verified();
                if json {
                    fields.push(("report".into(), report.to_json()));
                } else {
                    println!(
                        "{} ⊢ {}: {}",
                        entry.base.display(),
                        entry.dist.display(),
                        report.summary()
                    );
                    for d in report.discrepancies().iter().take(5) {
                        println!("  {}", d.render());
                    }
                }
            }
            Err(e) => {
                had_errors = true;
                all_verified = false;
                if json {
                    fields.push(("error".into(), Json::Str(e.to_string())));
                } else {
                    println!(
                        "{} ⊢ {}: ERROR — {e}",
                        entry.base.display(),
                        entry.dist.display()
                    );
                }
            }
        }
        if json {
            docs.push(Json::Obj(fields));
        }
    }
    let stats = session.stats();
    if json {
        print!(
            "{}",
            Json::Obj(vec![
                ("pairs".into(), Json::Arr(docs)),
                ("all_verified".into(), Json::Bool(all_verified)),
                ("had_errors".into(), Json::Bool(had_errors)),
                ("session_runs".into(), Json::Num(stats.runs as f64)),
                ("memo_hits".into(), Json::Num(stats.memo_hits as f64)),
                ("memo_entries".into(), Json::Num(stats.memo_entries as f64)),
            ])
            .render_pretty()
        );
    } else {
        eprintln!(
            "batch: {} pairs, {} memoized layer hits across the shared session",
            entries.len(),
            stats.memo_hits
        );
    }
    Ok(if had_errors {
        ExitCode::from(2)
    } else if all_verified {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    })
}

fn run_bug_table(title: &str, cases: Vec<scalify::bugs::BugCase>) -> bool {
    let mut table =
        Table::new(title, &["Bug ID", "Description", "Issue", "Expected", "Result", "Time"]);
    let mut ok = true;
    for case in cases {
        let outcome = evaluate(&case);
        let expected = match case.expected {
            ExpectedLoc::Instruction => "instr",
            ExpectedLoc::Function => "func",
            ExpectedLoc::NotApplicable => "n/a",
        };
        let result = match (outcome.detected, outcome.loc) {
            (false, _) if case.expected == ExpectedLoc::NotApplicable => "n/a (as paper)",
            (false, _) => {
                ok = false;
                "MISSED"
            }
            (true, LocResult::Instruction) => "detected @instr",
            (true, LocResult::Function) => "detected @func",
            (true, _) => "detected (elsewhere)",
        };
        table.row(&[
            case.id.to_string(),
            case.description.to_string(),
            case.issue.to_string(),
            expected.to_string(),
            result.to_string(),
            scalify::util::fmt_duration(outcome.duration),
        ]);
    }
    print!("{}", table.render());
    table.save_csv(&title.replace([' ', '—'], "_"));
    ok
}

fn cmd_bugs(flags: &Flags) -> Result<ExitCode> {
    let only_new = flags.contains_key("new");
    let only_reproduced = flags.contains_key("reproduced");
    let only_transform = flags.contains_key("transform");
    let mut all_ok = true;
    if !only_new && !only_transform {
        all_ok &= run_bug_table("Table 4 - reproduced bugs", reproduced_bugs());
    }
    if !only_reproduced && !only_transform {
        all_ok &= run_bug_table("Table 5 - new bugs", new_bugs());
    }
    if !only_new && !only_reproduced {
        all_ok &= run_bug_table(
            "Pipeline and data-parallel bugs",
            parallel_transform_bugs(),
        );
    }
    Ok(if all_ok { ExitCode::SUCCESS } else { ExitCode::from(1) })
}

fn cmd_exec(flags: &Flags) -> Result<ExitCode> {
    let path = require(flags, "artifact", "HLO-text artifact to execute")?;
    let exe = scalify::runtime::Executable::load(Path::new(path))?;
    let g = exe.graph();
    let mut prng = scalify::util::Prng::new(42);
    let inputs: Vec<scalify::interp::Tensor> = g
        .parameters()
        .iter()
        .map(|&pid| scalify::interp::Tensor::random(g.node(pid).shape.clone(), &mut prng))
        .collect();
    let t0 = std::time::Instant::now();
    let out = exe.run(&inputs)?;
    // artifacts with zero outputs are legal (e.g. effect-only modules) —
    // don't index out[0] unconditionally
    match out.first() {
        Some(first) => println!(
            "executed {} in {:?}: {} outputs, first shape {}",
            path,
            t0.elapsed(),
            out.len(),
            first.shape
        ),
        None => println!("executed {} in {:?}: 0 outputs", path, t0.elapsed()),
    }
    Ok(ExitCode::SUCCESS)
}

fn usage() -> String {
    format!(
        "scalify {} — computational-graph equivalence verifier\n\
         usage:\n  \
         scalify verify --base a.hlo.txt --dist b.hlo.txt [--cores N] [--json]\n  \
         scalify model --model llama-8b|llama-70b|llama-405b|llama-tiny|mixtral-8x7b|mixtral-8x22b\
         |dpstep-tiny|dpstep-small \
         --par tp32|sp32|fd32|ep8|pp4|dp4z1|pp2tp4 [--layers N] [--json]\n  \
         scalify batch --manifest pairs.txt [--json]\n  \
         scalify bugs [--reproduced|--new|--transform]\n  \
         scalify exec --artifact artifacts/model_single.hlo.txt\n  \
         scalify info\n\
         common flags: --threads N --no-partition --no-parallel --no-memoize\n\
         exit codes: 0 verified · 1 unverified · 2 usage/input error · 3 runtime error",
        scalify::VERSION
    )
}

fn run(args: &[String]) -> Result<ExitCode> {
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let flags = cli::parse_flags(&args[1.min(args.len())..])?;
    match cmd {
        "verify" => cmd_verify(&flags),
        "model" => cmd_model(&flags),
        "batch" => cmd_batch(&flags),
        "bugs" => cmd_bugs(&flags),
        "exec" => cmd_exec(&flags),
        "info" => {
            println!("scalify {} — computational-graph equivalence verifier", scalify::VERSION);
            Ok(ExitCode::SUCCESS)
        }
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(ExitCode::SUCCESS)
        }
        other => Err(ScalifyError::config(format!(
            "unknown command '{other}'\n{}",
            usage()
        ))),
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("scalify: {e}");
            ExitCode::from(cli::exit_code_for(&e))
        }
    }
}
