//! `scalify` CLI — the leader entrypoint.
//!
//! ```text
//! scalify verify --base <hlo> --dist <hlo> [--cores N] [--json]   verify two HLO files
//! scalify model --model llama-8b --par tp32 [--layers N] [--json] verify a zoo model
//! scalify batch --manifest pairs.txt [--json]                     verify a manifest through one session
//! scalify serve --addr 127.0.0.1:7878 [--cache-dir DIR] [--shards N]     run the verification fleet
//! scalify client verify|stats|metrics|cancel|shutdown --addr HOST:PORT   drive a running daemon
//! scalify bench [--json]                                          cold/warm service latency → BENCH_service.json
//! scalify bench --scale [--json]                                  405B-class scale tier → BENCH_scale.json
//! scalify bench --diff [--json]                                   incremental verify-on-diff tier → BENCH_diff.json
//! scalify bench --serve-load [--json]                             concurrent fleet load tier → BENCH_serve.json
//! scalify bugs [--reproduced|--new]                               run the bug corpus
//! scalify exec --artifact <hlo>                                   run via the runtime
//! scalify info                                                    version/build info
//! ```
//!
//! Exit codes: 0 verified/ok · 1 unverified (a divergence was found) ·
//! 2 usage or input error · 3 runtime execution error. With `--json`,
//! stdout carries exactly one machine-readable document.
//!
//! Observability: `--trace FILE` on verify/model/batch (and on
//! `bench --scale`) writes a Chrome trace-event / Perfetto JSON span
//! trace of the run; `SCALIFY_LOG=warn|info|debug` sets stderr log
//! verbosity; `scalify client metrics` scrapes a daemon's counters as
//! Prometheus text.

use scalify::bugs::{
    evaluate, new_bugs, parallel_transform_bugs, replica_group_bugs, reproduced_bugs,
    ExpectedLoc, LocResult,
};
use scalify::cli;
use scalify::diff::VerifyState;
use scalify::error::{Result, ResultExt, ScalifyError};
use scalify::hlo::parse_hlo_file;
use scalify::ir::Graph;
use scalify::obs;
use scalify::report::json::Json;
use scalify::report::Table;
use scalify::service::{
    verify_with_retry, Client, Request, Response, RetryPolicy, Scheduler, Server,
    VerifyOpts, VerifySource, PROTOCOL_V2,
};
use scalify::verifier::{GraphPair, Session, VerifyConfig, VerifyReport};
use std::collections::HashMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::sync::{Arc, Mutex};

type Flags = HashMap<String, String>;

fn require<'f>(flags: &'f Flags, key: &str, usage: &str) -> Result<&'f String> {
    flags
        .get(key)
        .ok_or_else(|| ScalifyError::config(format!("missing --{key} ({usage})")))
}

/// Load a `(base, dist)` HLO file pair with positional replicated
/// annotations (HLO files carry no sharding info).
fn load_pair(base: &Path, dist: &Path, cores: u32) -> Result<GraphPair> {
    let bg = parse_hlo_file(base, 1).with_ctx(|| format!("--base {}", base.display()))?;
    let dg = parse_hlo_file(dist, cores).with_ctx(|| format!("--dist {}", dist.display()))?;
    GraphPair::replicated(bg, dg)
}

fn emit_report(report: &VerifyReport, json: bool, max_discrepancies: usize) {
    if json {
        print!("{}", report.to_json_string());
        return;
    }
    println!("{}", report.summary());
    for d in report.discrepancies().iter().take(max_discrepancies) {
        println!("  {}", d.render());
    }
}

/// Run a verification, threading the incremental flags through:
/// `--against FILE` replays unchanged layers from a previously captured
/// [`VerifyState`]; `--emit-state FILE` persists the state this run
/// derives. A stale, corrupt or mismatched state file degrades to a cold
/// verify with a warning — it never turns a verifiable pair into an
/// error.
fn verify_incremental(
    session: &Session,
    pair: &GraphPair,
    flags: &Flags,
) -> Result<VerifyReport> {
    let emit_state = flags.get("emit-state");
    let against = match flags.get("against") {
        None => None,
        Some(path) => match VerifyState::load(Path::new(path)) {
            Ok(state) if state.matches_graph(&pair.dist) => Some(state),
            Ok(state) => {
                scalify::log_warn!(
                    "--against {path} captured '{}' on {} cores, this \
                     run verifies '{}' on {} cores; running cold",
                    state.model,
                    state.num_cores,
                    pair.dist.name,
                    pair.dist.num_cores
                );
                scalify::log_debug!(
                    "state file {path} parsed fine; only the graph identity check \
                     failed, so re-capture with --emit-state to use it again"
                );
                None
            }
            Err(why) => {
                scalify::log_warn!("{why}; running cold");
                None
            }
        },
    };
    let (report, state) = match &against {
        Some(prev) => {
            let (report, state) = session.verify_against(pair, prev)?;
            (report, Some(state))
        }
        None if emit_state.is_some() => {
            let (report, state) = session.verify_capture(pair)?;
            (report, Some(state))
        }
        None => (session.verify(pair)?, None),
    };
    if let Some(path) = emit_state {
        let state = state.as_ref().ok_or_else(|| {
            ScalifyError::runtime(
                "--emit-state verify produced no state to persist (internal: \
                 capture/against runs always derive one)",
            )
        })?;
        // an unwritable path is a runtime failure (exit code 3), not an
        // I/O mishap to shrug off: the caller asked for the state file
        // and must not find out at --against time that it never existed
        state.save(Path::new(path)).map_err(|e| {
            ScalifyError::runtime(format!("writing --emit-state {path}: {}", e.message()))
        })?;
        eprintln!("scalify: wrote verification state to {path}");
    }
    Ok(report)
}

/// Wrap a command body in `--trace FILE` handling: tracing switches on
/// before the work runs and the collected spans are exported as one
/// Chrome trace-event / Perfetto JSON document afterwards — on failed
/// and unverified runs too, since those traces are the interesting
/// ones. Without `--trace` the body runs untouched and every span site
/// stays on its disabled (one atomic load) path.
fn trace_scope<T>(flags: &Flags, f: impl FnOnce() -> Result<T>) -> Result<T> {
    let Some(path) = flags.get("trace") else { return f() };
    obs::start_tracing();
    let out = f();
    match obs::export_chrome_trace(Path::new(path)) {
        Ok(n) => eprintln!("scalify: wrote {n} trace spans to {path}"),
        Err(e) => scalify::log_warn!("writing --trace {path} failed: {e}"),
    }
    out
}

fn cmd_verify(flags: &Flags) -> Result<ExitCode> {
    let base = require(flags, "base", "baseline HLO file")?;
    let dist = require(flags, "dist", "distributed HLO file")?;
    let cores: u32 = match flags.get("cores") {
        Some(c) => c
            .parse()
            .map_err(|_| ScalifyError::config(format!("--cores wants an integer, got '{c}'")))?,
        None => 1,
    };
    let pair = load_pair(Path::new(base), Path::new(dist), cores)?;
    let session = Session::new(cli::config_from_flags(flags)?);
    let report = verify_incremental(&session, &pair, flags)?;
    emit_report(&report, flags.contains_key("json"), usize::MAX);
    Ok(report_exit(&report))
}

fn cmd_model(flags: &Flags) -> Result<ExitCode> {
    let model = flags.get("model").map(|s| s.as_str()).unwrap_or("llama-8b");
    // --parallelism is the spelled-out alias of --par
    let par_spec = flags
        .get("par")
        .or_else(|| flags.get("parallelism"))
        .map(|s| s.as_str())
        .unwrap_or("tp32");
    let par = cli::parallelism(par_spec)?;
    let layers = match flags.get("layers") {
        Some(l) => Some(l.parse().map_err(|_| {
            ScalifyError::config(format!("--layers wants an integer, got '{l}'"))
        })?),
        None => None,
    };
    let json = flags.contains_key("json");
    if !json {
        eprintln!("generating {model} ({}) graphs…", par.label());
    }
    let pair = cli::model_pair(model, par, layers)?;
    // scripted v1→v2 edit for the incremental CI/bench path — zoo models
    // only, because HLO text round-trips lose the layer tags the edit
    // keys on
    let pair = match flags.get("edit-layer") {
        Some(l) => {
            let layer: u32 = l.parse().map_err(|_| {
                ScalifyError::config(format!("--edit-layer wants an integer, got '{l}'"))
            })?;
            scalify::diff::one_op_edit(&pair, layer)?
        }
        None => pair,
    };
    if !json {
        eprintln!(
            "verifying {} baseline + {} distributed nodes…",
            pair.base.len(),
            pair.dist.len()
        );
    }
    let session = Session::new(cli::config_from_flags(flags)?);
    let report = verify_incremental(&session, &pair, flags)?;
    emit_report(&report, json, 10);
    Ok(report_exit(&report))
}

/// Parse an HLO file through the batch arena: each distinct
/// `(path, cores)` parses once, however often the manifest repeats it.
fn arena_parse(
    arena: &mut HashMap<(PathBuf, u32), Graph>,
    path: &Path,
    cores: u32,
) -> Result<Graph> {
    let key = (path.to_path_buf(), cores);
    if let Some(g) = arena.get(&key) {
        return Ok(g.clone());
    }
    let g = parse_hlo_file(path, cores).with_ctx(|| path.display().to_string())?;
    arena.insert(key, g.clone());
    Ok(g)
}

fn cmd_batch(flags: &Flags) -> Result<ExitCode> {
    let manifest = require(flags, "manifest", "text file of `base.hlo dist.hlo [cores]` lines")?;
    let text = std::fs::read_to_string(manifest)
        .with_ctx(|| format!("reading manifest {manifest}"))?;
    let entries = cli::parse_manifest(&text).with_ctx(|| format!("manifest {manifest}"))?;
    let json = flags.contains_key("json");

    // one arena of parsed graphs for the whole batch: manifests that pit
    // one baseline against many variants parse the baseline once
    let mut arena: HashMap<(PathBuf, u32), Graph> = HashMap::new();
    let prepared: Vec<Result<GraphPair>> = entries
        .iter()
        .map(|entry| {
            let bg = arena_parse(&mut arena, &entry.base, 1)?;
            let dg = arena_parse(&mut arena, &entry.dist, entry.cores)?;
            GraphPair::replicated(bg, dg)
        })
        .collect();
    drop(arena);

    // one session for the whole batch: templates compile once, and layers
    // shared between pairs (same model, different variants) hit the memo.
    // Entries run in parallel through the same bounded scheduler the
    // service uses, so batch and serve latencies are comparable.
    let session = Arc::new(Session::new(cli::config_from_flags(flags)?));
    let workers = cli::usize_flag(flags, "workers", 4)?.min(entries.len().max(1));
    let scheduler = Scheduler::new(workers, cli::usize_flag(flags, "queue", 64)?);
    // every manifest entry "arrives" now, so per-entry wall time is
    // measured from here — queue wait included, like the service's
    // per-request latency. Read off the shared metrics clock so batch
    // wall_secs and trace timestamps agree.
    let submitted = obs::stamp();
    let jobs: Vec<_> = prepared
        .into_iter()
        .map(|prep| {
            let session = Arc::clone(&session);
            move || {
                // one broken pair must not discard the rest of the batch
                prep.and_then(|pair| {
                    session.verify(&pair).map(|report| (report, submitted.elapsed()))
                })
            }
        })
        .collect();
    // flatten scheduler-level failures (a panicked worker job) into the
    // same per-entry error slot a broken pair lands in
    let outcomes: Vec<Result<_>> =
        scheduler.run_all(jobs).into_iter().map(|r| r.and_then(|x| x)).collect();

    let mut all_verified = true;
    let mut had_errors = false;
    let mut docs: Vec<Json> = Vec::new();
    for (entry, outcome) in entries.iter().zip(outcomes) {
        let mut fields = vec![
            ("base".into(), Json::Str(entry.base.display().to_string())),
            ("dist".into(), Json::Str(entry.dist.display().to_string())),
            ("cores".into(), Json::Num(entry.cores as f64)),
        ];
        match outcome {
            Ok((report, wall)) => {
                all_verified &= report.verified();
                if json {
                    fields.push(("report".into(), report.to_json()));
                    // per-entry wall time (queue wait + verify), so
                    // service and batch latency are comparable
                    fields.push(("wall_secs".into(), Json::Num(wall.as_secs_f64())));
                } else {
                    println!(
                        "{} ⊢ {}: {} [wall {}]",
                        entry.base.display(),
                        entry.dist.display(),
                        report.summary(),
                        scalify::util::fmt_duration(wall)
                    );
                    for d in report.discrepancies().iter().take(5) {
                        println!("  {}", d.render());
                    }
                }
            }
            Err(e) => {
                had_errors = true;
                all_verified = false;
                if json {
                    fields.push(("error".into(), Json::Str(e.to_string())));
                } else {
                    println!(
                        "{} ⊢ {}: ERROR — {e}",
                        entry.base.display(),
                        entry.dist.display()
                    );
                }
            }
        }
        if json {
            docs.push(Json::Obj(fields));
        }
    }
    let stats = session.stats();
    if json {
        print!(
            "{}",
            Json::Obj(vec![
                ("pairs".into(), Json::Arr(docs)),
                ("all_verified".into(), Json::Bool(all_verified)),
                ("had_errors".into(), Json::Bool(had_errors)),
                ("workers".into(), Json::Num(workers as f64)),
                ("session_runs".into(), Json::Num(stats.runs as f64)),
                ("memo_hits".into(), Json::Num(stats.memo_hits as f64)),
                ("memo_entries".into(), Json::Num(stats.memo_entries as f64)),
                ("memo_evictions".into(), Json::Num(stats.memo_evictions as f64)),
            ])
            .render_pretty()
        );
    } else {
        eprintln!(
            "batch: {} pairs on {} workers, {} memoized layer hits across the shared session",
            entries.len(),
            workers,
            stats.memo_hits
        );
    }
    Ok(if had_errors {
        ExitCode::from(2)
    } else if all_verified {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    })
}

fn cmd_serve(flags: &Flags) -> Result<ExitCode> {
    let cfg = cli::serve_config_from_flags(flags)?;
    let cache_note = cfg
        .cache_dir
        .as_ref()
        .map(|d| format!(", cache-dir {}", d.display()))
        .unwrap_or_default();
    let fleet_note =
        if cfg.shards > 1 { format!(", {} shards", cfg.shards) } else { String::new() };
    let server = Server::start(cfg)?;
    // the bound address goes to stdout (and is flushed) so scripts and
    // tests can read the ephemeral port; progress chatter stays on stderr
    println!("scalify: serving on {}", server.local_addr());
    let _ = std::io::stdout().flush();
    eprintln!(
        "scalify: verification service ready{fleet_note}{cache_note}; stop it with \
         `scalify client shutdown --addr {}`",
        server.local_addr()
    );
    server.wait();
    eprintln!("scalify: service stopped");
    Ok(ExitCode::SUCCESS)
}

/// Build the `scalify client verify` source from flags: `--bug ID`,
/// `--base/--dist [--cores N]` file pair, or `--model/--par [--layers N]`.
fn client_source(flags: &Flags) -> Result<VerifySource> {
    if let Some(id) = flags.get("bug") {
        return Ok(VerifySource::Bug { id: id.clone() });
    }
    match (flags.get("base"), flags.get("dist")) {
        (Some(base), Some(dist)) => {
            let cores: u32 = match flags.get("cores") {
                Some(c) => c.parse().map_err(|_| {
                    ScalifyError::config(format!("--cores wants an integer, got '{c}'"))
                })?,
                None => 1,
            };
            return Ok(VerifySource::Hlo {
                base: std::fs::read_to_string(base)
                    .with_ctx(|| format!("--base {base}"))?,
                dist: std::fs::read_to_string(dist)
                    .with_ctx(|| format!("--dist {dist}"))?,
                cores,
            });
        }
        // half an HLO pair must not silently fall back to a zoo model
        (Some(_), None) | (None, Some(_)) => {
            return Err(ScalifyError::config(
                "inline HLO verify needs both --base and --dist",
            ));
        }
        (None, None) => {}
    }
    let model = flags.get("model").cloned().unwrap_or_else(|| "llama-tiny".into());
    let par = flags
        .get("par")
        .or_else(|| flags.get("parallelism"))
        .cloned()
        .unwrap_or_else(|| "tp2".into());
    let layers = match flags.get("layers") {
        Some(l) => Some(l.parse().map_err(|_| {
            ScalifyError::config(format!("--layers wants an integer, got '{l}'"))
        })?),
        None => None,
    };
    let edit_layer = match flags.get("edit-layer") {
        Some(l) => Some(l.parse().map_err(|_| {
            ScalifyError::config(format!("--edit-layer wants an integer, got '{l}'"))
        })?),
        None => None,
    };
    Ok(VerifySource::Model { model, par, layers, edit_layer })
}

/// Exit code for a verify outcome: 0 verified, 1 unverified, 4 degraded
/// (the deadline cut the run; the verdict covers only the verified
/// prefix, so neither 0 nor 1 would be honest).
fn report_exit(report: &VerifyReport) -> ExitCode {
    if report.degraded {
        ExitCode::from(4)
    } else if report.verified() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

fn cmd_client(op: &str, flags: &Flags) -> Result<ExitCode> {
    let addr = require(flags, "addr", "daemon address host:port")?;
    let timeout_secs: f64 = match flags.get("timeout-secs") {
        Some(t) => {
            let secs = t.parse().map_err(|_| {
                ScalifyError::config(format!("--timeout-secs wants a number, got '{t}'"))
            })?;
            if secs < 0.0 {
                return Err(ScalifyError::config(format!(
                    "--timeout-secs must be >= 0 (0 disables the bound), got '{t}'"
                )));
            }
            secs
        }
        None => 30.0,
    };
    let timeout = std::time::Duration::from_secs_f64(timeout_secs);
    let retries: u32 = match flags.get("retries") {
        Some(r) => r.parse().map_err(|_| {
            ScalifyError::config(format!("--retries wants an integer, got '{r}'"))
        })?,
        None => 0,
    };
    let mut client = Client::connect_with_timeout(addr, timeout)?;
    let json = flags.contains_key("json");
    match op {
        "verify" => {
            let source = client_source(flags)?;
            // --against FILE rides the verify_diff request: the client
            // ships the state document verbatim, the daemon decides
            // whether it is usable (degrading to cold with a warning)
            let state = match flags.get("against") {
                Some(path) => {
                    let text = std::fs::read_to_string(path)
                        .with_ctx(|| format!("--against {path}"))?;
                    Some(Json::parse(&text).with_ctx(|| format!("--against {path}"))?)
                }
                None => None,
            };
            // any v2 request option upgrades the connection; without
            // them the request stays v1, byte-identical to older CLIs
            let wants_v2 = flags.contains_key("id")
                || flags.contains_key("priority")
                || flags.contains_key("deadline-secs")
                || flags.contains_key("stream");
            let opts = VerifyOpts {
                id: flags.get("id").cloned(),
                priority: match flags.get("priority") {
                    Some(p) => p.parse().map_err(|_| {
                        ScalifyError::config(format!(
                            "--priority wants an integer, got '{p}'"
                        ))
                    })?,
                    None => 0,
                },
                deadline_secs: match flags.get("deadline-secs") {
                    Some(d) => Some(d.parse().map_err(|_| {
                        ScalifyError::config(format!(
                            "--deadline-secs wants a number, got '{d}'"
                        ))
                    })?),
                    None => None,
                },
                stream: flags.contains_key("stream"),
            };
            let on_event = |e: scalify::service::LayerEvent| {
                eprintln!(
                    "layer {} ({}/{}) {}",
                    e.layer,
                    e.index + 1,
                    e.total,
                    if e.verified { "verified" } else { "UNVERIFIED" }
                );
            };
            let (report, latency_secs, stats, warning) = if retries > 0 {
                // reconnect-and-retry: each attempt is a fresh v2
                // connection reusing ONE request id, so a retry after a
                // lost response supersedes the stale attempt instead of
                // running it twice
                let policy = RetryPolicy {
                    attempts: retries + 1,
                    timeout,
                    ..RetryPolicy::default()
                };
                let request = match state {
                    Some(s) => Request::VerifyDiff { source, state: s },
                    None => Request::Verify(source),
                };
                let resp = verify_with_retry(addr, &request, &opts, &policy, on_event)?;
                match resp {
                    Response::VerifyDone { report, latency_secs, stats, warning, .. } => {
                        (report, latency_secs, stats, warning)
                    }
                    Response::Cancelled { message, .. } => {
                        return Err(ScalifyError::runtime(message));
                    }
                    Response::Error { message } => {
                        return Err(ScalifyError::runtime(message));
                    }
                    other => {
                        return Err(ScalifyError::runtime(format!(
                            "unexpected response to verify: {other:?}"
                        )));
                    }
                }
            } else if wants_v2 {
                let negotiated = client.hello(PROTOCOL_V2)?;
                if negotiated < PROTOCOL_V2 {
                    return Err(ScalifyError::runtime(format!(
                        "daemon only speaks protocol v{negotiated}; \
                         --id/--priority/--deadline-secs/--stream need v{PROTOCOL_V2}"
                    )));
                }
                let request = match state {
                    Some(s) => Request::VerifyDiff { source, state: s },
                    None => Request::Verify(source),
                };
                let resp = client.verify_opts(&request, &opts, on_event)?;
                match resp {
                    Response::VerifyDone { report, latency_secs, stats, warning, .. } => {
                        (report, latency_secs, stats, warning)
                    }
                    Response::Cancelled { message, .. } => {
                        return Err(ScalifyError::runtime(message));
                    }
                    Response::Error { message } => {
                        return Err(ScalifyError::runtime(message));
                    }
                    other => {
                        return Err(ScalifyError::runtime(format!(
                            "unexpected response to verify: {other:?}"
                        )));
                    }
                }
            } else {
                match state {
                    Some(s) => client.verify_diff(source, s)?,
                    None => {
                        let (report, latency_secs, stats) = client.verify(source)?;
                        (report, latency_secs, stats, None)
                    }
                }
            };
            if let Some(w) = &warning {
                scalify::log_warn!("{w}");
            }
            if json {
                let mut fields = vec![
                    ("report".into(), report.to_json()),
                    ("latency_secs".into(), Json::Num(latency_secs)),
                    ("stats".into(), stats.to_json()),
                ];
                if let Some(w) = &warning {
                    fields.push(("warning".into(), Json::Str(w.clone())));
                }
                print!("{}", Json::Obj(fields).render_pretty());
            } else {
                println!("{}", report.summary());
                for d in report.discrepancies().iter().take(10) {
                    println!("  {}", d.render());
                }
                eprintln!(
                    "daemon: {} jobs, {} memo hits ({} entries), {:.1} ms request latency",
                    stats.jobs,
                    stats.memo_hits,
                    stats.memo_entries,
                    latency_secs * 1e3
                );
            }
            Ok(report_exit(&report))
        }
        "faults" => {
            // inspect/arm/disarm the daemon's fault-injection registry
            // (chaos tooling; see TESTING.md for the spec syntax)
            client.hello(PROTOCOL_V2)?;
            let spec = flags.get("set").map(String::as_str);
            let clear = flags.contains_key("clear");
            let faults = client.faults(spec, clear)?;
            if json {
                let docs = faults
                    .iter()
                    .map(|f| {
                        Json::Obj(vec![
                            ("point".into(), Json::Str(f.point.clone())),
                            ("kind".into(), Json::Str(f.kind.clone())),
                            ("rate".into(), Json::Num(f.rate)),
                            ("seed".into(), Json::Num(f.seed as f64)),
                            ("evaluated".into(), Json::Num(f.evaluated as f64)),
                            ("fired".into(), Json::Num(f.fired as f64)),
                        ])
                    })
                    .collect();
                print!("{}", Json::Obj(vec![("faults".into(), Json::Arr(docs))]).render_pretty());
            } else if faults.is_empty() {
                eprintln!("scalify: no fault points armed");
            } else {
                for f in &faults {
                    println!(
                        "{}: {} at rate {} (seed {}) — fired {}/{}",
                        f.point, f.kind, f.rate, f.seed, f.fired, f.evaluated
                    );
                }
            }
            Ok(ExitCode::SUCCESS)
        }
        "stats" => {
            print!("{}", client.stats()?.to_json().render_pretty());
            Ok(ExitCode::SUCCESS)
        }
        "metrics" => {
            // Prometheus text exposition, already newline-terminated —
            // pipe it straight to stdout for scrapers and curl users
            print!("{}", client.metrics()?);
            Ok(ExitCode::SUCCESS)
        }
        "cancel" => {
            let id = require(flags, "id", "request id to cancel")?;
            client.hello(PROTOCOL_V2)?;
            if client.cancel(id)? {
                eprintln!("scalify: daemon cancelled in-flight request '{id}'");
                Ok(ExitCode::SUCCESS)
            } else {
                eprintln!("scalify: no in-flight request with id '{id}'");
                Ok(ExitCode::from(1))
            }
        }
        "shutdown" => {
            client.shutdown()?;
            eprintln!("scalify: daemon acknowledged shutdown");
            Ok(ExitCode::SUCCESS)
        }
        other => Err(ScalifyError::config(format!(
            "unknown client operation '{other}' (expected verify, stats, metrics, cancel, \
             faults or shutdown; e.g. `scalify client stats --addr 127.0.0.1:7878`)"
        ))),
    }
}

/// Bench regression gate: compare a fresh bench capture against a
/// committed baseline. The service tier gates the warm path at >1.5×
/// (plus a small absolute slack so sub-millisecond noise on shared CI
/// runners cannot trip the gate); the scale tier (`--scale`) gates the
/// cold, warm and no-memo parallel cold paths at a generous 2× with a
/// one-second slack, since a 126-layer cold verification rides CI-runner
/// weather (the parallel-vs-sequential ≥2× speedup itself is asserted
/// inside [`cmd_bench_scale`], like the diff tier's 10×); the diff
/// tier (`--diff`) gates the cold and the incremental path the same way —
/// the 10× cold/incremental speedup itself is asserted inside
/// [`cmd_bench_diff`], not here.
fn bench_check(baseline_path: &str, fresh_path: &str, tier: &str) -> Result<ExitCode> {
    let (ratio, slack, metrics): (f64, f64, &[&str]) = match tier {
        "scale" => (2.0, 1.0, &["cold_secs", "warm_secs", "cold_nomemo_par_secs"]),
        "diff" => (2.0, 2.0, &["cold_secs", "incremental_secs"]),
        // the load tier gates client-observed percentiles under
        // saturation; slack absorbs shared-CI queueing noise without
        // letting a real regression through
        "serve" => (2.0, 0.3, &["p50_secs", "p95_secs"]),
        _ => (1.5, 0.05, &["warm_secs"]),
    };
    let load = |path: &str| -> Result<Json> {
        let text =
            std::fs::read_to_string(path).with_ctx(|| format!("reading bench file {path}"))?;
        Json::parse(&text).with_ctx(|| format!("parsing bench file {path}"))
    };
    let baseline = load(baseline_path)?;
    let fresh = load(fresh_path)?;
    let scenarios = |doc: &Json| -> Result<HashMap<String, HashMap<String, f64>>> {
        let arr = doc
            .get("scenarios")
            .and_then(Json::as_arr)
            .ok_or_else(|| ScalifyError::parse("bench file has no 'scenarios' array"))?;
        let mut map = HashMap::new();
        for s in arr {
            let par = s
                .str_at("par")
                .ok_or_else(|| ScalifyError::parse("scenario missing 'par'"))?;
            let mut vals = HashMap::new();
            for &m in metrics {
                let v = s.f64_at(m).ok_or_else(|| {
                    ScalifyError::parse(format!("scenario '{par}' missing '{m}'"))
                })?;
                vals.insert(m.to_string(), v);
            }
            map.insert(par.to_string(), vals);
        }
        Ok(map)
    };
    let base = scenarios(&baseline)?;
    let new = scenarios(&fresh)?;
    let mut regressed = false;
    for (par, base_vals) in &base {
        let Some(new_vals) = new.get(par) else {
            eprintln!("bench-check: scenario '{par}' missing from {fresh_path}");
            regressed = true;
            continue;
        };
        for &m in metrics {
            let (base_v, new_v) = (base_vals[m], new_vals[m]);
            let limit = base_v * ratio + slack;
            let verdict = if new_v > limit { "REGRESSED" } else { "ok" };
            eprintln!(
                "bench-check {par}: {m} {new_v:.4}s vs baseline {base_v:.4}s \
                 (limit {limit:.4}s) — {verdict}"
            );
            regressed |= new_v > limit;
        }
    }
    if regressed {
        eprintln!(
            "bench-check: latency regressed more than {ratio}× over \
             {baseline_path} (re-baseline deliberately if the slowdown is intended)"
        );
        Ok(ExitCode::from(1))
    } else {
        eprintln!("bench-check: within {ratio}× of {baseline_path}");
        Ok(ExitCode::SUCCESS)
    }
}

/// Sum of e-nodes examined by the matcher across a report's layers.
fn ematch_tried(report: &VerifyReport) -> u64 {
    report.layers.iter().map(|l| l.matches_tried as u64).sum()
}

/// `scalify bench`: cold vs warm vs restart-warm service latency for the
/// llama pair under tp4, pp2tp4 and dp2tp2, written to
/// `BENCH_service.json`, plus the indexed-vs-naive e-match work ratio.
/// `--scale` runs the 405B-class tier instead (see [`cmd_bench_scale`]);
/// `--diff` runs the incremental verify-on-diff tier (see
/// [`cmd_bench_diff`]). `--check BASELINE.json` compares an existing
/// fresh report against the committed baseline instead (the CI
/// bench-regression gate; combine with `--scale`/`--diff` to gate those
/// tiers at their 2× thresholds).
fn cmd_bench(flags: &Flags) -> Result<ExitCode> {
    use scalify::partition::MemoEntry;

    let scale = flags.contains_key("scale");
    let diff = flags.contains_key("diff");
    let serve_load = flags.contains_key("serve-load");
    if [scale, diff, serve_load].iter().filter(|b| **b).count() > 1 {
        return Err(ScalifyError::config(
            "bench takes at most one of --scale, --diff or --serve-load",
        ));
    }
    let checking = flags.contains_key("check");
    let model = flags.get("model").map(String::as_str).unwrap_or(if scale || diff {
        "llama-405b-like"
    } else {
        "bench-llama"
    });
    // under --check --scale/--diff/--serve-load the fresh capture
    // defaults to the name the CI job writes, NOT the committed
    // baseline's — comparing a file against itself would green-light any
    // regression
    let tier = if scale {
        "scale"
    } else if diff {
        "diff"
    } else if serve_load {
        "serve"
    } else {
        "service"
    };
    let out_path = flags.get("out").map(String::as_str).unwrap_or(match (tier, checking) {
        ("scale", true) => "BENCH_scale_fresh.json",
        ("scale", false) => "BENCH_scale.json",
        ("diff", true) => "BENCH_diff_fresh.json",
        ("diff", false) => "BENCH_diff.json",
        ("serve", true) => "BENCH_serve_fresh.json",
        ("serve", false) => "BENCH_serve.json",
        _ => "BENCH_service.json",
    });
    if let Some(baseline_path) = flags.get("check") {
        if baseline_path == out_path {
            return Err(ScalifyError::config(format!(
                "bench --check would compare '{baseline_path}' against itself; point --out \
                 at the freshly generated capture"
            )));
        }
        return bench_check(baseline_path, out_path, tier);
    }
    if scale {
        return cmd_bench_scale(flags, model, out_path);
    }
    if diff {
        return cmd_bench_diff(flags, model, out_path);
    }
    if serve_load {
        return cmd_bench_serve_load(flags, out_path);
    }
    let pair_for = |par_spec: &str| -> Result<GraphPair> {
        let par = cli::parallelism(par_spec)?;
        if model == "bench-llama" {
            // bench-sized llama: heads divisible by tp4, layers by pp2
            let cfg = scalify::modelgen::LlamaConfig {
                layers: 4,
                hidden: 32,
                heads: 8,
                kv_heads: 8,
                ffn: 64,
                seqlen: 8,
                batch: 1,
            };
            scalify::modelgen::try_llama_pair(&cfg, par)
        } else {
            cli::model_pair(model, par, None)
        }
    };

    let t_start = obs::stamp();
    let mut scenarios: Vec<Json> = Vec::new();
    for par_spec in ["tp4", "pp2tp4", "dp2tp2"] {
        let pair = pair_for(par_spec)?;

        // fresh session per scenario so "cold" is honest; the memo-write
        // hook collects entries the way the service cache would
        let mut session = Session::new(VerifyConfig::default());
        let collected: Arc<Mutex<Vec<(u64, MemoEntry)>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&collected);
        session.set_memo_write_hook(Arc::new(move |fp, entry| {
            sink.lock().expect("bench hook lock").push((fp, entry.clone()));
        }));

        let t0 = obs::stamp();
        let cold_report = session.verify(&pair)?;
        let cold = t0.elapsed();
        let t0 = obs::stamp();
        let warm_report = session.verify(&pair)?;
        let warm = t0.elapsed();

        // restart simulation: a brand-new session preloaded from the
        // collected entries — the daemon's `--cache-dir` warm start
        let restarted = Session::new(VerifyConfig::default());
        let entries = collected.lock().expect("bench hook lock").clone();
        restarted.preload_memo(entries);
        let t0 = obs::stamp();
        let restart_report = restarted.verify(&pair)?;
        let restart = t0.elapsed();

        for (label, report) in [
            ("cold", &cold_report),
            ("warm", &warm_report),
            ("restart-warm", &restart_report),
        ] {
            if !report.verified() {
                return Err(ScalifyError::runtime(format!(
                    "bench pair under {par_spec} must verify, but the {label} run was {}",
                    report.summary()
                )));
            }
        }
        // e-match work comparison: one sequential un-memoized run under
        // each matcher. Identical verdicts are asserted — the indexed
        // matcher must only be faster, never different.
        let ratio_cfg = |mode: scalify::egraph::MatchMode| VerifyConfig {
            parallel: false,
            memoize: false,
            limits: scalify::egraph::RunLimits {
                match_mode: mode,
                ..scalify::egraph::RunLimits::default()
            },
            ..VerifyConfig::default()
        };
        let indexed_report =
            Session::new(ratio_cfg(scalify::egraph::MatchMode::Indexed)).verify(&pair)?;
        let naive_report =
            Session::new(ratio_cfg(scalify::egraph::MatchMode::Naive)).verify(&pair)?;
        if indexed_report.verified() != naive_report.verified() {
            return Err(ScalifyError::runtime(format!(
                "matcher divergence under {par_spec}: indexed={}, naive={}",
                indexed_report.summary(),
                naive_report.summary()
            )));
        }
        let (indexed_tried, naive_tried) =
            (ematch_tried(&indexed_report), ematch_tried(&naive_report));
        let reduction = naive_tried as f64 / (indexed_tried.max(1)) as f64;

        let stats = session.stats();
        let restart_stats = restarted.stats();
        scenarios.push(Json::Obj(vec![
            ("par".into(), Json::Str(par_spec.into())),
            ("layers".into(), Json::Num(cold_report.layers.len() as f64)),
            ("cold_secs".into(), Json::Num(cold.as_secs_f64())),
            ("warm_secs".into(), Json::Num(warm.as_secs_f64())),
            ("restart_warm_secs".into(), Json::Num(restart.as_secs_f64())),
            (
                "warm_speedup".into(),
                Json::Num(cold.as_secs_f64() / warm.as_secs_f64().max(1e-9)),
            ),
            ("ematch_tried".into(), Json::Num(indexed_tried as f64)),
            ("naive_ematch_tried".into(), Json::Num(naive_tried as f64)),
            ("ematch_reduction".into(), Json::Num(reduction)),
            ("memo_entries".into(), Json::Num(stats.memo_entries as f64)),
            ("memo_hits".into(), Json::Num(stats.memo_hits as f64)),
            (
                "restart_memo_hits".into(),
                Json::Num(restart_stats.memo_hits as f64),
            ),
        ]));
        eprintln!(
            "bench {par_spec}: cold {}, warm {}, restart-warm {}, e-match reduction {:.1}x",
            scalify::util::fmt_duration(cold),
            scalify::util::fmt_duration(warm),
            scalify::util::fmt_duration(restart),
            reduction
        );
    }

    let doc = Json::Obj(vec![
        ("bench".into(), Json::Str("service".into())),
        ("model".into(), Json::Str(model.into())),
        ("scenarios".into(), Json::Arr(scenarios)),
        ("total_secs".into(), Json::Num(t_start.elapsed().as_secs_f64())),
    ]);
    std::fs::write(out_path, doc.render_pretty()).with_ctx(|| format!("writing {out_path}"))?;
    eprintln!("scalify: wrote {out_path}");
    if flags.contains_key("json") {
        print!("{}", doc.render_pretty());
    }
    Ok(ExitCode::SUCCESS)
}

/// `scalify bench --scale`: the 405B-class tier. Verifies the 126-layer
/// GQA `llama-405b-like` pair cold and warm under tp8 / pp2tp4 / dp2tp2
/// and writes `BENCH_scale.json` with per-phase wall clock
/// (`partition` / `parallel-rewrite` / `verify-layers`) and the per-rule
/// match/apply/time counters of the cold run — the paper's "405B within
/// minutes on a commodity machine" claim as a reproducible artifact.
///
/// Each scenario also contrasts the parallel DAG cold path against the
/// fully sequential one with memoization **off** for both (with the memo
/// on, 125 of the 126 structurally-identical decoder layers dedup to one
/// job, so parallel ≈ sequential and the comparison measures nothing).
/// The run fails in-binary if the two paths disagree on the verdict or
/// any discrepancy site, or — on a machine with ≥ 4 cores — if the
/// parallel path is not at least 2× faster.
fn cmd_bench_scale(flags: &Flags, model: &str, out_path: &str) -> Result<ExitCode> {
    let layers = match flags.get("layers") {
        Some(l) => Some(l.parse().map_err(|_| {
            ScalifyError::config(format!("--layers wants an integer, got '{l}'"))
        })?),
        None => None,
    };
    let cores_here =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let t_start = obs::stamp();
    let mut scenarios: Vec<Json> = Vec::new();
    for par_spec in ["tp8", "pp2tp4", "dp2tp2"] {
        let par = cli::parallelism(par_spec)?;
        eprintln!("bench --scale: generating {model} under {par_spec}…");
        let pair = cli::model_pair(model, par, layers)?;
        eprintln!(
            "bench --scale: verifying {} baseline + {} distributed nodes…",
            pair.base.len(),
            pair.dist.len()
        );
        let session = Session::new(VerifyConfig::default());
        let t0 = obs::stamp();
        let cold_report = session.verify(&pair)?;
        let cold = t0.elapsed();
        let t0 = obs::stamp();
        let warm_report = session.verify(&pair)?;
        let warm = t0.elapsed();
        for (label, report) in [("cold", &cold_report), ("warm", &warm_report)] {
            if !report.verified() {
                return Err(ScalifyError::runtime(format!(
                    "scale pair under {par_spec} must verify, but the {label} run was {}",
                    report.summary()
                )));
            }
        }

        // ---- parallel vs sequential honest cold (memoize off) ----
        let t0 = obs::stamp();
        let par_report = Session::new(VerifyConfig {
            memoize: false,
            ..VerifyConfig::default()
        })
        .verify(&pair)?;
        let nomemo_par = t0.elapsed();
        let t0 = obs::stamp();
        let seq_report = Session::new(VerifyConfig {
            memoize: false,
            parallel: false,
            threads: 1,
            ..VerifyConfig::default()
        })
        .verify(&pair)?;
        let nomemo_seq = t0.elapsed();
        // the two paths must be observationally identical: same verdict,
        // same discrepancy sites, same per-layer verified flags (summary
        // strings embed durations and memo counts, so compare projections)
        let sites = |r: &VerifyReport| -> Vec<String> {
            r.discrepancies().iter().map(|d| d.site.clone()).collect()
        };
        if par_report.verified() != seq_report.verified()
            || sites(&par_report) != sites(&seq_report)
        {
            return Err(ScalifyError::runtime(format!(
                "parallel and sequential cold paths disagree under {par_spec}: \
                 '{}' vs '{}'",
                par_report.summary(),
                seq_report.summary()
            )));
        }
        let verified_flags = |r: &VerifyReport| -> Vec<(u32, bool)> {
            r.layers.iter().map(|l| (l.layer, l.verified)).collect()
        };
        if verified_flags(&par_report) != verified_flags(&seq_report) {
            return Err(ScalifyError::runtime(format!(
                "parallel and sequential cold paths disagree per-layer under {par_spec}"
            )));
        }
        let speedup = nomemo_seq.as_secs_f64() / nomemo_par.as_secs_f64().max(1e-9);
        if cores_here >= 4 && speedup < 2.0 {
            return Err(ScalifyError::runtime(format!(
                "parallel cold verify is only {speedup:.2}× faster than sequential \
                 under {par_spec} on {cores_here} cores (the scale tier requires ≥2×)"
            )));
        }

        // ---- tracing-overhead contrast (first scenario only) ----
        // One more cold verify with the tracer live, routed through a
        // bounded scheduler so the trace carries scheduler-queue spans
        // alongside the per-layer and per-rule ones. The enabled tracer
        // must stay within 5% of the untraced cold run, plus an absolute
        // slack so sub-second runs on noisy CI runners cannot trip the
        // gate. With `--trace FILE` the spans are exported as Perfetto
        // JSON; without it they are measured and discarded.
        let mut trace_fields: Vec<(String, Json)> = Vec::new();
        if par_spec == "tp8" {
            obs::start_tracing();
            let traced_session = Session::new(VerifyConfig::default());
            let traced_pair = pair.clone();
            let sched = Scheduler::new(1, 1);
            let t0 = obs::stamp();
            let traced_report =
                sched.execute(move || traced_session.verify(&traced_pair))??;
            let traced = t0.elapsed();
            let spans = match flags.get("trace") {
                Some(path) => {
                    let n = obs::export_chrome_trace(Path::new(path))
                        .with_ctx(|| format!("writing --trace {path}"))?;
                    eprintln!("scalify: wrote {n} trace spans to {path}");
                    n
                }
                None => obs::stop_tracing().len(),
            };
            if !traced_report.verified() {
                return Err(ScalifyError::runtime(format!(
                    "scale pair under {par_spec} must verify, but the traced run \
                     was {}",
                    traced_report.summary()
                )));
            }
            let overhead = traced.as_secs_f64() / cold.as_secs_f64().max(1e-9);
            let limit = cold.as_secs_f64() * 1.05 + 0.5;
            if traced.as_secs_f64() > limit {
                return Err(ScalifyError::runtime(format!(
                    "traced cold verify took {:.3}s vs {:.3}s untraced \
                     (limit {limit:.3}s) — span recording must stay within 5%",
                    traced.as_secs_f64(),
                    cold.as_secs_f64()
                )));
            }
            trace_fields.push((
                "traced_cold_secs".into(),
                Json::Num(traced.as_secs_f64()),
            ));
            trace_fields.push(("trace_overhead_ratio".into(), Json::Num(overhead)));
            trace_fields.push(("trace_events".into(), Json::Num(spans as f64)));
            eprintln!(
                "bench --scale {par_spec}: traced cold {} ({spans} spans, \
                 {overhead:.2}× untraced cold)",
                scalify::util::fmt_duration(traced),
            );
        }

        let phases = Json::Obj(
            cold_report
                .stopwatch
                .phases()
                .map(|(name, d)| (name.to_owned(), Json::Num(d.as_secs_f64())))
                .collect(),
        );
        let mut rules: Vec<scalify::egraph::RuleStat> = Vec::new();
        for l in &cold_report.layers {
            scalify::egraph::merge_rule_stats(&mut rules, &l.rules);
        }
        let stats = session.stats();
        let mut fields = vec![
            ("par".into(), Json::Str(par_spec.into())),
            ("layers".into(), Json::Num(cold_report.layers.len() as f64)),
            ("cold_secs".into(), Json::Num(cold.as_secs_f64())),
            ("warm_secs".into(), Json::Num(warm.as_secs_f64())),
            ("cold_nomemo_par_secs".into(), Json::Num(nomemo_par.as_secs_f64())),
            ("cold_nomemo_seq_secs".into(), Json::Num(nomemo_seq.as_secs_f64())),
            ("parallel_speedup".into(), Json::Num(speedup)),
            ("phases".into(), phases),
            ("ematch_tried".into(), Json::Num(ematch_tried(&cold_report) as f64)),
            (
                "rules".into(),
                Json::Arr(rules.iter().map(scalify::report::rule_stat_to_json).collect()),
            ),
            ("memo_entries".into(), Json::Num(stats.memo_entries as f64)),
            ("memo_hits".into(), Json::Num(stats.memo_hits as f64)),
        ];
        fields.extend(trace_fields);
        scenarios.push(Json::Obj(fields));
        eprintln!(
            "bench --scale {par_spec}: cold {} ({} layers), warm {}, no-memo cold \
             {} parallel vs {} sequential ({speedup:.2}× on {cores_here} cores)",
            scalify::util::fmt_duration(cold),
            cold_report.layers.len(),
            scalify::util::fmt_duration(warm),
            scalify::util::fmt_duration(nomemo_par),
            scalify::util::fmt_duration(nomemo_seq),
        );
    }
    let doc = Json::Obj(vec![
        ("bench".into(), Json::Str("scale".into())),
        ("model".into(), Json::Str(model.into())),
        ("scenarios".into(), Json::Arr(scenarios)),
        ("total_secs".into(), Json::Num(t_start.elapsed().as_secs_f64())),
    ]);
    std::fs::write(out_path, doc.render_pretty()).with_ctx(|| format!("writing {out_path}"))?;
    eprintln!("scalify: wrote {out_path}");
    if flags.contains_key("json") {
        print!("{}", doc.render_pretty());
    }
    Ok(ExitCode::SUCCESS)
}

/// `scalify bench --diff`: the incremental verify-on-diff tier. Captures
/// the verification state of `llama-405b-like` under tp8, applies a
/// scripted one-op edit to one mid-model layer, and measures a
/// `verify --against` re-verification of the edited pair against four
/// reference points:
///
/// * `cold_secs` — a from-scratch verify with memoization **off**. The
///   405B-class model's decoder layers are structurally identical, so a
///   default-config cold run dedups 125 of 126 layers in-session; that
///   win belongs to the memo, not the diff front end, and crediting it
///   to `--against` would overstate the speedup.
/// * `cold_memo_secs` — the default-config cold run (what a user
///   actually pays today), reported alongside for honesty.
/// * `unchanged_secs` — `verify --against` with zero edits: every layer
///   must replay (the 100%-reuse contract).
/// * `incremental_secs` — `verify --against` after the one-op edit:
///   exactly one layer re-verifies, verdicts identical to cold.
///
/// The run fails (exit ≠ 0) if any verdict diverges, if the diff front
/// end localizes the edit to more than its layer, or if the cold →
/// incremental speedup lands under 10× — the tier's core claim.
fn cmd_bench_diff(flags: &Flags, model: &str, out_path: &str) -> Result<ExitCode> {
    let layers = match flags.get("layers") {
        Some(l) => Some(l.parse().map_err(|_| {
            ScalifyError::config(format!("--layers wants an integer, got '{l}'"))
        })?),
        None => None,
    };
    let par_spec = flags.get("par").map(String::as_str).unwrap_or("tp8");
    let par = cli::parallelism(par_spec)?;
    let t_start = obs::stamp();
    eprintln!("bench --diff: generating {model} under {par_spec}…");
    let pair = cli::model_pair(model, par, layers)?;
    eprintln!(
        "bench --diff: verifying {} baseline + {} distributed nodes…",
        pair.base.len(),
        pair.dist.len()
    );

    // honest from-scratch cold: memoization off, so identical decoder
    // layers cannot dedup in-session
    let nomemo = VerifyConfig { memoize: false, ..VerifyConfig::default() };
    let t0 = obs::stamp();
    let cold_report = Session::new(nomemo).verify(&pair)?;
    let cold = t0.elapsed();

    // default-config cold + state capture (what `--emit-state` persists)
    let t0 = obs::stamp();
    let (memo_report, state) =
        Session::new(VerifyConfig::default()).verify_capture(&pair)?;
    let cold_memo = t0.elapsed();

    // unchanged re-verify in a fresh session: every layer must replay
    let t0 = obs::stamp();
    let (unchanged_report, _) =
        Session::new(VerifyConfig::default()).verify_against(&pair, &state)?;
    let unchanged = t0.elapsed();
    let reused = unchanged_report.layers.iter().filter(|l| l.reused).count();
    if reused != unchanged_report.layers.len() {
        return Err(ScalifyError::runtime(format!(
            "unchanged re-verify reused {reused}/{} layers — the 100%-reuse \
             contract is broken",
            unchanged_report.layers.len()
        )));
    }

    // scripted one-op edit on a mid-model layer
    let mut tags: Vec<u32> =
        state.layers.iter().map(|l| l.layer).filter(|&t| t != u32::MAX).collect();
    tags.sort_unstable();
    let edit_layer = *tags
        .get(tags.len() / 2)
        .ok_or_else(|| ScalifyError::runtime("model has no tagged layers to edit"))?;
    let edited = scalify::diff::one_op_edit(&pair, edit_layer)?;

    // the diff front end must localize the edit to exactly that layer
    let diff = scalify::diff::GraphDiff::compute(&pair.dist, &edited.dist);
    if diff.dirty_layers != vec![edit_layer] {
        return Err(ScalifyError::runtime(format!(
            "edit to layer {edit_layer} dirtied layers {:?}",
            diff.dirty_layers
        )));
    }

    let t0 = obs::stamp();
    let (inc_report, _) =
        Session::new(VerifyConfig::default()).verify_against(&edited, &state)?;
    let incremental = t0.elapsed();
    let reverified = inc_report.layers.iter().filter(|l| l.reverified).count();
    if reverified != 1 {
        return Err(ScalifyError::runtime(format!(
            "one-op edit re-verified {reverified} layers (expected exactly 1)"
        )));
    }
    let inc_reused = inc_report.layers.iter().filter(|l| l.reused).count();
    let delta_nodes: usize = inc_report.layers.iter().map(|l| l.delta_nodes).sum();

    for (label, report) in [
        ("cold", &cold_report),
        ("cold-memo", &memo_report),
        ("unchanged", &unchanged_report),
        ("incremental", &inc_report),
    ] {
        if !report.verified() {
            return Err(ScalifyError::runtime(format!(
                "diff-bench pair must verify, but the {label} run was {}",
                report.summary()
            )));
        }
    }

    let speedup = cold.as_secs_f64() / incremental.as_secs_f64().max(1e-9);
    if speedup < 10.0 {
        return Err(ScalifyError::runtime(format!(
            "incremental re-verify is only {speedup:.1}× faster than cold \
             (the diff tier requires ≥10×)"
        )));
    }

    let scenarios = vec![Json::Obj(vec![
        ("par".into(), Json::Str(par_spec.into())),
        ("layers".into(), Json::Num(cold_report.layers.len() as f64)),
        ("edit_layer".into(), Json::Num(edit_layer as f64)),
        ("cold_secs".into(), Json::Num(cold.as_secs_f64())),
        ("cold_memo_secs".into(), Json::Num(cold_memo.as_secs_f64())),
        ("unchanged_secs".into(), Json::Num(unchanged.as_secs_f64())),
        ("incremental_secs".into(), Json::Num(incremental.as_secs_f64())),
        ("speedup".into(), Json::Num(speedup)),
        ("reused_layers".into(), Json::Num(inc_reused as f64)),
        ("reverified_layers".into(), Json::Num(reverified as f64)),
        ("delta_nodes".into(), Json::Num(delta_nodes as f64)),
    ])];
    eprintln!(
        "bench --diff {par_spec}: cold {} (no memo), cold {} (memo), unchanged replay {}, \
         one-op edit {} — {speedup:.1}× cold→incremental",
        scalify::util::fmt_duration(cold),
        scalify::util::fmt_duration(cold_memo),
        scalify::util::fmt_duration(unchanged),
        scalify::util::fmt_duration(incremental),
    );
    let doc = Json::Obj(vec![
        ("bench".into(), Json::Str("diff".into())),
        ("model".into(), Json::Str(model.into())),
        ("scenarios".into(), Json::Arr(scenarios)),
        ("total_secs".into(), Json::Num(t_start.elapsed().as_secs_f64())),
    ]);
    std::fs::write(out_path, doc.render_pretty()).with_ctx(|| format!("writing {out_path}"))?;
    eprintln!("scalify: wrote {out_path}");
    if flags.contains_key("json") {
        print!("{}", doc.render_pretty());
    }
    Ok(ExitCode::SUCCESS)
}

/// `scalify bench --serve-load`: the fleet load tier. Boots an
/// in-process sharded daemon (4 shards, 4 scheduler workers, queue 16,
/// 2 verifier threads per shard) and hammers it with 8 concurrent
/// clients, each sending a mixed stream of zoo verifies, bug-corpus
/// verifies and incremental `verify_diff` requests against a
/// pre-captured state. Reports client-observed p50/p95/max latency and
/// saturation throughput; `bench --check BENCH_serve.json
/// --serve-load` gates the percentiles in nightly CI.
fn cmd_bench_serve_load(flags: &Flags, out_path: &str) -> Result<ExitCode> {
    use scalify::service::ServeConfig;

    const CLIENTS: usize = 8;
    const REQUESTS_PER_CLIENT: usize = 24;

    let cfg = ServeConfig {
        queue_capacity: 16,
        workers: 4,
        shards: 4,
        verify: VerifyConfig { threads: 2, ..VerifyConfig::default() },
        ..ServeConfig::default()
    };
    eprintln!(
        "bench --serve-load: starting an in-process fleet ({} shards, {} workers, \
         queue {})…",
        cfg.shards, cfg.workers, cfg.queue_capacity
    );
    let server = Server::start(cfg)?;
    let addr = server.local_addr().to_string();

    // pre-capture the state the diff mix replays against, exactly as a
    // client would have persisted it from an earlier --emit-state run
    let diff_source = VerifySource::Model {
        model: "llama-tiny".into(),
        par: "tp2".into(),
        layers: Some(4),
        edit_layer: None,
    };
    let pair = cli::model_pair("llama-tiny", cli::parallelism("tp2")?, Some(4))?;
    let capture_session = Session::new(VerifyConfig {
        threads: 2,
        parallel: false,
        ..VerifyConfig::default()
    });
    let (_, captured) = capture_session.verify_capture(&pair)?;
    let state_doc = captured.to_json();

    eprintln!(
        "bench --serve-load: {CLIENTS} clients × {REQUESTS_PER_CLIENT} mixed requests…"
    );
    let t_start = std::time::Instant::now();
    // bounded channel sized for every sample: senders never block, and
    // the harness stays std-only
    let (tx, rx) = std::sync::mpsc::sync_channel::<f64>(CLIENTS * REQUESTS_PER_CLIENT);
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let addr = addr.clone();
        let diff_source = diff_source.clone();
        let state_doc = state_doc.clone();
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut client = Client::connect(&addr)?;
            for r in 0..REQUESTS_PER_CLIENT {
                let t0 = std::time::Instant::now();
                match (c + r) % 3 {
                    0 => {
                        client.verify(VerifySource::Model {
                            model: "llama-tiny".into(),
                            par: "tp2".into(),
                            layers: None,
                            edit_layer: None,
                        })?;
                    }
                    1 => {
                        // bug-corpus requests come back unverified — that
                        // is still a served request, not an error
                        client.verify(VerifySource::Bug { id: "T4#1".into() })?;
                    }
                    _ => {
                        client.verify_diff(diff_source.clone(), state_doc.clone())?;
                    }
                }
                let _ = tx.send(t0.elapsed().as_secs_f64());
            }
            Ok(())
        }));
    }
    drop(tx);
    let mut latencies: Vec<f64> = rx.iter().collect();
    for handle in handles {
        handle
            .join()
            .map_err(|_| ScalifyError::runtime("a load-bench client thread panicked"))??;
    }
    let total_secs = t_start.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    let pct = |q: f64| -> f64 {
        if latencies.is_empty() {
            return 0.0;
        }
        let idx = ((latencies.len() - 1) as f64 * q).round() as usize;
        latencies[idx]
    };
    let total_requests = latencies.len();
    let (p50, p95, max) = (pct(0.50), pct(0.95), latencies.last().copied().unwrap_or(0.0));
    let throughput_rps = total_requests as f64 / total_secs.max(1e-9);
    eprintln!(
        "bench --serve-load: {total_requests} requests in {total_secs:.2}s — \
         p50 {:.1} ms, p95 {:.1} ms, max {:.1} ms, {throughput_rps:.1} req/s",
        p50 * 1e3,
        p95 * 1e3,
        max * 1e3
    );

    // second phase: the same mix under a 10% slow-layer fault — measures
    // the fleet's degraded throughput floor for the BENCH artifact
    const DEGRADED_CLIENTS: usize = 4;
    const DEGRADED_REQUESTS: usize = 8;
    let mut fault_client = Client::connect(&addr)?;
    fault_client.faults(Some("verify-layer:delay25:0.1:97"), false)?;
    eprintln!(
        "bench --serve-load: degraded phase — {DEGRADED_CLIENTS} clients × \
         {DEGRADED_REQUESTS} requests under verify-layer:delay25:0.1:97…"
    );
    let t_deg = std::time::Instant::now();
    let mut deg_handles = Vec::new();
    for c in 0..DEGRADED_CLIENTS {
        let addr = addr.clone();
        let diff_source = diff_source.clone();
        let state_doc = state_doc.clone();
        deg_handles.push(std::thread::spawn(move || -> Result<()> {
            let mut client = Client::connect(&addr)?;
            for r in 0..DEGRADED_REQUESTS {
                match (c + r) % 3 {
                    0 => {
                        client.verify(VerifySource::Model {
                            model: "llama-tiny".into(),
                            par: "tp2".into(),
                            layers: None,
                            edit_layer: None,
                        })?;
                    }
                    1 => {
                        client.verify(VerifySource::Bug { id: "T4#1".into() })?;
                    }
                    _ => {
                        client.verify_diff(diff_source.clone(), state_doc.clone())?;
                    }
                }
            }
            Ok(())
        }));
    }
    for handle in deg_handles {
        handle
            .join()
            .map_err(|_| ScalifyError::runtime("a degraded-phase client thread panicked"))??;
    }
    let degraded_secs = t_deg.elapsed().as_secs_f64();
    let degraded_rps =
        (DEGRADED_CLIENTS * DEGRADED_REQUESTS) as f64 / degraded_secs.max(1e-9);
    fault_client.faults(None, true)?;
    eprintln!(
        "bench --serve-load: degraded phase — {} requests in {degraded_secs:.2}s, \
         {degraded_rps:.1} req/s",
        DEGRADED_CLIENTS * DEGRADED_REQUESTS
    );

    // drain the daemon before reporting, so a wedged shutdown fails the
    // bench instead of leaking a background fleet
    let mut shutdown_client = Client::connect(&addr)?;
    shutdown_client.shutdown()?;
    server.wait();

    let doc = Json::Obj(vec![
        ("bench".into(), Json::Str("serve".into())),
        ("clients".into(), Json::Num(CLIENTS as f64)),
        ("requests_per_client".into(), Json::Num(REQUESTS_PER_CLIENT as f64)),
        (
            "scenarios".into(),
            Json::Arr(vec![Json::Obj(vec![
                ("par".into(), Json::Str(format!("mixed-{CLIENTS}"))),
                ("p50_secs".into(), Json::Num(p50)),
                ("p95_secs".into(), Json::Num(p95)),
                ("max_secs".into(), Json::Num(max)),
                ("throughput_rps".into(), Json::Num(throughput_rps)),
                ("degraded_rps".into(), Json::Num(degraded_rps)),
            ])]),
        ),
        ("total_secs".into(), Json::Num(total_secs)),
    ]);
    std::fs::write(out_path, doc.render_pretty()).with_ctx(|| format!("writing {out_path}"))?;
    eprintln!("scalify: wrote {out_path}");
    if flags.contains_key("json") {
        print!("{}", doc.render_pretty());
    }
    Ok(ExitCode::SUCCESS)
}

fn run_bug_table(title: &str, cases: Vec<scalify::bugs::BugCase>) -> bool {
    let mut table =
        Table::new(title, &["Bug ID", "Description", "Issue", "Expected", "Result", "Time"]);
    let mut ok = true;
    for case in cases {
        let outcome = evaluate(&case);
        let expected = match case.expected {
            ExpectedLoc::Instruction => "instr",
            ExpectedLoc::Function => "func",
            ExpectedLoc::NotApplicable => "n/a",
        };
        let result = match (outcome.detected, outcome.loc) {
            (false, _) if case.expected == ExpectedLoc::NotApplicable => "n/a (as paper)",
            (false, _) => {
                ok = false;
                "MISSED"
            }
            (true, LocResult::Instruction) => "detected @instr",
            (true, LocResult::Function) => "detected @func",
            (true, _) => "detected (elsewhere)",
        };
        table.row(&[
            case.id.to_string(),
            case.description.to_string(),
            case.issue.to_string(),
            expected.to_string(),
            result.to_string(),
            scalify::util::fmt_duration(outcome.duration),
        ]);
    }
    print!("{}", table.render());
    table.save_csv(&title.replace([' ', '—'], "_"));
    ok
}

fn cmd_bugs(flags: &Flags) -> Result<ExitCode> {
    let only_new = flags.contains_key("new");
    let only_reproduced = flags.contains_key("reproduced");
    let only_transform = flags.contains_key("transform");
    let mut all_ok = true;
    if !only_new && !only_transform {
        all_ok &= run_bug_table("Table 4 - reproduced bugs", reproduced_bugs());
    }
    if !only_reproduced && !only_transform {
        all_ok &= run_bug_table("Table 5 - new bugs", new_bugs());
    }
    if !only_new && !only_reproduced {
        all_ok &= run_bug_table(
            "Pipeline and data-parallel bugs",
            parallel_transform_bugs(),
        );
        all_ok &= run_bug_table("Replica-group (mesh subgroup) bugs", replica_group_bugs());
    }
    Ok(if all_ok { ExitCode::SUCCESS } else { ExitCode::from(1) })
}

fn cmd_exec(flags: &Flags) -> Result<ExitCode> {
    let path = require(flags, "artifact", "HLO-text artifact to execute")?;
    let exe = scalify::runtime::Executable::load(Path::new(path))?;
    let g = exe.graph();
    let mut prng = scalify::util::Prng::new(42);
    let inputs: Vec<scalify::interp::Tensor> = g
        .parameters()
        .iter()
        .map(|&pid| scalify::interp::Tensor::random(g.node(pid).shape.clone(), &mut prng))
        .collect();
    let t0 = std::time::Instant::now();
    let out = exe.run(&inputs)?;
    // artifacts with zero outputs are legal (e.g. effect-only modules) —
    // don't index out[0] unconditionally
    match out.first() {
        Some(first) => println!(
            "executed {} in {:?}: {} outputs, first shape {}",
            path,
            t0.elapsed(),
            out.len(),
            first.shape
        ),
        None => println!("executed {} in {:?}: 0 outputs", path, t0.elapsed()),
    }
    Ok(ExitCode::SUCCESS)
}

fn usage() -> String {
    format!(
        "scalify {} — computational-graph equivalence verifier\n\
         usage:\n  \
         scalify verify --base a.hlo.txt --dist b.hlo.txt [--cores N] \
         [--against STATE.json] [--emit-state STATE.json] [--trace TRACE.json] [--json]\n  \
         scalify model --model llama-8b|llama-70b|llama-405b|llama-405b-like|llama-tiny\
         |llama-tiny-gqa|mixtral-8x7b|mixtral-8x22b|mixtral-tiny|dpstep-tiny|dpstep-small \
         --par tp32|sp32|fd32|ep8|pp4|dp4z1|pp2tp4|dp2tp2|pp2dp2tp2 [--layers N] \
         [--against STATE.json] [--emit-state STATE.json] [--edit-layer N] \
         [--trace TRACE.json] [--json]\n  \
         scalify batch --manifest pairs.txt [--workers N] [--trace TRACE.json] [--json]\n  \
         scalify serve [--addr 127.0.0.1:7878] [--cache-dir DIR] [--queue N] [--workers N] \
         [--shards N]\n  \
         scalify client verify|stats|metrics|cancel|faults|shutdown --addr HOST:PORT \
         [--model M --par P | --bug ID | --base a.hlo --dist b.hlo] [--against STATE.json] \
         [--edit-layer N] [--id ID] [--priority N] [--deadline-secs S] [--stream] \
         [--timeout-secs S] [--retries N] [--set SPEC] [--clear] [--json]\n  \
         scalify bench [--scale|--diff|--serve-load] [--model M] [--out FILE] \
         [--check BASELINE.json] [--trace TRACE.json] [--json]\n  \
         scalify bugs [--reproduced|--new|--transform]\n  \
         scalify exec --artifact artifacts/model_single.hlo.txt\n  \
         scalify info\n\
         common flags: --threads N --memo-capacity N --no-partition --no-parallel --no-memoize\n\
         env: SCALIFY_LOG=warn|info|debug (stderr log level, default warn)\n     \
         SCALIFY_FAULTS=point:kind:rate:seed[,...] (deterministic fault injection,\n     \
         e.g. shard-verify:panic:0.2:42 — see TESTING.md § chaos suite)\n\
         exit codes: 0 verified/ok · 1 unverified · 2 usage/input error · 3 runtime error \
         · 4 degraded (deadline hit; partial verdict)",
        scalify::VERSION
    )
}

fn run(args: &[String]) -> Result<ExitCode> {
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    // `client` takes its operation as a positional word (`scalify client
    // stats --addr ...`), everything else is pure `--flag value`
    if cmd == "client" {
        let (op, rest) = match args.get(1) {
            Some(op) if !op.starts_with("--") => (op.as_str(), &args[2..]),
            _ => ("", &args[1..]),
        };
        let flags = cli::parse_flags(rest)?;
        return cmd_client(op, &flags);
    }
    let flags = cli::parse_flags(&args[1.min(args.len())..])?;
    match cmd {
        "verify" => trace_scope(&flags, || cmd_verify(&flags)),
        "model" => trace_scope(&flags, || cmd_model(&flags)),
        "batch" => trace_scope(&flags, || cmd_batch(&flags)),
        "serve" => cmd_serve(&flags),
        "bench" => cmd_bench(&flags),
        "bugs" => cmd_bugs(&flags),
        "exec" => cmd_exec(&flags),
        "info" => {
            println!("scalify {} — computational-graph equivalence verifier", scalify::VERSION);
            Ok(ExitCode::SUCCESS)
        }
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(ExitCode::SUCCESS)
        }
        other => Err(ScalifyError::config(format!(
            "unknown command '{other}'\n{}",
            usage()
        ))),
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // arm chaos faults before any subsystem runs, so injection covers
    // startup paths (cache load, shard construction) too
    if let Err(e) = scalify::faults::install_from_env() {
        eprintln!("scalify: {e}");
        return ExitCode::from(2);
    }
    match run(&args) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("scalify: {e}");
            ExitCode::from(cli::exit_code_for(&e))
        }
    }
}
