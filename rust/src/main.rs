//! `scalify` CLI — the leader entrypoint.
//!
//! ```text
//! scalify verify --base <hlo> --dist <hlo> [--cores N]   verify two HLO files
//! scalify model --model llama-8b --par tp32 [--layers N] verify a zoo model
//! scalify bugs [--reproduced|--new]                      run the bug corpus
//! scalify exec --artifact <hlo>                          run via PJRT
//! scalify info                                           version/build info
//! ```

use scalify::bugs::{evaluate, new_bugs, reproduced_bugs, ExpectedLoc, LocResult};
use scalify::hlo::parse_hlo_file;
use scalify::ir::Annotation;
use scalify::modelgen::{llama_pair, mixtral_pair, LlamaConfig, MixtralConfig, Parallelism};
use scalify::report::Table;
use scalify::verifier::{GraphPair, Verifier, VerifyConfig};
use std::collections::HashMap;
use std::path::Path;
use std::process::ExitCode;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = args
                .get(i + 1)
                .filter(|v| !v.starts_with("--"))
                .cloned()
                .unwrap_or_else(|| "true".into());
            if val != "true" {
                i += 1;
            }
            flags.insert(key.to_string(), val);
        }
        i += 1;
    }
    flags
}

fn parallelism(spec: &str) -> Parallelism {
    let (kind, deg) = spec.split_at(2);
    let deg: u32 = deg.parse().unwrap_or(32);
    match kind {
        "tp" => Parallelism::Tensor { tp: deg },
        "sp" => Parallelism::Sequence { tp: deg },
        "fd" => Parallelism::FlashDecoding { tp: deg },
        "ep" => Parallelism::Expert { ep: deg },
        other => panic!("unknown parallelism '{other}' (tp/sp/fd/ep + degree)"),
    }
}

fn model_pair(model: &str, par: Parallelism, layers: Option<u32>) -> GraphPair {
    let mk = |mut cfg: LlamaConfig| {
        if let Some(l) = layers {
            cfg.layers = l;
        }
        llama_pair(&cfg, par)
    };
    match model {
        "llama-8b" => mk(LlamaConfig::llama3_8b()),
        "llama-70b" => mk(LlamaConfig::llama3_70b()),
        "llama-405b" => mk(LlamaConfig::llama3_405b()),
        "llama-tiny" => mk(LlamaConfig::tiny()),
        "mixtral-8x7b" => {
            let mut cfg = MixtralConfig::mixtral_8x7b();
            if let Some(l) = layers {
                cfg.layers = l;
            }
            mixtral_pair(&cfg, par)
        }
        "mixtral-8x22b" => {
            let mut cfg = MixtralConfig::mixtral_8x22b();
            if let Some(l) = layers {
                cfg.layers = l;
            }
            mixtral_pair(&cfg, par)
        }
        other => panic!("unknown model '{other}'"),
    }
}

fn cmd_verify(flags: &HashMap<String, String>) -> ExitCode {
    let base = flags.get("base").expect("--base <hlo file>");
    let dist = flags.get("dist").expect("--dist <hlo file>");
    let cores: u32 = flags.get("cores").map(|c| c.parse().unwrap()).unwrap_or(1);
    let bg = parse_hlo_file(Path::new(base), 1).expect("parse --base");
    let dg = parse_hlo_file(Path::new(dist), cores).expect("parse --dist");
    // positional replicated annotations (HLO files carry no sharding info)
    let ann: Vec<Annotation> = bg
        .parameters()
        .into_iter()
        .zip(dg.parameters())
        .map(|(b, d)| Annotation::replicated(b, d))
        .collect();
    let pair = GraphPair::new(bg, dg, ann);
    let report = Verifier::new(VerifyConfig::default()).verify_pair(&pair);
    println!("{}", report.summary());
    for d in report.discrepancies() {
        println!("  {}", d.render());
    }
    if report.verified() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

fn cmd_model(flags: &HashMap<String, String>) -> ExitCode {
    let model = flags.get("model").map(|s| s.as_str()).unwrap_or("llama-8b");
    let par = parallelism(flags.get("par").map(|s| s.as_str()).unwrap_or("tp32"));
    let layers = flags.get("layers").map(|l| l.parse().unwrap());
    eprintln!("generating {model} ({}) graphs…", par.label());
    let pair = model_pair(model, par, layers);
    eprintln!(
        "verifying {} baseline + {} distributed nodes…",
        pair.base.len(),
        pair.dist.len()
    );
    let report = Verifier::new(VerifyConfig::default()).verify_pair(&pair);
    println!("{}", report.summary());
    for d in report.discrepancies().iter().take(10) {
        println!("  {}", d.render());
    }
    if report.verified() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

fn run_bug_table(title: &str, cases: Vec<scalify::bugs::BugCase>) -> bool {
    let mut table =
        Table::new(title, &["Bug ID", "Description", "Issue", "Expected", "Result", "Time"]);
    let mut ok = true;
    for case in cases {
        let outcome = evaluate(&case);
        let expected = match case.expected {
            ExpectedLoc::Instruction => "instr",
            ExpectedLoc::Function => "func",
            ExpectedLoc::NotApplicable => "n/a",
        };
        let result = match (outcome.detected, outcome.loc) {
            (false, _) if case.expected == ExpectedLoc::NotApplicable => "n/a (as paper)",
            (false, _) => {
                ok = false;
                "MISSED"
            }
            (true, LocResult::Instruction) => "detected @instr",
            (true, LocResult::Function) => "detected @func",
            (true, _) => "detected (elsewhere)",
        };
        table.row(&[
            case.id.to_string(),
            case.description.to_string(),
            case.issue.to_string(),
            expected.to_string(),
            result.to_string(),
            scalify::util::fmt_duration(outcome.duration),
        ]);
    }
    print!("{}", table.render());
    table.save_csv(&title.replace([' ', '—'], "_"));
    ok
}

fn cmd_bugs(flags: &HashMap<String, String>) -> ExitCode {
    let only_new = flags.contains_key("new");
    let only_reproduced = flags.contains_key("reproduced");
    let mut all_ok = true;
    if !only_new {
        all_ok &= run_bug_table("Table 4 - reproduced bugs", reproduced_bugs());
    }
    if !only_reproduced {
        all_ok &= run_bug_table("Table 5 - new bugs", new_bugs());
    }
    if all_ok {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

fn cmd_exec(flags: &HashMap<String, String>) -> ExitCode {
    let path = flags.get("artifact").expect("--artifact <hlo file>");
    let exe = scalify::runtime::Executable::load(Path::new(path)).expect("load artifact");
    let g = parse_hlo_file(Path::new(path), 1).expect("parse artifact");
    let mut prng = scalify::util::Prng::new(42);
    let inputs: Vec<scalify::interp::Tensor> = g
        .parameters()
        .iter()
        .map(|&pid| scalify::interp::Tensor::random(g.node(pid).shape.clone(), &mut prng))
        .collect();
    let t0 = std::time::Instant::now();
    let out = exe.run(&inputs).expect("execute");
    println!(
        "executed {} in {:?}: {} outputs, first shape {}",
        path,
        t0.elapsed(),
        out.len(),
        out[0].shape
    );
    ExitCode::SUCCESS
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);
    match cmd {
        "verify" => cmd_verify(&flags),
        "model" => cmd_model(&flags),
        "bugs" => cmd_bugs(&flags),
        "exec" => cmd_exec(&flags),
        "info" => {
            println!("scalify {} — computational-graph equivalence verifier", scalify::VERSION);
            ExitCode::SUCCESS
        }
        _ => {
            println!(
                "scalify {} — usage:\n  scalify verify --base a.hlo.txt --dist b.hlo.txt [--cores N]\n  scalify model --model llama-8b|llama-70b|llama-405b|mixtral-8x7b|mixtral-8x22b --par tp32|sp32|fd32|ep8 [--layers N]\n  scalify bugs [--reproduced|--new]\n  scalify exec --artifact artifacts/model_single.hlo.txt\n  scalify info",
                scalify::VERSION
            );
            ExitCode::SUCCESS
        }
    }
}
