//! # Scalify — verifying computational graphs of distributed ML frameworks
//!
//! Reproduction of *"Verifying Computational Graphs in Production-Grade
//! Distributed Machine Learning Frameworks"* (Scalify, 2025).
//!
//! Scalify checks **semantic equivalence** between a baseline
//! (single-device) computational graph and a transformed (distributed /
//! optimized) graph, exposing silent errors before they degrade trained
//! models.
//!
//! ## The `Session` API
//!
//! The entrypoint is a persistent [`verifier::Session`]: it owns the
//! compiled rewrite-template set, a cross-run layer memo keyed by
//! structural fingerprint, and a reusable worker pool — so verifying a
//! second model config or a second parallelism variant reuses everything
//! the first call built. Malformed input is a typed [`error::ScalifyError`],
//! never a panic:
//!
//! ```
//! use scalify::prelude::*;
//! use scalify::modelgen::demo;
//!
//! let cfg = VerifyConfig::builder().threads(2).build()?;
//! let session = Session::new(cfg);
//!
//! // first call verifies every layer and fills the session memo…
//! let report = session.verify(&demo::matmul_allreduce_pair(2))?;
//! assert!(report.verified());
//!
//! // …so a structurally-overlapping second call replays it
//! let again = session.verify(&demo::matmul_allreduce_pair(2))?;
//! assert!(again.layers.iter().all(|l| l.memoized));
//! # Ok::<(), scalify::error::ScalifyError>(())
//! ```
//!
//! Reports serialize to JSON ([`verifier::VerifyReport::to_json_string`])
//! and parse back ([`verifier::VerifyReport::from_json_str`]) for
//! machine consumers; the CLI exposes the same via `--json` and verifies
//! whole manifests through one shared session (`scalify batch`).
//!
//! For fleets, the [`service`] module runs the session as a long-lived
//! daemon (`scalify serve`): concurrent clients share one compiled
//! template set and one layer memo through a bounded scheduler, and
//! `--cache-dir` persists memo entries (keyed by stable structural
//! fingerprint) across process restarts.
//!
//! ## Engine internals
//!
//! * an **e-graph** engine ([`egraph`]) performing equality saturation over
//!   tensor IR terms,
//! * a **Datalog-style relational analysis** ([`relations`]) propagating
//!   `sharded` / `layout` / `partial` / `slice` / `loop_red` facts between
//!   the two graphs (Table 1 of the paper),
//! * **symbolic bijection inference** ([`layout`]) aligning heterogeneous
//!   reshape–transpose sequences (Algorithm 2),
//! * **graph partitioning, parallel rewriting and layer memoization**
//!   ([`partition`]) for production-scale graphs (Algorithm 1), and
//! * **discrepancy-based bug localization** ([`localize`]) mapping failures
//!   back to source sites.
//!
//! The crate also ships the substrates a full reproduction needs: a tensor
//! IR ([`ir`]), an HLO-text parser/printer ([`hlo`]) interoperating with
//! JAX-lowered artifacts, a reference interpreter with simulated
//! collectives ([`interp`]), a model zoo emitting Llama/Mixtral-style
//! baseline+distributed graph pairs ([`modelgen`]), a corpus of injected
//! production bugs ([`bugs`]), numerical/per-element baseline verifiers
//! ([`baseline`]), and an execution runtime ([`runtime`]) for AOT-compiled
//! JAX artifacts.
pub mod error;
pub mod obs;
pub mod util;
pub mod faults;
pub mod ir;
pub mod hlo;
pub mod interp;
pub mod egraph;
pub mod layout;
pub mod relations;
pub mod partition;
pub mod diff;
pub mod verifier;
pub mod localize;
pub mod modelgen;
pub mod transform;
pub mod bugs;
pub mod baseline;
pub mod runtime;
pub mod report;
pub mod bench;
pub mod cli;
pub mod service;
pub mod proptest;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::error::{Result, ScalifyError};
    pub use crate::ir::{
        Annotation, AxesMask, DType, Graph, GraphBuilder, Mesh, Node, NodeId, Op,
        ReduceKind, ReplicaGroups, Shape,
    };
    pub use crate::diff::{GraphDiff, VerifyState};
    pub use crate::localize::Discrepancy;
    pub use crate::modelgen::{
        GraphPair, LlamaConfig, MixtralConfig, Parallelism, TrainStepConfig,
    };
    pub use crate::service::{Client, ServeConfig, Server, VerifySource};
    pub use crate::transform::{ParallelPlan, ShardRule};
    pub use crate::verifier::{
        Session, SessionStats, Verdict, VerifyConfig, VerifyConfigBuilder, VerifyReport,
    };
    #[allow(deprecated)]
    pub use crate::verifier::Verifier;
}

/// Crate version string used by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
