//! The persistent verification engine.
//!
//! A [`Session`] is the service-shaped entrypoint of Scalify: it owns
//! state that is expensive to build and profitable to reuse across many
//! `verify` calls —
//!
//! * the **compiled rewrite-template set** ([`crate::egraph::RuleSet`]),
//!   built once and shared via `Arc` with every worker,
//! * a **cross-run layer memo** ([`LayerMemo`]): layers are keyed by
//!   structural fingerprint, so a second Llama config or a second
//!   parallelism variant replays every structurally-identical layer
//!   instead of re-verifying it, and
//! * a **reusable worker pool** ([`WorkerPool`]) for the parallel cold
//!   pass, so threads are spawned once per session rather than once per
//!   call.
//!
//! The cold path schedules the whole verify as a **dependency DAG** on
//! the pool (see [`Session::parallel_pass`]): per-layer e-graph
//! saturation + relation fixpoints are independent jobs that only
//! synchronize on boundary out-relations, so a 126-layer model saturates
//! every core instead of verifying one layer at a time. Setting
//! `SCALIFY_SEQUENTIAL=1` disables the parallel pass entirely — the
//! differential-testing escape hatch, mirroring `SCALIFY_NAIVE_MATCH`
//! for the e-matcher. Both paths produce byte-identical verdicts,
//! localization sites and per-layer e-graph counts; the ordered
//! assembly pass below is the single source of truth for reports.
//!
//! Continuous verification alongside a training pipeline is the intended
//! shape (TTrace-style); `verify` takes `&self` and is safe to call from
//! multiple threads.

use super::boundary::RelSummary;
use super::{layer, LayerReport, Verdict, VerifyConfig, VerifyReport};
use crate::diff::{id_multiset_delta, layer_node_ids, LayerState, VerifyState};
use crate::egraph::RuleSet;
use crate::error::{Result, ScalifyError};
use crate::localize::Discrepancy;
use crate::obs;
use crate::partition::{extract_layers, fingerprint_pair, LayerMemo, LayerSlice, MemoEntry};
use crate::util::{Stopwatch, WorkerPool};
use crate::verifier::GraphPair;
use rustc_hash::FxHashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Aggregate statistics of a session's lifetime.
#[derive(Clone, Debug, Default)]
pub struct SessionStats {
    /// `verify` calls served.
    pub runs: usize,
    /// Distinct layer fingerprints memoized.
    pub memo_entries: usize,
    /// Layer verifications served from the memo.
    pub memo_hits: usize,
    /// Layer verifications computed and inserted.
    pub memo_misses: usize,
    /// Memo entries evicted to stay within `VerifyConfig::memo_capacity`.
    pub memo_evictions: usize,
    /// Compiled rewrite templates.
    pub templates: usize,
    /// Worker threads owned by the pool (0 when the session is sequential).
    pub threads: usize,
}

/// Observer invoked (outside the memo lock) each time the session inserts
/// a freshly-computed entry into its layer memo. The service layer hooks
/// its persistent on-disk cache here so warm state survives restarts.
pub type MemoWriteHook = Arc<dyn Fn(u64, &MemoEntry) + Send + Sync>;

/// One per-layer progress notification delivered through
/// [`VerifyControl::progress`] as the ordered assembly pass completes
/// each layer (whatever served it: cold verify, memo hit or diff
/// replay). Layers missing from the baseline graph produce a
/// discrepancy, not a progress event.
#[derive(Clone, Copy, Debug)]
pub struct LayerProgress {
    /// Layer tag (`LayerSlice::layer`).
    pub layer: u32,
    /// Zero-based position in dist order.
    pub index: usize,
    /// Total layers in this verify call.
    pub total: usize,
    /// Whether the layer verified.
    pub verified: bool,
    /// Served from the memo / parallel pass rather than verified cold.
    pub memoized: bool,
    /// Replayed from a persisted [`VerifyState`] (diff runs only).
    pub reused: bool,
}

/// Cooperative cancellation, deadline and progress hooks for a single
/// verify call ([`Session::verify_controlled`] /
/// [`Session::verify_against_controlled`]).
///
/// All three hooks are checked or fired **at layer boundaries** of the
/// ordered assembly pass — the granularity the streaming service
/// protocol exposes. A set `cancel` token aborts the call with a typed
/// [`ScalifyError::Runtime`] whose message contains `cancelled`; no
/// partial report is produced. An expired `deadline` instead *degrades*:
/// the call returns a [`VerifyReport`] carrying the verified-layer
/// prefix with `degraded: true` and the first unverified layer named,
/// and the deadline is also threaded into
/// [`crate::egraph::RunLimits::deadline`] so a single long saturation
/// stops within one rewrite iteration. The parallel cold pass is not
/// interrupted mid-round (its jobs are short); cancellation takes
/// effect when the assembly pass next reaches a layer boundary.
#[derive(Clone, Default)]
pub struct VerifyControl {
    /// Shared flag; set to `true` (by any thread) to abort the call.
    pub cancel: Arc<AtomicBool>,
    /// Absolute deadline; past it the call aborts at the next boundary.
    pub deadline: Option<Instant>,
    /// Per-layer progress observer (e.g. the streaming event writer).
    pub progress: Option<Arc<dyn Fn(LayerProgress) + Send + Sync>>,
}

impl VerifyControl {
    /// Control block with no deadline, no observer and an unset token.
    pub fn new() -> VerifyControl {
        VerifyControl::default()
    }

    /// The shared cancellation token (clone to hand to another thread).
    pub fn token(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.cancel)
    }

    /// Whether the token has been set.
    pub fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }

    fn check_cancel(&self) -> Result<()> {
        if self.cancel.load(Ordering::Relaxed) {
            return Err(ScalifyError::runtime("verify cancelled at a layer boundary"));
        }
        Ok(())
    }

    fn deadline_passed(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }
}

fn check_cancel(control: Option<&VerifyControl>) -> Result<()> {
    control.map_or(Ok(()), VerifyControl::check_cancel)
}

fn deadline_passed(control: Option<&VerifyControl>) -> bool {
    control.is_some_and(VerifyControl::deadline_passed)
}

fn notify_progress(control: Option<&VerifyControl>, p: LayerProgress) {
    if let Some(cb) = control.and_then(|c| c.progress.as_ref()) {
        cb(p);
    }
}

/// Persistent verification engine; see the module docs.
pub struct Session {
    cfg: VerifyConfig,
    rules: Arc<RuleSet>,
    memo: Mutex<LayerMemo>,
    pool: Option<WorkerPool>,
    runs: AtomicUsize,
    memo_hook: Option<MemoWriteHook>,
}

impl Session {
    /// New session owning compiled templates, an empty memo and (when the
    /// config enables parallelism) a worker pool.
    pub fn new(cfg: VerifyConfig) -> Session {
        Session::with_rules(cfg, Arc::new(RuleSet::compile()))
    }

    /// New session sharing an already-compiled rule set. The shard pool
    /// of the service daemon uses this so N shards compile the template
    /// set once instead of N times; each shard still owns its own memo
    /// and worker pool.
    pub fn with_rules(cfg: VerifyConfig, rules: Arc<RuleSet>) -> Session {
        let pool = if cfg.parallel && cfg.threads > 1 {
            Some(WorkerPool::new(cfg.threads))
        } else {
            None
        };
        Session {
            rules,
            memo: Mutex::new(LayerMemo::with_capacity(cfg.memo_capacity)),
            pool,
            runs: AtomicUsize::new(0),
            memo_hook: None,
            cfg,
        }
    }

    /// Register the memo-write observer. Must be called before the session
    /// is shared (`&mut self`); the hook fires after every fresh insert,
    /// outside the memo lock, so it may do I/O without serializing
    /// concurrent `verify` callers.
    pub fn set_memo_write_hook(&mut self, hook: MemoWriteHook) {
        self.memo_hook = Some(hook);
    }

    /// Warm-start the memo from previously-persisted entries (no misses
    /// are counted; the work was done by an earlier process). Returns how
    /// many entries were loaded. Entries beyond `memo_capacity` evict LRU
    /// as usual.
    pub fn preload_memo<I>(&self, entries: I) -> usize
    where
        I: IntoIterator<Item = (u64, MemoEntry)>,
    {
        let mut memo = self.memo.lock().expect("memo lock");
        let mut n = 0;
        for (fp, entry) in entries {
            memo.preload(fp, entry);
            n += 1;
        }
        n
    }

    /// Session with the default configuration.
    pub fn with_default_config() -> Session {
        Session::new(VerifyConfig::default())
    }

    /// The session configuration.
    pub fn config(&self) -> &VerifyConfig {
        &self.cfg
    }

    /// The shared compiled rewrite-template set.
    pub fn rules(&self) -> &Arc<RuleSet> {
        &self.rules
    }

    /// Lifetime statistics (runs, memo reuse, pool size).
    pub fn stats(&self) -> SessionStats {
        let memo = self.memo.lock().expect("memo lock");
        SessionStats {
            runs: self.runs.load(Ordering::Relaxed),
            memo_entries: memo.len(),
            memo_hits: memo.hits,
            memo_misses: memo.misses,
            memo_evictions: memo.evictions,
            templates: self.rules.len(),
            threads: self.pool.as_ref().map(|p| p.threads()).unwrap_or(0),
        }
    }

    /// Drop every memoized layer result (e.g. after a rule-set change in a
    /// long-lived service).
    pub fn clear_memo(&self) {
        self.memo.lock().expect("memo lock").clear();
    }

    /// Verify a baseline/distributed graph pair.
    ///
    /// Unlike the deprecated `Verifier::verify_pair`, malformed input is a
    /// typed [`ScalifyError`] instead of a panic, and repeated calls reuse
    /// the session's templates, memo and workers.
    pub fn verify(&self, pair: &GraphPair) -> Result<VerifyReport> {
        Ok(self.verify_full(pair, None, false, None)?.0)
    }

    /// [`Session::verify`] with cancellation/deadline/progress hooks; see
    /// [`VerifyControl`].
    pub fn verify_controlled(
        &self,
        pair: &GraphPair,
        control: &VerifyControl,
    ) -> Result<VerifyReport> {
        Ok(self.verify_full(pair, None, false, Some(control))?.0)
    }

    /// Verify and additionally capture a persistable [`VerifyState`]
    /// (per-layer fingerprints, boundary out-relations and stable node
    /// ids) that a later `verify_against` can replay.
    pub fn verify_capture(&self, pair: &GraphPair) -> Result<(VerifyReport, VerifyState)> {
        let (report, state) = self.verify_full(pair, None, true, None)?;
        Ok((report, state.expect("capture always builds a state")))
    }

    /// Incremental re-verification against a previous run's persisted
    /// state: layers whose pair fingerprint still matches a *verified*
    /// entry in `prev` replay their boundary out-relations without any
    /// e-graph work (`LayerReport::reused`); everything downstream of the
    /// diff re-derives as usual (`LayerReport::reverified`, with
    /// `delta_nodes` from the stable-id multiset difference). Replay is
    /// fingerprint-gated, so a stale or wrong state can cost time but
    /// never produce a wrong verdict. Returns the fresh state for the
    /// next round.
    pub fn verify_against(
        &self,
        pair: &GraphPair,
        prev: &VerifyState,
    ) -> Result<(VerifyReport, VerifyState)> {
        let (report, state) = self.verify_full(pair, Some(prev), true, None)?;
        Ok((report, state.expect("capture always builds a state")))
    }

    /// [`Session::verify_against`] with cancellation/deadline/progress
    /// hooks; see [`VerifyControl`].
    pub fn verify_against_controlled(
        &self,
        pair: &GraphPair,
        prev: &VerifyState,
        control: &VerifyControl,
    ) -> Result<(VerifyReport, VerifyState)> {
        let (report, state) = self.verify_full(pair, Some(prev), true, Some(control))?;
        Ok((report, state.expect("capture always builds a state")))
    }

    fn verify_full(
        &self,
        pair: &GraphPair,
        against: Option<&VerifyState>,
        capture: bool,
        control: Option<&VerifyControl>,
    ) -> Result<(VerifyReport, Option<VerifyState>)> {
        self.validate_pair(pair)?;
        self.runs.fetch_add(1, Ordering::Relaxed);
        obs::metrics::count("scalify_verify_runs_total", 1);
        let _run_span = obs::span_fmt("verify", format_args!("verify {}", pair.dist.name));

        let start = Instant::now();
        let mut sw = Stopwatch::new();

        // thread the call's deadline into the saturation limits so one
        // long rewrite stops within an iteration, not a layer
        let mut limits = self.cfg.limits;
        if let Some(d) = control.and_then(|c| c.deadline) {
            limits.deadline = Some(limits.deadline.map_or(d, |l| l.min(d)));
        }

        // ---- partitioning ----
        let (base_layers, dist_layers) = sw.time("partition", || {
            let _sp = obs::span("phase", "partition");
            if self.cfg.partition {
                (extract_layers(&pair.base), extract_layers(&pair.dist))
            } else {
                (whole_graph_slice(&pair.base), whole_graph_slice(&pair.dist))
            }
        });
        let base_layers = Arc::new(base_layers);
        let dist_layers = Arc::new(dist_layers);

        // annotation map: dist param orig id -> (base orig id, summary)
        let mut boundary: FxHashMap<crate::ir::NodeId, (crate::ir::NodeId, RelSummary)> =
            FxHashMap::default();
        for a in &pair.annotations {
            let rel = match &a.relation {
                crate::ir::InputRelation::ShardAlong { dim, parts, axis } => {
                    RelSummary::Sharded { dim: *dim, parts: *parts, axis: *axis }
                }
                crate::ir::InputRelation::Replicated => RelSummary::Duplicate,
                crate::ir::InputRelation::DeviceIds => continue,
            };
            if let Some(b) = a.baseline {
                boundary.insert(a.distributed, (b, rel));
            }
        }

        // pair layers by tag, in dist order
        let base_idx_by_tag: FxHashMap<u32, usize> =
            base_layers.iter().enumerate().map(|(i, l)| (l.layer, i)).collect();

        // ---- optional parallel DAG pass ----
        // The cold verify is a dependency DAG: layer k's exact input
        // relations come from the boundary out-relations of the earlier
        // layers that produce its inputs. `parallel_pass` schedules that
        // DAG on the worker pool — dependency-satisfied layers run with
        // exact relations, the rest run speculatively (boundary relations
        // between transformer layers are almost always `Duplicate`: the
        // residual stream keeps its placement) and are promoted when the
        // exact relations turn out to match. The ordered assembly pass
        // below reuses any result whose relations equal the exact ones.
        // (skipped on `verify_against` runs: the persisted state is about
        // to replay unchanged layers for free; skipped entirely under
        // SCALIFY_SEQUENTIAL=1, the differential-testing escape hatch)
        let mut speculated: FxHashMap<u32, (Vec<(usize, usize, RelSummary)>, layer::LayerOutcome)> =
            FxHashMap::default();
        if self.cfg.parallel
            && !sequential_override()
            && self.cfg.partition
            && dist_layers.len() > 1
            && against.is_none()
        {
            sw.time("parallel-rewrite", || {
                let _sp = obs::span("phase", "parallel-rewrite");
                speculated = self.parallel_pass(
                    &base_layers,
                    &dist_layers,
                    &base_idx_by_tag,
                    &boundary,
                    limits,
                );
            });
        }

        // stable node identities, grouped the way the state stores them —
        // only computed when a state is being captured or compared
        let node_ids_by_layer = if capture || against.is_some() {
            Some(layer_node_ids(&pair.dist, self.cfg.partition))
        } else {
            None
        };

        // ---- sequential pass with exact boundary propagation ----
        let mut reports = Vec::new();
        let mut state_layers: Option<Vec<LayerState>> = capture.then(Vec::new);
        let mut all_discrepancies: Vec<Discrepancy> = Vec::new();
        let mut exhausted: Option<String> = None;
        let mut degraded_at: Option<String> = None;
        let total_layers = dist_layers.len();
        sw.time("verify-layers", || -> Result<()> {
            let _sp = obs::span("phase", "verify-layers");
            for (li, dslice) in dist_layers.iter().enumerate() {
                // cancellation, deadlines and superseded-request aborts
                // all take effect here, at layer boundaries: cancel is a
                // typed error, a blown deadline degrades to the verified
                // prefix instead of throwing it away
                check_cancel(control)?;
                if deadline_passed(control) {
                    degraded_at = Some(format!("layer {}", dslice.layer));
                    break;
                }
                crate::faults::check("verify-layer")?;
                let Some(bslice) =
                    base_idx_by_tag.get(&dslice.layer).map(|&i| &base_layers[i])
                else {
                    all_discrepancies.push(Discrepancy {
                        dist_node: crate::ir::NodeId(0),
                        site: String::new(),
                        func: String::new(),
                        expr: format!("layer {}", dslice.layer),
                        reason: "layer missing from baseline graph".into(),
                        layer: Some(dslice.layer),
                    });
                    continue;
                };
                let t0 = Instant::now();
                // exactly one `layer`-category span per reported layer,
                // whatever served it (replay, memo, promotion, cold)
                let mut lsp =
                    obs::span_fmt("layer", format_args!("layer {}", dslice.layer));
                lsp.attr("layer", dslice.layer as u64);
                let input_rels = layer::collect_input_rels(bslice, dslice, &boundary);
                let fp = fingerprint_pair(bslice, dslice, &input_rels, pair.dist.num_cores);
                // (the slice hashes its own mesh axes — see hash_slice)
                let new_ids = node_ids_by_layer
                    .as_ref()
                    .and_then(|m| m.get(&dslice.layer))
                    .map(|v| v.as_slice())
                    .unwrap_or(&[]);
                let prev_layer = against.and_then(|s| s.layer(dslice.layer));
                // semi-naive replay: an unchanged layer (same fingerprint,
                // previously verified) re-emits its persisted boundary
                // out-relations — the facts downstream layers seed from —
                // without running an e-graph. A changed layer falls through
                // to full verification, and because its *out-relations*
                // feed the next layer's fingerprint, any layer its change
                // actually affects re-verifies in turn.
                let state_replay =
                    prev_layer.filter(|ls| ls.verified && ls.fingerprint == fp);
                if let Some(ls) = state_replay {
                    // diff replay decision: unchanged layer, no e-graph work
                    lsp.attr("reused", 1);
                    obs::metrics::count("scalify_layers_reused_total", 1);
                    let entry = MemoEntry {
                        verified: true,
                        out_rels: ls.out_rels.clone(),
                        egraph_nodes: ls.egraph_nodes,
                        egraph_classes: ls.egraph_classes,
                    };
                    if self.cfg.memoize {
                        // warm the session memo too (no miss counted: the
                        // work was done by the producing run)
                        self.memo.lock().expect("memo lock").preload(fp, entry.clone());
                    }
                    for (k, rel) in ls.out_rels.iter().enumerate() {
                        if let (Some(&b), Some(&d)) = (
                            bslice.boundary_outputs.get(k),
                            dslice.boundary_outputs.get(k),
                        ) {
                            boundary.insert(d, (b, rel.clone()));
                        }
                    }
                    reports.push(LayerReport {
                        layer: dslice.layer,
                        stage: dslice.stage(),
                        verified: true,
                        memoized: false,
                        reused: true,
                        reverified: false,
                        delta_nodes: 0,
                        egraph_nodes: ls.egraph_nodes,
                        egraph_classes: ls.egraph_classes,
                        facts: 0,
                        matches_tried: 0,
                        rules: vec![],
                        duration: t0.elapsed(),
                    });
                    if let Some(layers) = &mut state_layers {
                        layers.push(LayerState {
                            layer: dslice.layer,
                            stage: dslice.stage(),
                            fingerprint: fp,
                            verified: true,
                            out_rels: ls.out_rels.clone(),
                            egraph_nodes: ls.egraph_nodes,
                            egraph_classes: ls.egraph_classes,
                            node_ids: new_ids.to_vec(),
                        });
                    }
                    notify_progress(
                        control,
                        LayerProgress {
                            layer: dslice.layer,
                            index: li,
                            total: total_layers,
                            verified: true,
                            memoized: false,
                            reused: true,
                        },
                    );
                    continue;
                }
                // `verify_layer` is a pure function of (slices, input
                // relations, cores, rules, limits), so a parallel-pass
                // result computed with the *same* relations is the exact
                // result — verified or not; failed outcomes carry their
                // discrepancies and replay identically
                let spec_hit = speculated
                    .get(&dslice.layer)
                    .filter(|(rels, _)| rels == &input_rels)
                    .map(|(_, o)| o.clone());
                let from_parallel = spec_hit.is_some();
                // the memo lock is taken per lookup/insert, never across a
                // verify_layer call, so concurrent `verify` callers on the
                // same session interleave instead of serializing
                let memo_entry = if self.cfg.memoize && spec_hit.is_none() {
                    self.memo.lock().expect("memo lock").get(fp)
                } else {
                    None
                };
                let (outcome, memoized) = match (spec_hit, self.cfg.memoize, memo_entry) {
                    (Some(o), memoize, _) => {
                        // a speculative result must land in the cross-run
                        // memo too, or a parallel first run leaves the
                        // session cold for every later run
                        if memoize && o.verified {
                            let entry = MemoEntry {
                                verified: o.verified,
                                out_rels: o.out_rels.clone(),
                                egraph_nodes: o.egraph_nodes,
                                egraph_classes: o.egraph_classes,
                            };
                            let inserted = {
                                let mut memo = self.memo.lock().expect("memo lock");
                                if memo.contains_verified(fp) {
                                    false
                                } else {
                                    memo.put(fp, entry.clone());
                                    true
                                }
                            };
                            if inserted {
                                if let Some(hook) = &self.memo_hook {
                                    hook(fp, &entry);
                                }
                            }
                        }
                        (o, true)
                    }
                    (None, true, Some(entry)) if entry.verified => (
                        layer::LayerOutcome {
                            verified: true,
                            out_rels: entry.out_rels.clone(),
                            discrepancies: vec![],
                            egraph_nodes: entry.egraph_nodes,
                            egraph_classes: entry.egraph_classes,
                            facts: 0,
                            exhausted: false,
                            matches_tried: 0,
                            node_overshoot: 0,
                            rule_stats: vec![],
                            stop: crate::egraph::StopReason::Saturated,
                        },
                        true,
                    ),
                    _ => {
                        let o = layer::verify_layer(
                            bslice,
                            dslice,
                            &input_rels,
                            pair.dist.num_cores,
                            &self.rules,
                            limits,
                            self.cfg.max_rounds,
                        );
                        if self.cfg.memoize && o.verified {
                            let entry = MemoEntry {
                                verified: o.verified,
                                out_rels: o.out_rels.clone(),
                                egraph_nodes: o.egraph_nodes,
                                egraph_classes: o.egraph_classes,
                            };
                            self.memo.lock().expect("memo lock").put(fp, entry.clone());
                            if let Some(hook) = &self.memo_hook {
                                hook(fp, &entry);
                            }
                        }
                        (o, false)
                    }
                };
                if outcome.stop == crate::egraph::StopReason::DeadlineExceeded
                    && !outcome.verified
                {
                    // the saturation was cut short, so "not verified" means
                    // "not *yet* verified" — drop the truncated layer's
                    // outcome (its discrepancies would be artifacts of the
                    // interrupted run) and degrade at this boundary.
                    // A layer that verified *despite* the cut is a complete
                    // proof (verification is monotone) and is kept above.
                    degraded_at = Some(format!("layer {}", dslice.layer));
                    break;
                }
                if outcome.exhausted {
                    exhausted = Some(format!("layer {}", dslice.layer));
                }
                // propagate boundary output relations
                for (k, rel) in outcome.out_rels.iter().enumerate() {
                    if let (Some(&b), Some(&d)) =
                        (bslice.boundary_outputs.get(k), dslice.boundary_outputs.get(k))
                    {
                        boundary.insert(d, (b, rel.clone()));
                    }
                }
                all_discrepancies.extend(outcome.discrepancies.iter().cloned());
                let reverified = against.is_some();
                let delta_nodes = if reverified {
                    id_multiset_delta(
                        prev_layer.map(|l| l.node_ids.as_slice()).unwrap_or(&[]),
                        new_ids,
                    )
                } else {
                    0
                };
                lsp.attr("memoized", memoized as u64);
                lsp.attr("verified", outcome.verified as u64);
                lsp.attr("matches_tried", outcome.matches_tried as u64);
                if from_parallel {
                    // speculative-then-promoted DAG result served here
                    lsp.attr("promoted", 1);
                }
                if reverified {
                    // diff decision: downstream of the edit, re-derived
                    lsp.attr("reverified", 1);
                    lsp.attr("delta_nodes", delta_nodes as u64);
                    obs::metrics::count("scalify_layers_reverified_total", 1);
                }
                obs::metrics::count(
                    if memoized {
                        "scalify_layers_memoized_total"
                    } else {
                        "scalify_layers_cold_total"
                    },
                    1,
                );
                reports.push(LayerReport {
                    layer: dslice.layer,
                    stage: dslice.stage(),
                    verified: outcome.verified,
                    memoized,
                    reused: false,
                    reverified,
                    delta_nodes,
                    egraph_nodes: outcome.egraph_nodes,
                    egraph_classes: outcome.egraph_classes,
                    facts: outcome.facts,
                    matches_tried: outcome.matches_tried,
                    rules: outcome.rule_stats.clone(),
                    duration: t0.elapsed(),
                });
                if let Some(layers) = &mut state_layers {
                    layers.push(LayerState {
                        layer: dslice.layer,
                        stage: dslice.stage(),
                        fingerprint: fp,
                        verified: outcome.verified,
                        out_rels: outcome.out_rels.clone(),
                        egraph_nodes: outcome.egraph_nodes,
                        egraph_classes: outcome.egraph_classes,
                        node_ids: new_ids.to_vec(),
                    });
                }
                notify_progress(
                    control,
                    LayerProgress {
                        layer: dslice.layer,
                        index: li,
                        total: total_layers,
                        verified: outcome.verified,
                        memoized,
                        reused: false,
                    },
                );
            }
            Ok(())
        })?;

        let verdict = if let Some(at) = exhausted {
            Verdict::ResourceExhausted { at }
        } else if reports.iter().all(|r| r.verified) && all_discrepancies.is_empty() {
            Verdict::Verified
        } else {
            Verdict::Unverified { discrepancies: all_discrepancies }
        };
        let state = state_layers.map(|layers| VerifyState {
            model: pair.dist.name.clone(),
            num_cores: pair.dist.num_cores,
            mesh: pair.dist.mesh.clone(),
            status: verdict.status().into(),
            layers,
        });
        let report = VerifyReport {
            verdict,
            layers: reports,
            stopwatch: sw,
            total: start.elapsed(),
            degraded: degraded_at.is_some(),
            first_unverified: degraded_at,
        };
        Ok((report, state))
    }

    /// Typed validation of a pair before any work is done (the one-shot
    /// API's `debug_assert!`s, promoted to real errors).
    fn validate_pair(&self, pair: &GraphPair) -> Result<()> {
        pair.base.validate().map_err(|e| e.context("baseline graph"))?;
        pair.dist.validate().map_err(|e| e.context("distributed graph"))?;
        if pair.dist.num_cores == 0 {
            return Err(ScalifyError::model_spec("distributed graph declares 0 cores"));
        }
        for a in &pair.annotations {
            if a.distributed.idx() >= pair.dist.len() {
                return Err(ScalifyError::model_spec(format!(
                    "annotation names distributed node {} but the graph has {} nodes",
                    a.distributed.0,
                    pair.dist.len()
                )));
            }
            if let Some(b) = a.baseline {
                if b.idx() >= pair.base.len() {
                    return Err(ScalifyError::model_spec(format!(
                        "annotation names baseline node {} but the graph has {} nodes",
                        b.0,
                        pair.base.len()
                    )));
                }
            }
        }
        Ok(())
    }

    /// Parallel cold verification scheduled as a dependency DAG on the
    /// session pool.
    ///
    /// Layer `k`'s exact input relations are determined by the boundary
    /// out-relations of the earlier layers producing its inputs, so the
    /// layers form a DAG (in practice: a chain through the residual
    /// stream, plus dep-free weight inputs). The pass runs in rounds:
    ///
    /// 1. **Cascade** — every layer whose producers are finalized derives
    ///    its exact input relations for free: a finished job with the same
    ///    relations is *promoted* to the exact result (`verify_layer` is
    ///    deterministic in its inputs), and a verified cross-run memo
    ///    entry replays its out-relations without any job.
    /// 2. **Schedule** — dependency-satisfied layers run with exact
    ///    relations; the rest run **speculatively** (unknown boundaries
    ///    assumed `Duplicate` — the residual stream keeps its placement),
    ///    so all 126 layers of a 405B-class model are in flight at once
    ///    instead of waiting on the chain. With memoization on,
    ///    fingerprint-identical jobs run once and alias.
    ///
    /// Mis-speculated results are dropped and re-run with exact relations
    /// in a later round; a panicking job errors only its own slot (typed,
    /// via [`WorkerPool::run_all`]) and its layer falls back to the
    /// assembly pass. The returned map is keyed by layer tag; the
    /// assembly pass re-checks relation equality before reusing any
    /// entry, so this pass can only waste work, never change a verdict.
    fn parallel_pass(
        &self,
        base_layers: &Arc<Vec<LayerSlice>>,
        dist_layers: &Arc<Vec<LayerSlice>>,
        base_idx_by_tag: &FxHashMap<u32, usize>,
        boundary: &FxHashMap<crate::ir::NodeId, (crate::ir::NodeId, RelSummary)>,
        limits: crate::egraph::RunLimits,
    ) -> FxHashMap<u32, (Vec<(usize, usize, RelSummary)>, layer::LayerOutcome)> {
        type Rels = Vec<(usize, usize, RelSummary)>;
        let Some(pool) = &self.pool else {
            // sequential session: the assembly pass does all the work
            return FxHashMap::default();
        };
        let cfg = &self.cfg;
        let n = dist_layers.len();

        // ---- dependency DAG over dist-order layer indices ----
        // producer[orig node] = slice producing it as a boundary output
        let mut producer: FxHashMap<crate::ir::NodeId, usize> = FxHashMap::default();
        for (di, d) in dist_layers.iter().enumerate() {
            for &o in &d.boundary_outputs {
                producer.insert(o, di);
            }
        }
        // deps = earlier slices producing one of this slice's inputs.
        // Only earlier ones: the assembly pass walks layers in dist order,
        // so a later producer's out-relations are never visible to this
        // layer there either (the untagged prologue/epilogue slice can
        // consume the last layer's output — that back-edge is not a dep).
        let deps: Vec<Vec<usize>> = dist_layers
            .iter()
            .enumerate()
            .map(|(di, d)| {
                let mut ds: Vec<usize> = d
                    .ext_inputs
                    .iter()
                    .filter_map(|e| producer.get(e).copied())
                    .filter(|&p| p < di)
                    .collect();
                ds.sort_unstable();
                ds.dedup();
                ds
            })
            .collect();

        // finalized = exact out-relations known (or nothing to propagate);
        // exact_outs = those out-relations, for downstream boundary views
        let mut finalized = vec![false; n];
        let mut exact_outs: Vec<Option<Vec<RelSummary>>> = vec![None; n];
        // finished jobs (exact or speculative) awaiting promotion, with
        // the input relations they actually used
        let mut pending: Vec<Option<(Rels, layer::LayerOutcome)>> = (0..n).map(|_| None).collect();
        let mut spec_submitted = vec![false; n];
        let mut out: FxHashMap<u32, (Rels, layer::LayerOutcome)> = FxHashMap::default();

        // the boundary exactly as the assembly pass will see it when it
        // reaches slice `di`: annotations + finalized earlier out-relations
        let view_for = |di: usize,
                        exact_outs: &[Option<Vec<RelSummary>>]|
         -> FxHashMap<crate::ir::NodeId, (crate::ir::NodeId, RelSummary)> {
            let mut view = boundary.clone();
            for (j, outs) in exact_outs.iter().enumerate().take(di) {
                let Some(rels) = outs else { continue };
                let dj = &dist_layers[j];
                let Some(&bi) = base_idx_by_tag.get(&dj.layer) else { continue };
                let bj = &base_layers[bi];
                for (k, rel) in rels.iter().enumerate() {
                    if let (Some(&b), Some(&d)) =
                        (bj.boundary_outputs.get(k), dj.boundary_outputs.get(k))
                    {
                        view.insert(d, (b, rel.clone()));
                    }
                }
            }
            view
        };

        loop {
            // ---- cascade: finalize everything derivable without new work ----
            let mut progressed = true;
            while progressed {
                progressed = false;
                for di in 0..n {
                    if finalized[di] || !deps[di].iter().all(|&j| finalized[j]) {
                        continue;
                    }
                    let d = &dist_layers[di];
                    let Some(&bi) = base_idx_by_tag.get(&d.layer) else {
                        // no baseline partner: the assembly pass reports
                        // the discrepancy; nothing to propagate
                        finalized[di] = true;
                        progressed = true;
                        continue;
                    };
                    let b = &base_layers[bi];
                    let rels = layer::collect_input_rels(b, d, &view_for(di, &exact_outs));
                    if let Some((jrels, o)) = pending[di].take() {
                        if jrels == rels {
                            // promotion: same relations ⇒ same outcome
                            obs::metrics::count("scalify_parallel_promoted_total", 1);
                            exact_outs[di] = Some(o.out_rels.clone());
                            out.insert(d.layer, (jrels, o));
                            finalized[di] = true;
                            progressed = true;
                            continue;
                        }
                        // mis-speculation: drop the result; an exact job
                        // runs in the next round
                    }
                    if cfg.memoize {
                        let fp = fingerprint_pair(b, d, &rels, d.graph.num_cores);
                        let peeked =
                            self.memo.lock().expect("memo lock").peek_verified(fp);
                        if let Some(entry) = peeked {
                            // memo replay: out-relations propagate with no
                            // job; the assembly pass serves the layer from
                            // the memo (counting the hit there)
                            exact_outs[di] = Some(entry.out_rels.clone());
                            finalized[di] = true;
                            progressed = true;
                        }
                    }
                }
            }

            // ---- schedule one round of jobs ----
            // exact jobs for every dependency-satisfied layer, speculative
            // jobs (once) for the rest so the whole DAG is in flight, not
            // just the frontier
            let mut jobs: Vec<(usize, bool, Rels)> = Vec::new();
            // per job-slot: (layer index, exact?, fingerprint-when-memoizing)
            let mut job_meta: Vec<(usize, bool, Option<u64>)> = Vec::new();
            let mut alias: FxHashMap<u64, Vec<usize>> = FxHashMap::default();
            let mut seen: FxHashMap<u64, usize> = FxHashMap::default();
            for di in 0..n {
                if finalized[di] || pending[di].is_some() {
                    continue;
                }
                let d = &dist_layers[di];
                let Some(&bi) = base_idx_by_tag.get(&d.layer) else { continue };
                let b = &base_layers[bi];
                let ready = deps[di].iter().all(|&j| finalized[j]);
                let rels = if ready {
                    layer::collect_input_rels(b, d, &view_for(di, &exact_outs))
                } else if !spec_submitted[di] {
                    layer::collect_input_rels_speculative(b, d, &view_for(di, &exact_outs))
                } else {
                    // speculation already missed once; wait for exactness
                    continue;
                };
                if !ready {
                    spec_submitted[di] = true;
                }
                // fingerprint dedup: structurally identical layers with
                // identical relations run once and alias the result
                let fp = cfg
                    .memoize
                    .then(|| fingerprint_pair(b, d, &rels, d.graph.num_cores));
                if let Some(fp) = fp {
                    if seen.contains_key(&fp) {
                        alias.entry(fp).or_default().push(di);
                        continue;
                    }
                    seen.insert(fp, di);
                }
                if !ready {
                    obs::metrics::count("scalify_speculative_jobs_total", 1);
                }
                jobs.push((di, ready, rels));
                job_meta.push((di, ready, fp));
            }
            if jobs.is_empty() {
                break;
            }

            let max_rounds = cfg.max_rounds;
            let closures: Vec<_> = jobs
                .into_iter()
                .map(|(di, exact, rels)| {
                    let base = Arc::clone(base_layers);
                    let dist = Arc::clone(dist_layers);
                    let rules = Arc::clone(&self.rules);
                    let bi = base_idx_by_tag[&dist_layers[di].layer];
                    move || {
                        let d = &dist[di];
                        // job spans live on the worker thread that ran
                        // them, so the trace shows the DAG's real packing;
                        // a later promotion shows up on the assembly
                        // pass's `layer` span (`promoted`)
                        let mut jsp =
                            obs::span_fmt("job", format_args!("job layer {}", d.layer));
                        jsp.attr("layer", d.layer as u64);
                        jsp.attr("speculative", u64::from(!exact));
                        let o = layer::verify_layer(
                            &base[bi],
                            d,
                            &rels,
                            d.graph.num_cores,
                            &rules,
                            limits,
                            max_rounds,
                        );
                        jsp.attr("matches_tried", o.matches_tried as u64);
                        jsp.attr("verified", u64::from(o.verified));
                        (di, rels, o)
                    }
                })
                .collect();
            for (slot, result) in pool.run_all(closures).into_iter().enumerate() {
                let (jdi, exact, fp) = job_meta[slot];
                match result {
                    Ok((di, rels, o)) => {
                        if let Some(aliases) = fp.and_then(|fp| alias.get(&fp)) {
                            for &adi in aliases {
                                pending[adi] = Some((rels.clone(), o.clone()));
                            }
                        }
                        pending[di] = Some((rels, o));
                    }
                    Err(_) => {
                        // a panicked job errors only its own slot: no
                        // result is recorded, so the assembly pass
                        // recomputes this layer on the caller thread,
                        // where the panic surfaces in the caller's own
                        // context (as a typed error under the service
                        // scheduler). An exact job that failed must still
                        // finalize its layer — the panic is deterministic
                        // and rescheduling would spin forever; downstream
                        // layers just see no out-relations from it.
                        if exact {
                            finalized[jdi] = true;
                        }
                    }
                }
            }
        }
        out
    }
}

/// `SCALIFY_SEQUENTIAL=1` forces the fully sequential cold path — the
/// differential-testing escape hatch for the parallel DAG scheduler,
/// mirroring `SCALIFY_NAIVE_MATCH` for the indexed e-matcher. Both paths
/// must produce byte-identical verdicts, localization sites and
/// per-layer e-graph counts (asserted by the determinism suite).
fn sequential_override() -> bool {
    std::env::var("SCALIFY_SEQUENTIAL").map(|v| v == "1").unwrap_or(false)
}

/// Whole graph as a single pseudo-layer (partitioning disabled).
fn whole_graph_slice(g: &crate::ir::Graph) -> Vec<LayerSlice> {
    let mut g2 = g.clone();
    for n in g2.nodes.iter_mut() {
        n.meta.layer = None;
    }
    extract_layers(&g2)
}
