//! Single-layer verification: register both subgraphs into one e-graph,
//! saturate, propagate relations to fixpoint, check boundary outputs.

use super::boundary::{summarize, RelSummary};
use crate::egraph::{
    merge_rule_stats, EGraph, ENode, Id, RuleSet, RuleStat, RunLimits, Runner, StopReason,
};
use crate::ir::{NodeId, Op};
use crate::localize::{frontier, Discrepancy};
use crate::partition::LayerSlice;
use crate::relations::{GraphCtx, RelEngine, StepOutcome};
use rustc_hash::FxHashMap;

/// Result of verifying one layer pair.
#[derive(Clone, Debug)]
pub struct LayerOutcome {
    /// All boundary outputs related.
    pub verified: bool,
    /// Relation summary per boundary output pair.
    pub out_rels: Vec<RelSummary>,
    /// Localized divergence frontier (empty when verified).
    pub discrepancies: Vec<Discrepancy>,
    /// E-graph size at the end.
    pub egraph_nodes: usize,
    /// E-graph class count at the end.
    pub egraph_classes: usize,
    /// Facts derived.
    pub facts: usize,
    /// Hit the saturation resource limit.
    pub exhausted: bool,
    /// E-nodes examined by the matcher across all saturation rounds.
    pub matches_tried: usize,
    /// How far past the node budget the run landed (0 unless exhausted).
    pub node_overshoot: usize,
    /// Per-rule match/apply/time counters, summed across rounds.
    pub rule_stats: Vec<RuleStat>,
    /// Stop reason of the last saturation round.
    pub stop: StopReason,
}

/// Resolve each dist-slice input to its baseline partner + relation using
/// the boundary map (annotations + previous layers' outputs). Returns
/// `(base_param_pos, dist_param_pos, rel)` triples.
pub fn collect_input_rels(
    bslice: &LayerSlice,
    dslice: &LayerSlice,
    boundary: &FxHashMap<NodeId, (NodeId, RelSummary)>,
) -> Vec<(usize, usize, RelSummary)> {
    let mut rels = Vec::new();
    for (dpos, dorig) in dslice.ext_inputs.iter().enumerate() {
        if let Some((borig, rel)) = boundary.get(dorig) {
            if let Some(bpos) = bslice.ext_inputs.iter().position(|b| b == borig) {
                rels.push((bpos, dpos, rel.clone()));
            }
        }
    }
    rels
}

/// Speculative variant: unknown boundaries are assumed `Duplicate`
/// positionally (used by the parallel pre-pass; the sequential pass
/// re-checks with exact relations, so speculation can only waste work,
/// never unsoundly verify).
pub fn collect_input_rels_speculative(
    bslice: &LayerSlice,
    dslice: &LayerSlice,
    boundary: &FxHashMap<NodeId, (NodeId, RelSummary)>,
) -> Vec<(usize, usize, RelSummary)> {
    let mut rels = collect_input_rels(bslice, dslice, boundary);
    let known: Vec<usize> = rels.iter().map(|(_, d, _)| *d).collect();
    for (dpos, _) in dslice.ext_inputs.iter().enumerate() {
        if known.contains(&dpos) {
            continue;
        }
        // positional pairing with matching shapes
        if dpos < bslice.ext_inputs.len() {
            rels.push((dpos, dpos, RelSummary::Duplicate));
        }
    }
    rels.sort_by_key(|(_, d, _)| *d);
    rels
}

/// Register a slice's nodes into the e-graph. Parameters are namespaced
/// per side so baseline and distributed inputs never hash-cons together.
fn register_slice(eg: &mut EGraph, slice: &LayerSlice, side: &str, distributed: bool) -> Vec<Id> {
    let g = &slice.graph;
    let mut map = Vec::with_capacity(g.len());
    for n in &g.nodes {
        let op = match &n.op {
            Op::Parameter { index, name } => Op::Parameter {
                index: *index,
                name: format!("{side}::{name}"),
            },
            other => other.clone(),
        };
        let children: Vec<Id> = n.inputs.iter().map(|i| map[i.idx()]).collect();
        let id = eg.add_with_data(ENode::new(op, children), n.shape.clone(), distributed, n.id);
        map.push(id);
    }
    map
}

/// Verify one layer pair using a pre-compiled rewrite-template set.
///
/// This function is the unit of work the parallel cold pass ships to pool
/// threads: it takes only shared-immutable inputs (`&LayerSlice`, the
/// session's `&RuleSet`) and builds everything mutable — the `EGraph`, the
/// relation engine, the match log — locally, arena-style. The whole arena
/// is dropped with the job, so concurrent layer verifications never share
/// or free state across threads; `LayerOutcome` is plain owned data and
/// crosses back over the channel by value.
pub fn verify_layer(
    bslice: &LayerSlice,
    dslice: &LayerSlice,
    input_rels: &[(usize, usize, RelSummary)],
    cores: u32,
    rules: &RuleSet,
    limits: RunLimits,
    max_rounds: usize,
) -> LayerOutcome {
    let mut eg = EGraph::new();
    let b2c = register_slice(&mut eg, bslice, "B", false);
    let d2c = register_slice(&mut eg, dslice, "D", true);
    let base_uses = bslice.graph.uses();

    // the slice inherits the full graph's declared mesh, so subgroup
    // collectives resolve against the same axes everywhere; `cores` is the
    // flat fallback for callers without mesh info
    let mesh = if dslice.graph.mesh.is_empty() {
        crate::ir::Mesh::flat(cores)
    } else {
        dslice.graph.mesh_view()
    };
    let mut rel = RelEngine::with_mesh(mesh);

    // ---- register input relations ----
    let bparams = bslice.graph.parameters();
    let dparams = dslice.graph.parameters();
    for (bpos, dpos, summary) in input_rels {
        let (Some(&bp), Some(&dp)) = (bparams.get(*bpos), dparams.get(*dpos)) else {
            continue;
        };
        let bclass = b2c[bp.idx()];
        let dclass = d2c[dp.idx()];
        let bdims = &bslice.graph.node(bp).shape.dims;
        match summary {
            RelSummary::Duplicate => rel.register_replicated(&eg, bclass, dclass, bdims),
            RelSummary::Sharded { dim, parts, axis } => {
                rel.register_shard(&eg, bclass, dclass, bdims, *dim, *parts, *axis)
            }
            RelSummary::MeshSharded { entries } => {
                rel.register_mesh_shard(&eg, bclass, dclass, bdims, entries)
            }
            RelSummary::Partial { kind, axes } => {
                rel.register_partial(&eg, bclass, dclass, bdims, *kind, *axes)
            }
        }
    }

    // ---- saturate + propagate to fixpoint ----
    // the runner is stateful: per-rule match cursors persist across the
    // relation-fixpoint rounds, so a round only re-matches what the
    // previous relation pass changed
    let mut runner = Runner::new(rules.rules(), limits);
    let mut exhausted = false;
    let mut matches_tried = 0usize;
    let mut node_overshoot = 0usize;
    let mut rule_stats: Vec<RuleStat> = Vec::new();
    let mut last_stop = StopReason::Saturated;
    let mut outcomes: Vec<StepOutcome> = vec![StepOutcome::NotReady; dslice.graph.len()];
    for round in 0..max_rounds {
        // one span per saturate+propagate fixpoint round, tagged with the
        // relation facts it derived
        let mut rsp = crate::obs::span_fmt("round", format_args!("round {round}"));
        rsp.attr("layer", dslice.layer as u64);
        let report = runner.run(&mut eg);
        matches_tried += report.matches_tried;
        node_overshoot = node_overshoot.max(report.node_overshoot);
        merge_rule_stats(&mut rule_stats, &report.rules);
        last_stop = report.stop;
        rsp.attr("matches_tried", report.matches_tried as u64);
        if report.stop == StopReason::NodeLimit {
            exhausted = true;
            break;
        }
        if report.stop == StopReason::DeadlineExceeded {
            // not a resource verdict: the caller sees the stop reason and
            // degrades to a partial (verified-prefix) report
            break;
        }
        rel.rekey(&eg);
        let facts_before = rel.fact_count;

        let ctx = GraphCtx {
            base: &bslice.graph,
            dist: &dslice.graph,
            b2c: &b2c,
            d2c: &d2c,
            base_uses: &base_uses,
            class_index: std::cell::RefCell::new(None),
        };
        rel.propagate_base_layouts(&mut eg, &ctx);
        for n in &dslice.graph.nodes {
            outcomes[n.id.idx()] = rel.process_dist_node(&mut eg, &ctx, n);
        }

        // union duplicate facts so structural matching sees through them
        let mut unions = 0;
        for n in &dslice.graph.nodes {
            for f in rel.facts_for(&eg, d2c[n.id.idx()]) {
                if f.is_duplicate(&rel.store) && !eg.same(f.base, f.dist) {
                    eg.union(f.base, f.dist);
                    unions += 1;
                }
            }
        }
        if unions > 0 {
            eg.rebuild();
            rel.rekey(&eg);
        }

        let new_facts = rel.fact_count.saturating_sub(facts_before);
        rsp.attr("facts", new_facts as u64);
        rsp.attr("unions", unions as u64);
        crate::obs::metrics::count("scalify_relation_facts_total", new_facts as u64);
        if rel.fact_count == facts_before && unions == 0 {
            break;
        }
    }

    // ---- boundary output check ----
    let mut out_rels = Vec::new();
    let mut failed_outputs: Vec<(NodeId, String)> = Vec::new();
    let mut verified = true;
    let n_outs = bslice.graph.outputs.len().max(dslice.graph.outputs.len());
    for k in 0..n_outs {
        let (Some(&bo), Some(&do_)) =
            (bslice.graph.outputs.get(k), dslice.graph.outputs.get(k))
        else {
            verified = false;
            continue;
        };
        let bclass = eg.find(b2c[bo.idx()]);
        let dclass = eg.find(d2c[do_.idx()]);
        let mut summary = None;
        for f in rel.facts_for(&eg, dclass) {
            if eg.find(f.base) != bclass {
                continue;
            }
            if let Some(s) = summarize(&f, &rel.store, &eg) {
                // prefer Duplicate over weaker summaries
                let better = matches!(s, RelSummary::Duplicate) || summary.is_none();
                if better {
                    summary = Some(s);
                }
            }
        }
        if summary.is_none() && bclass == dclass {
            summary = Some(RelSummary::Duplicate);
        }
        // final graph outputs must be exact duplicates: a shard/partial
        // left at the very end is a divergence (e.g. missing all-reduce)
        let is_final = dslice.final_outputs.get(k).copied().unwrap_or(false);
        if is_final && !matches!(summary, Some(RelSummary::Duplicate)) {
            let residual = match &summary {
                Some(RelSummary::Partial { kind, .. }) => format!(
                    "output is still a per-core partial ({kind:?}) — missing collective reduction?"
                ),
                Some(RelSummary::Sharded { dim, .. }) => format!(
                    "output is still sharded along dim {dim} — missing all-gather?"
                ),
                Some(RelSummary::MeshSharded { entries }) => format!(
                    "output is still mesh-sharded ({entries:?}) — missing all-gathers?"
                ),
                _ => "output never related to the baseline output".to_string(),
            };
            failed_outputs.push((do_, residual));
            summary = None;
        } else if summary.is_none() {
            failed_outputs.push((do_, "output never related to the baseline output".into()));
        }
        match summary {
            Some(s) => out_rels.push(s),
            None => {
                verified = false;
                out_rels.push(RelSummary::Duplicate); // placeholder, unused on failure
            }
        }
    }
    if exhausted {
        verified = false;
    }

    // ---- analysis soundness check ----
    // a rule only unions terms it proved equal, and equal terms have
    // equal shapes; a merge that had to drop a disagreeing shape is a
    // typed discrepancy, never a silent first-shape-wins
    let mut shape_conflict_discrepancies: Vec<Discrepancy> = Vec::new();
    for conflict in eg.shape_conflicts() {
        verified = false;
        let reason = format!(
            "merged classes disagree on shape ({} vs {})",
            conflict.kept, conflict.dropped
        );
        match conflict.repr {
            Some((true, node)) if node.idx() < dslice.graph.len() => {
                shape_conflict_discrepancies
                    .push(Discrepancy::from_node(&dslice.graph, node, reason));
            }
            Some((false, node)) if node.idx() < bslice.graph.len() => {
                // baseline-side representative: report it against the
                // baseline node's metadata but keep the dist-node slot 0
                let mut d = Discrepancy::from_node(&bslice.graph, node, reason);
                d.dist_node = NodeId(0);
                shape_conflict_discrepancies.push(d);
            }
            _ => shape_conflict_discrepancies.push(Discrepancy {
                dist_node: NodeId(0),
                site: String::new(),
                func: String::new(),
                expr: format!("e-class {}", conflict.class.0),
                reason,
                layer: Some(dslice.layer),
            }),
        }
    }

    // ---- localization on failure ----
    let discrepancies = if verified {
        vec![]
    } else {
        let related: Vec<bool> = dslice
            .graph
            .nodes
            .iter()
            .map(|n| {
                rel.has_any(&eg, d2c[n.id.idx()])
                    || rel.percore_for(&eg, d2c[n.id.idx()]).first().is_some()
                    || n.inputs.is_empty()
            })
            .collect();
        let mut ds: Vec<Discrepancy> = frontier(&dslice.graph, &related)
            .into_iter()
            .map(|id| {
                let node = dslice.graph.node(id);
                let reason = match outcomes[id.idx()] {
                    StepOutcome::NoRule if node.op.is_collective() => {
                        // the wrong-replica-group family: the operand has a
                        // relation but this collective's groups discharge
                        // nothing it pends
                        "collective replica_groups do not match any pending \
                         relation of the operand (wrong subgroup?)"
                    }
                    StepOutcome::NoRule => {
                        "inputs are verified but no relation rule applies here"
                    }
                    _ => "no relation derived for this operation",
                }
                .to_string();
                Discrepancy::from_node(&dslice.graph, id, reason)
            })
            .collect();
        // failed outputs whose relation never resolved (e.g. a leftover
        // partial at the graph output = missing all-reduce)
        for (orig, reason) in failed_outputs {
            if let Some(&sub_id) = dslice.node_map.get(&orig) {
                if !ds.iter().any(|d| d.dist_node == sub_id) {
                    ds.push(Discrepancy::from_node(&dslice.graph, sub_id, reason));
                }
            }
        }
        ds.extend(shape_conflict_discrepancies);
        ds
    };

    LayerOutcome {
        verified,
        out_rels,
        discrepancies,
        egraph_nodes: eg.node_count(),
        egraph_classes: eg.class_count(),
        facts: rel.fact_count,
        exhausted,
        matches_tried,
        node_overshoot,
        rule_stats,
        stop: last_stop,
    }
}
