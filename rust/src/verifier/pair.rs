//! The unit of verification: a baseline/distributed graph pair plus the
//! registered input relations (§5.2.1).

use crate::ir::{Annotation, Graph};

/// A baseline graph, its distributed counterpart, and the input-tensor
/// annotations recorded by the (instrumented) framework during IR
/// generation.
#[derive(Clone, Debug)]
pub struct GraphPair {
    /// Single-device baseline graph (`num_cores == 1`).
    pub base: Graph,
    /// Distributed SPMD graph (`num_cores == tp degree`).
    pub dist: Graph,
    /// Input relations between the two graphs' parameters.
    pub annotations: Vec<Annotation>,
}

impl GraphPair {
    /// Construct, validating both graphs.
    pub fn new(base: Graph, dist: Graph, annotations: Vec<Annotation>) -> GraphPair {
        debug_assert!(base.validate().is_ok(), "baseline graph invalid");
        debug_assert!(dist.validate().is_ok(), "distributed graph invalid");
        GraphPair { base, dist, annotations }
    }

    /// Construct from untrusted input: structural validation failures are
    /// typed [`crate::error::ScalifyError::ModelSpec`] errors instead of
    /// (debug-only) panics.
    pub fn try_new(
        base: Graph,
        dist: Graph,
        annotations: Vec<Annotation>,
    ) -> crate::error::Result<GraphPair> {
        base.validate().map_err(|e| e.context("baseline graph"))?;
        dist.validate().map_err(|e| e.context("distributed graph"))?;
        Ok(GraphPair { base, dist, annotations })
    }

    /// Pair two parsed graphs positionally with replicated annotations —
    /// the construction every HLO-text path (CLI `verify`, `batch`
    /// manifests, the service's inline pairs) uses, since HLO text
    /// carries no sharding info.
    pub fn replicated(base: Graph, dist: Graph) -> crate::error::Result<GraphPair> {
        let annotations: Vec<Annotation> = base
            .parameters()
            .into_iter()
            .zip(dist.parameters())
            .map(|(b, d)| Annotation::replicated(b, d))
            .collect();
        GraphPair::try_new(base, dist, annotations)
    }

    /// Total node count across both graphs.
    pub fn total_nodes(&self) -> usize {
        self.base.len() + self.dist.len()
    }
}
