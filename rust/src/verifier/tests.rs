//! Verifier integration tests: hand-built graph pairs exercising the full
//! pipeline (Figure 3's matmul example, collectives, bug patterns).

use super::*;
use crate::ir::{Annotation, DType, GraphBuilder, ReduceKind, ReplicaGroups, Shape};

fn f32s(dims: &[i64]) -> Shape {
    Shape::new(DType::F32, dims.to_vec())
}

fn cfg_seq() -> VerifyConfig {
    VerifyConfig { parallel: false, ..VerifyConfig::default() }
}

/// Figure 3: Y = X·W baseline vs contracted-dim-sharded TP + all-reduce.
fn matmul_tp_pair(missing_allreduce: bool) -> GraphPair {
    let mut bb = GraphBuilder::new("base", 1);
    bb.at("mlp.py", 10).in_func("mlp_fwd");
    let x = bb.parameter("x", f32s(&[4, 8]));
    let w = bb.parameter("w", f32s(&[8, 16]));
    let y = bb.matmul(x, w);
    bb.output(y);
    let base = bb.finish();

    let mut db = GraphBuilder::new("dist", 2);
    db.at("mlp.py", 10).in_func("mlp_fwd");
    let xs = db.parameter("x", f32s(&[4, 4]));
    let ws = db.parameter("w", f32s(&[4, 16]));
    db.at("mlp.py", 11);
    let part = db.matmul(xs, ws);
    db.at("mlp.py", 12);
    let out = if missing_allreduce {
        part
    } else {
        db.all_reduce(part, ReduceKind::Add, ReplicaGroups::full(2))
    };
    db.output(out);
    let dist = db.finish();

    let ann = vec![
        Annotation::shard(x, crate::ir::NodeId(0), 1, 2),
        Annotation::shard(w, crate::ir::NodeId(1), 0, 2),
    ];
    GraphPair::new(base, dist, ann)
}

#[test]
fn tp_matmul_verifies() {
    let pair = matmul_tp_pair(false);
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(report.verified(), "{:?}", report.verdict);
}

/// Hand-built subgroup pair on a declared [dp, tp] mesh: x·w contracted
/// over the tp-sharded dim leaves a tp-axis partial. Only the tp-subgroup
/// all-reduce (`{{0,1},{2,3}}`) completes it; dp-axis or full-mesh groups
/// double-count contributions (each dp replica holds the same partials),
/// so those variants are genuine numerical bugs the rules must refuse.
fn mesh_matmul_pair(groups: ReplicaGroups) -> GraphPair {
    let mut bb = GraphBuilder::new("base", 1);
    bb.at("mlp.py", 10).in_func("mlp_fwd");
    let x = bb.parameter("x", f32s(&[4, 8]));
    let w = bb.parameter("w", f32s(&[8, 16]));
    let y = bb.matmul(x, w);
    bb.output(y);
    let base = bb.finish();

    let mut db = GraphBuilder::new("dist", 4);
    db.at("mlp.py", 10).in_func("mlp_fwd");
    let xs = db.parameter("x", f32s(&[4, 4]));
    let ws = db.parameter("w", f32s(&[4, 16]));
    db.at("mlp.py", 11);
    let part = db.matmul(xs, ws);
    db.at("mlp.py", 12);
    let out = db.all_reduce(part, ReduceKind::Add, groups);
    db.output(out);
    let mut dist = db.finish();
    dist.mesh = vec![2, 2]; // [dp, tp]

    // x and w sharded on the tp axis (axis 1): cores in the same tp group
    // hold complementary halves, dp groups replicate
    let ann = vec![
        Annotation::shard_on(x, crate::ir::NodeId(0), 1, 2, 1),
        Annotation::shard_on(w, crate::ir::NodeId(1), 0, 2, 1),
    ];
    GraphPair::new(base, dist, ann)
}

#[test]
fn subgroup_allreduce_discharges_on_matching_axis() {
    let tp_groups = ReplicaGroups(vec![vec![0, 1], vec![2, 3]]);
    let pair = mesh_matmul_pair(tp_groups);
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(report.verified(), "{:?}", report.verdict);
}

#[test]
fn subgroup_allreduce_over_wrong_axis_fails() {
    // dp-axis groups {{0,2},{1,3}} cannot discharge a tp-axis partial:
    // each group sums two copies of the SAME local partial (cores agree on
    // the tp digit), doubling the value instead of completing the sum
    let dp_groups = ReplicaGroups(vec![vec![0, 2], vec![1, 3]]);
    let pair = mesh_matmul_pair(dp_groups);
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(!report.verified(), "wrong-axis subgroup reduce must not verify");
    assert!(
        report
            .discrepancies()
            .iter()
            .any(|d| d.site == "mlp.py:12" || d.site == "mlp.py:11"),
        "localization should land on the collective or its operand: {:?}",
        report.discrepancies()
    );
}

#[test]
fn full_mesh_allreduce_cannot_discharge_subgroup_partial() {
    // the pre-mesh behavior would happily discharge ANY add-partial with a
    // full-mesh all-reduce; on a [2,2] mesh with a tp-axis partial that
    // sums 4 contributions where 2 complete the value — unverifiable
    let full = ReplicaGroups::full(4);
    let pair = mesh_matmul_pair(full);
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(!report.verified(), "full-mesh reduce of a tp partial must not verify");
}

#[test]
fn missing_allreduce_unverified_and_localized() {
    let pair = matmul_tp_pair(true);
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(!report.verified());
    // the partial matmul output is the frontier (its inputs are verified)
    // — localization should not be empty and should carry a source site
    let ds = report.discrepancies();
    assert!(!ds.is_empty());
    assert!(ds.iter().all(|d| d.site.starts_with("mlp.py")), "{ds:?}");
}

#[test]
fn redundant_allreduce_detected() {
    // baseline Y = X + X; distributed adds an all-reduce over replicated
    // data → result is c*(X+X), NOT equivalent
    let mut bb = GraphBuilder::new("base", 1);
    let x = bb.parameter("x", f32s(&[4]));
    let y = bb.add(x, x);
    bb.output(y);
    let base = bb.finish();

    let mut db = GraphBuilder::new("dist", 2);
    db.at("mlp.py", 5).in_func("residual");
    let xd = db.parameter("x", f32s(&[4]));
    let yd = db.add(xd, xd);
    let red = db.all_reduce(yd, ReduceKind::Add, ReplicaGroups::full(2));
    db.output(red);
    let dist = db.finish();

    let ann = vec![Annotation::replicated(x, crate::ir::NodeId(0))];
    let report = Session::new(cfg_seq()).verify(&GraphPair::new(base, dist, ann)).unwrap();
    assert!(!report.verified());
}

#[test]
fn allgather_restores_duplicate() {
    // baseline: Y = tanh(X); distributed: tanh of row-shard then all-gather
    let mut bb = GraphBuilder::new("base", 1);
    let x = bb.parameter("x", f32s(&[8, 4]));
    let y = bb.tanh(x);
    bb.output(y);
    let base = bb.finish();

    let mut db = GraphBuilder::new("dist", 4);
    let xs = db.parameter("x", f32s(&[2, 4]));
    let t = db.tanh(xs);
    let g = db.all_gather(t, 0, ReplicaGroups::full(4));
    db.output(g);
    let dist = db.finish();

    let ann = vec![Annotation::shard(x, crate::ir::NodeId(0), 0, 4)];
    let report = Session::new(cfg_seq()).verify(&GraphPair::new(base, dist, ann)).unwrap();
    assert!(report.verified(), "{:?}", report.verdict);
}

#[test]
fn wrong_gather_dim_unverified() {
    let mut bb = GraphBuilder::new("base", 1);
    let x = bb.parameter("x", f32s(&[8, 4]));
    let y = bb.tanh(x);
    bb.output(y);
    let base = bb.finish();

    let mut db = GraphBuilder::new("dist", 4);
    db.at("gather.py", 3).in_func("collect");
    let xs = db.parameter("x", f32s(&[2, 4]));
    let t = db.tanh(xs);
    // BUG: gather along dim 1 instead of 0 → shape [2,16] ≠ [8,4]
    let g = db.all_gather(t, 1, ReplicaGroups::full(4));
    let r = db.reshape(g, vec![8, 4]);
    db.output(r);
    let dist = db.finish();

    let ann = vec![Annotation::shard(x, crate::ir::NodeId(0), 0, 4)];
    let report = Session::new(cfg_seq()).verify(&GraphPair::new(base, dist, ann)).unwrap();
    assert!(!report.verified());
}

#[test]
fn reduce_scatter_pipeline_verifies() {
    // baseline: Y = X·W ; distributed: partial matmul → reduce-scatter
    // (shards rows of Y) → all-gather restores
    let mut bb = GraphBuilder::new("base", 1);
    let x = bb.parameter("x", f32s(&[8, 8]));
    let w = bb.parameter("w", f32s(&[8, 8]));
    let y = bb.matmul(x, w);
    bb.output(y);
    let base = bb.finish();

    let mut db = GraphBuilder::new("dist", 2);
    let xs = db.parameter("x", f32s(&[8, 4]));
    let ws = db.parameter("w", f32s(&[4, 8]));
    let part = db.matmul(xs, ws);
    let rs = db.reduce_scatter(part, ReduceKind::Add, 0, ReplicaGroups::full(2));
    let ag = db.all_gather(rs, 0, ReplicaGroups::full(2));
    db.output(ag);
    let dist = db.finish();

    let ann = vec![
        Annotation::shard(x, crate::ir::NodeId(0), 1, 2),
        Annotation::shard(w, crate::ir::NodeId(1), 0, 2),
    ];
    let report = Session::new(cfg_seq()).verify(&GraphPair::new(base, dist, ann)).unwrap();
    assert!(report.verified(), "{:?}", report.verdict);
}

#[test]
fn elementwise_on_shards_verifies() {
    // column-parallel linear: W sharded on output dim, no collective needed
    // as long as the consumer keeps working on shards; final all-gather
    let mut bb = GraphBuilder::new("base", 1);
    let x = bb.parameter("x", f32s(&[4, 8]));
    let w = bb.parameter("w", f32s(&[8, 16]));
    let h = bb.matmul(x, w);
    let a = bb.tanh(h);
    bb.output(a);
    let base = bb.finish();

    let mut db = GraphBuilder::new("dist", 4);
    let xd = db.parameter("x", f32s(&[4, 8]));
    let wd = db.parameter("w", f32s(&[8, 4]));
    let h = db.matmul(xd, wd);
    let a = db.tanh(h);
    let g = db.all_gather(a, 1, ReplicaGroups::full(4));
    db.output(g);
    let dist = db.finish();

    let ann = vec![
        Annotation::replicated(x, crate::ir::NodeId(0)),
        Annotation::shard(w, crate::ir::NodeId(1), 1, 4),
    ];
    let report = Session::new(cfg_seq()).verify(&GraphPair::new(base, dist, ann)).unwrap();
    assert!(report.verified(), "{:?}", report.verdict);
}

#[test]
fn bsh_layout_bug_detected() {
    // Figure 1: output (s*b, h) reshaped directly to (b, s, h) instead of
    // reshape (s, b, h) + transpose. Baseline does it right.
    let mut bb = GraphBuilder::new("base", 1);
    bb.in_func("attention_bsh");
    let x = bb.parameter("attn_out", f32s(&[12, 16])); // (s*b=6*2, h)
    let r = bb.reshape(x, vec![6, 2, 16]);
    let t = bb.transpose(r, vec![1, 0, 2]); // (b, s, h)
    bb.output(t);
    let base = bb.finish();

    let mut db = GraphBuilder::new("dist", 2);
    db.at("bsh.py", 42).in_func("attention_bsh");
    let xd = db.parameter("attn_out", f32s(&[12, 16]));
    // BUG: reshape straight to (b, s, h)
    let r = db.reshape(xd, vec![2, 6, 16]);
    db.output(r);
    let dist = db.finish();

    let ann = vec![Annotation::replicated(crate::ir::NodeId(0), crate::ir::NodeId(0))];
    let report = Session::new(cfg_seq()).verify(&GraphPair::new(base, dist, ann)).unwrap();
    assert!(!report.verified(), "BSH bug must not verify");
}

#[test]
fn bsh_correct_version_verifies() {
    let mut bb = GraphBuilder::new("base", 1);
    let x = bb.parameter("attn_out", f32s(&[12, 16]));
    let r = bb.reshape(x, vec![6, 2, 16]);
    let t = bb.transpose(r, vec![1, 0, 2]);
    bb.output(t);
    let base = bb.finish();

    let mut db = GraphBuilder::new("dist", 2);
    let xd = db.parameter("attn_out", f32s(&[12, 16]));
    let r = db.reshape(xd, vec![6, 2, 16]);
    let t = db.transpose(r, vec![1, 0, 2]);
    db.output(t);
    let dist = db.finish();

    let ann = vec![Annotation::replicated(crate::ir::NodeId(0), crate::ir::NodeId(0))];
    let report = Session::new(cfg_seq()).verify(&GraphPair::new(base, dist, ann)).unwrap();
    assert!(report.verified(), "{:?}", report.verdict);
}

#[test]
fn precision_mismatch_detected() {
    // distributed inserts a bf16 round-trip the baseline doesn't have
    let mut bb = GraphBuilder::new("base", 1);
    let x = bb.parameter("x", f32s(&[4]));
    let e = bb.exp(x);
    bb.output(e);
    let base = bb.finish();

    let mut db = GraphBuilder::new("dist", 2);
    db.at("rope.py", 77).in_func("rotary");
    let xd = db.parameter("x", f32s(&[4]));
    let lo = db.convert(xd, DType::BF16);
    let hi = db.convert(lo, DType::F32);
    let e = db.exp(hi);
    db.output(e);
    let dist = db.finish();

    let ann = vec![Annotation::replicated(crate::ir::NodeId(0), crate::ir::NodeId(0))];
    let report = Session::new(cfg_seq()).verify(&GraphPair::new(base, dist, ann)).unwrap();
    assert!(!report.verified(), "precision mismatch must not verify");
    let ds = report.discrepancies();
    assert!(!ds.is_empty());
}

#[test]
fn expert_parallel_unrolled_loop_verifies() {
    // Figure 8 / Mixtral pattern: baseline sums per-expert contributions
    // (slices of the stacked expert weights); distributed computes its
    // local expert and all-reduces.
    let cores = 4u32;
    let e_dim = 4i64; // experts == cores
    let mut bb = GraphBuilder::new("base", 1);
    let x = bb.parameter("x", f32s(&[4, 8]));
    let w = bb.parameter("experts", f32s(&[e_dim, 8, 8])); // stacked experts
    let mut acc = None;
    for e in 0..e_dim {
        let we3 = bb.slice_dim(w, 0, e, e + 1); // [1,8,8]
        let we = bb.reshape(we3, vec![8, 8]);
        let y = bb.matmul(x, we);
        acc = Some(match acc {
            None => y,
            Some(a) => bb.add(a, y),
        });
    }
    bb.output(acc.unwrap());
    let base = bb.finish();

    let mut db = GraphBuilder::new("dist", cores);
    let xd = db.parameter("x", f32s(&[4, 8]));
    let wd = db.parameter("experts", f32s(&[1, 8, 8])); // local expert
    let wl = db.reshape(wd, vec![8, 8]);
    let y = db.matmul(xd, wl);
    let red = db.all_reduce(y, ReduceKind::Add, ReplicaGroups::full(cores));
    db.output(red);
    let dist = db.finish();

    let ann = vec![
        Annotation::replicated(x, crate::ir::NodeId(0)),
        Annotation::shard(w, crate::ir::NodeId(1), 0, cores),
    ];
    let report = Session::new(cfg_seq()).verify(&GraphPair::new(base, dist, ann)).unwrap();
    assert!(report.verified(), "{:?}", report.verdict);
}

#[test]
fn memoization_hits_identical_layers() {
    // two identical TP layers: second should be memoized
    fn pair_with_layers(n: u32) -> GraphPair {
        let mut bb = GraphBuilder::new("base", 1);
        bb.layer(None);
        let x0 = bb.parameter("x", f32s(&[4, 8]));
        let mut cur = x0;
        let mut ws = Vec::new();
        for l in 0..n {
            bb.layer(Some(l));
            let w = bb.parameter(&format!("w{l}"), f32s(&[8, 8]));
            ws.push(w);
            let h = bb.matmul(cur, w);
            cur = bb.tanh(h);
        }
        bb.layer(None);
        bb.output(cur);
        let base = bb.finish();

        let mut db = GraphBuilder::new("dist", 2);
        db.layer(None);
        let xd = db.parameter("x", f32s(&[4, 8]));
        let mut cur = xd;
        let mut wds = Vec::new();
        for l in 0..n {
            db.layer(Some(l));
            let w = db.parameter(&format!("w{l}"), f32s(&[4, 8]));
            wds.push(w);
            let h = db.matmul(cur, w); // x repl · w row-shard: needs x shard!
            let red = db.all_reduce(h, ReduceKind::Add, ReplicaGroups::full(2));
            cur = db.tanh(red);
        }
        db.layer(None);
        db.output(cur);
        let dist = db.finish();

        // x replicated won't match w row-sharded matmul; instead shard x
        // columns to match: redo annotations — x sharded dim1? But x is
        // the residual stream... use megatron style: w col-shard then
        // row-shard needs two matmuls. For this memo test we shard x too.
        let mut ann = vec![Annotation::shard(x0, xd, 1, 2)];
        for (wb, wd) in ws.iter().zip(&wds) {
            ann.push(Annotation::shard(*wb, *wd, 0, 2));
        }
        GraphPair::new(base, dist, ann)
    }
    // NOTE: sharding x along dim1 only works for the first layer; the tanh
    // output is duplicate after all-reduce, so layer 2+ see a duplicate
    // input against a row-sharded weight — no rule fires and the layer
    // fails. That asymmetry is intentional here? No — this test wants
    // verified layers. Rework: make each layer's matmul take the previous
    // duplicate output against a REPLICATED weight (trivial TP), which
    // verifies and memoizes.
    let _ = pair_with_layers;

    fn trivial_pair(n: u32) -> GraphPair {
        let mut bb = GraphBuilder::new("base", 1);
        bb.layer(None);
        let x0 = bb.parameter("x", f32s(&[4, 8]));
        let mut cur = x0;
        let mut ws = Vec::new();
        for l in 0..n {
            bb.layer(Some(l));
            let w = bb.parameter(&format!("w{l}"), f32s(&[8, 8]));
            ws.push(w);
            let h = bb.matmul(cur, w);
            cur = bb.tanh(h);
        }
        bb.layer(None);
        bb.output(cur);
        let base = bb.finish();

        let mut db = GraphBuilder::new("dist", 2);
        db.layer(None);
        let xd = db.parameter("x", f32s(&[4, 8]));
        let mut cur = xd;
        let mut wds = Vec::new();
        for l in 0..n {
            db.layer(Some(l));
            let w = db.parameter(&format!("w{l}"), f32s(&[8, 8]));
            wds.push(w);
            let h = db.matmul(cur, w);
            cur = db.tanh(h);
        }
        db.layer(None);
        db.output(cur);
        let dist = db.finish();

        let mut ann = vec![Annotation::replicated(x0, xd)];
        for (wb, wd) in ws.iter().zip(&wds) {
            ann.push(Annotation::replicated(*wb, *wd));
        }
        GraphPair::new(base, dist, ann)
    }

    let pair = trivial_pair(6);
    let cfg = VerifyConfig { parallel: false, memoize: true, ..VerifyConfig::default() };
    let report = Session::new(cfg).verify(&pair).unwrap();
    assert!(report.verified(), "{:?}", report.verdict);
    let memoized = report.layers.iter().filter(|l| l.memoized).count();
    assert!(memoized >= 5, "expected ≥5 memo hits, got {memoized}");

    // memoization off → no layer memoized
    let cfg = VerifyConfig { parallel: false, memoize: false, ..VerifyConfig::default() };
    let report2 = Session::new(cfg).verify(&pair).unwrap();
    assert!(report2.verified());
    assert_eq!(report2.layers.iter().filter(|l| l.memoized).count(), 0);
}

#[test]
fn memo_write_hook_and_preload_warm_a_fresh_session() {
    use crate::partition::MemoEntry;
    use std::sync::{Arc, Mutex};

    // the first session persists entries through its write hook (the way
    // the service cache does)...
    let pair = matmul_tp_pair(false);
    let mut warm = Session::new(cfg_seq());
    let collected: Arc<Mutex<Vec<(u64, MemoEntry)>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = Arc::clone(&collected);
    warm.set_memo_write_hook(Arc::new(move |fp, entry| {
        sink.lock().expect("hook lock").push((fp, entry.clone()));
    }));
    assert!(warm.verify(&pair).unwrap().verified());
    let entries = collected.lock().expect("hook lock").clone();
    assert!(!entries.is_empty(), "verified layers must reach the hook");

    // ...and a brand-new session preloaded with them answers its first
    // verify entirely from the memo
    let fresh = Session::new(cfg_seq());
    assert_eq!(fresh.preload_memo(entries.clone()), entries.len());
    let report = fresh.verify(&pair).unwrap();
    assert!(report.verified());
    let stats = fresh.stats();
    assert!(stats.memo_hits > 0, "preloaded entries must serve the first verify");
    assert_eq!(stats.memo_misses, 0, "nothing should be recomputed: {stats:?}");
    assert!(report.layers.iter().all(|l| l.memoized));
}

#[test]
fn memo_capacity_evictions_surface_in_stats() {
    // capacity 1: each new distinct layer fingerprint evicts the previous
    let cfg = VerifyConfig {
        parallel: false,
        memo_capacity: 1,
        ..VerifyConfig::default()
    };
    let session = Session::new(cfg);
    assert!(session.verify(&matmul_tp_pair(false)).unwrap().verified());
    // a structurally different pair brings a different fingerprint
    let other = crate::modelgen::demo::matmul_allreduce_pair(2);
    assert!(session.verify(&other).unwrap().verified());
    let stats = session.stats();
    assert!(stats.memo_entries <= 1, "{stats:?}");
    assert!(stats.memo_evictions >= 1, "{stats:?}");
}

#[test]
fn parallel_mode_agrees_with_sequential() {
    let pair = matmul_tp_pair(false);
    let seq = Session::new(cfg_seq()).verify(&pair).unwrap();
    let par = Session::new(VerifyConfig { parallel: true, ..VerifyConfig::default() })
        .verify(&pair)
        .unwrap();
    assert_eq!(seq.verified(), par.verified());
}

#[test]
fn resource_exhaustion_reported() {
    let pair = matmul_tp_pair(false);
    let cfg = VerifyConfig {
        parallel: false,
        limits: crate::egraph::RunLimits {
            max_iters: 50,
            max_nodes: 2,
            ..crate::egraph::RunLimits::default()
        },
        ..VerifyConfig::default()
    };
    let report = Session::new(cfg).verify(&pair).unwrap();
    assert!(matches!(report.verdict, Verdict::ResourceExhausted { .. }));
}

#[test]
fn sequence_parallel_rms_norm_style_verifies() {
    // sequence parallelism: activations sharded along the sequence dim,
    // elementwise chain stays shard-local, all-gather at the end
    let mut bb = GraphBuilder::new("base", 1);
    let x = bb.parameter("x", f32s(&[16, 8]));
    let sq = bb.mul(x, x);
    let act = bb.tanh(sq);
    bb.output(act);
    let base = bb.finish();

    let mut db = GraphBuilder::new("dist", 4);
    let xd = db.parameter("x", f32s(&[4, 8]));
    let sq = db.mul(xd, xd);
    let act = db.tanh(sq);
    let g = db.all_gather(act, 0, ReplicaGroups::full(4));
    db.output(g);
    let dist = db.finish();

    let ann = vec![Annotation::shard(x, crate::ir::NodeId(0), 0, 4)];
    let report = Session::new(cfg_seq()).verify(&GraphPair::new(base, dist, ann)).unwrap();
    assert!(report.verified(), "{:?}", report.verdict);
}
