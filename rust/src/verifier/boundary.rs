//! Boundary relation summaries propagated between layers (Algorithm 1's
//! `PropagateOutputToNextLayer`).

use crate::egraph::EGraph;
use crate::ir::{AxesMask, ReduceKind};
use crate::layout::AtomStore;
use crate::relations::Fact;

/// The relation of a boundary tensor pair, reduced to what the next
/// layer's input registration needs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RelSummary {
    /// Distributed value replicates the baseline value.
    Duplicate,
    /// Distributed value is the per-core shard along `dim`.
    Sharded {
        /// Baseline dimension that is split.
        dim: usize,
        /// Shard count.
        parts: u32,
        /// Mesh axis the shard spans (0 on flat meshes).
        axis: usize,
    },
    /// Distributed value is sharded along several dims at once, each over
    /// its own mesh axis — `(dim, parts, axis)` entries, sorted by dim.
    /// The dp×tp residual stream of a mesh training step crosses layer
    /// boundaries in this form.
    MeshSharded {
        /// `(baseline dim, shard count, mesh axis)` entries.
        entries: Vec<(usize, u32, usize)>,
    },
    /// Distributed value is a per-core partial; `kind`-reducing over each
    /// group of cores varying on the masked `axes` yields the baseline
    /// value.
    Partial {
        /// Pending reduction.
        kind: ReduceKind,
        /// Mesh axes the pending reduction spans (`1` on flat meshes).
        axes: AxesMask,
    },
}

/// Summarize a fact into a boundary relation, if it has one of the three
/// propagatable forms. Non-identity layouts and multi-axis shardings are
/// not propagated (the layer fails its check instead — a soundness-
/// preserving incompleteness, §5.1).
pub fn summarize(fact: &Fact, store: &AtomStore, _eg: &EGraph) -> Option<RelSummary> {
    if fact.is_duplicate(store) {
        return Some(RelSummary::Duplicate);
    }
    // identity-layout partial
    if fact.shard_atoms.is_empty() {
        if let Some(kind) = fact.partial {
            if fact.base_expr.structurally_equal(&fact.dist_expr, store) {
                return Some(RelSummary::Partial { kind, axes: fact.partial_axes.max(1) });
            }
        }
        return None;
    }
    // axis-aligned sharding: every shard atom must lead its own base axis
    // with the remainder matching the dist side, all other axes equal
    if !fact.shard_atoms.is_empty() && fact.partial.is_none() {
        let base_exp = fact.base_expr.expanded(store);
        let dist_exp = fact.dist_expr.expanded(store);
        if base_exp.axes.len() != dist_exp.axes.len() {
            return None;
        }
        let mut entries: Vec<(usize, u32, usize)> = Vec::new();
        for (i, (b, d)) in base_exp.axes.iter().zip(&dist_exp.axes).enumerate() {
            let bf: Vec<_> = b.iter().copied().filter(|&a| store.size(a) != 1).collect();
            let df: Vec<_> = d.iter().copied().filter(|&a| store.size(a) != 1).collect();
            let lead_shard =
                bf.first().copied().filter(|a| fact.shard_atoms.contains(a));
            if let Some(s) = lead_shard {
                if bf[1..] != df[..] {
                    return None;
                }
                entries.push((i, store.size(s) as u32, store.mesh_axis(s) as usize));
            } else if bf != df {
                return None;
            }
        }
        // every shard atom must be accounted for by exactly one axis
        if entries.len() != fact.shard_atoms.len() {
            return None;
        }
        return Some(match entries.as_slice() {
            [(dim, parts, axis)] => {
                RelSummary::Sharded { dim: *dim, parts: *parts, axis: *axis }
            }
            _ => RelSummary::MeshSharded { entries },
        });
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::Id;
    use crate::layout::AxisExpr;

    #[test]
    fn summarize_duplicate() {
        let mut store = AtomStore::new();
        let e = AxisExpr::from_shape(&mut store, &[4, 8]);
        let f = Fact::duplicate(Id(0), Id(1), e);
        let eg = EGraph::new();
        assert_eq!(summarize(&f, &store, &eg), Some(RelSummary::Duplicate));
    }

    #[test]
    fn summarize_sharded() {
        let mut store = AtomStore::new();
        let base = AxisExpr::from_shape(&mut store, &[8, 16]);
        let atom1 = base.axes[1][0];
        let kids = store.split_leaf(atom1, &[4, 4]).unwrap();
        let dist = AxisExpr::from_axes(vec![base.axes[0].clone(), vec![kids[1]]]);
        let f = Fact {
            base: Id(0),
            dist: Id(1),
            base_expr: base,
            dist_expr: dist,
            shard_atoms: vec![kids[0]],
            partial: None,
            partial_axes: 0,
        };
        let eg = EGraph::new();
        assert_eq!(
            summarize(&f, &store, &eg),
            Some(RelSummary::Sharded { dim: 1, parts: 4, axis: 0 })
        );
    }

    #[test]
    fn summarize_sharded_carries_mesh_axis() {
        let mut store = AtomStore::new();
        let base = AxisExpr::from_shape(&mut store, &[8, 16]);
        let atom0 = base.axes[0][0];
        let kids = store.split_leaf(atom0, &[2, 4]).unwrap();
        assert!(store.set_mesh_axis(kids[0], 1));
        let dist = AxisExpr::from_axes(vec![vec![kids[1]], base.axes[1].clone()]);
        let f = Fact {
            base: Id(0),
            dist: Id(1),
            base_expr: base,
            dist_expr: dist,
            shard_atoms: vec![kids[0]],
            partial: None,
            partial_axes: 0,
        };
        let eg = EGraph::new();
        assert_eq!(
            summarize(&f, &store, &eg),
            Some(RelSummary::Sharded { dim: 0, parts: 2, axis: 1 })
        );
    }

    #[test]
    fn summarize_partial() {
        let mut store = AtomStore::new();
        let e = AxisExpr::from_shape(&mut store, &[4]);
        let f = Fact {
            base: Id(0),
            dist: Id(1),
            base_expr: e.clone(),
            dist_expr: e,
            shard_atoms: vec![],
            partial: Some(ReduceKind::Add),
            partial_axes: 0b10,
        };
        let eg = EGraph::new();
        assert_eq!(
            summarize(&f, &store, &eg),
            Some(RelSummary::Partial { kind: ReduceKind::Add, axes: 0b10 })
        );
    }

    #[test]
    fn transposed_layout_not_summarizable() {
        let mut store = AtomStore::new();
        let base = AxisExpr::from_shape(&mut store, &[4, 8]);
        let dist = base.transpose(&[1, 0]).unwrap();
        let f = Fact {
            base: Id(0),
            dist: Id(1),
            base_expr: base,
            dist_expr: dist,
            shard_atoms: vec![],
            partial: None,
            partial_axes: 0,
        };
        let eg = EGraph::new();
        assert_eq!(summarize(&f, &store, &eg), None);
    }
}
