//! Boundary relation summaries propagated between layers (Algorithm 1's
//! `PropagateOutputToNextLayer`).

use crate::egraph::EGraph;
use crate::ir::ReduceKind;
use crate::layout::AtomStore;
use crate::relations::Fact;

/// The relation of a boundary tensor pair, reduced to what the next
/// layer's input registration needs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RelSummary {
    /// Distributed value replicates the baseline value.
    Duplicate,
    /// Distributed value is the per-core shard along `dim`.
    Sharded {
        /// Baseline dimension that is split.
        dim: usize,
        /// Shard count.
        parts: u32,
    },
    /// Distributed value is a per-core partial; cross-core `kind`-reduction
    /// yields the baseline value.
    Partial {
        /// Pending reduction.
        kind: ReduceKind,
    },
}

/// Summarize a fact into a boundary relation, if it has one of the three
/// propagatable forms. Non-identity layouts and multi-axis shardings are
/// not propagated (the layer fails its check instead — a soundness-
/// preserving incompleteness, §5.1).
pub fn summarize(fact: &Fact, store: &AtomStore, _eg: &EGraph) -> Option<RelSummary> {
    if fact.is_duplicate(store) {
        return Some(RelSummary::Duplicate);
    }
    // identity-layout partial
    if fact.shard_atoms.is_empty() {
        if let Some(kind) = fact.partial {
            if fact.base_expr.structurally_equal(&fact.dist_expr, store) {
                return Some(RelSummary::Partial { kind });
            }
        }
        return None;
    }
    // single-shard, axis-aligned
    if fact.shard_atoms.len() == 1 && fact.partial.is_none() {
        let s = fact.shard_atoms[0];
        let base_exp = fact.base_expr.expanded(store);
        // shard axis = base axis whose leading factor is s; all other axes
        // must match the dist side exactly
        let dist_exp = fact.dist_expr.expanded(store);
        if base_exp.axes.len() != dist_exp.axes.len() {
            return None;
        }
        let mut dim = None;
        for (i, (b, d)) in base_exp.axes.iter().zip(&dist_exp.axes).enumerate() {
            let bf: Vec<_> = b.iter().copied().filter(|&a| store.size(a) != 1).collect();
            let df: Vec<_> = d.iter().copied().filter(|&a| store.size(a) != 1).collect();
            if bf.first() == Some(&s) && bf[1..] == df[..] {
                if dim.is_some() {
                    return None;
                }
                dim = Some(i);
            } else if bf != df {
                return None;
            }
        }
        return dim.map(|d| RelSummary::Sharded { dim: d, parts: store.size(s) as u32 });
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::Id;
    use crate::layout::AxisExpr;

    #[test]
    fn summarize_duplicate() {
        let mut store = AtomStore::new();
        let e = AxisExpr::from_shape(&mut store, &[4, 8]);
        let f = Fact::duplicate(Id(0), Id(1), e);
        let eg = EGraph::new();
        assert_eq!(summarize(&f, &store, &eg), Some(RelSummary::Duplicate));
    }

    #[test]
    fn summarize_sharded() {
        let mut store = AtomStore::new();
        let base = AxisExpr::from_shape(&mut store, &[8, 16]);
        let atom1 = base.axes[1][0];
        let kids = store.split_leaf(atom1, &[4, 4]).unwrap();
        let dist = AxisExpr::from_axes(vec![base.axes[0].clone(), vec![kids[1]]]);
        let f = Fact {
            base: Id(0),
            dist: Id(1),
            base_expr: base,
            dist_expr: dist,
            shard_atoms: vec![kids[0]],
            partial: None,
        };
        let eg = EGraph::new();
        assert_eq!(
            summarize(&f, &store, &eg),
            Some(RelSummary::Sharded { dim: 1, parts: 4 })
        );
    }

    #[test]
    fn summarize_partial() {
        let mut store = AtomStore::new();
        let e = AxisExpr::from_shape(&mut store, &[4]);
        let f = Fact {
            base: Id(0),
            dist: Id(1),
            base_expr: e.clone(),
            dist_expr: e,
            shard_atoms: vec![],
            partial: Some(ReduceKind::Add),
        };
        let eg = EGraph::new();
        assert_eq!(
            summarize(&f, &store, &eg),
            Some(RelSummary::Partial { kind: ReduceKind::Add })
        );
    }

    #[test]
    fn transposed_layout_not_summarizable() {
        let mut store = AtomStore::new();
        let base = AxisExpr::from_shape(&mut store, &[4, 8]);
        let dist = base.transpose(&[1, 0]).unwrap();
        let f = Fact {
            base: Id(0),
            dist: Id(1),
            base_expr: base,
            dist_expr: dist,
            shard_atoms: vec![],
            partial: None,
        };
        let eg = EGraph::new();
        assert_eq!(summarize(&f, &store, &eg), None);
    }
}
