//! The Scalify verifier: Algorithm 1 end to end.
//!
//! ```text
//! (L_s, L_m) ← PartitionGraphsToLayers(G_s, G_m)
//! for each layer pair:
//!     register + saturate + propagate relations   (bounded e-graph)
//!     check boundary outputs, memoize by fingerprint
//!     propagate output relations to the next layer
//! on failure: localize the discrepancy frontier   (§5.3)
//! ```
//!
//! The public entrypoint is [`Session`]: a persistent engine that keeps
//! the compiled rewrite templates, the cross-run layer memo and a worker
//! pool alive across `verify` calls. The one-shot [`Verifier`] remains as
//! a deprecated shim for one release.

pub mod boundary;
pub mod layer;
mod pair;
mod session;

use crate::egraph::RunLimits;
use crate::error::{Result, ScalifyError};
use crate::localize::Discrepancy;
use crate::util::{fmt_duration, Stopwatch};
pub use pair::GraphPair;
pub use session::{LayerProgress, MemoWriteHook, Session, SessionStats, VerifyControl};

/// Verifier configuration (the Figure-12 ablation toggles live here).
///
/// Construct via [`VerifyConfig::builder`] for validated configs, or use
/// the struct literal / [`Default`] for trusted in-process callers.
#[derive(Clone, Debug)]
pub struct VerifyConfig {
    /// Partition along layer boundaries (off = whole-graph e-graph; expect
    /// resource exhaustion on real models, as the paper reports).
    pub partition: bool,
    /// Verify independent layer pairs on worker threads.
    pub parallel: bool,
    /// Memoize layer results by structural fingerprint.
    pub memoize: bool,
    /// Maximum entries the layer memo holds before LRU eviction — bounds
    /// the memory of a long-lived daemon session. Defaults to
    /// [`crate::partition::fingerprint::DEFAULT_MEMO_CAPACITY`].
    pub memo_capacity: usize,
    /// Worker threads for parallel rewriting.
    pub threads: usize,
    /// E-graph saturation budgets per layer.
    pub limits: RunLimits,
    /// Relation-propagation fixpoint rounds per layer.
    pub max_rounds: usize,
}

impl Default for VerifyConfig {
    fn default() -> Self {
        VerifyConfig {
            partition: true,
            parallel: true,
            memoize: true,
            memo_capacity: crate::partition::fingerprint::DEFAULT_MEMO_CAPACITY,
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            limits: RunLimits::default(),
            max_rounds: 8,
        }
    }
}

impl VerifyConfig {
    /// Start a validated configuration builder.
    pub fn builder() -> VerifyConfigBuilder {
        VerifyConfigBuilder { cfg: VerifyConfig::default() }
    }
}

/// Builder for [`VerifyConfig`]; `build` validates the combination and
/// returns a typed [`ScalifyError::Config`] on nonsense inputs.
#[derive(Clone, Debug)]
pub struct VerifyConfigBuilder {
    cfg: VerifyConfig,
}

impl VerifyConfigBuilder {
    /// Partition along layer boundaries.
    pub fn partition(mut self, on: bool) -> Self {
        self.cfg.partition = on;
        self
    }

    /// Verify independent layer pairs on worker threads.
    pub fn parallel(mut self, on: bool) -> Self {
        self.cfg.parallel = on;
        self
    }

    /// Memoize layer results by structural fingerprint.
    pub fn memoize(mut self, on: bool) -> Self {
        self.cfg.memoize = on;
        self
    }

    /// Layer-memo capacity before LRU eviction (must be >= 1).
    pub fn memo_capacity(mut self, capacity: usize) -> Self {
        self.cfg.memo_capacity = capacity;
        self
    }

    /// Worker-thread count (must be 1..=1024).
    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = threads;
        self
    }

    /// E-graph saturation budgets per layer.
    pub fn limits(mut self, limits: RunLimits) -> Self {
        self.cfg.limits = limits;
        self
    }

    /// Maximum rewrite iterations per saturation run.
    pub fn max_iters(mut self, iters: usize) -> Self {
        self.cfg.limits.max_iters = iters;
        self
    }

    /// E-node budget per layer e-graph.
    pub fn max_nodes(mut self, nodes: usize) -> Self {
        self.cfg.limits.max_nodes = nodes;
        self
    }

    /// Relation-propagation fixpoint rounds per layer.
    pub fn max_rounds(mut self, rounds: usize) -> Self {
        self.cfg.max_rounds = rounds;
        self
    }

    /// Validate and produce the configuration.
    pub fn build(self) -> Result<VerifyConfig> {
        let c = &self.cfg;
        if c.threads == 0 {
            return Err(ScalifyError::config("threads must be >= 1"));
        }
        if c.threads > 1024 {
            return Err(ScalifyError::config(format!(
                "threads = {} is not a sane worker count (max 1024)",
                c.threads
            )));
        }
        if c.limits.max_iters == 0 {
            return Err(ScalifyError::config("limits.max_iters must be >= 1"));
        }
        if c.limits.max_nodes == 0 {
            return Err(ScalifyError::config("limits.max_nodes must be >= 1"));
        }
        if c.max_rounds == 0 {
            return Err(ScalifyError::config("max_rounds must be >= 1"));
        }
        if c.memo_capacity == 0 {
            return Err(ScalifyError::config(
                "memo_capacity must be >= 1 (use memoize(false) to disable memoization)",
            ));
        }
        if c.parallel && !c.partition {
            return Err(ScalifyError::config(
                "parallel layer verification requires partitioning (there is only one \
                 whole-graph task without it)",
            ));
        }
        Ok(self.cfg)
    }
}

/// Verification verdict.
#[derive(Clone, Debug)]
pub enum Verdict {
    /// Semantically equivalent: every boundary and final output proved.
    Verified,
    /// Divergence found; discrepancies are the localized frontier.
    Unverified {
        /// Localized divergence sites.
        discrepancies: Vec<Discrepancy>,
    },
    /// Rewriting blew the resource budget (the unpartitioned-full-model
    /// outcome in Figure 12).
    ResourceExhausted {
        /// Which layer (or whole graph) hit the limit.
        at: String,
    },
}

/// Per-layer statistics.
#[derive(Clone, Debug)]
pub struct LayerReport {
    /// Layer tag.
    pub layer: u32,
    /// Pipeline stage owning the layer (None outside pipeline
    /// parallelism).
    pub stage: Option<u32>,
    /// Verified?
    pub verified: bool,
    /// Served from the memo table?
    pub memoized: bool,
    /// Replayed from a previous run's persisted [`crate::diff::VerifyState`]
    /// (`verify --against`): the fingerprint still matched, no e-graph ran.
    pub reused: bool,
    /// Re-verified because the diff touched this layer (only set on
    /// `verify --against` runs; cold verifications leave both flags off).
    pub reverified: bool,
    /// Stable-node-id multiset delta against the previous run's state
    /// for this layer (0 for reused layers and cold runs).
    pub delta_nodes: usize,
    /// E-graph nodes at the end of saturation.
    pub egraph_nodes: usize,
    /// E-graph classes at the end of saturation (0 when the layer was
    /// served from a pre-widening memo entry).
    pub egraph_classes: usize,
    /// Facts derived.
    pub facts: usize,
    /// E-nodes examined by the e-matcher (0 for memo-served layers — the
    /// work was done by the original verification).
    pub matches_tried: usize,
    /// Per-rule match/apply/time counters (empty for memo-served layers).
    pub rules: Vec<crate::egraph::RuleStat>,
    /// Wall time.
    pub duration: std::time::Duration,
}

/// Full verification report.
#[derive(Clone, Debug)]
pub struct VerifyReport {
    /// Verdict.
    pub verdict: Verdict,
    /// Per-layer details.
    pub layers: Vec<LayerReport>,
    /// Phase timings.
    pub stopwatch: Stopwatch,
    /// Total wall time.
    pub total: std::time::Duration,
    /// The deadline expired mid-run: `layers` holds only the verified
    /// prefix, and the verdict covers that prefix — nothing is claimed
    /// about the layers after [`VerifyReport::first_unverified`].
    pub degraded: bool,
    /// First layer the run did not get to (set iff `degraded`).
    pub first_unverified: Option<String>,
}

impl VerifyReport {
    /// True when the verdict is [`Verdict::Verified`].
    pub fn verified(&self) -> bool {
        matches!(self.verdict, Verdict::Verified)
    }

    /// Discrepancies (empty when verified).
    pub fn discrepancies(&self) -> &[Discrepancy] {
        match &self.verdict {
            Verdict::Unverified { discrepancies } => discrepancies,
            _ => &[],
        }
    }

    /// Human-readable summary.
    pub fn summary(&self) -> String {
        let memoized = self.layers.iter().filter(|l| l.memoized).count();
        let reused = self.layers.iter().filter(|l| l.reused).count();
        let status = match &self.verdict {
            Verdict::Verified => "VERIFIED".to_string(),
            Verdict::Unverified { discrepancies } => {
                format!("UNVERIFIED ({} discrepancies)", discrepancies.len())
            }
            Verdict::ResourceExhausted { at } => format!("RESOURCE-EXHAUSTED at {at}"),
        };
        let reuse = if reused > 0 {
            format!(", {reused} reused from state")
        } else {
            String::new()
        };
        let degraded = if self.degraded {
            match &self.first_unverified {
                Some(at) => format!(" [DEGRADED: deadline hit before {at}]"),
                None => " [DEGRADED: deadline hit]".to_string(),
            }
        } else {
            String::new()
        };
        format!(
            "{status}{degraded} — {} layers ({} memoized{reuse}) in {}",
            self.layers.len(),
            memoized,
            fmt_duration(self.total)
        )
    }
}

/// One-shot verifier over an owned [`Session`].
#[deprecated(
    since = "0.2.0",
    note = "use `Session`, which reuses compiled rewrite templates, the layer memo and the \
            worker pool across `verify` calls and reports typed errors instead of panicking"
)]
pub struct Verifier {
    session: Session,
}

#[allow(deprecated)]
impl Verifier {
    /// New verifier with `cfg`.
    pub fn new(cfg: VerifyConfig) -> Verifier {
        Verifier { session: Session::new(cfg) }
    }

    /// Verify a baseline/distributed graph pair.
    ///
    /// # Panics
    /// Panics on malformed pairs (the historical behavior);
    /// [`Session::verify`] returns a typed error instead.
    pub fn verify_pair(&self, pair: &GraphPair) -> VerifyReport {
        match self.session.verify(pair) {
            Ok(report) => report,
            Err(e) => panic!("verify_pair on malformed input: {e}"),
        }
    }
}

#[cfg(test)]
mod tests;
