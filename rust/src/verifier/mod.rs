//! The Scalify verifier: Algorithm 1 end to end.
//!
//! ```text
//! (L_s, L_m) ← PartitionGraphsToLayers(G_s, G_m)
//! for each layer pair:
//!     register + saturate + propagate relations   (bounded e-graph)
//!     check boundary outputs, memoize by fingerprint
//!     propagate output relations to the next layer
//! on failure: localize the discrepancy frontier   (§5.3)
//! ```

pub mod boundary;
pub mod layer;
mod pair;

use crate::egraph::RunLimits;
use crate::localize::Discrepancy;
use crate::partition::{extract_layers, fingerprint_pair, LayerMemo};
use crate::partition::{LayerSlice};
use crate::util::{fmt_duration, Stopwatch};
use boundary::RelSummary;
pub use pair::GraphPair;
use rustc_hash::FxHashMap;
use std::time::Instant;

/// Verifier configuration (the Figure-12 ablation toggles live here).
#[derive(Clone, Debug)]
pub struct VerifyConfig {
    /// Partition along layer boundaries (off = whole-graph e-graph; expect
    /// resource exhaustion on real models, as the paper reports).
    pub partition: bool,
    /// Verify independent layer pairs on worker threads.
    pub parallel: bool,
    /// Memoize layer results by structural fingerprint.
    pub memoize: bool,
    /// Worker threads for parallel rewriting.
    pub threads: usize,
    /// E-graph saturation budgets per layer.
    pub limits: RunLimits,
    /// Relation-propagation fixpoint rounds per layer.
    pub max_rounds: usize,
}

impl Default for VerifyConfig {
    fn default() -> Self {
        VerifyConfig {
            partition: true,
            parallel: true,
            memoize: true,
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            limits: RunLimits::default(),
            max_rounds: 8,
        }
    }
}

/// Verification verdict.
#[derive(Clone, Debug)]
pub enum Verdict {
    /// Semantically equivalent: every boundary and final output proved.
    Verified,
    /// Divergence found; discrepancies are the localized frontier.
    Unverified {
        /// Localized divergence sites.
        discrepancies: Vec<Discrepancy>,
    },
    /// Rewriting blew the resource budget (the unpartitioned-full-model
    /// outcome in Figure 12).
    ResourceExhausted {
        /// Which layer (or whole graph) hit the limit.
        at: String,
    },
}

/// Per-layer statistics.
#[derive(Clone, Debug)]
pub struct LayerReport {
    /// Layer tag.
    pub layer: u32,
    /// Verified?
    pub verified: bool,
    /// Served from the memo table?
    pub memoized: bool,
    /// E-graph nodes at the end of saturation.
    pub egraph_nodes: usize,
    /// Facts derived.
    pub facts: usize,
    /// Wall time.
    pub duration: std::time::Duration,
}

/// Full verification report.
#[derive(Clone, Debug)]
pub struct VerifyReport {
    /// Verdict.
    pub verdict: Verdict,
    /// Per-layer details.
    pub layers: Vec<LayerReport>,
    /// Phase timings.
    pub stopwatch: Stopwatch,
    /// Total wall time.
    pub total: std::time::Duration,
}

impl VerifyReport {
    /// True when the verdict is [`Verdict::Verified`].
    pub fn verified(&self) -> bool {
        matches!(self.verdict, Verdict::Verified)
    }

    /// Discrepancies (empty when verified).
    pub fn discrepancies(&self) -> &[Discrepancy] {
        match &self.verdict {
            Verdict::Unverified { discrepancies } => discrepancies,
            _ => &[],
        }
    }

    /// Human-readable summary.
    pub fn summary(&self) -> String {
        let memoized = self.layers.iter().filter(|l| l.memoized).count();
        let status = match &self.verdict {
            Verdict::Verified => "VERIFIED".to_string(),
            Verdict::Unverified { discrepancies } => {
                format!("UNVERIFIED ({} discrepancies)", discrepancies.len())
            }
            Verdict::ResourceExhausted { at } => format!("RESOURCE-EXHAUSTED at {at}"),
        };
        format!(
            "{status} — {} layers ({} memoized) in {}",
            self.layers.len(),
            memoized,
            fmt_duration(self.total)
        )
    }
}

/// The verifier.
pub struct Verifier {
    cfg: VerifyConfig,
}

impl Verifier {
    /// New verifier with `cfg`.
    pub fn new(cfg: VerifyConfig) -> Verifier {
        Verifier { cfg }
    }

    /// Verify a baseline/distributed graph pair.
    pub fn verify_pair(&self, pair: &GraphPair) -> VerifyReport {
        let start = Instant::now();
        let mut sw = Stopwatch::new();

        // ---- partitioning ----
        let (base_layers, dist_layers) = sw.time("partition", || {
            if self.cfg.partition {
                (extract_layers(&pair.base), extract_layers(&pair.dist))
            } else {
                (whole_graph_slice(&pair.base), whole_graph_slice(&pair.dist))
            }
        });

        // annotation map: dist param orig id -> (base orig id, summary)
        let mut boundary: FxHashMap<crate::ir::NodeId, (crate::ir::NodeId, RelSummary)> =
            FxHashMap::default();
        for a in &pair.annotations {
            let rel = match &a.relation {
                crate::ir::InputRelation::ShardAlong { dim, parts } => {
                    RelSummary::Sharded { dim: *dim, parts: *parts }
                }
                crate::ir::InputRelation::Replicated => RelSummary::Duplicate,
                crate::ir::InputRelation::DeviceIds => continue,
            };
            if let Some(b) = a.baseline {
                boundary.insert(a.distributed, (b, rel));
            }
        }

        // pair layers by tag, in dist order
        let base_by_tag: FxHashMap<u32, &LayerSlice> =
            base_layers.iter().map(|l| (l.layer, l)).collect();

        let mut reports = Vec::new();
        let mut all_discrepancies: Vec<Discrepancy> = Vec::new();
        let mut memo = LayerMemo::new();
        let mut exhausted: Option<String> = None;

        // ---- optional speculative parallel pass ----
        // Boundary relations between transformer layers are almost always
        // the same as the layer's own input relation (the residual stream
        // keeps its placement). Speculatively verify all layer pairs in
        // parallel assuming `Duplicate` for unknown boundaries; the
        // sequential pass reuses a speculation hit whenever the exact
        // boundary relations match what was speculated.
        let mut speculated: FxHashMap<u32, (Vec<(usize, usize, RelSummary)>, layer::LayerOutcome)> =
            FxHashMap::default();
        if self.cfg.parallel && self.cfg.partition && dist_layers.len() > 1 {
            sw.time("parallel-rewrite", || {
                speculated = self.speculative_pass(&dist_layers, &base_by_tag, &boundary);
            });
        }

        // ---- sequential pass with exact boundary propagation ----
        sw.time("verify-layers", || {
            for dslice in &dist_layers {
                let Some(bslice) = base_by_tag.get(&dslice.layer) else {
                    all_discrepancies.push(Discrepancy {
                        dist_node: crate::ir::NodeId(0),
                        site: String::new(),
                        func: String::new(),
                        expr: format!("layer {}", dslice.layer),
                        reason: "layer missing from baseline graph".into(),
                        layer: Some(dslice.layer),
                    });
                    continue;
                };
                let t0 = Instant::now();
                let input_rels = layer::collect_input_rels(bslice, dslice, &boundary);
                let fp = fingerprint_pair(bslice, dslice, &input_rels, pair.dist.num_cores);
                let spec_hit = speculated
                    .get(&dslice.layer)
                    .filter(|(rels, o)| rels == &input_rels && o.verified)
                    .map(|(_, o)| o.clone());
                let (outcome, memoized) = match (spec_hit, self.cfg.memoize, memo.get(fp)) {
                    (Some(o), _, _) => (o, true),
                    (None, true, Some(entry)) if entry.verified => (
                        layer::LayerOutcome {
                            verified: true,
                            out_rels: entry.out_rels.clone(),
                            discrepancies: vec![],
                            egraph_nodes: entry.egraph_nodes,
                            facts: 0,
                            exhausted: false,
                        },
                        true,
                    ),
                    _ => {
                        let o = layer::verify_layer(
                            bslice,
                            dslice,
                            &input_rels,
                            pair.dist.num_cores,
                            self.cfg.limits,
                            self.cfg.max_rounds,
                        );
                        if self.cfg.memoize && o.verified {
                            memo.put(
                                fp,
                                crate::partition::fingerprint::MemoEntry {
                                    verified: o.verified,
                                    out_rels: o.out_rels.clone(),
                                    egraph_nodes: o.egraph_nodes,
                                },
                            );
                        }
                        (o, false)
                    }
                };
                if outcome.exhausted {
                    exhausted = Some(format!("layer {}", dslice.layer));
                }
                // propagate boundary output relations
                for (k, rel) in outcome.out_rels.iter().enumerate() {
                    if let (Some(&b), Some(&d)) =
                        (bslice.boundary_outputs.get(k), dslice.boundary_outputs.get(k))
                    {
                        boundary.insert(d, (b, rel.clone()));
                    }
                }
                all_discrepancies.extend(outcome.discrepancies.iter().cloned());
                reports.push(LayerReport {
                    layer: dslice.layer,
                    verified: outcome.verified,
                    memoized,
                    egraph_nodes: outcome.egraph_nodes,
                    facts: outcome.facts,
                    duration: t0.elapsed(),
                });
            }
        });

        let verdict = if let Some(at) = exhausted {
            Verdict::ResourceExhausted { at }
        } else if reports.iter().all(|r| r.verified) && all_discrepancies.is_empty() {
            Verdict::Verified
        } else {
            Verdict::Unverified { discrepancies: all_discrepancies }
        };
        VerifyReport { verdict, layers: reports, stopwatch: sw, total: start.elapsed() }
    }

    /// Speculative parallel layer verification. When memoization is on,
    /// distinct layer structures are verified once (fingerprint dedup);
    /// when off, every layer pair is verified, but in parallel.
    fn speculative_pass(
        &self,
        dist_layers: &[LayerSlice],
        base_by_tag: &FxHashMap<u32, &LayerSlice>,
        boundary: &FxHashMap<crate::ir::NodeId, (crate::ir::NodeId, RelSummary)>,
    ) -> FxHashMap<u32, (Vec<(usize, usize, RelSummary)>, layer::LayerOutcome)> {
        let cfg = &self.cfg;
        let mut jobs: Vec<(u32, &LayerSlice, &LayerSlice, Vec<(usize, usize, RelSummary)>)> =
            Vec::new();
        let mut seen = rustc_hash::FxHashMap::default(); // fp -> first tag
        let mut alias: Vec<(u32, u64)> = Vec::new();
        for d in dist_layers {
            let Some(b) = base_by_tag.get(&d.layer) else { continue };
            let rels = layer::collect_input_rels_speculative(b, d, boundary);
            if cfg.memoize {
                let fp = fingerprint_pair(b, d, &rels, d.graph.num_cores);
                if let Some(&_first) = seen.get(&fp) {
                    alias.push((d.layer, fp));
                    continue;
                }
                seen.insert(fp, d.layer);
                alias.push((d.layer, fp));
            }
            jobs.push((d.layer, b, d, rels));
        }
        let cores = jobs.first().map(|(_, _, d, _)| d.graph.num_cores).unwrap_or(1);
        let results: Vec<(u32, Vec<(usize, usize, RelSummary)>, layer::LayerOutcome)> =
            if cfg.threads <= 1 || jobs.len() <= 1 {
                jobs.into_iter()
                    .map(|(tag, b, d, rels)| {
                        let o = layer::verify_layer(b, d, &rels, cores, cfg.limits, cfg.max_rounds);
                        (tag, rels, o)
                    })
                    .collect()
            } else {
                let chunk =
                    crate::util::ceil_div(jobs.len() as i64, cfg.threads as i64).max(1) as usize;
                let mut out = Vec::new();
                std::thread::scope(|scope| {
                    let mut handles = Vec::new();
                    for batch in jobs.chunks(chunk) {
                        let batch: Vec<_> = batch.to_vec();
                        handles.push(scope.spawn(move || {
                            batch
                                .into_iter()
                                .map(|(tag, b, d, rels)| {
                                    let o = layer::verify_layer(
                                        b,
                                        d,
                                        &rels,
                                        cores,
                                        cfg.limits,
                                        cfg.max_rounds,
                                    );
                                    (tag, rels, o)
                                })
                                .collect::<Vec<_>>()
                        }));
                    }
                    for h in handles {
                        out.extend(h.join().expect("worker panicked"));
                    }
                });
                out
            };
        let mut by_tag: FxHashMap<u32, (Vec<(usize, usize, RelSummary)>, layer::LayerOutcome)> =
            results.into_iter().map(|(t, r, o)| (t, (r, o))).collect();
        // fingerprint aliases: replay the representative result on every
        // identical layer (memoization across the speculative pool)
        if cfg.memoize {
            let mut fp_result: FxHashMap<u64, (Vec<(usize, usize, RelSummary)>, layer::LayerOutcome)> =
                FxHashMap::default();
            for (tag, fp) in &alias {
                if let Some(v) = by_tag.get(tag) {
                    fp_result.insert(*fp, v.clone());
                }
            }
            for (tag, fp) in &alias {
                if !by_tag.contains_key(tag) {
                    if let Some(v) = fp_result.get(fp) {
                        by_tag.insert(*tag, v.clone());
                    }
                }
            }
        }
        by_tag
    }
}


/// Whole graph as a single pseudo-layer (partitioning disabled).
fn whole_graph_slice(g: &crate::ir::Graph) -> Vec<LayerSlice> {
    let mut g2 = g.clone();
    for n in g2.nodes.iter_mut() {
        n.meta.layer = None;
    }
    extract_layers(&g2)
}

#[cfg(test)]
mod tests;
