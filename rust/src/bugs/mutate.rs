//! Graph surgery: controlled mutations that inject the bug corpus.

use crate::ir::{Graph, Meta, NodeId, Op};
use crate::verifier::GraphPair;
use rustc_hash::FxHashMap;

/// Bypass every node matching `pred`: its consumers read its first input
/// instead (models a *missing* operation, e.g. a dropped all-reduce).
pub fn bypass_nodes(g: &mut Graph, mut pred: impl FnMut(&Graph, NodeId) -> bool) -> usize {
    let targets: Vec<NodeId> =
        g.nodes.iter().map(|n| n.id).filter(|&id| pred(g, id)).collect();
    let mut redirect: FxHashMap<NodeId, NodeId> = FxHashMap::default();
    for t in &targets {
        let src = g.node(*t).inputs[0];
        // chase chains of bypassed nodes
        let src = *redirect.get(&src).unwrap_or(&src);
        redirect.insert(*t, src);
    }
    let mut changed = 0;
    for n in g.nodes.iter_mut() {
        for i in n.inputs.iter_mut() {
            if let Some(&r) = redirect.get(i) {
                *i = r;
                changed += 1;
            }
        }
    }
    for o in g.outputs.iter_mut() {
        if let Some(&r) = redirect.get(o) {
            *o = r;
        }
    }
    changed
}

/// Mutate the op of every node matching `pred` in place (wrong replica
/// groups, wrong reshape dims, wrong transpose, …). The node's shape may
/// be updated too via the second closure.
pub fn mutate_ops(
    g: &mut Graph,
    mut pred: impl FnMut(&Graph, NodeId) -> bool,
    f: impl Fn(&mut Op, &mut crate::ir::Shape),
) -> usize {
    let targets: Vec<NodeId> =
        g.nodes.iter().map(|n| n.id).filter(|&id| pred(g, id)).collect();
    for &t in &targets {
        let node = g.node_mut(t);
        let mut op = node.op.clone();
        let mut shape = node.shape.clone();
        f(&mut op, &mut shape);
        node.op = op;
        node.shape = shape;
    }
    targets.len()
}

/// Insert extra nodes after the first node matching `pred`: `build`
/// receives the rebuilt graph and the (remapped) id of the matched node and
/// returns the replacement id consumers should use. Returns the id remap so
/// callers can fix annotations.
pub fn wrap_first(
    g: &Graph,
    mut pred: impl FnMut(&Graph, NodeId) -> bool,
    build: impl FnOnce(&mut Graph, NodeId) -> NodeId,
) -> (Graph, FxHashMap<NodeId, NodeId>) {
    let target = g.nodes.iter().map(|n| n.id).find(|&id| pred(g, id));
    let mut out = Graph::new(g.name.clone(), g.num_cores);
    out.mesh = g.mesh.clone(); // keep declared mesh axes through the rebuild
    let mut remap: FxHashMap<NodeId, NodeId> = FxHashMap::default();
    let mut build = Some(build);
    for n in &g.nodes {
        let inputs: Vec<NodeId> = n.inputs.iter().map(|i| remap[i]).collect();
        let meta = out.import_meta(g, &n.meta);
        let new_id = out.push(n.op.clone(), inputs, n.shape.clone(), meta);
        if Some(n.id) == target {
            let wrapped = (build.take().unwrap())(&mut out, new_id);
            remap.insert(n.id, wrapped);
        } else {
            remap.insert(n.id, new_id);
        }
    }
    out.outputs = g.outputs.iter().map(|o| remap[o]).collect();
    (out, remap)
}

/// Apply a dist-graph rebuild remap to a pair's annotations.
pub fn remap_annotations(pair: &mut GraphPair, remap: &FxHashMap<NodeId, NodeId>) {
    for a in pair.annotations.iter_mut() {
        if let Some(&r) = remap.get(&a.distributed) {
            a.distributed = r;
        }
    }
}

/// Find the nth node (0-based) matching a predicate.
pub fn nth_match(
    g: &Graph,
    mut pred: impl FnMut(&Graph, NodeId) -> bool,
    n: usize,
) -> Option<NodeId> {
    g.nodes.iter().map(|x| x.id).filter(|&id| pred(g, id)).nth(n)
}

/// Predicate helper: node is in `func` (framework function name).
pub fn in_func(g: &Graph, id: NodeId, func: &str) -> bool {
    g.interner.resolve(g.node(id).meta.func) == func
}

/// Predicate helper: node op name equals `name`.
pub fn is_op(g: &Graph, id: NodeId, name: &str) -> bool {
    g.node(id).op.name() == name
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DType, GraphBuilder, ReduceKind, ReplicaGroups, Shape};

    fn tp_graph() -> Graph {
        let mut b = GraphBuilder::new("g", 2);
        let x = b.parameter("x", Shape::new(DType::F32, vec![4, 4]));
        let w = b.parameter("w", Shape::new(DType::F32, vec![4, 4]));
        let h = b.matmul(x, w);
        let r = b.all_reduce(h, ReduceKind::Add, ReplicaGroups::full(2));
        let t = b.tanh(r);
        b.output(t);
        b.finish()
    }

    #[test]
    fn bypass_removes_collective() {
        let mut g = tp_graph();
        let n = bypass_nodes(&mut g, |g, id| is_op(g, id, "all-reduce"));
        assert!(n > 0);
        g.validate().unwrap();
        // tanh now reads the matmul directly
        let tanh = g.nodes.iter().find(|n| n.op.name() == "tanh").unwrap();
        assert_eq!(g.node(tanh.inputs[0]).op.name(), "dot");
    }

    #[test]
    fn mutate_changes_groups() {
        let mut g = tp_graph();
        let n = mutate_ops(
            &mut g,
            |g, id| is_op(g, id, "all-reduce"),
            |op, _| {
                if let Op::AllReduce { groups, .. } = op {
                    *groups = ReplicaGroups::split(2, 2);
                }
            },
        );
        assert_eq!(n, 1);
        g.validate().unwrap();
    }

    #[test]
    fn wrap_inserts_nodes() {
        let g = tp_graph();
        let (g2, remap) = wrap_first(
            &g,
            |g, id| is_op(g, id, "dot"),
            |g, id| {
                let shape = g.node(id).shape.clone();
                let lo = g.push(
                    Op::Convert { to: DType::BF16 },
                    vec![id],
                    shape.with_dtype(DType::BF16),
                    Meta::none(),
                );
                g.push(Op::Convert { to: DType::F32 }, vec![lo], shape, Meta::none())
            },
        );
        g2.validate().unwrap();
        assert_eq!(g2.len(), g.len() + 2);
        assert!(remap.len() == g.len());
        // all-reduce consumes the round-tripped value now
        let ar = g2.nodes.iter().find(|n| n.op.name() == "all-reduce").unwrap();
        assert_eq!(g2.node(ar.inputs[0]).op.name(), "convert");
    }
}
