//! The bug catalog: Table 4 (19 reproduced) + Table 5 (5 new).

use super::mutate::{
    bypass_nodes, in_func, is_op, mutate_ops, nth_match, remap_annotations, wrap_first,
};
use crate::ir::{Annotation, DType, GraphBuilder, NodeId, Op, ReplicaGroups, Shape};
use crate::modelgen::{
    dpstep_pair, llama_pair, mixtral_pair, LlamaConfig, MixtralConfig, Parallelism,
    TrainStepConfig,
};
use crate::verifier::GraphPair;

/// Bug category (paper §7.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Category {
    /// Wrong communication primitive / missing or redundant collective.
    IncorrectDistributedOp,
    /// Wrong device assignment (replica groups).
    IncorrectDistributedConfig,
    /// Single-device and distributed pipelines use different precisions.
    InconsistentPrecision,
    /// Reshape splits tensors incorrectly.
    IncorrectAxisSplit,
    /// Invalid layout-transformation sequence.
    IncorrectLayoutOptimization,
    /// Manifests outside graph compilation (Scalify cannot see it).
    OutsideGraph,
}

/// The paper's localization rating for the case.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExpectedLoc {
    /// ▸ — pinpoints the faulty instruction.
    Instruction,
    /// ★ — pinpoints the faulty function / data structure.
    Function,
    /// n/a — undetected (outside the graph-compilation phase).
    NotApplicable,
}

/// One bug case.
pub struct BugCase {
    /// Paper id, e.g. `T4#3`.
    pub id: &'static str,
    /// Short description (paper row).
    pub description: &'static str,
    /// Category.
    pub category: Category,
    /// Upstream issue/commit reference from the paper.
    pub issue: &'static str,
    /// Paper's localization rating.
    pub expected: ExpectedLoc,
    /// Ground-truth source site of the fault (`file:line`, function).
    pub truth_site: &'static str,
    /// Ground-truth function.
    pub truth_func: &'static str,
    /// Build the buggy pair.
    pub build: fn() -> GraphPair,
}

/// Llama config used by the bug corpus: one layer, 4 heads so head-level
/// layout faults are non-trivial.
fn bug_llama() -> LlamaConfig {
    LlamaConfig { layers: 1, hidden: 8, heads: 4, kv_heads: 4, ffn: 16, seqlen: 4, batch: 1 }
}

fn llama_tp() -> GraphPair {
    llama_pair(&bug_llama(), Parallelism::Tensor { tp: 2 })
}

fn flash() -> GraphPair {
    llama_pair(&LlamaConfig::tiny(), Parallelism::FlashDecoding { tp: 2 })
}

fn mixtral_ep() -> GraphPair {
    mixtral_pair(&MixtralConfig::tiny(), Parallelism::Expert { ep: 4 })
}

/// Sequence-parallel attention all-to-all micro-pair (deepspeed-5808-like).
fn a2a_pair(bug: Option<(usize, usize)>) -> GraphPair {
    let (s, h, tp) = (8i64, 8i64, 2u32);
    let mut bb = GraphBuilder::new("base", 1);
    bb.layer(Some(0)).at("sp_attention.py", 15).in_func("seq_alltoall");
    let x = bb.parameter("x", Shape::new(DType::F32, vec![s, h]));
    let y = bb.tanh(x);
    bb.output(y);
    let base = bb.finish();

    let mut db = GraphBuilder::new("dist", tp);
    db.layer(Some(0)).at("sp_attention.py", 15).in_func("seq_alltoall");
    let xd = db.parameter("x", Shape::new(DType::F32, vec![s / tp as i64, h]));
    let t = db.tanh(xd);
    db.at("sp_attention.py", 22);
    let (split_dim, concat_dim) = bug.unwrap_or((1, 0));
    let a = db.all_to_all(t, split_dim, concat_dim, ReplicaGroups::full(tp));
    db.at("sp_attention.py", 23);
    let g = db.all_gather(a, if concat_dim == 0 { 1 } else { 0 }, ReplicaGroups::full(tp));
    // the reshape "patch" that forces the baseline's output shape — in the
    // real bugs this is the incorrect reshape Scalify pinpoints
    db.at("sp_attention.py", 24);
    let out = db.reshape(g, vec![s, h]);
    db.output(out);
    let dist = db.finish();

    let ann = vec![Annotation::shard(x, NodeId(0), 0, tp)];
    GraphPair::new(base, dist, ann)
}

fn bypass(mut pair: GraphPair, pred: impl FnMut(&crate::ir::Graph, NodeId) -> bool) -> GraphPair {
    bypass_nodes(&mut pair.dist, pred);
    pair
}

fn wrong_groups(mut pair: GraphPair, func: &str, nth: usize) -> GraphPair {
    let func = func.to_owned();
    let target = nth_match(
        &pair.dist,
        |g, id| is_op(g, id, "all-reduce") && in_func(g, id, &func),
        nth,
    );
    if let Some(t) = target {
        let cores = pair.dist.num_cores;
        mutate_ops(
            &mut pair.dist,
            |_, id| id == t,
            |op, _| {
                if let Op::AllReduce { groups, .. } = op {
                    *groups = ReplicaGroups::split(cores, cores);
                }
            },
        );
    }
    pair
}

/// Append a redundant all-reduce after the node matched by (func, op, nth).
fn redundant_allreduce(pair: GraphPair, func: &'static str, opname: &'static str, nth: usize) -> GraphPair {
    let cores = pair.dist.num_cores;
    let (dist, remap) = wrap_first(
        &pair.dist,
        {
            let mut count = 0;
            move |g, id| {
                if is_op(g, id, opname) && in_func(g, id, func) {
                    let hit = count == nth;
                    count += 1;
                    hit
                } else {
                    false
                }
            }
        },
        |g, id| {
            let node = g.node(id);
            let (shape, meta) = (node.shape.clone(), node.meta);
            g.push(
                Op::AllReduce {
                    kind: crate::ir::ReduceKind::Add,
                    groups: ReplicaGroups::full(cores),
                },
                vec![id],
                shape,
                meta,
            )
        },
    );
    let mut pair = GraphPair { dist, ..pair };
    remap_annotations(&mut pair, &remap);
    pair
}

/// Wrap a node with a bf16 → f32 round-trip (precision fault).
fn precision_roundtrip(pair: GraphPair, func: &'static str, opname: &'static str, nth: usize) -> GraphPair {
    let (dist, remap) = wrap_first(
        &pair.dist,
        {
            let mut count = 0;
            move |g, id| {
                if is_op(g, id, opname) && in_func(g, id, func) {
                    let hit = count == nth;
                    count += 1;
                    hit
                } else {
                    false
                }
            }
        },
        |g, id| {
            let node = g.node(id);
            let (shape, meta) = (node.shape.clone(), node.meta);
            let lo = g.push(
                Op::Convert { to: DType::BF16 },
                vec![id],
                shape.with_dtype(DType::BF16),
                meta,
            );
            g.push(Op::Convert { to: DType::F32 }, vec![lo], shape, meta)
        },
    );
    let mut pair = GraphPair { dist, ..pair };
    remap_annotations(&mut pair, &remap);
    pair
}

/// The BSH layout fault (Figure 1): replace the (nh,T,hd)→(T,nh,hd)
/// transpose with the identity, keeping shapes consistent.
fn bsh_fault(mut pair: GraphPair) -> GraphPair {
    let target = nth_match(
        &pair.dist,
        |g, id| {
            matches!(g.node(id).op, Op::Transpose { ref perm } if perm == &[1, 0, 2])
                && in_func(g, id, "attention_output")
        },
        0,
    );
    if let Some(t) = target {
        let in_dims = pair.dist.node(pair.dist.node(t).inputs[0]).shape.dims.clone();
        mutate_ops(
            &mut pair.dist,
            |_, id| id == t,
            |op, shape| {
                *op = Op::Transpose { perm: vec![0, 1, 2] };
                shape.dims = in_dims.clone();
            },
        );
        // the downstream reshape keeps its dims (element counts agree), so
        // the graph stays valid but semantically wrong — Figure 1 exactly
    }
    pair
}

/// Missing-normalization fault: drop the norm-weight multiply.
fn missing_norm(pair: GraphPair, nth: usize) -> GraphPair {
    let target = nth_match(
        &pair.dist,
        |g, id| is_op(g, id, "multiply") && in_func(g, id, "rms_norm"),
        // each rmsnorm has 4 muls (x*x, s*1/H, x*r, xn*g); the g-mul is 4th
        nth * 4 + 3,
    );
    match target {
        Some(t) => bypass(pair, move |_, id| id == t),
        None => pair,
    }
}

/// Wrong-sharding fault: the annotation claims the q-projection is sharded
/// along dim 0 while the distributed graph actually consumes a dim-1 shard.
fn wrong_sharding(mut pair: GraphPair) -> GraphPair {
    for a in pair.annotations.iter_mut() {
        if let crate::ir::InputRelation::ShardAlong { dim, .. } = &mut a.relation {
            // flip the first column-sharded weight (q_proj)
            if *dim == 1 {
                *dim = 0;
                break;
            }
        }
    }
    pair
}

/// Wrong operation ordering: all-reduce applied after the residual add
/// instead of before it (reduces the replicated residual too).
fn reduce_after_residual(pair: GraphPair) -> GraphPair {
    // remove the attention all-reduce…
    let t = nth_match(&pair.dist, |g, id| is_op(g, id, "all-reduce"), 0);
    let pair = match t {
        Some(t) => bypass(pair, move |_, id| id == t),
        None => pair,
    };
    // …and put it after the residual add instead
    redundant_allreduce(pair, "decoder_layer", "add", 0)
}

/// KV-cache slicing / logits-layout bugs manifest outside the compiled
/// graph (runtime cache update, host-side postprocessing): the compiled
/// pair itself is correct, so Scalify verifies it — the paper's n/a rows.
fn outside_graph_flash() -> GraphPair {
    flash()
}
fn outside_graph_llama() -> GraphPair {
    llama_tp()
}

/// Table 4: the 19 reproduced bugs.
pub fn reproduced_bugs() -> Vec<BugCase> {
    vec![
        BugCase {
            id: "T4#1",
            description: "Incorrect layout optimization (BSH attention output)",
            category: Category::IncorrectLayoutOptimization,
            issue: "transformersneuronx-69d039d",
            expected: ExpectedLoc::Function,
            truth_site: "attention.py:79",
            truth_func: "attention_output",
            build: || bsh_fault(llama_tp()),
        },
        BugCase {
            id: "T4#2",
            description: "Incorrect all-to-all layout (seq-parallel, bs>1)",
            category: Category::IncorrectLayoutOptimization,
            issue: "deepspeed-5808",
            expected: ExpectedLoc::Instruction,
            truth_site: "sp_attention.py:24",
            truth_func: "seq_alltoall",
            build: || a2a_pair(Some((0, 1))),
        },
        BugCase {
            id: "T4#3",
            description: "Missing all-reduce (attention output projection)",
            category: Category::IncorrectDistributedOp,
            issue: "megatronlm-1699",
            expected: ExpectedLoc::Function,
            truth_site: "decoder.py:55",
            truth_func: "decoder_layer",
            build: || {
                let t = nth_match(
                    &llama_tp().dist,
                    |g, id| is_op(g, id, "all-reduce") && in_func(g, id, "attention_output"),
                    0,
                );
                bypass(llama_tp(), move |_, id| Some(id) == t)
            },
        },
        BugCase {
            id: "T4#4",
            description: "Missing all-reduce (MLP down projection)",
            category: Category::IncorrectDistributedOp,
            issue: "megatronlm-599",
            expected: ExpectedLoc::Function,
            truth_site: "decoder.py:61",
            truth_func: "decoder_layer",
            build: || {
                let t = nth_match(
                    &llama_tp().dist,
                    |g, id| is_op(g, id, "all-reduce") && in_func(g, id, "mlp_fwd"),
                    0,
                );
                bypass(llama_tp(), move |_, id| Some(id) == t)
            },
        },
        BugCase {
            id: "T4#5",
            description: "Missing all-reduce (MoE expert sum)",
            category: Category::IncorrectDistributedOp,
            issue: "deepspeed-7188",
            expected: ExpectedLoc::Function,
            truth_site: "moe.py:90",
            truth_func: "moe_layer",
            build: || bypass(mixtral_ep(), |g, id| is_op(g, id, "all-reduce")),
        },
        BugCase {
            id: "T4#6",
            description: "Missing all-reduce (flash-decoding denominator)",
            category: Category::IncorrectDistributedOp,
            issue: "megatronlm-5fffdfc",
            expected: ExpectedLoc::Function,
            truth_site: "flash_decoding.py:50",
            truth_func: "flash_decode",
            build: || {
                let t = nth_match(&flash().dist, |g, id| is_op(g, id, "all-reduce"), 2);
                bypass(flash(), move |_, id| Some(id) == t)
            },
        },
        BugCase {
            id: "T4#7",
            description: "Missing normalization (attention input norm weight)",
            category: Category::IncorrectDistributedOp,
            issue: "megatronlm-1620",
            expected: ExpectedLoc::Function,
            truth_site: "attention.py:40",
            truth_func: "attention_fwd",
            build: || missing_norm(llama_tp(), 0),
        },
        BugCase {
            id: "T4#8",
            description: "Missing normalization (MLP input norm weight)",
            category: Category::IncorrectDistributedOp,
            issue: "megatronlm-1611",
            expected: ExpectedLoc::Function,
            truth_site: "mlp.py:33",
            truth_func: "mlp_fwd",
            build: || missing_norm(llama_tp(), 1),
        },
        BugCase {
            id: "T4#9",
            description: "Redundant all-reduce (replicated residual)",
            category: Category::IncorrectDistributedOp,
            issue: "nemo-9344",
            expected: ExpectedLoc::Instruction,
            truth_site: "decoder.py:55",
            truth_func: "decoder_layer",
            build: || redundant_allreduce(llama_tp(), "decoder_layer", "add", 0),
        },
        BugCase {
            id: "T4#10",
            description: "Redundant all-reduce (double reduce after MLP)",
            category: Category::IncorrectDistributedOp,
            issue: "transformerengine-3",
            expected: ExpectedLoc::Instruction,
            truth_site: "mlp.py:36",
            truth_func: "mlp_fwd",
            build: || redundant_allreduce(llama_tp(), "mlp_fwd", "all-reduce", 0),
        },
        BugCase {
            id: "T4#11",
            description: "Redundant all-reduce (column-sharded gate output)",
            category: Category::IncorrectDistributedOp,
            issue: "nemo-8487",
            expected: ExpectedLoc::Instruction,
            truth_site: "mlp.py:33",
            truth_func: "mlp_fwd",
            build: || redundant_allreduce(llama_tp(), "mlp_fwd", "dot", 0),
        },
        BugCase {
            id: "T4#12",
            description: "Redundant all-reduce (MoE output reduced twice)",
            category: Category::IncorrectDistributedOp,
            issue: "deepspeed-6714",
            expected: ExpectedLoc::Instruction,
            truth_site: "moe.py:84",
            truth_func: "moe_local",
            build: || redundant_allreduce(mixtral_ep(), "moe_local", "all-reduce", 0),
        },
        BugCase {
            id: "T4#13",
            description: "Incorrect replica groups (attention all-reduce)",
            category: Category::IncorrectDistributedConfig,
            issue: "megatronlm-32bbb76",
            expected: ExpectedLoc::Instruction,
            truth_site: "attention.py:79",
            truth_func: "attention_output",
            build: || wrong_groups(llama_tp(), "attention_output", 0),
        },
        BugCase {
            id: "T4#14",
            description: "Incorrect replica groups (MLP all-reduce)",
            category: Category::IncorrectDistributedConfig,
            issue: "deepspeed-5618",
            expected: ExpectedLoc::Instruction,
            truth_site: "mlp.py:36",
            truth_func: "mlp_fwd",
            build: || wrong_groups(llama_tp(), "mlp_fwd", 0),
        },
        BugCase {
            id: "T4#15",
            description: "Incorrect replica groups (flash-decoding max)",
            category: Category::IncorrectDistributedConfig,
            issue: "nemo-5564",
            expected: ExpectedLoc::Instruction,
            truth_site: "flash_decoding.py:31",
            truth_func: "flash_decode",
            build: || wrong_groups(flash(), "flash_decode", 0),
        },
        BugCase {
            id: "T4#16",
            description: "Incorrect replica groups (MoE all-reduce)",
            category: Category::IncorrectDistributedConfig,
            issue: "transformerengine-335",
            expected: ExpectedLoc::Instruction,
            truth_site: "moe.py:84",
            truth_func: "moe_local",
            build: || wrong_groups(mixtral_ep(), "moe_local", 0),
        },
        BugCase {
            id: "T4#17",
            description: "Inconsistent precision (bf16 round-trip on q)",
            category: Category::InconsistentPrecision,
            issue: "deepspeed-2071",
            expected: ExpectedLoc::Instruction,
            truth_site: "attention.py:40",
            truth_func: "attention_fwd",
            build: || precision_roundtrip(llama_tp(), "attention_fwd", "dot", 0),
        },
        BugCase {
            id: "T4#18",
            description: "Incorrect KV cache slicing (runtime phase)",
            category: Category::OutsideGraph,
            issue: "transformersneuronx-e2f5241",
            expected: ExpectedLoc::NotApplicable,
            truth_site: "",
            truth_func: "",
            build: outside_graph_flash,
        },
        BugCase {
            id: "T4#19",
            description: "Incorrect logits layout (host postprocessing)",
            category: Category::OutsideGraph,
            issue: "transformersneuronx-0c646b0",
            expected: ExpectedLoc::NotApplicable,
            truth_site: "",
            truth_func: "",
            build: outside_graph_llama,
        },
    ]
}

// ---- pipeline / data-parallel fault builders ----

fn pipeline_pair() -> GraphPair {
    llama_pair(&LlamaConfig::tiny(), Parallelism::Pipeline { pp: 2 })
}

fn dp_pair(zero_stage: u8) -> GraphPair {
    dpstep_pair(&TrainStepConfig::tiny(), Parallelism::Data { dp: 2, zero_stage })
}

/// Stage-boundary off-by-one: the send at the pipeline boundary reads one
/// node upstream of the true boundary value (the residual *before* the
/// MLP add), so the next stage starts from a stale activation.
fn stage_boundary_off_by_one() -> GraphPair {
    let mut pair = pipeline_pair();
    if let Some(s) = nth_match(&pair.dist, |g, id| is_op(g, id, "send"), 0) {
        let src = pair.dist.node(s).inputs[0];
        if let Some(&earlier) = pair.dist.node(src).inputs.first() {
            pair.dist.node_mut(s).inputs[0] = earlier;
        }
    }
    pair
}

/// Missing gradient all-reduce (ZeRO-0): the data-parallel replicas apply
/// their local partial gradients without reducing across the mesh.
fn missing_grad_allreduce() -> GraphPair {
    let mut pair = dp_pair(0);
    let t = nth_match(&pair.dist, |g, id| is_op(g, id, "all-reduce"), 0);
    if let Some(t) = t {
        bypass_nodes(&mut pair.dist, move |_, id| id == t);
    }
    pair
}

/// Stale ZeRO shard: the gradient reduce-scatter is dropped, so each rank
/// updates its optimizer-state shard with the unreduced local partial.
fn stale_zero_shard() -> GraphPair {
    let mut pair = dp_pair(1);
    let t = nth_match(&pair.dist, |g, id| is_op(g, id, "reduce-scatter"), 0);
    if let Some(t) = t {
        bypass_nodes(&mut pair.dist, move |_, id| id == t);
    }
    pair
}

/// Missing ZeRO-2 parameter gather: the forward matmul consumes the local
/// weight shard instead of the gathered full weight.
fn missing_weight_gather() -> GraphPair {
    let mut pair = dp_pair(2);
    let t = nth_match(&pair.dist, |g, id| is_op(g, id, "all-gather"), 0);
    if let Some(t) = t {
        bypass_nodes(&mut pair.dist, move |_, id| id == t);
    }
    pair
}

/// New catalog cases targeting the pipeline / data-parallel scenario
/// space the transform engine opened (the dominant bug classes in the
/// distributed-training bug studies; see PAPERS.md).
pub fn parallel_transform_bugs() -> Vec<BugCase> {
    vec![
        BugCase {
            id: "PT#1",
            description: "Pipeline stage boundary off-by-one (stale activation sent)",
            category: Category::IncorrectDistributedOp,
            issue: "study:pipeline-boundary",
            expected: ExpectedLoc::Function,
            truth_site: "decoder.py:61",
            truth_func: "decoder_layer",
            build: stage_boundary_off_by_one,
        },
        BugCase {
            id: "PT#2",
            description: "Missing gradient all-reduce (ZeRO-0 data parallelism)",
            category: Category::IncorrectDistributedOp,
            issue: "study:missing-grad-allreduce",
            expected: ExpectedLoc::Function,
            truth_site: "optim.py:12",
            truth_func: "optimizer_step",
            build: missing_grad_allreduce,
        },
        BugCase {
            id: "PT#3",
            description: "Stale ZeRO shard (gradient reduce-scatter dropped)",
            category: Category::IncorrectDistributedOp,
            issue: "study:stale-zero-shard",
            expected: ExpectedLoc::Function,
            truth_site: "optim.py:12",
            truth_func: "optimizer_step",
            build: stale_zero_shard,
        },
        BugCase {
            id: "PT#4",
            description: "Wrong microbatch split (off-by-one pipeline slice)",
            category: Category::IncorrectAxisSplit,
            issue: "study:microbatch-split",
            expected: ExpectedLoc::Instruction,
            truth_site: "pipeline.py:40",
            truth_func: "microbatch_split",
            build: || crate::modelgen::demo::microbatch_pair(true),
        },
        BugCase {
            id: "PT#5",
            description: "Missing ZeRO-2 parameter all-gather (forward on a weight shard)",
            category: Category::IncorrectDistributedOp,
            issue: "study:missing-param-gather",
            expected: ExpectedLoc::Function,
            truth_site: "layers.py:14",
            truth_func: "forward",
            build: missing_weight_gather,
        },
    ]
}

// ---- replica-group (mesh subgroup) fault builders ----

/// The dp2×tp2 mesh training step: one SPMD graph whose gradient
/// all-reduces run over the strided dp subgroups and whose hidden-dim
/// discharges run over the contiguous tp subgroups.
fn mesh_step() -> GraphPair {
    dpstep_pair(&TrainStepConfig::tiny(), Parallelism::Mesh3D { pp: 1, dp: 2, tp: 2 })
}

/// Swap the `nth` all-reduce running over `from_axis`'s subgroups onto
/// `to_axis`'s subgroups — the classic wrong-replica-group mixup between
/// mesh axes (still well-formed groups, so only semantics catch it).
fn swap_axis_groups(
    mut pair: GraphPair,
    from_axis: usize,
    to_axis: usize,
    nth: usize,
) -> GraphPair {
    let mesh = pair.dist.mesh_view();
    let from = mesh.groups_for(1 << from_axis);
    let to = mesh.groups_for(1 << to_axis);
    let target = nth_match(
        &pair.dist,
        |g, id| matches!(&g.node(id).op, Op::AllReduce { groups, .. } if *groups == from),
        nth,
    );
    if let Some(t) = target {
        mutate_ops(
            &mut pair.dist,
            |_, id| id == t,
            |op, _| {
                if let Op::AllReduce { groups, .. } = op {
                    *groups = to.clone();
                }
            },
        );
    }
    pair
}

/// Permute subgroup membership across mesh axes: `{{0,1},{2,3}}` becomes
/// `{{0,3},{1,2}}` — every group still a valid partition, but its members
/// mix different dp ranks' batch shards.
fn permute_axis_groups(mut pair: GraphPair, axis: usize, nth: usize) -> GraphPair {
    let mesh = pair.dist.mesh_view();
    let from = mesh.groups_for(1 << axis);
    let target = nth_match(
        &pair.dist,
        |g, id| matches!(&g.node(id).op, Op::AllReduce { groups, .. } if *groups == from),
        nth,
    );
    if let Some(t) = target {
        mutate_ops(
            &mut pair.dist,
            |_, id| id == t,
            |op, _| {
                if let Op::AllReduce { groups, .. } = op {
                    // rotate the tail members one group forward
                    let mut gs = groups.0.clone();
                    if gs.len() >= 2 && gs.iter().all(|g| g.len() >= 2) {
                        let n = gs.len();
                        let tails: Vec<u32> =
                            (0..n).map(|i| *gs[i].last().unwrap()).collect();
                        for (i, g) in gs.iter_mut().enumerate() {
                            *g.last_mut().unwrap() = tails[(i + 1) % n];
                        }
                        *groups = ReplicaGroups(gs);
                    }
                }
            },
        );
    }
    pair
}

/// Overlapping replica groups: core 1 reduced into two groups. Not even a
/// valid partition — graph validation rejects the module with a typed
/// error naming the collective's source site.
fn overlapping_groups(mut pair: GraphPair, func: &str, nth: usize) -> GraphPair {
    let func = func.to_owned();
    let target = nth_match(
        &pair.dist,
        |g, id| is_op(g, id, "all-reduce") && in_func(g, id, &func),
        nth,
    );
    if let Some(t) = target {
        mutate_ops(
            &mut pair.dist,
            |_, id| id == t,
            |op, _| {
                if let Op::AllReduce { groups, .. } = op {
                    *groups = ReplicaGroups(vec![vec![0, 1], vec![1]]);
                }
            },
        );
    }
    pair
}

/// The wrong-replica-group corpus over subgroup collectives (`RG#1..3`):
/// the silent-error class the mesh scenarios make expressible — groups
/// that are well-formed partitions but reduce over the wrong mesh axis,
/// permute members across axes, or are not a partition at all.
pub fn replica_group_bugs() -> Vec<BugCase> {
    vec![
        BugCase {
            id: "RG#1",
            description: "Gradient all-reduce over the tp groups instead of dp (mesh step)",
            category: Category::IncorrectDistributedConfig,
            issue: "study:wrong-axis-grad-reduce",
            expected: ExpectedLoc::Instruction,
            truth_site: "backward.py:16",
            truth_func: "backward",
            build: || swap_axis_groups(mesh_step(), 0, 1, 0),
        },
        BugCase {
            id: "RG#2",
            description: "Overlapping replica groups (core reduced into two groups)",
            category: Category::IncorrectDistributedConfig,
            issue: "study:overlapping-groups",
            expected: ExpectedLoc::Instruction,
            truth_site: "attention.py:79",
            truth_func: "attention_output",
            build: || overlapping_groups(llama_tp(), "attention_output", 0),
        },
        BugCase {
            id: "RG#3",
            description: "Subgroup permutation across mesh axes (tp groups mix dp ranks)",
            category: Category::IncorrectDistributedConfig,
            issue: "study:permuted-subgroups",
            expected: ExpectedLoc::Instruction,
            truth_site: "layers.py:14",
            truth_func: "forward",
            build: || permute_axis_groups(mesh_step(), 1, 0),
        },
    ]
}

/// Table 5: the 5 previously-unknown bugs.
pub fn new_bugs() -> Vec<BugCase> {
    vec![
        BugCase {
            id: "T5#1",
            description: "Incorrect layout optimization (TNx BSH output)",
            category: Category::IncorrectLayoutOptimization,
            issue: "TNx",
            expected: ExpectedLoc::Function,
            truth_site: "attention.py:124",
            truth_func: "attention_bsh",
            build: || crate::modelgen::demo::bsh_pair(true),
        },
        BugCase {
            id: "T5#2",
            description: "Wrong all-to-all transformation (TNx)",
            category: Category::IncorrectLayoutOptimization,
            issue: "TNx",
            expected: ExpectedLoc::Instruction,
            truth_site: "sp_attention.py:24",
            truth_func: "seq_alltoall",
            build: || a2a_pair(Some((1, 1))),
        },
        BugCase {
            id: "T5#3",
            description: "Wrong sharding of tensors (TNx)",
            category: Category::IncorrectAxisSplit,
            issue: "TNx",
            expected: ExpectedLoc::Instruction,
            truth_site: "attention.py:40",
            truth_func: "attention_fwd",
            build: || wrong_sharding(llama_tp()),
        },
        BugCase {
            id: "T5#4",
            description: "Wrong precision ordering (NxD rotary embedding)",
            category: Category::InconsistentPrecision,
            issue: "NxD",
            expected: ExpectedLoc::Function,
            truth_site: "rotary.py:44",
            truth_func: "apply_rotary",
            build: || precision_roundtrip(llama_tp(), "apply_rotary", "broadcast", 0),
        },
        BugCase {
            id: "T5#5",
            description: "Wrong operation ordering (NxD reduce after residual)",
            category: Category::IncorrectDistributedOp,
            issue: "NxD",
            expected: ExpectedLoc::Function,
            truth_site: "decoder.py:55",
            truth_func: "decoder_layer",
            build: || reduce_after_residual(llama_tp()),
        },
    ]
}
