//! Bug-case evaluation: run Scalify, classify detection + localization.

use super::catalog::BugCase;
use crate::verifier::{Session, VerifyConfig};

/// Localization quality achieved on a case.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LocResult {
    /// A reported discrepancy names the exact ground-truth `file:line`.
    Instruction,
    /// A reported discrepancy lands in the ground-truth function.
    Function,
    /// Detected, but no discrepancy near the ground truth.
    Elsewhere,
    /// Not detected.
    Undetected,
}

/// Outcome of evaluating one bug case.
#[derive(Clone, Debug)]
pub struct BugOutcome {
    /// Bug verdict: true when Scalify reported non-equivalence.
    pub detected: bool,
    /// Localization quality vs the ground truth.
    pub loc: LocResult,
    /// All reported sites (for diagnostics).
    pub sites: Vec<String>,
    /// Verification wall time.
    pub duration: std::time::Duration,
}

/// Run Scalify on the case's buggy pair and classify the outcome.
pub fn evaluate(case: &BugCase) -> BugOutcome {
    let t0 = std::time::Instant::now();
    let pair = (case.build)();
    let result =
        Session::new(VerifyConfig { parallel: false, ..VerifyConfig::default() })
            .verify(&pair);
    let report = match result {
        Ok(report) => report,
        // ONLY a typed structural rejection (malformed replica groups and
        // friends caught by graph validation) counts as a detection: the
        // bug never reaches the device, and the error carries the
        // offending node's source site. Any other verify error is harness
        // breakage and must stay loud.
        Err(e @ crate::error::ScalifyError::ModelSpec(_)) => {
            let msg = e.to_string();
            let loc = if !case.truth_site.is_empty() && msg.contains(case.truth_site) {
                LocResult::Instruction
            } else if !case.truth_func.is_empty() && msg.contains(case.truth_func) {
                LocResult::Function
            } else {
                LocResult::Elsewhere
            };
            return BugOutcome {
                detected: true,
                loc,
                sites: vec![msg],
                duration: t0.elapsed(),
            };
        }
        Err(e) => panic!("bug-corpus pair failed to verify for a non-structural reason: {e}"),
    };
    let detected = !report.verified();
    let discrepancies = report.discrepancies();
    let sites: Vec<String> = discrepancies
        .iter()
        .map(|d| format!("{} [{}]", d.render(), d.func))
        .collect();
    let loc = if !detected {
        LocResult::Undetected
    } else if !case.truth_site.is_empty()
        && discrepancies.iter().any(|d| d.site == case.truth_site)
    {
        LocResult::Instruction
    } else if !case.truth_func.is_empty()
        && discrepancies.iter().any(|d| d.func == case.truth_func)
    {
        LocResult::Function
    } else {
        LocResult::Elsewhere
    };
    BugOutcome { detected, loc, sites, duration: report.total }
}
