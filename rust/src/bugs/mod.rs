//! Bug corpus: the 19 reproduced production bugs of Table 4 and the 5 new
//! Amazon-SDK bugs of Table 5, re-implemented as graph mutations on the
//! model zoo's verified pairs.
//!
//! Each case records the paper's bug id, category, upstream issue link,
//! the *ground-truth* source site of the injected fault, and the paper's
//! reported localization precision (▸ instruction / ★ function / n/a).
//! The evaluation harness runs Scalify on each mutated pair and classifies
//! the outcome against the ground truth.

mod mutate;
mod catalog;
mod eval;

pub use catalog::{new_bugs, reproduced_bugs, BugCase, Category, ExpectedLoc};
pub use eval::{evaluate, BugOutcome, LocResult};
pub use mutate::{bypass_nodes, in_func, is_op, mutate_ops, remap_annotations, wrap_first};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_sizes_match_paper() {
        assert_eq!(reproduced_bugs().len(), 19);
        assert_eq!(new_bugs().len(), 5);
    }

    #[test]
    fn all_detectable_bugs_detected_and_na_missed() {
        for case in reproduced_bugs() {
            let outcome = evaluate(&case);
            match case.expected {
                ExpectedLoc::NotApplicable => assert!(
                    !outcome.detected,
                    "{} should be missed (manifests outside graph compilation)",
                    case.id
                ),
                _ => assert!(outcome.detected, "{} should be detected", case.id),
            }
        }
    }

    #[test]
    fn new_bugs_all_detected() {
        for case in new_bugs() {
            let outcome = evaluate(&case);
            assert!(outcome.detected, "{} should be detected", case.id);
        }
    }

    #[test]
    fn localization_quality_matches_paper() {
        // every detected bug must localize at least to the function, and
        // the ▸-rated ones to the exact instruction site
        for case in reproduced_bugs().into_iter().chain(new_bugs()) {
            let outcome = evaluate(&case);
            match case.expected {
                ExpectedLoc::Instruction => assert_eq!(
                    outcome.loc,
                    LocResult::Instruction,
                    "{}: expected instruction-precise localization, got {:?} ({:?})",
                    case.id,
                    outcome.loc,
                    outcome.sites
                ),
                ExpectedLoc::Function => assert!(
                    matches!(outcome.loc, LocResult::Instruction | LocResult::Function),
                    "{}: expected >= function-precise localization, got {:?} ({:?})",
                    case.id,
                    outcome.loc,
                    outcome.sites
                ),
                ExpectedLoc::NotApplicable => {}
            }
        }
    }
}
