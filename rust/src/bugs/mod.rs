//! Bug corpus: the 19 reproduced production bugs of Table 4 and the 5 new
//! Amazon-SDK bugs of Table 5, re-implemented as graph mutations on the
//! model zoo's verified pairs.
//!
//! Each case records the paper's bug id, category, upstream issue link,
//! the *ground-truth* source site of the injected fault, and the paper's
//! reported localization precision (▸ instruction / ★ function / n/a).
//! The evaluation harness runs Scalify on each mutated pair and classifies
//! the outcome against the ground truth.

mod mutate;
mod catalog;
mod eval;

pub use catalog::{
    new_bugs, parallel_transform_bugs, replica_group_bugs, reproduced_bugs, BugCase,
    Category, ExpectedLoc,
};
pub use eval::{evaluate, BugOutcome, LocResult};
pub use mutate::{bypass_nodes, in_func, is_op, mutate_ops, remap_annotations, wrap_first};

// The per-case detection/localization assertions were promoted from an
// inline test module into the first-class integration suite
// `rust/tests/bug_corpus.rs` (run as `cargo test --test bug_corpus`), so
// CI can gate on the corpus independently of unit tests.
