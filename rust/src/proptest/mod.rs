//! Property-testing micro-framework (proptest is unavailable offline).
//!
//! Seeded generators + failure shrinking. Each property runs `cases`
//! times with derived seeds; on failure the failing seed is reported so
//! the case reproduces exactly (`SCALIFY_PROPTEST_SEED` overrides the
//! in-code base seed — see TESTING.md), and structured inputs are
//! shrunk toward a minimal counterexample with [`minimize`].

use crate::util::Prng;

/// Run `prop` for `cases` generated inputs; panic with the failing seed.
pub fn check<F: FnMut(&mut Prng) -> Result<(), String>>(
    name: &str,
    base_seed: u64,
    cases: u64,
    mut prop: F,
) {
    for i in 0..cases {
        let seed = base_seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(i);
        let mut prng = Prng::new(seed);
        if let Err(msg) = prop(&mut prng) {
            panic!("property '{name}' failed (seed {seed}, case {i}): {msg}");
        }
    }
}

/// Base seed for a property: the `SCALIFY_PROPTEST_SEED` environment
/// variable when set (to reproduce a CI failure locally), else `default`.
pub fn base_seed(default: u64) -> u64 {
    std::env::var("SCALIFY_PROPTEST_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Case count for a property: the `SCALIFY_PROPTEST_CASES` environment
/// variable when set (the nightly CI run raises it for deeper grids),
/// else `default`. PR runs keep the small defaults so the suite stays
/// fast; a failure reproduces locally from the reported seed regardless.
pub fn case_count(default: u64) -> u64 {
    std::env::var("SCALIFY_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Greedy input shrinking: starting from a failing `input`, repeatedly try
/// the candidates `shrink` proposes (smallest-first) and keep any that
/// still fails, until no candidate fails. Returns the minimal failing
/// input and its failure message.
pub fn minimize<T: Clone, F, S>(mut input: T, mut fails: F, shrink: S) -> (T, String)
where
    F: FnMut(&T) -> Option<String>,
    S: Fn(&T) -> Vec<T>,
{
    let mut msg = fails(&input).expect("minimize requires a failing input");
    loop {
        let mut advanced = false;
        for cand in shrink(&input) {
            if let Some(m) = fails(&cand) {
                input = cand;
                msg = m;
                advanced = true;
                break;
            }
        }
        if !advanced {
            return (input, msg);
        }
    }
}

/// Generate a random small shape (rank 1..=3, dims 1..=6).
pub fn small_dims(p: &mut Prng) -> Vec<i64> {
    let rank = p.range(1, 4);
    (0..rank).map(|_| p.range(1, 7) as i64).collect()
}

/// Generate a random permutation of 0..n.
pub fn permutation(p: &mut Prng, n: usize) -> Vec<usize> {
    let mut perm: Vec<usize> = (0..n).collect();
    p.shuffle(&mut perm);
    perm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::{infer_bijection, AtomStore, AxisExpr};

    #[test]
    fn prop_bijection_roundtrip_random_layout_chains() {
        // any chain of grouping reshapes + transposes on both paths admits
        // a valid bijection (same atoms, each once) and check passes
        check("bijection-roundtrip", 0xB17, 200, |p| {
            let mut st = AtomStore::new();
            let dims = small_dims(p);
            let x = AxisExpr::from_shape(&mut st, &dims);
            let chain = |st: &mut AtomStore, mut e: AxisExpr, p: &mut Prng| {
                for _ in 0..p.range(0, 4) {
                    if p.chance(0.5) {
                        let perm = permutation(p, e.rank());
                        e = e.transpose(&perm).unwrap();
                    } else {
                        // merge all axes then split into a random grouping
                        let total = e.dims(st).iter().product::<i64>();
                        let mut parts = Vec::new();
                        let mut rem = total;
                        while rem > 1 && parts.len() < 3 {
                            let mut d = 1;
                            for cand in [2, 3, 4, 5] {
                                if rem % cand == 0 && p.chance(0.4) {
                                    d = cand;
                                    break;
                                }
                            }
                            parts.push(d);
                            rem /= d;
                        }
                        parts.push(rem);
                        if let Ok(r) = e.reshape(st, &parts) {
                            e = r;
                        }
                    }
                }
                e
            };
            let a = chain(&mut st, x.clone(), p);
            let b = chain(&mut st, x, p);
            match infer_bijection(&st, &a, &b) {
                Some(bij) => {
                    if !crate::layout::bijection_check(&st, &a, &b, &bij) {
                        return Err(format!("bijection failed check: {}", bij.describe()));
                    }
                    Ok(())
                }
                None => Err("no bijection for same-atom layouts".into()),
            }
        });
    }

    #[test]
    fn prop_printed_hlo_roundtrips_numerically() {
        use crate::hlo::{parse_hlo_module, print_hlo_module};
        use crate::interp::{run_single, Tensor};
        use crate::ir::{DType, GraphBuilder, ReduceKind, Shape};
        check("hlo-roundtrip-numerics", 0x4110, 60, |p| {
            let dims = vec![p.range(1, 5) as i64, p.range(1, 5) as i64];
            let mut b = GraphBuilder::new("rt", 1);
            let x = b.parameter("x", Shape::new(DType::F32, dims.clone()));
            let mut cur = x;
            for _ in 0..p.range(1, 5) {
                cur = match p.range(0, 5) {
                    0 => b.exp(cur),
                    1 => b.tanh(cur),
                    2 => b.neg(cur),
                    3 => {
                        let t = b.transpose(cur, vec![1, 0]);
                        b.transpose(t, vec![1, 0])
                    }
                    _ => b.abs(cur),
                };
            }
            let red = b.reduce(cur, ReduceKind::Add, vec![0, 1]);
            b.output(red);
            let g = b.finish();
            let xv = Tensor::random(Shape::new(DType::F32, dims), p);
            let before = run_single(&g, &[xv.clone()]).map_err(|e| e.to_string())?;
            let g2 = parse_hlo_module(&print_hlo_module(&g), 1).map_err(|e| e.to_string())?;
            let after = run_single(&g2, &[xv]).map_err(|e| e.to_string())?;
            let d = before[0].max_abs_diff(&after[0]);
            if d > 1e-9 {
                return Err(format!("roundtrip drift {d}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_union_find_congruence_random_merges() {
        use crate::egraph::{EGraph, ENode};
        use crate::ir::Op;
        check("egraph-congruence", 0xE6, 100, |p| {
            let mut eg = EGraph::new();
            let leaves: Vec<_> = (0..4)
                .map(|i| {
                    eg.add(ENode::new(
                        Op::Parameter { index: i, name: format!("p{i}") },
                        vec![],
                    ))
                })
                .collect();
            // unary towers over each leaf
            let towers: Vec<Vec<_>> = leaves
                .iter()
                .map(|&l| {
                    let mut t = vec![l];
                    for _ in 0..3 {
                        let top = *t.last().unwrap();
                        t.push(eg.add(ENode::new(Op::Neg, vec![top])));
                    }
                    t
                })
                .collect();
            // random leaf unions
            let a = p.range(0, 4);
            let b = p.range(0, 4);
            eg.union(leaves[a], leaves[b]);
            eg.rebuild();
            // congruence must lift to every tower level
            for lvl in 0..4 {
                if !eg.same(towers[a][lvl], towers[b][lvl]) {
                    return Err(format!("level {lvl} not congruent"));
                }
            }
            Ok(())
        });
    }

    // ---- transform-engine differential properties ----

    use crate::modelgen::{
        dpstep_pair, golden_llama_pair, llama_pair, LlamaConfig, Parallelism, TrainStepConfig,
    };
    use crate::verifier::{Session, VerifyConfig};

    fn quiet_session() -> Session {
        Session::new(VerifyConfig { parallel: false, ..VerifyConfig::default() })
    }

    /// None when the engine-derived pair for (cfg, par) verifies and
    /// matches the interpreter; otherwise the failure description.
    fn llama_engine_failure(cfg: &LlamaConfig, par: Parallelism) -> Option<String> {
        let pair = match crate::modelgen::try_llama_pair(cfg, par) {
            Ok(p) => p,
            Err(e) => return Some(format!("build failed: {e}")),
        };
        let report = match quiet_session().verify(&pair) {
            Ok(r) => r,
            Err(e) => return Some(format!("verify errored: {e}")),
        };
        if !report.verified() {
            return Some(format!("unverified: {}", report.summary()));
        }
        let num = crate::baseline::numerical_verify(&pair, 1, 1e-3, 0xD1FF);
        if !num.equivalent {
            return Some(format!("numerics diverged by {}", num.max_dev));
        }
        None
    }

    /// Shrink a Llama config toward the minimal failing shape: fewer
    /// layers, then narrower dimensions (keeping head/ffn divisibility).
    fn shrink_llama(cfg: &LlamaConfig) -> Vec<LlamaConfig> {
        let mut out = Vec::new();
        if cfg.layers > 1 {
            out.push(LlamaConfig { layers: cfg.layers / 2, ..*cfg });
        }
        if cfg.heads > 2 && cfg.heads % 2 == 0 {
            out.push(LlamaConfig {
                heads: cfg.heads / 2,
                kv_heads: cfg.kv_heads.min(cfg.heads / 2),
                hidden: cfg.hidden / 2,
                ..*cfg
            });
        }
        if cfg.ffn > 4 && cfg.ffn % 2 == 0 {
            out.push(LlamaConfig { ffn: cfg.ffn / 2, ..*cfg });
        }
        if cfg.seqlen > 2 && cfg.seqlen % 2 == 0 {
            out.push(LlamaConfig { seqlen: cfg.seqlen / 2, ..*cfg });
        }
        out
    }

    /// Random (config, technique) grid: every engine-derived Llama variant
    /// must verify against its baseline and agree with the interpreter.
    /// Failures are shrunk to a minimal config before reporting.
    #[test]
    fn prop_engine_derived_llama_variants_verify() {
        check("transform-llama-grid", base_seed(0x7A11), case_count(6), |p| {
            let hd = [2i64, 4][p.range(0, 2)];
            let heads = [2i64, 4][p.range(0, 2)];
            let layers = 1 + p.range(0, 3) as u32;
            let tp = if heads == 4 { [2u32, 4][p.range(0, 2)] } else { 2 };
            // sometimes grouped-query attention: half the KV heads, when
            // the reduced count still divides the tensor-parallel degree
            let kv_heads = if p.chance(0.5) && (heads / 2) % tp as i64 == 0 {
                heads / 2
            } else {
                heads
            };
            let cfg = LlamaConfig {
                layers,
                hidden: heads * hd,
                heads,
                kv_heads,
                ffn: [4i64, 8][p.range(0, 2)],
                seqlen: [2i64, 4][p.range(0, 2)],
                batch: 1,
            };
            let par = match p.range(0, 4) {
                0 => Parallelism::Tensor { tp },
                1 => Parallelism::Sequence { tp },
                2 => Parallelism::Pipeline { pp: layers.min(2) },
                _ => Parallelism::Combined { pp: layers.min(2), tp },
            };
            // skip invalid combinations (divisibility) — the generator
            // aims at valid grids, try_llama_pair's validation is tested
            // elsewhere — and degenerate sequence shards of local extent 1
            if crate::modelgen::try_llama_pair(&cfg, par).is_err() {
                return Ok(());
            }
            if matches!(par, Parallelism::Sequence { .. }) && cfg.tokens() / tp as i64 < 2 {
                return Ok(());
            }
            if llama_engine_failure(&cfg, par).is_some() {
                let (min_cfg, msg) = minimize(
                    cfg,
                    |c| {
                        if crate::modelgen::try_llama_pair(c, par).is_err() {
                            return None; // invalid shrinks don't count
                        }
                        llama_engine_failure(c, par)
                    },
                    shrink_llama,
                );
                return Err(format!(
                    "{} on shrunk config {min_cfg:?}: {msg}",
                    par.label()
                ));
            }
            Ok(())
        });
    }

    /// Random dp/ZeRO grid over the training-step zoo: every derived pair
    /// verifies and agrees with the interpreter.
    #[test]
    fn prop_engine_derived_zero_variants_verify() {
        check("transform-zero-grid", base_seed(0x2E50), case_count(6), |p| {
            let dp = [2u32, 4][p.range(0, 2)];
            let cfg = TrainStepConfig {
                layers: 1 + p.range(0, 3) as u32,
                batch: dp as i64 * (2 + p.range(0, 2) as i64),
                hidden: [8i64, 16][p.range(0, 2)],
            };
            let zero_stage = p.range(0, 3) as u8;
            if zero_stage >= 1
                && (cfg.hidden % dp as i64 != 0 || cfg.hidden / dp as i64 < 2)
            {
                return Ok(());
            }
            let pair = dpstep_pair(&cfg, Parallelism::Data { dp, zero_stage });
            let report = quiet_session().verify(&pair).map_err(|e| e.to_string())?;
            if !report.verified() {
                return Err(format!("dp{dp}z{zero_stage} {cfg:?}: {}", report.summary()));
            }
            let num = crate::baseline::numerical_verify(&pair, 1, 1e-3, p.next_u64());
            if !num.equivalent {
                return Err(format!(
                    "dp{dp}z{zero_stage} {cfg:?}: numerics diverged by {}",
                    num.max_dev
                ));
            }
            Ok(())
        });
    }

    /// The indexed incremental e-matcher must be a pure optimization:
    /// across a random transform grid, verdicts, per-layer stop behavior
    /// and e-graph sizes are identical to the naive full-rescan matcher,
    /// and the indexed matcher never does *more* e-match work.
    #[test]
    fn prop_indexed_matcher_is_equivalent_to_naive() {
        use crate::egraph::{MatchMode, RunLimits};
        let cfg_for = |mode: MatchMode| VerifyConfig {
            parallel: false,
            memoize: false,
            limits: RunLimits { match_mode: mode, ..RunLimits::default() },
            ..VerifyConfig::default()
        };
        check("matcher-differential", base_seed(0x10D3), case_count(8), |p| {
            // half llama inference variants, half dp/ZeRO training steps
            let pair = if p.chance(0.5) {
                let heads = [2i64, 4][p.range(0, 2)];
                let tp = 2u32;
                let kv_heads =
                    if p.chance(0.5) && (heads / 2) % tp as i64 == 0 { heads / 2 } else { heads };
                let cfg = LlamaConfig {
                    layers: 1 + p.range(0, 3) as u32,
                    hidden: heads * [2i64, 4][p.range(0, 2)],
                    heads,
                    kv_heads,
                    ffn: [4i64, 8][p.range(0, 2)],
                    seqlen: [2i64, 4][p.range(0, 2)],
                    batch: 1,
                };
                let layers = cfg.layers;
                let par = match p.range(0, 4) {
                    0 => Parallelism::Tensor { tp },
                    1 => Parallelism::Sequence { tp },
                    2 => Parallelism::Pipeline { pp: layers.min(2) },
                    _ => Parallelism::Combined { pp: layers.min(2), tp },
                };
                match crate::modelgen::try_llama_pair(&cfg, par) {
                    Ok(pair) => pair,
                    Err(_) => return Ok(()), // invalid combo — not this property's job
                }
            } else {
                let dp = [2u32, 4][p.range(0, 2)];
                let cfg = TrainStepConfig {
                    layers: 1 + p.range(0, 3) as u32,
                    batch: dp as i64 * 2,
                    hidden: [8i64, 16][p.range(0, 2)],
                };
                let zero_stage = p.range(0, 3) as u8;
                if zero_stage >= 1 && (cfg.hidden % dp as i64 != 0 || cfg.hidden / dp as i64 < 2)
                {
                    return Ok(());
                }
                dpstep_pair(&cfg, Parallelism::Data { dp, zero_stage })
            };
            let indexed = Session::new(cfg_for(MatchMode::Indexed)).verify(&pair);
            let naive = Session::new(cfg_for(MatchMode::Naive)).verify(&pair);
            let (indexed, naive) = match (indexed, naive) {
                (Ok(a), Ok(b)) => (a, b),
                (Err(a), Err(b)) => {
                    if a.to_string() == b.to_string() {
                        return Ok(());
                    }
                    return Err(format!("error divergence: '{a}' vs '{b}'"));
                }
                (a, b) => {
                    return Err(format!(
                        "one matcher errored: indexed ok={} naive ok={}",
                        a.is_ok(),
                        b.is_ok()
                    ))
                }
            };
            if indexed.verdict.status() != naive.verdict.status() {
                return Err(format!(
                    "verdict divergence: indexed {} vs naive {}",
                    indexed.summary(),
                    naive.summary()
                ));
            }
            if indexed.layers.len() != naive.layers.len() {
                return Err("layer count divergence".into());
            }
            let mut tried_indexed = 0usize;
            let mut tried_naive = 0usize;
            for (a, b) in indexed.layers.iter().zip(&naive.layers) {
                if a.verified != b.verified {
                    return Err(format!("layer {} verdict divergence", a.layer));
                }
                if a.egraph_nodes != b.egraph_nodes || a.egraph_classes != b.egraph_classes {
                    return Err(format!(
                        "layer {} e-graph divergence: {}n/{}c vs {}n/{}c",
                        a.layer, a.egraph_nodes, a.egraph_classes, b.egraph_nodes,
                        b.egraph_classes
                    ));
                }
                tried_indexed += a.matches_tried;
                tried_naive += b.matches_tried;
            }
            if tried_indexed > tried_naive {
                return Err(format!(
                    "indexed matcher did MORE e-match work: {tried_indexed} vs {tried_naive}"
                ));
            }
            Ok(())
        });
    }

    /// Random pp×dp×tp mesh grid: every derived 3D-mesh pair (llama
    /// inference and training step) verifies with subgroup collectives
    /// and agrees with the lockstep interpreter.
    #[test]
    fn prop_engine_derived_mesh_variants_verify() {
        check("transform-mesh-grid", base_seed(0x3D3D), case_count(6), |p| {
            let dp = [1u32, 2][p.range(0, 2)];
            let tp = [2u32, 2, 4][p.range(0, 3)];
            let pp = [1u32, 2][p.range(0, 2)];
            if dp * tp < 2 {
                return Ok(());
            }
            let par = Parallelism::Mesh3D { pp, dp, tp };
            if p.chance(0.5) {
                let heads = tp.max(2) as i64;
                let cfg = LlamaConfig {
                    layers: pp.max(1) + p.range(0, 2) as u32,
                    hidden: heads * 2,
                    heads,
                    kv_heads: heads,
                    ffn: (tp as i64) * 2,
                    seqlen: [2i64, 4][p.range(0, 2)],
                    batch: 1,
                };
                if crate::modelgen::try_llama_pair(&cfg, par).is_err() {
                    return Ok(());
                }
                if let Some(msg) = llama_engine_failure(&cfg, par) {
                    return Err(format!("{} on {cfg:?}: {msg}", par.label()));
                }
            } else {
                let cfg = TrainStepConfig {
                    layers: 1 + p.range(0, 2) as u32,
                    batch: dp as i64 * 2,
                    hidden: (tp as i64) * 4,
                };
                let pair = match crate::modelgen::try_dpstep_pair(&cfg, par) {
                    Ok(pair) => pair,
                    Err(_) => return Ok(()),
                };
                let report = quiet_session().verify(&pair).map_err(|e| e.to_string())?;
                if !report.verified() {
                    return Err(format!(
                        "{} {cfg:?}: {}",
                        par.label(),
                        report.summary()
                    ));
                }
                let num = crate::baseline::numerical_verify(&pair, 1, 1e-3, p.next_u64());
                if !num.equivalent {
                    return Err(format!(
                        "{} {cfg:?}: numerics diverged by {}",
                        par.label(),
                        num.max_dev
                    ));
                }
            }
            Ok(())
        });
    }

    /// Differential: on random configs the engine-derived tensor/sequence
    /// graphs agree with the hand-built golden builders core-for-core.
    #[test]
    fn prop_engine_agrees_with_golden_builders() {
        use crate::interp::{run_spmd, Tensor};
        use crate::modelgen::llama::shard_inputs;
        check("transform-vs-golden", base_seed(0x601D), case_count(4), |p| {
            let heads = [2i64, 4][p.range(0, 2)];
            let cfg = LlamaConfig {
                layers: 1 + p.range(0, 2) as u32,
                hidden: heads * 2,
                heads,
                kv_heads: heads,
                ffn: 4,
                seqlen: [2i64, 4][p.range(0, 2)],
                batch: 1,
            };
            let par = if p.chance(0.5) {
                Parallelism::Tensor { tp: 2 }
            } else {
                Parallelism::Sequence { tp: 2 }
            };
            let engine = llama_pair(&cfg, par);
            let golden = golden_llama_pair(&cfg, par);
            let base_inputs: Vec<Tensor> = engine
                .base
                .parameters()
                .iter()
                .map(|&pid| Tensor::random(engine.base.node(pid).shape.clone(), p))
                .collect();
            let e_ins = shard_inputs(&engine, &base_inputs).map_err(|e| e.to_string())?;
            let g_ins = shard_inputs(&golden, &base_inputs).map_err(|e| e.to_string())?;
            let e_out = run_spmd(&engine.dist, &e_ins).map_err(|e| e.to_string())?;
            let g_out = run_spmd(&golden.dist, &g_ins).map_err(|e| e.to_string())?;
            for core in 0..engine.dist.num_cores as usize {
                let d = e_out[core][0].max_abs_diff(&g_out[core][0]);
                if d > 1e-4 {
                    return Err(format!(
                        "{} {cfg:?} core {core}: engine vs golden diverged by {d}",
                        par.label()
                    ));
                }
            }
            Ok(())
        });
    }

    /// Incremental re-verification is semantics-free on unchanged
    /// graphs: across a random transform grid, `verify_against` a
    /// just-captured state replays 100% of the layers and reproduces the
    /// cold verdict exactly (SCALIFY_PROPTEST_CASES widens the grid in
    /// the nightly run).
    #[test]
    fn prop_unchanged_reverify_reuses_every_layer() {
        check("incremental-full-reuse", base_seed(0xD1FF), case_count(6), |p| {
            let heads = [2i64, 4][p.range(0, 2)];
            let tp = 2u32;
            let kv_heads =
                if p.chance(0.5) && (heads / 2) % tp as i64 == 0 { heads / 2 } else { heads };
            let cfg = LlamaConfig {
                layers: 1 + p.range(0, 3) as u32,
                hidden: heads * [2i64, 4][p.range(0, 2)],
                heads,
                kv_heads,
                ffn: [4i64, 8][p.range(0, 2)],
                seqlen: [2i64, 4][p.range(0, 2)],
                batch: 1,
            };
            let layers = cfg.layers;
            let par = match p.range(0, 4) {
                0 => Parallelism::Tensor { tp },
                1 => Parallelism::Sequence { tp },
                2 => Parallelism::Pipeline { pp: layers.min(2) },
                _ => Parallelism::Combined { pp: layers.min(2), tp },
            };
            let pair = match crate::modelgen::try_llama_pair(&cfg, par) {
                Ok(pair) => pair,
                Err(_) => return Ok(()), // invalid combo — not this property's job
            };
            let (cold, state) =
                quiet_session().verify_capture(&pair).map_err(|e| e.to_string())?;
            let (warm, _) = quiet_session()
                .verify_against(&pair, &state)
                .map_err(|e| e.to_string())?;
            if cold.verified() != warm.verified() {
                return Err(format!(
                    "{} {cfg:?}: cold {} vs incremental {}",
                    par.label(),
                    cold.summary(),
                    warm.summary()
                ));
            }
            if cold.verified() {
                let reused = warm.layers.iter().filter(|l| l.reused).count();
                if reused != warm.layers.len() {
                    return Err(format!(
                        "{} {cfg:?}: unchanged graph reused {reused}/{} layers",
                        par.label(),
                        warm.layers.len()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn minimize_finds_a_local_minimum() {
        // property: fails iff n >= 10; shrinking from 64 by halving must
        // land on a minimal failing candidate along the halving chain
        let (min, msg) = minimize(
            64u32,
            |&n| if n >= 10 { Some(format!("{n} too big")) } else { None },
            |&n| vec![n / 2],
        );
        assert_eq!(min, 16, "{msg}"); // 64→32→16; 8 passes, so 16 is minimal
    }

    #[test]
    fn prop_verified_pairs_are_numerically_equivalent() {
        // soundness spot-check: whenever Scalify verifies a random demo
        // pair, the interpreter agrees
        use crate::baseline::numerical_verify;
        use crate::modelgen::demo::matmul_allreduce_pair;
        check("verify-implies-numerics", 0x5EED, 8, |p| {
            let tp = [2u32, 4][p.range(0, 2)];
            let pair = matmul_allreduce_pair(tp);
            let report = crate::verifier::Session::new(crate::verifier::VerifyConfig {
                parallel: false,
                ..Default::default()
            })
            .verify(&pair)
            .unwrap();
            if !report.verified() {
                return Err("demo pair must verify".into());
            }
            let num = numerical_verify(&pair, 2, 1e-4, p.next_u64());
            if !num.equivalent {
                return Err(format!("verified pair diverged numerically by {}", num.max_dev));
            }
            Ok(())
        });
    }
}
