//! Property-testing micro-framework (proptest is unavailable offline).
//!
//! Seeded generators + failure shrinking by re-running with recorded seeds.
//! Each property runs `cases` times with derived seeds; on failure the
//! minimal failing seed is reported so the case reproduces exactly.

use crate::util::Prng;

/// Run `prop` for `cases` generated inputs; panic with the failing seed.
pub fn check<F: FnMut(&mut Prng) -> Result<(), String>>(
    name: &str,
    base_seed: u64,
    cases: u64,
    mut prop: F,
) {
    for i in 0..cases {
        let seed = base_seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(i);
        let mut prng = Prng::new(seed);
        if let Err(msg) = prop(&mut prng) {
            panic!("property '{name}' failed (seed {seed}, case {i}): {msg}");
        }
    }
}

/// Generate a random small shape (rank 1..=3, dims 1..=6).
pub fn small_dims(p: &mut Prng) -> Vec<i64> {
    let rank = p.range(1, 4);
    (0..rank).map(|_| p.range(1, 7) as i64).collect()
}

/// Generate a random permutation of 0..n.
pub fn permutation(p: &mut Prng, n: usize) -> Vec<usize> {
    let mut perm: Vec<usize> = (0..n).collect();
    p.shuffle(&mut perm);
    perm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::{infer_bijection, AtomStore, AxisExpr};

    #[test]
    fn prop_bijection_roundtrip_random_layout_chains() {
        // any chain of grouping reshapes + transposes on both paths admits
        // a valid bijection (same atoms, each once) and check passes
        check("bijection-roundtrip", 0xB17, 200, |p| {
            let mut st = AtomStore::new();
            let dims = small_dims(p);
            let x = AxisExpr::from_shape(&mut st, &dims);
            let chain = |st: &mut AtomStore, mut e: AxisExpr, p: &mut Prng| {
                for _ in 0..p.range(0, 4) {
                    if p.chance(0.5) {
                        let perm = permutation(p, e.rank());
                        e = e.transpose(&perm).unwrap();
                    } else {
                        // merge all axes then split into a random grouping
                        let total = e.dims(st).iter().product::<i64>();
                        let mut parts = Vec::new();
                        let mut rem = total;
                        while rem > 1 && parts.len() < 3 {
                            let mut d = 1;
                            for cand in [2, 3, 4, 5] {
                                if rem % cand == 0 && p.chance(0.4) {
                                    d = cand;
                                    break;
                                }
                            }
                            parts.push(d);
                            rem /= d;
                        }
                        parts.push(rem);
                        if let Ok(r) = e.reshape(st, &parts) {
                            e = r;
                        }
                    }
                }
                e
            };
            let a = chain(&mut st, x.clone(), p);
            let b = chain(&mut st, x, p);
            match infer_bijection(&st, &a, &b) {
                Some(bij) => {
                    if !crate::layout::bijection_check(&st, &a, &b, &bij) {
                        return Err(format!("bijection failed check: {}", bij.describe()));
                    }
                    Ok(())
                }
                None => Err("no bijection for same-atom layouts".into()),
            }
        });
    }

    #[test]
    fn prop_printed_hlo_roundtrips_numerically() {
        use crate::hlo::{parse_hlo_module, print_hlo_module};
        use crate::interp::{run_single, Tensor};
        use crate::ir::{DType, GraphBuilder, ReduceKind, Shape};
        check("hlo-roundtrip-numerics", 0x4110, 60, |p| {
            let dims = vec![p.range(1, 5) as i64, p.range(1, 5) as i64];
            let mut b = GraphBuilder::new("rt", 1);
            let x = b.parameter("x", Shape::new(DType::F32, dims.clone()));
            let mut cur = x;
            for _ in 0..p.range(1, 5) {
                cur = match p.range(0, 5) {
                    0 => b.exp(cur),
                    1 => b.tanh(cur),
                    2 => b.neg(cur),
                    3 => {
                        let t = b.transpose(cur, vec![1, 0]);
                        b.transpose(t, vec![1, 0])
                    }
                    _ => b.abs(cur),
                };
            }
            let red = b.reduce(cur, ReduceKind::Add, vec![0, 1]);
            b.output(red);
            let g = b.finish();
            let xv = Tensor::random(Shape::new(DType::F32, dims), p);
            let before = run_single(&g, &[xv.clone()]).map_err(|e| e.to_string())?;
            let g2 = parse_hlo_module(&print_hlo_module(&g), 1).map_err(|e| e.to_string())?;
            let after = run_single(&g2, &[xv]).map_err(|e| e.to_string())?;
            let d = before[0].max_abs_diff(&after[0]);
            if d > 1e-9 {
                return Err(format!("roundtrip drift {d}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_union_find_congruence_random_merges() {
        use crate::egraph::{EGraph, ENode};
        use crate::ir::Op;
        check("egraph-congruence", 0xE6, 100, |p| {
            let mut eg = EGraph::new();
            let leaves: Vec<_> = (0..4)
                .map(|i| {
                    eg.add(ENode::new(
                        Op::Parameter { index: i, name: format!("p{i}") },
                        vec![],
                    ))
                })
                .collect();
            // unary towers over each leaf
            let towers: Vec<Vec<_>> = leaves
                .iter()
                .map(|&l| {
                    let mut t = vec![l];
                    for _ in 0..3 {
                        let top = *t.last().unwrap();
                        t.push(eg.add(ENode::new(Op::Neg, vec![top])));
                    }
                    t
                })
                .collect();
            // random leaf unions
            let a = p.range(0, 4);
            let b = p.range(0, 4);
            eg.union(leaves[a], leaves[b]);
            eg.rebuild();
            // congruence must lift to every tower level
            for lvl in 0..4 {
                if !eg.same(towers[a][lvl], towers[b][lvl]) {
                    return Err(format!("level {lvl} not congruent"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_verified_pairs_are_numerically_equivalent() {
        // soundness spot-check: whenever Scalify verifies a random demo
        // pair, the interpreter agrees
        use crate::baseline::numerical_verify;
        use crate::modelgen::demo::matmul_allreduce_pair;
        check("verify-implies-numerics", 0x5EED, 8, |p| {
            let tp = [2u32, 4][p.range(0, 2)];
            let pair = matmul_allreduce_pair(tp);
            let report = crate::verifier::Session::new(crate::verifier::VerifyConfig {
                parallel: false,
                ..Default::default()
            })
            .verify(&pair)
            .unwrap();
            if !report.verified() {
                return Err("demo pair must verify".into());
            }
            let num = numerical_verify(&pair, 2, 1e-4, p.next_u64());
            if !num.equivalent {
                return Err(format!("verified pair diverged numerically by {}", num.max_dev));
            }
            Ok(())
        });
    }
}
