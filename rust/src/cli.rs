//! CLI argument parsing and command plumbing for the `scalify` binary.
//!
//! Lives in the library (rather than `main.rs`) so the parsing rules are
//! unit-testable: every malformed input is a typed
//! [`ScalifyError::Config`] with a usage hint, never a panic.

use crate::error::{Result, ScalifyError};
use crate::modelgen::{
    try_llama_pair, try_mixtral_pair, GraphPair, LlamaConfig, MixtralConfig, Parallelism,
};
use crate::verifier::VerifyConfig;
use std::collections::HashMap;
use std::path::PathBuf;

/// Flags that never take a value, across all subcommands.
pub const BOOLEAN_FLAGS: &[&str] = &[
    "json",
    "new",
    "reproduced",
    "transform",
    "scale",
    "diff",
    "serve-load",
    "stream",
    "no-partition",
    "no-parallel",
    "no-memoize",
    "clear",
];

/// Parse `--flag value` / `--switch` argument lists.
///
/// A value-taking flag whose value is missing — or swallowed by the next
/// `--flag` — is a [`ScalifyError::Config`] with a usage hint, instead of
/// the silent mis-parse the one-shot CLI used to do.
pub fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let Some(key) = args[i].strip_prefix("--") else {
            return Err(ScalifyError::config(format!(
                "unexpected positional argument '{}' (flags are --key value; run `scalify` \
                 for usage)",
                args[i]
            )));
        };
        if key.is_empty() {
            return Err(ScalifyError::config("bare '--' is not a flag"));
        }
        if BOOLEAN_FLAGS.contains(&key) {
            flags.insert(key.to_string(), "true".to_string());
            i += 1;
            continue;
        }
        match args.get(i + 1) {
            Some(v) if !v.starts_with("--") => {
                flags.insert(key.to_string(), v.clone());
                i += 2;
            }
            _ => {
                return Err(ScalifyError::config(format!(
                    "flag --{key} requires a value (e.g. `--{key} <value>`); run `scalify` \
                     for usage"
                )));
            }
        }
    }
    Ok(flags)
}

/// Parse a parallelism spec: `tp32` / `sp8` / `fd4` / `ep8`, the pipeline
/// and data specs `pp4`, `dp4` / `dp4z2` (ZeRO stage suffix), the
/// combined `pp2tp4`, and the 3D-mesh specs `pp2dp2tp2` / `dp2tp2` /
/// `pp2dp4` (axes in pp-dp-tp order; omitted axes default to 1).
pub fn parallelism(spec: &str) -> Result<Parallelism> {
    let usage = "expected a technique + degree, e.g. tp32, sp32, fd32, ep8, pp4, \
                 dp4z1, pp2tp4 or pp2dp2tp2";
    let bad = |what: &str| {
        ScalifyError::config(format!("{what} in '{spec}' ({usage})"))
    };
    let parse_deg = |s: &str| -> Result<u32> {
        let deg: u32 = s.parse().map_err(|_| bad("bad parallelism degree"))?;
        if deg == 0 {
            return Err(bad("parallelism degree must be >= 1"));
        }
        Ok(deg)
    };
    // 3D mesh: any spec combining a dp component with pp and/or tp
    // (pp<A>dp<B>tp<C> with axes in that order; `dp4z1`-style ZeRO specs
    // have no pp/tp component and stay plain data parallelism)
    if let Some(dp_at) = spec.find("dp") {
        let has_pp = spec.starts_with("pp");
        let tp_at = spec[dp_at..].find("tp").map(|i| i + dp_at);
        if has_pp || tp_at.is_some() {
            let pp = if has_pp { parse_deg(&spec[2..dp_at])? } else { 1 };
            let dp_end = tp_at.unwrap_or(spec.len());
            let dp = parse_deg(&spec[dp_at + 2..dp_end])?;
            let tp = match tp_at {
                Some(at) => parse_deg(&spec[at + 2..])?,
                None => 1,
            };
            if !has_pp && dp_at != 0 {
                return Err(bad("unknown parallelism"));
            }
            return Ok(Parallelism::Mesh3D { pp, dp, tp });
        }
    }
    // combined pipeline × tensor: pp<A>tp<B>
    if let Some(rest) = spec.strip_prefix("pp") {
        if let Some(tp_at) = rest.find("tp") {
            let pp = parse_deg(&rest[..tp_at])?;
            let tp = parse_deg(&rest[tp_at + 2..])?;
            return Ok(Parallelism::Combined { pp, tp });
        }
    }
    // data parallelism with optional ZeRO stage: dp<N>[z<S>]
    if let Some(rest) = spec.strip_prefix("dp") {
        let (deg, zero) = match rest.find('z') {
            Some(at) => {
                let stage: u8 = rest[at + 1..]
                    .parse()
                    .map_err(|_| bad("bad ZeRO stage"))?;
                (&rest[..at], stage)
            }
            None => (rest, 0u8),
        };
        if zero > 2 {
            return Err(bad("ZeRO stage must be 0, 1 or 2"));
        }
        return Ok(Parallelism::Data { dp: parse_deg(deg)?, zero_stage: zero });
    }
    let (kind, deg): (&str, &str) = ["tp", "sp", "fd", "ep", "pp"]
        .iter()
        .find_map(|k| spec.strip_prefix(k).map(|rest| (*k, rest)))
        .ok_or_else(|| {
            ScalifyError::config(format!("unknown parallelism '{spec}' ({usage})"))
        })?;
    let deg = parse_deg(deg)?;
    Ok(match kind {
        "tp" => Parallelism::Tensor { tp: deg },
        "sp" => Parallelism::Sequence { tp: deg },
        "fd" => Parallelism::FlashDecoding { tp: deg },
        "pp" => Parallelism::Pipeline { pp: deg },
        _ => Parallelism::Expert { ep: deg },
    })
}

/// Known zoo models for `scalify model --model <name>`.
pub const KNOWN_MODELS: &[&str] = &[
    "llama-8b",
    "llama-70b",
    "llama-405b",
    "llama-405b-like",
    "llama-tiny",
    "llama-tiny-gqa",
    "mixtral-8x7b",
    "mixtral-8x22b",
    "mixtral-tiny",
    "dpstep-tiny",
    "dpstep-small",
];

/// Build the zoo pair named by the CLI, with typed validation errors.
pub fn model_pair(model: &str, par: Parallelism, layers: Option<u32>) -> Result<GraphPair> {
    let mk = |mut cfg: LlamaConfig| {
        if let Some(l) = layers {
            cfg.layers = l;
        }
        try_llama_pair(&cfg, par)
    };
    let mk_mix = |mut cfg: MixtralConfig| {
        if let Some(l) = layers {
            cfg.layers = l;
        }
        try_mixtral_pair(&cfg, par)
    };
    let mk_dp = |mut cfg: crate::modelgen::TrainStepConfig| {
        if let Some(l) = layers {
            cfg.layers = l;
        }
        crate::modelgen::try_dpstep_pair(&cfg, par)
    };
    match model {
        "llama-8b" => mk(LlamaConfig::llama3_8b()),
        "llama-70b" => mk(LlamaConfig::llama3_70b()),
        "llama-405b" => mk(LlamaConfig::llama3_405b()),
        "llama-405b-like" => mk(LlamaConfig::llama3_405b_like()),
        "llama-tiny" => mk(LlamaConfig::tiny()),
        "llama-tiny-gqa" => mk(LlamaConfig::tiny_gqa()),
        "mixtral-8x7b" => mk_mix(MixtralConfig::mixtral_8x7b()),
        "mixtral-8x22b" => mk_mix(MixtralConfig::mixtral_8x22b()),
        "mixtral-tiny" => mk_mix(MixtralConfig::tiny()),
        "dpstep-tiny" => mk_dp(crate::modelgen::TrainStepConfig::tiny()),
        "dpstep-small" => mk_dp(crate::modelgen::TrainStepConfig::small()),
        other => Err(ScalifyError::model_spec(format!(
            "unknown model '{other}' (known: {})",
            KNOWN_MODELS.join(", ")
        ))),
    }
}

/// Build a validated [`VerifyConfig`] from common CLI flags
/// (`--threads N`, `--memo-capacity N`, `--no-partition`, `--no-parallel`,
/// `--no-memoize`).
pub fn config_from_flags(flags: &HashMap<String, String>) -> Result<VerifyConfig> {
    let mut b = VerifyConfig::builder();
    if flags.contains_key("threads") {
        b = b.threads(usize_flag(flags, "threads", 1)?);
    }
    if flags.contains_key("memo-capacity") {
        b = b.memo_capacity(usize_flag(flags, "memo-capacity", 1)?);
    }
    if flags.contains_key("no-partition") {
        // whole-graph mode has a single task; parallel would be a no-op
        b = b.partition(false).parallel(false);
    }
    if flags.contains_key("no-parallel") {
        b = b.parallel(false);
    }
    if flags.contains_key("no-memoize") {
        b = b.memoize(false);
    }
    b.build()
}

/// Parse an optional positive-integer flag, with a default.
pub fn usize_flag(
    flags: &HashMap<String, String>,
    key: &str,
    default: usize,
) -> Result<usize> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => match v.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(n),
            _ => Err(ScalifyError::config(format!(
                "--{key} wants a positive integer, got '{v}'"
            ))),
        },
    }
}

/// Build a validated [`crate::service::ServeConfig`] from `scalify serve`
/// flags (`--addr`, `--cache-dir`, `--queue`, `--workers`, `--shards`,
/// plus the common verifier flags).
pub fn serve_config_from_flags(
    flags: &HashMap<String, String>,
) -> Result<crate::service::ServeConfig> {
    let mut cfg = crate::service::ServeConfig {
        verify: config_from_flags(flags)?,
        // the CLI default is a fixed well-known port (the library default
        // of port 0 is for tests); `--addr 127.0.0.1:0` still works for
        // scripting against an ephemeral port
        addr: "127.0.0.1:7878".into(),
        ..Default::default()
    };
    if let Some(addr) = flags.get("addr") {
        cfg.addr = addr.clone();
    }
    if let Some(dir) = flags.get("cache-dir") {
        cfg.cache_dir = Some(PathBuf::from(dir));
    }
    cfg.queue_capacity = usize_flag(flags, "queue", cfg.queue_capacity)?;
    cfg.workers = usize_flag(flags, "workers", cfg.workers)?;
    cfg.shards = usize_flag(flags, "shards", cfg.shards)?;
    Ok(cfg)
}

/// One `base dist [cores]` line of a batch manifest.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    /// Baseline HLO file.
    pub base: PathBuf,
    /// Distributed/optimized HLO file.
    pub dist: PathBuf,
    /// SPMD width of the distributed module.
    pub cores: u32,
}

/// Parse a batch manifest: one `base.hlo dist.hlo [cores]` per line,
/// `#`-comments and blank lines ignored.
pub fn parse_manifest(text: &str) -> Result<Vec<ManifestEntry>> {
    let mut entries = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split_whitespace().collect();
        let (base, dist, cores) = match fields.as_slice() {
            [b, d] => (*b, *d, 1),
            [b, d, c] => {
                let cores: u32 = c.parse().map_err(|_| {
                    ScalifyError::parse(format!(
                        "manifest line {}: bad core count '{c}'",
                        lineno + 1
                    ))
                })?;
                if cores == 0 {
                    return Err(ScalifyError::parse(format!(
                        "manifest line {}: core count must be >= 1",
                        lineno + 1
                    )));
                }
                (*b, *d, cores)
            }
            _ => {
                return Err(ScalifyError::parse(format!(
                    "manifest line {}: expected `base.hlo dist.hlo [cores]`, got '{line}'",
                    lineno + 1
                )))
            }
        };
        entries.push(ManifestEntry {
            base: PathBuf::from(base),
            dist: PathBuf::from(dist),
            cores,
        });
    }
    if entries.is_empty() {
        return Err(ScalifyError::parse(
            "manifest names no pairs (expected `base.hlo dist.hlo [cores]` lines)",
        ));
    }
    Ok(entries)
}

/// Process exit code for an error: usage/input problems exit 2, execution
/// failures exit 3 (verification *failure* exits 1, handled by commands).
pub fn exit_code_for(err: &ScalifyError) -> u8 {
    match err {
        ScalifyError::Runtime(_) => 3,
        _ => 2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_flags_values_and_switches() {
        let f = parse_flags(&args(&["--model", "llama-8b", "--json", "--par", "tp8"])).unwrap();
        assert_eq!(f.get("model").map(String::as_str), Some("llama-8b"));
        assert_eq!(f.get("json").map(String::as_str), Some("true"));
        assert_eq!(f.get("par").map(String::as_str), Some("tp8"));
    }

    #[test]
    fn parse_flags_missing_value_is_config_error() {
        // `--base --dist b.hlo` used to silently treat --base as a switch
        let err = parse_flags(&args(&["--base", "--dist", "b.hlo"])).unwrap_err();
        assert!(matches!(err, ScalifyError::Config(_)), "{err}");
        assert!(err.message().contains("--base"), "{err}");

        let err = parse_flags(&args(&["--cores"])).unwrap_err();
        assert!(matches!(err, ScalifyError::Config(_)), "{err}");
    }

    #[test]
    fn parse_flags_rejects_positional_junk() {
        let err = parse_flags(&args(&["llama-8b"])).unwrap_err();
        assert!(matches!(err, ScalifyError::Config(_)), "{err}");
    }

    #[test]
    fn parallelism_specs_parse() {
        assert_eq!(parallelism("tp32").unwrap(), Parallelism::Tensor { tp: 32 });
        assert_eq!(parallelism("sp8").unwrap(), Parallelism::Sequence { tp: 8 });
        assert_eq!(parallelism("fd4").unwrap(), Parallelism::FlashDecoding { tp: 4 });
        assert_eq!(parallelism("ep8").unwrap(), Parallelism::Expert { ep: 8 });
        assert_eq!(parallelism("pp4").unwrap(), Parallelism::Pipeline { pp: 4 });
        assert_eq!(parallelism("dp4").unwrap(), Parallelism::Data { dp: 4, zero_stage: 0 });
        assert_eq!(parallelism("dp8z2").unwrap(), Parallelism::Data { dp: 8, zero_stage: 2 });
        assert_eq!(parallelism("pp2tp4").unwrap(), Parallelism::Combined { pp: 2, tp: 4 });
    }

    #[test]
    fn mesh_parallelism_specs_parse() {
        assert_eq!(
            parallelism("pp2dp2tp2").unwrap(),
            Parallelism::Mesh3D { pp: 2, dp: 2, tp: 2 }
        );
        assert_eq!(
            parallelism("dp2tp2").unwrap(),
            Parallelism::Mesh3D { pp: 1, dp: 2, tp: 2 }
        );
        assert_eq!(
            parallelism("pp2dp4").unwrap(),
            Parallelism::Mesh3D { pp: 2, dp: 4, tp: 1 }
        );
        // ZeRO data specs are NOT mesh specs
        assert_eq!(parallelism("dp4z1").unwrap(), Parallelism::Data { dp: 4, zero_stage: 1 });
        // labels round-trip through the parser
        assert_eq!(
            parallelism(&Parallelism::Mesh3D { pp: 2, dp: 2, tp: 2 }.label()).unwrap(),
            Parallelism::Mesh3D { pp: 2, dp: 2, tp: 2 }
        );
        assert_eq!(
            parallelism(&Parallelism::Mesh3D { pp: 1, dp: 2, tp: 2 }.label()).unwrap(),
            Parallelism::Mesh3D { pp: 1, dp: 2, tp: 2 }
        );
        for bad in ["dp2tp", "ppdp2tp2", "pp2dp0tp2", "xxdp2tp2"] {
            assert!(parallelism(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn parallelism_rejects_malformed_specs() {
        // `tp` (no degree) and `x` (shorter than the prefix) both used to
        // panic via split_at(2)
        for bad in
            ["tp", "x", "", "zz8", "tp-3", "tp0", "ep1.5", "pp0", "dp4z9", "pptp2", "pp2tp"]
        {
            let err = parallelism(bad).unwrap_err();
            assert!(matches!(err, ScalifyError::Config(_)), "{bad}: {err}");
            assert!(err.message().contains("e.g. tp32"), "{bad}: {err}");
        }
    }

    #[test]
    fn dpstep_models_build_and_validate() {
        let pair =
            model_pair("dpstep-tiny", Parallelism::Data { dp: 2, zero_stage: 1 }, None).unwrap();
        assert_eq!(pair.dist.num_cores, 2);
        // the training-step zoo is data-parallel only
        let err = model_pair("dpstep-tiny", Parallelism::Tensor { tp: 2 }, None).unwrap_err();
        assert!(matches!(err, ScalifyError::ModelSpec(_)), "{err}");
        // and llama rejects data parallelism with a pointer at dpstep
        let err =
            model_pair("llama-tiny", Parallelism::Data { dp: 2, zero_stage: 0 }, None).unwrap_err();
        assert!(err.message().contains("dpstep"), "{err}");
    }

    #[test]
    fn model_pair_unknown_model_is_typed() {
        let err = model_pair("gpt-5", Parallelism::Tensor { tp: 2 }, None).unwrap_err();
        assert!(matches!(err, ScalifyError::ModelSpec(_)), "{err}");
        assert!(err.message().contains("llama-8b"));
    }

    #[test]
    fn model_pair_invalid_combination_is_typed() {
        // llama under expert parallelism used to panic in modelgen
        let err = model_pair("llama-tiny", Parallelism::Expert { ep: 4 }, None).unwrap_err();
        assert!(matches!(err, ScalifyError::ModelSpec(_)), "{err}");
        // mixtral under tensor parallelism likewise
        let err = model_pair("mixtral-8x7b", Parallelism::Tensor { tp: 8 }, None).unwrap_err();
        assert!(matches!(err, ScalifyError::ModelSpec(_)), "{err}");
    }

    #[test]
    fn model_pair_layers_override_applies() {
        let one = model_pair("llama-tiny", Parallelism::Tensor { tp: 2 }, Some(1)).unwrap();
        let two = model_pair("llama-tiny", Parallelism::Tensor { tp: 2 }, Some(2)).unwrap();
        assert!(two.total_nodes() > one.total_nodes());
    }

    #[test]
    fn config_from_flags_builds_and_validates() {
        let f = parse_flags(&args(&["--threads", "2", "--no-memoize"])).unwrap();
        let cfg = config_from_flags(&f).unwrap();
        assert_eq!(cfg.threads, 2);
        assert!(!cfg.memoize);

        let f = parse_flags(&args(&["--threads", "0"])).unwrap();
        assert!(matches!(config_from_flags(&f), Err(ScalifyError::Config(_))));

        let f = parse_flags(&args(&["--threads", "many"])).unwrap();
        assert!(matches!(config_from_flags(&f), Err(ScalifyError::Config(_))));

        // --no-partition implies sequential (parallel+no-partition is
        // rejected by the builder)
        let f = parse_flags(&args(&["--no-partition"])).unwrap();
        let cfg = config_from_flags(&f).unwrap();
        assert!(!cfg.partition && !cfg.parallel);
    }

    #[test]
    fn serve_config_from_flags_builds_and_validates() {
        let f = parse_flags(&args(&[
            "--addr",
            "127.0.0.1:7878",
            "--cache-dir",
            "/tmp/scalify-cache",
            "--queue",
            "16",
            "--workers",
            "3",
            "--shards",
            "4",
        ]))
        .unwrap();
        let cfg = serve_config_from_flags(&f).unwrap();
        assert_eq!(cfg.addr, "127.0.0.1:7878");
        assert_eq!(cfg.cache_dir, Some(PathBuf::from("/tmp/scalify-cache")));
        assert_eq!(cfg.queue_capacity, 16);
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.shards, 4);

        // defaults apply when flags are absent (the CLI pins the
        // well-known port; the library default stays ephemeral for tests)
        let cfg = serve_config_from_flags(&parse_flags(&args(&[])).unwrap()).unwrap();
        assert_eq!(cfg.addr, "127.0.0.1:7878");
        assert_eq!(cfg.cache_dir, None);
        assert_eq!(cfg.shards, 1, "one shard by default: the pre-fleet behavior");
        assert_eq!(crate::service::ServeConfig::default().addr, "127.0.0.1:0");

        // zero / junk are config errors
        for bad in [["--queue", "0"], ["--workers", "many"], ["--shards", "0"]] {
            let f = parse_flags(&args(&bad)).unwrap();
            assert!(matches!(
                serve_config_from_flags(&f),
                Err(ScalifyError::Config(_))
            ));
        }
    }

    #[test]
    fn memo_capacity_flag_threads_through() {
        let f = parse_flags(&args(&["--memo-capacity", "128"])).unwrap();
        assert_eq!(config_from_flags(&f).unwrap().memo_capacity, 128);
        let f = parse_flags(&args(&["--memo-capacity", "0"])).unwrap();
        assert!(matches!(config_from_flags(&f), Err(ScalifyError::Config(_))));
    }

    #[test]
    fn gqa_zoo_models_build() {
        // the 405B-class entry, clipped to 2 layers so the test stays fast
        let pair =
            model_pair("llama-405b-like", Parallelism::Tensor { tp: 8 }, Some(2)).unwrap();
        assert_eq!(pair.dist.num_cores, 8);
        let tiny = model_pair("llama-tiny-gqa", Parallelism::Tensor { tp: 2 }, None).unwrap();
        assert_eq!(tiny.dist.num_cores, 2);
        // tp must divide the KV heads, not just the query heads
        let err =
            model_pair("llama-tiny-gqa", Parallelism::Tensor { tp: 4 }, None).unwrap_err();
        assert!(err.message().contains("kv_heads"), "{err}");
    }

    #[test]
    fn mixtral_tiny_is_a_known_model() {
        let pair =
            model_pair("mixtral-tiny", Parallelism::Expert { ep: 4 }, None).unwrap();
        assert_eq!(pair.dist.num_cores, 4);
    }

    #[test]
    fn manifest_parses_and_reports_line_numbers() {
        let text = "# pairs\nbase.hlo dist.hlo 8\n\nsingle.hlo opt.hlo\n";
        let entries = parse_manifest(text).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].cores, 8);
        assert_eq!(entries[1].cores, 1);
        assert_eq!(entries[1].base, PathBuf::from("single.hlo"));

        let err = parse_manifest("a.hlo\n").unwrap_err();
        assert!(err.message().contains("line 1"), "{err}");
        let err = parse_manifest("a.hlo b.hlo zero\n").unwrap_err();
        assert!(err.message().contains("bad core count"), "{err}");
        assert!(parse_manifest("# only comments\n").is_err());
    }

    #[test]
    fn exit_codes_by_domain() {
        assert_eq!(exit_code_for(&ScalifyError::config("x")), 2);
        assert_eq!(exit_code_for(&ScalifyError::parse("x")), 2);
        assert_eq!(exit_code_for(&ScalifyError::model_spec("x")), 2);
        assert_eq!(exit_code_for(&ScalifyError::runtime("x")), 3);
    }
}
