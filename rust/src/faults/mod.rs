//! Deterministic fault injection.
//!
//! A process-wide registry of named injection points threaded through the
//! service stack: cache segment I/O (`cache-write`), worker-pool job
//! execution (`pool-job`), scheduler admission (`sched-admit`), shard
//! routing (`shard-route`), the verify job body (`shard-verify`), the
//! connection read/write path (`conn-read`, `conn-write`) and the
//! per-layer verify loop (`verify-layer`).
//!
//! Faults are installed from `SCALIFY_FAULTS=point:kind:rate:seed` (comma
//! separated) or at runtime via the daemon's `faults` protocol request.
//! Each point draws from its own seeded [`Prng`], so a given spec fires
//! on a reproducible subsequence of evaluations regardless of wall-clock
//! or thread interleaving at *other* points.
//!
//! When nothing is installed, [`fire`] is a single relaxed atomic load —
//! the same zero-cost-when-off discipline as `obs::trace`.

use crate::error::{Result, ScalifyError};
use crate::util::Prng;
use rustc_hash::FxHashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

/// Every injection point wired into the codebase. `install` rejects
/// unknown names so a typo in a chaos spec fails loudly instead of
/// silently injecting nothing.
pub const POINTS: &[&str] = &[
    "cache-write",
    "pool-job",
    "sched-admit",
    "shard-route",
    "shard-verify",
    "conn-read",
    "conn-write",
    "verify-layer",
];

/// What an armed injection point does when it fires.
///
/// Not every kind is meaningful at every point; sites interpret the
/// actions they understand and ignore the rest (documented per site).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// Panic at the injection site (exercises supervision / catch_unwind).
    Panic,
    /// Return a typed `ScalifyError::Runtime` from the site.
    Error,
    /// Sleep for the given duration before continuing.
    Delay(Duration),
    /// Drop the connection / skip the write (transport sites).
    Drop,
    /// Corrupt one byte of the buffer about to be written (cache site).
    Bitrot,
}

impl FaultKind {
    fn label(&self) -> String {
        match self {
            FaultKind::Panic => "panic".into(),
            FaultKind::Error => "error".into(),
            FaultKind::Delay(d) => format!("delay{}", d.as_millis()),
            FaultKind::Drop => "drop".into(),
            FaultKind::Bitrot => "bitrot".into(),
        }
    }
}

/// A fired fault, handed back to the injection site to act on. `noise`
/// is a per-fire random value sites can use for deterministic variation
/// (the cache site picks which byte to flip with it).
#[derive(Clone, Copy, Debug)]
pub struct FaultAction {
    /// The armed kind.
    pub kind: FaultKind,
    /// Per-fire draw from the point's PRNG.
    pub noise: u64,
}

struct FaultPoint {
    kind: FaultKind,
    rate: f64,
    seed: u64,
    prng: Prng,
    evaluated: u64,
    fired: u64,
}

/// Externally visible state of one armed point (the `faults` protocol
/// response and the CLI table).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultStatus {
    /// Injection-point name.
    pub point: String,
    /// Kind label as written in the spec (`panic`, `delay25`, ...).
    pub kind: String,
    /// Fire probability per evaluation.
    pub rate: f64,
    /// PRNG seed.
    pub seed: u64,
    /// Times the point was reached while armed.
    pub evaluated: u64,
    /// Times it actually fired.
    pub fired: u64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);

fn registry() -> MutexGuard<'static, FxHashMap<String, FaultPoint>> {
    static REGISTRY: OnceLock<Mutex<FxHashMap<String, FaultPoint>>> = OnceLock::new();
    REGISTRY
        .get_or_init(|| Mutex::new(FxHashMap::default()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

/// True when at least one fault is armed (one relaxed load).
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Evaluate the named point. Returns `None` on the fast path (nothing
/// armed, or the armed point's Bernoulli draw came up clean).
pub fn fire(point: &str) -> Option<FaultAction> {
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    let mut map = registry();
    let fp = map.get_mut(point)?;
    fp.evaluated += 1;
    if !fp.prng.chance(fp.rate) {
        return None;
    }
    fp.fired += 1;
    Some(FaultAction { kind: fp.kind, noise: fp.prng.next_u64() })
}

/// Evaluate the named point on a `Result` path: panics on `Panic`,
/// sleeps on `Delay`, returns a typed runtime error on `Error`.
/// `Drop`/`Bitrot` are not meaningful here and are ignored.
pub fn check(point: &str) -> Result<()> {
    match fire(point) {
        None => Ok(()),
        Some(a) => match a.kind {
            FaultKind::Panic => panic!("injected fault at {point}: panic"),
            FaultKind::Delay(d) => {
                std::thread::sleep(d);
                Ok(())
            }
            FaultKind::Error => Err(ScalifyError::runtime(format!(
                "retryable: injected fault at {point}"
            ))),
            FaultKind::Drop | FaultKind::Bitrot => Ok(()),
        },
    }
}

/// Evaluate the named point on an infallible path: panics on `Panic`,
/// sleeps on `Delay`, ignores everything else.
pub fn disturb(point: &str) {
    if let Some(a) = fire(point) {
        match a.kind {
            FaultKind::Panic => panic!("injected fault at {point}: panic"),
            FaultKind::Delay(d) => std::thread::sleep(d),
            _ => {}
        }
    }
}

fn parse_kind(s: &str) -> Result<FaultKind> {
    match s {
        "panic" => Ok(FaultKind::Panic),
        "error" => Ok(FaultKind::Error),
        "drop" => Ok(FaultKind::Drop),
        "bitrot" => Ok(FaultKind::Bitrot),
        _ => {
            if let Some(ms) = s.strip_prefix("delay") {
                let ms: u64 = if ms.is_empty() {
                    100
                } else {
                    ms.parse().map_err(|_| {
                        ScalifyError::config(format!("invalid delay in fault kind '{s}'"))
                    })?
                };
                Ok(FaultKind::Delay(Duration::from_millis(ms)))
            } else {
                Err(ScalifyError::config(format!(
                    "unknown fault kind '{s}' (expected panic, error, drop, bitrot or delayMS)"
                )))
            }
        }
    }
}

/// Install faults from a spec: comma-separated `point:kind:rate:seed`
/// entries, e.g. `shard-verify:panic:0.2:42,conn-write:drop:0.1:7`.
/// Replaces any previously armed point of the same name; other points
/// stay armed. An empty spec is a no-op.
pub fn install(spec: &str) -> Result<()> {
    let mut parsed = Vec::new();
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let parts: Vec<&str> = entry.split(':').collect();
        if parts.len() != 4 {
            return Err(ScalifyError::config(format!(
                "invalid fault entry '{entry}' (expected point:kind:rate:seed)"
            )));
        }
        let point = parts[0];
        if !POINTS.contains(&point) {
            return Err(ScalifyError::config(format!(
                "unknown fault point '{point}' (known: {})",
                POINTS.join(", ")
            )));
        }
        let kind = parse_kind(parts[1])?;
        let rate: f64 = parts[2].parse().map_err(|_| {
            ScalifyError::config(format!("invalid fault rate '{}' in '{entry}'", parts[2]))
        })?;
        if !(0.0..=1.0).contains(&rate) {
            return Err(ScalifyError::config(format!(
                "fault rate {rate} out of [0, 1] in '{entry}'"
            )));
        }
        let seed: u64 = parts[3].parse().map_err(|_| {
            ScalifyError::config(format!("invalid fault seed '{}' in '{entry}'", parts[3]))
        })?;
        parsed.push((point.to_string(), kind, rate, seed));
    }
    if parsed.is_empty() {
        return Ok(());
    }
    let mut map = registry();
    for (point, kind, rate, seed) in parsed {
        map.insert(
            point,
            FaultPoint { kind, rate, seed, prng: Prng::new(seed), evaluated: 0, fired: 0 },
        );
    }
    ENABLED.store(true, Ordering::Relaxed);
    Ok(())
}

/// Install faults from `SCALIFY_FAULTS`, if set. Invalid specs are a
/// config error so a typo'd chaos run fails at startup, not silently.
pub fn install_from_env() -> Result<()> {
    match std::env::var("SCALIFY_FAULTS") {
        Ok(spec) => install(&spec).map_err(|e| e.context("SCALIFY_FAULTS")),
        Err(_) => Ok(()),
    }
}

/// Disarm every point and restore the zero-cost fast path.
pub fn clear() {
    let mut map = registry();
    map.clear();
    ENABLED.store(false, Ordering::Relaxed);
}

/// Snapshot of every armed point, sorted by name for stable output.
pub fn snapshot() -> Vec<FaultStatus> {
    let map = registry();
    let mut out: Vec<FaultStatus> = map
        .iter()
        .map(|(point, fp)| FaultStatus {
            point: point.clone(),
            kind: fp.kind.label(),
            rate: fp.rate,
            seed: fp.seed,
            evaluated: fp.evaluated,
            fired: fp.fired,
        })
        .collect();
    out.sort_by(|a, b| a.point.cmp(&b.point));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-wide and other tests in this binary may
    // arm faults; every test here clears before and after and runs the
    // assertions under names it armed itself.

    #[test]
    fn disabled_registry_fires_nothing() {
        clear();
        assert!(!enabled());
        assert!(fire("cache-write").is_none());
        assert!(check("sched-admit").is_ok());
    }

    #[test]
    fn rate_one_always_fires_and_counts() {
        clear();
        install("conn-read:drop:1.0:7").unwrap();
        for _ in 0..5 {
            let a = fire("conn-read").expect("rate 1.0 must fire");
            assert_eq!(a.kind, FaultKind::Drop);
        }
        // unarmed points still pass through
        assert!(fire("conn-write").is_none());
        let snap = snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].point, "conn-read");
        assert_eq!(snap[0].evaluated, 5);
        assert_eq!(snap[0].fired, 5);
        clear();
        assert!(fire("conn-read").is_none());
    }

    #[test]
    fn same_seed_fires_the_same_subsequence() {
        clear();
        install("verify-layer:error:0.3:99").unwrap();
        let a: Vec<bool> = (0..64).map(|_| fire("verify-layer").is_some()).collect();
        clear();
        install("verify-layer:error:0.3:99").unwrap();
        let b: Vec<bool> = (0..64).map(|_| fire("verify-layer").is_some()).collect();
        clear();
        assert_eq!(a, b);
        assert!(a.iter().any(|f| *f));
        assert!(a.iter().any(|f| !*f));
    }

    #[test]
    fn error_kind_is_a_typed_retryable_runtime_error() {
        clear();
        install("sched-admit:error:1.0:1").unwrap();
        let e = check("sched-admit").unwrap_err();
        assert!(matches!(e, ScalifyError::Runtime(_)));
        assert!(e.message().starts_with("retryable: "));
        assert!(e.message().contains("sched-admit"));
        clear();
    }

    #[test]
    fn delay_kind_parses_with_and_without_millis() {
        assert_eq!(parse_kind("delay").unwrap(), FaultKind::Delay(Duration::from_millis(100)));
        assert_eq!(parse_kind("delay25").unwrap(), FaultKind::Delay(Duration::from_millis(25)));
        assert!(parse_kind("delayx").is_err());
    }

    #[test]
    fn bad_specs_are_config_errors() {
        clear();
        for spec in [
            "nope:panic:1.0:1",          // unknown point
            "cache-write:explode:1.0:1", // unknown kind
            "cache-write:panic:1.5:1",   // rate out of range
            "cache-write:panic:1.0",     // missing seed
            "cache-write:panic:x:1",     // bad rate
        ] {
            let e = install(spec).unwrap_err();
            assert!(matches!(e, ScalifyError::Config(_)), "{spec}: {e}");
        }
        assert!(!enabled(), "failed installs must not arm the registry");
        // a valid multi-entry spec arms every listed point
        install("cache-write:bitrot:1.0:3, conn-write:drop:0.5:4").unwrap();
        assert_eq!(snapshot().len(), 2);
        clear();
    }
}
