//! Graph evaluation: single-core and lockstep SPMD with collectives.

use super::Tensor;
use crate::ir::{CmpKind, ConstVal, Graph, Op, ReduceKind, Shape};

/// Evaluation failure.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EvalError {
    /// Wrong number of inputs supplied.
    InputCount {
        /// Parameters declared by the graph.
        expected: usize,
        /// Tensors supplied.
        got: usize,
    },
    /// Input tensor shape mismatch.
    InputShape {
        /// Parameter index.
        index: usize,
        /// Supplied dims.
        got: Vec<i64>,
        /// Declared dims.
        want: Vec<i64>,
    },
    /// An op the interpreter does not execute (e.g. `Custom`).
    Unsupported(String),
}

impl std::fmt::Display for EvalError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EvalError::InputCount { expected, got } => {
                write!(f, "expected {expected} inputs, got {got}")
            }
            EvalError::InputShape { index, got, want } => {
                write!(f, "input {index} has dims {got:?}, parameter wants {want:?}")
            }
            EvalError::Unsupported(op) => write!(f, "cannot interpret op '{op}'"),
        }
    }
}

impl std::error::Error for EvalError {}

fn reduce_apply(kind: ReduceKind, a: f64, b: f64) -> f64 {
    match kind {
        ReduceKind::Add => a + b,
        ReduceKind::Max => a.max(b),
        ReduceKind::Min => a.min(b),
        ReduceKind::Mul => a * b,
    }
}

fn reduce_identity(kind: ReduceKind) -> f64 {
    match kind {
        ReduceKind::Add => 0.0,
        ReduceKind::Max => f64::NEG_INFINITY,
        ReduceKind::Min => f64::INFINITY,
        ReduceKind::Mul => 1.0,
    }
}

/// Run a single-core graph (`num_cores` must be 1).
pub fn run_single(g: &Graph, inputs: &[Tensor]) -> Result<Vec<Tensor>, EvalError> {
    assert_eq!(g.num_cores, 1, "run_single needs a 1-core graph");
    let per_core = run_spmd(g, &[inputs.to_vec()])?;
    Ok(per_core.into_iter().next().unwrap())
}

/// Run an SPMD graph in lockstep across `g.num_cores` simulated cores.
///
/// `inputs[core][param_index]` supplies the per-core parameter values.
/// Returns `outputs[core][output_index]`.
pub fn run_spmd(g: &Graph, inputs: &[Vec<Tensor>]) -> Result<Vec<Vec<Tensor>>, EvalError> {
    let cores = g.num_cores as usize;
    assert_eq!(inputs.len(), cores, "need one input set per core");
    let params = g.parameters();
    for per_core in inputs {
        if per_core.len() != params.len() {
            return Err(EvalError::InputCount { expected: params.len(), got: per_core.len() });
        }
        for (i, (&pid, t)) in params.iter().zip(per_core.iter()).enumerate() {
            let want = &g.node(pid).shape.dims;
            if &t.shape.dims != want {
                return Err(EvalError::InputShape {
                    index: i,
                    got: t.shape.dims.clone(),
                    want: want.clone(),
                });
            }
        }
    }

    // values[node][core]; dead nodes (e.g. a stripped root tuple left in
    // the arena by the HLO parser) get placeholder scalars and are skipped.
    let live = g.live_set();
    let mut values: Vec<Vec<Tensor>> = Vec::with_capacity(g.len());
    for node in &g.nodes {
        if !live[node.id.idx()] {
            values.push(vec![
                Tensor::scalar(0.0, node.shape.dtype);
                cores
            ]);
            continue;
        }
        let per_core: Vec<Tensor> = match &node.op {
            // ---- collectives need simultaneous access to all cores ----
            Op::AllReduce { kind, groups } => {
                let src: Vec<&Tensor> =
                    (0..cores).map(|c| &values[node.inputs[0].idx()][c]).collect();
                (0..cores)
                    .map(|c| {
                        let group = groups
                            .group_of(c as u32)
                            .map(|s| s.to_vec())
                            .unwrap_or_else(|| vec![c as u32]);
                        let mut acc =
                            vec![reduce_identity(*kind); src[c].data.len()];
                        for &core in &group {
                            for (a, v) in acc.iter_mut().zip(&src[core as usize].data) {
                                *a = reduce_apply(*kind, *a, *v);
                            }
                        }
                        Tensor::new(src[c].shape.clone(), acc).quantize(node.shape.dtype)
                    })
                    .collect()
            }
            Op::AllGather { dim, groups } => {
                let src: Vec<&Tensor> =
                    (0..cores).map(|c| &values[node.inputs[0].idx()][c]).collect();
                (0..cores)
                    .map(|c| {
                        let group = groups
                            .group_of(c as u32)
                            .map(|s| s.to_vec())
                            .unwrap_or_else(|| vec![c as u32]);
                        let parts: Vec<Tensor> =
                            group.iter().map(|&g0| src[g0 as usize].clone()).collect();
                        Tensor::concat(&parts, *dim).quantize(node.shape.dtype)
                    })
                    .collect()
            }
            Op::ReduceScatter { kind, dim, groups } => {
                let src: Vec<&Tensor> =
                    (0..cores).map(|c| &values[node.inputs[0].idx()][c]).collect();
                (0..cores)
                    .map(|c| {
                        let group = groups
                            .group_of(c as u32)
                            .map(|s| s.to_vec())
                            .unwrap_or_else(|| vec![c as u32]);
                        let mut acc = vec![reduce_identity(*kind); src[c].data.len()];
                        for &core in &group {
                            for (a, v) in acc.iter_mut().zip(&src[core as usize].data) {
                                *a = reduce_apply(*kind, *a, *v);
                            }
                        }
                        let full = Tensor::new(src[c].shape.clone(), acc);
                        let rank_in_group =
                            group.iter().position(|&g0| g0 == c as u32).unwrap() as u32;
                        let parts = full.split(*dim, group.len() as u32);
                        parts[rank_in_group as usize].clone().quantize(node.shape.dtype)
                    })
                    .collect()
            }
            Op::AllToAll { split_dim, concat_dim, groups } => {
                let src: Vec<&Tensor> =
                    (0..cores).map(|c| &values[node.inputs[0].idx()][c]).collect();
                (0..cores)
                    .map(|c| {
                        let group = groups
                            .group_of(c as u32)
                            .map(|s| s.to_vec())
                            .unwrap_or_else(|| vec![c as u32]);
                        let my_rank = group.iter().position(|&g0| g0 == c as u32).unwrap();
                        // chunk `my_rank` of every peer, in group order
                        let parts: Vec<Tensor> = group
                            .iter()
                            .map(|&peer| {
                                src[peer as usize].split(*split_dim, group.len() as u32)
                                    [my_rank]
                                    .clone()
                            })
                            .collect();
                        Tensor::concat(&parts, *concat_dim).quantize(node.shape.dtype)
                    })
                    .collect()
            }
            // ---- everything else is per-core local ----
            _ => {
                let mut per_core = Vec::with_capacity(cores);
                for c in 0..cores {
                    let get = |i: usize| -> &Tensor { &values[node.inputs[i].idx()][c] };
                    let t = eval_local(g, node, c, inputs, &get)?;
                    per_core.push(t);
                }
                per_core
            }
        };
        values.push(per_core);
    }

    Ok((0..cores)
        .map(|c| g.outputs.iter().map(|o| values[o.idx()][c].clone()).collect())
        .collect())
}

/// Evaluate a non-collective node on one core.
fn eval_local<'a>(
    g: &Graph,
    node: &crate::ir::Node,
    core: usize,
    inputs: &[Vec<Tensor>],
    get: &dyn Fn(usize) -> &'a Tensor,
) -> Result<Tensor, EvalError> {
    let out_shape = node.shape.clone();
    let quant = |t: Tensor| t.quantize(out_shape.dtype);
    Ok(match &node.op {
        Op::Parameter { index, .. } => {
            let params = g.parameters();
            let pos = params.iter().position(|&p| p == node.id).unwrap();
            debug_assert_eq!(
                *index,
                match &g.node(params[pos]).op {
                    Op::Parameter { index, .. } => *index,
                    _ => unreachable!(),
                }
            );
            inputs[core][pos].clone().quantize(out_shape.dtype)
        }
        Op::Constant(c) => {
            let data = match c {
                ConstVal::Scalar(v) => vec![*v; out_shape.elements() as usize],
                ConstVal::Dense(vs) => vs.clone(),
            };
            quant(Tensor::new(out_shape.clone(), data))
        }
        Op::Iota { dim, .. } => {
            let mut data = Vec::with_capacity(out_shape.elements() as usize);
            for flat in 0..out_shape.elements() {
                let coords = out_shape.unflatten_index(flat);
                data.push(coords[*dim] as f64);
            }
            quant(Tensor::new(out_shape.clone(), data))
        }
        Op::Add => quant(binary(get(0), get(1), |a, b| a + b)),
        Op::Sub => quant(binary(get(0), get(1), |a, b| a - b)),
        Op::Mul => quant(binary(get(0), get(1), |a, b| a * b)),
        Op::Div => quant(binary(get(0), get(1), |a, b| a / b)),
        Op::Max => quant(binary(get(0), get(1), f64::max)),
        Op::Min => quant(binary(get(0), get(1), f64::min)),
        Op::Pow => quant(binary(get(0), get(1), f64::powf)),
        Op::Neg => quant(unary(get(0), |a| -a)),
        Op::Exp => quant(unary(get(0), f64::exp)),
        Op::Log => quant(unary(get(0), f64::ln)),
        Op::Tanh => quant(unary(get(0), f64::tanh)),
        Op::Rsqrt => quant(unary(get(0), |a| 1.0 / a.sqrt())),
        Op::Sqrt => quant(unary(get(0), f64::sqrt)),
        Op::Abs => quant(unary(get(0), f64::abs)),
        Op::Logistic => quant(unary(get(0), |a| 1.0 / (1.0 + (-a).exp()))),
        Op::Sin => quant(unary(get(0), f64::sin)),
        Op::Cos => quant(unary(get(0), f64::cos)),
        Op::Convert { to } => get(0).clone().quantize(*to),
        // send/recv relocate a tensor between pipeline stages; in the
        // lockstep simulation the value simply passes through
        Op::Send { .. } | Op::Recv { .. } => quant(get(0).clone()),
        Op::Compare(kind) => {
            let f = |a: f64, b: f64| -> f64 {
                let r = match kind {
                    CmpKind::Eq => a == b,
                    CmpKind::Ne => a != b,
                    CmpKind::Lt => a < b,
                    CmpKind::Le => a <= b,
                    CmpKind::Gt => a > b,
                    CmpKind::Ge => a >= b,
                };
                if r {
                    1.0
                } else {
                    0.0
                }
            };
            quant(binary(get(0), get(1), f))
        }
        Op::Select => {
            let p = get(0);
            let t = get(1);
            let f = get(2);
            let data = p
                .data
                .iter()
                .zip(t.data.iter().zip(&f.data))
                .map(|(&c, (&x, &y))| if c != 0.0 { x } else { y })
                .collect();
            quant(Tensor::new(t.shape.clone(), data))
        }
        Op::Dot { lhs_contract, rhs_contract, lhs_batch, rhs_batch } => quant(dot_general(
            get(0),
            get(1),
            lhs_contract,
            rhs_contract,
            lhs_batch,
            rhs_batch,
            &out_shape,
        )),
        Op::Reshape { .. } => quant(Tensor::new(out_shape.clone(), get(0).data.clone())),
        Op::Transpose { perm } => {
            let x = get(0);
            let mut data = Vec::with_capacity(out_shape.elements() as usize);
            for flat in 0..out_shape.elements() {
                let out_coords = out_shape.unflatten_index(flat);
                // output dim i = input dim perm[i]
                let mut in_coords = vec![0i64; perm.len()];
                for (i, &p) in perm.iter().enumerate() {
                    in_coords[p] = out_coords[i];
                }
                data.push(x.at(&in_coords));
            }
            quant(Tensor::new(out_shape.clone(), data))
        }
        Op::Slice { starts, limits: _, strides } => {
            let x = get(0);
            let mut data = Vec::with_capacity(out_shape.elements() as usize);
            for flat in 0..out_shape.elements() {
                let out_coords = out_shape.unflatten_index(flat);
                let in_coords: Vec<i64> = out_coords
                    .iter()
                    .zip(starts.iter().zip(strides))
                    .map(|(&c, (&s, &st))| s + c * st)
                    .collect();
                data.push(x.at(&in_coords));
            }
            quant(Tensor::new(out_shape.clone(), data))
        }
        Op::Concat { dim } => {
            let parts: Vec<Tensor> =
                (0..node.inputs.len()).map(|i| get(i).clone()).collect();
            quant(Tensor::concat(&parts, *dim))
        }
        Op::Broadcast { mapped, .. } => {
            let x = get(0);
            let mut data = Vec::with_capacity(out_shape.elements() as usize);
            for flat in 0..out_shape.elements() {
                let out_coords = out_shape.unflatten_index(flat);
                let in_coords: Vec<i64> = mapped.iter().map(|&m| out_coords[m]).collect();
                data.push(x.at(&in_coords));
            }
            quant(Tensor::new(out_shape.clone(), data))
        }
        Op::Reduce { kind, dims } => {
            let x = get(0);
            let mut acc =
                vec![reduce_identity(*kind); out_shape.elements() as usize];
            for flat in 0..x.shape.elements() {
                let coords = x.shape.unflatten_index(flat);
                let out_coords: Vec<i64> = coords
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| !dims.contains(i))
                    .map(|(_, &c)| c)
                    .collect();
                let oi = out_shape.flatten_index(&out_coords) as usize;
                acc[oi] = reduce_apply(*kind, acc[oi], x.data[flat as usize]);
            }
            quant(Tensor::new(out_shape.clone(), acc))
        }
        Op::Tuple | Op::GetTupleElement { .. } => {
            // tuples only appear as artifact entry wrappers; the verifier
            // strips them before interpretation.
            return Err(EvalError::Unsupported(node.op.name().to_owned()));
        }
        Op::Custom { name } => return Err(EvalError::Unsupported(name.clone())),
        Op::AllReduce { .. }
        | Op::AllGather { .. }
        | Op::ReduceScatter { .. }
        | Op::AllToAll { .. } => unreachable!("collectives handled by caller"),
    })
}

fn unary(x: &Tensor, f: impl Fn(f64) -> f64) -> Tensor {
    Tensor::new(x.shape.clone(), x.data.iter().map(|&v| f(v)).collect())
}

fn binary(a: &Tensor, b: &Tensor, f: impl Fn(f64, f64) -> f64) -> Tensor {
    // scalar broadcast on either side; otherwise shapes must match
    if a.shape.rank() == 0 && b.shape.rank() != 0 {
        return Tensor::new(b.shape.clone(), b.data.iter().map(|&v| f(a.data[0], v)).collect());
    }
    if b.shape.rank() == 0 && a.shape.rank() != 0 {
        return Tensor::new(a.shape.clone(), a.data.iter().map(|&v| f(v, b.data[0])).collect());
    }
    assert_eq!(a.shape.dims, b.shape.dims);
    Tensor::new(
        a.shape.clone(),
        a.data.iter().zip(&b.data).map(|(&x, &y)| f(x, y)).collect(),
    )
}

#[allow(clippy::too_many_arguments)]
fn dot_general(
    lhs: &Tensor,
    rhs: &Tensor,
    lhs_contract: &[usize],
    rhs_contract: &[usize],
    lhs_batch: &[usize],
    rhs_batch: &[usize],
    out_shape: &Shape,
) -> Tensor {
    let lhs_free: Vec<usize> = (0..lhs.shape.rank())
        .filter(|i| !lhs_contract.contains(i) && !lhs_batch.contains(i))
        .collect();
    let rhs_free: Vec<usize> = (0..rhs.shape.rank())
        .filter(|i| !rhs_contract.contains(i) && !rhs_batch.contains(i))
        .collect();
    let contract_sizes: Vec<i64> = lhs_contract.iter().map(|&d| lhs.shape.dims[d]).collect();
    let contract_total: i64 = contract_sizes.iter().product();
    let contract_shape = Shape::new(lhs.shape.dtype, contract_sizes);

    let mut data = Vec::with_capacity(out_shape.elements() as usize);
    for flat in 0..out_shape.elements() {
        let out_coords = out_shape.unflatten_index(flat);
        // out layout: batch dims, lhs free, rhs free
        let nb = lhs_batch.len();
        let nlf = lhs_free.len();
        let mut acc = 0.0f64;
        for k in 0..contract_total {
            let k_coords = contract_shape.unflatten_index(k);
            let mut lc = vec![0i64; lhs.shape.rank()];
            for (i, &d) in lhs_batch.iter().enumerate() {
                lc[d] = out_coords[i];
            }
            for (i, &d) in lhs_free.iter().enumerate() {
                lc[d] = out_coords[nb + i];
            }
            for (i, &d) in lhs_contract.iter().enumerate() {
                lc[d] = k_coords[i];
            }
            let mut rc = vec![0i64; rhs.shape.rank()];
            for (i, &d) in rhs_batch.iter().enumerate() {
                rc[d] = out_coords[i];
            }
            for (i, &d) in rhs_free.iter().enumerate() {
                rc[d] = out_coords[nb + nlf + i];
            }
            for (i, &d) in rhs_contract.iter().enumerate() {
                rc[d] = k_coords[i];
            }
            acc += lhs.at(&lc) * rhs.at(&rc);
        }
        data.push(acc);
    }
    Tensor::new(out_shape.clone(), data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DType, GraphBuilder, ReplicaGroups, Shape};
    use crate::util::Prng;

    fn f32s(dims: &[i64]) -> Shape {
        Shape::new(DType::F32, dims.to_vec())
    }

    #[test]
    fn matmul_matches_manual() {
        let mut b = GraphBuilder::new("t", 1);
        let x = b.parameter("x", f32s(&[2, 2]));
        let w = b.parameter("w", f32s(&[2, 2]));
        let y = b.matmul(x, w);
        b.output(y);
        let g = b.finish();
        let xv = Tensor::new(f32s(&[2, 2]), vec![1.0, 2.0, 3.0, 4.0]);
        let wv = Tensor::new(f32s(&[2, 2]), vec![1.0, 1.0, 1.0, 1.0]);
        let out = run_single(&g, &[xv, wv]).unwrap();
        assert_eq!(out[0].data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn sharded_matmul_allreduce_equals_baseline() {
        // baseline: Y = X[4,8] · W[8,4]
        let mut bb = GraphBuilder::new("base", 1);
        let x = bb.parameter("x", f32s(&[4, 8]));
        let w = bb.parameter("w", f32s(&[8, 4]));
        let y = bb.matmul(x, w);
        bb.output(y);
        let base = bb.finish();

        // distributed (2 cores): X sharded on dim1, W sharded on dim0,
        // local matmul + all-reduce
        let mut db = GraphBuilder::new("dist", 2);
        let xs = db.parameter("x_shard", f32s(&[4, 4]));
        let ws = db.parameter("w_shard", f32s(&[4, 4]));
        let part = db.matmul(xs, ws);
        let red = db.all_reduce(part, crate::ir::ReduceKind::Add, ReplicaGroups::full(2));
        db.output(red);
        let dist = db.finish();

        let mut p = Prng::new(5);
        let xv = Tensor::random(f32s(&[4, 8]), &mut p);
        let wv = Tensor::random(f32s(&[8, 4]), &mut p);
        let base_out = run_single(&base, &[xv.clone(), wv.clone()]).unwrap();

        let x_parts = xv.split(1, 2);
        let w_parts = wv.split(0, 2);
        let dist_out = run_spmd(
            &dist,
            &[
                vec![x_parts[0].clone(), w_parts[0].clone()],
                vec![x_parts[1].clone(), w_parts[1].clone()],
            ],
        )
        .unwrap();
        for core in 0..2 {
            assert!(
                base_out[0].max_abs_diff(&dist_out[core][0]) < 1e-5,
                "core {core} diverged"
            );
        }
    }

    #[test]
    fn all_gather_reassembles() {
        let mut db = GraphBuilder::new("d", 2);
        let xs = db.parameter("x", f32s(&[2, 2]));
        let ag = db.all_gather(xs, 0, ReplicaGroups::full(2));
        db.output(ag);
        let g = db.finish();
        let a = Tensor::new(f32s(&[2, 2]), vec![0.0, 1.0, 2.0, 3.0]);
        let b = Tensor::new(f32s(&[2, 2]), vec![4.0, 5.0, 6.0, 7.0]);
        let out = run_spmd(&g, &[vec![a], vec![b]]).unwrap();
        assert_eq!(out[0][0].data, (0..8).map(|v| v as f64).collect::<Vec<_>>());
        assert_eq!(out[0][0].data, out[1][0].data);
    }

    #[test]
    fn reduce_scatter_shards_the_sum() {
        let mut db = GraphBuilder::new("d", 2);
        let xs = db.parameter("x", f32s(&[4]));
        let rs = db.reduce_scatter(xs, crate::ir::ReduceKind::Add, 0, ReplicaGroups::full(2));
        db.output(rs);
        let g = db.finish();
        let a = Tensor::new(f32s(&[4]), vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(f32s(&[4]), vec![10.0, 20.0, 30.0, 40.0]);
        let out = run_spmd(&g, &[vec![a], vec![b]]).unwrap();
        assert_eq!(out[0][0].data, vec![11.0, 22.0]);
        assert_eq!(out[1][0].data, vec![33.0, 44.0]);
    }

    #[test]
    fn all_to_all_transposes_mesh() {
        let mut db = GraphBuilder::new("d", 2);
        let xs = db.parameter("x", f32s(&[2, 2]));
        let a2a = db.all_to_all(xs, 0, 1, ReplicaGroups::full(2));
        db.output(a2a);
        let g = db.finish();
        // core0 rows [r00, r01], core1 rows [r10, r11]
        let a = Tensor::new(f32s(&[2, 2]), vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(f32s(&[2, 2]), vec![5.0, 6.0, 7.0, 8.0]);
        let out = run_spmd(&g, &[vec![a], vec![b]]).unwrap();
        // core0 gets row0 of each, concat along dim1: [1,2,5,6]
        assert_eq!(out[0][0].shape.dims, vec![1, 4]);
        assert_eq!(out[0][0].data, vec![1.0, 2.0, 5.0, 6.0]);
        assert_eq!(out[1][0].data, vec![3.0, 4.0, 7.0, 8.0]);
    }

    #[test]
    fn softmax_decomposition_runs() {
        // softmax(x) via max/exp/sum ops — exercises reduce+broadcast+div
        let mut b = GraphBuilder::new("sm", 1);
        let x = b.parameter("x", f32s(&[2, 4]));
        let m = b.reduce(x, crate::ir::ReduceKind::Max, vec![1]);
        let mb = b.broadcast(m, vec![2, 4], vec![0]);
        let sh = b.sub(x, mb);
        let e = b.exp(sh);
        let s = b.reduce(e, crate::ir::ReduceKind::Add, vec![1]);
        let sb = b.broadcast(s, vec![2, 4], vec![0]);
        let sm = b.div(e, sb);
        b.output(sm);
        let g = b.finish();
        let xv = Tensor::new(f32s(&[2, 4]), vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
        let out = run_single(&g, &[xv]).unwrap();
        let row1: f64 = out[0].data[4..].iter().sum();
        assert!((row1 - 1.0).abs() < 1e-6);
        assert!((out[0].data[..4].iter().sum::<f64>() - 1.0).abs() < 1e-6);
        assert_eq!(out[0].data[4], 0.25);
    }

    #[test]
    fn precision_quantization_visible() {
        // bf16 convert loses bits that f32 path keeps
        let mut b = GraphBuilder::new("q", 1);
        let x = b.parameter("x", f32s(&[1]));
        let lo = b.convert(x, DType::BF16);
        let back = b.convert(lo, DType::F32);
        b.output(back);
        let g = b.finish();
        let v = 1.0 + 1.0 / 512.0;
        let out = run_single(&g, &[Tensor::new(f32s(&[1]), vec![v])]).unwrap();
        assert_ne!(out[0].data[0], v);
    }

    #[test]
    fn iota_and_slice() {
        let mut b = GraphBuilder::new("i", 1);
        let i = b.iota(Shape::new(DType::S32, vec![4]), 0);
        let s = b.slice_dim(i, 0, 1, 3);
        b.output(s);
        let g = b.finish();
        let out = run_single(&g, &[]).unwrap();
        assert_eq!(out[0].data, vec![1.0, 2.0]);
    }

    #[test]
    fn transpose_dot_general_batched() {
        let mut b = GraphBuilder::new("t", 1);
        let x = b.parameter("x", f32s(&[2, 3, 4]));
        let t = b.transpose(x, vec![0, 2, 1]);
        let y = b.matmul(x, t); // [2,3,4]·[2,4,3] -> [2,3,3]
        b.output(y);
        let g = b.finish();
        let mut p = Prng::new(7);
        let xv = Tensor::random(f32s(&[2, 3, 4]), &mut p);
        let out = run_single(&g, &[xv.clone()]).unwrap();
        assert_eq!(out[0].shape.dims, vec![2, 3, 3]);
        // diagonal entries are squared norms => non-negative
        for b0 in 0..2 {
            for i in 0..3 {
                assert!(out[0].at(&[b0, i, i]) >= 0.0);
            }
        }
    }

    /// Naive per-group references for the collectives, computed directly
    /// from the group lists with no shared code path — the oracle the
    /// lockstep interpreter is cross-checked against on subgroup shapes.
    mod naive {
        use crate::interp::Tensor;
        use crate::ir::ReplicaGroups;

        pub fn all_reduce(src: &[Tensor], groups: &ReplicaGroups) -> Vec<Tensor> {
            src.iter()
                .enumerate()
                .map(|(c, t)| {
                    let group = groups.group_of(c as u32).expect("covering groups");
                    let mut out = vec![0.0; t.data.len()];
                    for (i, slot) in out.iter_mut().enumerate() {
                        *slot = group.iter().map(|&g| src[g as usize].data[i]).sum();
                    }
                    Tensor::new(t.shape.clone(), out)
                })
                .collect()
        }

        pub fn all_gather(src: &[Tensor], dim: usize, groups: &ReplicaGroups) -> Vec<Tensor> {
            (0..src.len())
                .map(|c| {
                    let group = groups.group_of(c as u32).expect("covering groups");
                    let parts: Vec<Tensor> =
                        group.iter().map(|&g| src[g as usize].clone()).collect();
                    Tensor::concat(&parts, dim)
                })
                .collect()
        }

        pub fn reduce_scatter(
            src: &[Tensor],
            dim: usize,
            groups: &ReplicaGroups,
        ) -> Vec<Tensor> {
            let summed = all_reduce(src, groups);
            (0..src.len())
                .map(|c| {
                    let group = groups.group_of(c as u32).expect("covering groups");
                    let rank = group.iter().position(|&g| g == c as u32).unwrap();
                    summed[c].split(dim, group.len() as u32)[rank].clone()
                })
                .collect()
        }
    }

    /// Cross-check the lockstep interpreter's subgroup collectives against
    /// the naive per-group references, over both axis shapes of a [2,2]
    /// mesh (contiguous tp groups, strided dp groups) and a lopsided
    /// grouping.
    #[test]
    fn subgroup_collectives_match_naive_reference() {
        use crate::ir::GraphBuilder;
        use crate::util::Prng;
        let group_shapes: Vec<Vec<Vec<u32>>> = vec![
            vec![vec![0, 1], vec![2, 3]], // tp axis of [2,2]
            vec![vec![0, 2], vec![1, 3]], // dp axis of [2,2]
            vec![vec![0, 1, 2, 3]],       // full mesh
            vec![vec![0, 3], vec![1, 2]], // permuted (still a partition)
        ];
        let mut p = Prng::new(0x5AB);
        for groups in group_shapes {
            let rg = ReplicaGroups(groups);
            let src: Vec<Tensor> =
                (0..4).map(|_| Tensor::random(f32s(&[4, 4]), &mut p)).collect();

            // all-reduce
            let mut b = GraphBuilder::new("ar", 4);
            let x = b.parameter("x", f32s(&[4, 4]));
            let r = b.all_reduce(x, crate::ir::ReduceKind::Add, rg.clone());
            b.output(r);
            let g = b.finish();
            let ins: Vec<Vec<Tensor>> = src.iter().map(|t| vec![t.clone()]).collect();
            let got = run_spmd(&g, &ins).unwrap();
            let want = naive::all_reduce(&src, &rg);
            for c in 0..4 {
                assert!(
                    got[c][0].max_abs_diff(&want[c]) < 1e-9,
                    "all-reduce {rg:?} core {c}"
                );
            }

            // all-gather along dim 0
            let mut b = GraphBuilder::new("ag", 4);
            let x = b.parameter("x", f32s(&[4, 4]));
            let r = b.all_gather(x, 0, rg.clone());
            b.output(r);
            let g = b.finish();
            let got = run_spmd(&g, &ins).unwrap();
            let want = naive::all_gather(&src, 0, &rg);
            for c in 0..4 {
                assert!(
                    got[c][0].max_abs_diff(&want[c]) < 1e-9,
                    "all-gather {rg:?} core {c}"
                );
            }

            // reduce-scatter along dim 0
            let mut b = GraphBuilder::new("rs", 4);
            let x = b.parameter("x", f32s(&[4, 4]));
            let r = b.reduce_scatter(x, crate::ir::ReduceKind::Add, 0, rg.clone());
            b.output(r);
            let g = b.finish();
            let got = run_spmd(&g, &ins).unwrap();
            let want = naive::reduce_scatter(&src, 0, &rg);
            for c in 0..4 {
                assert!(
                    got[c][0].max_abs_diff(&want[c]) < 1e-9,
                    "reduce-scatter {rg:?} core {c}"
                );
            }
        }
    }

    #[test]
    fn partial_group_allreduce_only_reduces_group() {
        let mut db = GraphBuilder::new("d", 4);
        let xs = db.parameter("x", f32s(&[1]));
        let ar = db.all_reduce(xs, crate::ir::ReduceKind::Add, ReplicaGroups::split(4, 2));
        db.output(ar);
        let g = db.finish();
        let ins: Vec<Vec<Tensor>> =
            (0..4).map(|c| vec![Tensor::new(f32s(&[1]), vec![(c + 1) as f64])]).collect();
        let out = run_spmd(&g, &ins).unwrap();
        assert_eq!(out[0][0].data, vec![3.0]); // 1+2
        assert_eq!(out[2][0].data, vec![7.0]); // 3+4
    }
}
