//! Host tensor: row-major `f64` storage + shape, with dtype quantization.

use crate::ir::{DType, Shape};
use crate::util::Prng;

/// A concrete tensor value (row-major, f64 storage).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// Logical shape (dtype describes the *simulated* storage precision).
    pub shape: Shape,
    /// Row-major values.
    pub data: Vec<f64>,
}

impl Tensor {
    /// Construct, checking element count.
    pub fn new(shape: Shape, data: Vec<f64>) -> Tensor {
        assert_eq!(shape.elements() as usize, data.len(), "shape/data mismatch");
        Tensor { shape, data }
    }

    /// All-zeros tensor.
    pub fn zeros(shape: Shape) -> Tensor {
        let n = shape.elements() as usize;
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Scalar tensor.
    pub fn scalar(v: f64, dtype: DType) -> Tensor {
        Tensor { shape: Shape::scalar(dtype), data: vec![v] }
    }

    /// Random tensor in [-1, 1) from the deterministic PRNG.
    pub fn random(shape: Shape, prng: &mut Prng) -> Tensor {
        let n = shape.elements() as usize;
        let mut data = vec![0.0f64; n];
        for v in data.iter_mut() {
            *v = prng.unit_f32() as f64;
        }
        let mut t = Tensor { shape, data };
        t.quantize_in_place();
        t
    }

    /// Value at multi-dim coordinates.
    pub fn at(&self, coords: &[i64]) -> f64 {
        self.data[self.shape.flatten_index(coords) as usize]
    }

    /// Round every element to the storage precision of `dtype`.
    ///
    /// bf16/f16/f32 rounding is exact bit truncation via the corresponding
    /// Rust float casts; integers round-to-nearest; pred thresholds at 0.
    pub fn quantize(mut self, dtype: DType) -> Tensor {
        self.shape.dtype = dtype;
        self.quantize_in_place();
        self
    }

    fn quantize_in_place(&mut self) {
        match self.shape.dtype {
            DType::F64 => {}
            DType::F32 => {
                for v in self.data.iter_mut() {
                    *v = *v as f32 as f64;
                }
            }
            DType::F16 => {
                for v in self.data.iter_mut() {
                    *v = f16_round(*v);
                }
            }
            DType::BF16 => {
                for v in self.data.iter_mut() {
                    *v = bf16_round(*v);
                }
            }
            DType::S32 | DType::U32 | DType::S8 => {
                for v in self.data.iter_mut() {
                    *v = v.round();
                }
            }
            DType::Pred => {
                for v in self.data.iter_mut() {
                    *v = if *v != 0.0 { 1.0 } else { 0.0 };
                }
            }
        }
    }

    /// Max absolute difference against another tensor of the same shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape.dims, other.shape.dims, "shape mismatch in diff");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Split into `parts` equal chunks along `dim` (shard simulation).
    pub fn split(&self, dim: usize, parts: u32) -> Vec<Tensor> {
        let size = self.shape.dims[dim];
        assert_eq!(size % parts as i64, 0, "dim {dim} of size {size} not divisible by {parts}");
        let chunk = size / parts as i64;
        (0..parts as i64)
            .map(|p| self.slice_dim(dim, p * chunk, (p + 1) * chunk))
            .collect()
    }

    /// Contiguous slice along one dim.
    pub fn slice_dim(&self, dim: usize, start: i64, limit: i64) -> Tensor {
        let mut dims = self.shape.dims.clone();
        dims[dim] = limit - start;
        let out_shape = self.shape.with_dims(dims);
        let mut out = Vec::with_capacity(out_shape.elements() as usize);
        for flat in 0..out_shape.elements() {
            let mut coords = out_shape.unflatten_index(flat);
            coords[dim] += start;
            out.push(self.at(&coords));
        }
        Tensor::new(out_shape, out)
    }

    /// Concatenate tensors along `dim`.
    pub fn concat(parts: &[Tensor], dim: usize) -> Tensor {
        assert!(!parts.is_empty());
        let mut dims = parts[0].shape.dims.clone();
        dims[dim] = parts.iter().map(|t| t.shape.dims[dim]).sum();
        let out_shape = parts[0].shape.with_dims(dims);
        let mut out = Vec::with_capacity(out_shape.elements() as usize);
        for flat in 0..out_shape.elements() {
            let mut coords = out_shape.unflatten_index(flat);
            // find which part this coordinate falls into
            let mut offset = 0i64;
            let mut chosen = 0usize;
            for (i, p) in parts.iter().enumerate() {
                let sz = p.shape.dims[dim];
                if coords[dim] < offset + sz {
                    chosen = i;
                    break;
                }
                offset += sz;
            }
            coords[dim] -= offset;
            out.push(parts[chosen].at(&coords));
        }
        Tensor::new(out_shape, out)
    }
}

/// Round an f64 to the nearest bf16 value (round-to-nearest-even on the
/// f32 bit pattern).
pub fn bf16_round(v: f64) -> f64 {
    let bits = (v as f32).to_bits();
    // round-to-nearest-even at bit 16
    let rounded = bits.wrapping_add(0x7FFF + ((bits >> 16) & 1)) & 0xFFFF_0000;
    f32::from_bits(rounded) as f64
}

/// Round an f64 to the nearest f16 value.
pub fn f16_round(v: f64) -> f64 {
    // Minimal f16 emulation: clamp + quantize mantissa to 10 bits.
    let f = v as f32;
    if !f.is_finite() {
        return f as f64;
    }
    let max = 65504.0f32;
    let clamped = f.clamp(-max, max);
    if clamped == 0.0 {
        return 0.0;
    }
    let bits = clamped.to_bits();
    let exp = ((bits >> 23) & 0xFF) as i32 - 127;
    if exp < -14 {
        // subnormal-ish: quantize to multiples of 2^-24
        let q = (clamped / 2f32.powi(-24)).round() * 2f32.powi(-24);
        return q as f64;
    }
    // keep 10 mantissa bits (round-to-nearest-even at bit 13)
    let rounded = bits.wrapping_add(0xFFF + ((bits >> 13) & 1)) & 0xFFFF_E000;
    f32::from_bits(rounded) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(dims: &[i64], data: Vec<f64>) -> Tensor {
        Tensor::new(Shape::new(DType::F64, dims.to_vec()), data)
    }

    #[test]
    fn split_concat_roundtrip() {
        let x = t(&[4, 2], (0..8).map(|v| v as f64).collect());
        let parts = x.split(0, 2);
        assert_eq!(parts[0].data, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(parts[1].data, vec![4.0, 5.0, 6.0, 7.0]);
        let back = Tensor::concat(&parts, 0);
        assert_eq!(back.data, x.data);
    }

    #[test]
    fn split_concat_inner_dim() {
        let x = t(&[2, 4], (0..8).map(|v| v as f64).collect());
        let parts = x.split(1, 2);
        assert_eq!(parts[0].data, vec![0.0, 1.0, 4.0, 5.0]);
        let back = Tensor::concat(&parts, 1);
        assert_eq!(back.data, x.data);
    }

    #[test]
    fn bf16_loses_precision_f32_keeps_more() {
        let v = 1.0 + 1.0 / 512.0; // needs 9 mantissa bits
        assert_eq!(v as f32 as f64, v);
        assert_ne!(bf16_round(v), v); // bf16 has 7 bits
        let h = f16_round(v);
        assert_eq!(h, v); // f16 has 10 bits
    }

    #[test]
    fn quantize_pred() {
        let x = t(&[3], vec![0.0, 2.0, -1.0]).quantize(DType::Pred);
        assert_eq!(x.data, vec![0.0, 1.0, 1.0]);
    }

    #[test]
    fn max_abs_diff() {
        let a = t(&[2], vec![1.0, 2.0]);
        let b = t(&[2], vec![1.5, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }

    #[test]
    fn bf16_round_is_idempotent() {
        let mut p = crate::util::Prng::new(11);
        for _ in 0..1000 {
            let v = p.unit_f32() as f64 * 100.0;
            let r = bf16_round(v);
            assert_eq!(bf16_round(r), r);
        }
    }
}
