//! Reference interpreter for the tensor IR, including SPMD collectives.
//!
//! This is the *numerical* substrate of the reproduction: it executes
//! baseline graphs on one core and distributed graphs on a simulated core
//! mesh (lockstep SPMD, collectives exchanging values across cores). The
//! numerical-differential baseline verifier ([`crate::baseline`]) and the
//! differential tests of the model zoo are built on it.
//!
//! Values are computed in `f64` but **rounded to each node's element type
//! after every op** ([`Tensor::quantize`]) so precision-mismatch bugs
//! (paper bug category 3) show up numerically, exactly as they do on real
//! hardware.

mod tensor;
mod eval;

pub use eval::{run_single, run_spmd, EvalError};
pub use tensor::Tensor;
