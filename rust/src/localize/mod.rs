//! Discrepancy-based bug localization (paper §5.3).
//!
//! A bare "unverified" verdict is not actionable. After a failed layer
//! verification, the frontier analysis walks the distributed graph and
//! reports the nodes that *should* have related but didn't, **whose inputs
//! all did relate** — those are the first points where the two graphs'
//! semantics diverge, and their source metadata names the code to fix.

use crate::ir::{Graph, NodeId};

/// How precisely the report pins the bug (paper Table 4/5 legend).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LocPrecision {
    /// ▸ — the faulty instruction itself.
    Instruction,
    /// ★ — the faulty function / data structure.
    Function,
}

/// One localized discrepancy.
#[derive(Clone, Debug)]
pub struct Discrepancy {
    /// Distributed-graph node at the divergence frontier.
    pub dist_node: NodeId,
    /// `file:line` source site.
    pub site: String,
    /// Enclosing framework function.
    pub func: String,
    /// Operator name / expression text.
    pub expr: String,
    /// Why the verifier flagged it.
    pub reason: String,
    /// Layer the node belongs to.
    pub layer: Option<u32>,
}

impl Discrepancy {
    /// Build from a distributed-graph node plus a reason string.
    pub fn from_node(g: &Graph, id: NodeId, reason: impl Into<String>) -> Discrepancy {
        let n = g.node(id);
        Discrepancy {
            dist_node: id,
            site: g.source_site(id),
            func: g.interner.resolve(n.meta.func).to_owned(),
            expr: {
                let e = g.interner.resolve(n.meta.expr);
                if e.is_empty() {
                    n.op.name().to_owned()
                } else {
                    e.to_owned()
                }
            },
            reason: reason.into(),
            layer: n.meta.layer,
        }
    }

    /// One-line rendering for reports.
    pub fn render(&self) -> String {
        let site = if self.site.is_empty() { "<unknown site>" } else { &self.site };
        let func = if self.func.is_empty() { String::new() } else { format!(" in {}()", self.func) };
        format!("{site}{func}: {} — {}", self.expr, self.reason)
    }
}

/// Frontier analysis: from per-node relation status, keep the unverified
/// nodes **all of whose tensor inputs are verified** — the paper's rule
/// for turning a sea of unverified nodes into a handful of root causes.
///
/// `related[i]` says whether distributed node `i` ended up with any
/// relation. Nodes with no inputs (parameters, constants) are never
/// frontier candidates; dead nodes are skipped.
pub fn frontier(g: &Graph, related: &[bool]) -> Vec<NodeId> {
    let live = g.live_set();
    let mut out = Vec::new();
    for n in &g.nodes {
        if !live[n.id.idx()] || related[n.id.idx()] || n.inputs.is_empty() {
            continue;
        }
        let inputs_ok = n.inputs.iter().all(|i| {
            related[i.idx()]
                || g.node(*i).inputs.is_empty() && matches!(
                    g.node(*i).op,
                    crate::ir::Op::Constant(_) | crate::ir::Op::Iota { .. }
                )
        });
        if inputs_ok {
            out.push(n.id);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DType, GraphBuilder, Shape};

    #[test]
    fn frontier_picks_first_divergence_only() {
        let mut b = GraphBuilder::new("m", 1);
        b.at("mlp.py", 10).in_func("mlp_fwd");
        let x = b.parameter("x", Shape::new(DType::F32, vec![4]));
        b.at("mlp.py", 11);
        let e = b.exp(x); // diverges here
        b.at("mlp.py", 12);
        let n = b.neg(e); // downstream of the divergence
        b.output(n);
        let g = b.finish();
        // x related, e and n not
        let related = vec![true, false, false];
        let f = frontier(&g, &related);
        assert_eq!(f, vec![e]);
        let d = Discrepancy::from_node(&g, e, "no rule fired");
        assert_eq!(d.site, "mlp.py:11");
        assert_eq!(d.func, "mlp_fwd");
        assert!(d.render().contains("mlp.py:11"));
    }

    #[test]
    fn frontier_allows_constant_inputs() {
        let mut b = GraphBuilder::new("m", 1);
        let x = b.parameter("x", Shape::new(DType::F32, vec![2]));
        let c = b.constant(1.0, DType::F32);
        let bc = b.broadcast_scalar(c, vec![2]);
        let s = b.add(x, bc);
        b.output(s);
        let g = b.finish();
        // x related; c/bc/s not — bc's input is a constant, so bc is frontier
        let related = vec![true, false, false, false];
        let f = frontier(&g, &related);
        assert_eq!(f, vec![bc]);
    }

    #[test]
    fn verified_graph_has_empty_frontier() {
        let mut b = GraphBuilder::new("m", 1);
        let x = b.parameter("x", Shape::new(DType::F32, vec![2]));
        let e = b.exp(x);
        b.output(e);
        let g = b.finish();
        assert!(frontier(&g, &[true, true]).is_empty());
    }
}
