//! Model zoo: the "framework backend" of the reproduction.
//!
//! The paper generates its graphs from Transformers NeuronX / NeuronX
//! Distributed on Trainium. That stack is unavailable here, so this module
//! plays the instrumented framework: it emits baseline (single-device) and
//! distributed (SPMD) IR graphs for Llama-style dense and Mixtral-style
//! MoE transformers — plus a data-parallel training-step family — with
//! per-node source metadata and sharding annotations.
//!
//! Since the transform-engine refactor the distributed halves are
//! **derived**, not hand-written: the zoo builds the baseline graph and a
//! [`crate::transform::ParallelPlan`], and [`crate::transform::apply`]
//! mechanically produces the distributed graph (column/row sharding with
//! collective discharge, sequence-parallel gather/scatter sections,
//! pipeline stage splitting with send/recv boundaries, expert-loop
//! redistribution, data-parallel/ZeRO gradient and optimizer-state
//! collectives). The original hand-built builders remain as *golden
//! references* (`golden_llama_pair`, `golden_mixtral_pair`) for the
//! differential test harness; flash decoding restructures the softmax and
//! stays hand-built.

pub mod dpstep;
pub mod llama;
pub mod mixtral;
pub mod demo;

pub use crate::verifier::GraphPair;
pub use dpstep::{dpstep_pair, try_dpstep_pair, TrainStepConfig};
pub use llama::{golden_llama_pair, llama_pair, try_llama_pair, LlamaConfig};
pub use mixtral::{golden_mixtral_pair, mixtral_pair, try_mixtral_pair, MixtralConfig};

/// Parallelization technique of the distributed graph: the paper's four
/// SPMD techniques (§7.1) plus the pipeline / data-parallel scenarios the
/// transform engine derives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Parallelism {
    /// Megatron-style tensor parallelism: attention heads + MLP sharded.
    Tensor {
        /// TP degree (number of cores).
        tp: u32,
    },
    /// Tensor parallelism + sequence-parallel norm/residual sections.
    Sequence {
        /// TP degree.
        tp: u32,
    },
    /// Flash decoding: KV cache sharded along the sequence dimension,
    /// distributed two-pass softmax (max + sum all-reduces).
    FlashDecoding {
        /// KV-shard degree.
        tp: u32,
    },
    /// Expert parallelism (Mixtral): one expert group per core, baseline
    /// computes the unrolled expert sum.
    Expert {
        /// EP degree (== experts in our builder).
        ep: u32,
    },
    /// Pipeline parallelism: contiguous layer ranges per stage, boundary
    /// activations carried by send/recv pairs, stage ownership recorded in
    /// [`crate::ir::Meta::stage`].
    Pipeline {
        /// Stage count.
        pp: u32,
    },
    /// Data parallelism over the batch dimension with ZeRO-style
    /// optimizer-state partitioning of the training step.
    Data {
        /// Replica count.
        dp: u32,
        /// ZeRO stage: 0 = replicated states + gradient all-reduce,
        /// 1 = sharded optimizer states + gradient reduce-scatter,
        /// 2 = additionally sharded parameters (gathered on use).
        zero_stage: u8,
    },
    /// Pipeline × tensor parallelism: the tensor transform inside each
    /// stage, stage splitting on top. The SPMD width of the emitted graph
    /// is the per-stage tensor degree; stages ride as metadata.
    Combined {
        /// Stage count.
        pp: u32,
        /// Per-stage tensor degree.
        tp: u32,
    },
    /// Full 3D mesh: pipeline stages × a `[dp, tp]` SPMD mesh in ONE
    /// graph. The emitted graph is `dp·tp` cores wide with **subgroup**
    /// collectives — tp all-reduces over the contiguous tp groups, dp
    /// gradient all-reduces over the strided dp groups — and pipeline
    /// stages carried as metadata + send/recv boundaries, exactly the
    /// production pp×dp×tp shape the paper's Llama-405B runs use. For
    /// inference zoo models the dp axis replicates (pure data-parallel
    /// serving); for the training-step zoo it batch-shards with dp-group
    /// gradient reduction.
    Mesh3D {
        /// Stage count (1 = no pipeline splitting).
        pp: u32,
        /// Data-parallel axis size (mesh axis 0, slow).
        dp: u32,
        /// Tensor-parallel axis size (mesh axis 1, fast).
        tp: u32,
    },
}

impl Parallelism {
    /// SPMD width of the distributed graph (the per-stage width for
    /// combined pipeline×tensor plans; see [`Parallelism::total_devices`]
    /// for the full mesh size).
    pub fn cores(&self) -> u32 {
        match self {
            Parallelism::Tensor { tp }
            | Parallelism::Sequence { tp }
            | Parallelism::FlashDecoding { tp }
            | Parallelism::Combined { tp, .. } => *tp,
            Parallelism::Expert { ep } => *ep,
            Parallelism::Pipeline { pp } => *pp,
            Parallelism::Data { dp, .. } => *dp,
            Parallelism::Mesh3D { dp, tp, .. } => dp * tp,
        }
    }

    /// Total devices the plan occupies (stages × per-stage width).
    pub fn total_devices(&self) -> u32 {
        match self {
            Parallelism::Combined { pp, tp } => pp * tp,
            Parallelism::Mesh3D { pp, dp, tp } => pp * dp * tp,
            other => other.cores(),
        }
    }

    /// Short label for reports.
    pub fn label(&self) -> String {
        match self {
            Parallelism::Tensor { tp } => format!("tp{tp}"),
            Parallelism::Sequence { tp } => format!("sp{tp}"),
            Parallelism::FlashDecoding { tp } => format!("fd{tp}"),
            Parallelism::Expert { ep } => format!("ep{ep}"),
            Parallelism::Pipeline { pp } => format!("pp{pp}"),
            Parallelism::Data { dp, zero_stage } => format!("dp{dp}z{zero_stage}"),
            Parallelism::Combined { pp, tp } => format!("pp{pp}tp{tp}"),
            Parallelism::Mesh3D { pp, dp, tp } => {
                // canonical spec form: pp omitted when 1 (`dp2tp2`)
                if *pp == 1 {
                    format!("dp{dp}tp{tp}")
                } else {
                    format!("pp{pp}dp{dp}tp{tp}")
                }
            }
        }
    }

    /// SPMD mesh axes of the emitted distributed graph (empty = flat).
    /// Only mesh plans declare axes; the pipeline factor is not an SPMD
    /// axis (stages are metadata).
    pub fn mesh_axes(&self) -> Vec<u32> {
        match self {
            Parallelism::Mesh3D { dp, tp, .. } => vec![*dp, *tp],
            _ => Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests;
