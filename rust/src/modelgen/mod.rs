//! Model zoo: the "framework backend" of the reproduction.
//!
//! The paper generates its graphs from Transformers NeuronX / NeuronX
//! Distributed on Trainium. That stack is unavailable here, so this module
//! plays the instrumented framework: it emits baseline (single-device) and
//! distributed (SPMD) IR graphs for Llama-style dense and Mixtral-style
//! MoE transformers under the paper's four parallelization techniques —
//! tensor parallelism, sequence parallelism, expert parallelism and flash
//! decoding — with per-node source metadata and sharding annotations, the
//! same structural patterns the NeuronX compiler emits (column/row-sharded
//! projections, partial products discharged by collectives, BSH
//! reshape–transpose output layout, unrolled expert loops).

pub mod llama;
mod mixtral;
pub mod demo;

pub use crate::verifier::GraphPair;
pub use llama::{llama_pair, try_llama_pair, LlamaConfig};
pub use mixtral::{mixtral_pair, try_mixtral_pair, MixtralConfig};

/// Parallelization technique of the distributed graph (§7.1: the four
/// techniques the paper evaluates).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Parallelism {
    /// Megatron-style tensor parallelism: attention heads + MLP sharded.
    Tensor {
        /// TP degree (number of cores).
        tp: u32,
    },
    /// Tensor parallelism + sequence-parallel norm/residual sections.
    Sequence {
        /// TP degree.
        tp: u32,
    },
    /// Flash decoding: KV cache sharded along the sequence dimension,
    /// distributed two-pass softmax (max + sum all-reduces).
    FlashDecoding {
        /// KV-shard degree.
        tp: u32,
    },
    /// Expert parallelism (Mixtral): one expert group per core, baseline
    /// computes the unrolled expert sum.
    Expert {
        /// EP degree (== experts in our builder).
        ep: u32,
    },
}

impl Parallelism {
    /// Core count of the distributed graph.
    pub fn cores(&self) -> u32 {
        match self {
            Parallelism::Tensor { tp }
            | Parallelism::Sequence { tp }
            | Parallelism::FlashDecoding { tp } => *tp,
            Parallelism::Expert { ep } => *ep,
        }
    }

    /// Short label for reports.
    pub fn label(&self) -> String {
        match self {
            Parallelism::Tensor { tp } => format!("tp{tp}"),
            Parallelism::Sequence { tp } => format!("sp{tp}"),
            Parallelism::FlashDecoding { tp } => format!("fd{tp}"),
            Parallelism::Expert { ep } => format!("ep{ep}"),
        }
    }
}

#[cfg(test)]
mod tests;
