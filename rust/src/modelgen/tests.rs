//! Model-zoo tests: every generated pair must (a) numerically agree under
//! the SPMD interpreter and (b) verify with Scalify. This is the strongest
//! evidence the reproduction's graphs mean what they claim.

use super::*;
use crate::interp::{run_single, run_spmd, Tensor};
use crate::modelgen::llama::shard_inputs;
use crate::util::Prng;
use crate::verifier::{Session, VerifyConfig};

fn cfg_seq() -> VerifyConfig {
    VerifyConfig { parallel: false, ..VerifyConfig::default() }
}

/// Interpreter differential: baseline vs every core of the SPMD run.
fn assert_numerically_equivalent(pair: &GraphPair, tol: f64, seed: u64) {
    let mut p = Prng::new(seed);
    let base_inputs: Vec<Tensor> = pair
        .base
        .parameters()
        .iter()
        .map(|&pid| Tensor::random(pair.base.node(pid).shape.clone(), &mut p))
        .collect();
    let base_out = run_single(&pair.base, &base_inputs).unwrap();
    let dist_inputs = shard_inputs(pair, &base_inputs).unwrap();
    let dist_out = run_spmd(&pair.dist, &dist_inputs).unwrap();
    for core in 0..pair.dist.num_cores as usize {
        assert_eq!(base_out.len(), dist_out[core].len(), "output arity mismatch");
        for (k, (b, d)) in base_out.iter().zip(&dist_out[core]).enumerate() {
            let diff = b.max_abs_diff(d);
            assert!(diff < tol, "core {core} output {k} diverged by {diff}");
        }
    }
}

#[test]
fn llama_tp_tiny_numerics_match() {
    let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::Tensor { tp: 2 });
    assert_numerically_equivalent(&pair, 1e-4, 11);
}

#[test]
fn llama_tp_tiny_verifies() {
    let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::Tensor { tp: 2 });
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(report.verified(), "{}", render_failure(&report));
}

#[test]
fn llama_sp_tiny_numerics_match() {
    let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::Sequence { tp: 2 });
    assert_numerically_equivalent(&pair, 1e-4, 13);
}

#[test]
fn llama_sp_tiny_verifies() {
    let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::Sequence { tp: 2 });
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(report.verified(), "{}", render_failure(&report));
}

#[test]
fn llama_gqa_baseline_numerics_match() {
    // GQA expansion sanity: single-device pair (tp1 = identity transform)
    let pair = llama_pair(&LlamaConfig::tiny_gqa(), Parallelism::Tensor { tp: 1 });
    assert_numerically_equivalent(&pair, 1e-4, 29);
}

#[test]
fn llama_gqa_tp_tiny_numerics_match() {
    // tp2 over 2 KV heads: one KV head per core, 2 query heads per core
    let pair = llama_pair(&LlamaConfig::tiny_gqa(), Parallelism::Tensor { tp: 2 });
    assert_numerically_equivalent(&pair, 1e-4, 31);
}

#[test]
fn llama_gqa_tp_tiny_verifies() {
    let pair = llama_pair(&LlamaConfig::tiny_gqa(), Parallelism::Tensor { tp: 2 });
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(report.verified(), "{}", render_failure(&report));
}

#[test]
fn llama_gqa_validation_rejects_bad_combos() {
    // kv_heads must divide heads
    let bad = LlamaConfig { kv_heads: 3, ..LlamaConfig::tiny_gqa() };
    assert!(try_llama_pair(&bad, Parallelism::Tensor { tp: 2 }).is_err());
    // tp must divide kv_heads (4 query heads would split, 2 KV heads not)
    assert!(try_llama_pair(&LlamaConfig::tiny_gqa(), Parallelism::Tensor { tp: 4 }).is_err());
    // flash decoding stays MHA-only
    assert!(try_llama_pair(&LlamaConfig::tiny_gqa(), Parallelism::FlashDecoding { tp: 2 })
        .is_err());
}

#[test]
fn flash_decoding_tiny_numerics_match() {
    let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::FlashDecoding { tp: 2 });
    assert_numerically_equivalent(&pair, 1e-4, 17);
}

#[test]
fn flash_decoding_tiny_verifies() {
    let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::FlashDecoding { tp: 2 });
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(report.verified(), "{}", render_failure(&report));
}

#[test]
fn mixtral_ep_tiny_numerics_match() {
    let pair = mixtral_pair(&MixtralConfig::tiny(), Parallelism::Expert { ep: 4 });
    assert_numerically_equivalent(&pair, 1e-4, 19);
}

#[test]
fn mixtral_ep_tiny_verifies() {
    let pair = mixtral_pair(&MixtralConfig::tiny(), Parallelism::Expert { ep: 4 });
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(report.verified(), "{}", render_failure(&report));
}

#[test]
fn demo_pairs_behave() {
    let good = demo::matmul_allreduce_pair(4);
    assert_numerically_equivalent(&good, 1e-4, 23);
    assert!(Session::new(cfg_seq()).verify(&good).unwrap().verified());

    let bsh_ok = demo::bsh_pair(false);
    assert!(Session::new(cfg_seq()).verify(&bsh_ok).unwrap().verified());
    let bsh_bug = demo::bsh_pair(true);
    assert!(!Session::new(cfg_seq()).verify(&bsh_bug).unwrap().verified());

    let mb_ok = demo::microbatch_pair(false);
    assert_numerically_equivalent(&mb_ok, 1e-4, 59);
    assert!(Session::new(cfg_seq()).verify(&mb_ok).unwrap().verified());
    let mb_bug = demo::microbatch_pair(true);
    assert!(!Session::new(cfg_seq()).verify(&mb_bug).unwrap().verified());
}

#[test]
fn graphs_validate_and_have_metadata() {
    let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::Tensor { tp: 2 });
    pair.base.validate().unwrap();
    pair.dist.validate().unwrap();
    // every live node inside a layer carries a source site
    let live = pair.dist.live_set();
    let tagged = pair
        .dist
        .nodes
        .iter()
        .filter(|n| live[n.id.idx()] && n.meta.layer.is_some())
        .filter(|n| !pair.dist.source_site(n.id).is_empty())
        .count();
    let total = pair
        .dist
        .nodes
        .iter()
        .filter(|n| live[n.id.idx()] && n.meta.layer.is_some())
        .count();
    assert_eq!(tagged, total, "all layer nodes must carry source sites");
}

#[test]
fn multi_layer_memoizes() {
    let cfg = LlamaConfig { layers: 4, ..LlamaConfig::tiny() };
    let pair = llama_pair(&cfg, Parallelism::Tensor { tp: 2 });
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(report.verified(), "{}", render_failure(&report));
    let memoized = report.layers.iter().filter(|l| l.memoized).count();
    assert!(memoized >= 3, "identical decoder layers should memoize, got {memoized}");
}

fn render_failure(report: &crate::verifier::VerifyReport) -> String {
    let mut s = report.summary();
    for d in report.discrepancies() {
        s.push('\n');
        s.push_str(&d.render());
    }
    s
}

// ---- transform-engine scenarios (pipeline, data/ZeRO, combined) ----

#[test]
fn llama_pipeline_tiny_verifies_and_matches_numerically() {
    let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::Pipeline { pp: 2 });
    assert!(pair.dist.nodes.iter().any(|n| n.op.name() == "send"));
    assert!(pair.dist.nodes.iter().any(|n| n.op.name() == "recv"));
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(report.verified(), "{}", render_failure(&report));
    // stage ownership surfaces in the per-layer report
    assert!(report.layers.iter().any(|l| l.stage == Some(0)));
    assert!(report.layers.iter().any(|l| l.stage == Some(1)));
    assert_numerically_equivalent(&pair, 1e-4, 29);
}

#[test]
fn llama_combined_pipeline_tensor_verifies() {
    let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::Combined { pp: 2, tp: 2 });
    assert_eq!(pair.dist.num_cores, 2, "SPMD width is the per-stage tp degree");
    assert!(pair.dist.nodes.iter().any(|n| n.op.name() == "send"));
    assert!(pair.dist.nodes.iter().any(|n| n.op.name() == "all-reduce"));
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(report.verified(), "{}", render_failure(&report));
    assert_numerically_equivalent(&pair, 1e-4, 31);
}

#[test]
fn dpstep_zero_stages_verify_and_match_numerically() {
    for (dp, zero) in [(2u32, 0u8), (2, 1), (2, 2), (4, 1)] {
        let pair = dpstep_pair(
            &TrainStepConfig::tiny(),
            Parallelism::Data { dp, zero_stage: zero },
        );
        let report = Session::new(cfg_seq()).verify(&pair).unwrap();
        assert!(report.verified(), "dp{dp}z{zero}: {}", render_failure(&report));
        assert_numerically_equivalent(&pair, 1e-3, 37 + dp as u64 + zero as u64);
    }
}

#[test]
fn llama_mesh3d_verifies_and_matches_numerically() {
    use crate::ir::Mesh;
    // pp2 × dp2 × tp2 over llama-tiny: one SPMD graph, 4 cores wide
    // ([dp, tp] mesh), tp-SUBGROUP all-reduces, pp stages as metadata
    let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::Mesh3D { pp: 2, dp: 2, tp: 2 });
    assert_eq!(pair.dist.num_cores, 4);
    assert_eq!(pair.dist.mesh, vec![2, 2]);
    assert!(pair.dist.nodes.iter().any(|n| n.op.name() == "send"));
    let tp_groups = Mesh::new(vec![2, 2]).groups_for(1 << 1);
    assert!(
        pair.dist.nodes.iter().any(|n| matches!(
            &n.op,
            crate::ir::Op::AllReduce { groups, .. } if *groups == tp_groups
        )),
        "mesh llama must reduce over tp subgroups {{0,1}},{{2,3}}"
    );
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(report.verified(), "{}", render_failure(&report));
    assert_numerically_equivalent(&pair, 1e-4, 53);
}

#[test]
fn dpstep_mesh3d_verifies_and_matches_numerically() {
    use crate::ir::Mesh;
    // the dp2×tp2 training step: dp-subgroup gradient all-reduces
    // (strided groups) + tp-subgroup discharges in one graph
    let pair =
        dpstep_pair(&TrainStepConfig::tiny(), Parallelism::Mesh3D { pp: 1, dp: 2, tp: 2 });
    assert_eq!(pair.dist.num_cores, 4);
    let mesh = Mesh::new(vec![2, 2]);
    let dp_groups = mesh.groups_for(1 << 0);
    let tp_groups = mesh.groups_for(1 << 1);
    let has = |g: &crate::ir::ReplicaGroups| {
        pair.dist
            .nodes
            .iter()
            .any(|n| matches!(&n.op, crate::ir::Op::AllReduce { groups, .. } if groups == g))
    };
    assert!(has(&dp_groups), "gradient reduction over strided dp groups {{0,2}},{{1,3}}");
    assert!(has(&tp_groups), "hidden-dim discharge over contiguous tp groups");
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(report.verified(), "{}", render_failure(&report));
    assert_numerically_equivalent(&pair, 1e-3, 59);
}

#[test]
fn dpstep_mesh3d_with_pipeline_verifies() {
    let pair =
        dpstep_pair(&TrainStepConfig::tiny(), Parallelism::Mesh3D { pp: 2, dp: 2, tp: 2 });
    assert_eq!(pair.dist.num_cores, 4);
    assert_eq!(pair.dist.mesh, vec![2, 2]);
    assert!(pair.dist.nodes.iter().any(|n| n.op.name() == "send"));
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(report.verified(), "{}", render_failure(&report));
    assert_numerically_equivalent(&pair, 1e-3, 61);
}

#[test]
fn dpstep_collectives_match_zero_stage() {
    let count = |pair: &GraphPair, op: &str| {
        pair.dist.nodes.iter().filter(|n| n.op.name() == op).count()
    };
    let z0 = dpstep_pair(&TrainStepConfig::tiny(), Parallelism::Data { dp: 2, zero_stage: 0 });
    assert!(count(&z0, "all-reduce") > 0, "ZeRO-0 all-reduces gradients");
    assert_eq!(count(&z0, "reduce-scatter"), 0);
    let z1 = dpstep_pair(&TrainStepConfig::tiny(), Parallelism::Data { dp: 2, zero_stage: 1 });
    assert!(count(&z1, "reduce-scatter") > 0, "ZeRO-1 reduce-scatters gradients");
    assert!(count(&z1, "all-gather") > 0, "ZeRO-1 gathers the update vector");
    let z2 = dpstep_pair(&TrainStepConfig::tiny(), Parallelism::Data { dp: 2, zero_stage: 2 });
    assert!(
        count(&z2, "all-gather") > count(&z1, "all-gather"),
        "ZeRO-2 additionally gathers the sharded weights on use"
    );
}

// ---- engine vs hand-built golden builders (differential) ----

/// Both the engine-derived and the golden hand-built pair must verify and
/// agree numerically on identical inputs.
fn assert_engine_matches_golden(cfg: &LlamaConfig, par: Parallelism, seed: u64) {
    let engine = llama_pair(cfg, par);
    let golden = golden_llama_pair(cfg, par);
    let session = Session::new(cfg_seq());
    let er = session.verify(&engine).unwrap();
    assert!(er.verified(), "engine {}: {}", par.label(), render_failure(&er));
    let gr = session.verify(&golden).unwrap();
    assert!(gr.verified(), "golden {}: {}", par.label(), render_failure(&gr));

    // numerically: run both distributed graphs on shards of the same
    // baseline inputs and compare against the shared baseline
    let mut p = Prng::new(seed);
    let base_inputs: Vec<Tensor> = engine
        .base
        .parameters()
        .iter()
        .map(|&pid| Tensor::random(engine.base.node(pid).shape.clone(), &mut p))
        .collect();
    let base_out = run_single(&engine.base, &base_inputs).unwrap();
    for (label, pair) in [("engine", &engine), ("golden", &golden)] {
        let ins = shard_inputs(pair, &base_inputs).unwrap();
        let out = run_spmd(&pair.dist, &ins).unwrap();
        for core in 0..pair.dist.num_cores as usize {
            let diff = base_out[0].max_abs_diff(&out[core][0]);
            assert!(diff < 1e-4, "{label} {} core {core} diverged by {diff}", par.label());
        }
    }
}

#[test]
fn engine_tensor_parallel_matches_golden() {
    assert_engine_matches_golden(&LlamaConfig::tiny(), Parallelism::Tensor { tp: 2 }, 41);
}

#[test]
fn engine_sequence_parallel_matches_golden() {
    assert_engine_matches_golden(&LlamaConfig::tiny(), Parallelism::Sequence { tp: 2 }, 43);
}

#[test]
fn engine_expert_parallel_matches_golden() {
    let cfg = MixtralConfig::tiny();
    let par = Parallelism::Expert { ep: 4 };
    let engine = mixtral_pair(&cfg, par);
    let golden = golden_mixtral_pair(&cfg, par);
    let session = Session::new(cfg_seq());
    assert!(session.verify(&engine).unwrap().verified(), "engine ep4");
    assert!(session.verify(&golden).unwrap().verified(), "golden ep4");
    assert_numerically_equivalent(&engine, 1e-4, 47);
    assert_numerically_equivalent(&golden, 1e-4, 47);
}

#[test]
fn shard_inputs_missing_annotation_is_typed_error() {
    // Regression for the `unwrap_or_else(panic!)` bug: a distributed
    // parameter without an annotation must be a ModelSpec error.
    let mut pair = llama_pair(&LlamaConfig::tiny(), Parallelism::Tensor { tp: 2 });
    pair.annotations.remove(3); // drop one weight annotation
    let mut p = Prng::new(53);
    let base_inputs: Vec<Tensor> = pair
        .base
        .parameters()
        .iter()
        .map(|&pid| Tensor::random(pair.base.node(pid).shape.clone(), &mut p))
        .collect();
    let err = shard_inputs(&pair, &base_inputs).unwrap_err();
    assert!(
        matches!(err, crate::error::ScalifyError::ModelSpec(_)),
        "expected ModelSpec, got {err}"
    );
    assert!(err.message().contains("no annotation"), "{err}");
}
