//! Model-zoo tests: every generated pair must (a) numerically agree under
//! the SPMD interpreter and (b) verify with Scalify. This is the strongest
//! evidence the reproduction's graphs mean what they claim.

use super::*;
use crate::interp::{run_single, run_spmd, Tensor};
use crate::modelgen::llama::shard_inputs;
use crate::util::Prng;
use crate::verifier::{Session, VerifyConfig};

fn cfg_seq() -> VerifyConfig {
    VerifyConfig { parallel: false, ..VerifyConfig::default() }
}

/// Interpreter differential: baseline vs every core of the SPMD run.
fn assert_numerically_equivalent(pair: &GraphPair, tol: f64, seed: u64) {
    let mut p = Prng::new(seed);
    let base_inputs: Vec<Tensor> = pair
        .base
        .parameters()
        .iter()
        .map(|&pid| Tensor::random(pair.base.node(pid).shape.clone(), &mut p))
        .collect();
    let base_out = run_single(&pair.base, &base_inputs).unwrap();
    let dist_inputs = shard_inputs(pair, &base_inputs);
    let dist_out = run_spmd(&pair.dist, &dist_inputs).unwrap();
    for core in 0..pair.dist.num_cores as usize {
        let diff = base_out[0].max_abs_diff(&dist_out[core][0]);
        assert!(diff < tol, "core {core} diverged by {diff}");
    }
}

#[test]
fn llama_tp_tiny_numerics_match() {
    let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::Tensor { tp: 2 });
    assert_numerically_equivalent(&pair, 1e-4, 11);
}

#[test]
fn llama_tp_tiny_verifies() {
    let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::Tensor { tp: 2 });
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(report.verified(), "{}", render_failure(&report));
}

#[test]
fn llama_sp_tiny_numerics_match() {
    let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::Sequence { tp: 2 });
    assert_numerically_equivalent(&pair, 1e-4, 13);
}

#[test]
fn llama_sp_tiny_verifies() {
    let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::Sequence { tp: 2 });
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(report.verified(), "{}", render_failure(&report));
}

#[test]
fn flash_decoding_tiny_numerics_match() {
    let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::FlashDecoding { tp: 2 });
    assert_numerically_equivalent(&pair, 1e-4, 17);
}

#[test]
fn flash_decoding_tiny_verifies() {
    let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::FlashDecoding { tp: 2 });
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(report.verified(), "{}", render_failure(&report));
}

#[test]
fn mixtral_ep_tiny_numerics_match() {
    let pair = mixtral_pair(&MixtralConfig::tiny(), Parallelism::Expert { ep: 4 });
    assert_numerically_equivalent(&pair, 1e-4, 19);
}

#[test]
fn mixtral_ep_tiny_verifies() {
    let pair = mixtral_pair(&MixtralConfig::tiny(), Parallelism::Expert { ep: 4 });
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(report.verified(), "{}", render_failure(&report));
}

#[test]
fn demo_pairs_behave() {
    let good = demo::matmul_allreduce_pair(4);
    assert_numerically_equivalent(&good, 1e-4, 23);
    assert!(Session::new(cfg_seq()).verify(&good).unwrap().verified());

    let bsh_ok = demo::bsh_pair(false);
    assert!(Session::new(cfg_seq()).verify(&bsh_ok).unwrap().verified());
    let bsh_bug = demo::bsh_pair(true);
    assert!(!Session::new(cfg_seq()).verify(&bsh_bug).unwrap().verified());
}

#[test]
fn graphs_validate_and_have_metadata() {
    let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::Tensor { tp: 2 });
    pair.base.validate().unwrap();
    pair.dist.validate().unwrap();
    // every live node inside a layer carries a source site
    let live = pair.dist.live_set();
    let tagged = pair
        .dist
        .nodes
        .iter()
        .filter(|n| live[n.id.idx()] && n.meta.layer.is_some())
        .filter(|n| !pair.dist.source_site(n.id).is_empty())
        .count();
    let total = pair
        .dist
        .nodes
        .iter()
        .filter(|n| live[n.id.idx()] && n.meta.layer.is_some())
        .count();
    assert_eq!(tagged, total, "all layer nodes must carry source sites");
}

#[test]
fn multi_layer_memoizes() {
    let cfg = LlamaConfig { layers: 4, ..LlamaConfig::tiny() };
    let pair = llama_pair(&cfg, Parallelism::Tensor { tp: 2 });
    let report = Session::new(cfg_seq()).verify(&pair).unwrap();
    assert!(report.verified(), "{}", render_failure(&report));
    let memoized = report.layers.iter().filter(|l| l.memoized).count();
    assert!(memoized >= 3, "identical decoder layers should memoize, got {memoized}");
}

fn render_failure(report: &crate::verifier::VerifyReport) -> String {
    let mut s = report.summary();
    for d in report.discrepancies() {
        s.push('\n');
        s.push_str(&d.render());
    }
    s
}
