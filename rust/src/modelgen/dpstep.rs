//! Data-parallel training-step graphs (the ZeRO scenario family).
//!
//! The inference zoo flattens batch×sequence into one token axis, so
//! batch sharding cannot pass through attention there. Data parallelism
//! is instead exercised on what it actually parallelizes in production: a
//! **training step**. The baseline is one SGD-with-momentum step of a
//! tanh-MLP tower — forward, backward (explicit transpose-free
//! `dot_general` gradients), momentum update, weight update — with the
//! updated weights as graph outputs.
//!
//! The transform engine derives the distributed step from a
//! [`crate::transform::ParallelPlan`] that batch-shards the data tensors
//! and, per ZeRO stage, shards the optimizer state / parameters:
//!
//! * **stage 0** — states replicated; the batch-contracted gradient dots
//!   become per-core partials discharged by `all-reduce` at the momentum
//!   update (the classic gradient all-reduce).
//! * **stage 1** — momentum sharded along dim 0; the gradient partial is
//!   discharged by `reduce-scatter`, the update vector is `all-gather`ed
//!   before it touches the replicated weights.
//! * **stage 2** — weights sharded too; the forward pass `all-gather`s
//!   each weight on use, the update happens on the shard, and the updated
//!   shard is gathered at the output (ZeRO-2/3-style partitioning).
//!
//! Every collective above is *derived*, not hand-placed: the plan only
//! names which parameters shard.

use super::{GraphPair, Parallelism};
use crate::error::{Result, ScalifyError};
use crate::ir::{DType, Graph, GraphBuilder, NodeId, Shape};
use crate::transform::ParallelPlan;

/// Training-step configuration (graph-shape parameters only).
#[derive(Clone, Copy, Debug)]
pub struct TrainStepConfig {
    /// MLP layers.
    pub layers: u32,
    /// Global batch size B.
    pub batch: i64,
    /// Hidden size H (square weights).
    pub hidden: i64,
}

impl TrainStepConfig {
    /// Tiny config for interpreter-level differential tests (batch and
    /// hidden chosen so dp ∈ {2, 4} keeps local shard extents ≥ 2).
    pub fn tiny() -> Self {
        TrainStepConfig { layers: 2, batch: 8, hidden: 8 }
    }

    /// A few more layers for memoization / bench scenarios.
    pub fn small() -> Self {
        TrainStepConfig { layers: 4, batch: 8, hidden: 16 }
    }
}

fn f32s(dims: &[i64]) -> Shape {
    Shape::new(DType::F32, dims.to_vec())
}

/// Baseline single-device training step.
///
/// Partition-group tags: forward of layer `l` is group `l`; backward +
/// optimizer of layer `l` is group `2L-1-l` — groups appear in
/// topological order, so Algorithm 1's forward boundary propagation walks
/// the step in execution order.
pub(crate) fn train_step_baseline(cfg: &TrainStepConfig) -> Graph {
    let (bsz, h, layers) = (cfg.batch, cfg.hidden, cfg.layers);
    let mut b = GraphBuilder::new("dpstep_base", 1);
    b.layer(None).at("train.py", 8).in_func("train_step");
    let x = b.parameter("batch.x", f32s(&[bsz, h]));
    let y = b.parameter("batch.y", f32s(&[bsz, h]));

    // ---- forward ----
    let mut weights: Vec<NodeId> = Vec::new();
    let mut acts: Vec<NodeId> = vec![x];
    for l in 0..layers {
        b.layer(Some(l)).at("layers.py", 14).in_func("forward");
        let w = b.parameter(&format!("l{l}.weight"), f32s(&[h, h]));
        let z = b.matmul(acts[l as usize], w);
        let a = b.tanh(z);
        weights.push(w);
        acts.push(a);
    }

    // ---- backward + optimizer, deepest layer first ----
    let mut delta: Option<NodeId> = None;
    let mut updates: Vec<(u32, NodeId)> = Vec::new();
    for (k, l) in (0..layers).rev().enumerate() {
        b.layer(Some(layers + k as u32));
        b.at("backward.py", 9).in_func("backward");
        let d_next = match delta {
            // δ_L = a_L − y (squared-error gradient seed)
            None => b.sub(acts[layers as usize], y),
            Some(d) => d,
        };
        // t = δ_{l+1} ⊙ (1 − a_{l+1}²)  (tanh backward)
        b.at("backward.py", 12);
        let aa = b.mul(acts[(l + 1) as usize], acts[(l + 1) as usize]);
        let one = b.constant(1.0, DType::F32);
        let one_b = b.broadcast_scalar(one, vec![bsz, h]);
        let deriv = b.sub(one_b, aa);
        let t = b.mul(d_next, deriv);
        // gW_l = a_lᵀ · t  — contracts the batch dim on both sides; under
        // data parallelism this is exactly the per-core partial gradient
        b.at("backward.py", 16);
        let g = b.dot_general(acts[l as usize], t, vec![0], vec![0], vec![], vec![]);
        // δ_l = t · W_lᵀ
        b.at("backward.py", 18);
        let d_prev = b.dot_general(t, weights[l as usize], vec![1], vec![1], vec![], vec![]);
        delta = Some(d_prev);

        b.at("optim.py", 9).in_func("optimizer_step");
        let m = b.parameter(&format!("l{l}.momentum"), f32s(&[h, h]));
        let mu = b.constant(0.9, DType::F32);
        let mu_b = b.broadcast_scalar(mu, vec![h, h]);
        let m_scaled = b.mul(mu_b, m);
        // the gradient-reduction site: m' = μ·m + gW
        b.at("optim.py", 12);
        let m_new = b.add(m_scaled, g);
        b.at("optim.py", 14);
        let lr = b.constant(0.01, DType::F32);
        let lr_b = b.broadcast_scalar(lr, vec![h, h]);
        let update = b.mul(lr_b, m_new);
        b.at("optim.py", 16);
        let w_new = b.sub(weights[l as usize], update);
        updates.push((l, w_new));
    }
    b.layer(None);
    updates.sort_by_key(|(l, _)| *l);
    for (_, w_new) in updates {
        b.output(w_new);
    }
    b.finish()
}

/// The plan for one ZeRO stage: data tensors batch-shard; stage ≥ 1
/// shards the momentum, stage ≥ 2 the weights too.
pub(crate) fn zero_plan(dp: u32, zero_stage: u8) -> ParallelPlan {
    let mut plan = ParallelPlan::new(Parallelism::Data { dp, zero_stage })
        .shard("batch.x", 0)
        .shard("batch.y", 0);
    if zero_stage >= 1 {
        plan = plan.shard("momentum", 0);
    }
    if zero_stage >= 2 {
        plan = plan.shard("weight", 0);
    }
    plan
}

/// The plan for a 3D `pp×dp×tp` mesh step: data tensors batch-shard over
/// the dp axis (axis 0), weights Megatron-shard over the tp axis (axis 1)
/// — column-sharded on even layers, row-sharded on odd, so hidden-dim
/// contractions leave **tp-subgroup** partials — and each momentum shards
/// with its weight. Gradient batch contractions leave **dp-subgroup**
/// partials discharged by strided-group all-reduces at the optimizer
/// update: both subgroup collective families in one SPMD graph.
pub(crate) fn mesh_plan(cfg: &TrainStepConfig, pp: u32, dp: u32, tp: u32) -> ParallelPlan {
    let mut plan = ParallelPlan::new(Parallelism::Mesh3D { pp, dp, tp })
        .shard_on("batch.x", 0, 0)
        .shard_on("batch.y", 0, 0);
    for l in 0..cfg.layers {
        let dim = if l % 2 == 0 { 1 } else { 0 };
        plan = plan
            .shard_on(&format!("l{l}.weight"), dim, 1)
            .shard_on(&format!("l{l}.momentum"), dim, 1);
    }
    plan
}

/// Build a baseline + data-parallel training-step pair, validating the
/// configuration instead of panicking.
pub fn try_dpstep_pair(cfg: &TrainStepConfig, par: Parallelism) -> Result<GraphPair> {
    if cfg.layers == 0 || cfg.batch <= 0 || cfg.hidden <= 0 {
        return Err(ScalifyError::model_spec(format!(
            "training-step config has a non-positive dimension: {cfg:?}"
        )));
    }
    match par {
        Parallelism::Data { dp, zero_stage } => {
            if dp == 0 {
                return Err(ScalifyError::model_spec(
                    "data-parallel degree must be >= 1",
                ));
            }
            if zero_stage > 2 {
                return Err(ScalifyError::model_spec(format!(
                    "ZeRO stage {zero_stage} is not modeled (stages 0-2)"
                )));
            }
            if cfg.batch % dp as i64 != 0 {
                return Err(ScalifyError::model_spec(format!(
                    "batch ({}) must be divisible by dp ({dp})",
                    cfg.batch
                )));
            }
            if zero_stage >= 1 && cfg.hidden % dp as i64 != 0 {
                return Err(ScalifyError::model_spec(format!(
                    "hidden ({}) must be divisible by dp ({dp}) to shard optimizer state",
                    cfg.hidden
                )));
            }
        }
        Parallelism::Mesh3D { pp, dp, tp } => {
            if pp == 0 || dp == 0 || tp == 0 {
                return Err(ScalifyError::model_spec("mesh degrees must be >= 1"));
            }
            if cfg.batch % dp as i64 != 0 {
                return Err(ScalifyError::model_spec(format!(
                    "batch ({}) must be divisible by dp ({dp})",
                    cfg.batch
                )));
            }
            if cfg.hidden % tp as i64 != 0 {
                return Err(ScalifyError::model_spec(format!(
                    "hidden ({}) must be divisible by tp ({tp}) to shard the weights",
                    cfg.hidden
                )));
            }
            // stage splitting cuts along the 2·layers forward/backward
            // partition groups
            if pp > 2 * cfg.layers {
                return Err(ScalifyError::model_spec(format!(
                    "pipeline degree ({pp}) exceeds the {} forward/backward groups",
                    2 * cfg.layers
                )));
            }
        }
        other => {
            return Err(ScalifyError::model_spec(format!(
                "the training-step zoo is data-parallel only (got {})",
                other.label()
            )));
        }
    }
    Ok(dpstep_pair(cfg, par))
}

/// Build a baseline + data-parallel training-step pair.
///
/// # Panics
/// Panics on invalid configurations; use [`try_dpstep_pair`] on untrusted
/// input.
pub fn dpstep_pair(cfg: &TrainStepConfig, par: Parallelism) -> GraphPair {
    let base = train_step_baseline(cfg);
    let plan = match par {
        Parallelism::Data { dp, zero_stage } => zero_plan(dp, zero_stage),
        Parallelism::Mesh3D { pp, dp, tp } => mesh_plan(cfg, pp, dp, tp),
        _ => panic!("the training-step zoo is data-parallel only"),
    };
    crate::transform::apply(&base, &plan)
        .expect("training-step parallel plan applies to its own baseline")
}
