//! Mixtral-style MoE graph pairs: expert parallelism with the baseline's
//! unrolled expert-sum loop (paper §7.1 "expert parallelism implemented
//! with recursive loops", Figure 8's slicing/unroll patterns).

use super::{GraphPair, Parallelism};
use crate::ir::{Annotation, DType, GraphBuilder, NodeId, ReduceKind, ReplicaGroups, Shape};

/// Mixtral model configuration.
#[derive(Clone, Copy, Debug)]
pub struct MixtralConfig {
    /// Decoder layers.
    pub layers: u32,
    /// Hidden size.
    pub hidden: i64,
    /// Experts per layer.
    pub experts: i64,
    /// Expert FFN size.
    pub ffn: i64,
    /// Sequence length.
    pub seqlen: i64,
    /// Batch size.
    pub batch: i64,
}

impl MixtralConfig {
    /// Mixtral-8x7B-shaped graph (32 layers, 8 experts).
    pub fn mixtral_8x7b() -> Self {
        MixtralConfig { layers: 32, hidden: 4096, experts: 8, ffn: 14336, seqlen: 64, batch: 4 }
    }
    /// Mixtral-8x22B-shaped graph (56 layers, 8 experts).
    pub fn mixtral_8x22b() -> Self {
        MixtralConfig { layers: 56, hidden: 6144, experts: 8, ffn: 16384, seqlen: 64, batch: 4 }
    }
    /// Tiny config for interpreter tests.
    pub fn tiny() -> Self {
        MixtralConfig { layers: 2, hidden: 8, experts: 4, ffn: 8, seqlen: 2, batch: 1 }
    }
    /// Token count.
    pub fn tokens(&self) -> i64 {
        self.batch * self.seqlen
    }
}

fn f32s(dims: &[i64]) -> Shape {
    Shape::new(DType::F32, dims.to_vec())
}

struct MoeWeights {
    /// stacked expert weights: up (E, H, F) / down (E, F, H) — sharded
    /// along E across the EP mesh.
    w_up: NodeId,
    w_down: NodeId,
}

/// One expert's FFN given its (H,F)/(F,H) weights.
fn expert_ffn(b: &mut GraphBuilder, x: NodeId, wu: NodeId, wd: NodeId) -> NodeId {
    b.at("moe.py", 58).in_func("expert_mlp");
    let up = b.matmul(x, wu);
    let s = b.logistic(up);
    let act = b.mul(up, s);
    b.matmul(act, wd)
}

/// Baseline MoE block: unrolled loop summing every expert's contribution
/// (z = e₀(x) + e₁(x) + …) via slices of the stacked weights.
fn moe_block_base(b: &mut GraphBuilder, x: NodeId, w: &MoeWeights, cfg: &MixtralConfig) -> NodeId {
    let (h, f) = (cfg.hidden, cfg.ffn);
    let mut acc: Option<NodeId> = None;
    for e in 0..cfg.experts {
        b.at("moe.py", 70).in_func("moe_unrolled");
        let wu3 = b.slice(w.w_up, vec![e, 0, 0], vec![e + 1, h, f]);
        let wu = b.reshape(wu3, vec![h, f]);
        let wd3 = b.slice(w.w_down, vec![e, 0, 0], vec![e + 1, f, h]);
        let wd = b.reshape(wd3, vec![f, h]);
        let y = expert_ffn(b, x, wu, wd);
        b.at("moe.py", 76).in_func("moe_unrolled");
        acc = Some(match acc {
            None => y,
            Some(a) => b.add(a, y),
        });
    }
    acc.unwrap()
}

/// Distributed MoE block: each core holds `experts/ep` experts locally,
/// computes their sum, and all-reduces across the mesh.
fn moe_block_dist(
    b: &mut GraphBuilder,
    x: NodeId,
    w: &MoeWeights,
    cfg: &MixtralConfig,
    ep: u32,
) -> NodeId {
    let (h, f) = (cfg.hidden, cfg.ffn);
    let local = cfg.experts / ep as i64;
    let mut acc: Option<NodeId> = None;
    for e in 0..local {
        b.at("moe.py", 70).in_func("moe_local");
        // single local expert: the framework emits a plain reshape of the
        // local stacked-weight shard (no slice), matching the baseline's
        // reshape(slice(W, e)) node shapes exactly
        let (wu, wd) = if local == 1 {
            (b.reshape(w.w_up, vec![h, f]), b.reshape(w.w_down, vec![f, h]))
        } else {
            let wu3 = b.slice(w.w_up, vec![e, 0, 0], vec![e + 1, h, f]);
            let wu = b.reshape(wu3, vec![h, f]);
            let wd3 = b.slice(w.w_down, vec![e, 0, 0], vec![e + 1, f, h]);
            (wu, b.reshape(wd3, vec![f, h]))
        };
        let y = expert_ffn(b, x, wu, wd);
        acc = Some(match acc {
            None => y,
            Some(a) => b.add(a, y),
        });
    }
    b.at("moe.py", 84).in_func("moe_local");
    b.all_reduce(acc.unwrap(), ReduceKind::Add, ReplicaGroups::full(ep))
}

/// Build the Mixtral pair under expert parallelism, validating the
/// config/parallelism combination instead of panicking.
pub fn try_mixtral_pair(
    cfg: &MixtralConfig,
    par: Parallelism,
) -> crate::error::Result<GraphPair> {
    use crate::error::ScalifyError;
    let spec = |m: String| Err(ScalifyError::ModelSpec(m));
    if cfg.layers == 0
        || cfg.hidden <= 0
        || cfg.experts <= 0
        || cfg.ffn <= 0
        || cfg.seqlen <= 0
        || cfg.batch <= 0
    {
        return spec(format!("mixtral config has a non-positive dimension: {cfg:?}"));
    }
    let Parallelism::Expert { ep } = par else {
        return spec(format!(
            "mixtral supports expert parallelism only (got {})",
            par.label()
        ));
    };
    if ep == 0 {
        return spec("expert-parallel degree must be >= 1".into());
    }
    if cfg.experts % ep as i64 != 0 {
        return spec(format!(
            "experts ({}) must be divisible by ep ({ep})",
            cfg.experts
        ));
    }
    Ok(mixtral_pair(cfg, par))
}

/// Build the Mixtral pair under expert parallelism.
///
/// The distributed half is **derived**: the transform engine shards the
/// stacked expert weights along the expert dim and the baseline's
/// unrolled expert-sum loop collapses to the core-local experts plus one
/// all-reduce (the loop-redistribution pattern). The pre-engine builder
/// survives as [`golden_mixtral_pair`] for differential testing.
///
/// # Panics
/// Panics on invalid config/parallelism combinations; use
/// [`try_mixtral_pair`] on untrusted input.
pub fn mixtral_pair(cfg: &MixtralConfig, par: Parallelism) -> GraphPair {
    let Parallelism::Expert { ep } = par else {
        panic!("mixtral_pair expects expert parallelism");
    };
    assert_eq!(cfg.experts % ep as i64, 0, "experts must divide ep");
    let base = moe_baseline(cfg);
    let plan = crate::transform::ParallelPlan::new(par)
        .shard("experts.up", 0)
        .shard("experts.down", 0)
        .collectives_at("moe.py", 84, "moe_local");
    crate::transform::apply(&base, &plan)
        .expect("mixtral expert plan applies to its own baseline")
}

/// Baseline single-device Mixtral graph (shared by the engine and golden
/// paths).
pub(crate) fn moe_baseline(cfg: &MixtralConfig) -> crate::ir::Graph {
    let t = cfg.tokens();
    let (h, f) = (cfg.hidden, cfg.ffn);
    let mut bb = GraphBuilder::new("mixtral_base", 1);
    bb.layer(None).at("model.py", 10).in_func("model_fwd");
    let bx = bb.parameter("hidden_states", f32s(&[t, h]));
    let mut cur = bx;
    for l in 0..cfg.layers {
        bb.layer(Some(l));
        bb.at("moe.py", 30).in_func("moe_layer");
        let w = MoeWeights {
            w_up: bb.parameter(&format!("l{l}.experts.up"), f32s(&[cfg.experts, h, f])),
            w_down: bb.parameter(&format!("l{l}.experts.down"), f32s(&[cfg.experts, f, h])),
        };
        let moe = moe_block_base(&mut bb, cur, &w, cfg);
        bb.at("moe.py", 90).in_func("moe_layer");
        cur = bb.add(cur, moe);
    }
    bb.layer(None);
    bb.output(cur);
    bb.finish()
}

/// The hand-built expert-parallel builder, kept verbatim as the golden
/// reference for the differential harness.
///
/// # Panics
/// Panics on invalid combinations, like the historical `mixtral_pair`.
pub fn golden_mixtral_pair(cfg: &MixtralConfig, par: Parallelism) -> GraphPair {
    let Parallelism::Expert { ep } = par else {
        panic!("mixtral_pair expects expert parallelism");
    };
    assert_eq!(cfg.experts % ep as i64, 0, "experts must divide ep");
    let t = cfg.tokens();
    let (h, f) = (cfg.hidden, cfg.ffn);
    let e_local = cfg.experts / ep as i64;

    let mut bb = GraphBuilder::new("mixtral_base", 1);
    bb.layer(None).at("model.py", 10).in_func("model_fwd");
    let bx = bb.parameter("hidden_states", f32s(&[t, h]));
    let mut cur = bx;
    let mut bws = Vec::new();
    for l in 0..cfg.layers {
        bb.layer(Some(l));
        bb.at("moe.py", 30).in_func("moe_layer");
        let w = MoeWeights {
            w_up: bb.parameter(&format!("l{l}.experts.up"), f32s(&[cfg.experts, h, f])),
            w_down: bb.parameter(&format!("l{l}.experts.down"), f32s(&[cfg.experts, f, h])),
        };
        let moe = moe_block_base(&mut bb, cur, &w, cfg);
        bb.at("moe.py", 90).in_func("moe_layer");
        cur = bb.add(cur, moe);
        bws.push(w);
    }
    bb.layer(None);
    bb.output(cur);
    let base = bb.finish();

    let mut db = GraphBuilder::new("mixtral_dist", ep);
    db.layer(None).at("model.py", 10).in_func("model_fwd");
    let dx = db.parameter("hidden_states", f32s(&[t, h]));
    let mut cur = dx;
    let mut dws = Vec::new();
    for l in 0..cfg.layers {
        db.layer(Some(l));
        db.at("moe.py", 30).in_func("moe_layer");
        let w = MoeWeights {
            w_up: db.parameter(&format!("l{l}.experts.up"), f32s(&[e_local, h, f])),
            w_down: db.parameter(&format!("l{l}.experts.down"), f32s(&[e_local, f, h])),
        };
        let moe = moe_block_dist(&mut db, cur, &w, cfg, ep);
        db.at("moe.py", 90).in_func("moe_layer");
        cur = db.add(cur, moe);
        dws.push(w);
    }
    db.layer(None);
    db.output(cur);
    let dist = db.finish();

    let mut ann = vec![Annotation::replicated(bx, dx)];
    for (bw, dw) in bws.iter().zip(&dws) {
        ann.push(Annotation::shard(bw.w_up, dw.w_up, 0, ep));
        ann.push(Annotation::shard(bw.w_down, dw.w_down, 0, ep));
    }
    GraphPair::new(base, dist, ann)
}
