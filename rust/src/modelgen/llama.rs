//! Llama-style dense transformer graph pairs.
//!
//! Emits the same structural patterns Transformers NeuronX produces for
//! Llama-3 inference: RMSNorm, rotary embeddings (rotate-half), multi-head
//! attention with the BSH output reshape–transpose, SwiGLU MLP; and the
//! distributed variants: Megatron-style tensor parallelism (column/row
//! sharded projections + all-reduce), sequence parallelism (all-gather /
//! reduce-scatter around the sharded residual stream), and flash decoding
//! (sequence-sharded KV with a distributed two-pass softmax).

use super::{GraphPair, Parallelism};
use crate::ir::{Annotation, DType, GraphBuilder, NodeId, ReduceKind, ReplicaGroups, Shape};

/// Llama model configuration (graph-shape parameters only).
#[derive(Clone, Copy, Debug)]
pub struct LlamaConfig {
    /// Decoder layers.
    pub layers: u32,
    /// Hidden size H.
    pub hidden: i64,
    /// Attention (query) heads.
    pub heads: i64,
    /// Key/value heads (== `heads` for classic multi-head attention;
    /// fewer for grouped-query attention, where each KV head serves
    /// `heads / kv_heads` query heads via a broadcast expansion).
    pub kv_heads: i64,
    /// FFN intermediate size.
    pub ffn: i64,
    /// Sequence length.
    pub seqlen: i64,
    /// Batch size.
    pub batch: i64,
}

impl LlamaConfig {
    /// Llama-3.1-8B-shaped graph (32 layers).
    pub fn llama3_8b() -> Self {
        LlamaConfig {
            layers: 32,
            hidden: 4096,
            heads: 32,
            kv_heads: 32,
            ffn: 14336,
            seqlen: 64,
            batch: 4,
        }
    }
    /// Llama-3.1-70B-shaped graph (80 layers).
    pub fn llama3_70b() -> Self {
        LlamaConfig {
            layers: 80,
            hidden: 8192,
            heads: 64,
            kv_heads: 64,
            ffn: 28672,
            seqlen: 64,
            batch: 4,
        }
    }
    /// Llama-3.1-405B-shaped graph (126 layers).
    pub fn llama3_405b() -> Self {
        LlamaConfig {
            layers: 126,
            hidden: 16384,
            heads: 128,
            kv_heads: 128,
            ffn: 53248,
            seqlen: 64,
            batch: 4,
        }
    }
    /// 405B-class scale-bench geometry: the real Llama-3.1-405B layer
    /// count and GQA head layout (128 query heads over 8 KV heads). This
    /// is the `llama-405b-like` zoo entry `scalify bench --scale` runs.
    pub fn llama3_405b_like() -> Self {
        LlamaConfig {
            layers: 126,
            hidden: 16384,
            heads: 128,
            kv_heads: 8,
            ffn: 53248,
            seqlen: 64,
            batch: 4,
        }
    }
    /// Tiny config for interpreter-level differential tests.
    pub fn tiny() -> Self {
        LlamaConfig { layers: 2, hidden: 8, heads: 2, kv_heads: 2, ffn: 16, seqlen: 4, batch: 1 }
    }
    /// Tiny grouped-query config (4 query heads over 2 KV heads) for
    /// interpreter-level differential tests of the GQA expansion.
    pub fn tiny_gqa() -> Self {
        LlamaConfig { layers: 2, hidden: 8, heads: 4, kv_heads: 2, ffn: 16, seqlen: 4, batch: 1 }
    }
    /// Head dim.
    pub fn head_dim(&self) -> i64 {
        self.hidden / self.heads
    }
    /// Query heads per KV head (1 for MHA).
    pub fn kv_group(&self) -> i64 {
        self.heads / self.kv_heads
    }
    /// Token count T = batch * seqlen.
    pub fn tokens(&self) -> i64 {
        self.batch * self.seqlen
    }
}

fn f32s(dims: &[i64]) -> Shape {
    Shape::new(DType::F32, dims.to_vec())
}

/// Weight handles of one layer (baseline or distributed).
struct LayerWeights {
    g_attn: NodeId,
    wq: NodeId,
    wk: NodeId,
    wv: NodeId,
    wo: NodeId,
    g_mlp: NodeId,
    wg: NodeId,
    wu: NodeId,
    wd: NodeId,
}

/// RMSNorm: x * rsqrt(mean(x²) + eps) * g.
fn rmsnorm(b: &mut GraphBuilder, x: NodeId, g: NodeId, t: i64, h: i64) -> NodeId {
    b.at("rmsnorm.py", 12).in_func("rms_norm");
    let xx = b.mul(x, x);
    let s = b.reduce(xx, ReduceKind::Add, vec![1]); // (T)
    let inv_h = b.constant(1.0 / h as f64, DType::F32);
    let inv_h_b = b.broadcast_scalar(inv_h, vec![t]);
    let mean = b.mul(s, inv_h_b);
    let eps = b.constant(1e-5, DType::F32);
    let eps_b = b.broadcast_scalar(eps, vec![t]);
    let var = b.add(mean, eps_b);
    let r = b.rsqrt(var);
    let rb = b.broadcast(r, vec![t, h], vec![0]);
    let xn = b.mul(x, rb);
    let gb = b.broadcast(g, vec![t, h], vec![1]);
    b.mul(xn, gb)
}

/// rotate_half: concat(-x[.., d/2:], x[.., :d/2]) on the last dim.
fn rotate_half(b: &mut GraphBuilder, x: NodeId, nh: i64, t: i64, hd: i64) -> NodeId {
    b.at("rotary.py", 31).in_func("rotate_half");
    let lo = b.slice(x, vec![0, 0, 0], vec![nh, t, hd / 2]);
    let hi = b.slice(x, vec![0, 0, hd / 2], vec![nh, t, hd]);
    let neg_hi = b.neg(hi);
    b.concat(vec![neg_hi, lo], 2)
}

/// Apply rotary embedding: x*cos + rotate_half(x)*sin.
fn rotary(
    b: &mut GraphBuilder,
    x: NodeId,
    cos: NodeId,
    sin: NodeId,
    nh: i64,
    t: i64,
    hd: i64,
) -> NodeId {
    b.at("rotary.py", 44).in_func("apply_rotary");
    let cos_b = b.broadcast(cos, vec![nh, t, hd], vec![1, 2]);
    let sin_b = b.broadcast(sin, vec![nh, t, hd], vec![1, 2]);
    let xc = b.mul(x, cos_b);
    let xr = rotate_half(b, x, nh, t, hd);
    let xs = b.mul(xr, sin_b);
    b.add(xc, xs)
}

/// Softmax over the last dim of a rank-3 tensor.
fn softmax3(b: &mut GraphBuilder, x: NodeId, d0: i64, d1: i64, d2: i64) -> NodeId {
    b.at("attention.py", 88).in_func("softmax");
    let m = b.reduce(x, ReduceKind::Max, vec![2]);
    let mb = b.broadcast(m, vec![d0, d1, d2], vec![0, 1]);
    let sh = b.sub(x, mb);
    let e = b.exp(sh);
    let s = b.reduce(e, ReduceKind::Add, vec![2]);
    let sb = b.broadcast(s, vec![d0, d1, d2], vec![0, 1]);
    b.div(e, sb)
}

/// SiLU: x * sigmoid(x).
fn silu(b: &mut GraphBuilder, x: NodeId) -> NodeId {
    b.at("mlp.py", 21).in_func("silu");
    let s = b.logistic(x);
    b.mul(x, s)
}

/// GQA expansion: repeat each KV head for its query-head group —
/// `(nkv, T, hd) -> broadcast (nkv, g, T, hd) -> reshape (nkv*g, T, hd)`.
fn expand_kv(b: &mut GraphBuilder, x: NodeId, nkv: i64, group: i64, t: i64, hd: i64) -> NodeId {
    b.at("attention.py", 52).in_func("repeat_kv");
    let e = b.broadcast(x, vec![nkv, group, t, hd], vec![0, 2, 3]);
    b.reshape(e, vec![nkv * group, t, hd])
}

/// One decoder layer. `nh_local` is the per-core query-head count
/// (== heads for the baseline); KV heads follow at `nh_local / kv_group`
/// and are broadcast-expanded to the query heads under GQA.
#[allow(clippy::too_many_arguments)]
fn decoder_layer(
    b: &mut GraphBuilder,
    x: NodeId,
    w: &LayerWeights,
    cos: NodeId,
    sin: NodeId,
    cfg: &LlamaConfig,
    nh_local: i64,
    tp: u32,
    seq_parallel: bool,
) -> NodeId {
    let t = if seq_parallel { cfg.tokens() / tp as i64 } else { cfg.tokens() };
    let t_full = cfg.tokens();
    let h = cfg.hidden;
    let hd = cfg.head_dim();
    let h_local = nh_local * hd;
    let group = cfg.kv_group();
    let nkv_local = nh_local / group;
    let groups = || ReplicaGroups::full(tp);

    // ---- attention ----
    let xn = rmsnorm(b, x, w.g_attn, t, h);
    // sequence parallelism: gather the full sequence before attention
    let xn = if seq_parallel { b.all_gather(xn, 0, groups()) } else { xn };

    b.at("attention.py", 40).in_func("attention_fwd");
    let q = b.matmul(xn, w.wq); // (T, h_local)
    let k = b.matmul(xn, w.wk); // (T, nkv_local * hd)
    let v = b.matmul(xn, w.wv);
    let q3 = b.reshape(q, vec![t_full, nh_local, hd]);
    let k3 = b.reshape(k, vec![t_full, nkv_local, hd]);
    let v3 = b.reshape(v, vec![t_full, nkv_local, hd]);
    let qh = b.transpose(q3, vec![1, 0, 2]); // (nh, T, hd)
    let kh = b.transpose(k3, vec![1, 0, 2]); // (nkv, T, hd)
    let vh = b.transpose(v3, vec![1, 0, 2]);
    let qr = rotary(b, qh, cos, sin, nh_local, t_full, hd);
    let kr = rotary(b, kh, cos, sin, nkv_local, t_full, hd);
    // GQA: expand the KV heads to the query heads after rotary
    let (kr, vh) = if group > 1 {
        (
            expand_kv(b, kr, nkv_local, group, t_full, hd),
            expand_kv(b, vh, nkv_local, group, t_full, hd),
        )
    } else {
        (kr, vh)
    };

    b.at("attention.py", 61).in_func("attention_fwd");
    let scores = b.dot_general(qr, kr, vec![2], vec![2], vec![0], vec![0]); // (nh,T,T)
    let scale = b.constant((hd as f64).sqrt(), DType::F32);
    let scale_b = b.broadcast_scalar(scale, vec![nh_local, t_full, t_full]);
    let scaled = b.div(scores, scale_b);
    let sm = softmax3(b, scaled, nh_local, t_full, t_full);
    let ctx = b.dot_general(sm, vh, vec![2], vec![1], vec![0], vec![0]); // (nh,T,hd)

    // BSH output path (the Figure-1 site): (nh,T,hd) -> (T,nh,hd) -> (T,H)
    b.at("attention.py", 79).in_func("attention_output");
    let ctx_t = b.transpose(ctx, vec![1, 0, 2]);
    let ctx2 = b.reshape(ctx_t, vec![t_full, h_local]);
    let attn = b.matmul(ctx2, w.wo); // (T, H), partial under TP

    // TP: discharge the partial; SP: reduce-scatter back to shards
    let attn = if tp > 1 {
        if seq_parallel {
            b.reduce_scatter(attn, ReduceKind::Add, 0, groups())
        } else {
            b.all_reduce(attn, ReduceKind::Add, groups())
        }
    } else {
        attn
    };
    b.at("decoder.py", 55).in_func("decoder_layer");
    let resid1 = b.add(x, attn);

    // ---- MLP ----
    let xn2 = rmsnorm(b, resid1, w.g_mlp, t, h);
    let xn2 = if seq_parallel { b.all_gather(xn2, 0, groups()) } else { xn2 };
    b.at("mlp.py", 33).in_func("mlp_fwd");
    let gate = b.matmul(xn2, w.wg);
    let up = b.matmul(xn2, w.wu);
    let act = silu(b, gate);
    b.at("mlp.py", 36).in_func("mlp_fwd");
    let fused = b.mul(act, up);
    let down = b.matmul(fused, w.wd); // (T, H), partial under TP
    let down = if tp > 1 {
        if seq_parallel {
            b.reduce_scatter(down, ReduceKind::Add, 0, groups())
        } else {
            b.all_reduce(down, ReduceKind::Add, groups())
        }
    } else {
        down
    };
    b.at("decoder.py", 61).in_func("decoder_layer");
    b.add(resid1, down)
}

/// Declare one layer's weights. Shapes differ between baseline and the
/// TP-sharded variant; `kv_local` is the K/V projection output width
/// (`kv_heads_local * head_dim`, == `h_local` for MHA).
#[allow(clippy::too_many_arguments)]
fn layer_weights(
    b: &mut GraphBuilder,
    l: u32,
    h: i64,
    _ffn: i64,
    h_local: i64,
    kv_local: i64,
    ffn_local: i64,
) -> LayerWeights {
    b.at("decoder.py", 20).in_func("decoder_layer");
    LayerWeights {
        g_attn: b.parameter(&format!("l{l}.attn_norm.g"), f32s(&[h])),
        wq: b.parameter(&format!("l{l}.q_proj"), f32s(&[h, h_local])),
        wk: b.parameter(&format!("l{l}.k_proj"), f32s(&[h, kv_local])),
        wv: b.parameter(&format!("l{l}.v_proj"), f32s(&[h, kv_local])),
        wo: b.parameter(&format!("l{l}.o_proj"), f32s(&[h_local, h])),
        g_mlp: b.parameter(&format!("l{l}.mlp_norm.g"), f32s(&[h])),
        wg: b.parameter(&format!("l{l}.gate_proj"), f32s(&[h, ffn_local])),
        wu: b.parameter(&format!("l{l}.up_proj"), f32s(&[h, ffn_local])),
        wd: b.parameter(&format!("l{l}.down_proj"), f32s(&[ffn_local, h])),
    }
}

fn annotate_layer(
    ann: &mut Vec<Annotation>,
    bw: &LayerWeights,
    dw: &LayerWeights,
    tp: u32,
) {
    ann.push(Annotation::replicated(bw.g_attn, dw.g_attn));
    ann.push(Annotation::shard(bw.wq, dw.wq, 1, tp));
    ann.push(Annotation::shard(bw.wk, dw.wk, 1, tp));
    ann.push(Annotation::shard(bw.wv, dw.wv, 1, tp));
    ann.push(Annotation::shard(bw.wo, dw.wo, 0, tp));
    ann.push(Annotation::replicated(bw.g_mlp, dw.g_mlp));
    ann.push(Annotation::shard(bw.wg, dw.wg, 1, tp));
    ann.push(Annotation::shard(bw.wu, dw.wu, 1, tp));
    ann.push(Annotation::shard(bw.wd, dw.wd, 0, tp));
}

/// Build a baseline + distributed Llama graph pair, validating the
/// config/parallelism combination instead of panicking.
pub fn try_llama_pair(
    cfg: &LlamaConfig,
    par: Parallelism,
) -> crate::error::Result<GraphPair> {
    use crate::error::ScalifyError;
    let spec = |m: String| Err(ScalifyError::ModelSpec(m));
    if cfg.layers == 0
        || cfg.hidden <= 0
        || cfg.heads <= 0
        || cfg.kv_heads <= 0
        || cfg.ffn <= 0
        || cfg.seqlen <= 0
        || cfg.batch <= 0
    {
        return spec(format!("llama config has a non-positive dimension: {cfg:?}"));
    }
    if cfg.hidden % cfg.heads != 0 {
        return spec(format!(
            "hidden ({}) must be divisible by heads ({})",
            cfg.hidden, cfg.heads
        ));
    }
    if cfg.heads % cfg.kv_heads != 0 {
        return spec(format!(
            "heads ({}) must be divisible by kv_heads ({}) for grouped-query attention",
            cfg.heads, cfg.kv_heads
        ));
    }
    let degree = par.cores();
    if degree == 0 {
        return spec("parallelism degree must be >= 1".into());
    }
    let check_tp = |tp: u32| -> crate::error::Result<()> {
        if cfg.heads % tp as i64 != 0 {
            return Err(ScalifyError::model_spec(format!(
                "heads ({}) must be divisible by tp ({tp})",
                cfg.heads
            )));
        }
        if cfg.kv_heads % tp as i64 != 0 {
            return Err(ScalifyError::model_spec(format!(
                "kv_heads ({}) must be divisible by tp ({tp})",
                cfg.kv_heads
            )));
        }
        if cfg.ffn % tp as i64 != 0 {
            return Err(ScalifyError::model_spec(format!(
                "ffn ({}) must be divisible by tp ({tp})",
                cfg.ffn
            )));
        }
        Ok(())
    };
    match par {
        Parallelism::Tensor { tp } | Parallelism::Sequence { tp } => {
            check_tp(tp)?;
            if matches!(par, Parallelism::Sequence { .. }) && cfg.tokens() % tp as i64 != 0 {
                return spec(format!(
                    "tokens ({}) must be divisible by tp ({tp}) for sequence parallelism",
                    cfg.tokens()
                ));
            }
        }
        Parallelism::FlashDecoding { tp } => {
            if cfg.kv_heads != cfg.heads {
                return spec(format!(
                    "flash decoding is built for multi-head attention (kv_heads {} != \
                     heads {})",
                    cfg.kv_heads, cfg.heads
                ));
            }
            if cfg.seqlen % tp as i64 != 0 {
                return spec(format!(
                    "seqlen ({}) must be divisible by the KV-shard degree ({tp})",
                    cfg.seqlen
                ));
            }
        }
        Parallelism::Expert { .. } => {
            return spec(
                "expert parallelism is a Mixtral configuration (use mixtral_pair)".into(),
            );
        }
        Parallelism::Pipeline { pp } => {
            if pp > cfg.layers {
                return spec(format!(
                    "pipeline degree ({pp}) exceeds the layer count ({})",
                    cfg.layers
                ));
            }
        }
        Parallelism::Data { .. } => {
            return spec(
                "data parallelism applies to the training-step zoo (use dpstep_pair): \
                 the flattened-token inference graphs cannot batch-shard through \
                 attention"
                    .into(),
            );
        }
        Parallelism::Combined { pp, tp } => {
            check_tp(tp)?;
            if pp > cfg.layers {
                return spec(format!(
                    "pipeline degree ({pp}) exceeds the layer count ({})",
                    cfg.layers
                ));
            }
        }
        Parallelism::Mesh3D { pp, dp, tp } => {
            // inference serves the dp axis by replication (each dp group
            // answers its own requests), so dp adds no shape constraints —
            // it widens the mesh and turns every tp collective into a
            // subgroup collective
            check_tp(tp)?;
            let _ = dp;
            if pp > cfg.layers {
                return spec(format!(
                    "pipeline degree ({pp}) exceeds the layer count ({})",
                    cfg.layers
                ));
            }
        }
    }
    Ok(llama_pair(cfg, par))
}

/// Build a baseline + distributed Llama graph pair.
///
/// Tensor, sequence, pipeline and combined variants are **derived** by the
/// transform engine ([`crate::transform::apply`]) from the baseline graph
/// and a [`ParallelPlan`]; flash decoding restructures the softmax and
/// keeps its hand-built builder. The pre-engine hand-built dense builder
/// survives as [`golden_llama_pair`] for differential testing.
///
/// # Panics
/// Panics on invalid config/parallelism combinations; use
/// [`try_llama_pair`] on untrusted input.
pub fn llama_pair(cfg: &LlamaConfig, par: Parallelism) -> GraphPair {
    match par {
        Parallelism::Tensor { .. }
        | Parallelism::Sequence { .. }
        | Parallelism::Pipeline { .. }
        | Parallelism::Combined { .. }
        | Parallelism::Mesh3D { .. } => {
            let base = dense_baseline(cfg);
            crate::transform::apply(&base, &dense_plan(par))
                .expect("llama parallel plan applies to its own baseline")
        }
        Parallelism::FlashDecoding { tp } => flash_decoding_pair(cfg, tp),
        Parallelism::Expert { .. } => panic!("expert parallelism is a Mixtral configuration"),
        Parallelism::Data { .. } => {
            panic!("data parallelism applies to the training-step zoo (dpstep_pair)")
        }
    }
}

/// The hand-built dense builder, kept verbatim as the golden reference the
/// differential harness checks the engine against (tensor / sequence
/// variants only; other techniques never had hand-built forms).
///
/// # Panics
/// Panics on invalid combinations, like the historical `llama_pair`.
pub fn golden_llama_pair(cfg: &LlamaConfig, par: Parallelism) -> GraphPair {
    match par {
        Parallelism::Tensor { tp } => llama_dense_pair(cfg, tp, false),
        Parallelism::Sequence { tp } => llama_dense_pair(cfg, tp, true),
        Parallelism::FlashDecoding { tp } => flash_decoding_pair(cfg, tp),
        other => panic!("no hand-built golden builder for {}", other.label()),
    }
}

/// Baseline single-device Llama graph (shared by the engine and golden
/// paths).
pub(crate) fn dense_baseline(cfg: &LlamaConfig) -> crate::ir::Graph {
    let t = cfg.tokens();
    let h = cfg.hidden;
    let hd = cfg.head_dim();
    let mut bb = GraphBuilder::new("llama_base", 1);
    bb.layer(None).at("model.py", 10).in_func("model_fwd");
    let bx = bb.parameter("hidden_states", f32s(&[t, h]));
    let bcos = bb.parameter("rotary.cos", f32s(&[t, hd]));
    let bsin = bb.parameter("rotary.sin", f32s(&[t, hd]));
    let mut cur = bx;
    for l in 0..cfg.layers {
        bb.layer(Some(l));
        let w = layer_weights(&mut bb, l, h, cfg.ffn, h, cfg.kv_heads * hd, cfg.ffn);
        cur = decoder_layer(&mut bb, cur, &w, bcos, bsin, cfg, cfg.heads, 1, false);
    }
    bb.layer(None);
    bb.output(cur);
    bb.finish()
}

/// The plan that shards a dense Llama baseline: Megatron column/row
/// placement of the projections, token-sharded residual stream under
/// sequence parallelism, nothing sharded for pure pipeline plans.
fn dense_plan(par: Parallelism) -> crate::transform::ParallelPlan {
    use crate::transform::ParallelPlan;
    let plan = ParallelPlan::new(par);
    // the mesh axis Megatron sharding spans: the whole (flat) mesh for
    // classic plans, the tp axis (axis 1 of [dp, tp]) for 3D-mesh plans —
    // which is what turns the inserted all-reduces into tp-subgroup
    // collectives over `replica_groups={{0..tp-1},{tp..2tp-1},…}`
    let tp_axis = match par {
        Parallelism::Mesh3D { .. } => 1,
        _ => 0,
    };
    let shardy = matches!(
        par,
        Parallelism::Tensor { .. }
            | Parallelism::Sequence { .. }
            | Parallelism::Combined { .. }
            | Parallelism::Mesh3D { .. }
    );
    let mut plan = if shardy {
        plan.shard_on("q_proj", 1, tp_axis)
            .shard_on("k_proj", 1, tp_axis)
            .shard_on("v_proj", 1, tp_axis)
            .shard_on("o_proj", 0, tp_axis)
            .shard_on("gate_proj", 1, tp_axis)
            .shard_on("up_proj", 1, tp_axis)
            .shard_on("down_proj", 0, tp_axis)
    } else {
        plan
    };
    if matches!(par, Parallelism::Sequence { .. }) {
        plan = plan.shard("hidden_states", 0);
    }
    plan
}

fn llama_dense_pair(cfg: &LlamaConfig, tp: u32, seq_parallel: bool) -> GraphPair {
    assert_eq!(
        cfg.kv_heads, cfg.heads,
        "the hand-built golden dense builder is MHA-only (GQA pairs go through the \
         transform engine)"
    );
    assert_eq!(cfg.heads % tp as i64, 0, "heads must divide tp");
    assert_eq!(cfg.ffn % tp as i64, 0, "ffn must divide tp");
    if seq_parallel {
        assert_eq!(cfg.tokens() % tp as i64, 0, "tokens must divide tp for SP");
    }
    let t = cfg.tokens();
    let h = cfg.hidden;
    let hd = cfg.head_dim();

    // ---- baseline ----
    let mut bb = GraphBuilder::new("llama_base", 1);
    bb.layer(None).at("model.py", 10).in_func("model_fwd");
    let bx = bb.parameter("hidden_states", f32s(&[t, h]));
    let bcos = bb.parameter("rotary.cos", f32s(&[t, hd]));
    let bsin = bb.parameter("rotary.sin", f32s(&[t, hd]));
    let mut cur = bx;
    let mut bweights = Vec::new();
    for l in 0..cfg.layers {
        bb.layer(Some(l));
        let w = layer_weights(&mut bb, l, h, cfg.ffn, h, h, cfg.ffn);
        cur = decoder_layer(&mut bb, cur, &w, bcos, bsin, cfg, cfg.heads, 1, false);
        bweights.push(w);
    }
    bb.layer(None);
    bb.output(cur);
    let base = bb.finish();

    // ---- distributed ----
    let mut db = GraphBuilder::new("llama_dist", tp);
    db.layer(None).at("model.py", 10).in_func("model_fwd");
    let t_in = if seq_parallel { t / tp as i64 } else { t };
    let dx = db.parameter("hidden_states", f32s(&[t_in, h]));
    let dcos = db.parameter("rotary.cos", f32s(&[t, hd]));
    let dsin = db.parameter("rotary.sin", f32s(&[t, hd]));
    let nh_local = cfg.heads / tp as i64;
    let mut cur = dx;
    let mut dweights = Vec::new();
    for l in 0..cfg.layers {
        db.layer(Some(l));
        let w = layer_weights(
            &mut db,
            l,
            h,
            cfg.ffn,
            nh_local * hd,
            nh_local * hd,
            cfg.ffn / tp as i64,
        );
        cur = decoder_layer(&mut db, cur, &w, dcos, dsin, cfg, nh_local, tp, seq_parallel);
        dweights.push(w);
    }
    // sequence parallelism keeps the residual sharded; gather at the end
    // (tagged into the last layer so it is verified after the layer chain)
    let out = if seq_parallel {
        db.layer(Some(cfg.layers - 1));
        db.all_gather(cur, 0, ReplicaGroups::full(tp))
    } else {
        cur
    };
    db.layer(None);
    db.output(out);
    let dist = db.finish();

    let mut ann = if seq_parallel {
        vec![Annotation::shard(bx, dx, 0, tp)]
    } else {
        vec![Annotation::replicated(bx, dx)]
    };
    ann.push(Annotation::replicated(bcos, dcos));
    ann.push(Annotation::replicated(bsin, dsin));
    for (bw, dw) in bweights.iter().zip(&dweights) {
        annotate_layer(&mut ann, bw, dw, tp);
    }
    GraphPair::new(base, dist, ann)
}

/// Flash decoding: one query token, KV cache sharded along the sequence
/// dim, two-pass distributed softmax (all-reduce max, then all-reduce sum).
/// The baseline is the single-device flash-style oracle (same order of
/// operations, no collectives).
fn flash_decoding_pair(cfg: &LlamaConfig, tp: u32) -> GraphPair {
    let nh = cfg.heads;
    let hd = cfg.head_dim();
    let s = cfg.seqlen;
    assert_eq!(s % tp as i64, 0, "seqlen must divide tp");
    let s_local = s / tp as i64;

    let build = |cores: u32, s_kv: i64| -> (crate::ir::Graph, Vec<NodeId>) {
        let mut b = GraphBuilder::new(if cores == 1 { "flash_base" } else { "flash_dist" }, cores);
        b.layer(Some(0)).at("flash_decoding.py", 18).in_func("flash_decode");
        let q = b.parameter("q", f32s(&[nh, 1, hd]));
        let kc = b.parameter("k_cache", f32s(&[nh, s_kv, hd]));
        let vc = b.parameter("v_cache", f32s(&[nh, s_kv, hd]));
        b.at("flash_decoding.py", 25);
        let scores = b.dot_general(q, kc, vec![2], vec![2], vec![0], vec![0]); // (nh,1,s_kv)
        let scale = b.constant((hd as f64).sqrt(), DType::F32);
        let scale_b = b.broadcast_scalar(scale, vec![nh, 1, s_kv]);
        let scaled = b.div(scores, scale_b);
        // pass 1: global max
        b.at("flash_decoding.py", 31);
        let m_loc = b.reduce(scaled, ReduceKind::Max, vec![2]); // (nh,1)
        let m = if cores > 1 {
            b.all_reduce(m_loc, ReduceKind::Max, ReplicaGroups::full(cores))
        } else {
            m_loc
        };
        let mb = b.broadcast(m, vec![nh, 1, s_kv], vec![0, 1]);
        let sh = b.sub(scaled, mb);
        let e = b.exp(sh);
        // pass 2: numerator + denominator
        b.at("flash_decoding.py", 42);
        let num = b.dot_general(e, vc, vec![2], vec![1], vec![0], vec![0]); // (nh,1,hd)
        let den = b.reduce(e, ReduceKind::Add, vec![2]); // (nh,1)
        let (num, den) = if cores > 1 {
            (
                b.all_reduce(num, ReduceKind::Add, ReplicaGroups::full(cores)),
                b.all_reduce(den, ReduceKind::Add, ReplicaGroups::full(cores)),
            )
        } else {
            (num, den)
        };
        b.at("flash_decoding.py", 50);
        let den_b = b.broadcast(den, vec![nh, 1, hd], vec![0, 1]);
        let out = b.div(num, den_b);
        b.output(out);
        (b.finish(), vec![q, kc, vc])
    };

    let (base, bp) = build(1, s);
    let (dist, dp) = build(tp, s_local);
    let ann = vec![
        Annotation::replicated(bp[0], dp[0]),
        Annotation::shard(bp[1], dp[1], 1, tp),
        Annotation::shard(bp[2], dp[2], 1, tp),
    ];
    GraphPair::new(base, dist, ann)
}

/// Split baseline inputs into per-core distributed inputs according to the
/// pair's annotations (used by the interpreter differential tests and the
/// numerical baseline verifier).
///
/// A distributed parameter without an annotation — or an annotation naming
/// a baseline parameter the pair does not have — is a typed
/// [`crate::error::ScalifyError::ModelSpec`] (this used to panic via
/// `unwrap_or_else(panic!)`, which took down embedding services on any
/// malformed pair).
pub fn shard_inputs(
    pair: &GraphPair,
    base_inputs: &[crate::interp::Tensor],
) -> crate::error::Result<Vec<Vec<crate::interp::Tensor>>> {
    use crate::error::ScalifyError;
    let cores = pair.dist.num_cores as usize;
    let bparams = pair.base.parameters();
    let dparams = pair.dist.parameters();
    if base_inputs.len() != bparams.len() {
        return Err(ScalifyError::model_spec(format!(
            "shard_inputs got {} baseline inputs for {} baseline parameters",
            base_inputs.len(),
            bparams.len()
        )));
    }
    let mut per_core: Vec<Vec<crate::interp::Tensor>> = vec![Vec::new(); cores];
    for &dp in &dparams {
        let ann = pair
            .annotations
            .iter()
            .find(|a| a.distributed == dp)
            .ok_or_else(|| {
                ScalifyError::model_spec(format!(
                    "no annotation for distributed parameter {} ('{}')",
                    dp.0,
                    match &pair.dist.node(dp).op {
                        crate::ir::Op::Parameter { name, .. } => name.as_str(),
                        _ => "?",
                    }
                ))
            })?;
        if let crate::ir::InputRelation::DeviceIds = &ann.relation {
            for (r, c) in per_core.iter_mut().enumerate() {
                c.push(crate::interp::Tensor::scalar(r as f64, DType::S32));
            }
            continue;
        }
        let bpos = bparams
            .iter()
            .position(|&b| Some(b) == ann.baseline)
            .ok_or_else(|| {
                ScalifyError::model_spec(format!(
                    "annotation for distributed parameter {} names a baseline node \
                     that is not a parameter of the baseline graph",
                    dp.0
                ))
            })?;
        let bval = &base_inputs[bpos];
        match &ann.relation {
            crate::ir::InputRelation::Replicated => {
                for c in per_core.iter_mut() {
                    c.push(bval.clone());
                }
            }
            crate::ir::InputRelation::ShardAlong { dim, parts, axis } => {
                // core r holds shard digit(r, axis): the raw core id on
                // flat meshes, the axis digit on multi-axis meshes (cores
                // in the same subgroup position share a shard)
                let mesh = pair.dist.mesh_view();
                let axis_ok =
                    *axis < mesh.rank() && mesh.size(*axis) == *parts;
                if *dim >= bval.shape.rank()
                    || !axis_ok
                    || bval.shape.dims[*dim] % *parts as i64 != 0
                {
                    return Err(ScalifyError::model_spec(format!(
                        "annotation shards baseline parameter {} along dim {dim} into \
                         {parts} parts (mesh axis {axis}), which does not fit shape {} \
                         on {cores} cores",
                        bpos, bval.shape
                    )));
                }
                let shards = bval.split(*dim, *parts);
                for (r, c) in per_core.iter_mut().enumerate() {
                    let d = mesh.digit(r as u32, *axis) as usize;
                    c.push(shards[d].clone());
                }
            }
            crate::ir::InputRelation::DeviceIds => unreachable!("handled above"),
        }
    }
    Ok(per_core)
}
