//! Tiny demonstration pairs for docs, quickstart and smoke tests.

use super::GraphPair;
use crate::ir::{Annotation, DType, GraphBuilder, NodeId, ReduceKind, ReplicaGroups, Shape};

fn f32s(dims: &[i64]) -> Shape {
    Shape::new(DType::F32, dims.to_vec())
}

/// Figure 3 of the paper: `Y = X·W` baseline vs contracted-dim-sharded
/// tensor parallelism discharged by an all-reduce.
pub fn matmul_allreduce_pair(tp: u32) -> GraphPair {
    let mut bb = GraphBuilder::new("base", 1);
    bb.at("mlp.py", 10).in_func("mlp_fwd");
    let x = bb.parameter("x", f32s(&[4, 8 * tp as i64]));
    let w = bb.parameter("w", f32s(&[8 * tp as i64, 16]));
    let y = bb.matmul(x, w);
    bb.output(y);
    let base = bb.finish();

    let mut db = GraphBuilder::new("dist", tp);
    db.at("mlp.py", 10).in_func("mlp_fwd");
    let xs = db.parameter("x", f32s(&[4, 8]));
    let ws = db.parameter("w", f32s(&[8, 16]));
    db.at("mlp.py", 11);
    let part = db.matmul(xs, ws);
    db.at("mlp.py", 12);
    let red = db.all_reduce(part, ReduceKind::Add, ReplicaGroups::full(tp));
    db.output(red);
    let dist = db.finish();

    let ann = vec![
        Annotation::shard(x, NodeId(0), 1, tp),
        Annotation::shard(w, NodeId(1), 0, tp),
    ];
    GraphPair::new(base, dist, ann)
}

/// Pipeline microbatching demo: the baseline splits the batch into two
/// microbatches, pushes each through a two-stage MLP and concatenates the
/// results — the unrolled GPipe schedule. The distributed graph mirrors it
/// with per-node stage annotations; `buggy = true` skews the second
/// microbatch's slice by one row (the wrong-microbatch-split fault: rows
/// 3..7 instead of 4..8, duplicating row 3 and dropping row 7).
pub fn microbatch_pair(buggy: bool) -> GraphPair {
    let (bsz, h) = (8i64, 4i64);
    let build = |dist: bool, buggy: bool| -> (crate::ir::Graph, Vec<NodeId>) {
        let cores = if dist { 2 } else { 1 };
        let mut b = GraphBuilder::new(if dist { "mb_dist" } else { "mb_base" }, cores);
        b.layer(Some(0)).at("pipeline.py", 30).in_func("microbatch_split");
        let x = b.parameter("x", f32s(&[bsz, h]));
        let w1 = b.parameter("w1", f32s(&[h, h]));
        let w2 = b.parameter("w2", f32s(&[h, h]));
        let mut outs = Vec::new();
        for mb in 0..2i64 {
            b.layer(Some(0)).at("pipeline.py", 40).in_func("microbatch_split");
            let (start, limit) = if buggy && mb == 1 {
                (3, 7) // off-by-one microbatch boundary
            } else {
                (mb * 4, mb * 4 + 4)
            };
            let xs = b.slice_dim(x, 0, start, limit);
            if dist {
                b.stage(Some(0));
            }
            b.layer(Some(0)).at("pipeline.py", 44).in_func("stage_a");
            let h1 = b.matmul(xs, w1);
            let a = b.tanh(h1);
            if dist {
                b.stage(Some(1));
            }
            b.layer(Some(1)).at("pipeline.py", 48).in_func("stage_b");
            let y = b.matmul(a, w2);
            outs.push(y);
        }
        b.layer(Some(1)).at("pipeline.py", 52).in_func("microbatch_concat");
        let out = b.concat(outs, 0);
        b.stage(None);
        b.output(out);
        (b.finish(), vec![x, w1, w2])
    };
    let (base, bp) = build(false, false);
    let (dist, dp) = build(true, buggy);
    let ann = bp
        .into_iter()
        .zip(dp)
        .map(|(b, d)| Annotation::replicated(b, d))
        .collect();
    GraphPair::new(base, dist, ann)
}

/// The Figure-1 BSH pair: `buggy = true` reproduces the incorrect layout
/// transformation (direct reshape instead of reshape+transpose).
pub fn bsh_pair(buggy: bool) -> GraphPair {
    let (s, b, h) = (6i64, 2i64, 16i64);
    let mut bb = GraphBuilder::new("base", 1);
    bb.at("attention.py", 120).in_func("attention_bsh");
    let x = bb.parameter("attn_out", f32s(&[s * b, h]));
    let r = bb.reshape(x, vec![s, b, h]);
    let t = bb.transpose(r, vec![1, 0, 2]);
    bb.output(t);
    let base = bb.finish();

    let mut db = GraphBuilder::new("dist", 2);
    db.at("attention.py", 120).in_func("attention_bsh");
    let xd = db.parameter("attn_out", f32s(&[s * b, h]));
    let out = if buggy {
        db.at("attention.py", 124);
        db.reshape(xd, vec![b, s, h])
    } else {
        let r = db.reshape(xd, vec![s, b, h]);
        db.transpose(r, vec![1, 0, 2])
    };
    db.output(out);
    let dist = db.finish();

    let ann = vec![Annotation::replicated(x, NodeId(0))];
    GraphPair::new(base, dist, ann)
}
