//! Tiny demonstration pairs for docs, quickstart and smoke tests.

use super::GraphPair;
use crate::ir::{Annotation, DType, GraphBuilder, NodeId, ReduceKind, ReplicaGroups, Shape};

fn f32s(dims: &[i64]) -> Shape {
    Shape::new(DType::F32, dims.to_vec())
}

/// Figure 3 of the paper: `Y = X·W` baseline vs contracted-dim-sharded
/// tensor parallelism discharged by an all-reduce.
pub fn matmul_allreduce_pair(tp: u32) -> GraphPair {
    let mut bb = GraphBuilder::new("base", 1);
    bb.at("mlp.py", 10).in_func("mlp_fwd");
    let x = bb.parameter("x", f32s(&[4, 8 * tp as i64]));
    let w = bb.parameter("w", f32s(&[8 * tp as i64, 16]));
    let y = bb.matmul(x, w);
    bb.output(y);
    let base = bb.finish();

    let mut db = GraphBuilder::new("dist", tp);
    db.at("mlp.py", 10).in_func("mlp_fwd");
    let xs = db.parameter("x", f32s(&[4, 8]));
    let ws = db.parameter("w", f32s(&[8, 16]));
    db.at("mlp.py", 11);
    let part = db.matmul(xs, ws);
    db.at("mlp.py", 12);
    let red = db.all_reduce(part, ReduceKind::Add, ReplicaGroups::full(tp));
    db.output(red);
    let dist = db.finish();

    let ann = vec![
        Annotation::shard(x, NodeId(0), 1, tp),
        Annotation::shard(w, NodeId(1), 0, tp),
    ];
    GraphPair::new(base, dist, ann)
}

/// The Figure-1 BSH pair: `buggy = true` reproduces the incorrect layout
/// transformation (direct reshape instead of reshape+transpose).
pub fn bsh_pair(buggy: bool) -> GraphPair {
    let (s, b, h) = (6i64, 2i64, 16i64);
    let mut bb = GraphBuilder::new("base", 1);
    bb.at("attention.py", 120).in_func("attention_bsh");
    let x = bb.parameter("attn_out", f32s(&[s * b, h]));
    let r = bb.reshape(x, vec![s, b, h]);
    let t = bb.transpose(r, vec![1, 0, 2]);
    bb.output(t);
    let base = bb.finish();

    let mut db = GraphBuilder::new("dist", 2);
    db.at("attention.py", 120).in_func("attention_bsh");
    let xd = db.parameter("attn_out", f32s(&[s * b, h]));
    let out = if buggy {
        db.at("attention.py", 124);
        db.reshape(xd, vec![b, s, h])
    } else {
        let r = db.reshape(xd, vec![s, b, h]);
        db.transpose(r, vec![1, 0, 2])
    };
    db.output(out);
    let dist = db.finish();

    let ann = vec![Annotation::replicated(x, NodeId(0))];
    GraphPair::new(base, dist, ann)
}
