//! String interning for source-location metadata.
//!
//! Every IR node carries a source site (`file.py:42`, expression text).
//! Graphs for 126-layer models have hundreds of thousands of nodes whose
//! metadata strings repeat per layer, so we intern them once and store a
//! 4-byte [`Sym`] per node.

use rustc_hash::FxHashMap;

/// Interned string handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Sym(pub u32);

impl Sym {
    /// The empty string, pre-interned in every [`Interner`].
    pub const EMPTY: Sym = Sym(0);
}

/// Append-only string interner.
#[derive(Debug, Default, Clone)]
pub struct Interner {
    map: FxHashMap<String, Sym>,
    strings: Vec<String>,
}

impl Interner {
    /// Create an interner with `""` pre-interned as [`Sym::EMPTY`].
    pub fn new() -> Self {
        let mut i = Interner { map: FxHashMap::default(), strings: Vec::new() };
        let empty = i.intern("");
        debug_assert_eq!(empty, Sym::EMPTY);
        i
    }

    /// Intern a string, returning its stable handle.
    pub fn intern(&mut self, s: &str) -> Sym {
        if let Some(&sym) = self.map.get(s) {
            return sym;
        }
        let sym = Sym(self.strings.len() as u32);
        self.strings.push(s.to_owned());
        self.map.insert(s.to_owned(), sym);
        sym
    }

    /// Resolve a handle back to its string.
    pub fn resolve(&self, sym: Sym) -> &str {
        &self.strings[sym.0 as usize]
    }

    /// Number of distinct interned strings.
    pub fn len(&self) -> usize {
        self.strings.len()
    }

    /// True if only the empty string is interned.
    pub fn is_empty(&self) -> bool {
        self.strings.len() <= 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_roundtrip() {
        let mut i = Interner::new();
        let a = i.intern("attention.py:10");
        let b = i.intern("mlp.py:99");
        let a2 = i.intern("attention.py:10");
        assert_eq!(a, a2);
        assert_ne!(a, b);
        assert_eq!(i.resolve(a), "attention.py:10");
        assert_eq!(i.resolve(b), "mlp.py:99");
    }

    #[test]
    fn empty_is_sym_zero() {
        let mut i = Interner::new();
        assert_eq!(i.intern(""), Sym::EMPTY);
        assert_eq!(i.resolve(Sym::EMPTY), "");
    }

    #[test]
    fn dedup_counts() {
        let mut i = Interner::new();
        for _ in 0..100 {
            i.intern("same");
        }
        assert_eq!(i.len(), 2); // "" + "same"
    }
}
