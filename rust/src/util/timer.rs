//! Lightweight phase timing used by the verifier and the bench harness.

use std::time::{Duration, Instant};

/// Accumulating stopwatch with named phases.
///
/// The verifier records per-phase wall time (partitioning, rewriting,
/// bijection inference, localization) so benches and `--verbose` output can
/// break down where time is spent — the paper's Figure 12 needs exactly
/// this split.
#[derive(Debug, Clone, Default)]
pub struct Stopwatch {
    phases: Vec<(String, Duration)>,
}

impl Stopwatch {
    /// Fresh stopwatch with no recorded phases.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure and record it under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        self.record(name, start.elapsed());
        out
    }

    /// Record an externally measured duration (accumulates across calls).
    pub fn record(&mut self, name: &str, d: Duration) {
        if let Some(entry) = self.phases.iter_mut().find(|(n, _)| n == name) {
            entry.1 += d;
        } else {
            self.phases.push((name.to_owned(), d));
        }
    }

    /// Total across all phases.
    pub fn total(&self) -> Duration {
        self.phases.iter().map(|(_, d)| *d).sum()
    }

    /// Duration of one phase (zero if never recorded).
    pub fn phase(&self, name: &str) -> Duration {
        self.phases
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, d)| *d)
            .unwrap_or_default()
    }

    /// Iterate recorded `(phase, duration)` pairs in insertion order.
    pub fn phases(&self) -> impl Iterator<Item = (&str, Duration)> {
        self.phases.iter().map(|(n, d)| (n.as_str(), *d))
    }

    /// Merge another stopwatch's phases into this one (used when parallel
    /// workers each keep a local stopwatch).
    pub fn merge(&mut self, other: &Stopwatch) {
        for (name, d) in other.phases() {
            self.record(name, d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_same_phase() {
        let mut sw = Stopwatch::new();
        sw.record("rewrite", Duration::from_millis(5));
        sw.record("rewrite", Duration::from_millis(7));
        sw.record("parse", Duration::from_millis(1));
        assert_eq!(sw.phase("rewrite"), Duration::from_millis(12));
        assert_eq!(sw.total(), Duration::from_millis(13));
    }

    #[test]
    fn time_records_result() {
        let mut sw = Stopwatch::new();
        let v = sw.time("work", || 41 + 1);
        assert_eq!(v, 42);
        assert!(sw.phase("work") >= Duration::ZERO);
    }

    #[test]
    fn merge_combines() {
        let mut a = Stopwatch::new();
        a.record("x", Duration::from_millis(2));
        let mut b = Stopwatch::new();
        b.record("x", Duration::from_millis(3));
        b.record("y", Duration::from_millis(4));
        a.merge(&b);
        assert_eq!(a.phase("x"), Duration::from_millis(5));
        assert_eq!(a.phase("y"), Duration::from_millis(4));
    }
}
