//! A small reusable worker pool.
//!
//! The verifier's parallel layer pass used to spawn fresh scoped threads
//! on every `verify` call; a [`crate::verifier::Session`] instead owns
//! one `WorkerPool` for its whole lifetime, so repeated calls reuse warm
//! threads. Jobs are `'static` closures (slices travel behind `Arc`),
//! and [`WorkerPool::run_all`] preserves submission order in its results.
//! [`WorkerPool::submit`] is the fire-and-forget form the service
//! scheduler builds its bounded queue on.
//!
//! Panic isolation: a panicking job is caught on the worker and surfaces
//! as a typed [`ScalifyError::Runtime`] in that job's result slot — never
//! as a `resume_unwind` on the caller, and never as a dead worker thread.
//! The sender lock recovers from poisoning, so one bad job cannot wedge
//! every later `submit` (the daemon-wide "pool sender lock" hang).

use crate::error::{Result, ScalifyError};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Render a `catch_unwind` payload into the message `panic!` carried.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Fixed-size pool of long-lived worker threads.
///
/// The submit side lives behind a `Mutex` so the pool is `Sync` on every
/// supported toolchain (`mpsc::Sender` itself only became `Sync` in
/// Rust 1.72) — a pool can be shared by reference across the service's
/// connection threads.
pub struct WorkerPool {
    tx: Mutex<Option<Sender<Job>>>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx: Arc<Mutex<Receiver<Job>>> = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("scalify-worker-{i}"))
                    .spawn(move || loop {
                        // hold the lock only while receiving, not while
                        // running; recover a poisoned receiver lock — the
                        // queue itself is still sound after a panic
                        let job = {
                            let guard = rx.lock().unwrap_or_else(|p| p.into_inner());
                            guard.recv()
                        };
                        match job {
                            // a panicking job must not kill the worker:
                            // result-returning callers observe the panic
                            // through their own catch_unwind wrapper
                            Ok(job) => {
                                let _ = catch_unwind(AssertUnwindSafe(move || {
                                    // panic/delay faults fire inside the
                                    // unwind guard, like any job panic
                                    crate::faults::disturb("pool-job");
                                    job()
                                }));
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawning worker thread")
            })
            .collect();
        WorkerPool { tx: Mutex::new(Some(tx)), workers }
    }

    /// Worker count.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue one job without waiting for it (fire-and-forget). The
    /// caller is responsible for any completion signalling; see
    /// [`crate::service::Scheduler`] for the bounded, result-returning
    /// layer on top of this. Errors (typed, never a panic) only when the
    /// pool has shut down.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) -> Result<()> {
        // a caller that panicked mid-section may have poisoned the lock;
        // the sender is still sound, so recover instead of propagating
        let guard = self.tx.lock().unwrap_or_else(|p| p.into_inner());
        let tx = guard
            .as_ref()
            .ok_or_else(|| ScalifyError::runtime("worker pool already shut down"))?;
        tx.send(Box::new(job))
            .map_err(|_| ScalifyError::runtime("worker pool hung up"))
    }

    /// Run every job on the pool and return their results in submission
    /// order. Blocks until all jobs finish. A panicking job yields a
    /// typed `Err(ScalifyError::Runtime)` in its slot — the other jobs'
    /// results are unaffected and the pool stays usable.
    pub fn run_all<T, F>(&self, jobs: Vec<F>) -> Vec<Result<T>>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = jobs.len();
        let (res_tx, res_rx) = channel::<(usize, std::thread::Result<T>)>();
        let mut slots: Vec<Option<Result<T>>> = (0..n).map(|_| None).collect();
        let mut pending = 0usize;
        for (i, job) in jobs.into_iter().enumerate() {
            let res_tx = res_tx.clone();
            match self.submit(move || {
                let out = catch_unwind(AssertUnwindSafe(job));
                // receiver only disappears if the caller itself died
                let _ = res_tx.send((i, out));
            }) {
                Ok(()) => pending += 1,
                Err(e) => slots[i] = Some(Err(e)),
            }
        }
        drop(res_tx);
        for _ in 0..pending {
            let Ok((i, out)) = res_rx.recv() else { break };
            slots[i] = Some(out.map_err(|panic| {
                ScalifyError::runtime(format!(
                    "worker job panicked: {}",
                    panic_message(panic.as_ref())
                ))
            }));
        }
        slots
            .into_iter()
            .map(|s| {
                s.unwrap_or_else(|| {
                    Err(ScalifyError::runtime("worker pool dropped a job result"))
                })
            })
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // closing the channel wakes every worker with RecvError (a
        // poisoned lock still holds the sender that must be dropped)
        match self.tx.lock() {
            Ok(mut guard) => {
                guard.take();
            }
            Err(poisoned) => {
                poisoned.into_inner().take();
            }
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unwrap_all<T>(results: Vec<Result<T>>) -> Vec<T> {
        results.into_iter().map(|r| r.unwrap()).collect()
    }

    #[test]
    fn runs_jobs_in_submission_order() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<_> = (0..32).map(|i| move || i * 2).collect();
        let out = unwrap_all(pool.run_all(jobs));
        assert_eq!(out, (0..32).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn pool_is_reusable_across_batches() {
        let pool = WorkerPool::new(2);
        for round in 0..3 {
            let jobs: Vec<_> = (0..8).map(|i| move || i + round).collect();
            assert_eq!(unwrap_all(pool.run_all(jobs)).len(), 8);
        }
        assert_eq!(pool.threads(), 2);
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.threads(), 1);
        assert_eq!(unwrap_all(pool.run_all(vec![|| 41 + 1])), vec![42]);
    }

    #[test]
    fn submit_is_fire_and_forget() {
        let pool = WorkerPool::new(2);
        let (tx, rx) = std::sync::mpsc::channel();
        for i in 0..8 {
            let tx = tx.clone();
            pool.submit(move || {
                let _ = tx.send(i);
            })
            .unwrap();
        }
        drop(tx);
        let mut got: Vec<i32> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn job_panic_is_a_typed_error_and_the_pool_survives() {
        let pool = WorkerPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() -> i32 + Send>> =
            vec![Box::new(|| 1), Box::new(|| panic!("boom")), Box::new(|| 3)];
        let out = pool.run_all(jobs);
        assert_eq!(*out[0].as_ref().unwrap(), 1);
        let err = out[1].as_ref().unwrap_err();
        assert!(matches!(err, ScalifyError::Runtime(_)), "{err:?}");
        assert!(err.message().contains("boom"), "{err}");
        assert_eq!(*out[2].as_ref().unwrap(), 3);
        // both workers are still alive and serving
        assert_eq!(unwrap_all(pool.run_all(vec![|| 7, || 8])), vec![7, 8]);
    }

    #[test]
    fn panicking_fire_and_forget_job_does_not_kill_the_worker() {
        let pool = WorkerPool::new(1);
        pool.submit(|| panic!("dropped on the floor")).unwrap();
        // the single worker must survive to run this
        assert_eq!(unwrap_all(pool.run_all(vec![|| 5])), vec![5]);
    }
}
