//! A small reusable worker pool.
//!
//! The verifier's speculative parallel pass used to spawn fresh scoped
//! threads on every `verify` call; a [`crate::verifier::Session`] instead
//! owns one `WorkerPool` for its whole lifetime, so repeated calls reuse
//! warm threads. Jobs are `'static` closures (slices travel behind `Arc`),
//! and [`WorkerPool::run_all`] preserves submission order in its results.
//! [`WorkerPool::submit`] is the fire-and-forget form the service
//! scheduler builds its bounded queue on.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size pool of long-lived worker threads.
///
/// The submit side lives behind a `Mutex` so the pool is `Sync` on every
/// supported toolchain (`mpsc::Sender` itself only became `Sync` in
/// Rust 1.72) — a pool can be shared by reference across the service's
/// connection threads.
pub struct WorkerPool {
    tx: Mutex<Option<Sender<Job>>>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx: Arc<Mutex<Receiver<Job>>> = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("scalify-worker-{i}"))
                    .spawn(move || loop {
                        // hold the lock only while receiving, not while running
                        let job = match rx.lock() {
                            Ok(guard) => guard.recv(),
                            Err(_) => break,
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawning worker thread")
            })
            .collect();
        WorkerPool { tx: Mutex::new(Some(tx)), workers }
    }

    /// Worker count.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue one job without waiting for it (fire-and-forget). The
    /// caller is responsible for any completion signalling; see
    /// [`crate::service::Scheduler`] for the bounded, result-returning
    /// layer on top of this.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        let guard = self.tx.lock().expect("pool sender lock");
        guard
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(job))
            .expect("worker pool hung up");
    }

    /// Run every job on the pool and return their results in submission
    /// order. Blocks until all jobs finish; a panicking job is re-raised
    /// here (on the caller), not in the worker.
    pub fn run_all<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = jobs.len();
        let (res_tx, res_rx) = channel::<(usize, std::thread::Result<T>)>();
        for (i, job) in jobs.into_iter().enumerate() {
            let res_tx = res_tx.clone();
            self.submit(move || {
                let out = catch_unwind(AssertUnwindSafe(job));
                // receiver only disappears if the caller itself died
                let _ = res_tx.send((i, out));
            });
        }
        drop(res_tx);
        let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, out) = res_rx.recv().expect("worker pool hung up");
            match out {
                Ok(v) => results[i] = Some(v),
                Err(panic) => resume_unwind(panic),
            }
        }
        results.into_iter().map(|r| r.expect("missing job result")).collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // closing the channel wakes every worker with RecvError (a
        // poisoned lock still holds the sender that must be dropped)
        match self.tx.lock() {
            Ok(mut guard) => {
                guard.take();
            }
            Err(poisoned) => {
                poisoned.into_inner().take();
            }
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_jobs_in_submission_order() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<_> = (0..32).map(|i| move || i * 2).collect();
        let out = pool.run_all(jobs);
        assert_eq!(out, (0..32).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn pool_is_reusable_across_batches() {
        let pool = WorkerPool::new(2);
        for round in 0..3 {
            let jobs: Vec<_> = (0..8).map(|i| move || i + round).collect();
            assert_eq!(pool.run_all(jobs).len(), 8);
        }
        assert_eq!(pool.threads(), 2);
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.threads(), 1);
        assert_eq!(pool.run_all(vec![|| 41 + 1]), vec![42]);
    }

    #[test]
    fn submit_is_fire_and_forget() {
        let pool = WorkerPool::new(2);
        let (tx, rx) = std::sync::mpsc::channel();
        for i in 0..8 {
            let tx = tx.clone();
            pool.submit(move || {
                let _ = tx.send(i);
            });
        }
        drop(tx);
        let mut got: Vec<i32> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..8).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn job_panic_propagates_to_caller() {
        let pool = WorkerPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() -> i32 + Send>> =
            vec![Box::new(|| 1), Box::new(|| panic!("boom"))];
        pool.run_all(jobs);
    }
}
