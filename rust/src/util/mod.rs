//! Small shared utilities: deterministic PRNG, string interning, timing.
//!
//! The build environment is fully offline, so instead of pulling `rand` /
//! `string-interner` we carry the ~100 lines ourselves.

pub mod prng;
pub mod intern;
pub mod pool;
pub mod timer;

pub use intern::{Interner, Sym};
pub use pool::{panic_message, WorkerPool};
pub use prng::Prng;
pub use timer::Stopwatch;

/// Human-readable duration, matching the paper's "1m 40s" style.
pub fn fmt_duration(d: std::time::Duration) -> String {
    if d.as_micros() < 1_000 {
        return format!("{}us", d.as_micros());
    }
    let total_ms = d.as_millis();
    if total_ms < 1_000 {
        return format!("{:.1}ms", d.as_secs_f64() * 1e3);
    }
    let secs = d.as_secs_f64();
    if secs < 60.0 {
        return format!("{:.1}s", secs);
    }
    let mins = (secs / 60.0).floor() as u64;
    let rem = secs - (mins as f64) * 60.0;
    format!("{}m {:.0}s", mins, rem)
}

/// Integer ceil-div used all over shard-size computations.
pub fn ceil_div(a: i64, b: i64) -> i64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn fmt_duration_bands() {
        assert_eq!(fmt_duration(Duration::from_millis(12)), "12.0ms");
        assert_eq!(fmt_duration(Duration::from_micros(250)), "250us");
        assert_eq!(fmt_duration(Duration::from_millis(4_200)), "4.2s");
        assert_eq!(fmt_duration(Duration::from_secs(100)), "1m 40s");
        assert_eq!(fmt_duration(Duration::from_secs(181)), "3m 1s");
    }

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 32), 1);
        assert_eq!(ceil_div(0, 4), 0);
    }
}
