//! SplitMix64 + xoshiro256** deterministic PRNG.
//!
//! Used by the interpreter's input generation, the bug-injection fuzzer and
//! the in-repo property-testing harness. Deterministic by construction so
//! every test failure is reproducible from its seed.

/// Deterministic PRNG (xoshiro256** seeded via SplitMix64).
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Prng {
    /// Create a PRNG from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire-style rejection-free-enough bound for test workloads.
        self.next_u64() % n
    }

    /// Uniform usize in `[lo, hi)` (half-open).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f32 in `[-1, 1)`, the distribution used for synthetic tensors.
    pub fn unit_f32(&mut self) -> f32 {
        let v = (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32; // [0,1)
        v * 2.0 - 1.0
    }

    /// Fill a buffer with `unit_f32` samples.
    pub fn fill_f32(&mut self, buf: &mut [f32]) {
        for v in buf.iter_mut() {
            *v = self.unit_f32();
        }
    }

    /// Bernoulli draw with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        ((self.next_u64() >> 11) as f64 / (1u64 << 53) as f64) < p
    }

    /// Choose an element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_clones() {
        let mut a = Prng::new(7);
        let mut b = Prng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn unit_f32_in_range() {
        let mut p = Prng::new(42);
        for _ in 0..10_000 {
            let v = p.unit_f32();
            assert!((-1.0..1.0).contains(&v), "out of range: {v}");
        }
    }

    #[test]
    fn below_in_range() {
        let mut p = Prng::new(3);
        for _ in 0..1000 {
            assert!(p.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        p.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
